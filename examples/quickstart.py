"""Quickstart: the ARMS controller in ~40 lines.

Drives the threshold-free tiering controller (paper Alg. 1+2, §4) with a
synthetic workload whose hot set shifts halfway through, and prints how the
controller detects the change (PHT -> recency mode) and re-populates the
fast tier.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import ARMSConfig, arms_step, init_state

N_PAGES, FAST_CAPACITY = 512, 64
cfg = ARMSConfig()
state = init_state(N_PAGES, cfg)
rng = np.random.default_rng(0)

hot = np.arange(FAST_CAPACITY)                    # initial hot set
for interval in range(40):
    if interval == 20:                            # hot set SHIFTS
        hot = np.arange(256, 256 + FAST_CAPACITY)

    counts = np.zeros(N_PAGES)
    counts[hot] = rng.poisson(30, FAST_CAPACITY)  # hot pages
    counts += rng.poisson(0.3, N_PAGES)           # background noise

    in_fast = np.asarray(state.in_fast)
    slow_share = counts[~in_fast].sum() / max(counts.sum(), 1e-9)

    state, plan = arms_step(state, jnp.asarray(counts),
                            slow_bw_frac=float(slow_share),
                            app_bw_frac=0.3, cfg=cfg, k=FAST_CAPACITY)

    hot_resident = int(np.asarray(state.in_fast)[hot].sum())
    print(f"t={interval:2d} mode={'RECENCY' if int(state.mode) else 'history'}"
          f" migrated={int(plan.count):2d}"
          f" hot-set residency={hot_resident}/{FAST_CAPACITY}")

assert int(np.asarray(state.in_fast)[hot].sum()) == FAST_CAPACITY
print("\nnew hot set fully promoted after the shift — no thresholds, "
      "no tuning.")
