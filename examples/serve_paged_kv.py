"""Serving example (deliverable b): batched greedy decoding with the
ARMS-tiered paged KV cache — the paper's technique as a serving feature.

The attention KV cache is paged across a fast (HBM) pool and a slow (host)
pool; per-page attention mass drives the ARMS controller, which promotes
the hot pages under its bandwidth-aware batched migration plan.

Run:  PYTHONPATH=src python examples/serve_paged_kv.py [arch] [tokens]
"""
import sys

from repro.launch.serve import serve

arch = sys.argv[1] if len(sys.argv) > 1 else "granite-8b"
tokens = int(sys.argv[2]) if len(sys.argv) > 2 else 64

rep = serve(arch, n_tokens=tokens, batch=2)
fast_mass = rep.fast_mass
print(f"\nfast-tier attention-mass share over time: "
      f"{fast_mass[0]:.2f} -> {fast_mass[-1]:.2f}")
assert fast_mass[-1] > 0.3, "ARMS should capture the hot attention mass"
print("ok")
