"""End-to-end training driver (deliverable b): trains a reduced
granite-8b-family model for a few hundred steps on CPU with the full
substrate — synthetic zipf data pipeline with prefetch, AdamW, async
checkpointing, preemption guard, straggler monitor — and verifies the loss
goes down.

Run:  PYTHONPATH=src python examples/train_tiered_lm.py [steps]
"""
import sys
import tempfile

from repro.launch.train import train

steps = int(sys.argv[1]) if len(sys.argv) > 1 else 200

with tempfile.TemporaryDirectory() as ckpt_dir:
    losses = train("granite-8b", n_steps=steps, batch=8, seq=128,
                   ckpt_dir=ckpt_dir, ckpt_every=50)

first, last = losses[0], sum(losses[-10:]) / 10
print(f"\nloss {first:.3f} -> {last:.3f} over {steps} steps "
      f"({(1 - last / first) * 100:.1f}% reduction)")
assert last < first, "training should reduce the loss"
print("ok")
