"""Reproduce the paper's headline comparison (Fig. 7) on one workload.

Runs ARMS against HeMem, Memtis, and TPP — each both untuned and TUNED —
on the tiered-memory simulator (pmem-large machine model, PEBS sampling
noise, 1:8 fast:slow ratio) and prints normalized performance.  Every
tuning study runs as ONE lane-batched sweep in the compiled scan engine
(`tuning.tune` -> `scan_engine.sweep_policy_configs`): the whole budget is
a single compiled dispatch, all configs scored under a shared CRN noise
field.

Workloads are declarative ``WorkloadSpec`` pytrees (`workloads.spec`):
the numpy reference engine replays their materialized f32 trace, while
the scan engine synthesizes the same counts on device with no [T, n]
array at all — which is also how the closing phase-shift scenario below
is run: `phases([gups, silo-tpcc])` is *declared* with a combinator, not
hand-coded as a new generator.

Run:  PYTHONPATH=src python examples/simulate_tiering.py [workload]
"""
import sys

from repro.baselines.arms_policy import ARMSPolicy, ARMSSpec
from repro.baselines.hemem import HeMemPolicy, HeMemSpec
from repro.baselines.memtis import MemtisPolicy
from repro.baselines.static import AllSlowPolicy
from repro.baselines.tpp import TPPPolicy
from repro.simulator import scan_engine, tuning, workload_spec, workloads
from repro.simulator.engine import run
from repro.simulator.machine import PMEM_LARGE

wl = sys.argv[1] if len(sys.argv) > 1 else "gups"
T, n = 300, 2048
k = n // 8
spec = workloads.spec(wl, T=T)            # declarative workload
trace = spec.materialize(T, n)            # numpy-engine path (f32, [T, n])

results = {}
for name, pol in [("all-slow", AllSlowPolicy()), ("hemem", HeMemPolicy()),
                  ("memtis", MemtisPolicy()), ("tpp", TPPPolicy()),
                  ("arms", ARMSPolicy())]:
    results[name] = run(pol, trace, PMEM_LARGE, k)

tuned = {}
for fam, tune_fn in [("hemem", tuning.tune_hemem),
                     ("memtis", tuning.tune_memtis),
                     ("tpp", tuning.tune_tpp)]:
    print(f"tuning {fam} on {wl} (24-config lane-batched sweep) ...")
    _best_cfg, tuned[fam], _rows = tune_fn(trace, PMEM_LARGE, k, budget=24,
                                           search_seed=0, sim_seed=0)

base = results["all-slow"].exec_time_s
print(f"\nworkload={wl}  (speedup over all-data-in-slow-tier; Fig. 1/7)")
for name, res in results.items():
    print(f"  {name:12s} {base / res.exec_time_s:5.2f}x   "
          f"promotions={res.promotions:5d} wasteful={res.wasteful:4d}")
for fam, res in tuned.items():
    print(f"  {'tuned-' + fam:12s} {base / res.exec_time_s:5.2f}x")
a = results["arms"].exec_time_s
print(f"\nARMS vs default HeMem: "
      f"{results['hemem'].exec_time_s / a:.2f}x; "
      f"vs tuned-HeMem: {tuned['hemem'].exec_time_s / a:.3f} "
      f"(paper: within 3%); vs tuned-Memtis: "
      f"{tuned['memtis'].exec_time_s / a:.3f}; vs tuned-TPP: "
      f"{tuned['tpp'].exec_time_s / a:.3f}")

# --- composed scenario: a phase shift DECLARED with a combinator ---------
# First half gups (relocating hot set), second half silo-tpcc ("latest"
# sliding window) — the paper's adaptivity story in one spec.  Runs
# device-synthesized in the scan engine: no [T, n] trace is built.
combo = workload_spec.phases(
    [workloads.spec("gups", T=T), workloads.spec("silo-tpcc", T=T)], [T // 2])
print(f"\ncomposed scenario {workload_spec.label_of(combo)} "
      f"(device-synthesized, no [T, n] trace):")
for name, pspec in [("hemem", HeMemSpec.make()), ("arms", ARMSSpec.make())]:
    res = scan_engine.simulate_workload(pspec, combo, PMEM_LARGE, k, T, n)
    print(f"  {name:6s} exec={res.exec_time_s:7.3f}s "
          f"promotions={res.promotions:5d} wasteful={res.wasteful:4d} "
          f"recall={res.hot_recall:.3f}")
