"""Reproduce the paper's headline comparison (Fig. 7) on one workload.

Runs ARMS against HeMem, Memtis, and TPP — each both untuned and TUNED —
on the tiered-memory simulator (pmem-large machine model, PEBS sampling
noise, 1:8 fast:slow ratio) and prints normalized performance, then
extends the comparison across the MACHINE axis (emulated-CXL NUMA and a
three-tier DRAM/CXL/PMem chain) — the full robustness question of the
paper in one axis-product call.

Everything routes through the spec trilogy:
  * policies are ``PolicySpec`` pytrees (baselines/protocol.py);
  * workloads are ``WorkloadSpec`` pytrees (`workloads.spec`) that the
    scan engine synthesizes on device — no [T, n] trace exists for the
    compiled runs (the numpy reference engine replays the materialized
    f32 trace of the same spec);
  * machines are ``TieredMachineSpec`` pytrees resolved by registry name
    (`machines.get`) — two- and three-tier chains batch in ONE dispatch.

``experiment.sweep(policies=..., workloads=..., machines=...)`` flattens
the axis product into lanes of one compiled dispatch per policy family;
``tuning.tune`` rides the same API with the config grid on the policy
axis.

Run:  PYTHONPATH=src python examples/simulate_tiering.py [workload]
"""
import sys

from repro.simulator import experiment, tuning, workload_spec, workloads

wl = sys.argv[1] if len(sys.argv) > 1 else "gups"
T, n = 300, 2048
k = n // 8
spec = workloads.spec(wl, T=T)            # declarative workload

# --- untuned comparison: one axis-product sweep (policy axis) ------------
POLICIES = ["all-slow", "hemem", "memtis", "tpp", "arms"]
res = experiment.sweep(POLICIES, workloads=[spec], machines=["pmem-large"],
                       k=k, T=T, n=n)
results = {p: res.at(policy=p) for p in POLICIES}

tuned = {}
for fam in ("hemem", "memtis", "tpp"):
    print(f"tuning {fam} on {wl} (24-config lane-batched sweep) ...")
    out = tuning.tune(fam, None, "pmem-large", k, budget=24,
                      search_seed=0, sim_seed=0, workloads=[spec], T=T, n=n)
    _best_cfg, tuned[fam], _rows = next(iter(out.values()))

base = results["all-slow"].exec_time_s
print(f"\nworkload={wl}  (speedup over all-data-in-slow-tier; Fig. 1/7)")
for name, r in results.items():
    print(f"  {name:12s} {base / r.exec_time_s:5.2f}x   "
          f"promotions={r.promotions:5d} wasteful={r.wasteful:4d}")
for fam, r in tuned.items():
    print(f"  {'tuned-' + fam:12s} {base / r.exec_time_s:5.2f}x")
a = results["arms"].exec_time_s
print(f"\nARMS vs default HeMem: "
      f"{results['hemem'].exec_time_s / a:.2f}x; "
      f"vs tuned-HeMem: {tuned['hemem'].exec_time_s / a:.3f} "
      f"(paper: within 3%); vs tuned-Memtis: "
      f"{tuned['memtis'].exec_time_s / a:.3f}; vs tuned-TPP: "
      f"{tuned['tpp'].exec_time_s / a:.3f}")

# --- machine axis: robustness across hardware, no re-tuning --------------
# Two-tier PMem and NUMA presets plus the three-tier DRAM/CXL/PMem chain,
# all lanes of one dispatch per family (tier depths neutrally padded).
MACHS = ["pmem-large", "numa", "dram-cxl-pmem"]
mres = experiment.sweep(["hemem", "arms"], workloads=[spec],
                        machines=MACHS, k=k, T=T, n=n)
print(f"\nARMS vs HeMem across machines ({wl}; P x M axis product, "
      f"one dispatch per family):")
for m in MACHS:
    h = mres.at(policy="hemem", machine=m).exec_time_s
    ar = mres.at(policy="arms", machine=m).exec_time_s
    print(f"  {m:14s} arms_vs_hemem={h / ar:5.2f}x  (arms {ar:7.3f}s)")

# --- composed scenario: a phase shift DECLARED with a combinator ---------
# First half gups (relocating hot set), second half silo-tpcc ("latest"
# sliding window) — the paper's adaptivity story in one spec, swept
# against both a two- and a three-tier machine in one call.
combo = workload_spec.phases(
    [workloads.spec("gups", T=T), workloads.spec("silo-tpcc", T=T)], [T // 2])
cres = experiment.sweep(["hemem", "arms"], workloads=[combo],
                        machines=["pmem-large", "dram-cxl-pmem"],
                        k=k, T=T, n=n)
print(f"\ncomposed scenario {workload_spec.label_of(combo)} "
      f"(device-synthesized, no [T, n] trace):")
for coords, r in cres.items():
    print(f"  {coords['policy']:6s} on {coords['machine']:14s} "
          f"exec={r.exec_time_s:7.3f}s promotions={r.promotions:5d} "
          f"wasteful={r.wasteful:4d} recall={r.hot_recall:.3f}")
