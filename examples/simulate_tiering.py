"""Reproduce the paper's headline comparison (Fig. 7) on one workload.

Runs ARMS against HeMem (default + tuned), Memtis, and TPP on the
tiered-memory simulator (pmem-large machine model, PEBS sampling noise,
1:8 fast:slow ratio) and prints normalized performance.

Run:  PYTHONPATH=src python examples/simulate_tiering.py [workload]
"""
import sys

from repro.baselines.arms_policy import ARMSPolicy
from repro.baselines.hemem import HeMemPolicy
from repro.baselines.memtis import MemtisPolicy
from repro.baselines.static import AllSlowPolicy
from repro.baselines.tpp import TPPPolicy
from repro.simulator import tuning, workloads
from repro.simulator.engine import run
from repro.simulator.machine import PMEM_LARGE

wl = sys.argv[1] if len(sys.argv) > 1 else "gups"
T, n = 300, 2048
k = n // 8
trace = workloads.make(wl, T=T, n=n)

results = {}
for name, pol in [("all-slow", AllSlowPolicy()), ("hemem", HeMemPolicy()),
                  ("memtis", MemtisPolicy()), ("tpp", TPPPolicy()),
                  ("arms", ARMSPolicy())]:
    results[name] = run(pol, trace, PMEM_LARGE, k)

print(f"tuning HeMem on {wl} (24-config search) ...")
_best_cfg, tuned, _ = tuning.tune_hemem(trace, PMEM_LARGE, k, budget=24)

base = results["all-slow"].exec_time_s
print(f"\nworkload={wl}  (speedup over all-data-in-slow-tier; Fig. 1/7)")
for name, res in results.items():
    print(f"  {name:12s} {base / res.exec_time_s:5.2f}x   "
          f"promotions={res.promotions:5d} wasteful={res.wasteful:4d}")
print(f"  {'tuned-hemem':12s} {base / tuned.exec_time_s:5.2f}x")
print(f"\nARMS vs default HeMem: "
      f"{results['hemem'].exec_time_s / results['arms'].exec_time_s:.2f}x; "
      f"vs tuned: "
      f"{tuned.exec_time_s / results['arms'].exec_time_s:.3f} "
      f"(paper: within 3%)")
