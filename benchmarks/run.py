"""Benchmark harness entry point (deliverable d).

One function per paper table/figure (benchmarks/paper_tables.py) plus
framework-layer benches (kernels, tiered serving, roofline summary).
Prints ``name,us_per_call,derived`` CSV and a paper-claims validation
report; exits non-zero if a reproduced claim fails.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the tuning study (slowest bench)")
    args, _ = ap.parse_known_args()

    from benchmarks import common, framework, paper_tables as pt
    common.header()
    if not args.quick:
        pt.bench_tuning_study()
        pt.bench_tuned_baselines()
        pt.bench_arms_sweep()
    # always-on gates: tuning sweeps must stay lane-batched in the compiled
    # scan engine (a silent fallback to a sequential loop fails CI here),
    # workload-lane sweeps must stay on the device-synthesis path (never
    # host-materializing a [T, n] trace), and machine-axis sweeps must
    # compile to ONE P*M-lane dispatch (no per-machine recompiles) —
    # recorded in BENCH_machines.json.  The kernel gate asserts the fused
    # interval path stays bitwise-identical to the unfused scan under CRN
    # and that default sweeps stream (no [T, ...] timeline allocation) —
    # recorded in BENCH_kernels.json.  The search gate asserts every
    # ASHA/CE round stays ONE compiled dispatch per family and that ASHA
    # reaches within 3% of the exhaustive grid best at <= 40% of its
    # lane-intervals; the transfer gate asserts the tuned-on-A/deployed-
    # on-B matrix's exact grid-strategy invariants over >= 3 machine
    # presets — both recorded in BENCH_search.json.  The robustness gate
    # runs the adversarial-scenario leaderboard (all eight policy
    # families x scenarios x machines as ONE dispatch per family, ARMS
    # worst-case slowdown bounded) — recorded in BENCH_robustness.json.
    # The serving gate closes the model-stack loop: decode traffic on the
    # policy-generic tiered paged-KV pool, captured -> fitted -> swept
    # with the trace-replay lane, one dispatch per family — recorded in
    # BENCH_serving.json.  The sharding gate runs the mesh sweep fabric
    # (union dispatch + shard_map lane sharding) in a forced-8-device
    # subprocess: bitwise equality at every mesh size, ONE dispatch for
    # the whole mixed-family board, throughput within noise — recorded
    # in BENCH_sharding.json.
    pt.bench_baseline_sweep_gate()
    pt.bench_workload_sweep_gate()
    pt.bench_machine_sweep_gate()
    pt.bench_kernel_gate()
    pt.bench_search_gate()
    pt.bench_transfer_matrix()
    pt.bench_machine_sensitivity()
    pt.bench_robustness_gate()
    pt.bench_serving_gate()
    pt.bench_sharding_gate()
    pt.bench_main_comparison()
    pt.bench_migrations()
    pt.bench_adaptivity()
    pt.bench_tier_ratios()
    pt.bench_scaling()
    pt.bench_numa_machine()
    pt.bench_overheads()
    framework.bench_kernels()
    framework.bench_tiered_serving()
    framework.bench_sparse_serving()
    framework.bench_roofline_summary()

    print("\n=== paper-claim validation ===")
    failed = 0
    for name, value, target, ok in pt.CLAIMS:
        status = "PASS" if ok else "FAIL"
        if not ok:
            failed += 1
        print(f"[{status}] {name}: measured {value} (target {target})")
    print(f"=== {len(pt.CLAIMS) - failed}/{len(pt.CLAIMS)} claims hold ===")
    raise SystemExit(1 if failed else 0)


if __name__ == "__main__":
    main()
