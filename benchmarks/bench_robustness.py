"""Robustness leaderboard: every policy family vs the adversarial
thrashing suite (``simulator/scenarios.py``), scored across machines.

Each cell of the scenario x machine grid runs every policy under shared
CRN noise; a cell's score is the slowdown vs the oracle placement on the
SAME cell (exec_time / oracle exec_time), plus a thrash metric — the
wasteful-migration fraction (migrations whose page bounced straight
back).  A policy's leaderboard row is its worst-case and mean slowdown
over the whole grid: the paper's robustness claim is about the tail, not
the average, so the board is sorted by worst case.

The whole board — every policy x scenario x machine x CRN lane — is ONE
``experiment.sweep`` call, which the union fabric (simulator/fabric.py)
compiles to literally ONE lane-batched dispatch for the whole mixed-family
panel (counted via ``scan_engine.count_dispatches``; the gate in
benchmarks/paper_tables.py fails CI if the board splinters into
per-family or per-cell dispatches).

Usage: PYTHONPATH=src:. python benchmarks/bench_robustness.py \
           [--out BENCH_robustness.json]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.simulator import experiment, scan_engine, scenarios

#: leaderboard axes: every policy family, the full adversarial suite,
#: and one machine per tier topology (2-tier PMEM, 2-tier CXL, 3-tier).
POLICIES = ("oracle", "arms", "hemem", "memtis", "tpp",
            "hybridtier", "jenga", "tierbpf")
MACHINES = ("pmem-large", "cxl-1hop", "dram-cxl-pmem")


def run_robustness(T: int = 240, n: int = 1024, k: int = 128,
                   machines=MACHINES, policies=POLICIES,
                   sim_seed: int = 0, wl_seed: int = 0) -> dict:
    """Run the leaderboard sweep; returns the BENCH_robustness record."""
    suite = scenarios.suite(n, k)
    n_families = len({type(experiment.policy_spec(p)) for p in policies})
    t0 = time.time()
    with scan_engine.count_dispatches() as ctr:
        res = experiment.sweep(list(policies), workloads=suite,
                               machines=list(machines), k=k, T=T, n=n,
                               sim_seed=sim_seed, wl_seed=wl_seed)
    wall = time.time() - t0
    dispatches = ctr.count

    scen = res.axes["workload"]
    mach = res.axes["machine"]
    oracle = {(w, m): res.at(policy="oracle", workload=w,
                             machine=m).exec_time_s
              for w in scen for m in mach}
    board = {}
    for p in policies:
        cells = []
        for w in scen:
            for m in mach:
                r = res.at(policy=p, workload=w, machine=m)
                moves = r.promotions + r.demotions
                cells.append(dict(
                    scenario=w, machine=m,
                    slowdown=r.exec_time_s / oracle[(w, m)],
                    thrash=r.wasteful / max(moves, 1),
                    migrations=int(moves)))
        worst = max(cells, key=lambda c: c["slowdown"])
        board[str(p)] = dict(
            worst_slowdown=round(worst["slowdown"], 4),
            worst_cell=f"{worst['scenario']}@{worst['machine']}",
            mean_slowdown=round(sum(c["slowdown"] for c in cells)
                                / len(cells), 4),
            worst_thrash=round(max(c["thrash"] for c in cells), 4),
            mean_thrash=round(sum(c["thrash"] for c in cells)
                              / len(cells), 4),
            cells=[dict(c, slowdown=round(c["slowdown"], 4),
                        thrash=round(c["thrash"], 4)) for c in cells])
    ranked = sorted(board, key=lambda p: board[p]["worst_slowdown"])
    return dict(T=T, n_pages=n, k=k, scenarios=scen, machines=mach,
                policies=list(map(str, policies)),
                n_families=n_families, dispatches=dispatches,
                single_dispatch=dispatches == 1,
                wall_s=round(wall, 3),
                ranking=ranked, leaderboard=board)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_robustness.json")
    ap.add_argument("--T", type=int, default=240)
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--k", type=int, default=128)
    args = ap.parse_args()

    rec = run_robustness(T=args.T, n=args.n, k=args.k)
    # merge: keep the "gate" record CI wrote, replace the full-scale one.
    try:
        with open(args.out) as f:
            out = json.load(f)
    except (OSError, ValueError):
        out = {}
    out["full"] = rec
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"dispatches={rec['dispatches']} (families={rec['n_families']}) "
          f"wall={rec['wall_s']}s")
    hdr = f"{'policy':<12} {'worst':>7} {'mean':>7} {'thrash':>7}  worst cell"
    print(hdr + "\n" + "-" * len(hdr))
    for p in rec["ranking"]:
        b = rec["leaderboard"][p]
        print(f"{p:<12} {b['worst_slowdown']:>7.3f} "
              f"{b['mean_slowdown']:>7.3f} {b['mean_thrash']:>7.3f}  "
              f"{b['worst_cell']}")


if __name__ == "__main__":
    main()
