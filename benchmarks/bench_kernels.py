"""Fused interval fast path: warm step-time, fused vs unfused, into
BENCH_kernels.json.

The fused path (``use_interval_kernel``, kernels/interval_step) replaces
the scan engine's per-interval chain of small XLA ops: threshold-select
oracle masks instead of full ``lax.top_k`` + scatter, migrations +
wasteful accounting hoisted inside the any-lane fire cond, single fused
accounting + recall.  Streaming reduction (``reduce="stream"``) folds the
per-interval timelines into the scan carry, so sweep output memory is
O(lanes), independent of T.  Success metric is WARM STEP TIME of the
compiled engine on the BENCH_machines / BENCH_workloads configurations —
not kernel count; both routes are bitwise-identical (the gate asserts it).

Usage:
  PYTHONPATH=src:. python benchmarks/bench_kernels.py \
      [--n 65536] [--T 4096] [--quick] [--out BENCH_kernels.json]
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.baselines.hemem import HeMemSpec
from repro.kernels.interval_step import ref as istep_ref
from repro.simulator import experiment, scan_engine, tuning, workload_spec

MACH_SET = ["pmem-large", "numa", "cxl-1hop", "dram-cxl-pmem"]


def _sweep_pair(label, rec, **kw):
    """Cold + warm fused vs warm unfused for one sweep config; streaming
    reduction on both sides so only the interval path differs."""
    _, cold = common.timed(experiment.sweep, **kw)
    _, warm_fused = common.timed(experiment.sweep, **kw)
    _, _ = common.timed(experiment.sweep, use_interval_kernel=False, **kw)
    _, warm_unfused = common.timed(experiment.sweep,
                                   use_interval_kernel=False, **kw)
    lanes = scan_engine.last_dispatch["lanes"]
    T = kw.get("T") or kw["trace"].shape[0]
    rec[label] = dict(
        lanes=lanes, T=T,
        cold_fused_s=round(cold, 3),
        warm_fused_s=round(warm_fused, 3),
        warm_unfused_s=round(warm_unfused, 3),
        fused_step_us=round(warm_fused / T * 1e6, 2),
        unfused_step_us=round(warm_unfused / T * 1e6, 2),
        step_time_win=round(warm_unfused / max(warm_fused, 1e-9), 3))
    print(f"[bench_kernels] {label}: fused {warm_fused:.3f}s / unfused "
          f"{warm_unfused:.3f}s warm ({rec[label]['step_time_win']}x)",
          flush=True)
    return rec[label]


def stream_alloc_proof(T: int = 4096, n: int = 65536) -> dict:
    """Abstract-evaluate the synth engine at BENCH_workloads scale: count
    output leaves with a T-sized axis under each reduction.  Zero under
    "stream" is the O(1)-in-T claim; costs nothing (no compilation)."""
    k = n // 8
    wl = scan_engine._stack_workloads([workload_spec.named("gups", T=T)])
    mach, caps = scan_engine._mach_lanes("pmem-large", 1, n, k)
    spec = scan_engine._lane_specs(HeMemSpec.make(), 1)
    keys = jax.random.PRNGKey(0)[None]
    sample = jax.ShapeDtypeStruct((T, 1), jnp.float32)

    def t_leaves(reduce):
        out = jax.eval_shape(
            lambda s: scan_engine._simulate(
                spec, None, None, k, mach, caps, keys, s, "crn_prng",
                False, wl=wl, wl_keys=keys,
                noise_key=jax.random.PRNGKey(0), wl_rep=1, n=n,
                reduce=reduce), sample)
        return sum(T in leaf.shape
                   for leaf in jax.tree_util.tree_leaves(out))

    stream, stack = t_leaves("stream"), t_leaves("stack")
    return dict(T=T, n_pages=n,
                stream_T_sized_outputs=stream,
                stack_T_sized_outputs=stack,
                stack_timeline_bytes_per_lane=4 * T * 4,
                stream_summary_bytes_per_lane=4 * 4)


def collect(n: int, T: int) -> dict:
    k = n // 8
    rec: dict = dict(n_pages=n, T=T, k=k)

    # BENCH_machines configuration: P configs x M machines, silo-tpcc
    # synth lanes (tier depths 2 and 3 mixed in one dispatch).
    cfgs = tuning.sample_configs(4)
    specs = [HeMemSpec.make(**c) for c in cfgs]
    _sweep_pair("machines_cfg", rec, policies=specs,
                workloads=["silo-tpcc"], machines=MACH_SET, k=32,
                T=96, n=256, sim_seed=2)

    # BENCH_workloads configuration: the W x B tuned-HeMem study at full
    # scale — the sweep whose 88 s warm time motivated this pass.
    _sweep_pair("workloads_cfg", rec, policies=specs,
                workloads=["gups", "silo-tpcc"], machines="pmem-large",
                k=k, T=T, n=n)

    # oracle top-k micro: threshold bisection vs lax.top_k + scatter,
    # the synth mode's per-interval device oracle ([W, n] rows).
    x = jnp.asarray(np.random.default_rng(0).gamma(1.5, 2.0, (4, n)),
                    jnp.float32)
    thresh = jax.jit(lambda v: istep_ref.topk_mask_ref(v, k))
    topk = jax.jit(
        lambda v: jax.vmap(lambda r: scan_engine._topk_mask(r, k))(v))
    for f in (thresh, topk):
        jax.block_until_ready(f(x))
    reps = 20
    _, t_thresh = common.timed(lambda: [jax.block_until_ready(thresh(x))
                                        for _ in range(reps)])
    _, t_topk = common.timed(lambda: [jax.block_until_ready(topk(x))
                                      for _ in range(reps)])
    rec["topk_mask_us"] = dict(
        rows=4, n=n, k=k,
        threshold_us=round(t_thresh / reps * 1e6, 1),
        lax_top_k_us=round(t_topk / reps * 1e6, 1),
        win=round(t_topk / max(t_thresh, 1e-12), 2))

    rec["stream_alloc"] = stream_alloc_proof()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--T", type=int, default=4096)
    ap.add_argument("--quick", action="store_true",
                    help="tiny scale smoke run (n=2048, T=256)")
    args = ap.parse_args()
    n, T = (2048, 256) if args.quick else (args.n, args.T)

    rec = collect(n, T)
    out = dict(
        description="Fused interval fast path (use_interval_kernel) vs "
                    "unfused scan engine, streaming reduction on both; "
                    "warm step-time is the success metric",
        machine="CI container CPU (2 cores); CPU route = fused jnp refs, "
                "Pallas kernels compiled on TPU",
        notes=[
            "Both routes are bitwise-identical under CRN "
            "(tests/test_interval_step.py + bench_kernel_gate).",
            "stream_alloc proves reduce='stream' emits no [T, ...] "
            "output at n=65536/T=4096 (eval_shape, no compile).",
        ],
        **rec,
    )
    # keep the CI gate's record (paper_tables.bench_kernel_gate merges
    # itself under "gate") across manual full-scale reruns.
    try:
        with open(args.out) as f:
            prev = json.load(f)
        if "gate" in prev:
            out["gate"] = prev["gate"]
    except (OSError, ValueError):
        pass
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
