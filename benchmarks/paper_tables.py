"""Benchmarks reproducing the paper's tables/figures (deliverable d).

Each function reproduces one figure/table and emits CSV rows; the asserted
claims are collected and reported at the end of run.py.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import emit, geomean
from repro.baselines.hemem import HeMemPolicy
from repro.simulator import tuning
from repro.simulator.engine import run
from repro.simulator.machine import NUMA, PMEM_LARGE

CLAIMS = []


def claim(name, value, target, ok):
    CLAIMS.append((name, value, target, bool(ok)))


# ------------------------------------------------------ Fig. 2/3 + Table 2
def bench_tuning_study(budget: int = 24):
    """Tuned vs default HeMem per workload (paper: 1.05-2.09x gains)."""
    gains = []
    for wl in common.WORKLOAD_SET:
        trace = common.trace_for(wl)
        best_cfg, best_res, _rows = tuning.tune_hemem(
            trace, PMEM_LARGE, common.K, budget=budget)
        default, wall = common.run_policy("hemem", trace)
        gain = default.exec_time_s / best_res.exec_time_s
        gains.append(gain)
        emit(f"tuning_study.{wl}", wall * 1e6,
             f"tuned_gain={gain:.3f};best={best_cfg}")
    claim("tuning helps (geomean default/tuned)", f"{geomean(gains):.2f}x",
          ">=1.05x (paper: 1.05-2.09x per workload)", geomean(gains) >= 1.05)


# ------------------------------------------------------------------ Fig. 7
def bench_main_comparison():
    """ARMS vs HeMem/tuned-HeMem/Memtis/TPP on pmem-large."""
    vs_hemem, vs_memtis, vs_tpp, vs_tuned = [], [], [], []
    for wl in common.WORKLOAD_SET:
        trace = common.trace_for(wl)
        res = {}
        for pol in ("all-slow", "hemem", "memtis", "tpp", "arms"):
            res[pol], wall = common.run_policy(pol, trace)
        _cfg, tuned, _ = tuning.tune_hemem(trace, PMEM_LARGE, common.K,
                                           budget=24)
        a = res["arms"].exec_time_s
        vs_hemem.append(res["hemem"].exec_time_s / a)
        vs_memtis.append(res["memtis"].exec_time_s / a)
        vs_tpp.append(res["tpp"].exec_time_s / a)
        vs_tuned.append(tuned.exec_time_s / a)
        emit(f"main_comparison.{wl}", wall * 1e6,
             f"arms_vs_hemem={vs_hemem[-1]:.3f};"
             f"arms_vs_memtis={vs_memtis[-1]:.3f};"
             f"arms_vs_tpp={vs_tpp[-1]:.3f};"
             f"arms_vs_tuned={vs_tuned[-1]:.3f}")
    claim("ARMS vs default HeMem (geomean)", f"{geomean(vs_hemem):.2f}x",
          "paper: 1.26x", geomean(vs_hemem) >= 1.2)
    claim("ARMS vs Memtis (geomean)", f"{geomean(vs_memtis):.2f}x",
          "paper: 1.34x", geomean(vs_memtis) >= 1.1)
    claim("ARMS vs TPP (geomean)", f"{geomean(vs_tpp):.2f}x",
          "paper: 2.3x", geomean(vs_tpp) >= 1.5)
    claim("ARMS within 3% of tuned HeMem (geomean)",
          f"{geomean(vs_tuned):.3f}", "paper: >=0.97",
          geomean(vs_tuned) >= 0.97)


# ----------------------------------------------------------------- Fig. 10
def bench_migrations():
    """Promotion counts + wasteful migrations per system."""
    tot = {p: 0 for p in ("hemem", "memtis", "tpp", "arms")}
    waste = dict(tot)
    for wl in common.WORKLOAD_SET:
        trace = common.trace_for(wl)
        for pol in tot:
            res, wall = common.run_policy(pol, trace)
            tot[pol] += res.promotions
            waste[pol] += res.wasteful
        emit(f"migrations.{wl}", wall * 1e6,
             ";".join(f"{p}={tot[p]}" for p in tot))
    emit("migrations.wasteful_total", 0,
         ";".join(f"{p}={waste[p]}" for p in waste))
    claim("TPP migrates most (paper: 'extremely high')",
          f"tpp={tot['tpp']}", f"> 2x arms={tot['arms']}",
          tot["tpp"] > 2 * tot["arms"])
    claim("ARMS wasteful migrations lowest among adaptive systems",
          f"arms={waste['arms']}",
          f"<= memtis={waste['memtis']}, tpp={waste['tpp']}",
          waste["arms"] <= waste["memtis"]
          and waste["arms"] <= waste["tpp"])


# ------------------------------------------------------------------ Fig. 9
def bench_adaptivity():
    """PHT change-point detection timeline (btree hot-set shift)."""
    trace = common.trace_for("btree")   # shuffles hot set at T/2
    res, wall = common.run_policy("arms", trace)
    mode = res.timeline_mode
    shift = common.T // 2
    detect = np.flatnonzero(mode[shift:] == 1)
    latency = int(detect[0]) if len(detect) else -1
    emit("adaptivity.btree", wall * 1e6,
         f"detect_latency_intervals={latency};"
         f"recency_intervals={int((mode == 1).sum())}")
    claim("PHT detects hot-set change (Fig. 9)",
          f"latency={latency} intervals", "< 25 intervals (2.5s)",
          0 <= latency < 25)


# ----------------------------------------------------------------- Fig. 13
def bench_tier_ratios():
    """ARMS vs default HeMem across fast:slow capacity ratios."""
    wins = []
    for wl in ("xsbench", "gups"):
        trace = common.trace_for(wl)
        for ratio in (16, 8, 4, 2):
            k = common.N_PAGES // ratio
            h, _ = common.run_policy("hemem", trace, k=k)
            a, wall = common.run_policy("arms", trace, k=k)
            sp = h.exec_time_s / a.exec_time_s
            wins.append(sp)
            emit(f"tier_ratios.{wl}.1to{ratio}", wall * 1e6,
                 f"arms_vs_hemem={sp:.3f}")
    claim("ARMS robust across tier ratios (Fig. 13)",
          f"min={min(wins):.2f}x", ">= 0.95x at every ratio",
          min(wins) >= 0.95)


# ----------------------------------------------------------------- Fig. 12
def bench_scaling():
    """Thread-count analogue: workload intensity scaling (MLP factor)."""
    import dataclasses
    trace = common.trace_for("silo-ycsb")
    for mlp in (16, 32, 64, 128):   # ~4..20 threads of MLP
        m = dataclasses.replace(PMEM_LARGE, mlp=float(mlp))
        h, _ = common.run_policy("hemem", trace, machine=m)
        a, wall = common.run_policy("arms", trace, machine=m)
        emit(f"scaling.mlp{mlp}", wall * 1e6,
             f"arms_vs_hemem={h.exec_time_s / a.exec_time_s:.3f}")


# ----------------------------------------------------------------- Fig. 11
def bench_numa_machine():
    """Different hardware (emulated-CXL NUMA node), no re-tuning."""
    sp = []
    for wl in ("gups", "btree", "silo-ycsb", "xsbench"):
        trace = common.trace_for(wl)
        h, _ = common.run_policy("hemem", trace, machine=NUMA)
        a, wall = common.run_policy("arms", trace, machine=NUMA)
        sp.append(h.exec_time_s / a.exec_time_s)
        emit(f"numa.{wl}", wall * 1e6, f"arms_vs_hemem={sp[-1]:.3f}")
    claim("ARMS wins on different hardware without re-tuning (Fig. 11)",
          f"{geomean(sp):.2f}x", ">= 1.0x geomean", geomean(sp) >= 1.0)


# -------------------------------------------- batched sweeps (scan engine)
def bench_arms_sweep(budget: int = 24, n_seeds: int = 8,
                     n: int = 4096, T: int = 512):
    """Batched lax.scan+vmap ARMS sweeps vs the sequential numpy loop.

    Runs at the acceptance scale (n_pages >= 4096, T >= 512).  Three
    numbers per sweep: sequential numpy loop, batched cold (includes the
    one-off compile), batched warm.  Returns a dict for BENCH_tuning.json.
    """
    import time

    from repro.baselines.arms_policy import ARMSPolicy
    from repro.core.state import ARMSConfig
    from repro.simulator import scan_engine, workloads

    trace = workloads.make("gups", T=T, n=n)
    k = n // 8
    rec = dict(workload="gups", n_pages=n, T=T, k=k, budget=budget,
               n_seeds=n_seeds)

    # --- config sweep (the tuning study) ---
    cfgs = tuning.sample_arms_configs(budget)
    t0 = time.time()
    for cfg in cfgs:
        run(ARMSPolicy(ARMSConfig(**cfg)), trace, PMEM_LARGE, k, seed=0)
    rec["config_sweep_sequential_s"] = round(time.time() - t0, 3)

    overrides = {key: [c[key] for c in cfgs] for key in tuning.ARMS_SPACE}
    t0 = time.time()
    scan_engine.sweep_arms_configs(trace, PMEM_LARGE, k, overrides)
    rec["config_sweep_batched_cold_s"] = round(time.time() - t0, 3)
    t0 = time.time()
    scan_engine.sweep_arms_configs(trace, PMEM_LARGE, k, overrides)
    rec["config_sweep_batched_warm_s"] = round(time.time() - t0, 3)

    # same sweep with the pure-jnp score path (the Pallas kernel runs in
    # interpret mode off-TPU, which costs extra under batching)
    jnp_cfg = ARMSConfig(use_score_kernel=False)
    scan_engine.sweep_arms_configs(trace, PMEM_LARGE, k, overrides,
                                   base_cfg=jnp_cfg)
    t0 = time.time()
    scan_engine.sweep_arms_configs(trace, PMEM_LARGE, k, overrides,
                                   base_cfg=jnp_cfg)
    rec["config_sweep_batched_warm_jnp_s"] = round(time.time() - t0, 3)

    # --- seed sweep ---
    seeds = list(range(n_seeds))
    t0 = time.time()
    for s in seeds:
        run(ARMSPolicy(), trace, PMEM_LARGE, k, seed=s)
    rec["seed_sweep_sequential_s"] = round(time.time() - t0, 3)
    t0 = time.time()
    scan_engine.sweep_seeds(trace, PMEM_LARGE, k, seeds)
    rec["seed_sweep_batched_cold_s"] = round(time.time() - t0, 3)
    t0 = time.time()
    scan_engine.sweep_seeds(trace, PMEM_LARGE, k, seeds)
    rec["seed_sweep_batched_warm_s"] = round(time.time() - t0, 3)

    sp_cfg = rec["config_sweep_sequential_s"] / \
        rec["config_sweep_batched_warm_s"]
    sp_cfg_jnp = rec["config_sweep_sequential_s"] / \
        rec["config_sweep_batched_warm_jnp_s"]
    sp_seed = rec["seed_sweep_sequential_s"] / \
        rec["seed_sweep_batched_warm_s"]
    rec["config_sweep_speedup"] = round(sp_cfg, 2)
    rec["config_sweep_speedup_jnp"] = round(sp_cfg_jnp, 2)
    rec["seed_sweep_speedup"] = round(sp_seed, 2)
    emit(f"arms_sweep.config.n{n}",
         rec["config_sweep_batched_warm_s"] * 1e6,
         f"seq={rec['config_sweep_sequential_s']}s;"
         f"speedup={sp_cfg:.2f}x;jnp_path={sp_cfg_jnp:.2f}x")
    emit(f"arms_sweep.seeds.n{n}",
         rec["seed_sweep_batched_warm_s"] * 1e6,
         f"seq={rec['seed_sweep_sequential_s']}s;speedup={sp_seed:.2f}x")
    # conservative CI gate (the recorded BENCH_tuning.json documents the
    # full before/after including the pre-PR per-interval-sync baseline,
    # which is what the >=5x acceptance figure is measured against)
    claim("batched ARMS sweep beats sequential numpy loop",
          f"{max(sp_cfg, sp_cfg_jnp):.2f}x", ">= 2x (5x vs pre-PR baseline)",
          max(sp_cfg, sp_cfg_jnp) >= 2.0)
    return rec


# --------------------------------------------------------- §5/§6 overheads
def bench_overheads():
    """ARMS controller cost per policy interval + metadata bytes/page."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import ARMSConfig, arms_step, init_state

    for n in (4096, 65536, 1 << 20):
        cfg = ARMSConfig()
        st = init_state(n, cfg)
        counts = jnp.ones((n,))
        st, _ = arms_step(st, counts, 0.5, 0.5, cfg=cfg, k=n // 8)  # compile
        jax.block_until_ready(st.score)
        t0 = time.time()
        iters = 20
        for _ in range(iters):
            st, _ = arms_step(st, counts, 0.5, 0.5, cfg=cfg, k=n // 8)
        jax.block_until_ready(st.score)
        us = (time.time() - t0) / iters * 1e6
        emit(f"overheads.controller.n{n}", us,
             f"us_per_page={us / n:.4f}")
    # metadata bytes/page: 2 EWMAs + 2 scores (f32) + hot_age (i32) + tier
    emit("overheads.metadata", 0, "bytes_per_page=21 (paper: ~20)")
