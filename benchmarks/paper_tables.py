"""Benchmarks reproducing the paper's tables/figures (deliverable d).

Each function reproduces one figure/table and emits CSV rows; the asserted
claims are collected and reported at the end of run.py.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit, geomean
from repro.baselines.hemem import HeMemPolicy
from repro.simulator import scan_engine, tuning, workloads
from repro.simulator.engine import run
from repro.simulator.machine import NUMA, PMEM_LARGE
from repro.simulator.sampling import uniform_field

CLAIMS = []


def claim(name, value, target, ok):
    CLAIMS.append((name, value, target, bool(ok)))


def _default_row(rows, defaults):
    return next(res for cfg, res in rows if cfg == dict(defaults))


# ------------------------------------------------------ Fig. 2/3 + Table 2
def bench_tuning_study(budget: int = 24):
    """Tuned vs default HeMem per workload (paper: 1.05-2.09x gains).

    The whole budget is ONE lane-batched scan-engine dispatch per workload;
    tuned and default rows share the CRN noise field (paired comparison).
    """
    gains = []
    for wl in common.WORKLOAD_SET:
        trace = common.trace_for(wl)
        t0 = time.time()
        best_cfg, best_res, rows = tuning.tune_hemem(
            trace, PMEM_LARGE, common.K, budget=budget)
        wall = time.time() - t0
        default = _default_row(rows, tuning.HEMEM_DEFAULTS)
        gain = default.exec_time_s / best_res.exec_time_s
        gains.append(gain)
        emit(f"tuning_study.{wl}", wall * 1e6,
             f"tuned_gain={gain:.3f};best={best_cfg}")
    claim("tuning helps (geomean default/tuned)", f"{geomean(gains):.2f}x",
          ">=1.05x (paper: 1.05-2.09x per workload)", geomean(gains) >= 1.05)


# ------------------------------------------- Table 2: tuned-vs-untuned, all
def bench_tuned_baselines(budget: int = 16):
    """The paper's tuned-vs-untuned speedup table for every baseline family
    (Tuned-HeMem / Tuned-Memtis / Tuned-TPP), via the unified batched
    ``tuning.tune`` API — one compiled lane-batched sweep per family."""
    fams = [("hemem", tuning.tune_hemem, tuning.HEMEM_DEFAULTS),
            ("memtis", tuning.tune_memtis, tuning.MEMTIS_DEFAULTS),
            ("tpp", tuning.tune_tpp, tuning.TPP_DEFAULTS)]
    hemem_gains = []
    for wl in ("gups", "silo-tpcc", "xsbench"):
        trace = common.trace_for(wl)
        for fam, tune_fn, defaults in fams:
            t0 = time.time()
            best_cfg, best_res, rows = tune_fn(trace, PMEM_LARGE, common.K,
                                               budget=budget)
            wall = time.time() - t0
            gain = _default_row(rows, defaults).exec_time_s \
                / best_res.exec_time_s
            if fam == "hemem":
                hemem_gains.append(gain)
            emit(f"tuned_baselines.{wl}.{fam}", wall * 1e6,
                 f"tuned_gain={gain:.3f};"
                 f"lanes={scan_engine.last_dispatch['lanes']};"
                 f"best={best_cfg}")
    claim("tuned-baseline table: tuning HeMem helps on latest-style loads",
          f"max_gain={max(hemem_gains):.2f}x", ">= 1.02x somewhere",
          max(hemem_gains) >= 1.02)


# ------------------------------------- CI gate: sweeps must stay batched
def bench_baseline_sweep_gate():
    """Quick-gate: a small tuned-baseline sweep must (a) run as ONE
    lane-batched compiled dispatch — a regression that silently falls back
    to a sequential per-config loop fails here — and (b) agree exactly with
    the sequential numpy reference path under the shared CRN field."""
    T_, n, k, sim_seed = 96, 256, 32, 2
    trace = workloads.make("silo-tpcc", T=T_, n=n)
    t0 = time.time()
    _, _, rows = tuning.tune_hemem(trace, PMEM_LARGE, k, budget=6,
                                   sim_seed=sim_seed)
    wall = time.time() - t0
    lanes = scan_engine.last_dispatch.get("lanes")
    claim("tuned-baseline sweep runs lane-batched",
          f"lanes={lanes} for {len(rows)} configs",
          "one compiled dispatch covering the whole budget",
          lanes == len(rows) and scan_engine.last_dispatch.get(
              "sampling") == "crn")
    cfg, res = rows[0]
    ref = run(HeMemPolicy(**cfg), trace, PMEM_LARGE, k,
              sample_u=uniform_field(T_, n, seed=sim_seed))
    emit("baseline_sweep_gate.hemem", wall * 1e6,
         f"lanes={lanes};best_promotions={res.promotions}")
    claim("batched sweep == sequential numpy path (shared CRN)",
          f"P/D/W {res.promotions}/{res.demotions}/{res.wasteful}",
          f"numpy {ref.promotions}/{ref.demotions}/{ref.wasteful}",
          (res.promotions, res.demotions, res.wasteful)
          == (ref.promotions, ref.demotions, ref.wasteful))


# --------------------------------- CI gate: workload lanes must stay synth
def bench_workload_sweep_gate():
    """Quick-gate for the trace-synthesis path: a W-workload x B-config
    tuning sweep must (a) compile to ONE dispatch with W*B lanes, (b)
    never host-materialize a [T, n] trace (the whole point of the
    WorkloadSpec protocol: per-lane storage O(n), not O(T*n)), and (c)
    agree exactly with the sequential numpy reference replay of any lane
    on the materialized trace + reconstructed CRN noise rows."""
    from repro.baselines.hemem import HeMemPolicy
    from repro.simulator import workload_spec
    from repro.simulator.sampling import synth_noise_field

    wls = ["gups", "silo-tpcc", "xsbench"]
    T_, n, k, budget, sim_seed = 96, 256, 32, 4, 3
    mat_before = workload_spec.MATERIALIZE_CALLS
    t0 = time.time()
    per_wl = tuning.tune("hemem", None, PMEM_LARGE, k, budget=budget,
                         sim_seed=sim_seed, workloads=wls, T=T_, n=n)
    wall = time.time() - t0
    B = len(per_wl[wls[0]][2])
    lanes = scan_engine.last_dispatch.get("lanes")
    claim("workload sweep runs as one W*B-lane synth dispatch",
          f"lanes={lanes} for {len(wls)} workloads x {B} configs",
          "W*B lanes, synth=True, device CRN rows",
          lanes == len(wls) * B
          and scan_engine.last_dispatch.get("synth") is True
          and scan_engine.last_dispatch.get("sampling") == "crn_prng")
    claim("synth sweep never host-materializes a [T, n] trace",
          f"materialize_calls_delta="
          f"{workload_spec.MATERIALIZE_CALLS - mat_before}",
          "0", workload_spec.MATERIALIZE_CALLS == mat_before)
    # lane == sequential numpy replay on the materialized trace + the
    # host-reconstructed copy of the device CRN rows
    cfg, res = per_wl["silo-tpcc"][2][0]
    trace = workloads.spec("silo-tpcc", T=T_).materialize(T_, n)
    ref = run(HeMemPolicy(**cfg), trace, PMEM_LARGE, k,
              sample_u=synth_noise_field(T_, n, seed=sim_seed))
    emit("workload_sweep_gate.hemem", wall * 1e6,
         f"lanes={lanes};workloads={len(wls)};configs={B}")
    claim("synth lane == numpy replay of materialized trace (shared CRN)",
          f"P/D/W {res.promotions}/{res.demotions}/{res.wasteful}",
          f"numpy {ref.promotions}/{ref.demotions}/{ref.wasteful}",
          (res.promotions, res.demotions, res.wasteful)
          == (ref.promotions, ref.demotions, ref.wasteful))


# ------------------------------- CI gate: machine sweeps must stay batched
def bench_machine_sweep_gate():
    """Quick-gate for the machine axis: a P-config x M-machine sweep must
    (a) compile to ONE lane-batched dispatch covering the whole P*M
    product — a regression to per-machine recompiles or a sequential
    fallback fails here — with tier depths unified by neutral padding,
    and (b) agree exactly with a standalone single-machine dispatch on
    any lane.  Records the result in BENCH_machines.json."""
    import json

    from repro.baselines.hemem import HeMemSpec
    from repro.simulator import experiment, workload_spec

    T_, n, k, sim_seed = 96, 256, 32, 2
    cfgs = tuning.sample_configs(4)
    specs = [HeMemSpec.make(**c) for c in cfgs]
    mach_names = ["pmem-large", "numa", "cxl-1hop", "dram-cxl-pmem"]
    P, M = len(specs), len(mach_names)
    wl = workload_spec.named("silo-tpcc", T=T_)

    res, cold = common.timed(
        experiment.sweep, specs, workloads=[wl], machines=mach_names,
        k=k, T=T_, n=n, sim_seed=sim_seed)
    _, warm = common.timed(
        experiment.sweep, specs, workloads=[wl], machines=mach_names,
        k=k, T=T_, n=n, sim_seed=sim_seed)

    d = dict(scan_engine.last_dispatch)
    claim("machine sweep runs as ONE P*M-lane dispatch",
          f"lanes={d.get('lanes')} for {P} configs x {M} machines "
          f"(mixed 2/3-tier)",
          "P*M lanes, no per-machine recompiles or sequential fallback",
          d.get("lanes") == P * M and d.get("machines") == M
          and d.get("axis_product") is True)
    single = scan_engine.simulate_workload(specs[0], wl, "dram-cxl-pmem",
                                           k, T_, n, sim_seed=sim_seed)
    lane = res.at(policy=0, machine="dram-cxl-pmem")
    claim("machine-sweep lane == standalone single-machine run",
          f"P/D/W {lane.promotions}/{lane.demotions}/{lane.wasteful}",
          f"single {single.promotions}/{single.demotions}/"
          f"{single.wasteful}",
          (lane.promotions, lane.demotions, lane.wasteful)
          == (single.promotions, single.demotions, single.wasteful))
    emit("machine_sweep_gate.hemem", warm * 1e6,
         f"lanes={d.get('lanes')};machines={M};configs={P};"
         f"cold_s={cold:.3f}")
    rec = dict(workload="silo-tpcc", n_pages=n, T=T_, k=k,
               configs=P, machines=mach_names, lanes=d.get("lanes"),
               sampling=d.get("sampling"), cold_s=round(cold, 3),
               warm_s=round(warm, 3),
               best_config_per_machine={
                   m: min(range(P),
                          key=lambda p: res.at(policy=p,
                                               machine=m).exec_time_s)
                   for m in mach_names})
    with open("BENCH_machines.json", "w") as f:
        json.dump(rec, f, indent=1)
        f.write("\n")


# --------------- CI gate: fused interval path + streaming reduction
def bench_kernel_gate():
    """Quick-gate for the fused interval fast path: (a) the fused route
    (``use_interval_kernel``, default) must be BITWISE identical to the
    unfused scan under the shared CRN field for every policy family on a
    2-tier and a 3-tier machine — scalars and all four timelines; (b) a
    default sweep must run under streaming reduction with no [T, ...]
    output anywhere (checked structurally here at gate scale and by
    abstract evaluation at n=65536/T=4096).  Records warm fused-vs-unfused
    step time in BENCH_kernels.json."""
    import json

    from benchmarks import bench_kernels
    from repro.simulator import experiment

    T_, n, k, sim_seed = 96, 256, 32, 2
    fams = ["arms", "hemem", "memtis", "tpp", "all-slow", "oracle"]
    machs = ["pmem-large", "dram-cxl-pmem"]
    trace = workloads.make("silo-tpcc", T=T_, n=n)
    u = uniform_field(T_, n, seed=sim_seed)

    fused, cold = common.timed(
        experiment.sweep, fams, trace=trace, machines=machs, k=k,
        sample_u=u, timelines=True)
    plain, _ = common.timed(
        experiment.sweep, fams, trace=trace, machines=machs, k=k,
        sample_u=u, timelines=True, use_interval_kernel=False)
    bad = []
    for (where, a), (_, b) in zip(fused.items(), plain.items()):
        same = (a.promotions, a.demotions, a.wasteful) \
            == (b.promotions, b.demotions, b.wasteful) \
            and a.exec_time_s == b.exec_time_s \
            and a.hot_recall == b.hot_recall \
            and all(np.array_equal(getattr(a, f), getattr(b, f))
                    for f in ("timeline_slow_bw", "timeline_fast_hits",
                              "timeline_mode", "timeline_promotions"))
        if not same:
            bad.append(f"{where['policy']}@{where['machine']}")
    claim("fused interval path bitwise == unfused (CRN, all families)",
          f"{len(fams)} families x {machs} (2- and 3-tier): "
          + ("all equal" if not bad else "DIFF " + ",".join(bad)),
          "every scalar and timeline bitwise identical", not bad)

    # streaming is the sweep default: no [T, ...] output, summaries set
    res, _ = common.timed(
        experiment.sweep, ["hemem", "arms"], workloads=["gups"],
        machines=machs, k=k, T=T_, n=n, sim_seed=sim_seed)
    d = dict(scan_engine.last_dispatch)
    stream_ok = d.get("reduce") == "stream" and all(
        r.timeline_slow_bw is None and r.mean_slow_bw is not None
        for _, r in res.items())
    alloc = bench_kernels.stream_alloc_proof()
    claim("streaming sweep allocates no [T, ...] timeline",
          f"dispatch reduce={d.get('reduce')}; eval_shape at "
          f"n={alloc['n_pages']}/T={alloc['T']}: "
          f"{alloc['stream_T_sized_outputs']} T-sized outputs "
          f"(stack: {alloc['stack_T_sized_outputs']})",
          "reduce=stream, 0 T-sized output leaves, summaries populated",
          stream_ok and alloc["stream_T_sized_outputs"] == 0
          and alloc["stack_T_sized_outputs"] > 0)

    # warm fused vs unfused step time at gate scale -> BENCH_kernels.json
    # (benchmarks/bench_kernels.py re-measures at full n=65536/T=4096).
    _, warm_fused = common.timed(
        experiment.sweep, fams, trace=trace, machines=machs, k=k,
        sample_u=u, timelines=True)
    _, warm_unfused = common.timed(
        experiment.sweep, fams, trace=trace, machines=machs, k=k,
        sample_u=u, timelines=True, use_interval_kernel=False)
    emit("kernel_gate.fused_sweep", warm_fused * 1e6,
         f"families={len(fams)};machines={len(machs)};cold_s={cold:.3f};"
         f"unfused_warm_us={warm_unfused * 1e6:.0f}")
    rec = dict(scale="gate-quick", workload="silo-tpcc", n_pages=n, T=T_,
               k=k, families=fams, machines=machs,
               bitwise_equal=not bad, streaming_default=stream_ok,
               cold_fused_s=round(cold, 3),
               warm_fused_s=round(warm_fused, 3),
               warm_unfused_s=round(warm_unfused, 3),
               step_time_win=round(warm_unfused / max(warm_fused, 1e-9),
                                   3),
               stream_alloc=alloc)
    # merge under "gate" so the full-scale record written by
    # benchmarks/bench_kernels.py survives CI passes.
    try:
        with open("BENCH_kernels.json") as f:
            out = json.load(f)
    except (OSError, ValueError):
        out = {}
    out["gate"] = rec
    with open("BENCH_kernels.json", "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")


# ------------------- CI gate: adaptive search rounds must stay compiled
def bench_search_gate():
    """Quick-gate for the adaptive search engine: (a) every round of every
    strategy — grid's single scoring pass, each ASHA elimination rung,
    each CE redraw generation — must run as ONE compiled dispatch per
    policy family (the engine records the dispatch-counter delta per
    round); (b) ASHA must land within 3% of the exhaustive grid's best
    exec time for HeMem/Memtis/TPP while spending <= 40% of the grid's
    lane-intervals, on the same seeded population under the same CRN
    field.  Records the compute-vs-quality curves in BENCH_search.json
    under "gate" (benchmarks/bench_search.py writes the full-scale
    record)."""
    import json

    from repro.simulator import search

    T_, n, k, budget = 120, 256, 32, 16
    trace = workloads.make("silo-tpcc", T=T_, n=n)
    rec = dict(T=T_, n_pages=n, k=k, budget=budget, workload="silo-tpcc",
               families={})
    gaps, fracs, rounds_bad = [], [], []
    for fam in ("hemem", "memtis", "tpp"):
        t0 = time.time()
        runs = {s: search.run(fam, s, trace=trace, k=k, budget=budget)
                for s in ("grid", "asha", "ce")}
        wall = time.time() - t0
        g, a, c = runs["grid"], runs["asha"], runs["ce"]
        for s, sr in runs.items():
            rounds_bad += [f"{fam}.{s}#{r.index}" for r in sr.rounds
                           if r.dispatches != 1]
        gap = float(a.best_result.exec_time_s
                    / g.best_result.exec_time_s) - 1.0
        frac = a.lane_intervals / g.lane_intervals
        gaps.append(gap)
        fracs.append(frac)
        rec["families"][fam] = dict(
            grid_best_s=round(float(g.best_result.exec_time_s), 6),
            asha_best_s=round(float(a.best_result.exec_time_s), 6),
            ce_best_s=round(float(c.best_result.exec_time_s), 6),
            asha_gap=round(gap, 4), asha_li_frac=round(frac, 4),
            grid_lane_intervals=g.lane_intervals,
            asha_lane_intervals=a.lane_intervals,
            asha_rounds=len(a.rounds), ce_rounds=len(c.rounds),
            asha_curve=[[int(li), round(float(t), 6)]
                        for li, t in a.curve()],
            ce_curve=[[int(li), round(float(t), 6)]
                      for li, t in c.curve()])
        emit(f"search_gate.{fam}", wall * 1e6,
             f"asha_gap={gap:+.4f};li_frac={frac:.3f};"
             f"asha_rounds={len(a.rounds)}")
    claim("every search round is ONE compiled dispatch per family",
          "all rounds single-dispatch" if not rounds_bad
          else "MULTI " + ",".join(rounds_bad),
          "grid/ASHA/CE rounds never fall back to per-config loops",
          not rounds_bad)
    claim("ASHA within 3% of grid best at <= 40% of grid lane-intervals",
          f"max_gap={max(gaps):+.4f} at max_li_frac={max(fracs):.3f} "
          f"(hemem/memtis/tpp)",
          "gap <= 0.03, li_frac <= 0.40", max(gaps) <= 0.03
          and max(fracs) <= 0.40)
    # merge under "gate" so the full-scale record written by
    # benchmarks/bench_search.py survives CI passes.
    try:
        with open("BENCH_search.json") as f:
            out = json.load(f)
    except (OSError, ValueError):
        out = {}
    out.setdefault("gate", {})
    out["gate"].update(rec)
    with open("BENCH_search.json", "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")


# -------------------- machine-transfer matrix ("From Good to Great" §5)
def bench_transfer_matrix():
    """"Tuned on machine A, deployed on machine B" robustness matrix over
    the machine presets — the companion tuning paper's headline
    experiment.  Uses strategy="grid" so the matrix is EXACT: phase 2
    re-scores every tuned config under the same CRN field phase 1 ranked
    them with, making the native config optimal among the tuned set —
    diagonal slowdown 1.0 and off-diagonal >= 1.0 are invariants the gate
    asserts, and any off-diagonal > 1 is a real transfer penalty, not
    noise.  (benchmarks/bench_search.py records the ASHA-driven matrix at
    full scale.)"""
    import json

    from repro.simulator import search

    mach_names = ["pmem-large", "numa", "cxl-1hop", "dram-cxl-pmem"]
    T_, n, k, budget = 120, 256, 32, 8
    trace = workloads.make("silo-tpcc", T=T_, n=n)
    t0 = time.time()
    tm = search.transfer_matrix("hemem", trace, mach_names, k,
                                budget=budget, strategy="grid")
    wall = time.time() - t0
    M = len(tm.machines)
    diag_ok = bool(np.allclose(np.diag(tm.slowdown), 1.0))
    off_ok = bool((tm.slowdown >= 1.0 - 1e-12).all())
    worst = max(float(tm.slowdown[a, b]) for a in range(M)
                for b in range(M) if a != b)
    for r in tm.rows():
        emit(f"transfer_matrix.{r['tuned_on']}", wall * 1e6 / M,
             ";".join(f"{b}={s:.4f}" for b, s in r["slowdown"].items()))
    claim("transfer matrix spans >= 3 machine presets",
          f"{M} machines: {tm.machines}", ">= 3 presets", M >= 3)
    claim("native tuning optimal under shared CRN (diag 1.0, off >= 1.0)",
          f"diag_ok={diag_ok}; min_off={float(tm.slowdown.min()):.6f}; "
          f"worst_foreign={worst:.4f}x",
          "exact invariant of the grid-strategy matrix", diag_ok and off_ok)
    try:
        with open("BENCH_search.json") as f:
            out = json.load(f)
    except (OSError, ValueError):
        out = {}
    out.setdefault("gate", {})
    out["gate"]["transfer"] = dict(
        family="hemem", strategy="grid", machines=tm.machines,
        T=T_, n_pages=n, k=k, budget=budget,
        worst_foreign_slowdown=round(worst, 4), rows=tm.rows())
    with open("BENCH_search.json", "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")


# --------------------------------- machine-sensitivity table (Fig. 11 ++)
def bench_machine_sensitivity():
    """Best-untuned policy per machine: the paper's robustness claim taken
    across the machine axis (two-tier PMem/NUMA/CXL presets plus the
    three-tier DRAM/CXL/PMem chain), each family's W*M grid one compiled
    dispatch."""
    from repro.simulator import experiment

    mach_names = ["pmem-large", "numa", "cxl-1hop", "dram-cxl-pmem"]
    pols = ["hemem", "memtis", "tpp", "arms"]
    wls = ["gups", "silo-tpcc", "xsbench"]
    T_, n, k = 120, 512, 64
    t0 = time.time()
    res = experiment.sweep(pols, workloads=wls, machines=mach_names,
                           k=k, T=T_, n=n)
    wall = time.time() - t0
    ok_all = True
    for m in mach_names:
        geo = {p: geomean([res.at(policy=p, workload=w,
                                  machine=m).exec_time_s for w in wls])
               for p in pols}
        best = min(geo, key=geo.get)
        ok_all &= geo["arms"] <= geo[best] * 1.10
        emit(f"machine_sensitivity.{m}", wall * 1e6 / len(mach_names),
             f"best={best};" + ";".join(
                 f"{p}={geo[p]:.3f}s" for p in pols))
    claim("ARMS within 10% of best untuned policy on EVERY machine",
          "per-machine geomeans above", "robust without re-tuning",
          ok_all)


# --------------------- CI gate: adversarial robustness leaderboard
def bench_robustness_gate():
    """Quick-gate for the robustness leaderboard
    (benchmarks/bench_robustness.py): every policy family — the four
    binary baselines through the tier-native shim, the three tier-native
    families, and the oracle — scored on the adversarial thrashing suite
    across three machine topologies.  Asserts (a) the whole
    mixed-family policy x scenario x machine board compiles to exactly
    ONE lane-batched dispatch (the union fabric, simulator/fabric.py),
    and (b) ARMS' worst-case slowdown vs the
    per-cell oracle stays bounded (with the oracle's self-slowdown
    exactly 1 as a scoring sanity check).  Records the gate-scale board
    in BENCH_robustness.json under "gate"
    (benchmarks/bench_robustness.py writes the full-scale record)."""
    import json

    from benchmarks.bench_robustness import run_robustness

    t0 = time.time()
    rec = run_robustness(T=96, n=256, k=32)
    wall = time.time() - t0
    arms = rec["leaderboard"]["arms"]
    oracle = rec["leaderboard"]["oracle"]
    emit("robustness_gate", wall * 1e6,
         f"dispatches={rec['dispatches']};families={rec['n_families']};"
         f"arms_worst={arms['worst_slowdown']:.3f}@{arms['worst_cell']};"
         f"arms_thrash={arms['mean_thrash']:.3f}")
    claim("mixed-family robustness board is exactly ONE compiled dispatch",
          f"{rec['dispatches']} dispatch(es) for {rec['n_families']} "
          "families",
          "union fabric fuses every family onto one lane axis, no loops",
          rec["single_dispatch"])
    claim("ARMS worst-case slowdown on the adversarial suite",
          f"{arms['worst_slowdown']:.2f}x at {arms['worst_cell']} "
          f"(mean {arms['mean_slowdown']:.2f}x)",
          "<= 8x vs per-cell oracle; oracle self-slowdown == 1",
          arms["worst_slowdown"] <= 8.0
          and abs(oracle["worst_slowdown"] - 1.0) < 1e-6)
    try:
        with open("BENCH_robustness.json") as f:
            out = json.load(f)
    except (OSError, ValueError):
        out = {}
    # drop per-cell detail from the gate record; the board summary is
    # what CI diffs care about.
    out["gate"] = dict(rec, leaderboard={
        p: {kk: v for kk, v in b.items() if kk != "cells"}
        for p, b in rec["leaderboard"].items()})
    with open("BENCH_robustness.json", "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")


def bench_serving_gate():
    """Quick-gate for the closed serving loop (benchmarks/bench_serving.py):
    real decode traffic drives the policy-generic tiered paged-KV pool,
    its captured attention-mass trace is fitted to WorkloadSpec knobs and
    swept — together with the multi-tenant ``scenarios.serving_mix``
    built from the fit AND the raw trace replay — across every
    leaderboard policy family.  Asserts (a) the serving sweep and the
    trace replay each compile to exactly ONE mixed-family dispatch (the
    union fabric, simulator/fabric.py),
    (b) the captured trace appears as a scenario row of the board next
    to the fitted lane, and (c) the device-side telemetry carry did not
    collapse throughput vs the legacy per-token host-sync path.  Records
    the gate-scale board in BENCH_serving.json under "gate"
    (benchmarks/bench_serving.py writes the full-scale record)."""
    import json

    from benchmarks.bench_serving import run_serving

    t0 = time.time()
    rec = run_serving(n_tokens=16, batch=1, T=48, n=128, k=16,
                      arches=("granite-8b",),
                      serve_policies=("arms", "jenga"))
    wall = time.time() - t0
    sync = rec["telemetry_sync"]
    emit("serving_gate", wall * 1e6,
         f"sweep_disp={rec['sweep_dispatches']};"
         f"replay_disp={rec['replay_dispatches']};"
         f"families={rec['n_families']};"
         f"sync_speedup={sync['speedup']:.3f};"
         f"trace={rec['trace']['T']}x{rec['trace']['n']}")
    claim("serving sweep + trace replay are each ONE mixed-family dispatch",
          f"{rec['sweep_dispatches']}+{rec['replay_dispatches']} "
          f"dispatches for {rec['n_families']} families",
          "fitted/mix lanes and the replay ride one union lane axis",
          rec["single_dispatch"])
    claim("captured serving trace is a leaderboard scenario row",
          f"rows={rec['scenarios']}",
          "trace + fit:<label> + serving-mix rows present",
          "trace" in rec["scenarios"]
          and rec["fitted_label"] in rec["scenarios"]
          and any(s.startswith("serving-mix") for s in rec["scenarios"]))
    claim("device-side telemetry keeps serving throughput",
          f"{sync['tok_s_device']} tok/s device vs "
          f"{sync['tok_s_synced']} tok/s per-token sync "
          f"({sync['speedup']:.2f}x)",
          ">= 0.5x of the host-sync path (records the before/after)",
          sync["speedup"] >= 0.5)
    try:
        with open("BENCH_serving.json") as f:
            out = json.load(f)
    except (OSError, ValueError):
        out = {}
    out["gate"] = dict(rec, leaderboard={
        p: {kk: v for kk, v in b.items() if kk != "cells"}
        for p, b in rec["leaderboard"].items()})
    with open("BENCH_serving.json", "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")


# ----------------------- CI gate: mesh sweep fabric (sharding + union)
def bench_sharding_gate():
    """Quick-gate for the mesh sweep fabric (simulator/fabric.py, bench in
    benchmarks/bench_sharding.py): the mixed-family panel must (a) be
    bitwise-identical unsharded and at every mesh size in {1, 2, 4, 8}
    (run in a subprocess — splitting the host into virtual devices needs
    XLA_FLAGS set before jax initializes), (b) compile to exactly ONE
    union dispatch where the grouped path needs one per family, and (c)
    keep sharded throughput within noise of the unsharded path (>= 0.5x
    on a single-core CI host; on real multi-device hosts the curve
    scales).  Records the curve in BENCH_sharding.json under "gate"
    (benchmarks/bench_sharding.py writes the full-scale record)."""
    import json
    import os
    import subprocess
    import sys

    script = os.path.join(os.path.dirname(__file__), "bench_sharding.py")
    t0 = time.time()
    proc = subprocess.run([sys.executable, script, "--gate"],
                          capture_output=True, text=True)
    wall = time.time() - t0
    rec = {}
    if proc.returncode == 0:
        try:
            with open("BENCH_sharding.json") as f:
                rec = json.load(f)["gate"]
        except (OSError, ValueError, KeyError):
            rec = {}
    if not rec:
        tail = (proc.stderr or proc.stdout or "")[-300:]
        claim("mesh fabric gate subprocess produced a record",
              f"rc={proc.returncode}: {tail!r}", "BENCH_sharding.json gate "
              "record written", False)
        return
    curve = {c["mesh"]: c["lanes_per_s"] for c in rec["mesh_curve"]}
    emit("sharding_gate", wall * 1e6,
         f"lanes={rec['lanes']};devices={rec['devices']};"
         f"union_disp={rec['union']['dispatches']};"
         f"grouped_disp={rec['grouped']['dispatches']};"
         + ";".join(f"mesh{m}={v}l/s" for m, v in sorted(curve.items()))
         + f";unsharded={rec['union']['lanes_per_s']}l/s")
    claim("mesh-sharded sweep bitwise == unsharded at {1,2,4,8}",
          f"bitwise_all={rec['bitwise_all_meshes']} over "
          f"{len(rec['mesh_curve'])} mesh sizes x {rec['lanes']} lanes",
          "every cell bitwise-identical, padded lanes dropped",
          rec["bitwise_all_meshes"])
    claim("mixed-family board: ONE union dispatch vs one per family",
          f"union={rec['union']['dispatches']}, "
          f"grouped={rec['grouped']['dispatches']} "
          f"({rec['n_families']} families)",
          "union == 1 and grouped == n_families",
          rec["union_single_dispatch"]
          and rec["grouped_dispatch_per_family"])
    claim("sharded throughput within noise of unsharded",
          f"{rec['sharded_throughput_ratio']}x at mesh="
          f"{rec['best_mesh']}",
          ">= 0.5x on shared-core virtual devices",
          rec["sharded_throughput_ratio"] >= 0.5)


# ------------------------------------------------------------------ Fig. 7
def bench_main_comparison():
    """ARMS vs HeMem/tuned-HeMem/Memtis/TPP on pmem-large."""
    vs_hemem, vs_memtis, vs_tpp, vs_tuned = [], [], [], []
    for wl in common.WORKLOAD_SET:
        trace = common.trace_for(wl)
        res = {}
        for pol in ("all-slow", "hemem", "memtis", "tpp", "arms"):
            res[pol], wall = common.run_policy(pol, trace)
        _cfg, tuned, _ = tuning.tune_hemem(trace, PMEM_LARGE, common.K,
                                           budget=24)
        a = res["arms"].exec_time_s
        vs_hemem.append(res["hemem"].exec_time_s / a)
        vs_memtis.append(res["memtis"].exec_time_s / a)
        vs_tpp.append(res["tpp"].exec_time_s / a)
        vs_tuned.append(tuned.exec_time_s / a)
        emit(f"main_comparison.{wl}", wall * 1e6,
             f"arms_vs_hemem={vs_hemem[-1]:.3f};"
             f"arms_vs_memtis={vs_memtis[-1]:.3f};"
             f"arms_vs_tpp={vs_tpp[-1]:.3f};"
             f"arms_vs_tuned={vs_tuned[-1]:.3f}")
    claim("ARMS vs default HeMem (geomean)", f"{geomean(vs_hemem):.2f}x",
          "paper: 1.26x", geomean(vs_hemem) >= 1.2)
    claim("ARMS vs Memtis (geomean)", f"{geomean(vs_memtis):.2f}x",
          "paper: 1.34x", geomean(vs_memtis) >= 1.1)
    claim("ARMS vs TPP (geomean)", f"{geomean(vs_tpp):.2f}x",
          "paper: 2.3x", geomean(vs_tpp) >= 1.5)
    claim("ARMS within 3% of tuned HeMem (geomean)",
          f"{geomean(vs_tuned):.3f}", "paper: >=0.97",
          geomean(vs_tuned) >= 0.97)


# ----------------------------------------------------------------- Fig. 10
def bench_migrations():
    """Promotion counts + wasteful migrations per system."""
    tot = {p: 0 for p in ("hemem", "memtis", "tpp", "arms")}
    waste = dict(tot)
    for wl in common.WORKLOAD_SET:
        trace = common.trace_for(wl)
        for pol in tot:
            res, wall = common.run_policy(pol, trace)
            tot[pol] += res.promotions
            waste[pol] += res.wasteful
        emit(f"migrations.{wl}", wall * 1e6,
             ";".join(f"{p}={tot[p]}" for p in tot))
    emit("migrations.wasteful_total", 0,
         ";".join(f"{p}={waste[p]}" for p in waste))
    claim("TPP migrates most (paper: 'extremely high')",
          f"tpp={tot['tpp']}", f"> 2x arms={tot['arms']}",
          tot["tpp"] > 2 * tot["arms"])
    claim("ARMS wasteful migrations lowest among adaptive systems",
          f"arms={waste['arms']}",
          f"<= memtis={waste['memtis']}, tpp={waste['tpp']}",
          waste["arms"] <= waste["memtis"]
          and waste["arms"] <= waste["tpp"])


# ------------------------------------------------------------------ Fig. 9
def bench_adaptivity():
    """PHT change-point detection timeline (btree hot-set shift)."""
    trace = common.trace_for("btree")   # shuffles hot set at T/2
    res, wall = common.run_policy("arms", trace)
    mode = res.timeline_mode
    shift = common.T // 2
    detect = np.flatnonzero(mode[shift:] == 1)
    latency = int(detect[0]) if len(detect) else -1
    emit("adaptivity.btree", wall * 1e6,
         f"detect_latency_intervals={latency};"
         f"recency_intervals={int((mode == 1).sum())}")
    claim("PHT detects hot-set change (Fig. 9)",
          f"latency={latency} intervals", "< 25 intervals (2.5s)",
          0 <= latency < 25)


# ----------------------------------------------------------------- Fig. 13
def bench_tier_ratios():
    """ARMS vs default HeMem across fast:slow capacity ratios."""
    wins = []
    for wl in ("xsbench", "gups"):
        trace = common.trace_for(wl)
        for ratio in (16, 8, 4, 2):
            k = common.N_PAGES // ratio
            h, _ = common.run_policy("hemem", trace, k=k)
            a, wall = common.run_policy("arms", trace, k=k)
            sp = h.exec_time_s / a.exec_time_s
            wins.append(sp)
            emit(f"tier_ratios.{wl}.1to{ratio}", wall * 1e6,
                 f"arms_vs_hemem={sp:.3f}")
    claim("ARMS robust across tier ratios (Fig. 13)",
          f"min={min(wins):.2f}x", ">= 0.95x at every ratio",
          min(wins) >= 0.95)


# ----------------------------------------------------------------- Fig. 12
def bench_scaling():
    """Thread-count analogue: workload intensity scaling (MLP factor)."""
    import dataclasses
    trace = common.trace_for("silo-ycsb")
    for mlp in (16, 32, 64, 128):   # ~4..20 threads of MLP
        m = dataclasses.replace(PMEM_LARGE, mlp=float(mlp))
        h, _ = common.run_policy("hemem", trace, machine=m)
        a, wall = common.run_policy("arms", trace, machine=m)
        emit(f"scaling.mlp{mlp}", wall * 1e6,
             f"arms_vs_hemem={h.exec_time_s / a.exec_time_s:.3f}")


# ----------------------------------------------------------------- Fig. 11
def bench_numa_machine():
    """Different hardware (emulated-CXL NUMA node), no re-tuning."""
    sp = []
    for wl in ("gups", "btree", "silo-ycsb", "xsbench"):
        trace = common.trace_for(wl)
        h, _ = common.run_policy("hemem", trace, machine=NUMA)
        a, wall = common.run_policy("arms", trace, machine=NUMA)
        sp.append(h.exec_time_s / a.exec_time_s)
        emit(f"numa.{wl}", wall * 1e6, f"arms_vs_hemem={sp[-1]:.3f}")
    claim("ARMS wins on different hardware without re-tuning (Fig. 11)",
          f"{geomean(sp):.2f}x", ">= 1.0x geomean", geomean(sp) >= 1.0)


# -------------------------------------------- batched sweeps (scan engine)
def bench_arms_sweep(budget: int = 24, n_seeds: int = 8,
                     n: int = 4096, T: int = 512):
    """Batched lax.scan+vmap ARMS sweeps vs the sequential numpy loop.

    Runs at the acceptance scale (n_pages >= 4096, T >= 512).  Three
    numbers per sweep: sequential numpy loop, batched cold (includes the
    one-off compile), batched warm.  Returns a dict for BENCH_tuning.json.
    """
    import time

    from repro.baselines.arms_policy import ARMSPolicy
    from repro.core.state import ARMSConfig
    from repro.simulator import scan_engine, workloads

    trace = workloads.make("gups", T=T, n=n)
    k = n // 8
    rec = dict(workload="gups", n_pages=n, T=T, k=k, budget=budget,
               n_seeds=n_seeds)

    # --- config sweep (the tuning study) ---
    cfgs = tuning.sample_arms_configs(budget)
    t0 = time.time()
    for cfg in cfgs:
        run(ARMSPolicy(ARMSConfig(**cfg)), trace, PMEM_LARGE, k, seed=0)
    rec["config_sweep_sequential_s"] = round(time.time() - t0, 3)

    overrides = {key: [c[key] for c in cfgs] for key in tuning.ARMS_SPACE}
    t0 = time.time()
    scan_engine.sweep_arms_configs(trace, PMEM_LARGE, k, overrides)
    rec["config_sweep_batched_cold_s"] = round(time.time() - t0, 3)
    t0 = time.time()
    scan_engine.sweep_arms_configs(trace, PMEM_LARGE, k, overrides)
    rec["config_sweep_batched_warm_s"] = round(time.time() - t0, 3)

    # same sweep with the pure-jnp score path (the Pallas kernel runs in
    # interpret mode off-TPU, which costs extra under batching)
    jnp_cfg = ARMSConfig(use_score_kernel=False)
    scan_engine.sweep_arms_configs(trace, PMEM_LARGE, k, overrides,
                                   base_cfg=jnp_cfg)
    t0 = time.time()
    scan_engine.sweep_arms_configs(trace, PMEM_LARGE, k, overrides,
                                   base_cfg=jnp_cfg)
    rec["config_sweep_batched_warm_jnp_s"] = round(time.time() - t0, 3)

    # --- seed sweep ---
    seeds = list(range(n_seeds))
    t0 = time.time()
    for s in seeds:
        run(ARMSPolicy(), trace, PMEM_LARGE, k, seed=s)
    rec["seed_sweep_sequential_s"] = round(time.time() - t0, 3)
    t0 = time.time()
    scan_engine.sweep_seeds(trace, PMEM_LARGE, k, seeds)
    rec["seed_sweep_batched_cold_s"] = round(time.time() - t0, 3)
    t0 = time.time()
    scan_engine.sweep_seeds(trace, PMEM_LARGE, k, seeds)
    rec["seed_sweep_batched_warm_s"] = round(time.time() - t0, 3)

    sp_cfg = rec["config_sweep_sequential_s"] / \
        rec["config_sweep_batched_warm_s"]
    sp_cfg_jnp = rec["config_sweep_sequential_s"] / \
        rec["config_sweep_batched_warm_jnp_s"]
    sp_seed = rec["seed_sweep_sequential_s"] / \
        rec["seed_sweep_batched_warm_s"]
    rec["config_sweep_speedup"] = round(sp_cfg, 2)
    rec["config_sweep_speedup_jnp"] = round(sp_cfg_jnp, 2)
    rec["seed_sweep_speedup"] = round(sp_seed, 2)
    emit(f"arms_sweep.config.n{n}",
         rec["config_sweep_batched_warm_s"] * 1e6,
         f"seq={rec['config_sweep_sequential_s']}s;"
         f"speedup={sp_cfg:.2f}x;jnp_path={sp_cfg_jnp:.2f}x")
    emit(f"arms_sweep.seeds.n{n}",
         rec["seed_sweep_batched_warm_s"] * 1e6,
         f"seq={rec['seed_sweep_sequential_s']}s;speedup={sp_seed:.2f}x")
    # conservative CI gate (the recorded BENCH_tuning.json documents the
    # full before/after including the pre-PR per-interval-sync baseline,
    # which is what the >=5x acceptance figure is measured against)
    claim("batched ARMS sweep beats sequential numpy loop",
          f"{max(sp_cfg, sp_cfg_jnp):.2f}x", ">= 2x (5x vs pre-PR baseline)",
          max(sp_cfg, sp_cfg_jnp) >= 2.0)
    return rec


# --------------------------------------------------------- §5/§6 overheads
def bench_overheads():
    """ARMS controller cost per policy interval + metadata bytes/page."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.core import ARMSConfig, arms_step, init_state

    for n in (4096, 65536, 1 << 20):
        cfg = ARMSConfig()
        st = init_state(n, cfg)
        counts = jnp.ones((n,))
        st, _ = arms_step(st, counts, 0.5, 0.5, cfg=cfg, k=n // 8)  # compile
        jax.block_until_ready(st.score)
        t0 = time.time()
        iters = 20
        for _ in range(iters):
            st, _ = arms_step(st, counts, 0.5, 0.5, cfg=cfg, k=n // 8)
        jax.block_until_ready(st.score)
        us = (time.time() - t0) / iters * 1e6
        emit(f"overheads.controller.n{n}", us,
             f"us_per_page={us / n:.4f}")
    # metadata bytes/page: 2 EWMAs + 2 scores (f32) + hot_age (i32) + tier
    emit("overheads.metadata", 0, "bytes_per_page=21 (paper: ~20)")
