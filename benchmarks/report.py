"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run artifacts.  Usage:
  PYTHONPATH=src:. python -m benchmarks.report > /tmp/tables.md
"""
from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["zamba2-1.2b", "mistral-nemo-12b", "stablelm-1.6b",
              "qwen3-14b", "granite-8b", "llama4-scout-17b-16e",
              "deepseek-v2-236b", "mamba2-370m", "whisper-small",
              "llava-next-mistral-7b"]


def load():
    recs = {}
    for p in sorted(ARTIFACTS.glob("*.json")):
        r = json.loads(p.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_bytes(n):
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}EB"


def dryrun_table(recs):
    print("| arch | shape | 16x16 | 2x16x16 | compile(s) | "
          "args/dev | temp/dev |")
    print("|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r1 = recs.get((a, s, "16x16"))
            r2 = recs.get((a, s, "2x16x16"))
            if r1 is None:
                continue
            if r1["status"] == "skipped":
                print(f"| {a} | {s} | skip | skip | — | — | — |"
                      f"  <!-- {r1['reason']} -->")
                continue
            mem = r1.get("memory_analysis", {})
            print(f"| {a} | {s} | ok | "
                  f"{'ok' if r2 and r2['status'] == 'ok' else '—'} | "
                  f"{r1.get('compile_s', 0)} | "
                  f"{fmt_bytes(mem.get('argument_size_in_bytes', 0) / 256)} | "
                  f"{fmt_bytes(mem.get('temp_size_in_bytes', 0) / 256)} |")


def roofline_table(recs, mesh="16x16"):
    print("| arch | shape | compute(s) | memory(s) | collective(s) | "
          "dominant | MODEL/HLO flops | bound(s) |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None or r["status"] != "ok":
                continue
            rf = r["roofline"]
            useful = r.get("useful_flops_ratio") or 0
            bound = max(rf["compute_s"], rf["memory_s"],
                        rf["collective_s"])
            print(f"| {a} | {s} | {rf['compute_s']:.3e} | "
                  f"{rf['memory_s']:.3e} | {rf['collective_s']:.3e} | "
                  f"**{rf['dominant']}** | {useful:.3f} | {bound:.3e} |")


def _advice(rec) -> str:
    """One sentence on what would move the dominant term down (per cell)."""
    dom = rec["roofline"]["dominant"]
    shape, arch = rec["shape"], rec["arch"]
    kind = ("train" if shape.startswith("train")
            else "prefill" if shape.startswith("prefill") else "decode")
    moe = "deepseek" in arch or "llama4" in arch
    ssm = "mamba" in arch or "zamba" in arch
    if dom == "compute":
        return ("raise per-chip utilization: larger microbatch / fused "
                "Pallas kernels keep the MXU fed")
    if dom == "collective":
        if kind == "train":
            return ("reduce-scatter gradients + bf16/int8-EF compression on "
                    "the pod axis (ft/compression) halves the all-reduce "
                    "volume")
        if moe:
            return ("shard_map all-to-all MoE dispatch replaces the "
                    "expert-buffer partial-sum all-reduce")
        return ("shrink the TP degree for this model size, or replicate "
                "small embedding tables (serve layout)")
    # memory-dominant
    if kind == "decode":
        if ssm:
            return ("state is already O(1); fuse the recurrent update "
                    "(kernels/mamba_scan) to cut per-step round-trips")
        return ("ARMS KV-page tiering + sparse paged attention serves only "
                "the hot working set (tiering/sparse_attention: 0.4x pages "
                "at 0.3% error)")
    if kind == "prefill":
        return ("Pallas flash/SSD kernels keep score tiles in VMEM; "
                "xla_flash already applied — the rest is kernel headroom")
    return ("remat policy tuning (checkpoint only matmul outputs) + flash "
            "kernels remove the recompute-pass HBM traffic")


def advice_section(recs, mesh="16x16"):
    print("\n### Bottleneck advice (per cell, single-pod)\n")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None or r["status"] != "ok":
                continue
            print(f"- **{a} × {s}** ({r['roofline']['dominant']}-bound): "
                  f"{_advice(r)}")


def main():
    recs = load()
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    n_skip = sum(1 for r in recs.values() if r["status"] == "skipped")
    print(f"<!-- {len(recs)} artifacts: {n_ok} ok, {n_skip} skipped, "
          f"{len(recs) - n_ok - n_skip} failed -->\n")
    print("### Dry-run matrix\n")
    dryrun_table(recs)
    print("\n### Roofline (single-pod 16x16, 256 chips)\n")
    roofline_table(recs, "16x16")
    print("\n### Roofline (multi-pod 2x16x16, 512 chips)\n")
    roofline_table(recs, "2x16x16")
    advice_section(recs)


if __name__ == "__main__":
    main()
