"""Serving benchmark: the closed model-stack loop (DESIGN.md §10).

Four pieces, one record (``BENCH_serving.json``):

* **policy x arch serving grid** — ``launch/serve.py`` decode runs with
  the tiered paged-KV pool under several policy families: tokens/s plus
  the leaderboard telemetry (slowdown vs all-fast, thrash, promotions).
* **telemetry sync cost** (satellite b) — the same run with the legacy
  per-token host-sync telemetry vs the device-side carry (one sync at
  the end); records the before/after tokens/s.
* **capture -> fit -> sweep** — the serving run's attention-mass stream
  is captured as a ``TraceWorkload``, fitted to WorkloadSpec knobs, and
  swept TOGETHER with the multi-tenant ``scenarios.serving_mix`` built
  from the fitted spec, for every leaderboard policy family across
  machines — ONE ``experiment.sweep`` call, which the union fabric
  (simulator/fabric.py) compiles to ONE dispatch for the whole
  mixed-family panel (counted via ``scan_engine.count_dispatches``).
* **trace replay** — the captured trace itself runs as a sweep lane
  (``traces.replay``), appearing as the ``trace`` scenario row of the
  board.

The board scores each (policy, scenario, machine) cell as slowdown vs
the per-cell oracle, robustness-leaderboard style (sorted by worst
case).

Usage: PYTHONPATH=src:. python benchmarks/bench_serving.py \
           [--out BENCH_serving.json]
"""
from __future__ import annotations

import argparse
import json
import time

from repro.simulator import experiment, scan_engine, scenarios, traces

#: serving-grid axes (full run); the gate shrinks tokens, not the axes.
ARCHES = ("granite-8b", "stablelm-1.6b")
SERVE_POLICIES = ("arms", "memtis", "jenga")
#: sweep axes: the robustness-leaderboard families and machine set, plus
#: the serving preset whose fast tier is pinned to the roofline HBM bw.
SWEEP_POLICIES = ("oracle", "arms", "hemem", "memtis", "tpp",
                  "hybridtier", "jenga", "tierbpf")
SWEEP_MACHINES = ("hbm-pcie", "pmem-large")


def _serve_grid(arches, policies, n_tokens, batch):
    from repro.launch.serve import serve
    grid = {}
    capture = None
    for arch in arches:
        for pol in policies:
            t0 = time.time()
            rep = serve(arch, n_tokens=n_tokens, batch=batch, page_size=4,
                        policy=pol, capture=capture is None, quiet=True)
            grid[f"{arch}/{pol}"] = dict(
                tok_s=round(rep.tok_s, 2),
                wall_s=round(time.time() - t0, 3),
                promotions=rep.promotions, demotions=rep.demotions,
                thrash=round(rep.thrash, 4),
                slowdown=round(rep.slowdown, 4),
                fast_mass_end=round(float(rep.fast_mass[-1]), 4))
            if capture is None:
                capture = rep.trace
    return grid, capture


def _sync_comparison(arch, n_tokens, batch):
    """satellite (b): per-token host-sync telemetry vs device-side carry."""
    from repro.launch.serve import serve
    kw = dict(n_tokens=n_tokens, batch=batch, page_size=4, quiet=True)
    serve(arch, **kw)                                  # warm the caches
    sync = serve(arch, sync_telemetry=True, **kw).tok_s
    async_ = serve(arch, **kw).tok_s
    return dict(tok_s_synced=round(sync, 2), tok_s_device=round(async_, 2),
                speedup=round(async_ / max(sync, 1e-9), 3))


def _board(res):
    """Leaderboard rows: slowdown vs the per-cell BEST policy, worst-case
    sorted.  (bench_robustness normalizes by the oracle; here the machine
    axis includes hbm-pcie, where fast-tier accesses are nearly free and
    the oracle's every-interval remigration over PCIe makes it the
    WORST policy on churny cells — the per-cell best is the meaningful
    yardstick, and slowdown >= 1 by construction.)"""
    scen, mach = res.axes["workload"], res.axes["machine"]
    oracle = {(w, m): min(res.at(policy=p, workload=w,
                                 machine=m).exec_time_s
                          for p in res.axes["policy"])
              for w in scen for m in mach}
    board = {}
    for p in res.axes["policy"]:
        cells = []
        for w in scen:
            for m in mach:
                r = res.at(policy=p, workload=w, machine=m)
                moves = r.promotions + r.demotions
                cells.append(dict(
                    scenario=w, machine=m,
                    slowdown=round(r.exec_time_s / oracle[(w, m)], 4),
                    thrash=round(r.wasteful / max(moves, 1), 4)))
        worst = max(cells, key=lambda c: c["slowdown"])
        board[p] = dict(
            worst_slowdown=worst["slowdown"],
            worst_cell=f"{worst['scenario']}@{worst['machine']}",
            mean_slowdown=round(sum(c["slowdown"] for c in cells)
                                / len(cells), 4),
            cells=cells)
    return board


def run_serving(n_tokens: int = 32, batch: int = 2, T: int = 96,
                n: int = 256, k: int = 32, arches=ARCHES,
                serve_policies=SERVE_POLICIES, policies=SWEEP_POLICIES,
                machines=SWEEP_MACHINES, tenants: int = 4) -> dict:
    """Run the full serving benchmark; returns the BENCH_serving record."""
    grid, tw = _serve_grid(arches, serve_policies, n_tokens, batch)
    sync = _sync_comparison(arches[0], n_tokens, batch)

    # capture -> fit -> multi-tenant scenario, swept with every family
    fit = traces.fit_workload_spec(tw)
    mix = scenarios.serving_mix(n, k, tenants=tenants, specs=[fit])
    n_families = len({type(experiment.policy_spec(p)) for p in policies})

    t0 = time.time()
    with scan_engine.count_dispatches() as ctr:
        res = experiment.sweep(list(policies), workloads=[fit, mix],
                               machines=list(machines), k=k, T=T, n=n)
    sweep_disp = ctr.count
    with scan_engine.count_dispatches() as ctr:
        # the replay lane runs at the CAPTURED geometry (tw.n pages), with
        # a proportional fast tier
        rep = traces.replay(tw, list(policies), machines=list(machines))
    replay_disp = ctr.count
    wall = time.time() - t0

    board = _board(res)
    replay_board = _board(rep)
    # the captured trace is a scenario row of the combined leaderboard
    for p, row in replay_board.items():
        board[p]["cells"].extend(row["cells"])
        worst = max(board[p]["cells"], key=lambda c: c["slowdown"])
        board[p].update(
            worst_slowdown=worst["slowdown"],
            worst_cell=f"{worst['scenario']}@{worst['machine']}",
            mean_slowdown=round(sum(c["slowdown"]
                                    for c in board[p]["cells"])
                                / len(board[p]["cells"]), 4))
    ranked = sorted(board, key=lambda p: board[p]["worst_slowdown"])
    scen_rows = sorted({c["scenario"] for c in board[ranked[0]]["cells"]})

    return dict(
        n_tokens=n_tokens, batch=batch, T=T, n_pages=n, k=k,
        serving_grid=grid, telemetry_sync=sync,
        trace=dict(label=tw.label, T=tw.T, n=tw.n,
                   total=round(tw.total(), 3)),
        fitted_label=f"fit:{tw.label}",
        scenarios=scen_rows, machines=list(machines),
        policies=list(map(str, policies)), n_families=n_families,
        sweep_dispatches=sweep_disp, replay_dispatches=replay_disp,
        single_dispatch=sweep_disp == 1 and replay_disp == 1,
        wall_s=round(wall, 3), ranking=ranked, leaderboard=board)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--T", type=int, default=96)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--k", type=int, default=32)
    args = ap.parse_args()

    rec = run_serving(n_tokens=args.tokens, T=args.T, n=args.n, k=args.k)
    # merge: keep the "gate" record CI wrote, replace the full-scale one.
    try:
        with open(args.out) as f:
            out = json.load(f)
    except (OSError, ValueError):
        out = {}
    out["full"] = rec
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"sweep dispatches={rec['sweep_dispatches']} + replay "
          f"{rec['replay_dispatches']} (families={rec['n_families']}) "
          f"wall={rec['wall_s']}s  sync speedup="
          f"{rec['telemetry_sync']['speedup']}x")
    hdr = f"{'policy':<12} {'worst':>7} {'mean':>7}  worst cell"
    print(hdr + "\n" + "-" * len(hdr))
    for p in rec["ranking"]:
        b = rec["leaderboard"][p]
        print(f"{p:<12} {b['worst_slowdown']:>7.3f} "
              f"{b['mean_slowdown']:>7.3f}  {b['worst_cell']}")


if __name__ == "__main__":
    main()
