"""Framework-layer benchmarks: kernels vs refs, tiered serving telemetry,
roofline summary from the dry-run artifacts."""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def bench_kernels():
    """Pallas kernels (interpret mode on CPU) vs jnp references.

    On CPU the kernels run interpreted (validation only) — the reference
    timing is the meaningful CPU number; kernel wall time is reported for
    completeness, not speed."""
    rng = np.random.default_rng(0)
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_ref
    B, H, KV, dh, page, npp = 4, 8, 8, 128, 64, 8
    P = npp * B
    q = jnp.asarray(rng.standard_normal((B, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((P, page, KV, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((P, page, KV, dh)), jnp.float32)
    tables = jnp.asarray(
        np.stack([rng.choice(P, npp, replace=False) for _ in range(B)]),
        jnp.int32)
    lens = jnp.full((B,), page * npp, jnp.int32)
    us_ref = _time(jax.jit(paged_attention_ref), q, k, v, tables, lens)
    err = float(jnp.abs(
        paged_attention(q, k, v, tables, lens)
        - paged_attention_ref(q, k, v, tables, lens)).max())
    emit("kernels.paged_attention.ref", us_ref, f"allclose_err={err:.2e}")

    from repro.kernels.migrate.ref import migrate_ref
    src = jnp.asarray(rng.standard_normal((64, 64, 256)), jnp.float32)
    dst = jnp.asarray(rng.standard_normal((64, 64, 256)), jnp.float32)
    idx = jnp.asarray(rng.choice(64, 16, replace=False), jnp.int32)
    valid = jnp.ones(16, bool)
    us = _time(jax.jit(migrate_ref), src, dst, idx, idx, valid)
    mb = 16 * 64 * 256 * 4 / 1e6
    emit("kernels.migrate.ref", us, f"GB_s={mb / us * 1e3:.1f}")

    from repro.kernels.score_update.ops import score_update
    n = 1 << 20
    s = jnp.asarray(rng.random(n), jnp.float32)
    c = jnp.asarray(rng.poisson(5, n), jnp.float32)
    kw = dict(alpha_s=0.7, alpha_l=0.1, w_s=0.2, w_l=0.8, use_kernel=False)
    us = _time(lambda a, b, cc: score_update(a, b, cc, **kw), s, s, c)
    emit("kernels.score_update.ref", us, f"pages_per_us={n / us:.0f}")


def bench_tiered_serving():
    """Tokens/s + tiering telemetry for the tiered paged-KV serving layer."""
    from repro.launch.serve import serve
    t0 = time.time()
    rep = serve("granite-8b", n_tokens=48, batch=2, quiet=True)
    emit("serving.tiered_paged_kv", (time.time() - t0) * 1e6,
         f"tok_s={rep.tok_s:.1f};promotions={rep.promotions};"
         f"fast_mass_end={rep.fast_mass[-1]:.3f};"
         f"slowdown={rep.slowdown:.2f}")


def bench_sparse_serving():
    """Beyond-paper: ARMS-guided sparse attention — attended fraction and
    approximation error vs full paged attention on a skewed cache."""
    import dataclasses

    import numpy as np

    from repro.tiering import paged_kv as PK
    from repro.tiering.sparse_attention import sparse_attention_step

    cfg = PK.PagedKVConfig(page_size=8, n_pages=16, fast_pages=4,
                           policy_every=2)
    B, KV, H, DH = 1, 2, 4, 16
    rng = np.random.default_rng(0)
    kv = PK.init_paged_kv(cfg, B, KV, DH, dtype=jnp.float32)
    steps = cfg.page_size * cfg.n_pages
    t0 = time.time()
    for t in range(steps):
        q = jnp.asarray(rng.standard_normal((B, H, DH)), jnp.float32)
        scale = 6.0 if (t // cfg.page_size) in (2, 3) else 0.3
        k_new = jnp.asarray(rng.standard_normal((B, KV, DH)) * scale,
                            jnp.float32)
        v_new = jnp.asarray(rng.standard_normal((B, KV, DH)), jnp.float32)
        _, kv, _ = PK.serve_decode_step(kv, q, k_new, v_new, jnp.int32(t),
                                        cfg)
    pos = jnp.int32(steps - 1)
    full, _ = PK.paged_attention_step(kv, q, pos, cfg)
    sparse, _, frac = sparse_attention_step(kv, q, pos, cfg)
    err = float(jnp.abs(sparse - full).max() / jnp.abs(full).max())
    emit("serving.sparse_attention", (time.time() - t0) * 1e6,
         f"attended_frac={float(frac):.3f};rel_err={err:.3f}")


def bench_roofline_summary():
    """One CSV row per dry-run cell: the three roofline terms."""
    if not ARTIFACTS.exists():
        emit("roofline.missing", 0, "run launch/dryrun.py first")
        return
    for path in sorted(ARTIFACTS.glob("*.json")):
        rec = json.loads(path.read_text())
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        emit(f"roofline.{rec['arch']}.{rec['shape']}.{rec['mesh']}",
             r["compute_s"] * 1e6,
             f"dom={r['dominant']};mem_s={r['memory_s']:.3e};"
             f"coll_s={r['collective_s']:.3e};"
             f"useful={rec.get('useful_flops_ratio') or 0:.3f}")
