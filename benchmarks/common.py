"""Shared benchmark scaffolding: policies, workloads, CSV emission."""
from __future__ import annotations

import time

import numpy as np

from repro.baselines.arms_policy import ARMSPolicy
from repro.baselines.hemem import HeMemPolicy
from repro.baselines.memtis import MemtisPolicy
from repro.baselines.static import AllSlowPolicy, OraclePolicy
from repro.baselines.tpp import TPPPolicy
from repro.simulator import workloads
from repro.simulator.engine import run
from repro.simulator.machine import NUMA, PMEM_LARGE

T, N_PAGES = 300, 2048
K = N_PAGES // 8          # 1:8 fast:slow ratio (paper default)

WORKLOAD_SET = ["gups", "btree", "silo-ycsb", "silo-tpcc", "xsbench",
                "gapbs-bc", "gapbs-pr", "gapbs-cc", "liblinear"]

POLICIES = {
    "all-slow": AllSlowPolicy,
    "hemem": HeMemPolicy,
    "memtis": MemtisPolicy,
    "tpp": TPPPolicy,
    "arms": ARMSPolicy,
    "oracle": OraclePolicy,
}

_ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.2f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def header():
    print("name,us_per_call,derived", flush=True)


def spec_for(wl: str, t=T):
    """The declarative WorkloadSpec for a named workload (scenario
    combinators compose on top of these; scan-engine benches synthesize
    straight from the spec with no [T, n] array)."""
    return workloads.spec(wl, T=t)


def trace_for(wl: str, n=N_PAGES, t=T):
    """Materialized f32 trace for the numpy reference engine."""
    return spec_for(wl, t=t).materialize(t, n)


def timed(fn, *args, **kwargs):
    """(result, seconds) with the timer stopped only after the FULL result
    pytree is device-complete.  JAX dispatch is asynchronous: timing the
    call alone measures enqueue, not execution, so warm BENCH_*.json
    numbers would be understated.  ``block_until_ready`` traverses any
    pytree and no-ops on non-array leaves (SimResult floats etc.)."""
    import jax

    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(*args, **kwargs))
    return out, time.perf_counter() - t0


def run_policy(policy_name: str, trace, machine="pmem-large", k=K, seed=0):
    """``machine`` may be a registry name, MachineSpec, or
    TieredMachineSpec — resolution is one ``machines.get`` inside the
    engine."""
    return timed(run, POLICIES[policy_name](), trace, machine, k, seed=seed)


def geomean(xs):
    xs = np.asarray(xs, dtype=float)
    return float(np.exp(np.log(np.maximum(xs, 1e-12)).mean()))
