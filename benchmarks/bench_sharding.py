"""Mesh sweep fabric benchmark: lanes-per-second vs mesh size, and
union vs grouped dispatch on a mixed-family panel -> BENCH_sharding.json.

The fabric (simulator/fabric.py) promises two things this bench
measures and the ``bench_sharding_gate`` in run.py --quick asserts:

* **Sharding is free correctness-wise** — the same mixed-family
  P×W×M×S panel, run unsharded and under ``shard_map`` at mesh sizes
  {1, 2, 4, 8}, is bitwise-identical cell for cell (padded lanes are
  dropped before labeling, so non-multiple lane counts are exercised
  on purpose).
* **The union state kills the per-family dispatch** — the mixed board
  is exactly ONE compiled program (``scan_engine.count_dispatches``),
  vs one per family on the grouped path, without losing bitwise
  equality.

Mesh sizes > 1 need the host platform split into virtual devices
BEFORE jax initializes, so this script re-execs itself with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` appended; the
gate just runs the script as a subprocess and reads the JSON back.
Throughput context: on a multi-core (or genuinely multi-device) host
the lane shards run concurrently and the curve scales; CI containers
pinned to one core still must stay within noise of the unsharded path
(the gate bound is >= 0.5x, recorded honestly either way).

Usage: PYTHONPATH=src:. python benchmarks/bench_sharding.py \
           [--gate] [--out BENCH_sharding.json]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

_CHILD_ENV = "_BENCH_SHARDING_CHILD"
_FORCE_FLAG = "--xla_force_host_platform_device_count=8"

#: the mixed-family gate panel: binary + tier-native + oracle families.
POLICIES = ("oracle", "arms", "hemem", "memtis", "tpp",
            "hybridtier", "jenga", "tierbpf")
WORKLOADS = ("gups", "btree", "silo-tpcc")
MACHINES = ("pmem-large", "dram-cxl-pmem")
MESH_SIZES = (1, 2, 4, 8)


def _cells(res):
    """Every scalar/summary field of every cell, as a flat list of numpy
    arrays (bitwise comparison payload)."""
    import dataclasses

    import numpy as np
    fields = [f.name for f in dataclasses.fields(type(res.grid[0]))
              if f.name != "name"]
    out = []
    for _, r in res.items():
        out.extend(np.asarray(getattr(r, f)) for f in fields
                   if getattr(r, f) is not None)
    return out


def run_sharding(T: int, n: int, k: int, policies=POLICIES,
                 workloads=WORKLOADS, machines=MACHINES,
                 mesh_sizes=MESH_SIZES) -> dict:
    """Measure the fabric; requires jax.device_count() >= max(mesh_sizes)
    (the __main__ re-exec guarantees it)."""
    import time

    import jax
    import numpy as np

    from repro.simulator import experiment, scan_engine

    def timed(**kw):
        with scan_engine.count_dispatches() as ctr:
            t0 = time.time()
            res = experiment.sweep(list(policies), workloads=list(workloads),
                                   machines=list(machines), k=k, T=T, n=n,
                                   **kw)
            jax.block_until_ready(
                [np.asarray(res.grid[0].exec_time_s)])
        return res, time.time() - t0, ctr.count, dict(ctr.last)

    n_families = len({type(experiment.policy_spec(p))
                      for p in policies})
    lanes = len(policies) * len(workloads) * len(machines)

    base, cold_u, disp_u, info_u = timed()            # auto -> union
    _, warm_u, _, _ = timed()
    _, cold_g, disp_g, _ = timed(dispatch="grouped")
    _, warm_g, _, _ = timed(dispatch="grouped")
    ref = _cells(base)

    curve, bitwise_all = [], True
    for D in mesh_sizes:
        res_d, cold_d, _, info_d = timed(mesh=D)
        _, warm_d, _, _ = timed(mesh=D)
        eq = all(np.array_equal(a, b) for a, b in zip(ref, _cells(res_d)))
        bitwise_all &= eq
        curve.append(dict(
            mesh=D, padded_lanes=info_d.get("padded_lanes"),
            cold_s=round(cold_d, 3), warm_s=round(warm_d, 4),
            lanes_per_s=round(lanes / max(warm_d, 1e-9), 1),
            bitwise_equal_to_unsharded=bool(eq)))

    unsharded_lps = lanes / max(warm_u, 1e-9)
    best = max(curve, key=lambda c: c["lanes_per_s"])
    return dict(
        T=T, n_pages=n, k=k, lanes=lanes, devices=jax.device_count(),
        policies=list(policies), n_families=n_families,
        workloads=list(workloads), machines=list(machines),
        union=dict(dispatches=disp_u, cold_s=round(cold_u, 3),
                   warm_s=round(warm_u, 4),
                   lanes_per_s=round(unsharded_lps, 1)),
        grouped=dict(dispatches=disp_g, cold_s=round(cold_g, 3),
                     warm_s=round(warm_g, 4)),
        union_single_dispatch=disp_u == 1,
        grouped_dispatch_per_family=disp_g == n_families,
        union_compile_win=round(cold_g / max(cold_u, 1e-9), 3),
        mesh_curve=curve, bitwise_all_meshes=bool(bitwise_all),
        best_mesh=best["mesh"],
        sharded_throughput_ratio=round(
            best["lanes_per_s"] / max(unsharded_lps, 1e-9), 3))


def _child(args) -> None:
    if args.gate:
        rec, key = run_sharding(T=96, n=256, k=32), "gate"
    else:
        rec, key = run_sharding(T=240, n=512, k=64), "full"
    try:
        with open(args.out) as f:
            out = json.load(f)
    except (OSError, ValueError):
        out = {}
    out[key] = rec
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"lanes={rec['lanes']} devices={rec['devices']} "
          f"union={rec['union']['dispatches']} dispatch(es) "
          f"(grouped {rec['grouped']['dispatches']}) "
          f"bitwise_all={rec['bitwise_all_meshes']}")
    for c in rec["mesh_curve"]:
        print(f"  mesh={c['mesh']}: {c['lanes_per_s']} lanes/s "
              f"(warm {c['warm_s']}s, bitwise="
              f"{c['bitwise_equal_to_unsharded']})")
    print(f"  unsharded: {rec['union']['lanes_per_s']} lanes/s -> "
          f"ratio {rec['sharded_throughput_ratio']} at "
          f"mesh={rec['best_mesh']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_sharding.json")
    ap.add_argument("--gate", action="store_true",
                    help="gate scale (CI); default is the full record")
    args = ap.parse_args()
    if os.environ.get(_CHILD_ENV) == "1":
        _child(args)
        return
    # re-exec with the host platform split into 8 virtual devices; the
    # flag must be set before jax initializes anywhere in the process.
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + _FORCE_FLAG).strip()
    env[_CHILD_ENV] = "1"
    raise SystemExit(subprocess.run(
        [sys.executable, os.path.abspath(__file__)] + sys.argv[1:],
        env=env).returncode)


if __name__ == "__main__":
    main()
