"""Record the tuning/sweep before-vs-after timings into BENCH_tuning.json.

Three tiers of "before" for the ARMS sweep:
  * ``seed``:    the pre-PR-1 ARMS simulator path — per-interval device
    syncs in ``ARMSPolicy.step`` (``int(policy_every(state.mode))`` +
    ``float(sampling_period(...))`` every simulator interval) and the
    per-interval oracle ``argpartition`` in the engine loop.  Replicated
    here as ``SeedSyncARMSPolicy``/``_seed_engine_run`` so the number stays
    reproducible after the optimized code replaced it.
  * ``sequential``: the post-PR-1 numpy loop (host-cached cadence, hoisted
    oracle) replaying the sweep one simulation at a time.
  * ``batched``: the compiled lax.scan + vmap sweep (scan_engine).

For the tuned-baseline study (``tune_hemem`` — the paper's "Tuned-X"
comparators), the "before" is the pre-functional-protocol path: HeMem as an
imperative numpy object (``SeqNumpyHeMemPolicy`` replica below) replaying
the whole budget sequentially through the reference engine.  The "after"
runs the same budget as ONE lane-batched compiled scan
(``tuning.tune_hemem`` -> ``scan_engine.sweep_policy_configs``).

Usage: PYTHONPATH=src:. python benchmarks/bench_sweep.py [--out BENCH_tuning.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.baselines.arms_policy import ARMSPolicy
from repro.baselines.base import Policy
from repro.core import policy_every, sampling_period
from repro.core.state import ARMSConfig
from repro.simulator import scan_engine, tuning, workloads
from repro.simulator.engine import run
from repro.simulator.machine import PMEM_LARGE, interval_time
from repro.simulator.sampling import pebs_sample


class SeqNumpyHeMemPolicy(Policy):
    """Pre-PR HeMem: the imperative numpy implementation, verbatim.

    Replicated so the sequential-tuning baseline stays reproducible after
    the functional-protocol rewrite replaced it (the live ``HeMemPolicy``
    now runs the jittable spec even under the numpy engine).
    """

    name = "hemem"
    migration_limit = 12

    def __init__(self, hot_threshold=8.0, cooling_threshold=18.0,
                 migration_period=5, sample_period=10_000.0):
        self.hot_threshold = float(hot_threshold)
        self.cooling_threshold = float(cooling_threshold)
        self.migration_period = int(migration_period)
        self._sample_period = float(sample_period)

    def reset(self, n_pages, k, machine):
        self.n, self.k = n_pages, k
        self.counts = np.zeros(n_pages)
        self.in_fast = np.zeros(n_pages, bool)
        self.first_hot = np.full(n_pages, np.inf)
        self.t = 0

    def sampling_period(self):
        return self._sample_period

    def step(self, observed, slow_bw_frac, app_bw_frac):
        self.t += 1
        self.counts += observed
        if self.counts.max() >= self.cooling_threshold:
            self.counts *= 0.5
        hot = self.counts >= self.hot_threshold
        newly_hot = hot & np.isinf(self.first_hot)
        self.first_hot[newly_hot] = self.t
        self.first_hot[~hot] = np.inf
        if self.t % self.migration_period:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        want = np.flatnonzero(hot & ~self.in_fast)
        want = want[np.argsort(self.first_hot[want], kind="stable")]
        want = want[: self.migration_limit]
        free = self.k - int(self.in_fast.sum())
        need_victims = max(0, len(want) - free)
        cold_in_fast = np.flatnonzero(self.in_fast & ~hot)
        victims = cold_in_fast[np.argsort(self.counts[cold_in_fast],
                                          kind="stable")][:need_victims]
        want = want[: free + len(victims)]
        self.in_fast[victims] = False
        self.in_fast[want] = True
        return want, victims


class SeedSyncARMSPolicy(ARMSPolicy):
    """Pre-PR ARMSPolicy: device->host sync per simulator interval."""

    def sampling_period(self):
        return float(sampling_period(self.state.mode))

    def step(self, observed, slow_bw_frac, app_bw_frac):
        from repro.core import arms_step
        from repro.core.scheduler import observe_migration_cost
        self.t += 1
        self.buf += observed
        every = int(policy_every(self.state.mode))   # per-interval sync
        if self.t % every:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        self.state, plan = arms_step(
            self.state, self.buf / every, float(slow_bw_frac),
            float(app_bw_frac), cfg=self.cfg, k=self.k)
        self.buf[:] = 0.0
        valid = np.asarray(plan.valid)
        promote = np.asarray(plan.promote)[valid]
        demote = np.asarray(plan.demote)[valid]
        demote = demote[demote >= 0]
        if len(promote):
            self.state = observe_migration_cost(
                self.state, self._promo_us, self._demo_us, self.cfg)
        return promote.astype(np.int64), demote.astype(np.int64)

    @property
    def mode(self):
        return int(self.state.mode)


def _seed_engine_run(policy, trace, machine, k, seed=0):
    """Pre-PR engine loop: per-interval oracle argpartition, f64 cost model."""
    T, n = trace.shape
    rng = np.random.default_rng(seed)
    policy.reset(n, k, machine)
    in_fast = np.zeros(n, bool)
    slow_bw_frac, app_bw_frac = 1.0, 0.0
    exec_time = 0.0
    for t in range(T):
        true = trace[t]
        observed = pebs_sample(true, policy.sampling_period(), rng)
        promote, demote = policy.step(observed, slow_bw_frac, app_bw_frac)
        demote = np.asarray(demote, np.int64)
        promote = np.asarray(promote, np.int64)
        demote = demote[in_fast[demote]]
        in_fast[demote] = False
        promote = promote[~in_fast[promote]]
        room = k - int(in_fast.sum())
        promote = promote[:room]
        in_fast[promote] = True
        acc_fast = float(true[in_fast].sum())
        acc_slow = float(true.sum()) - acc_fast
        out = interval_time(machine, acc_fast, acc_slow,
                            len(promote), len(demote))
        exec_time += out.wall_s
        slow_bw_frac = acc_slow / max(acc_fast + acc_slow, 1e-9)
        # consumer-side clamp of the raw utilization ratio (engine.py does
        # the same before the policy sees it).
        app_bw_frac = min(1.0, out.app_bw_frac)
        np.argpartition(true, -k)  # per-interval oracle top-k (seed code)
    return exec_time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_tuning.json")
    ap.add_argument("--budget", type=int, default=24)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--T", type=int, default=512)
    args = ap.parse_args()

    from benchmarks import paper_tables

    n, T, budget = args.n, args.T, args.budget
    k = n // 8
    trace = workloads.make("gups", T=T, n=n)
    cfgs = tuning.sample_arms_configs(budget)

    print(f"[bench_sweep] ARMS config sweep, gups n={n} T={T} k={k} "
          f"budget={budget}", flush=True)
    # warm jit caches so the seed-replica loop isn't charged jax warmup
    _seed_engine_run(SeedSyncARMSPolicy(), trace[:32], PMEM_LARGE, k)
    t0 = time.time()
    for cfg in cfgs:
        _seed_engine_run(SeedSyncARMSPolicy(ARMSConfig(**cfg)), trace,
                         PMEM_LARGE, k)
    seed_style_s = round(time.time() - t0, 3)
    print(f"[bench_sweep] pre-PR (per-interval syncs) sequential: "
          f"{seed_style_s}s", flush=True)

    rec = paper_tables.bench_arms_sweep(budget=budget, n=n, T=T)
    rec["config_sweep_seed_style_sequential_s"] = seed_style_s
    rec["config_sweep_speedup_vs_seed"] = round(
        seed_style_s / rec["config_sweep_batched_warm_s"], 2)
    rec["config_sweep_speedup_vs_seed_jnp"] = round(
        seed_style_s / rec["config_sweep_batched_warm_jnp_s"], 2)

    # --- tuned-baseline sweeps (the paper's tuning study) ---
    # Before: the pre-PR sequential loop — imperative numpy HeMem, one
    # full reference-engine replay per config.  After: the same budget as
    # ONE lane-batched compiled scan (functional-protocol sweep).
    hm_trace = workloads.make("gups", T=300, n=2048)
    hm_k = 256
    hm_cfgs = tuning.sample_configs(budget, seed=0)
    run(SeqNumpyHeMemPolicy(), hm_trace[:32], PMEM_LARGE, hm_k)  # warm
    t0 = time.time()
    for cfg in hm_cfgs:
        run(SeqNumpyHeMemPolicy(**cfg), hm_trace, PMEM_LARGE, hm_k, seed=0)
    rec["tune_hemem_sequential_pre_pr_s"] = round(time.time() - t0, 3)
    print(f"[bench_sweep] tune_hemem pre-PR sequential numpy "
          f"({len(hm_cfgs)} configs): "
          f"{rec['tune_hemem_sequential_pre_pr_s']}s", flush=True)

    t0 = time.time()
    tuning.tune_hemem(hm_trace, PMEM_LARGE, hm_k, budget=budget)
    rec["tune_hemem_batched_cold_s"] = round(time.time() - t0, 3)
    t0 = time.time()
    tuning.tune_hemem(hm_trace, PMEM_LARGE, hm_k, budget=budget)
    rec["tune_hemem_batched_warm_s"] = round(time.time() - t0, 3)
    rec["tune_hemem_lanes"] = scan_engine.last_dispatch["lanes"]
    rec["tune_hemem_speedup_vs_pre_pr"] = round(
        rec["tune_hemem_sequential_pre_pr_s"]
        / rec["tune_hemem_batched_warm_s"], 2)
    print(f"[bench_sweep] tune_hemem batched: "
          f"cold {rec['tune_hemem_batched_cold_s']}s, "
          f"warm {rec['tune_hemem_batched_warm_s']}s "
          f"({rec['tune_hemem_speedup_vs_pre_pr']}x vs pre-PR)", flush=True)

    for fam, tune_fn in (("memtis", tuning.tune_memtis),
                         ("tpp", tuning.tune_tpp)):
        tune_fn(hm_trace, PMEM_LARGE, hm_k, budget=budget)   # compile
        t0 = time.time()
        tune_fn(hm_trace, PMEM_LARGE, hm_k, budget=budget)
        rec[f"tune_{fam}_batched_warm_s"] = round(time.time() - t0, 3)

    out = dict(
        description="Tuning/sweep bench before vs after the compiled "
                    "lax.scan+vmap simulation engine (PR 1) and the "
                    "lane-batched functional-policy sweeps (PR 2)",
        machine="pmem-large model; CI container CPU (2 cores)",
        notes=[
            "'seed_style' replays the pre-PR-1 code path: per-interval "
            "device syncs in ARMSPolicy.step and per-interval oracle "
            "argpartition in the engine loop.",
            "'sequential' is the numpy reference loop, one simulation "
            "per config.",
            "'batched' runs the whole sweep as one compiled lax.scan "
            "batched over configs; 'warm' excludes the one-off compile.",
            "'jnp' uses ARMSConfig(use_score_kernel=False): the fused "
            "Pallas score kernel runs in interpret mode off-TPU, which "
            "costs extra inside batched sweeps.",
            "'tune_hemem_sequential_pre_pr' replays the pre-functional-"
            "protocol tuning study: imperative numpy HeMem through the "
            "reference engine, one config at a time (gups, T=300, "
            "n=2048, k=256); 'tune_hemem_batched' is the same budget as "
            "one lane-batched compiled dispatch.",
        ],
        **rec,
    )
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
