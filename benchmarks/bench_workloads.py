"""Record the trace-synthesis scale win into BENCH_workloads.json.

Before (materialized path): every workload lane of a sweep needs its dense
``[T, n]`` f32 trace host-materialized, plus the host oracle masks and a
``[T, n]`` CRN uniform field — O(T*n) bytes each, which capped scenario
scale by host memory (n=65536, T=4096 is 1 GiB of trace per workload
before sampling fields).  After (synth path): the same W-workload x
B-config study runs as ONE compiled dispatch straight from the
``WorkloadSpec`` pytrees — true counts and the oracle are synthesized on
device per interval, per-lane storage is O(n), and nothing ``[T, n]``
exists on host or device.

Usage:
  PYTHONPATH=src:. python benchmarks/bench_workloads.py \
      [--n 65536] [--T 4096] [--budget 2] [--workloads gups,silo-tpcc] \
      [--quick] [--out BENCH_workloads.json]
"""
from __future__ import annotations

import argparse
import json
import resource
import time

from benchmarks import common
from repro.baselines.hemem import HeMemSpec
from repro.simulator import scan_engine, workload_spec, workloads
from repro.simulator.engine import oracle_topk_masks
from repro.simulator.machine import PMEM_LARGE
from repro.simulator.sampling import uniform_field


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_workloads.json")
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--T", type=int, default=4096)
    ap.add_argument("--budget", type=int, default=2)
    ap.add_argument("--workloads", default="gups,silo-tpcc")
    ap.add_argument("--quick", action="store_true",
                    help="tiny scale smoke run (n=2048, T=256)")
    args = ap.parse_args()

    n, T = (2048, 256) if args.quick else (args.n, args.T)
    k = n // 8
    wl_names = args.workloads.split(",")
    W, B = len(wl_names), args.budget
    cfgs = [dict(hot_threshold=float(h)) for h in (4, 8, 16, 32)][:B]
    specs = [workloads.spec(nm, T=T) for nm in wl_names]

    rec = dict(n_pages=n, T=T, k=k, workloads=wl_names, budget=B,
               lanes=W * B)
    rec["trace_bytes_per_workload"] = T * n * 4
    rec["synth_state_bytes_per_workload"] = 2 * n * 4  # rank + rank2 (i32)

    # --- after: device synthesis, one W*B-lane dispatch, nothing [T, n] ---
    print(f"[bench_workloads] synth sweep: {W} workloads x {B} configs, "
          f"n={n} T={T} k={k}", flush=True)
    mat_before = workload_spec.MATERIALIZE_CALLS
    _, cold_s = common.timed(
        scan_engine.sweep_workload_configs, HeMemSpec.make, cfgs, specs,
        PMEM_LARGE, k, T, n, names=wl_names)
    rec["synth_sweep_cold_s"] = round(cold_s, 3)
    _, warm_s = common.timed(
        scan_engine.sweep_workload_configs, HeMemSpec.make, cfgs, specs,
        PMEM_LARGE, k, T, n, names=wl_names)
    rec["synth_sweep_warm_s"] = round(warm_s, 3)
    rec["synth_lanes"] = scan_engine.last_dispatch["lanes"]
    rec["synth_materialize_calls"] = \
        workload_spec.MATERIALIZE_CALLS - mat_before
    rec["synth_peak_rss_mb"] = round(_rss_mb(), 1)
    print(f"[bench_workloads] synth: cold {rec['synth_sweep_cold_s']}s, "
          f"warm {rec['synth_sweep_warm_s']}s, "
          f"rss {rec['synth_peak_rss_mb']}MB", flush=True)

    # --- before: host-materialized traces + oracle + CRN field, one
    # trace-mode sweep per workload -----------------------------------
    mat_s = orc_s = sweep_s = 0.0
    for nm, sp in zip(wl_names, specs):
        t0 = time.time()
        trace = sp.materialize(T, n)
        mat_s += time.time() - t0
        t0 = time.time()
        oracle_topk_masks(trace, k)     # what trace-mode simulate() pays
        orc_s += time.time() - t0
        t0 = time.time()
        scan_engine.sweep_policy_configs(
            HeMemSpec.make, trace, PMEM_LARGE, k, cfgs,
            sample_u=uniform_field(T, n, seed=0))
        sweep_s += time.time() - t0
        print(f"[bench_workloads] materialized {nm}: done "
              f"(cum mat {mat_s:.1f}s orc {orc_s:.1f}s sweep {sweep_s:.1f}s)",
              flush=True)
    rec["materialized_trace_build_s"] = round(mat_s, 3)
    rec["materialized_oracle_s"] = round(orc_s, 3)
    rec["materialized_sweep_s"] = round(sweep_s, 3)
    rec["materialized_total_s"] = round(mat_s + orc_s + sweep_s, 3)
    rec["materialized_host_bytes"] = W * (2 * T * n * 4 + T * n)  # +u field
    rec["materialized_peak_rss_mb"] = round(_rss_mb(), 1)
    rec["scale_win_wall"] = round(
        rec["materialized_total_s"] / max(rec["synth_sweep_warm_s"], 1e-9), 2)
    rec["scale_win_bytes_per_workload"] = round(
        rec["trace_bytes_per_workload"]
        / rec["synth_state_bytes_per_workload"], 1)

    out = dict(
        description="Workload-lane sweep: device trace synthesis "
                    "(WorkloadSpec protocol) vs host-materialized [T, n] "
                    "traces, same W x B tuned-HeMem study",
        machine="pmem-large model; CI container CPU (2 cores)",
        notes=[
            "'synth' runs the whole W x B study as ONE compiled dispatch "
            "synthesizing true counts and the oracle on device; "
            "materialize_calls==0 proves no [T, n] array was built.",
            "'materialized' is the pre-protocol path: per workload, build "
            "the dense f32 trace, host oracle masks, a [T, n] CRN field, "
            "and one trace-mode sweep dispatch.",
            "bytes per workload: O(T*n) trace vs O(n) synth state "
            "(rank permutations).",
        ],
        **rec,
    )
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
