"""Adaptive search engine: compute-vs-quality curves per strategy + the
machine-transfer robustness matrix, into BENCH_search.json.

For each family x workload, runs the three strategies of
``simulator/search.py`` under the same budget / seeds and records the
comparison the subsystem was built to make: best-found ``exec_time_s``
against TOTAL LANE-INTERVALS SPENT (sum over rounds of dispatch lanes x
horizon — the strategy-agnostic compute unit).  The headline numbers are
``asha.gap`` (best-found vs the exhaustive grid's best, same seeded
population) and ``asha.li_frac`` (lane-intervals vs the grid's
``budget * T``): the ISSUE-7 acceptance band is gap <= 3% at <= 40%.

The transfer section reruns the companion tuning paper's robustness
experiment ("tuned on machine A, deployed on B"): one machine-lane search
per family, then one cross-evaluation sweep, reported as the A->B
slowdown-vs-native matrix.

Usage:
  PYTHONPATH=src:. python benchmarks/bench_search.py \
      [--T 300] [--n 2048] [--budget 16] [--quick] [--out BENCH_search.json]
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks import common
from repro.simulator import search, workloads

FAMILIES = ["hemem", "memtis", "tpp", "arms"]
WL_SET = ["gups", "silo-tpcc", "xsbench"]
MACH_SET = ["pmem-large", "numa", "cxl-1hop", "dram-cxl-pmem"]


def strategy_record(family: str, trace, k: int, budget: int,
                    search_seed: int = 0, sim_seed: int = 0) -> dict:
    """Run grid/asha/ce for one family on one trace -> comparison record.

    All three strategies share ``search_seed`` (grid and ASHA score the
    SAME seeded population; CE redraws from it) and ``sim_seed`` (every
    dispatch's lanes share the CRN noise field), so best-found deltas are
    attributable to the search loop alone.
    """
    rec = {}
    for strategy in ("grid", "asha", "ce"):
        t0 = time.time()
        sr = search.run(family, strategy, trace=trace, k=k, budget=budget,
                        search_seed=search_seed, sim_seed=sim_seed)
        wall = time.time() - t0
        rec[strategy] = dict(
            best_exec_time_s=round(float(sr.best_result.exec_time_s), 6),
            best_config={nm: round(float(v), 6)
                         for nm, v in sr.best_config.items()},
            rounds=len(sr.rounds),
            dispatches=sr.dispatches,
            lane_intervals=sr.lane_intervals,
            wall_s=round(wall, 3),
            curve=[[int(li), round(float(t), 6)] for li, t in sr.curve()],
        )
        if strategy == "asha":
            # rungs where the ranking was fully tied (zero information:
            # ASHA refuses to eliminate and carries the population — the
            # lane-interval fraction is only meaningful when this is 0).
            rec[strategy]["zero_info_rungs"] = sum(
                1 for r in sr.rounds[:-1]
                if r.survivors == r.population)
    grid = rec["grid"]
    for strategy in ("asha", "ce"):
        s = rec[strategy]
        s["gap_vs_grid"] = round(
            s["best_exec_time_s"] / grid["best_exec_time_s"] - 1.0, 4)
        s["li_frac_of_grid"] = round(
            s["lane_intervals"] / grid["lane_intervals"], 4)
    return rec


def transfer_record(family: str, trace, k: int, budget: int,
                    machines=MACH_SET, strategy: str = "asha") -> dict:
    """Machine-transfer matrix for one family: tune per machine (one
    machine-lane search), cross-evaluate in one final sweep."""
    t0 = time.time()
    tm = search.transfer_matrix(family, trace, list(machines), k,
                                budget=budget, strategy=strategy)
    wall = time.time() - t0
    worst = max(float(tm.slowdown[a, b])
                for a in range(len(tm.machines))
                for b in range(len(tm.machines)) if a != b)
    return dict(strategy=strategy, machines=tm.machines,
                wall_s=round(wall, 3),
                worst_foreign_slowdown=round(worst, 4),
                rows=tm.rows())


def collect(T: int, n: int, k: int, budget: int) -> dict:
    rec: dict = dict(T=T, n_pages=n, k=k, budget=budget,
                     strategies=dict(), transfer=dict())
    for wl in WL_SET:
        trace = workloads.make(wl, T=T, n=n)
        for family in FAMILIES:
            r = strategy_record(family, trace, k, budget)
            rec["strategies"][f"{family}.{wl}"] = r
            print(f"[bench_search] {family}.{wl}: grid "
                  f"{r['grid']['best_exec_time_s']}s | asha gap "
                  f"{r['asha']['gap_vs_grid']:+.2%} at "
                  f"{r['asha']['li_frac_of_grid']:.1%} lane-intervals | "
                  f"ce gap {r['ce']['gap_vs_grid']:+.2%} at "
                  f"{r['ce']['li_frac_of_grid']:.1%}", flush=True)
    trace = workloads.make("silo-tpcc", T=T, n=n)
    for family in ("hemem", "arms"):
        rec["transfer"][family] = transfer_record(family, trace, k, budget)
        print(f"[bench_search] transfer.{family}: worst foreign slowdown "
              f"{rec['transfer'][family]['worst_foreign_slowdown']}x "
              f"over {len(MACH_SET)} machines", flush=True)
    pairs = [f"{f}.{w}" for f in ("hemem", "memtis", "tpp")
             for w in WL_SET]
    gaps = {p: rec["strategies"][p]["asha"]["gap_vs_grid"] for p in pairs}
    fracs = {p: rec["strategies"][p]["asha"]["li_frac_of_grid"]
             for p in pairs}
    # pairs with a zero-information rung degrade toward exhaustive
    # scoring BY DESIGN (tie-aware ASHA refuses to eliminate on bitwise
    # ties) — they still find the grid best, but the <= 40% lane-interval
    # claim only applies where the rungs carry signal.
    degenerate = [p for p in pairs
                  if rec["strategies"][p]["asha"]["zero_info_rungs"] > 0]
    informative = [p for p in pairs if p not in degenerate]
    rec["asha_summary"] = dict(
        max_gap_vs_grid=round(max(gaps.values()), 4),
        informative_pairs=len(informative),
        max_li_frac_informative=round(
            max(fracs[p] for p in informative), 4),
        degenerate_pairs={p: dict(gap=gaps[p], li_frac=fracs[p])
                          for p in degenerate},
        acceptance="gap <= 0.03 everywhere; li_frac <= 0.40 on "
                   "signal-carrying pairs (ISSUE 7)",
        ok=max(gaps.values()) <= 0.03
        and max(fracs[p] for p in informative) <= 0.40)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_search.json")
    ap.add_argument("--T", type=int, default=common.T)
    ap.add_argument("--n", type=int, default=common.N_PAGES)
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--quick", action="store_true",
                    help="tiny scale smoke run (T=120, n=512)")
    args = ap.parse_args()
    T, n = (120, 512) if args.quick else (args.T, args.n)

    rec = collect(T, n, n // 8, args.budget)
    out = dict(
        description="Adaptive search (ASHA / cross-entropy) vs exhaustive "
                    "grid on the same seeded population + CRN field; "
                    "curves are [cumulative lane-intervals, best "
                    "exec_time_s at that round's horizon]",
        machine="CI container CPU (2 cores)",
        notes=[
            "ASHA rounds run at horizons T*eta**(r-R) (min t_min); "
            "non-final curve points are short-horizon scores.",
            "transfer.slowdown[a][b] = exec(tuned-on-a, deployed-on-b) / "
            "exec(tuned-on-b, on-b); diagonal 1.0 by construction.",
        ],
        **rec,
    )
    # keep the CI gate's record (paper_tables.bench_search_gate merges
    # itself under "gate") across manual full-scale reruns.
    try:
        with open(args.out) as f:
            prev = json.load(f)
        if "gate" in prev:
            out["gate"] = prev["gate"]
    except (OSError, ValueError):
        pass
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(json.dumps(out["asha_summary"], indent=1))


if __name__ == "__main__":
    main()
