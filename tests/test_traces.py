"""Trace capture / fit / replay (PR 9 tentpole layer 2, satellite d).

* Capture round-trip conserves total access counts EXACTLY (f64 +
  reduceat grouping — bitwise, not allclose).
* ``fit_workload_spec`` is a pure function of (trace, seed): two calls
  produce identical pytree leaves (the CRN pairing discipline).
* The fit recovers planted hot-set / duty-cycle structure.
* A captured trace runs as an ``experiment.sweep`` lane (trace-replay
  mode) — the serving-traffic-as-workload acceptance path.
"""
import jax
import numpy as np
import pytest

from repro.simulator import traces
from repro.simulator.workload_spec import NEVER

def _integer_steps(S=40, n=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 50, (S, n)).astype(np.float64)


class TestCaptureConservation:
    def test_round_trip_conserves_counts_exactly(self):
        steps = _integer_steps()
        tw = traces.capture_from_steps(steps, group=4)
        assert tw.counts.shape == (10, 8)
        # EXACT f64 equality, not allclose: integer-valued counts summed
        # by reduceat must reproduce the per-cell and total sums bitwise.
        assert tw.total() == float(steps.sum())
        want = steps.reshape(10, 4, 8).sum(axis=1)
        np.testing.assert_array_equal(tw.counts, want)

    def test_streaming_capture_matches_one_shot(self):
        steps = _integer_steps(S=24, n=5, seed=3)
        cap = traces.TraceCapture(n=5, group=3)
        for row in steps:
            cap.add(row)
        assert cap.steps == 24
        tw = cap.finish(label="stream")
        np.testing.assert_array_equal(
            tw.counts, traces.capture_from_steps(steps, group=3).counts)
        assert tw.meta["steps"] == 24 and tw.meta["group"] == 3

    def test_partial_interval_kept_and_conserved(self):
        steps = _integer_steps(S=10, n=4, seed=1)
        tw = traces.capture_from_steps(steps, group=4)   # 4+4+2
        assert tw.T == 3
        assert tw.total() == float(steps.sum())
        np.testing.assert_array_equal(tw.counts[2], steps[8:].sum(0))

    def test_drop_partial(self):
        steps = _integer_steps(S=10, n=4, seed=2)
        cap = traces.TraceCapture(n=4, group=4)
        for row in steps:
            cap.add(row)
        tw = cap.finish(drop_partial=True)
        assert tw.T == 2
        assert tw.total() == float(steps[:8].sum())

    def test_save_load_round_trip(self, tmp_path):
        tw = traces.capture_from_steps(_integer_steps(), group=2,
                                       label="kv-l0")
        path = str(tmp_path / "trace.npz")
        tw.save(path)
        back = traces.TraceWorkload.load(path)
        np.testing.assert_array_equal(back.counts, tw.counts)
        assert back.label == "kv-l0"

    def test_shape_validation(self):
        with pytest.raises(ValueError):
            traces.TraceWorkload(np.zeros(5))
        cap = traces.TraceCapture(n=4)
        with pytest.raises(ValueError):
            cap.add(np.zeros(3))
        with pytest.raises(ValueError):
            cap.finish()


class TestFitDeterminism:
    def test_fit_is_bit_deterministic_under_fixed_seed(self):
        tw = traces.capture_from_steps(_integer_steps(S=64, n=16, seed=9),
                                       group=2)
        a = traces.fit_workload_spec(tw, seed=3)
        b = traces.fit_workload_spec(tw, seed=3)
        la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
        assert len(la) == len(lb)
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert jax.tree_util.tree_structure(a) \
            == jax.tree_util.tree_structure(b)

    def test_fit_label_and_scale_independence(self):
        """The fitted knobs are fractional in n — fitting an 8-page trace
        yields a spec whose hot_frac applies at any n."""
        tw = traces.capture_from_steps(_integer_steps(), group=4,
                                       label="kv")
        spec = traces.fit_workload_spec(tw)
        from repro.simulator.workload_spec import label_of
        assert label_of(spec, "") == "fit:kv"


class TestFitRecoversStructure:
    def test_static_hotset(self):
        """4 of 32 pages carry ~95% of a steady stream -> hot_frac 1/8,
        high hot_weight, no churn (shift_every == NEVER), full duty."""
        T, n = 64, 32
        rng = np.random.default_rng(0)
        counts = rng.uniform(0.5, 1.5, (T, n))
        counts[:, :4] *= 150.0
        spec = traces.fit_workload_spec(traces.TraceWorkload(counts))
        from repro.simulator.workload_spec import _to_comps
        (c,) = _to_comps(spec)
        assert abs(c["hot_frac"] - 4 / 32) < 0.05
        assert c["hot_weight"] > 0.9
        assert c["shift_every"] == NEVER
        assert c["duty"] == 1.0

    def test_duty_cycle(self):
        """Bursts of 4 busy intervals every 8 -> period ~8, duty ~0.5."""
        T, n = 64, 16
        rng = np.random.default_rng(1)
        counts = rng.uniform(50, 60, (T, n))
        busy = (np.arange(T) % 8) < 4
        counts[~busy] *= 0.001
        spec = traces.fit_workload_spec(traces.TraceWorkload(counts))
        from repro.simulator.workload_spec import _to_comps
        (c,) = _to_comps(spec)
        assert abs(c["period"] - 8) <= 1
        assert abs(c["duty"] - 0.5) < 0.15
        assert c["idle_scale"] < 0.05

    def test_churning_hotset_fits_finite_shift(self):
        """A hot set that relocates every ~16 intervals fits a finite
        shift_every (static traces fit NEVER — contrast above)."""
        T, n = 96, 32
        rng = np.random.default_rng(2)
        counts = rng.uniform(0.5, 1.5, (T, n))
        for t in range(T):
            start = (4 * (t // 16)) % n
            counts[t, start:start + 4] *= 100.0
        spec = traces.fit_workload_spec(traces.TraceWorkload(counts))
        from repro.simulator.workload_spec import _to_comps
        (c,) = _to_comps(spec)
        assert c["shift_every"] < NEVER


class TestReplay:
    def test_trace_replays_as_sweep_lane(self):
        """The captured stream is a first-class experiment lane: the
        workload axis collapses to ["trace"] and every policy family
        produces a finite SimResult."""
        steps = _integer_steps(S=48, n=16, seed=11)
        steps[:, :4] *= 40.0                       # plant a hot set
        tw = traces.capture_from_steps(steps, group=2, label="serve")
        res = traces.replay(tw, ["arms", "all-slow", "oracle"], k=4)
        assert res.axes["workload"] == ["trace"]
        arms = res.at(policy="arms", workload="trace")
        allslow = res.at(policy="all-slow", workload="trace")
        oracle = res.at(policy="oracle", workload="trace")
        assert np.isfinite(arms.exec_time_s) and arms.exec_time_s > 0
        assert allslow.promotions == 0 and allslow.fast_hit_frac == 0.0
        assert arms.promotions > 0 and arms.fast_hit_frac > 0.0
        # the planted hot set is catchable: the oracle beats all-slow
        assert oracle.exec_time_s < allslow.exec_time_s
