"""Tier-native protocol: shim equivalence, targeted executor, new families.

The PR-8 contract extends the binary promote/demote protocol to
tier-targeted migrations.  Three guarantees anchor it:

  * SHIM EQUIVALENCE — every binary policy routed through the protocol's
    ``tier_policy`` shim and the tier-targeted executor is BITWISE equal
    (counts, exec time, timelines) to the historical hop-chain path under
    CRN, on 2- and 3-tier machines alike;
  * EXECUTOR EQUIVALENCE — the compiled targeted executor
    (``simjax.apply_targeted_migrations``) matches the sequential numpy
    reference (``engine.apply_targeted_migrations_np``) on random plans;
  * FAMILY EQUIVALENCE — the tier-native families (HybridTier / Jenga /
    TierBPF) produce exactly the same migration counts under both engines
    with shared CRN noise.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines.arms_policy import ARMSSpec
from repro.baselines.hemem import HeMemSpec
from repro.baselines.hybridtier import HybridTierPolicy, HybridTierSpec
from repro.baselines.jenga import JengaPolicy, JengaSpec
from repro.baselines.memtis import MemtisSpec
from repro.baselines.protocol import (pair_limit, rank_desc, rank_partition,
                                      tier_plan)
from repro.baselines.static import AllSlowSpec, OracleSpec
from repro.baselines.tierbpf import TierBPFPolicy, TierBPFSpec
from repro.baselines.tpp import TPPSpec
from repro.core import scheduler
from repro.simulator import (experiment, machine_spec, machines, scan_engine,
                             simjax, workloads)
from repro.simulator.engine import apply_targeted_migrations_np, run
from repro.simulator.sampling import uniform_field

T, N, K = 96, 256, 32

BINARY_FAMILIES = [
    ("arms", lambda: ARMSSpec.make()),
    ("hemem", lambda: HeMemSpec.make()),
    ("memtis", lambda: MemtisSpec.make()),
    ("tpp", lambda: TPPSpec.make()),
    ("all-slow", AllSlowSpec),
    ("oracle", OracleSpec),
]
TIER_FAMILIES = [
    (HybridTierPolicy, lambda: HybridTierSpec.make()),
    (JengaPolicy, lambda: JengaSpec.make()),
    (TierBPFPolicy, lambda: TierBPFSpec.make()),
]
MACHS = ["pmem-large", "dram-cxl-pmem"]


def _same_counts(a, b):
    assert a.promotions == b.promotions
    assert a.demotions == b.demotions
    assert a.wasteful == b.wasteful


class TestShimEquivalence:
    """Binary specs through the tier-targeted executor == hop-chain path."""

    @pytest.mark.parametrize("mach", MACHS)
    @pytest.mark.parametrize("fam,mk", BINARY_FAMILIES)
    def test_bitwise_equal_under_crn(self, fam, mk, mach):
        trace = workloads.make("gups", T=T, n=N)
        u = uniform_field(T, N, seed=123)
        base = scan_engine.simulate(mk(), trace, mach, K, sample_u=u)
        shim = scan_engine.simulate(mk(), trace, mach, K, sample_u=u,
                                    tier_shim=True)
        _same_counts(base, shim)
        assert base.exec_time_s == shim.exec_time_s          # bitwise
        assert base.hot_recall == shim.hot_recall
        np.testing.assert_array_equal(base.timeline_promotions,
                                      shim.timeline_promotions)
        np.testing.assert_array_equal(base.timeline_slow_bw,
                                      shim.timeline_slow_bw)

    def test_bitwise_equal_on_unfused_path(self):
        # the shim routes around the fused interval kernel as well.
        trace = workloads.make("silo-tpcc", T=T, n=N)
        u = uniform_field(T, N, seed=7)
        base = scan_engine.simulate(HeMemSpec.make(), trace,
                                    "dram-cxl-pmem", K, sample_u=u,
                                    use_interval_kernel=False)
        shim = scan_engine.simulate(HeMemSpec.make(), trace,
                                    "dram-cxl-pmem", K, sample_u=u,
                                    use_interval_kernel=False,
                                    tier_shim=True)
        _same_counts(base, shim)
        assert base.exec_time_s == shim.exec_time_s


class TestTargetedExecutor:
    """Compiled targeted executor vs the sequential numpy reference."""

    @pytest.mark.parametrize("seed", range(4))
    @pytest.mark.parametrize("R", [2, 3, 4])
    def test_matches_numpy_on_random_plans(self, seed, R):
        rng = np.random.default_rng(seed)
        n = 64
        caps_l = [8] + [int(rng.integers(4, 16)) for _ in range(R - 2)] + [n]
        caps = jnp.asarray(caps_l, jnp.int32)
        tier = rng.integers(0, R, size=n).astype(np.int64)
        # keep starting occupancy feasible for the non-bottom tiers
        for r in range(R - 1):
            over = np.flatnonzero(tier == r)[caps_l[r]:]
            tier[over] = R - 1
        m = 24
        pages = rng.choice(n, size=m, replace=False).astype(np.int64)
        dst = rng.integers(-2, R, size=m).astype(np.int64)

        tier_np = tier.copy()
        up_np, down_np, mu_np, md_np = apply_targeted_migrations_np(
            tier_np, pages, dst, caps_l)

        pad = np.concatenate([pages, -np.ones(5, np.int64)])
        dpad = np.concatenate([dst, np.zeros(5, np.int64)])
        tier_j, up_exec, down_exec, mu_j, md_j = (
            simjax.apply_targeted_migrations(
                jnp.asarray(tier, jnp.int32), jnp.asarray(pad, jnp.int32),
                jnp.asarray(dpad, jnp.int32), caps))
        np.testing.assert_array_equal(np.asarray(tier_j), tier_np)
        np.testing.assert_array_equal(np.asarray(mu_j), mu_np)
        np.testing.assert_array_equal(np.asarray(md_j), md_np)
        assert int(up_exec.sum()) == len(up_np)
        assert int(down_exec.sum()) == len(down_np)

    def test_sentinel_entries_are_inert(self):
        caps = jnp.asarray([2, 8], jnp.int32)
        tier = jnp.asarray([1, 1, 1, 0, 0, 1, 1, 1], jnp.int32)
        pages = jnp.asarray([-1, -1, -1], jnp.int32)
        dst = jnp.zeros(3, jnp.int32)
        t2, up, down, mu, md = simjax.apply_targeted_migrations(
            tier, pages, dst, caps)
        np.testing.assert_array_equal(np.asarray(t2), np.asarray(tier))
        assert int(up.sum()) == int(down.sum()) == 0
        assert int(mu.sum()) == int(md.sum()) == 0


class TestTierPlan:
    """Feasibility of the shared planner every tier-native family uses."""

    @pytest.mark.parametrize("seed", range(3))
    def test_plan_respects_caps_and_budgets(self, seed):
        rng = np.random.default_rng(seed)
        n, R = 96, 3
        caps = jnp.asarray([12, 20, n], jnp.int32)
        cur = np.full(n, R - 1, np.int64)
        cur[rng.choice(n, 10, replace=False)] = 0
        cur[rng.choice(np.flatnonzero(cur == 2), 15, replace=False)] = 1
        score = jnp.asarray(rng.random(n), jnp.float32)
        target = rank_partition(rank_desc(score), caps)
        budgets = jnp.asarray([rng.integers(1, 6) for _ in range(R - 1)],
                              jnp.int32)
        pages, dst, new_cur = tier_plan(
            score, jnp.asarray(cur, jnp.int32), target, caps, budgets,
            32, 32)
        new_cur = np.asarray(new_cur)
        occ = np.bincount(new_cur, minlength=R)
        assert (occ[:-1] <= np.asarray(caps)[:-1]).all()
        moved = np.flatnonzero(new_cur != cur)
        # up-moves spend the budget the down-moves left over, so TOTAL
        # crossings per pair stay within the pair's budget.
        for j in range(R - 1):
            crossing = sum(1 for p in moved
                           if min(cur[p], new_cur[p]) <= j
                           < max(cur[p], new_cur[p]))
            assert crossing <= int(budgets[j])

    def test_pair_limit_counts_crossings(self):
        lo = jnp.asarray([0, 0, 1, 0], jnp.int32)
        hi = jnp.asarray([2, 1, 2, 2], jnp.int32)
        valid = jnp.asarray([True, True, True, True])
        ok = pair_limit(lo, hi, valid, jnp.asarray([2, 1], jnp.int32))
        # pair 1 (tier1<->tier2) is crossed by entries 0, 2, 3 in order;
        # budget 1 keeps only entry 0.
        np.testing.assert_array_equal(np.asarray(ok),
                                      [True, True, False, False])


class TestTierNativeFamilies:
    """HybridTier / Jenga / TierBPF: scan engine == numpy engine (CRN)."""

    @pytest.mark.parametrize("mach", MACHS)
    @pytest.mark.parametrize("pol,mk", TIER_FAMILIES)
    def test_cross_engine_equivalence(self, pol, mk, mach):
        trace = workloads.make("gups", T=T, n=N)
        u = uniform_field(T, N, seed=123)
        m = machines.get(mach)
        ref = run(pol(), trace, m, K, sample_u=u)
        out = scan_engine.simulate(mk(), trace, mach, K, sample_u=u)
        _same_counts(ref, out)
        # exec time and recall accumulate in f32 on device, f64 on host.
        np.testing.assert_allclose(out.exec_time_s, ref.exec_time_s,
                                   rtol=1e-5)
        np.testing.assert_allclose(out.hot_recall, ref.hot_recall,
                                   rtol=1e-5)
        np.testing.assert_array_equal(out.timeline_promotions,
                                      ref.timeline_promotions)

    def test_families_migrate_on_hot_workloads(self):
        # regression: the defaults must actually fire at the named
        # workloads' observed-count magnitudes (~30-60 samples/interval
        # for hot pages), not sit inert below their thresholds.
        trace = workloads.make("gups", T=T, n=N)
        for _, mk in TIER_FAMILIES:
            out = scan_engine.simulate(mk(), trace, "pmem-large", K)
            assert out.promotions > 0


class TestPairBudgetsEdges:
    """scheduler.pair_budgets at the contract's edges (satellite c)."""

    def test_saturated_util_keeps_floor(self):
        # raw utilization can exceed 1 (overcommitted interval); the
        # budget must clamp to the floor of 1, never 0 or negative.
        u = jnp.asarray([3.2, 1.0, 0.5], jnp.float32)
        b = scheduler.pair_budgets(u, 64)
        np.testing.assert_array_equal(np.asarray(b), [1, 1])

    def test_bs_max_one(self):
        u = jnp.asarray([0.0, 0.0], jnp.float32)
        b = scheduler.pair_budgets(u, 1)
        np.testing.assert_array_equal(np.asarray(b), [1])

    def test_zero_bandwidth_padded_tier_gets_full_budget(self):
        # a 2-tier preset padded to 3 tiers for a mixed-depth sweep: the
        # neutral pad tier carries no traffic, so its utilization is 0
        # and the adjacent pair budget stays wide open (bounded by the
        # busier side of each pair).
        base = machines.get("pmem-large")
        spec, _caps = machine_spec.pad_tiers(
            base, machine_spec.resolved_caps(base, N, K), 3)
        util = machine_spec.tier_utilization_host(
            spec, np.array([5e6, 0.0, 3e7]),
            np.array([10.0, 0.0]), np.array([8.0, 0.0]))
        assert util[1] == 0.0
        b = np.asarray(scheduler.pair_budgets(
            jnp.asarray(util, jnp.float32), 32))
        assert b.shape == (2,)
        assert (1 <= b).all() and (b <= 32).all()


class TestSweepIntegration:
    """Tier-native and binary families mix in one sweep: ONE union
    dispatch by default (one per family on the forced grouped path),
    machine labels carried through for spec objects."""

    def test_mixed_family_dispatch_counts(self):
        trace = workloads.make("gups", T=T, n=N)
        u = uniform_field(T, N, seed=123)
        with scan_engine.count_dispatches() as ctr:
            res = experiment.sweep(["hemem", "jenga"], trace=trace,
                                   machines=["pmem-large", "dram-cxl-pmem"],
                                   k=K, sample_u=u)
        # default dispatch="auto": the union fabric fuses both families.
        assert ctr.count == 1
        assert ctr.last["dispatch"] == "union"
        assert ctr.last["families"] == 2
        with scan_engine.count_dispatches() as ctr:
            grp = experiment.sweep(["hemem", "jenga"], trace=trace,
                                   machines=["pmem-large", "dram-cxl-pmem"],
                                   k=K, sample_u=u, dispatch="grouped")
        assert ctr.count == 2
        assert res.axes["policy"] == ["hemem", "jenga"]
        solo = scan_engine.simulate(JengaSpec.make(), trace,
                                    "dram-cxl-pmem", K, sample_u=u)
        cell = res.at(policy="jenga", machine="dram-cxl-pmem")
        assert cell.promotions == solo.promotions
        assert cell.exec_time_s == solo.exec_time_s
        gcell = grp.at(policy="jenga", machine="dram-cxl-pmem")
        assert gcell.exec_time_s == cell.exec_time_s

    def test_machine_spec_labels_not_anonymous(self):
        specs = [machines.get("pmem-large"), machines.get("cxl-1hop")]
        trace = workloads.make("gups", T=T, n=N)
        res = experiment.sweep(["oracle"], trace=trace, machines=specs,
                               k=K)
        assert res.axes["machine"] == ["pmem-large", "cxl-1hop"]

    def test_dedup_labels_suffixes_duplicates_only(self):
        out = experiment._dedup_labels(["a", "b", "a", "c"])
        assert out == ["a#0", "b", "a#2", "c"]

    def test_anonymous_machine_specs_get_positional_labels(self):
        import dataclasses

        sp = machines.get("pmem-large")
        anon = dataclasses.replace(sp, name="")
        labels = experiment._machine_labels([anon, "numa"], [anon, sp])
        assert labels == ["m0", "numa"]


class TestSearchRouting:
    """tuning/search route the tier-native families (satellite f)."""

    def test_asha_smoke_on_jenga(self):
        from repro.simulator import search

        trace = workloads.make("gups", T=T, n=N)
        sr = search.run("jenga", "asha", trace=trace,
                        machine="pmem-large", k=K, budget=4, t_min=24)
        assert set(sr.best_config) == {"alpha", "confirm", "cooldown",
                                       "migration_period"}
        assert all(r.dispatches == 1 for r in sr.rounds)
        assert sr.best_result.exec_time_s > 0

    def test_families_registry_routes_new_specs(self):
        from repro.simulator import tuning

        for fam, cls in (("hybridtier", HybridTierSpec),
                         ("jenga", JengaSpec),
                         ("tierbpf", TierBPFSpec)):
            make, space, defaults = tuning.FAMILIES[fam]
            assert isinstance(make(**defaults), cls)
            assert set(defaults) <= set(space)
