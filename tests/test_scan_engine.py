"""Scan-engine vs numpy-engine equivalence + batched-sweep behaviour.

The compiled ``lax.scan`` engine must be a faithful replacement for the
numpy reference engine on EVERY policy speaking the functional protocol:
under a shared common-random-number sampling field both engines see
bitwise-identical PEBS noise and interval arithmetic, so migration counts
must match EXACTLY and execution time to float32 accumulation error.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines.arms_policy import ARMSPolicy, ARMSSpec
from repro.baselines.hemem import HeMemPolicy, HeMemSpec
from repro.baselines.memtis import MemtisPolicy, MemtisSpec
from repro.baselines.static import (AllSlowPolicy, AllSlowSpec, OraclePolicy,
                                    OracleSpec)
from repro.baselines.tpp import TPPPolicy, TPPSpec
from repro.core.state import ARMSConfig
from repro.simulator import scan_engine, tuning, workloads
from repro.simulator.engine import oracle_topk_masks, run
from repro.simulator.machine import NUMA, PMEM_LARGE
from repro.simulator.sampling import pebs_sample_from_uniform, uniform_field

T, N, K = 160, 512, 64

# (legacy numpy-engine policy, functional spec) per family, default knobs.
FAMILIES = [
    (HeMemPolicy, lambda: HeMemSpec.make()),
    (MemtisPolicy, lambda: MemtisSpec.make()),
    (TPPPolicy, lambda: TPPSpec.make()),
    (AllSlowPolicy, AllSlowSpec),
    (OraclePolicy, OracleSpec),
]


def _crn_pair(wl, machine=PMEM_LARGE, seed=0, cfg=None):
    trace = workloads.make(wl, T=T, n=N)
    u = uniform_field(T, N, seed=123)
    ref = run(ARMSPolicy(cfg), trace, machine, K, seed=seed, sample_u=u)
    out = scan_engine.arms_sim(trace, machine, K, cfg=cfg, sample_u=u)
    return ref, out


class TestEngineEquivalence:
    @pytest.mark.parametrize("wl", ["gups", "silo-tpcc"])
    def test_matches_numpy_reference(self, wl):
        ref, out = _crn_pair(wl)
        assert out.promotions == ref.promotions
        assert out.demotions == ref.demotions
        assert out.wasteful == ref.wasteful
        np.testing.assert_allclose(out.exec_time_s, ref.exec_time_s,
                                   rtol=1e-4)
        np.testing.assert_array_equal(out.timeline_promotions,
                                      ref.timeline_promotions)
        np.testing.assert_array_equal(out.timeline_mode, ref.timeline_mode)

    def test_matches_on_other_machine(self):
        ref, out = _crn_pair("gups", machine=NUMA)
        assert (out.promotions, out.demotions, out.wasteful) == \
            (ref.promotions, ref.demotions, ref.wasteful)
        np.testing.assert_allclose(out.exec_time_s, ref.exec_time_s,
                                   rtol=1e-4)

    def test_recall_and_hits_close(self):
        ref, out = _crn_pair("gups")
        np.testing.assert_allclose(out.hot_recall, ref.hot_recall, rtol=1e-4)
        np.testing.assert_allclose(out.fast_hit_frac, ref.fast_hit_frac,
                                   rtol=1e-4)

    @pytest.mark.parametrize("wl", ["gups", "silo-tpcc"])
    @pytest.mark.parametrize(
        "family", [f[0].__name__ for f in FAMILIES])
    def test_every_baseline_matches_numpy_reference(self, wl, family):
        """Cross-engine CRN equivalence for each functional-protocol policy:
        the scan engine and the numpy engine (via LegacyPolicyAdapter) must
        agree EXACTLY on promotions/demotions/wasteful counts."""
        policy_cls, make_spec = dict(
            (f[0].__name__, f) for f in FAMILIES)[family]
        trace = workloads.make(wl, T=T, n=N)
        u = uniform_field(T, N, seed=31)
        ref = run(policy_cls(), trace, PMEM_LARGE, K, sample_u=u)
        out = scan_engine.simulate(make_spec(), trace, PMEM_LARGE, K,
                                   sample_u=u)
        assert (out.promotions, out.demotions, out.wasteful) == \
            (ref.promotions, ref.demotions, ref.wasteful)
        np.testing.assert_allclose(out.exec_time_s, ref.exec_time_s,
                                   rtol=1e-4)
        np.testing.assert_array_equal(out.timeline_promotions,
                                      ref.timeline_promotions)

    def test_arms_spec_through_generic_adapter(self):
        """ARMSSpec driven by the generic LegacyPolicyAdapter reproduces the
        hand-tuned ARMSPolicy wrapper exactly (same functional core)."""
        from repro.baselines.protocol import LegacyPolicyAdapter
        trace = workloads.make("gups", T=T, n=N)
        u = uniform_field(T, N, seed=5)
        a = run(ARMSPolicy(), trace, PMEM_LARGE, K, sample_u=u)
        b = run(LegacyPolicyAdapter(ARMSSpec.make()), trace, PMEM_LARGE, K,
                sample_u=u)
        assert (a.promotions, a.demotions, a.wasteful) == \
            (b.promotions, b.demotions, b.wasteful)
        np.testing.assert_array_equal(a.timeline_mode, b.timeline_mode)

    def test_kernel_and_jnp_score_paths_agree(self):
        """The fused Pallas path and the jnp escape hatch are one formula."""
        u = uniform_field(T, N, seed=9)
        trace = workloads.make("gups", T=T, n=N)
        a = scan_engine.arms_sim(trace, PMEM_LARGE, K, sample_u=u)
        b = scan_engine.arms_sim(trace, PMEM_LARGE, K,
                                 cfg=ARMSConfig(use_score_kernel=False),
                                 sample_u=u)
        assert a.promotions == b.promotions
        assert a.wasteful == b.wasteful
        np.testing.assert_allclose(a.exec_time_s, b.exec_time_s, rtol=1e-5)


class TestSweeps:
    def test_seed_sweep_deterministic(self):
        trace = workloads.make("btree", T=T, n=N)
        r1 = scan_engine.sweep_seeds(trace, PMEM_LARGE, K, [0, 1, 2])
        r2 = scan_engine.sweep_seeds(trace, PMEM_LARGE, K, [0, 1, 2])
        for a, b in zip(r1, r2):
            assert a.exec_time_s == b.exec_time_s
            assert a.promotions == b.promotions
            np.testing.assert_array_equal(a.timeline_promotions,
                                          b.timeline_promotions)

    def test_seed_sweep_lane_matches_single_run(self):
        """A sweep lane is bitwise the same replay as a standalone run."""
        trace = workloads.make("gups", T=T, n=N)
        single = scan_engine.arms_sim(trace, PMEM_LARGE, K, seed=3)
        lane = scan_engine.sweep_seeds(trace, PMEM_LARGE, K, [0, 3, 7])[1]
        assert lane.promotions == single.promotions
        assert lane.exec_time_s == single.exec_time_s

    def test_seed_sweep_varies_noise(self):
        trace = workloads.make("silo-tpcc", T=T, n=N)
        rows = scan_engine.sweep_seeds(trace, PMEM_LARGE, K, range(4))
        assert len({r.exec_time_s for r in rows}) > 1  # noise does vary

    def test_config_sweep_lane_matches_crn_single_run(self):
        """Config lane 0 (defaults) == arms_sim on the sweep's CRN field."""
        seed = 0
        trace = workloads.make("gups", T=T, n=N)
        u = uniform_field(T, N, seed=seed)
        rows = scan_engine.sweep_arms_configs(
            trace, PMEM_LARGE, K, dict(alpha_s=[0.7, 0.5]), seed=seed)
        ref = scan_engine.arms_sim(trace, PMEM_LARGE, K, sample_u=u)
        assert rows[0].promotions == ref.promotions
        assert rows[0].exec_time_s == ref.exec_time_s

    def test_baseline_seed_sweep_runs_batched(self):
        trace = workloads.make("gups", T=80, n=256)
        rows = scan_engine.sweep_seeds(trace, PMEM_LARGE, 32, range(3),
                                       spec=HeMemSpec.make())
        assert len(rows) == 3 and all(r.name.startswith("hemem")
                                      for r in rows)
        assert scan_engine.last_dispatch["lanes"] == 3

    def test_config_sweep_differentiates_configs(self):
        trace = workloads.make("gups", T=T, n=N)
        rows = scan_engine.sweep_arms_configs(
            trace, PMEM_LARGE, K, dict(access_scale=[10_000.0, 0.0]))
        assert rows[0].promotions > 0
        assert rows[1].promotions == 0      # zero benefit -> gate rejects
        assert rows[1].exec_time_s > rows[0].exec_time_s

    def test_config_sweep_rejects_non_sweepable(self):
        trace = workloads.make("gups", T=40, n=64)
        with pytest.raises(ValueError):
            scan_engine.sweep_arms_configs(trace, PMEM_LARGE, 8,
                                           dict(bs_max=[32, 64]))

    def test_tune_arms_runs_batched(self):
        trace = workloads.make("gups", T=80, n=256)
        best_cfg, best_res, rows = tuning.tune_arms(trace, PMEM_LARGE, 32,
                                                    budget=6)
        assert len(rows) >= 6
        assert best_res.exec_time_s == min(r.exec_time_s for _, r in rows)
        assert set(best_cfg) == set(tuning.ARMS_SPACE)


class TestTuning:
    """The unified tune() entry: one compiled lane-batched sweep per family,
    scored identically to the sequential numpy path under a shared CRN
    field, with search noise decoupled from simulation noise."""

    def test_tune_hemem_matches_sequential_numpy_ranking(self):
        trace = workloads.make("silo-tpcc", T=T, n=N)
        sim_seed = 9
        best_cfg, best_res, rows = tuning.tune_hemem(
            trace, PMEM_LARGE, K, budget=6, search_seed=2, sim_seed=sim_seed)
        # ONE lane-batched dispatch covered the whole budget.
        assert scan_engine.last_dispatch["lanes"] == len(rows)
        assert scan_engine.last_dispatch["policy"] == "hemem"
        # every lane == its sequential numpy replay on the same CRN field
        u = uniform_field(T, N, seed=sim_seed)
        seq = []
        for cfg, res in rows:
            ref = run(HeMemPolicy(**cfg), trace, PMEM_LARGE, K, sample_u=u)
            assert (ref.promotions, ref.demotions, ref.wasteful) == \
                (res.promotions, res.demotions, res.wasteful)
            np.testing.assert_allclose(res.exec_time_s, ref.exec_time_s,
                                       rtol=1e-4)
            seq.append((ref.exec_time_s, cfg))
        # ... so the best-config ranking matches the sequential path.
        seq_ranking = [cfg for _, cfg in sorted(seq, key=lambda x: x[0])]
        assert [cfg for cfg, _ in rows] == seq_ranking
        assert best_cfg == seq_ranking[0]
        assert best_res.exec_time_s == min(r.exec_time_s for _, r in rows)

    @pytest.mark.parametrize("tune_fn,policy_cls", [
        (tuning.tune_memtis, MemtisPolicy), (tuning.tune_tpp, TPPPolicy)])
    def test_tune_baselines_match_sequential_numpy(self, tune_fn, policy_cls):
        trace = workloads.make("btree", T=80, n=256)
        k, sim_seed = 32, 4
        _, _, rows = tune_fn(trace, PMEM_LARGE, k, budget=4, sim_seed=sim_seed)
        assert scan_engine.last_dispatch["lanes"] == len(rows)
        u = uniform_field(80, 256, seed=sim_seed)
        for cfg, res in rows:
            ref = run(policy_cls(**cfg), trace, PMEM_LARGE, k, sample_u=u)
            assert (ref.promotions, ref.demotions, ref.wasteful) == \
                (res.promotions, res.demotions, res.wasteful)

    def test_search_seed_decoupled_from_sim_noise(self):
        """Changing the search seed must NOT change how a given config
        scores (the seed-coupling bug this PR fixes): the default config is
        drawn under every search seed and must score identically."""
        trace = workloads.make("gups", T=80, n=256)
        score = {}
        for search_seed in (0, 1):
            _, _, rows = tuning.tune_hemem(trace, PMEM_LARGE, 32, budget=4,
                                           search_seed=search_seed,
                                           sim_seed=3)
            score[search_seed] = {
                tuple(sorted(cfg.items())): r.exec_time_s for cfg, r in rows}
        shared = set(score[0]) & set(score[1])
        assert shared  # the always-inserted default config at minimum
        for cfg in shared:
            assert score[0][cfg] == score[1][cfg]

    def test_sim_seed_changes_noise(self):
        trace = workloads.make("silo-tpcc", T=80, n=256)
        a = tuning.tune_hemem(trace, PMEM_LARGE, 32, budget=3, sim_seed=0)[2]
        b = tuning.tune_hemem(trace, PMEM_LARGE, 32, budget=3, sim_seed=1)[2]
        assert any(ra.exec_time_s != rb.exec_time_s
                   for (_, ra), (_, rb) in zip(a, b))

    def test_tune_unknown_family_rejected(self):
        trace = workloads.make("gups", T=20, n=64)
        with pytest.raises(ValueError):
            tuning.tune("nimble", trace, PMEM_LARGE, 8, budget=2)


class TestSamplingTransform:
    def test_poisson_from_uniform_moments(self):
        """Inverse-CDF transform reproduces Poisson mean/variance."""
        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.random(200_000), jnp.float32)
        for lam in (0.05, 0.8, 5.0, 40.0):
            x = np.asarray(pebs_sample_from_uniform(
                u, jnp.full(u.shape, lam * 1e4, jnp.float32), 1e4))
            assert abs(x.mean() - lam) < 0.05 * max(lam, 1.0)
            assert abs(x.var() - lam) < 0.1 * max(lam, 1.0)

    def test_zero_rate_yields_zero(self):
        u = jnp.asarray([0.01, 0.5, 0.999], jnp.float32)
        x = pebs_sample_from_uniform(u, jnp.zeros(3), 1e4)
        np.testing.assert_array_equal(np.asarray(x), 0.0)


class TestOracleMasks:
    def test_matches_per_interval_argpartition(self):
        trace = workloads.make("btree", T=40, n=128)
        masks = oracle_topk_masks(trace, 16)
        for t in range(0, 40, 7):
            topk = np.argpartition(trace[t], -16)[-16:]
            assert masks[t].sum() == 16
            assert masks[t][topk].all()
