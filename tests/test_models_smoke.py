"""Per-architecture smoke tests (deliverable f).

For every assigned architecture: instantiate the REDUCED config of the same
family, run one forward pass + one train (loss+grad) step + one decode step
on CPU, assert output shapes and the absence of NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import model as M

ARCH_NAMES = sorted(registry.ARCHS)


def _batch(rng, cfg, bsz=2, seq=16):
    tokens = jax.random.randint(rng, (bsz, seq), 0, cfg.vocab_size_raw)
    batch = {"tokens": tokens,
             "labels": jnp.where(jnp.arange(seq)[None] < seq - 1,
                                 jnp.roll(tokens, -1, axis=1), -1)}
    if cfg.family == "encdec":
        batch["audio_embeds"] = jax.random.normal(
            rng, (bsz, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            rng, (bsz, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_NAMES)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch, rng):
        cfg = registry.reduced(registry.get_arch(arch))
        params = M.init_params(rng, cfg)
        batch = _batch(rng, cfg)
        logits, aux = M.forward(params, batch, cfg)
        seq = batch["tokens"].shape[1]
        if cfg.family == "vlm":
            seq += cfg.n_patches
        assert logits.shape == (2, seq, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/Inf in logits"
        assert bool(jnp.isfinite(aux))

    def test_train_step_finite_grads(self, arch, rng):
        cfg = registry.reduced(registry.get_arch(arch))
        params = M.init_params(rng, cfg)
        batch = _batch(rng, cfg)
        loss, grads = jax.value_and_grad(M.loss_fn)(params, batch, cfg)
        assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
        flat = jax.tree.leaves(grads)
        assert flat and all(bool(jnp.isfinite(g).all()) for g in flat), \
            f"{arch}: non-finite grads"
        # loss should be near log(vocab) for random params
        assert 1.0 < float(loss) < 2.0 * np.log(cfg.vocab_size)

    def test_decode_step(self, arch, rng):
        cfg = registry.reduced(registry.get_arch(arch))
        params = M.init_params(rng, cfg)
        cache = M.init_cache(cfg, bsz=2, s_max=16)
        token = jnp.zeros((2, 1), jnp.int32)
        logits, cache2 = M.decode_step(params, token, cache,
                                       jnp.int32(0), cfg)
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        # cache structure unchanged, at least one leaf updated
        assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_validates(arch):
    cfg = registry.get_arch(arch)
    cfg.validate()
    assert cfg.vocab_size % 128 == 0
    assert cfg.vocab_size >= cfg.vocab_size_raw


def test_decode_matches_forward_dense(rng):
    """Sequential decode reproduces the full forward logits (dense)."""
    cfg = registry.reduced(registry.get_arch("granite-8b"))
    params = M.init_params(rng, cfg)
    batch = _batch(rng, cfg, bsz=1, seq=8)
    ref, _ = M.forward(params, batch, cfg)
    cache = M.init_cache(cfg, bsz=1, s_max=8)
    outs = []
    for t in range(8):
        logits, cache = M.decode_step(params, batch["tokens"][:, t: t + 1],
                                      cache, jnp.int32(t), cfg)
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_decode_matches_forward_ssm(rng):
    """Recurrent decode matches the chunked-SSD full forward (mamba2)."""
    cfg = registry.reduced(registry.get_arch("mamba2-370m"))
    params = M.init_params(rng, cfg)
    batch = _batch(rng, cfg, bsz=1, seq=8)
    ref, _ = M.forward(params, batch, cfg)
    cache = M.init_cache(cfg, bsz=1, s_max=8)
    outs = []
    for t in range(8):
        logits, cache = M.decode_step(params, batch["tokens"][:, t: t + 1],
                                      cache, jnp.int32(t), cfg)
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_mla_decode_matches_full(rng):
    """MLA weight-absorbed decode == materialized full attention.

    capacity_factor is raised so the comparison is drop-free: GShard
    capacity drops depend on the token-batch size, so full-sequence and
    token-at-a-time execution only agree when no expert overflows.
    """
    import dataclasses
    cfg = registry.reduced(registry.get_arch("deepseek-v2-236b"))
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = M.init_params(rng, cfg)
    batch = _batch(rng, cfg, bsz=1, seq=8)
    ref, _ = M.forward(params, batch, cfg)
    cache = M.init_cache(cfg, bsz=1, s_max=8)
    outs = []
    for t in range(8):
        logits, cache = M.decode_step(params, batch["tokens"][:, t: t + 1],
                                      cache, jnp.int32(t), cfg)
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=5e-3, atol=5e-3)
