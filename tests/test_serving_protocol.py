"""Serving-on-the-protocol regressions (PR 9 tentpole).

* ARMS-via-protocol == frozen legacy ``arms_step`` serving loop on a fixed
  decode trace: plan-SEQUENCE equality (padded promote/demote arrays) plus
  the residency trajectory, step by step.
* Every POLICY_REGISTRY family drives a TieredPool (the ``--policy``
  acceptance surface) and preserves the capacity/single-residency
  invariants.
* The measured serving cost model (tiered_pool.serving_interval_outcome)
  is the byte-volume mirror of ``simjax._tier_times`` — cross-checked
  under the CACHELINE/PAGE_BYTES unit conversion — and the default
  serving machine's fast tier is pinned to the roofline HBM bandwidth.
* satellite (a): K and V slow pools DIVERGE under serving (the
  k_new-passed-twice bug regression).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import roofline
from repro.core import arms_step
from repro.core import init_state as arms_init
from repro.simulator import machines, simjax
from repro.simulator.experiment import POLICY_REGISTRY
from repro.simulator.simjax import CACHELINE, PAGE_BYTES
from repro.tiering import paged_kv as PK
from repro.tiering import tiered_pool as TP

CFG = PK.PagedKVConfig(page_size=8, n_pages=8, fast_pages=3, policy_every=4)
B, KV, H, DH = 2, 2, 4, 16


def _decode_trace(steps, seed=7, policy="arms"):
    """Drive serve_decode_step; return per-step (plan, in_fast, access)."""
    rng = np.random.default_rng(seed)
    kv = PK.init_paged_kv(CFG, B, KV, DH, dtype=jnp.float32, policy=policy)
    recs = []
    for t in range(steps):
        q = jnp.asarray(rng.standard_normal((B, H, DH)), jnp.float32)
        k_new = jnp.asarray(rng.standard_normal((B, KV, DH)), jnp.float32)
        v_new = jnp.asarray(rng.standard_normal((B, KV, DH)), jnp.float32)
        _, kv, plan = PK.serve_decode_step(kv, q, k_new, v_new,
                                           jnp.int32(t), CFG)
        recs.append((np.asarray(plan.promote), np.asarray(plan.demote),
                     np.asarray(kv.in_fast), np.asarray(plan.access)))
    return kv, recs


class TestARMSLegacyEquality:
    """The tentpole regression: ARMS through the PolicySpec protocol and
    the shared TieredPool executor reproduces the pre-refactor
    ``core.arms_step`` serving loop bit-for-bit — same padded plan arrays
    at every policy fire, same residency after every decode step."""

    def test_plan_sequence_matches_frozen_legacy_loop(self):
        T = 48
        kv, recs = _decode_trace(T)
        n, k, E = CFG.n_pages, CFG.fast_pages, CFG.policy_every
        pb = PK.page_kv_bytes(kv)
        mach = jax.tree_util.tree_map(
            lambda a: jnp.asarray(a, jnp.float32), machines.get(CFG.machine))

        # ---- frozen legacy serving loop (pre-refactor paged_kv.py),
        # driven with the same access stream and the same measured
        # bandwidth signals the pool computes -------------------------
        state = arms_init(n, CFG.arms)
        in_fast = jnp.zeros((n,), bool)
        counts = jnp.zeros((n,), jnp.float32)
        rf_w = jnp.zeros((), jnp.float32)
        rs_w = jnp.zeros((), jnp.float32)
        for t in range(T):
            promote_t, demote_t, fast_t, access_t = recs[t]
            # read volumes use this step's PRE-fire residency, exactly as
            # serve_decode_step computes them before pool_step
            n_valid = min(t // CFG.page_size + 1, n)
            valid = jnp.arange(n) < n_valid
            rf_w = rf_w + (valid & in_fast).sum().astype(jnp.float32) * pb
            rs_w = rs_w + (valid & ~in_fast).sum().astype(jnp.float32) * pb
            counts = counts + jnp.asarray(access_t, jnp.float32)
            if (t + 1) % E == 0:
                slow_bw = jnp.where(in_fast, 0.0, counts).sum() \
                    / jnp.maximum(counts.sum(), 1e-9)
                _, app_raw = TP.serving_interval_outcome(mach, rf_w, rs_w)
                app_bw = jnp.clip(app_raw, 0.0, 1.0)
                state, plan = arms_step(state, counts, slow_bw, app_bw,
                                        cfg=CFG.arms, k=k)
                promote = jnp.where(plan.valid, plan.promote,
                                    -1).astype(jnp.int32)
                demote = jnp.where(plan.valid & (plan.demote >= 0),
                                   plan.demote, -1).astype(jnp.int32)
                in_fast, _, _ = simjax.apply_padded_migrations(
                    in_fast, promote, demote, k)
                counts = jnp.zeros_like(counts)
                rf_w = jnp.zeros((), jnp.float32)
                rs_w = jnp.zeros((), jnp.float32)
                np.testing.assert_array_equal(np.asarray(promote), promote_t,
                                              err_msg=f"promote plan, t={t}")
                np.testing.assert_array_equal(np.asarray(demote), demote_t,
                                              err_msg=f"demote plan, t={t}")
            else:
                assert (promote_t == -1).all() and (demote_t == -1).all(), \
                    f"policy fired off-cadence at t={t}"
            np.testing.assert_array_equal(np.asarray(in_fast), fast_t,
                                          err_msg=f"residency, t={t}")

    def test_arms_resolves_to_serving_spec(self):
        """init_pool("arms") must pick the legacy-cadence serving spec,
        not the simulator-cadence ARMSSpec."""
        from repro.baselines.arms_policy import ARMSServeSpec
        pool = TP.init_pool("arms", 8, 3, pool_every=4)
        assert type(pool.spec) is ARMSServeSpec
        assert pool.spec.pool_every == 4


class TestAllFamiliesDriveThePool:
    """Acceptance: every POLICY_REGISTRY family must run the serving pool
    (the surface behind ``launch/serve.py --policy``)."""

    @pytest.mark.parametrize("name", sorted(POLICY_REGISTRY))
    def test_family_runs_and_keeps_invariants(self, name):
        n, k = 16, 4
        pool = TP.init_pool(name, n, k, pool_every=2)
        fast = jnp.asarray(np.arange(1, k + 2, dtype=np.float32)
                           .repeat(3).reshape(k + 1, 3)[:k])
        slow = jnp.zeros((n, 3), jnp.float32) \
            + jnp.arange(n, dtype=jnp.float32)[:, None]
        rng = np.random.default_rng(3)
        for t in range(8):
            acc = jnp.asarray(
                np.abs(rng.standard_normal(n)) * (np.arange(n) < 5),
                jnp.float32)
            pool, (buf,), plan = TP.pool_step(
                pool, acc, 4096.0, 65536.0, k=k, bufs=((fast, slow),),
                copy_back=True, page_bytes=4096.0)
            fast, slow = buf
        in_fast = np.asarray(pool.in_fast)
        slot = np.asarray(pool.slot)
        assert in_fast.sum() <= k
        fast_slots = slot[in_fast]
        assert len(set(fast_slots.tolist())) == len(fast_slots)
        assert (fast_slots < k).all()
        # fast-resident pages' data actually lives in their fast slot
        for page in np.flatnonzero(in_fast):
            np.testing.assert_allclose(np.asarray(fast[slot[page]]),
                                       float(page))
        tel = TP.telemetry(pool)
        assert tel["promotions"] >= 0 and 0.0 <= tel["thrash"] <= 1.0

    def test_serve_cli_exposes_every_family(self):
        """--policy choices == the registry (the CLI acceptance check)."""
        import inspect

        from repro.launch import serve as SV
        src = inspect.getsource(SV.main)
        assert "choices=sorted(POLICY_REGISTRY)" in src


class TestServingCostModel:
    """satellite (c): the hardcoded app_bw_frac=0.5 is gone — the signal
    derives from measured per-tier read volumes, and the serving cost
    arithmetic is the simulator's own bandwidth model."""

    def test_matches_simjax_tier_times_under_unit_conversion(self):
        mach = machines.get("hbm-pcie")
        mach32 = jax.tree_util.tree_map(
            lambda a: jnp.asarray(a, jnp.float32), mach)
        rng = np.random.default_rng(0)
        for _ in range(16):
            rf, rs, up_b, down_b = (float(x) for x in
                                    rng.uniform(0, 1e9, 4))
            wall, app_raw = TP.serving_interval_outcome(
                mach32, jnp.float32(rf), jnp.float32(rs),
                jnp.float32(up_b), jnp.float32(down_b))
            # simjax charges accesses in CACHELINEs and migrations in
            # PAGE_BYTES pages; convert byte volumes to those units.
            acc = [jnp.float32(rf / CACHELINE), jnp.float32(rs / CACHELINE)]
            mig_up = jnp.asarray([up_b / PAGE_BYTES], jnp.float32)
            mig_down = jnp.asarray([down_b / PAGE_BYTES], jnp.float32)
            _, times = simjax._tier_times(mach32, acc, mig_up, mig_down)
            np.testing.assert_allclose(
                float(app_raw), float(times[0] / jnp.maximum(times[1],
                                                             1e-12)),
                rtol=1e-5)
            np.testing.assert_allclose(
                float(wall),
                max(float(times[0]), float(times[1]), 1e-12), rtol=1e-5)

    def test_default_machine_fast_tier_is_roofline_hbm(self):
        mach = machines.get(TP.DEFAULT_MACHINE)
        assert float(np.asarray(mach.bw_read)[0]) == roofline.HBM_BW

    def test_app_bw_derives_from_measured_volumes(self):
        """Fast-heavy windows read high app_bw, slow-heavy read low — the
        signal moves with the measured traffic (no constant 0.5)."""
        pool = TP.init_pool("arms", 8, 3, pool_every=100)
        acc = jnp.ones((8,), jnp.float32)
        fast_heavy = TP.pool_observe(pool, acc, read_fast=1e9, read_slow=1e3)
        slow_heavy = TP.pool_observe(pool, acc, read_fast=1e3, read_slow=1e9)
        _, app_f = TP.pool_signals(fast_heavy)
        _, app_s = TP.pool_signals(slow_heavy)
        assert float(app_f) > 0.9
        assert float(app_s) < 0.1
        assert abs(float(app_f) - 0.5) > 0.1   # not the old constant


class TestKVDivergence:
    """satellite (a): serve.py once passed k_new as BOTH k_new and v_new;
    the K and V pools were bitwise-identical mirrors.  They must diverge
    under real (distinct) streams."""

    def test_serve_kv_pools_diverge(self):
        from repro.launch.serve import serve
        rep = serve("granite-8b", n_tokens=12, batch=1, page_size=8,
                    quiet=True)
        ks = np.asarray(rep.kv.k_slow)
        vs = np.asarray(rep.kv.v_slow)
        assert ks.any() and vs.any()
        assert not np.array_equal(ks, vs), \
            "K and V slow pools are identical — v_new regression"

    def test_write_token_keeps_streams_distinct(self):
        kv = PK.init_paged_kv(CFG, B, KV, DH, dtype=jnp.float32)
        rng = np.random.default_rng(5)
        for t in range(CFG.page_size):
            k_new = jnp.asarray(rng.standard_normal((B, KV, DH)),
                                jnp.float32)
            v_new = jnp.asarray(rng.standard_normal((B, KV, DH)),
                                jnp.float32)
            kv = PK.write_token(kv, k_new, v_new, jnp.int32(t), CFG)
        assert not np.array_equal(np.asarray(kv.k_slow),
                                  np.asarray(kv.v_slow))


class TestServePolicies:
    """serve() end-to-end under a binary baseline and a tier-native
    family (the full --policy surface; pool-level coverage above)."""

    @pytest.mark.parametrize("policy", ["memtis", "jenga"])
    def test_serve_with_family(self, policy):
        from repro.launch.serve import serve
        rep = serve("granite-8b", n_tokens=12, batch=1, page_size=8,
                    policy=policy, quiet=True)
        assert rep.policy == policy
        assert rep.fast_mass.shape == (12,)
        assert np.isfinite(rep.slowdown) and rep.slowdown > 0.0
