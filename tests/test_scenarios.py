"""Adversarial scenario suite: sizing, phase behaviour, degenerate knobs.

The scenario constructors (``simulator/scenarios.py``) are parameterized
relative to the machine geometry (n, k); these tests pin the sizing
arithmetic, the ``phase_off`` duty staggering they rely on, and the
clamps that keep degenerate knob values (drift past n, zero-length flip
windows, hot fractions rounding to zero pages) well-defined.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.simulator import scenarios
from repro.simulator import workload_spec as ws

T, N, K = 48, 256, 32


class TestSuite:
    def test_suite_labels_and_shapes(self):
        suite = scenarios.suite(N, K)
        labels = [ws.label_of(s) for s in suite]
        assert len(labels) == len(set(labels)) == 7
        assert labels[:3] == ["straddle-0.9x", "straddle-1x",
                              "straddle-1.1x"]
        assert labels[-1] == "serving-mix-4"
        for s in suite:
            tr = np.asarray(s.materialize(T, N, seed=0))
            assert tr.shape == (T, N)
            assert np.isfinite(tr).all() and (tr >= 0).all()

    def test_straddle_sizing_tracks_fast_tier(self):
        for ratio in scenarios.STRADDLE_RATIOS:
            s = scenarios.capacity_straddle(N, K, ratio)
            np.testing.assert_allclose(np.asarray(s.hot_frac),
                                       [ratio * K / N], rtol=1e-6)


class TestPhaseFlip:
    def test_hot_sets_alternate_and_repeat(self):
        pf = scenarios.phase_flip(N, K, period=10)
        tr = np.asarray(pf.materialize(30, N, seed=0))
        top = lambda row: set(np.argsort(-row)[:20])
        # antiphase: the two half-period windows expose different hot sets
        assert len(top(tr[0]) & top(tr[5])) < 10
        # periodic: one full period later the distribution recurs exactly
        np.testing.assert_array_equal(tr[0], tr[10])
        np.testing.assert_array_equal(tr[5], tr[15])

    def test_exactly_one_tenant_busy(self):
        td = scenarios.duty_cycled_tenants(N, K, tenants=3, period=60)
        for t in (0, 20, 40, 59):
            rates = np.asarray(td._rates(jnp.int32(t)))
            assert (rates > 0.5 * rates.max()).sum() == 1


class TestServingMix:
    def test_default_tenants_staggered(self):
        sm = scenarios.serving_mix(N, K, tenants=4, period=48)
        assert ws.label_of(sm) == "serving-mix-4"
        tr = np.asarray(sm.materialize(96, N, seed=0))
        assert np.isfinite(tr).all() and (tr >= 0).all()
        # duty-cycled phases: exactly one tenant's burst dominates at a
        # time, so the per-interval total stays within one tenant's band
        totals = tr.sum(1)
        assert totals.max() < 2.2 * np.median(totals[totals > 0])

    def test_composes_fitted_specs(self):
        """The capture->fit->scenario path: serving_mix accepts fitted
        WorkloadSpecs (traces.fit_workload_spec outputs) as tenants."""
        from repro.simulator import traces
        rng = np.random.default_rng(0)
        steps = rng.uniform(0.5, 1.5, (32, 16))
        steps[:, :3] *= 80.0
        fit = traces.fit_workload_spec(
            traces.capture_from_steps(steps, group=2, label="kv"))
        sm = scenarios.serving_mix(N, K, tenants=3, specs=[fit])
        assert ws.label_of(sm) == "serving-mix-3"
        comps = ws._to_comps(sm)
        assert len(comps) == 3
        # per-tenant work is split so aggregate load matches the fit
        for c in comps:
            assert c["idle_scale"] <= 0.05 + 1e-6
        tr = np.asarray(sm.materialize(T, N, seed=0))
        assert np.isfinite(tr).all()

    def test_work_split_across_tenants(self):
        sm = scenarios.serving_mix(N, K, tenants=4, work=8e6)
        comps = ws._to_comps(sm)
        np.testing.assert_allclose(sum(c["work"] for c in comps), 8e6,
                                   rtol=1e-6)


class TestDegenerateKnobs:
    def test_drift_rate_wraps_mod_n(self):
        s = scenarios.drifting_hot(N, K, rate=N + 44.0)
        assert ws.label_of(s) == "drift-44"
        np.testing.assert_allclose(np.asarray(s.drift_rate), 44.0)
        # a full-wrap rate is the identity drift, not an error
        s0 = scenarios.drifting_hot(N, K, rate=float(N))
        np.testing.assert_allclose(np.asarray(s0.drift_rate), 0.0)

    def test_flip_period_floors_at_two(self):
        for bad in (0, 1, -3):
            s = scenarios.phase_flip(N, K, period=bad)
            assert ws.label_of(s) == "phase-flip-2"
            tr = np.asarray(s.materialize(8, N, seed=0))
            assert np.isfinite(tr).all()

    def test_hot_frac_never_rounds_to_zero_pages(self):
        # tiny ratio * k on a small machine: still at least one hot page
        s = scenarios.capacity_straddle(8, 4, 0.01)
        assert float(s.hot_frac[0]) >= 1.0 / 8
        tr = np.asarray(s.materialize(8, 8, seed=0))
        assert np.isfinite(tr).all()

    def test_phases_rejects_zero_length_first_window(self):
        a = ws.gups_spec()
        b = ws.zipf_spec()
        with pytest.raises(ValueError):
            ws.phases([a, b], [0])
        ws.phases([a, b], [1])  # minimal non-degenerate window is fine


class TestPhaseOffNeutrality:
    def test_default_zero_matches_historical_duty_formula(self):
        # every pre-PR-8 spec has phase_off == 0; its _rates must equal
        # the historical busy test (t % period) < duty * period, bitwise.
        spec = ws.liblinear_spec()
        assert np.all(np.asarray(spec.phase_off) == 0)
        per = np.maximum(np.asarray(spec.period), 1)
        duty = np.asarray(spec.duty)
        idle = np.asarray(spec.idle_scale)
        w = np.asarray(spec.weight) * np.asarray(spec.work)
        for t in range(40):
            busy = (np.float32(t % per)
                    < (duty * per.astype(np.float32)).astype(np.float32))
            expect = w * np.where(busy, 1.0, idle)
            np.testing.assert_array_equal(
                np.asarray(spec._rates(jnp.int32(t))).astype(np.float64),
                expect.astype(np.float32).astype(np.float64))

    def test_phase_off_staggers_busy_windows(self):
        mk = lambda off: ws._from_comps([ws._comp(
            ws.KIND_HOTSET, hot_frac=0.1, hot_weight=0.9, period=10,
            duty=0.5, phase_off=off, idle_scale=0.0)])
        on = lambda s, t: float(s._rates(jnp.int32(t))[0]) > 0
        a, b = mk(0), mk(5)
        for t in range(20):
            assert on(a, t) != on(b, t)    # perfectly antiphase
