"""N-tier machine protocol: legacy-bitwise safety net + tier invariants.

The refactor's contract (ISSUE 5): an N=2 ``TieredMachineSpec`` run must
be BITWISE equivalent to the pre-refactor boolean two-tier path in both
engines.  ``_legacy_crn_run`` below is a frozen, self-contained copy of
that pre-refactor reference engine (CRN mode): same boolean ``in_fast``
placement, same f32 interval arithmetic, same at-source clamping — the
new engines must reproduce its migration counts and exec time exactly.

Plus: adjacent-pair hop-chain migration properties (conservation, caps),
three-tier cross-engine equivalence, neutral-padding bitwise neutrality,
the raw-ratio clamping regression, the machine registry, and the
axis-product experiment API.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines.arms_policy import ARMSPolicy
from repro.baselines.hemem import HeMemPolicy, HeMemSpec
from repro.baselines.memtis import MemtisPolicy, MemtisSpec
from repro.baselines.static import AllSlowPolicy, AllSlowSpec
from repro.baselines.tpp import TPPPolicy, TPPSpec
from repro.core import scheduler
from repro.simulator import (engine, experiment, machine_spec, machines,
                             scan_engine, tuning, workload_spec, workloads)
from repro.simulator.engine import run
from repro.simulator.machine import CACHELINE, PAGE_BYTES, PMEM_LARGE
from repro.simulator.machine import NUMA, MachineSpec, interval_time
from repro.simulator.sampling import uniform_field

T, N, K = 120, 256, 32


# --------------------------------------------------------------------------
# frozen pre-refactor two-tier reference engine (CRN mode only)
# --------------------------------------------------------------------------
def _legacy_accounting(m: MachineSpec):
    f32 = jnp.float32
    lat_f, lat_s = f32(m.lat_fast_ns), f32(m.lat_slow_ns)
    bw_f, bw_sr, bw_sw = f32(m.bw_fast), f32(m.bw_slow_read), \
        f32(m.bw_slow_write)
    mlp = f32(m.mlp)

    @jax.jit
    def acct(true_counts, in_fast, promo_pages, demo_pages):
        true = jnp.asarray(true_counts, f32)
        acc_fast = jnp.sum(true * in_fast)
        acc_slow = jnp.sum(true) - acc_fast
        promo = jnp.asarray(promo_pages, f32)
        demo = jnp.asarray(demo_pages, f32)
        app_fast_bytes = acc_fast * CACHELINE
        app_slow_bytes = acc_slow * CACHELINE
        mig_fast_bytes = (promo + demo) * PAGE_BYTES
        mig_slow_read = promo * PAGE_BYTES
        mig_slow_write = demo * PAGE_BYTES
        t_lat = (acc_fast * lat_f + acc_slow * lat_s) * 1e-9 / mlp
        t_bw_fast = (app_fast_bytes + mig_fast_bytes) / bw_f
        t_bw_slow = ((app_slow_bytes + mig_slow_read) / bw_sr
                     + mig_slow_write / bw_sw)
        wall = jnp.maximum(jnp.maximum(t_lat, t_bw_fast),
                           jnp.maximum(t_bw_slow, 1e-12))
        slow_share = acc_slow / jnp.maximum(acc_fast + acc_slow, 1e-9)
        app_frac = jnp.minimum(1.0, t_bw_fast / wall)   # at-source clamp
        return acc_fast, acc_slow, wall, slow_share, app_frac

    return acct


def _legacy_crn_run(policy, trace, m: MachineSpec, k, sample_u):
    """Pre-refactor numpy reference engine, boolean in_fast placement."""
    from repro.simulator.engine import WASTE_WINDOW, _crn_sampler

    T_, n = trace.shape
    policy.reset(n, k, machines.get(m))
    acct = _legacy_accounting(m)
    crn_sample = _crn_sampler()
    in_fast = np.zeros(n, bool)
    promoted_at = np.full(n, -(10 ** 9))
    demoted_at = np.full(n, -(10 ** 9))
    slow_bw_frac, app_bw_frac = 1.0, 0.0
    exec_time = 0.0
    promotions = demotions = wasteful = 0
    for t in range(T_):
        true = trace[t]
        if policy.wants_true_counts():
            observed = true
        else:
            observed = np.asarray(crn_sample(
                sample_u[t], true.astype(np.float32),
                np.float32(policy.sampling_period())), np.float64)
        promote, demote = policy.step(observed, slow_bw_frac, app_bw_frac)
        demote = np.asarray(demote, np.int64)
        promote = np.asarray(promote, np.int64)
        demote = demote[in_fast[demote]]
        in_fast[demote] = False
        promote = promote[~in_fast[promote]]
        room = k - int(in_fast.sum())
        promote = promote[:room]
        in_fast[promote] = True
        wasteful += int((t - demoted_at[promote] <= WASTE_WINDOW).sum())
        wasteful += int((t - promoted_at[demote] <= WASTE_WINDOW).sum())
        promoted_at[promote] = t
        demoted_at[demote] = t
        promotions += len(promote)
        demotions += len(demote)
        _, acc_slow, wall, slow_share, app_frac = (
            float(v) for v in acct(true.astype(np.float32), in_fast,
                                   float(len(promote)), float(len(demote))))
        extra_ns = getattr(policy, "slow_access_extra_ns", 0.0)
        if extra_ns:
            wall += acc_slow * extra_ns * 1e-9 / m.mlp
        exec_time += wall
        slow_bw_frac, app_bw_frac = slow_share, app_frac
    return dict(promotions=promotions, demotions=demotions,
                wasteful=wasteful, exec_time=exec_time)


class TestLegacyBitwiseEquivalence:
    """N=2 tier-index runs == the frozen pre-refactor two-tier engine."""

    @pytest.mark.parametrize("policy_cls", [HeMemPolicy, ARMSPolicy,
                                            TPPPolicy])
    def test_numpy_engine_matches_frozen_legacy(self, policy_cls):
        trace = workloads.make("silo-tpcc", T=T, n=N)
        u = uniform_field(T, N, seed=11)
        ref = _legacy_crn_run(policy_cls(), trace, PMEM_LARGE, K, u)
        out = run(policy_cls(), trace, PMEM_LARGE, K, sample_u=u)
        # the migration decisions are BITWISE those of the frozen legacy
        # engine; exec time is float-tolerant only because the two jitted
        # cost programs may fuse (FMA) differently.
        assert (out.promotions, out.demotions, out.wasteful) == \
            (ref["promotions"], ref["demotions"], ref["wasteful"])
        np.testing.assert_allclose(out.exec_time_s, ref["exec_time"],
                                   rtol=1e-5)

    def test_scan_engine_matches_frozen_legacy(self):
        trace = workloads.make("gups", T=T, n=N)
        u = uniform_field(T, N, seed=7)
        ref = _legacy_crn_run(HeMemPolicy(), trace, PMEM_LARGE, K, u)
        out = scan_engine.simulate(HeMemSpec.make(), trace, PMEM_LARGE, K,
                                   sample_u=u)
        assert (out.promotions, out.demotions, out.wasteful) == \
            (ref["promotions"], ref["demotions"], ref["wasteful"])
        np.testing.assert_allclose(out.exec_time_s, ref["exec_time"],
                                   rtol=1e-5)

    def test_numa_machine_matches_frozen_legacy(self):
        trace = workloads.make("btree", T=T, n=N)
        u = uniform_field(T, N, seed=3)
        ref = _legacy_crn_run(ARMSPolicy(), trace, NUMA, K, u)
        out = run(ARMSPolicy(), trace, NUMA, K, sample_u=u)
        assert (out.promotions, out.demotions, out.wasteful) == \
            (ref["promotions"], ref["demotions"], ref["wasteful"])
        np.testing.assert_allclose(out.exec_time_s, ref["exec_time"],
                                   rtol=1e-5)


# --------------------------------------------------------------------------
# hop-chain migration invariants
# --------------------------------------------------------------------------
def _random_case(rng, R):
    n = int(rng.integers(16, 64))
    tier = rng.integers(0, R, n).astype(np.int32)
    caps = np.full(R, n, np.int64)
    caps[0] = int(rng.integers(max(1, (tier == 0).sum()), n + 1))
    for r in range(1, R - 1):
        caps[r] = int(rng.integers((tier == r).sum(), n + 1))
    pad_p, pad_d = int(rng.integers(1, 12)), int(rng.integers(1, 12))
    pick = lambda w: np.where(rng.random(w) < 0.75,
                              rng.choice(n, w, replace=False), -1)
    return tier, pick(min(pad_p, n)).astype(np.int32), \
        pick(min(pad_d, n)).astype(np.int32), caps.astype(np.int32)


class TestTierMigrations:
    def test_n2_matches_boolean_form(self):
        rng = np.random.default_rng(0)
        for _ in range(40):
            tier, promote, demote, caps = _random_case(rng, 2)
            in_fast = tier == 0
            t2, pexec, dexec, up, down = simjax_apply(tier, promote, demote,
                                                      caps)
            f2, pexec_b, dexec_b = __import__(
                "repro.simulator.simjax", fromlist=["x"]
            ).apply_padded_migrations(jnp.asarray(in_fast),
                                      jnp.asarray(promote),
                                      jnp.asarray(demote), int(caps[0]))
            np.testing.assert_array_equal(np.asarray(t2) == 0,
                                          np.asarray(f2))
            np.testing.assert_array_equal(np.asarray(pexec),
                                          np.asarray(pexec_b))
            np.testing.assert_array_equal(np.asarray(dexec),
                                          np.asarray(dexec_b))
            assert int(up[0]) == int(np.asarray(pexec).sum())
            assert int(down[0]) == int(np.asarray(dexec).sum())

    @pytest.mark.parametrize("R", [2, 3, 4])
    def test_conservation_and_caps(self, R):
        rng = np.random.default_rng(R)
        for _ in range(40):
            tier, promote, demote, caps = _random_case(rng, R)
            t2, pexec, dexec, up, down = simjax_apply(tier, promote, demote,
                                                      caps)
            t2 = np.asarray(t2)
            # populations sum to n; every tier but the bottom within caps
            counts = np.bincount(t2, minlength=R)
            assert counts.sum() == len(t2)
            assert (t2 >= 0).all() and (t2 <= R - 1).all()
            for r in range(R - 1):
                assert counts[r] <= caps[r]

    @pytest.mark.parametrize("R", [2, 3, 4])
    def test_numpy_mirror_matches_jnp(self, R):
        rng = np.random.default_rng(100 + R)
        for _ in range(40):
            tier, promote, demote, caps = _random_case(rng, R)
            t_jnp, pexec, dexec, up, down = simjax_apply(
                tier, promote, demote, caps)
            t_np = tier.copy()
            pr, de, up_np, down_np = engine.apply_tier_migrations_np(
                t_np, promote[promote >= 0], demote[demote >= 0], caps)
            np.testing.assert_array_equal(np.asarray(t_jnp), t_np)
            assert int(np.asarray(pexec).sum()) == len(pr)
            assert int(np.asarray(dexec).sum()) == len(de)
            np.testing.assert_array_equal(np.asarray(up), up_np)
            np.testing.assert_array_equal(np.asarray(down), down_np)

    def test_demotion_cascades_past_full_tier(self):
        # tier 1 full -> a page demoted from tier 0 lands in tier 2,
        # crossing both pairs.
        tier = np.array([0, 1, 2, 2], np.int32)
        caps = np.array([1, 1, 4], np.int32)
        t2, pexec, dexec, up, down = simjax_apply(
            tier, np.array([-1], np.int32), np.array([0], np.int32), caps)
        assert int(np.asarray(t2)[0]) == 2
        np.testing.assert_array_equal(np.asarray(down), [1, 1])

    def test_promotion_charges_every_pair_crossed(self):
        tier = np.array([2, 2, 1, 0], np.int32)
        caps = np.array([3, 2, 4], np.int32)
        t2, pexec, dexec, up, down = simjax_apply(
            tier, np.array([0, 2], np.int32), np.array([-1], np.int32),
            caps)
        assert int(np.asarray(t2)[0]) == 0 and int(np.asarray(t2)[2]) == 0
        # page 0 came from tier 2 (both pairs), page 2 from tier 1 (pair 0)
        np.testing.assert_array_equal(np.asarray(up), [2, 1])


def simjax_apply(tier, promote, demote, caps):
    from repro.simulator import simjax
    return simjax.apply_tier_migrations(
        jnp.asarray(tier), jnp.asarray(promote), jnp.asarray(demote),
        jnp.asarray(caps))


# --------------------------------------------------------------------------
# three-tier cross-engine equivalence + conservation in a full run
# --------------------------------------------------------------------------
class TestThreeTier:
    @pytest.mark.parametrize("policy_cls,make_spec", [
        (HeMemPolicy, lambda: HeMemSpec.make()),
        (MemtisPolicy, lambda: MemtisSpec.make()),
        (TPPPolicy, lambda: TPPSpec.make()),
        (ARMSPolicy, None),
        (AllSlowPolicy, AllSlowSpec),
    ])
    def test_cross_engine_exact(self, policy_cls, make_spec):
        """Scan and numpy engines agree EXACTLY on a 3-tier chain under a
        shared CRN field — two independent implementations of the hop-chain
        semantics."""
        trace = workloads.make("silo-tpcc", T=80, n=N)
        u = uniform_field(80, N, seed=5)
        ref = run(policy_cls(), trace, "dram-cxl-pmem", K, sample_u=u)
        if make_spec is None:
            out = scan_engine.arms_sim(trace, "dram-cxl-pmem", K, sample_u=u)
        else:
            out = scan_engine.simulate(make_spec(), trace, "dram-cxl-pmem",
                                       K, sample_u=u)
        assert (out.promotions, out.demotions, out.wasteful) == \
            (ref.promotions, ref.demotions, ref.wasteful)
        np.testing.assert_allclose(out.exec_time_s, ref.exec_time_s,
                                   rtol=1e-4)

    def test_three_tier_differs_from_two_tier(self):
        trace = workloads.make("gups", T=80, n=N)
        u = uniform_field(80, N, seed=5)
        three = run(HeMemPolicy(), trace, "dram-cxl-pmem", K, sample_u=u)
        two = run(HeMemPolicy(), trace, "pmem-large", K, sample_u=u)
        assert three.exec_time_s != two.exec_time_s

    def test_padding_is_bitwise_neutral(self):
        """A 2-tier machine padded to 3 tiers replays bitwise unchanged."""
        trace = workloads.make("gups", T=80, n=N)
        u = uniform_field(80, N, seed=9)
        m = machines.get("pmem-large")
        caps = machine_spec.resolved_caps(m, N, K)
        padded, _ = machine_spec.pad_tiers(m, caps, 3)
        a = run(HeMemPolicy(), trace, m, K, sample_u=u)
        b = run(HeMemPolicy(), trace, padded, K, sample_u=u)
        assert (a.promotions, a.demotions, a.wasteful, a.exec_time_s) == \
            (b.promotions, b.demotions, b.wasteful, b.exec_time_s)


# --------------------------------------------------------------------------
# raw-ratio clamping (satellite): oversaturation visible, consumers clamp
# --------------------------------------------------------------------------
class TestRawUtilization:
    def test_interval_time_reports_oversaturation(self):
        # slow-tier-bound interval on pmem-large: slow bandwidth time far
        # exceeds the latency-bound time -> the raw ratio exceeds 1
        # instead of being pegged at 1 by the old min(1, t/wall) clamp.
        out = interval_time(PMEM_LARGE, 0.0, 1e9, 0, 0)
        assert out.slow_bw_frac > 1.0
        # unsaturated direction still reports <= 1
        assert out.app_bw_frac <= 1.0

    def test_simjax_raw_matches_host(self):
        # a machine whose fast tier is bandwidth-starved: tier-0 raw
        # utilization exceeds 1 and is visible to accounting consumers.
        m = machine_spec.make("starved", [80.0, 200.0], [1e9, 7.45e9],
                              [1e9, 2.25e9])
        from repro.simulator import simjax
        tier = jnp.zeros(8, jnp.int32)
        true = jnp.full((8,), 2e8, jnp.float32)
        _, _, wall, _, app_raw = (
            float(v) for v in simjax.interval_accounting(
                m, true, tier, jnp.asarray([400.0], jnp.float32),
                jnp.asarray([0.0], jnp.float32)))
        assert app_raw > 1.0
        host = machine_spec.interval_outcome_host(
            m, [8 * 2e8, 0.0], [400.0], [0.0])
        assert host[2] > 1.0                       # same story on host
        np.testing.assert_allclose(app_raw, host[2], rtol=1e-5)

    def test_consumer_clamp_preserves_signal(self):
        # scheduler.batch_size is the consumer: raw > 1 behaves as 1.
        for raw in (1.0, 1.7, 9.0):
            assert int(scheduler.batch_size(raw, 1.0, 64)) == \
                int(scheduler.batch_size(1.0, 1.0, 64))
        # and unsaturated signals pass through unchanged
        assert int(scheduler.batch_size(0.5, 1.0, 64)) == 32

    def test_pair_budgets_clip_and_bound(self):
        u = jnp.asarray([0.0, 2.5, 0.4], jnp.float32)   # oversaturated mid
        b = scheduler.pair_budgets(u, 64)
        assert b.shape == (2,)
        assert int(b[0]) == 1 and int(b[1]) == 1   # saturated endpoint
        b2 = scheduler.pair_budgets(jnp.asarray([0.0, 0.0], jnp.float32), 64)
        assert int(b2[0]) == 64


# --------------------------------------------------------------------------
# registry + experiment axis product
# --------------------------------------------------------------------------
class TestRegistryAndExperiment:
    def test_get_accepts_all_forms(self):
        a = machines.get("pmem-large")
        b = machines.get(PMEM_LARGE)
        c = machines.get(a)
        np.testing.assert_array_equal(np.asarray(a.lat_ns),
                                      np.asarray(b.lat_ns))
        assert c is a
        with pytest.raises(ValueError):
            machines.get("optane-9000")
        with pytest.raises(TypeError):
            machines.get(42)

    def test_names_anywhere(self):
        trace = workloads.make("gups", T=40, n=64)
        r1 = run(HeMemPolicy(), trace, "numa", 8)
        r2 = run(HeMemPolicy(), trace, NUMA, 8)
        assert r1.exec_time_s == r2.exec_time_s
        s1 = scan_engine.simulate(HeMemSpec.make(), trace, "pmem-large", 8)
        assert s1.promotions >= 0
        best_cfg, _, _ = tuning.tune("hemem", trace, "pmem-large", 8,
                                     budget=2)
        assert best_cfg

    def test_axis_product_one_dispatch_per_family(self):
        res = experiment.sweep(
            [HeMemSpec.make(), HeMemSpec.make(hot_threshold=4.0)],
            workloads=["gups", "silo-tpcc"],
            machines=["pmem-large", "dram-cxl-pmem"],
            k=16, T=50, n=128)
        assert res.shape == (2, 2, 2, 1)
        d = scan_engine.last_dispatch
        assert d["lanes"] == 8 and d["machines"] == 2 and d["synth"] is True
        assert d["axis_product"] is True
        # structured addressing by label and index agree
        assert res.at(policy=1, workload="silo-tpcc",
                      machine="dram-cxl-pmem") is res.grid[
            ((1 * 2 + 1) * 2 + 1) * 1]
        assert len(list(res.items())) == 8

    def test_lane_equals_single_run(self):
        wl = workload_spec.named("gups", T=50)
        res = experiment.sweep(
            [HeMemSpec.make()], workloads=[wl],
            machines=["pmem-large", "numa"], k=16, T=50, n=128, sim_seed=2)
        single = scan_engine.simulate_workload(
            HeMemSpec.make(), wl, "numa", 16, 50, 128, sim_seed=2)
        lane = res.at(machine="numa")
        assert (lane.promotions, lane.demotions, lane.wasteful) == \
            (single.promotions, single.demotions, single.wasteful)
        assert lane.exec_time_s == single.exec_time_s

    def test_seed_axis_varies_noise(self):
        # ARMS is sampling-noise sensitive (HeMem's coarse thresholds can
        # absorb small-seed noise into identical placements).
        res = experiment.sweep(["arms"], workloads=["silo-tpcc"],
                               machines=["pmem-large"], seeds=[0, 1, 2, 3],
                               k=32, T=100, n=256)
        times = {res.at(seed=s).exec_time_s for s in range(4)}
        assert len(times) > 1

    def test_trace_mode_matches_numpy(self):
        trace = workloads.make("btree", T=60, n=128)
        res = experiment.sweep([HeMemSpec.make()], trace=trace,
                               machines=["pmem-large"], k=16, sim_seed=4)
        u = uniform_field(60, 128, seed=4)
        ref = run(HeMemPolicy(), trace, "pmem-large", 16, sample_u=u)
        out = res.at()
        assert (out.promotions, out.demotions, out.wasteful) == \
            (ref.promotions, ref.demotions, ref.wasteful)

    def test_mixed_families_cover_grid(self):
        res = experiment.sweep(["hemem", "arms"], workloads=["gups"],
                               machines=["pmem-large"], k=16, T=40, n=128)
        assert res.shape == (2, 1, 1, 1)
        assert all(r is not None for r in res.grid)
        assert res.at(policy="arms").name.startswith("arms@")

    def test_at_rejects_out_of_range_indices(self):
        res = experiment.sweep(["hemem"], workloads=["gups"],
                               machines=["pmem-large", "numa"],
                               k=8, T=30, n=64)
        with pytest.raises(IndexError):
            res.at(machine=-1)       # would alias another axis block
        with pytest.raises(IndexError):
            res.at(machine=2)
        with pytest.raises(KeyError):
            res.at(machine="optane")

    def test_input_validation(self):
        with pytest.raises(ValueError):
            experiment.sweep(["hemem"], k=8)               # no workload/trace
        with pytest.raises(ValueError):
            experiment.sweep(["hemem"], workloads=["gups"],
                             trace=np.zeros((4, 8)), k=2, T=4, n=8)
        with pytest.raises(ValueError):
            experiment.sweep(["hemem"], workloads=["gups"], k=2)  # no T/n
        with pytest.raises(ValueError):
            experiment.sweep(["nimble"], workloads=["gups"], k=2, T=4, n=8)


class TestResolvedCaps:
    def test_encoding(self):
        m = machines.get("dram-cxl-pmem")
        caps = machine_spec.resolved_caps(m, n=1024, k=128)
        np.testing.assert_array_equal(caps, [128, 256, 1024])

    def test_two_tier_defaults(self):
        caps = machine_spec.resolved_caps(machines.get("pmem-large"),
                                          n=512, k=64)
        np.testing.assert_array_equal(caps, [64, 512])

    def test_absolute_and_clamped(self):
        m = machine_spec.make("t", [80, 200, 300], [1e11, 1e10, 1e9],
                              [1e11, 1e10, 1e9],
                              capacity_pages=[-1.0, 10_000.0, 0.0])
        caps = machine_spec.resolved_caps(m, n=256, k=32)
        np.testing.assert_array_equal(caps, [32, 256, 256])  # clamped to n
