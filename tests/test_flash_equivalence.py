"""Equivalence of the XLA flash-pattern attention vs the naive path
(§Perf iteration A4) and vs the Pallas flash kernel's ref oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.models import xla_flash


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,H,S,dh,block", [(2, 4, 256, 64, 64),
                                            (1, 2, 512, 32, 128)])
def test_flash_sdpa_matches_naive(B, H, S, dh, block, causal):
    rng = np.random.default_rng(S + dh)
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    ref = flash_attention_ref(q, k, v, causal=causal)
    got = xla_flash.flash_sdpa(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), dh ** -0.5, causal=causal, block=block)
    got = got.transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_sdpa_windowed():
    rng = np.random.default_rng(0)
    B, H, S, dh = 1, 2, 256, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    ref = flash_attention_ref(q, k, v, causal=True, window=64)
    got = xla_flash.flash_sdpa(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), dh ** -0.5, causal=True, window=64,
        block=64).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_gqa_full_flash_path_matches_naive(monkeypatch):
    """Force the flash path at small S and compare whole-block outputs."""
    from repro.configs import registry
    from repro.models import attention as A

    cfg = registry.reduced(registry.get_arch("granite-8b"))
    rng = jax.random.PRNGKey(0)
    p = A.gqa_init(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    ref, _ = A.gqa_full(p, x, cfg)
    monkeypatch.setattr(xla_flash, "FLASH_MIN_SEQ", 16)
    monkeypatch.setattr(xla_flash, "BLOCK", 16)
    got, _ = A.gqa_full(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_mla_full_flash_path_matches_naive(monkeypatch):
    from repro.configs import registry
    from repro.models import attention as A

    cfg = registry.reduced(registry.get_arch("deepseek-v2-236b"))
    rng = jax.random.PRNGKey(0)
    p = A.mla_init(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    ref, _ = A.mla_full(p, x, cfg)
    monkeypatch.setattr(xla_flash, "FLASH_MIN_SEQ", 16)
    monkeypatch.setattr(xla_flash, "BLOCK", 16)
    got, _ = A.mla_full(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
