"""Functional policy protocol: padded-index contract + engine agreement.

The protocol's fixed-shape sentinel-padded promote/demote arrays
(baselines/protocol.py) must execute EXACTLY like the numpy engine's
variable-length path when pushed through ``simjax.apply_padded_migrations``
— for arbitrary residency/k and for every policy's actual outputs.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.baselines.arms_policy import ARMSSpec
from repro.baselines.hemem import HeMemSpec
from repro.baselines.memtis import MemtisSpec
from repro.baselines.protocol import (SENTINEL, LegacyPolicyAdapter,
                                      ranked_take)
from repro.baselines.static import AllSlowSpec, OracleSpec
from repro.baselines.tpp import TPPSpec
from repro.simulator import simjax, workloads
from repro.simulator.machine import PMEM_LARGE
from repro.simulator.sampling import pebs_sample

SPECS = [lambda: HeMemSpec.make(migration_period=1),
         lambda: HeMemSpec.make(hot_threshold=1.0, cooling_threshold=1000.0,
                                migration_period=1),
         MemtisSpec.make, TPPSpec.make, AllSlowSpec, OracleSpec,
         ARMSSpec.make]


def _numpy_apply(in_fast, promote, demote, k):
    """The numpy engine's variable-length migration path (engine.run)."""
    in_fast = in_fast.copy()
    promote = promote[promote >= 0]
    demote = demote[demote >= 0]
    demote = demote[in_fast[demote]]
    in_fast[demote] = False
    promote = promote[~in_fast[promote]]
    room = k - int(in_fast.sum())
    promote = promote[:room]
    in_fast[promote] = True
    return in_fast, len(promote), len(demote)


def _assert_padded_matches_numpy(in_fast, promote, demote, k):
    ref_fast, n_p, n_d = _numpy_apply(in_fast, promote, demote, k)
    out_fast, pexec, dexec = simjax.apply_padded_migrations(
        jnp.asarray(in_fast), jnp.asarray(promote, jnp.int32),
        jnp.asarray(demote, jnp.int32), k)
    np.testing.assert_array_equal(np.asarray(out_fast), ref_fast)
    assert int(pexec.sum()) == n_p
    assert int(dexec.sum()) == n_d


class TestPaddedContractProperty:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(4, 48), st.integers(0, 2 ** 31 - 1))
    def test_padded_apply_matches_variable_length_path(self, n, seed):
        """Random residency + random sentinel-padded (possibly duplicate,
        interleaved-sentinel) migration lists: both paths agree bitwise."""
        rng = np.random.default_rng(seed)
        in_fast = rng.random(n) < rng.random()
        k = int(rng.integers(in_fast.sum(), n + 1))
        for _ in range(4):
            pad_p, pad_d = int(rng.integers(1, n + 4)), \
                int(rng.integers(1, n + 4))
            promote = rng.integers(-1, n, size=pad_p)
            demote = rng.integers(-1, n, size=pad_d)
            _assert_padded_matches_numpy(in_fast, promote, demote, k)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 40), st.integers(0, 2 ** 31 - 1))
    def test_ranked_take_matches_stable_numpy_argsort(self, n, seed):
        rng = np.random.default_rng(seed)
        key = rng.integers(0, 5, size=n).astype(np.float64)  # many ties
        mask = rng.random(n) < 0.6
        pad = int(rng.integers(1, n + 1))
        limit = int(rng.integers(0, n + 1))
        idx, count = ranked_take(jnp.asarray(key, jnp.float32),
                                 jnp.asarray(mask), pad, limit)
        want = np.flatnonzero(mask)
        want = want[np.argsort(key[want], kind="stable")][:min(pad, limit)]
        got = np.asarray(idx)
        got = got[got >= 0]
        np.testing.assert_array_equal(got, want)
        assert int(count) == len(want)


class TestPolicyPaddedOutputs:
    """Each policy's real padded outputs honor the contract and execute
    identically through both engines' migration paths."""

    @pytest.mark.parametrize("make_spec", SPECS,
                             ids=["hemem", "hemem-greedy", "memtis", "tpp",
                                  "all-slow", "oracle", "arms"])
    def test_step_outputs_well_formed_and_engine_agree(self, make_spec):
        spec = make_spec()
        T, n, k = 40, 96, 16
        trace = workloads.make("silo-tpcc", T=T, n=n)
        rng = np.random.default_rng(0)
        state = spec.init(n, k, PMEM_LARGE)
        in_fast = np.zeros(n, bool)
        for t in range(T):
            observed = trace[t] if spec.wants_true_counts else pebs_sample(
                trace[t], float(spec.sampling_period(state)), rng)
            state, promote, demote = spec.step(
                state, jnp.asarray(observed, jnp.float32),
                jnp.float32(0.5), jnp.float32(0.2), k)
            promote = np.asarray(promote)
            demote = np.asarray(demote)
            assert promote.shape == (spec.pad_promote(n, k),)
            assert demote.shape == (spec.pad_demote(n, k),)
            for arr in (promote, demote):
                assert ((arr == SENTINEL) | ((arr >= 0) & (arr < n))).all()
                valid = arr[arr >= 0]
                assert len(np.unique(valid)) == len(valid)  # no duplicates
            _assert_padded_matches_numpy(in_fast, promote, demote, k)
            in_fast, _, _ = (np.asarray(x) for x in
                             simjax.apply_padded_migrations(
                                 jnp.asarray(in_fast),
                                 jnp.asarray(promote, jnp.int32),
                                 jnp.asarray(demote, jnp.int32), k))
            assert in_fast.sum() <= k

    def test_adapter_drops_sentinels_preserving_order(self):
        spec = HeMemSpec.make(hot_threshold=1.0, migration_period=1)
        pol = LegacyPolicyAdapter(spec)
        n, k = 64, 8
        pol.reset(n, k, PMEM_LARGE)
        rng = np.random.default_rng(1)
        for _ in range(6):
            observed = rng.poisson(2.0, size=n).astype(np.float64)
            promote, demote = pol.step(observed, 0.5, 0.2)
            assert (promote >= 0).all() and (demote >= 0).all()
            assert len(promote) <= spec.migration_limit
            assert promote.dtype == np.int64
