"""Tiering-integration tests: paged KV, expert tiering, embedding tiering.

Key invariants: (a) tiered attention output == contiguous-cache oracle
regardless of page placement; (b) every logical page lives in exactly one
tier; (c) ARMS migrates hot pages/experts/rows into the fast tier.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.tiering import embedding_tiering as ET
from repro.tiering import expert_tiering as XT
from repro.tiering import paged_kv as PK

CFG = PK.PagedKVConfig(page_size=8, n_pages=8, fast_pages=3, policy_every=4)
B, KV, H, DH = 2, 2, 4, 16


def _contiguous_attention(ks, vs, q, pos):
    """Oracle: dense attention over the first pos+1 tokens."""
    S = ks.shape[0]
    rep = H // KV
    qg = q.reshape(B, KV, rep, DH)
    k = ks.transpose(1, 0, 2, 3)   # [B,S,KV,dh]
    v = vs.transpose(1, 0, 2, 3)
    s = jnp.einsum("bkrd,bskd->bkrs", qg, k).astype(jnp.float32)
    s *= DH ** -0.5
    s = jnp.where((jnp.arange(S) <= pos)[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bkrs,bskd->bkrd", p.astype(v.dtype),
                      v).reshape(B, H, DH)


class TestPagedKV:
    def _drive(self, steps, seed=0):
        rng = np.random.default_rng(seed)
        kv = PK.init_paged_kv(CFG, B, KV, DH, dtype=jnp.float32)
        S = CFG.page_size * CFG.n_pages
        ks_ref = np.zeros((S, B, KV, DH), np.float32)
        vs_ref = np.zeros((S, B, KV, DH), np.float32)
        outs, oracle = [], []
        for t in range(steps):
            q = jnp.asarray(rng.standard_normal((B, H, DH)), jnp.float32)
            k_new = jnp.asarray(rng.standard_normal((B, KV, DH)),
                                jnp.float32)
            v_new = jnp.asarray(rng.standard_normal((B, KV, DH)),
                                jnp.float32)
            ks_ref[t], vs_ref[t] = k_new, v_new
            out, kv, plan = PK.serve_decode_step(kv, q, k_new, v_new,
                                                 jnp.int32(t), CFG)
            outs.append(np.asarray(out))
            oracle.append(np.asarray(_contiguous_attention(
                jnp.asarray(ks_ref), jnp.asarray(vs_ref), q, t)))
        return kv, np.stack(outs), np.stack(oracle)

    def test_attention_matches_contiguous_oracle(self):
        """Placement must never change attention output."""
        kv, got, want = self._drive(40)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_single_residency_invariant(self):
        kv, _, _ = self._drive(48)
        in_fast = np.asarray(kv.in_fast)
        slots = np.asarray(kv.slot)
        fast_slots = slots[in_fast]
        assert len(set(fast_slots.tolist())) == len(fast_slots)
        assert in_fast.sum() <= CFG.fast_pages

    def test_hot_pages_get_promoted(self):
        """With causal decode the early pages accumulate attention mass;
        after enough steps some pages must be fast-resident."""
        kv, _, _ = self._drive(64)
        assert int(np.asarray(kv.in_fast).sum()) > 0


class TestExpertTiering:
    def test_hot_experts_promoted_and_weights_correct(self):
        E, Kf, D, F = 8, 3, 16, 8
        rng = np.random.default_rng(0)
        wi = jnp.asarray(rng.standard_normal((E, D, 2 * F)), jnp.float32)
        wo = jnp.asarray(rng.standard_normal((E, F, D)), jnp.float32)
        cfg = XT.ExpertTierConfig(n_experts=E, fast_experts=Kf,
                                  policy_every=1)
        t = XT.init_expert_tier(cfg, wi, wo)
        load = jnp.asarray([100, 90, 80, 1, 1, 1, 1, 1], jnp.float32)
        for _ in range(6):
            t, plan = XT.observe_and_policy(t, load, cfg)
        in_fast = np.asarray(t.in_fast)
        assert in_fast[:3].sum() == 3          # the 3 hot experts resident
        assert in_fast.sum() <= Kf
        wi_eff, wo_eff = XT.effective_weights(t)
        np.testing.assert_allclose(np.asarray(wi_eff), np.asarray(wi),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(wo_eff), np.asarray(wo),
                                   rtol=1e-6)

    def test_bursty_expert_filtered(self):
        """One-hit-wonder expert (single burst) must not displace steady
        hot experts (multi-round filter, §4.3)."""
        E, Kf = 8, 2
        rng = np.random.default_rng(1)
        wi = jnp.asarray(rng.standard_normal((E, 4, 8)), jnp.float32)
        wo = jnp.asarray(rng.standard_normal((E, 4, 4)), jnp.float32)
        cfg = XT.ExpertTierConfig(n_experts=E, fast_experts=Kf,
                                  policy_every=1)
        t = XT.init_expert_tier(cfg, wi, wo)
        steady = jnp.asarray([50, 50, 0, 0, 0, 0, 0, 0], jnp.float32)
        for _ in range(5):
            t, _ = XT.observe_and_policy(t, steady, cfg)
        burst = steady.at[7].set(500.0)
        t, plan = XT.observe_and_policy(t, burst, cfg)   # single burst
        assert not bool(t.in_fast[7])   # hot_age < 2: not promoted yet
        for _ in range(4):
            t, _ = XT.observe_and_policy(t, steady, cfg)
        assert not bool(t.in_fast[7])   # burst faded: never promoted


class TestEmbeddingTiering:
    def test_zipf_hot_blocks_promoted(self):
        V, D = 4096, 8
        cfg = ET.EmbedTierConfig(vocab=V, row_block=256, fast_blocks=4,
                                 policy_every=1)
        rng = np.random.default_rng(2)
        table = jnp.asarray(rng.standard_normal((V, D)), jnp.float32)
        t = ET.init_embed_tier(cfg, table)
        # zipf-ish ids concentrated in blocks 0-3
        ids = jnp.asarray(rng.integers(0, 1024, (64,)), jnp.int32)
        for _ in range(6):
            emb, hits, t = ET.lookup(t, ids, cfg)
            t, _ = ET.policy(t, cfg)
        emb, hits, t = ET.lookup(t, ids, cfg)
        assert float(hits) == 1.0      # all lookups hit the fast tier
        np.testing.assert_allclose(
            np.asarray(emb), np.asarray(jnp.take(table, ids, axis=0)))
