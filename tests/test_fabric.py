"""Mesh sweep fabric (simulator/fabric.py): union dispatch + lane
sharding, single-device half of the equivalence suite.

Three guarantees anchor the fabric:

  * UNION EQUIVALENCE — a mixed-family panel fused into ONE compiled
    dispatch by the union PolicyState is BITWISE equal (every scalar,
    summary and timeline) to the historical grouped per-family path
    under CRN, on 2- and 3-tier machines, fused and unfused, synth and
    trace modes;
  * SHARDING EQUIVALENCE — running the same panel under ``shard_map``
    (forced mesh of 1 here; mesh > 1 in test_fabric_mesh.py's
    forced-device-count subprocess) is bitwise equal to the plain path,
    with padded lanes dropped before labeling even when the lane count
    is not a multiple of the padding unit;
  * DISPATCH ACCOUNTING — ``scan_engine.count_dispatches`` counters
    nest/overlap without racing, and the whole mixed board records
    exactly one dispatch.
"""
import dataclasses

import numpy as np
import pytest

from repro.baselines.hemem import HeMemSpec
from repro.simulator import (experiment, fabric, machine_spec, machines,
                             scan_engine, search, workloads)
from repro.simulator.engine import SimResult
from repro.simulator.sampling import uniform_field

T, N, K = 48, 192, 24

#: every registry family rides the board (arms/hemem/memtis/tpp binary
#: through the shim, all-slow/oracle static, three tier-native).
ALL_FAMILIES = list(experiment.POLICY_REGISTRY)
MACHS = ["pmem-large", "dram-cxl-pmem"]       # 2-tier and 3-tier

_FIELDS = [f.name for f in dataclasses.fields(SimResult)
           if f.name != "name"]


def _assert_bitwise(ra, rb, tag=""):
    for (coords, a), (_, b) in zip(ra.items(), rb.items()):
        for f in _FIELDS:
            va, vb = getattr(a, f), getattr(b, f)
            if va is None and vb is None:
                continue
            assert np.array_equal(np.asarray(va), np.asarray(vb)), \
                f"{tag} {coords} {f}: {va} != {vb}"
    assert ra.axes == rb.axes


# ------------------------------------------------------- union dispatch
class TestUnionDispatch:
    @pytest.mark.parametrize("interval_kernel", [True, False])
    def test_union_bitwise_equals_grouped_synth(self, interval_kernel):
        """All nine families x 2-/3-tier x workloads, timelines on, fused
        and unfused: the ONE-program union path is bitwise the grouped
        per-family path."""
        kw = dict(workloads=["gups", "btree"], machines=MACHS, k=K, T=T,
                  n=N, timelines=True, use_interval_kernel=interval_kernel)
        with scan_engine.count_dispatches() as cu:
            ru = experiment.sweep(ALL_FAMILIES, dispatch="union", **kw)
        with scan_engine.count_dispatches() as cg:
            rg = experiment.sweep(ALL_FAMILIES, dispatch="grouped", **kw)
        assert cu.count == 1 and cu.last["dispatch"] == "union"
        assert cg.count == len(ALL_FAMILIES)
        _assert_bitwise(ru, rg, f"synth ik={interval_kernel}")

    def test_union_bitwise_equals_grouped_trace(self):
        trace = workloads.make("silo-tpcc", T=T, n=N)
        u = uniform_field(T, N, seed=7)
        kw = dict(trace=trace, machines=MACHS, k=K, sample_u=u,
                  timelines=True)
        ru = experiment.sweep(ALL_FAMILIES, dispatch="union", **kw)
        rg = experiment.sweep(ALL_FAMILIES, dispatch="grouped", **kw)
        _assert_bitwise(ru, rg, "trace")

    def test_auto_unions_mixed_and_groups_single_family(self):
        kw = dict(workloads=["gups"], machines=["pmem-large"], k=K, T=T,
                  n=N)
        with scan_engine.count_dispatches() as ctr:
            experiment.sweep(["hemem", "arms"], **kw)
        assert ctr.last["dispatch"] == "union"
        with scan_engine.count_dispatches() as ctr:
            experiment.sweep([HeMemSpec.make(), HeMemSpec.make(
                hot_threshold=2)], **kw)
        # one family (same treedef): plain stacked path, no union overhead
        assert ctr.count == 1 and ctr.last["dispatch"] == "grouped"

    def test_union_state_is_max_not_sum(self):
        """The slot union buckets by (shape, dtype) with per-bucket max
        multiplicity: far fewer slots than the sum of member leaves."""
        specs = [experiment.policy_spec(p) for p in ALL_FAMILIES]
        mach_all, _ = machine_spec.lane_stack(
            [machines.get(m) for m in MACHS], N, K)
        uspecs = fabric.build_union(specs, N, K, mach_all)
        members = uspecs[0].members
        assert len(members) == len(ALL_FAMILIES)
        total_leaves = sum(len(m.slot_ids) for m in members)
        assert len(uspecs[0].slot_defs) < total_leaves
        # every member's slots fit the union layout, and no member maps
        # two of its leaves onto the same slot
        for m in members:
            assert all(0 <= i < len(uspecs[0].slot_defs)
                       for i in m.slot_ids)
            assert len(set(m.slot_ids)) == len(m.slot_ids)

    def test_same_family_different_meta_get_separate_branches(self):
        """Member identity keys on the spec TREEDEF: two HeMems with
        different migration_limit meta cannot share a switch branch."""
        a, b = HeMemSpec.make(), HeMemSpec.make(migration_limit=4)
        kw = dict(workloads=["gups"], machines=["pmem-large"], k=K, T=T,
                  n=N)
        ru = experiment.sweep([a, b, "jenga"], dispatch="union", **kw)
        rg = experiment.sweep([a, b, "jenga"], dispatch="grouped", **kw)
        _assert_bitwise(ru, rg, "meta-variant")

    def test_bad_dispatch_value_raises(self):
        with pytest.raises(ValueError, match="dispatch"):
            experiment.sweep(["hemem"], workloads=["gups"], k=K, T=T, n=N,
                             dispatch="fused")


# ---------------------------------------------- sharding (single device)
class TestShardingSingleDevice:
    @pytest.mark.parametrize("interval_kernel", [True, False])
    def test_mesh1_bitwise_equals_plain(self, interval_kernel):
        kw = dict(workloads=["gups", "btree"], machines=MACHS, k=K, T=T,
                  n=N, timelines=True, use_interval_kernel=interval_kernel)
        pols = ["arms", "hemem", "tpp", "oracle", "jenga"]
        base = experiment.sweep(pols, **kw)
        m1 = experiment.sweep(pols, mesh=1, **kw)
        _assert_bitwise(base, m1, f"mesh1 ik={interval_kernel}")

    def test_mesh1_trace_mode(self):
        trace = workloads.make("gups", T=T, n=N)
        kw = dict(trace=trace, machines=MACHS, k=K)
        pols = ["hemem", "tierbpf", "memtis"]
        _assert_bitwise(experiment.sweep(pols, **kw),
                        experiment.sweep(pols, mesh=1, **kw), "trace-mesh1")

    def test_padded_lanes_dropped_before_labeling(self):
        """Satellite regression: a lane count that is NOT a multiple of
        the padding unit keeps the same result shape, labels and values
        as the unpadded run — padded lanes never leak into the grid."""
        pols = ["arms", "hemem", "tpp"]                 # 3*2*2 = 12 lanes
        kw = dict(workloads=["gups", "btree"], machines=MACHS, k=K, T=T,
                  n=N)
        base = experiment.sweep(pols, **kw)
        for mult in (5, 8):                             # 12 % mult != 0
            padded = experiment.sweep(pols, mesh=1, _pad_multiple=mult,
                                      **kw)
            assert padded.shape == base.shape
            assert padded.axes == base.axes
            assert len(padded.grid) == len(base.grid)
            _assert_bitwise(base, padded, f"pad_multiple={mult}")

    def test_dispatch_record_reports_logical_and_padded_lanes(self):
        with scan_engine.count_dispatches() as ctr:
            experiment.sweep(["arms", "hemem"], workloads=["gups"],
                             machines=MACHS, k=K, T=T, n=N, mesh=1,
                             _pad_multiple=3)
        assert ctr.last["lanes"] == 4                   # logical
        assert ctr.last["padded_lanes"] == 6            # ceil(4/3)*3
        assert ctr.last["mesh"] == 1

    def test_search_mesh_is_bitwise_and_logical_lane_intervals(self):
        """Satellite: SearchResult.lane_intervals counts LOGICAL lanes, so
        ASHA/CE compute curves are identical at any mesh size."""
        trace = workloads.make("gups", T=T, n=N)
        plain = search.run("hemem", "asha", trace=trace, k=K, budget=6)
        meshy = search.run("hemem", "asha", trace=trace, k=K, budget=6,
                           mesh=1)
        assert plain.best_config == meshy.best_config
        assert plain.lane_intervals == meshy.lane_intervals
        assert [r.lane_intervals for r in plain.rounds] == \
            [r.lane_intervals for r in meshy.rounds]
        assert float(plain.best_result.exec_time_s) == \
            float(meshy.best_result.exec_time_s)

    def test_mesh_too_big_raises(self):
        import jax
        with pytest.raises(ValueError, match="device"):
            fabric.resolve_mesh(jax.device_count() + 1)


# --------------------------------------------------- dispatch accounting
class TestCountDispatches:
    def test_counters_nest_without_racing(self):
        trace = workloads.make("gups", T=T, n=N)
        with scan_engine.count_dispatches() as outer:
            experiment.sweep(["hemem"], trace=trace, k=K)
            with scan_engine.count_dispatches() as inner:
                experiment.sweep(["hemem"], trace=trace, k=K)
            experiment.sweep(["hemem"], trace=trace, k=K)
        assert inner.count == 1
        assert outer.count == 3
        assert len(outer.records) == 3
        assert outer.last["lanes"] == 1

    def test_counter_sees_nothing_outside_its_scope(self):
        trace = workloads.make("gups", T=T, n=N)
        with scan_engine.count_dispatches() as ctr:
            pass
        experiment.sweep(["hemem"], trace=trace, k=K)
        assert ctr.count == 0 and ctr.last == {}
