"""Unit + property tests for the ARMS core (paper §4, Algorithms 1-2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import (MODE_HISTORY, MODE_RECENCY, ARMSConfig, arms_step,
                        init_state, pht_update)
from repro.core import classifier, costbenefit, scheduler
from repro.core.state import init_pht

CFG = ARMSConfig()


# ---------------------------------------------------------------- classifier
class TestClassifier:
    def test_ewma_time_constants(self):
        """EWMA_s (alpha=0.7) must settle much faster than EWMA_l (alpha=0.1).

        Pins the DESIGN.md formula-note semantics: alpha weights the NEW
        sample (prose), not the old average (Alg. 1 as printed).
        """
        st_ = init_state(4, CFG)
        for _ in range(3):
            st_ = classifier.update_scores(st_, jnp.full(4, 10.0), CFG,
                                           jnp.int32(MODE_HISTORY))
        # after 3 steps of x=10: ewma_s = 10*(1-0.3^3) = 9.73, ewma_l = 2.71
        np.testing.assert_allclose(st_.ewma_s, 10 * (1 - 0.3**3), rtol=1e-5)
        np.testing.assert_allclose(st_.ewma_l, 10 * (1 - 0.9**3), rtol=1e-5)
        assert float(st_.ewma_s[0]) > float(st_.ewma_l[0])

    def test_score_is_weighted_sum_and_mode_dependent(self):
        st_ = init_state(2, CFG)
        st_h = classifier.update_scores(st_, jnp.array([5.0, 0.0]), CFG,
                                        jnp.int32(MODE_HISTORY))
        st_r = classifier.update_scores(st_, jnp.array([5.0, 0.0]), CFG,
                                        jnp.int32(MODE_RECENCY))
        ws, wl = CFG.w_s_history, CFG.w_l_history
        np.testing.assert_allclose(
            st_h.score, ws * st_h.ewma_s + wl * st_h.ewma_l, rtol=1e-6)
        # recency mode weights the (larger) short EWMA more -> higher score
        assert float(st_r.score[0]) > float(st_h.score[0])

    def test_topk_mask_exact_k(self):
        score = jnp.arange(100, dtype=jnp.float32)
        mask, idx = classifier.topk_hot_mask(score, 10)
        assert int(mask.sum()) == 10
        assert bool(mask[90:].all())

    def test_hot_age_counts_consecutive_topk(self):
        st_ = init_state(4, CFG)
        hot = jnp.array([True, True, False, False])
        st_ = classifier.update_hot_age(st_, hot)
        st_ = classifier.update_hot_age(st_, hot)
        st_ = classifier.update_hot_age(
            st_, jnp.array([True, False, True, False]))
        assert st_.hot_age.tolist() == [3, 0, 1, 0]


# ----------------------------------------------------------------------- PHT
class TestPHT:
    def test_no_alarm_on_stationary_signal(self):
        s = init_pht()
        rng = np.random.default_rng(1)
        for _ in range(200):
            s, alarm, _ = pht_update(s, 0.3 + 0.005 * rng.standard_normal(),
                                     CFG)
            assert not bool(alarm)

    def test_alarm_on_step_increase_then_reset(self):
        s = init_pht()
        for _ in range(50):
            s, alarm, _ = pht_update(s, 0.2, CFG)
            assert not bool(alarm)
        fired = False
        for i in range(50):
            s, alarm, _ = pht_update(s, 0.8, CFG)
            if bool(alarm):
                fired = True
                assert int(s.n) == 0  # reset after alarm
                break
        assert fired and i < 5  # detects within a few intervals

    def test_no_alarm_on_decrease(self):
        """PHT is configured for increase detection only (hot-set change =>
        MORE slow-tier traffic)."""
        s = init_pht()
        for _ in range(50):
            s, alarm, _ = pht_update(s, 0.8, CFG)
        for _ in range(50):
            s, alarm, _ = pht_update(s, 0.1, CFG)
            assert not bool(alarm)


# -------------------------------------------------------------- cost/benefit
class TestCostBenefit:
    def _steady(self, n=64, k=8, hot=None, intervals=6):
        hot = hot if hot is not None else range(k)
        st_ = init_state(n, CFG)
        counts = np.zeros(n)
        for p in hot:
            counts[p] = 50.0
        for _ in range(intervals):
            st_, plan = arms_step(st_, jnp.asarray(counts), 0.2, 0.1,
                                  cfg=CFG, k=k)
        return st_, plan

    def test_one_hit_wonder_never_promoted(self):
        """A single burst (hot for 1 interval) fails the hot_age>=2 filter."""
        n, k = 64, 8
        st_ = init_state(n, CFG)
        burst = np.zeros(n)
        burst[10] = 100.0
        st_, plan = arms_step(st_, jnp.asarray(burst), 0.2, 0.1, cfg=CFG, k=k)
        assert int(plan.count) == 0
        st_, plan = arms_step(st_, jnp.zeros(n), 0.2, 0.1, cfg=CFG, k=k)
        assert int(plan.count) == 0
        assert not bool(st_.in_fast[10])

    def test_sustained_hot_pages_promoted(self):
        st_, _ = self._steady()
        assert int(st_.in_fast[:8].sum()) == 8

    def test_cost_gate_blocks_marginal_promotions(self):
        """If migration cost dwarfs the latency benefit, nothing moves."""
        expensive = ARMSConfig(init_promo_cost_us=1e12,
                               init_demo_cost_us=1e12)
        n, k = 64, 8
        st_ = init_state(n, expensive)
        counts = np.zeros(n)
        counts[:k] = 50.0
        for _ in range(6):
            st_, plan = arms_step(st_, jnp.asarray(counts), 0.2, 0.1,
                                  cfg=expensive, k=k)
        assert int(st_.in_fast.sum()) == 0

    def test_free_slot_promotions_have_no_victim(self):
        n, k = 64, 8
        st_ = init_state(n, CFG)
        counts = np.zeros(n)
        counts[:4] = 50.0
        plans = []
        for _ in range(4):
            st_, plan = arms_step(st_, jnp.asarray(counts), 0.2, 0.1,
                                  cfg=CFG, k=k)
            plans.append(plan)
        executed = [p for p in plans if int(p.count) > 0]
        assert executed
        for p in executed:
            d = np.asarray(p.demote)[np.asarray(p.valid)]
            assert (d == -1).all()  # fast tier had free slots

    def test_victim_is_coldest(self):
        """When the fast tier is full, the demoted page is the coldest one."""
        n, k = 32, 4
        st_ = init_state(n, CFG)
        counts = np.zeros(n)
        counts[:4] = [60, 50, 40, 30.0]
        for _ in range(5):
            st_, _ = arms_step(st_, jnp.asarray(counts), 0.2, 0.1, cfg=CFG,
                               k=k)
        assert int(st_.in_fast[:4].sum()) == 4
        # page 10 becomes hottest; coldest resident (page 3) must be evicted
        counts2 = counts.copy()
        counts2[10] = 100.0
        counts2[3] = 0.0
        for _ in range(6):
            st_, plan = arms_step(st_, jnp.asarray(counts2), 0.2, 0.1,
                                  cfg=CFG, k=k)
        assert bool(st_.in_fast[10])
        assert not bool(st_.in_fast[3])


# ------------------------------------------------------------------ scheduler
class TestScheduler:
    def test_bs_formula(self):
        """BS = max(1, (BW_max-BW_app)/BW_max * BS_max), clamped."""
        assert int(scheduler.batch_size(0.0, 1.0, 64)) == 64
        assert int(scheduler.batch_size(1.0, 1.0, 64)) == 1
        assert int(scheduler.batch_size(0.5, 1.0, 64)) == 32
        assert int(scheduler.batch_size(2.0, 1.0, 64)) == 1  # over-saturated

    def test_plan_respects_bandwidth_throttle(self):
        """At high app bandwidth, migrations trickle instead of bursting."""
        n, k = 256, 64
        st_ = init_state(n, CFG)
        counts = np.zeros(n)
        counts[:k] = 50.0
        # app uses ~98.5% of bandwidth -> BS = 1
        for i in range(3):
            st_, plan = arms_step(st_, jnp.asarray(counts), 0.2, 0.985,
                                  cfg=CFG, k=k)
            assert int(plan.count) <= 1
        assert int(st_.in_fast.sum()) <= 3

    def test_priority_hottest_first(self):
        """The hottest eligible candidate occupies plan slot 0."""
        n, k = 64, 8
        st_ = init_state(n, CFG)
        counts = np.zeros(n)
        counts[:8] = np.arange(80, 0, -10)
        for _ in range(3):
            st_, plan = arms_step(st_, jnp.asarray(counts), 0.2, 0.99,
                                  cfg=CFG, k=k)  # BS=1
            if int(plan.count) == 1:
                assert int(plan.promote[0]) == 0  # page 0 is hottest
                break
        else:
            pytest.fail("no promotion happened")


# ------------------------------------------------------- property (hypothesis)
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.integers(16, 96),
       kfrac=st.floats(0.1, 0.9),
       intervals=st.integers(1, 12))
def test_invariants_random_traces(seed, n, kfrac, intervals):
    """System invariants hold for arbitrary access traces:

    I1: fast-tier occupancy never exceeds k.
    I2: plans never promote an already-fast page nor demote a non-fast page.
    I3: plan count <= batch_size <= bs_max.
    I4: promote/demote indices are disjoint within a plan.
    """
    k = max(1, int(n * kfrac))
    rng = np.random.default_rng(seed)
    st_ = init_state(n, CFG)
    for _ in range(intervals):
        counts = rng.poisson(rng.uniform(0, 30), n).astype(np.float64)
        before_fast = np.asarray(st_.in_fast)
        st_, plan = arms_step(st_, jnp.asarray(counts),
                              float(rng.uniform(0, 1)),
                              float(rng.uniform(0, 1)), cfg=CFG, k=k)
        valid = np.asarray(plan.valid)
        promote = np.asarray(plan.promote)[valid]
        demote = np.asarray(plan.demote)[valid]
        # I2
        assert not before_fast[promote].any()
        real_demote = demote[demote >= 0]
        assert before_fast[real_demote].all()
        # I4
        assert not set(promote.tolist()) & set(real_demote.tolist())
        # I3
        assert int(plan.count) == valid.sum() <= int(plan.batch_size) \
            <= CFG.bs_max
        # I1
        assert int(st_.in_fast.sum()) <= k


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), steps=st.integers(1, 60))
def test_ewma_bounded_by_observed_range(seed, steps):
    """EWMAs stay within [0, max(x)] for non-negative inputs."""
    rng = np.random.default_rng(seed)
    xs = rng.uniform(0, 100, (steps, 8))
    st_ = init_state(8, CFG)
    for x in xs:
        st_ = classifier.update_scores(st_, jnp.asarray(x), CFG,
                                       jnp.int32(MODE_HISTORY))
    hi = xs.max()
    assert (np.asarray(st_.ewma_s) <= hi + 1e-4).all()
    assert (np.asarray(st_.ewma_l) <= hi + 1e-4).all()
    assert (np.asarray(st_.ewma_s) >= 0).all()


def test_recency_mode_doubles_sampling_and_policy_rate():
    from repro.core import policy_every, sampling_period
    assert int(sampling_period(jnp.int32(MODE_RECENCY))) * 2 == \
        int(sampling_period(jnp.int32(MODE_HISTORY)))
    assert int(policy_every(jnp.int32(MODE_RECENCY))) < \
        int(policy_every(jnp.int32(MODE_HISTORY)))
