"""WorkloadSpec protocol: probs invariants, combinator laws, and bitwise
synth-vs-materialize / cross-engine equivalence.

The acceptance bar for the trace-synthesis path: for every named workload,
the scan engine synthesizing ``true = work * probs`` on device must be
BITWISE identical to replaying the host-materialized ``[T, n]`` f32 trace
(same CRN noise), and the numpy reference engine on that trace must agree
exactly on migration counts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.baselines.arms_policy import ARMSSpec
from repro.baselines.hemem import HeMemPolicy, HeMemSpec
from repro.simulator import scan_engine, tuning, workload_spec, workloads
from repro.simulator.engine import oracle_topk_masks, run
from repro.simulator.machine import PMEM_LARGE
from repro.simulator.sampling import synth_noise_field

T, N, K = 96, 256, 32
NAMES = list(workload_spec.NAMED_WORKLOADS)


@jax.jit
def _step_jit(spec, state, t):
    return type(spec).step(spec, state, t)


def _probs_seq(spec, T_, n, seed=0, ts=None):
    """{t: probs[n]} from the pure step protocol (jitted once per treedef)."""
    state = spec.init(n, jax.random.PRNGKey(seed))
    out = {}
    for t in range(T_):
        state, p = _step_jit(spec, state, jnp.int32(t))
        if ts is None or t in ts:
            out[t] = np.asarray(p)
    return out


class TestSpecProperties:
    @pytest.mark.parametrize("name", NAMES)
    def test_probs_nonneg_and_sum_to_one(self, name):
        spec = workload_spec.named(name, T=60)
        for t, p in _probs_seq(spec, 60, 128, ts={0, 1, 29, 30, 59}).items():
            assert (p >= 0).all(), (name, t)
            np.testing.assert_allclose(p.sum(), 1.0, atol=1e-4)

    def test_composed_probs_sum_to_one(self):
        spec = workload_spec.mix(
            [workload_spec.drift(workload_spec.named("xsbench"), 1.5),
             workload_spec.phases([workload_spec.named("gups"),
                                   workload_spec.named("silo-tpcc")], [20])],
            [0.3, 0.7])
        for _, p in _probs_seq(spec, 45, 128, ts={0, 19, 20, 44}).items():
            assert (p >= 0).all()
            np.testing.assert_allclose(p.sum(), 1.0, atol=1e-4)

    def test_deterministic_and_f32(self):
        a = workloads.make("gups", T=40, n=128)
        b = workloads.make("gups", T=40, n=128)
        assert a.dtype == np.float32
        np.testing.assert_array_equal(a, b)

    def test_gups_hot_set_relocates(self):
        spec = workload_spec.gups_spec(shift_every=10)
        ps = _probs_seq(spec, 25, 128, ts={9, 10})
        assert not np.array_equal(ps[9], ps[10])  # event at t=10

    def test_btree_reshuffles_exactly_once(self):
        """Legacy one-shot semantics: a reshuffle at T // 2 and NOTHING
        after, even when T > 2 * (T // 2) (odd T)."""
        tr = workloads.make("btree", T=101, n=64)
        assert np.array_equal(tr[0], tr[49])          # stable before
        assert not np.array_equal(tr[49], tr[50])     # reshuffle at 50
        assert np.array_equal(tr[50], tr[100])        # stable after (t=100!)


class TestCombinators:
    def test_phases_hits_boundaries(self):
        gups = workload_spec.named("gups")
        tpcc = workload_spec.named("silo-tpcc")
        combo = workload_spec.phases([gups, tpcc], [30])
        pc = _probs_seq(combo, 60, 128, ts={0, 29, 30, 59})
        pg = _probs_seq(gups, 60, 128, ts={0, 29})
        pt = _probs_seq(tpcc, 60, 128, ts={30, 59})
        np.testing.assert_allclose(pc[29], pg[29], rtol=1e-5, atol=1e-9)
        np.testing.assert_allclose(pc[30], pt[30], rtol=1e-5, atol=1e-9)
        assert not np.allclose(pc[29], pc[30])

    def test_phases_validates_boundaries(self):
        g = workload_spec.named("gups")
        with pytest.raises(ValueError):
            workload_spec.phases([g, g], [10, 20])
        with pytest.raises(ValueError):
            workload_spec.phases([g, g, g], [20, 10])

    def test_mix_weights_normalize(self):
        a = workload_spec.named("gups")
        b = workload_spec.named("silo-ycsb")
        m1 = workload_spec.mix([a, b], [2.0, 2.0])
        m2 = workload_spec.mix([a, b], [1.0, 1.0])
        np.testing.assert_array_equal(np.asarray(m1.weight),
                                      np.asarray(m2.weight))
        pm = _probs_seq(m1, 3, 128, ts={2})[2]
        pa = _probs_seq(a, 3, 128, ts={2})[2]
        pb = _probs_seq(b, 3, 128, ts={2})[2]
        np.testing.assert_allclose(pm, 0.5 * pa + 0.5 * pb,
                                   rtol=1e-4, atol=1e-9)
        with pytest.raises(ValueError):
            workload_spec.mix([a, b], [1.0])
        with pytest.raises(ValueError):
            workload_spec.mix([a, b], [0.0, 0.0])

    def test_scale_multiplies_work(self):
        a = workload_spec.named("gups")
        s = workload_spec.scale(a, 2.5)
        st_ = s.init(64, jax.random.PRNGKey(0))
        assert float(s.work_of(st_, jnp.int32(0))) == pytest.approx(
            2.5 * float(a.work_of(a.init(64, jax.random.PRNGKey(0)),
                                  jnp.int32(0))), rel=1e-6)

    def test_drift_rolls_distribution(self):
        a = workload_spec.named("xsbench")     # stationary -> drift visible
        d = workload_spec.drift(a, 3.0)
        pa = _probs_seq(a, 11, 128, ts={10})[10]
        pd = _probs_seq(d, 11, 128, ts={10})[10]
        np.testing.assert_allclose(pd, np.roll(pa, 30), rtol=1e-5, atol=1e-9)

    def test_mixed_structure_specs_stack(self):
        """Different component counts pad and sweep in one dispatch."""
        combo = workload_spec.phases([workload_spec.named("gups"),
                                      workload_spec.named("liblinear")], [40])
        rows = scan_engine.sweep_workloads(
            [combo, workload_spec.named("btree", T=80)],
            PMEM_LARGE, K, 80, N)
        assert len(rows) == 2
        assert scan_engine.last_dispatch["lanes"] == 2
        assert scan_engine.last_dispatch["synth"] is True


class TestDegenerateKnobs:
    """Legacy generators crashed at hot_frac=1.0 (gups divided by n-k_hot)
    and window_frac=1.0 (silo_tpcc took % (n-w)); the spec knobs clamp."""

    def test_gups_full_hot_frac(self):
        tr = workloads.gups(20, n=64, hot_frac=1.0)
        assert np.isfinite(tr).all() and (tr >= 0).all()
        np.testing.assert_allclose(tr.sum(axis=1),
                                   workloads.DEFAULT_WORK, rtol=1e-4)
        # every page hot == uniform (not a concentrated leftover page)
        np.testing.assert_allclose(tr, workloads.DEFAULT_WORK / 64,
                                   rtol=1e-4)

    def test_tpcc_full_window_frac(self):
        tr = workloads.silo_tpcc(20, n=64, window_frac=1.0)
        assert np.isfinite(tr).all() and (tr >= 0).all()
        np.testing.assert_allclose(tr.sum(axis=1),
                                   workloads.DEFAULT_WORK, rtol=1e-4)

    @pytest.mark.parametrize("frac", [0.0, 1.0])
    def test_extreme_fracs_all_kinds(self, frac):
        specs = [workload_spec.gups_spec(hot_frac=frac),
                 workload_spec.xsbench_spec(hot_frac=frac),
                 workload_spec.tpcc_spec(window_frac=frac),
                 workload_spec.gapbs_spec(boost_frac=frac)]
        for sp in specs:
            tr = sp.materialize(10, 32)
            assert np.isfinite(tr).all() and (tr >= 0).all()


class TestSynthMaterializeEquivalence:
    """The acceptance bar: synth == materialized replay, bitwise, for every
    named workload — in the scan engine and against the numpy engine."""

    @pytest.mark.parametrize("name", NAMES)
    def test_bitwise_across_paths(self, name):
        wl = workload_spec.named(name, T=T)
        u = synth_noise_field(T, N, seed=7)
        synth = scan_engine.simulate_workload(
            HeMemSpec.make(), wl, PMEM_LARGE, K, T, N, sim_seed=7)
        trace = wl.materialize(T, N)
        assert trace.dtype == np.float32
        mat = scan_engine.simulate(HeMemSpec.make(), trace, PMEM_LARGE, K,
                                   sample_u=u)
        # scan engine: synthesized and materialized replays are BITWISE one
        assert synth.exec_time_s == mat.exec_time_s
        assert (synth.promotions, synth.demotions, synth.wasteful) == \
            (mat.promotions, mat.demotions, mat.wasteful)
        assert synth.hot_recall == mat.hot_recall
        assert synth.fast_hit_frac == mat.fast_hit_frac
        np.testing.assert_array_equal(synth.timeline_promotions,
                                      mat.timeline_promotions)
        np.testing.assert_array_equal(synth.timeline_slow_bw,
                                      mat.timeline_slow_bw)
        # numpy reference engine on the same trace + CRN field: exact counts
        ref = run(HeMemPolicy(), trace, PMEM_LARGE, K, sample_u=u)
        assert (synth.promotions, synth.demotions, synth.wasteful) == \
            (ref.promotions, ref.demotions, ref.wasteful)
        np.testing.assert_allclose(synth.exec_time_s, ref.exec_time_s,
                                   rtol=1e-4)

    def test_arms_synth_matches_materialized(self):
        wl = workload_spec.named("gups", T=T)
        u = synth_noise_field(T, N, seed=3)
        synth = scan_engine.simulate_workload(
            ARMSSpec.make(), wl, PMEM_LARGE, K, T, N, sim_seed=3)
        mat = scan_engine.simulate(ARMSSpec.make(), wl.materialize(T, N),
                                   PMEM_LARGE, K, sample_u=u)
        assert synth.exec_time_s == mat.exec_time_s
        assert (synth.promotions, synth.demotions, synth.wasteful) == \
            (mat.promotions, mat.demotions, mat.wasteful)
        np.testing.assert_array_equal(synth.timeline_mode, mat.timeline_mode)

    def test_device_oracle_matches_host_tie_rule(self):
        rng = np.random.default_rng(0)
        for _ in range(20):
            x = rng.integers(0, 4, size=64).astype(np.float32)  # many ties
            k = int(rng.integers(1, 64))
            host = oracle_topk_masks(x[None], k)[0]
            dev = np.asarray(scan_engine._topk_mask(jnp.asarray(x), k))
            np.testing.assert_array_equal(host, dev)
            assert host.sum() == k

    @settings(max_examples=30, deadline=None)
    @given(st.integers(2, 80), st.integers(0, 2 ** 31 - 1))
    def test_oracle_tie_rule_property(self, n, seed):
        rng = np.random.default_rng(seed)
        x = rng.integers(0, max(2, n // 4), size=n).astype(np.float32)
        k = int(rng.integers(1, n + 1))
        host = oracle_topk_masks(x[None], k)[0]
        dev = np.asarray(scan_engine._topk_mask(jnp.asarray(x), k))
        np.testing.assert_array_equal(host, dev)


class TestWorkloadSweeps:
    def test_lane_matches_single_run(self):
        """Lane (w, b) of a W x B sweep == the standalone synth run."""
        wls = [workload_spec.named("gups", T=T),
               workload_spec.named("silo-tpcc", T=T)]
        cfgs = [dict(hot_threshold=4), dict(hot_threshold=16)]
        grid = scan_engine.sweep_workload_configs(
            HeMemSpec.make, cfgs, wls, PMEM_LARGE, K, T, N, sim_seed=5)
        assert scan_engine.last_dispatch["lanes"] == 4
        assert scan_engine.last_dispatch["workloads"] == 2
        assert scan_engine.last_dispatch["configs"] == 2
        single = scan_engine.simulate_workload(
            HeMemSpec.make(hot_threshold=16), wls[1], PMEM_LARGE, K, T, N,
            sim_seed=5)
        lane = grid[1][1]
        assert lane.exec_time_s == single.exec_time_s
        assert lane.promotions == single.promotions

    def test_sweep_never_materializes(self):
        before = workload_spec.MATERIALIZE_CALLS
        scan_engine.sweep_workload_configs(
            HeMemSpec.make, [dict(), dict(hot_threshold=4)],
            [workload_spec.named("gups", T=40)], PMEM_LARGE, 16, 40, 128)
        assert workload_spec.MATERIALIZE_CALLS == before

    def test_tune_workload_lanes(self):
        out = tuning.tune("hemem", None, PMEM_LARGE, K, budget=3,
                          sim_seed=2, workloads=["gups", "xsbench"],
                          T=64, n=N)
        assert set(out) == {"gups", "xsbench"}
        lanes = scan_engine.last_dispatch["lanes"]
        assert lanes == 2 * len(out["gups"][2])
        for _nm, (best_cfg, best_res, rows) in out.items():
            assert best_res.exec_time_s == min(r.exec_time_s
                                               for _, r in rows)
            assert best_cfg == rows[0][0]

    def test_tune_disambiguates_duplicate_labels(self):
        """Two combinator scenarios sharing an auto-label must not
        overwrite each other's rows in the result dict."""
        a = workload_spec.phases([workload_spec.named("gups"),
                                  workload_spec.named("silo-tpcc")], [10])
        b = workload_spec.phases([workload_spec.named("gups"),
                                  workload_spec.named("silo-tpcc")], [30])
        out = tuning.tune("hemem", None, PMEM_LARGE, 16, budget=2,
                          workloads=[a, b], T=40, n=128)
        assert len(out) == 2

    def test_tune_rejects_trace_plus_workloads(self):
        with pytest.raises(ValueError):
            tuning.tune("hemem", np.zeros((4, 8)), PMEM_LARGE, 2,
                        workloads=["gups"], T=4, n=8)
        with pytest.raises(ValueError):
            tuning.tune("hemem", None, PMEM_LARGE, 2, workloads=["gups"])
