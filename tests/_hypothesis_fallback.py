"""Fallback shim for environments without ``hypothesis``.

``tests/test_core.py`` and ``tests/test_substrate.py`` use hypothesis for
property tests.  When the library is missing we still want the rest of each
module to collect and run, so this shim provides ``given``/``settings``/``st``
stand-ins under which every property test is skipped cleanly.

Install the real thing with ``pip install -r requirements-dev.txt``.
"""
from __future__ import annotations

import pytest

try:  # pragma: no cover - trivial re-export when hypothesis is present
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Inert placeholder for any ``st.<name>(...)`` strategy call."""

        def __getattr__(self, name):
            return _Strategy()

        def __call__(self, *args, **kwargs):
            return _Strategy()

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
