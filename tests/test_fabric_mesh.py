"""Mesh > 1 half of the fabric equivalence suite (see tests/test_fabric.py).

Splitting the host platform into virtual devices requires
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` BEFORE jax
initializes, which the main pytest process has long since done — so the
actual assertions run in one subprocess executing ``_MESH_SCRIPT``:
mesh sizes {2, 8} (with a lane count that is NOT a multiple of either)
must be bitwise-identical to the unsharded sweep, in synth and trace
modes, under the default union dispatch.
"""
import os
import subprocess
import sys

_MESH_SCRIPT = r"""
import numpy as np, dataclasses, jax
assert jax.device_count() == 8, jax.device_count()
from repro.simulator import experiment, scan_engine, workloads
from repro.simulator.engine import SimResult
from repro.simulator.sampling import uniform_field

T, N, K = 32, 128, 16
FIELDS = [f.name for f in dataclasses.fields(SimResult) if f.name != "name"]

def check(ra, rb, tag):
    assert ra.axes == rb.axes, tag
    for (coords, a), (_, b) in zip(ra.items(), rb.items()):
        for f in FIELDS:
            va, vb = getattr(a, f), getattr(b, f)
            if va is None and vb is None:
                continue
            assert np.array_equal(np.asarray(va), np.asarray(vb)), \
                (tag, coords, f)

# mixed families, 2- and 3-tier machines; 5*2*2 = 20 lanes — not a
# multiple of 8, so mesh=8 exercises the pad-and-drop path.
pols = ["arms", "hemem", "tpp", "oracle", "jenga"]
kw = dict(workloads=["gups", "btree"], machines=["pmem-large",
          "dram-cxl-pmem"], k=K, T=T, n=N, timelines=True)
base = experiment.sweep(pols, **kw)
for D in (2, 8):
    with scan_engine.count_dispatches() as ctr:
        res = experiment.sweep(pols, mesh=D, **kw)
    assert ctr.count == 1 and ctr.last["mesh"] == D
    assert ctr.last["lanes"] == 20
    assert ctr.last["padded_lanes"] == -(-20 // D) * D
    check(base, res, f"synth mesh={D}")

trace = workloads.make("silo-tpcc", T=T, n=N)
u = uniform_field(T, N, seed=3)
kt = dict(trace=trace, machines=["pmem-large", "cxl-1hop"], k=K,
          sample_u=u)
bt = experiment.sweep(pols, **kt)
check(bt, experiment.sweep(pols, mesh=8, **kt), "trace mesh=8")
check(bt, experiment.sweep(pols, mesh="auto", **kt), "trace mesh=auto")
print("MESH-OK")
"""


def test_mesh_sharded_sweeps_bitwise_equal_in_subprocess():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep \
        + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH-OK" in proc.stdout
