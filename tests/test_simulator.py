"""Simulator + baseline-policy behaviour tests (paper §3, §7 analogues)."""
import numpy as np
import pytest

from repro.baselines.arms_policy import ARMSPolicy
from repro.baselines.hemem import HeMemPolicy
from repro.baselines.memtis import MemtisPolicy
from repro.baselines.static import AllSlowPolicy, OraclePolicy
from repro.baselines.tpp import TPPPolicy
from repro.simulator import workloads
from repro.simulator.engine import run
from repro.simulator.machine import NUMA, PMEM_LARGE, interval_time

T, N, K = 120, 512, 64


def _trace(name):
    return workloads.make(name, T=T, n=N)


class TestMachineModel:
    def test_fast_placement_is_faster(self):
        slow = interval_time(PMEM_LARGE, 0, 1e7, 0, 0).wall_s
        fast = interval_time(PMEM_LARGE, 1e7, 0, 0, 0).wall_s
        assert fast < slow

    def test_migration_traffic_costs_time(self):
        base = interval_time(PMEM_LARGE, 1e6, 1e7, 0, 0).wall_s
        loaded = interval_time(PMEM_LARGE, 1e6, 1e7, 200, 200).wall_s
        assert loaded > base

    def test_numa_has_milder_slow_tier(self):
        p = interval_time(PMEM_LARGE, 0, 1e7, 0, 0).wall_s
        m = interval_time(NUMA, 0, 1e7, 0, 0).wall_s
        assert m < p  # paper §7.3: higher far-memory bandwidth on NUMA


class TestWorkloads:
    @pytest.mark.parametrize("name", sorted(workloads.WORKLOADS))
    def test_trace_shape_and_work(self, name):
        tr = _trace(name)
        assert tr.shape == (T, N)
        assert (tr >= 0).all()
        busy = tr.sum(axis=1) > 0.5 * workloads.DEFAULT_WORK
        assert busy.mean() > 0.4  # most intervals carry full work

    def test_deterministic(self):
        a, b = _trace("gups"), _trace("gups")
        np.testing.assert_array_equal(a, b)


class TestEngine:
    def test_deterministic_runs(self):
        tr = _trace("btree")
        r1 = run(HeMemPolicy(), tr, PMEM_LARGE, K, seed=7)
        r2 = run(HeMemPolicy(), tr, PMEM_LARGE, K, seed=7)
        assert r1.exec_time_s == r2.exec_time_s
        assert r1.promotions == r2.promotions

    def test_all_slow_never_migrates(self):
        r = run(AllSlowPolicy(), _trace("gups"), PMEM_LARGE, K)
        assert r.promotions == r.demotions == 0
        assert r.fast_hit_frac == 0.0

    def test_oracle_is_best(self):
        tr = _trace("silo-ycsb")
        oracle = run(OraclePolicy(), tr, PMEM_LARGE, K)
        for pol in (HeMemPolicy(), MemtisPolicy(), TPPPolicy(), ARMSPolicy()):
            res = run(pol, tr, PMEM_LARGE, K)
            assert oracle.exec_time_s <= res.exec_time_s * 1.05

    def test_capacity_never_exceeded(self):
        class Greedy(HeMemPolicy):
            migration_limit = 10 ** 9

        tr = _trace("gups")
        r = run(Greedy(hot_threshold=1, cooling_threshold=1000,
                       migration_period=1), tr, PMEM_LARGE, K)
        # engine caps promotions at capacity: fast hits possible but bounded
        assert r.promotions <= T * K


class TestPaperBehaviours:
    """Qualitative behaviours from the paper's analysis (§3, §7).

    These run at the benchmark scale (n=1024+ pages) where the paper's
    pathologies manifest; the tiny scale above is for engine mechanics.
    """

    def test_arms_beats_default_hemem(self):
        """Fig. 7: ARMS > default HeMem (geomean over a workload subset)."""
        sp = []
        for wl in ("gups", "btree", "gapbs-bc"):
            tr = workloads.make(wl, T=250, n=1024)
            h = run(HeMemPolicy(), tr, PMEM_LARGE, 128)
            a = run(ARMSPolicy(), tr, PMEM_LARGE, 128)
            sp.append(h.exec_time_s / a.exec_time_s)
        assert float(np.exp(np.mean(np.log(sp)))) > 1.2

    def test_tpp_migrates_most(self):
        """Fig. 10: TPP performs an extremely high number of migrations."""
        tr = _trace("xsbench")
        tpp = run(TPPPolicy(), tr, PMEM_LARGE, K)
        arms = run(ARMSPolicy(), tr, PMEM_LARGE, K)
        assert tpp.promotions > 3 * arms.promotions

    def test_arms_few_wasteful_migrations(self):
        """§7.2: multi-round filtering + cost/benefit suppress waste."""
        tr = _trace("xsbench")
        tpp = run(TPPPolicy(), tr, PMEM_LARGE, K)
        arms = run(ARMSPolicy(), tr, PMEM_LARGE, K)
        assert arms.wasteful < 0.2 * max(tpp.wasteful, 1)

    def test_memtis_infrequent_cooling_on_tpcc(self):
        """§7.1: Memtis's static cooling period hurts 'latest' workloads."""
        tr = _trace("silo-tpcc")
        memtis = run(MemtisPolicy(), tr, PMEM_LARGE, K)
        arms = run(ARMSPolicy(), tr, PMEM_LARGE, K)
        assert arms.exec_time_s < memtis.exec_time_s

    def test_arms_detects_hotset_change(self):
        """Fig. 9: PHT flips ARMS into recency mode on a hot-set shift."""
        tr = workloads.make("gups", T=250, n=1024)  # shift at t=150
        arms = run(ARMSPolicy(), tr, PMEM_LARGE, 128)
        assert arms.timeline_mode.max() == 1       # recency mode entered
        assert arms.timeline_mode[140:180].max() == 1  # around the shift

    def test_arms_robust_across_ratios(self):
        """Fig. 13: ARMS >= default HeMem at every fast:slow ratio."""
        tr = _trace("gups")
        for ratio in (16, 8, 4, 2):
            k = max(1, N // ratio)
            h = run(HeMemPolicy(), tr, PMEM_LARGE, k)
            a = run(ARMSPolicy(), tr, PMEM_LARGE, k)
            assert a.exec_time_s <= h.exec_time_s * 1.05

    def test_arms_works_on_numa_machine(self):
        """§7.3: same policy, different hardware, no re-tuning."""
        tr = _trace("btree")
        h = run(HeMemPolicy(), tr, NUMA, K)
        a = run(ARMSPolicy(), tr, NUMA, K)
        assert a.exec_time_s <= h.exec_time_s * 1.02
