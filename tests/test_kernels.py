"""Per-kernel allclose sweeps: Pallas (interpret mode on CPU) vs ref.py
oracles, across shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.migrate.kernel import migrate_kernel
from repro.kernels.migrate.ref import migrate_ref
from repro.kernels.paged_attention.kernel import paged_attention_kernel
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.score_update.kernel import score_update_kernel
from repro.kernels.score_update.ref import score_update_ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32).astype(dtype)


class TestPagedAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,H,KV,dh,page,npp",
        [(2, 8, 4, 128, 16, 4),
         (1, 4, 4, 64, 32, 2),     # MHA, small head
         (3, 16, 2, 128, 8, 8),    # high GQA ratio
         (2, 8, 8, 128, 64, 2)])
    def test_vs_ref(self, B, H, KV, dh, page, npp, dtype):
        rng = np.random.default_rng(B * 1000 + H)
        P = npp * B + 3
        q = _rand(rng, (B, H, dh), dtype)
        k = _rand(rng, (P, page, KV, dh), dtype)
        v = _rand(rng, (P, page, KV, dh), dtype)
        tables = jnp.asarray(
            rng.choice(P, (B, npp), replace=False), jnp.int32)
        lens = jnp.asarray(rng.integers(1, npp * page + 1, B), jnp.int32)
        ref = paged_attention_ref(q, k, v, tables, lens)
        out = paged_attention_kernel(q, k, v, tables, lens, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOL[dtype])


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize(
        "B,S,H,KV,dh,bq,bk",
        [(2, 128, 4, 2, 64, 64, 64),
         (1, 256, 8, 8, 128, 128, 128),
         (1, 64, 4, 1, 128, 32, 32)])
    def test_vs_ref(self, B, S, H, KV, dh, bq, bk, causal, dtype):
        rng = np.random.default_rng(S + H)
        q = _rand(rng, (B, S, H, dh), dtype)
        k = _rand(rng, (B, S, KV, dh), dtype)
        v = _rand(rng, (B, S, KV, dh), dtype)
        ref = flash_attention_ref(q, k, v, causal=causal)
        out = flash_attention_kernel(q, k, v, causal=causal, bq=bq, bk=bk,
                                     interpret=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOL[dtype])

    def test_windowed(self):
        rng = np.random.default_rng(0)
        q = _rand(rng, (1, 128, 4, 64), jnp.float32)
        k = _rand(rng, (1, 128, 2, 64), jnp.float32)
        v = _rand(rng, (1, 128, 2, 64), jnp.float32)
        ref = flash_attention_ref(q, k, v, causal=True, window=32)
        out = flash_attention_kernel(q, k, v, causal=True, window=32,
                                     bq=32, bk=32, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestMigrate:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16,
                                       jnp.int32])
    @pytest.mark.parametrize("Ps,Pd,M,page,feat",
                             [(16, 8, 4, 16, 128),
                              (4, 4, 4, 8, 256),
                              (32, 32, 12, 64, 128)])
    def test_vs_ref(self, Ps, Pd, M, page, feat, dtype):
        rng = np.random.default_rng(Ps + M)
        if dtype == jnp.int32:
            src = jnp.asarray(rng.integers(0, 100, (Ps, page, feat)),
                              jnp.int32)
            dst = jnp.asarray(rng.integers(0, 100, (Pd, page, feat)),
                              jnp.int32)
        else:
            src = _rand(rng, (Ps, page, feat), dtype)
            dst = _rand(rng, (Pd, page, feat), dtype)
        src_idx = jnp.asarray(rng.choice(Ps, M, replace=False), jnp.int32)
        dst_idx = jnp.asarray(rng.choice(Pd, M, replace=False), jnp.int32)
        valid = jnp.asarray(rng.random(M) < 0.7)
        ref = migrate_ref(src, dst, src_idx, dst_idx, valid)
        out = migrate_kernel(src, dst, src_idx, dst_idx, valid,
                             interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_no_valid_entries_is_noop(self):
        rng = np.random.default_rng(1)
        src = _rand(rng, (4, 8, 128), jnp.float32)
        dst = _rand(rng, (4, 8, 128), jnp.float32)
        idx = jnp.zeros(3, jnp.int32)
        out = migrate_kernel(src, dst, idx, idx, jnp.zeros(3, bool),
                             interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(dst))


class TestScoreUpdate:
    @pytest.mark.parametrize("n", [17, 4096, 10_000])
    def test_vs_ref(self, n):
        rng = np.random.default_rng(n)
        s = jnp.asarray(rng.random(n), jnp.float32)
        l = jnp.asarray(rng.random(n), jnp.float32)
        c = jnp.asarray(rng.poisson(5, n), jnp.float32)
        kw = dict(alpha_s=0.7, alpha_l=0.1, w_s=0.2, w_l=0.8)
        ref = score_update_ref(s, l, c, **kw)
        out = score_update_kernel(s, l, c, interpret=True, **kw)
        for o, r in zip(out, ref):
            np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                       rtol=1e-6, atol=1e-6)


class TestMambaScan:
    """Fused SSD scan kernel (kernels/mamba_scan) vs the chunked oracle
    (itself pinned to the naive recurrence in test_models_smoke)."""

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,S,H,P,N,Q",
                             [(2, 128, 3, 16, 32, 32),
                              (1, 64, 2, 64, 128, 16),
                              (3, 256, 1, 32, 64, 64)])
    def test_vs_ref(self, B, S, H, P, N, Q, dtype):
        from repro.kernels.mamba_scan.kernel import mamba_scan_kernel
        from repro.kernels.mamba_scan.ref import mamba_scan_ref
        rng = np.random.default_rng(S + P)
        x = _rand(rng, (B, S, H, P), dtype)
        dt = jnp.asarray(rng.uniform(0.1, 0.9, (B, S, H)), jnp.float32)
        A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
        Bm = _rand(rng, (B, S, N), jnp.float32)
        Cm = _rand(rng, (B, S, N), jnp.float32)
        y_ref, h_ref = mamba_scan_ref(x.astype(jnp.float32), dt, A, Bm, Cm,
                                      Q)
        y, h = mamba_scan_kernel(x, dt, A, Bm, Cm, chunk=Q, interpret=True)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   **TOL[dtype])
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                                   rtol=1e-4, atol=1e-4)
