import warnings

import pytest


@pytest.fixture(autouse=True)
def _quiet_donation_notice():
    """jit buffer donation is best-effort by shape; XLA's per-dispatch
    notice about the small machine-spec rows it could not alias is
    expected (see scan_engine) and would drown real warnings here."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield
