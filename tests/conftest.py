import warnings

import pytest


@pytest.fixture(autouse=True, scope="module")
def _bound_live_executables():
    """The full suite compiles enough XLA programs in one process that
    the CPU backend eventually segfaults inside ``backend_compile``
    (LLVM state, not Python memory — reproducible around ~450 tests,
    deterministic at whatever test crosses the threshold).  Dropping
    compiled executables between modules keeps the live-program count
    bounded; modules rarely share jit caches, so the recompile cost is
    noise next to the crash it prevents."""
    yield
    import jax
    jax.clear_caches()


@pytest.fixture(autouse=True)
def _quiet_donation_notice():
    """jit buffer donation is best-effort by shape; XLA's per-dispatch
    notice about the small machine-spec rows it could not alias is
    expected (see scan_engine) and would drown real warnings here."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield
