"""Beyond-paper ARMS-guided sparse attention: quality bound tests.

When attention mass is concentrated (the skew ARMS exploits), attending
only to the ARMS-resident hot pages + recency window + sink approximates
full attention with error bounded by the skipped mass."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.tiering import paged_kv as PK
from repro.tiering.sparse_attention import sparse_attention_step

CFG = PK.PagedKVConfig(page_size=8, n_pages=8, fast_pages=4, policy_every=2)
B, KV, H, DH = 1, 2, 4, 16


def _drive_skewed(steps, hot_scale=6.0, seed=0):
    """Decode with keys engineered so a few pages dominate attention."""
    rng = np.random.default_rng(seed)
    kv = PK.init_paged_kv(CFG, B, KV, DH, dtype=jnp.float32)
    qs = []
    for t in range(steps):
        q = jnp.asarray(rng.standard_normal((B, H, DH)), jnp.float32)
        k_new = jnp.asarray(rng.standard_normal((B, KV, DH)) * 0.3,
                            jnp.float32)
        if (t // CFG.page_size) in (1, 2):   # pages 1-2 get LOUD keys
            k_new = k_new * hot_scale
        v_new = jnp.asarray(rng.standard_normal((B, KV, DH)), jnp.float32)
        _, kv, _ = PK.serve_decode_step(kv, q, k_new, k_new * 0 + v_new,
                                        jnp.int32(t), CFG)
        qs.append(q)
    return kv, qs


def test_sparse_error_bounded_by_skipped_mass():
    """The module's quality claim: approximation error is bounded by the
    attention mass of the skipped (cold, non-resident) pages — which ARMS
    estimates online via its own EWMAs."""
    steps = CFG.page_size * CFG.n_pages
    kv, qs = _drive_skewed(steps)
    pos = jnp.int32(steps - 1)
    q = qs[-1]
    full, mass = PK.paged_attention_step(kv, q, pos, CFG)
    sparse, _, frac = sparse_attention_step(kv, q, pos, CFG)
    attended = np.asarray(kv.in_fast).copy()
    attended[0] = True                       # sink
    attended[-2:] = True                     # recency window
    total = float(np.asarray(mass).sum())
    skipped_frac = float(np.asarray(mass)[~attended].sum()) / total
    err = float(jnp.abs(sparse - full).max())
    base = float(jnp.abs(full).max())
    assert float(frac) < 1.0                 # genuinely skipped pages
    assert skipped_frac < 0.5                # ARMS holds the hot mass
    assert err / base <= skipped_frac + 0.05, (err / base, skipped_frac)


def test_sparse_attends_fraction_shrinks_with_fast_tier():
    steps = CFG.page_size * CFG.n_pages
    small = dataclasses.replace(CFG, fast_pages=2)
    kv, qs = _drive_skewed(steps)
    kv_small = PK.with_residency(kv, kv.in_fast & (
        jnp.cumsum(kv.in_fast.astype(jnp.int32)) <= 2))
    pos = jnp.int32(steps - 1)
    _, _, frac_big = sparse_attention_step(kv, qs[-1], pos, CFG)
    _, _, frac_small = sparse_attention_step(kv_small, qs[-1], pos, small)
    assert float(frac_small) <= float(frac_big)


def test_sink_and_recent_always_attended():
    steps = CFG.page_size * 4
    kv, qs = _drive_skewed(steps)
    # wipe residency: sparse must still include sink + recent pages
    kv = PK.with_residency(kv, jnp.zeros_like(kv.in_fast))
    pos = jnp.int32(steps - 1)
    out, _, frac = sparse_attention_step(kv, qs[-1], pos, CFG)
    assert bool(jnp.isfinite(out).all())
    assert float(frac) > 0.0
