"""Checkpoint + fault-tolerance tests: atomic save/restore, CRC integrity,
async writer, preemption, straggler detection, gradient compression, and
bit-exact restart continuity of the training launcher."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.ft import compression
from repro.ft.preemption import PreemptionGuard
from repro.ft.stragglers import StragglerMonitor


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
            "nested": {"b": jnp.asarray(rng.integers(0, 9, (4,)),
                                        jnp.int32),
                       "c": jnp.asarray(rng.standard_normal((3, 3)),
                                        jnp.bfloat16)}}


class TestCheckpoint:
    def test_roundtrip_exact(self, tmp_path):
        t = _tree()
        store.save(t, tmp_path, step=7)
        restored, step = store.restore(t, tmp_path)
        assert step == 7
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_corruption_detected(self, tmp_path):
        t = _tree()
        d = store.save(t, tmp_path, step=1)
        manifest = json.loads((d / "manifest.proc0.json").read_text())
        victim = d / manifest["leaves"][0]["file"]
        arr = np.load(victim)
        arr.flat[0] += 1
        np.save(victim, arr)
        with pytest.raises(IOError, match="crc"):
            store.restore(t, tmp_path)

    def test_atomicity_no_tmp_visible(self, tmp_path):
        store.save(_tree(), tmp_path, step=3)
        assert not list(tmp_path.glob("*.tmp"))
        assert store.latest_step(tmp_path) == 3

    def test_prune_keeps_last_k(self, tmp_path):
        t = _tree()
        for s in range(5):
            store.save(t, tmp_path, step=s, keep=2)
        steps = sorted(int(p.name.split("_")[1])
                       for p in tmp_path.glob("step_*"))
        assert steps == [3, 4]

    def test_async_checkpointer(self, tmp_path):
        ck = store.AsyncCheckpointer(tmp_path)
        t = _tree()
        ck.save(t, 11)
        ck.wait()
        restored, step = store.restore(t, tmp_path)
        assert step == 11
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(t["a"]))

    def test_elastic_restore_resharded(self, tmp_path):
        """Restore onto a different mesh: shardings pytree drives
        device_put placement (single-device CPU here, 1x1 mesh)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        t = _tree()
        store.save(t, tmp_path, step=2)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        shardings = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), t)
        restored, _ = store.restore(t, tmp_path, shardings=shardings)
        assert restored["a"].sharding == NamedSharding(mesh, P())


class TestPreemption:
    def test_guard_flags_and_restores_handler(self):
        import signal
        before = signal.getsignal(signal.SIGTERM)
        with PreemptionGuard() as g:
            assert not g.preempted
            g.fire()
            assert g.preempted
        assert signal.getsignal(signal.SIGTERM) is before


class TestStragglers:
    def test_detects_slow_host(self):
        mon = StragglerMonitor(n_hosts=8)
        rng = np.random.default_rng(0)
        for _ in range(10):
            mon.observe(1.0 + 0.01 * rng.standard_normal(8))
        times = 1.0 + 0.01 * rng.standard_normal(8)
        times[3] = 2.5
        rep = mon.observe(times)
        rep = mon.observe(times)
        assert rep.flagged[3]
        assert rep.flagged.sum() == 1

    def test_no_false_positives_on_noise(self):
        mon = StragglerMonitor(n_hosts=8)
        rng = np.random.default_rng(1)
        for _ in range(30):
            rep = mon.observe(1.0 + 0.05 * rng.standard_normal(8))
        assert not rep.flagged.any()


class TestCompression:
    def test_int8_error_feedback_reduces_bias(self):
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.standard_normal((64, 64)) * 1e-3,
                              jnp.float32)}
        ef = compression.init_error_feedback(g)
        # accumulated decompressed grads ~= accumulated true grads
        acc_true = np.zeros((64, 64))
        acc_q = np.zeros((64, 64))
        for _ in range(50):
            q, s, ef = compression.compress_int8(g, ef)
            deq = compression.decompress_int8(q, s)
            acc_true += np.asarray(g["w"])
            acc_q += np.asarray(deq["w"])
        rel = np.abs(acc_q - acc_true).max() / np.abs(acc_true).max()
        assert rel < 0.05   # EF keeps long-run error small

    def test_bf16_roundtrip_close(self):
        g = {"w": jnp.linspace(-1, 1, 256, dtype=jnp.float32)}
        out = compression.decompress_bf16(compression.compress_bf16(g))
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(g["w"]), atol=1e-2)


class TestRestartContinuity:
    def test_training_resumes_bit_identically(self, tmp_path):
        """A run interrupted at step 6 and restarted matches the
        uninterrupted run exactly (params+opt+data all restart-safe)."""
        from repro.launch.train import train
        kw = dict(arch="stablelm-1.6b", batch=2, seq=32,
                  ckpt_dir=str(tmp_path), ckpt_every=6)
        full = train(n_steps=10, **kw)
        # wipe nothing; restart from the step-6 checkpoint
        resumed = train(n_steps=10, restore=True, **kw)
        np.testing.assert_allclose(resumed, full[6:], rtol=1e-6)
