"""End-to-end dry-run integration test (deliverable e, CI-scale).

Runs the REAL dryrun module in a subprocess (so the 512 forced host
devices don't leak into this process) for one small cell on both
production meshes and validates the artifact schema + roofline terms.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.parametrize("mesh", ["pod1", "pod2"])
def test_dryrun_cell_compiles_and_reports(tmp_path, mesh):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "stablelm-1.6b", "--shape", "decode_32k",
           "--mesh", mesh, "--out", str(tmp_path)]
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    res = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                         env=env)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    rec = json.loads(
        (tmp_path / f"stablelm-1.6b__decode_32k__{mesh}.json").read_text())
    assert rec["status"] == "ok"
    assert rec["chips"] == (512 if mesh == "pod2" else 256)
    r = rec["roofline"]
    for term in ("compute_s", "memory_s", "collective_s"):
        assert r[term] >= 0.0
    assert r["dominant"] in ("compute", "memory", "collective")
    assert rec["model_flops"] > 0
    assert rec["collectives"]["_total"] >= 0
    assert rec["memory_analysis"]["temp_size_in_bytes"] > 0
