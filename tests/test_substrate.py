"""Substrate unit tests: optimizer, data pipeline, sharding rules, and the
scan-aware HLO cost model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.optim import adamw


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                                total_steps=200, master_fp32=False)
        params = {"w": jnp.array([5.0, -3.0, 2.0])}
        state = adamw.init(params, cfg)

        def loss(p):
            return jnp.sum((p["w"] - jnp.array([1.0, 2.0, 3.0])) ** 2)

        for _ in range(150):
            grads = jax.grad(loss)(params)
            params, state, _ = adamw.update(grads, state, params, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), [1, 2, 3],
                                   atol=0.05)

    def test_clip_norm(self):
        g = {"a": jnp.full((4,), 100.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        assert float(adamw.global_norm(clipped)) == pytest.approx(1.0,
                                                                  rel=1e-5)

    def test_schedule_shape(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_frac=0.1)
        lrs = [float(adamw.schedule(jnp.int32(s), cfg)) for s in
               (0, 5, 10, 55, 100)]
        assert lrs[0] < lrs[1] < lrs[2] == pytest.approx(1.0)  # warmup
        assert lrs[2] > lrs[3] > lrs[4]                        # cosine
        assert lrs[4] == pytest.approx(0.1, rel=1e-3)          # floor

    def test_bf16_params_fp32_master(self):
        cfg = adamw.AdamWConfig(lr=1e-3, master_fp32=True)
        params = {"w": jnp.ones((8,), jnp.bfloat16)}
        state = adamw.init(params, cfg)
        grads = {"w": jnp.full((8,), 1e-4, jnp.bfloat16)}
        p2, state, _ = adamw.update(grads, state, params, cfg)
        assert p2["w"].dtype == jnp.bfloat16
        assert state.master["w"].dtype == jnp.float32
        # master accumulates updates below bf16 resolution
        assert not np.array_equal(np.asarray(state.master["w"], np.float32),
                                  np.ones(8, np.float32))


class TestDataPipeline:
    def test_deterministic_and_restart_safe(self):
        from repro.data.pipeline import SyntheticLM
        src = SyntheticLM(vocab=1000, seq_len=16, global_batch=4, seed=3)
        a, b = src.batch_at(7), src.batch_at(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = src.batch_at(8)
        assert not np.array_equal(a["tokens"], c["tokens"])
        # labels are next-token with trailing mask
        np.testing.assert_array_equal(a["labels"][:, :-1],
                                      a["tokens"][:, 1:])
        assert (a["labels"][:, -1] == -1).all()

    def test_prefetcher_order(self):
        from repro.data.pipeline import Prefetcher, SyntheticLM
        src = SyntheticLM(vocab=100, seq_len=8, global_batch=2)
        pf = Prefetcher(src, start_step=5)
        try:
            for expect in (5, 6, 7):
                step, batch = pf.next()
                assert step == expect
                np.testing.assert_array_equal(batch["tokens"],
                                              src.batch_at(expect)["tokens"])
        finally:
            pf.close()

    def test_zipf_skew(self):
        from repro.data.pipeline import SyntheticLM
        src = SyntheticLM(vocab=10_000, seq_len=64, global_batch=8)
        toks = src.batch_at(0)["tokens"]
        # zipf: a large share of tokens from the head of the vocab
        assert (toks < 100).mean() > 0.3


class TestShardingRules:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_param_rules(self):
        from jax.sharding import PartitionSpec as P

        from repro.launch import sharding
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # shapes chosen divisible by 1 (single-device mesh: everything
        # divides) — rule CHOICE is what we pin here
        sds = {
            "embed": {"table": jax.ShapeDtypeStruct((1024, 64),
                                                    jnp.float32)},
            "layers": {"attn": {
                "wq": {"w": jax.ShapeDtypeStruct((4, 64, 128),
                                                 jnp.float32)},
                "wo": {"w": jax.ShapeDtypeStruct((4, 128, 64),
                                                 jnp.float32)}}},
        }
        out = sharding.param_shardings(sds, mesh)
        assert out["embed"]["table"].spec == P("model", "data")
        assert out["layers"]["attn"]["wq"]["w"].spec == \
            P(None, "data", "model")
        assert out["layers"]["attn"]["wo"]["w"].spec == \
            P(None, "model", "data")  # row-parallel output proj

    def test_serve_drops_fsdp_factor(self):
        from jax.sharding import PartitionSpec as P

        from repro.launch import sharding
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sds = {"mlp": {"wi": {"w": jax.ShapeDtypeStruct((64, 128),
                                                        jnp.float32)}}}
        train = sharding.param_shardings(sds, mesh)
        serve = sharding.param_shardings(sds, mesh, serve=True)
        assert train["mlp"]["wi"]["w"].spec == P("data", "model")
        assert serve["mlp"]["wi"]["w"].spec == P(None, "model")

    def test_cache_never_shards_stack_dim(self):
        from repro.launch import sharding
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        cache = jax.ShapeDtypeStruct((32, 16, 8, 256, 128), jnp.float32)
        out = sharding.cache_sharding(mesh, cache)
        assert out.spec[0] is None   # layer-stack dim (§Perf B1)


class TestRooflineParser:
    HLO = """
HloModule test

%region_body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %ag = f32[8,8]{1,0} all-gather(%gte), channel_id=1, dimensions={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ag)
}

%region_cond (p: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %c = s32[] constant(5)
  ROOT %cmp = pred[] compare(%gte2, %c), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %w = (s32[], f32[8,8]) while(%init), condition=%region_cond, body=%region_body
  %d = f32[8,8]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %r = f32[8,8]{1,0} add(%d, %gte3)
}
"""

    def test_scan_aware_collectives_and_flops(self):
        from repro import roofline
        res = roofline.analyze_hlo(self.HLO)
        # all-gather of 8*8*4 = 256B, x5 loop trips
        assert res["collectives"]["all-gather"] == 256 * 5
        # dot: 2 * 8*8 * 8 = 1024 flops, outside the loop (x1)
        assert res["flops"] == 1024

    def test_shape_bytes(self):
        from repro import roofline
        assert roofline._shape_bytes("f32[8,8]") == 256
        assert roofline._shape_bytes("bf16[2,4]{1,0}") == 16
        assert roofline._shape_bytes("(f32[4], s32[2])") == 24


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 512), seed=st.integers(0, 1000))
def test_score_update_kernel_matches_ref_property(n, seed):
    """Property: the fused score kernel equals the oracle for any size."""
    from repro.kernels.score_update.kernel import score_update_kernel
    from repro.kernels.score_update.ref import score_update_ref
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.random(n), jnp.float32)
    l = jnp.asarray(rng.random(n), jnp.float32)
    c = jnp.asarray(rng.poisson(3, n), jnp.float32)
    kw = dict(alpha_s=0.7, alpha_l=0.1, w_s=0.3, w_l=0.7)
    ref = score_update_ref(s, l, c, **kw)
    out = score_update_kernel(s, l, c, interpret=True, **kw)
    for o, r in zip(out, ref):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=1e-6,
                                   atol=1e-6)
