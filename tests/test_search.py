"""Search-engine invariants: ASHA/CE round structure, CRN-paired
elimination, deterministic ranking, and the machine-transfer matrix.

The load-bearing properties: (1) every search round is ONE compiled
dispatch per policy family (asserted via ``scan_engine.dispatch_count``
deltas); (2) ASHA with ``eta=1`` degenerates to exhaustive grid search
BITWISE — same configs, same scores, same ranking — because both paths
evaluate the same population in the same lanes under the same CRN field;
(3) survivors are always drawn from the previous round's population;
(4) rankings are stable (equal ``exec_time_s`` keeps draw order) and CE's
redraw stream is a pure function of ``search_seed``.
"""
import numpy as np
import pytest

from repro.simulator import scan_engine, search, tuning, workloads
from repro.simulator.engine import SimResult
from repro.simulator.machine import PMEM_LARGE

T, N, K = 80, 256, 32


def _trace(wl="gups"):
    return workloads.make(wl, T=T, n=N)


def _res(t):
    return SimResult(name="x", exec_time_s=t, promotions=0, demotions=0,
                     wasteful=0, hot_recall=0.0, fast_hit_frac=0.0)


# ------------------------------------------------------------ _sample_grid
class TestSampleGrid:
    def test_budget_respected_with_default_inserted(self):
        """The draw returns AT MOST ``budget`` configs even when the
        default config wasn't among the sampled indices (earlier
        revisions returned budget + 1)."""
        for budget in (1, 3, 6, 24):
            cfgs = tuning.sample_configs(budget)
            assert len(cfgs) <= budget
            assert dict(tuning.HEMEM_DEFAULTS) in cfgs

    def test_huge_space_not_materialized(self):
        """A grid far too large to materialize samples in O(budget)."""
        space = {f"k{i}": list(range(32)) for i in range(8)}  # 32**8 ~ 1e12
        defaults = {f"k{i}": 0 for i in range(8)}
        cfgs = tuning._sample_grid(space, defaults, 8, seed=1)
        assert len(cfgs) == 8
        keys = [tuple(sorted(c.items())) for c in cfgs]
        assert len(set(keys)) == len(keys)  # draws are unique
        for c in cfgs:
            assert list(c) == list(space)   # knob order preserved
            assert all(c[nm] in space[nm] for nm in space)

    def test_seeded_draw_deterministic(self):
        assert tuning.sample_configs(8, seed=5) == \
            tuning.sample_configs(8, seed=5)
        a = tuning.sample_configs(12, seed=0)
        b = tuning.sample_configs(12, seed=1)
        assert a != b

    def test_decode_matches_product_order(self):
        """Mixed-radix decode agrees with the itertools.product C order
        the materializing implementation indexed into."""
        import itertools
        space = dict(a=[1, 2, 3], b=[10, 20], c=[0.5, 0.7])
        grid = list(itertools.product(*space.values()))
        keys, sizes = list(space), [len(v) for v in space.values()]
        for i in range(len(grid)):
            assert tuning._decode_grid_index(space, keys, sizes, i) == \
                dict(zip(keys, grid[i]))


# ------------------------------------------------------------------- ASHA
class TestASHA:
    def test_eta1_reproduces_grid_bitwise(self):
        """budget >= population and eta=1 collapse ASHA to ONE full-horizon
        round — exactly grid search, bitwise, under the shared CRN."""
        trace = _trace()
        kw = dict(trace=trace, k=K, budget=6, search_seed=2, sim_seed=9)
        a = search.run("hemem", "asha", eta=1, **kw)
        g = search.run("hemem", "grid", **kw)
        assert [c for c, _ in a.rows] == [c for c, _ in g.rows]
        for (_, ra), (_, rg) in zip(a.rows, g.rows):
            assert ra.exec_time_s == rg.exec_time_s  # bitwise
        assert a.best_config == g.best_config
        assert len(a.rounds) == 1
        assert a.lane_intervals == g.lane_intervals

    def test_survivors_subset_of_population(self):
        trace = _trace("silo-tpcc")
        sr = search.run("hemem", "asha", trace=trace, k=K, budget=9, eta=3,
                        search_seed=1, sim_seed=0)
        assert len(sr.rounds) >= 2
        for rec in sr.rounds:
            pop = {search._cfg_key(c) for c in rec.population[None]}
            surv = [search._cfg_key(c) for c in rec.survivors[None]]
            assert set(surv) <= pop
        for prev, nxt in zip(sr.rounds, sr.rounds[1:]):
            assert nxt.population[None] == prev.survivors[None]
            assert len(nxt.population[None]) < len(prev.population[None])
        # final round runs at the full horizon; earlier rounds are shorter
        assert sr.rounds[-1].horizon == trace.shape[0]
        assert all(r.horizon < trace.shape[0] for r in sr.rounds[:-1])

    def test_zero_information_rung_eliminates_nobody(self):
        """When every lane of a rung scores bitwise-identically (knobs
        inert at that horizon — Memtis cooling periods never fire in a
        short low-sample-rate trace), an eta-cut would eliminate by draw
        order alone; ASHA must refuse and carry the whole population to
        the next rung."""
        trace = _trace()  # gups, T=80, n=256: no memtis cooling fires
        sr = search.run("memtis", "asha", trace=trace, k=K, budget=9,
                        eta=3, search_seed=1, sim_seed=0)
        assert len(sr.rounds) >= 2
        for rec in sr.rounds[:-1]:
            assert rec.survivors[None] == rec.population[None]
        # the full population reached the full-horizon round, so the
        # result ranks every config — exactly the exhaustive grid's rows.
        g = search.run("memtis", "grid", trace=trace, k=K, budget=9,
                       search_seed=1, sim_seed=0)
        assert [c for c, _ in sr.rows] == [c for c, _ in g.rows]
        assert sr.lane_intervals > g.lane_intervals  # paid for the rungs

    def test_one_dispatch_per_round(self):
        sr = search.run("hemem", "asha", trace=_trace(), k=K, budget=9,
                        eta=3, search_seed=0, sim_seed=0)
        assert all(rec.dispatches == 1 for rec in sr.rounds)
        assert sr.dispatches == len(sr.rounds)
        assert sr.lane_intervals == sum(r.lane_intervals for r in sr.rounds)
        assert sr.lane_intervals == sum(r.lanes * r.horizon
                                        for r in sr.rounds)

    def test_machine_lane_mode(self):
        """machines=[...]: per-machine elimination, each round one
        union-population x M dispatch; every machine gets its own result."""
        machines = ["pmem-large", "numa"]
        out = search.run("hemem", "asha", trace=_trace(), machines=machines,
                         k=K, budget=6, eta=3, search_seed=0, sim_seed=0)
        # group labels are the RESOLVED spec names (same scheme as
        # experiment.sweep's machine axis: "numa" -> spec named "NUMA")
        assert sorted(nm.lower() for nm in out) == sorted(machines)
        a, b = out["pmem-large"], out["NUMA"]
        assert a.rounds is b.rounds          # shared round records
        rec = a.rounds[0]
        union = {search._cfg_key(c)
                 for g in rec.population for c in rec.population[g]}
        assert rec.lanes == len(union) * len(machines)
        assert rec.dispatches == 1


# ---------------------------------------------------------- cross-entropy
class TestCE:
    def test_deterministic_under_search_seed(self):
        trace = _trace()
        kw = dict(trace=trace, k=K, budget=8, ce_rounds=2, sim_seed=3)
        a = search.run("hemem", "ce", search_seed=7, **kw)
        b = search.run("hemem", "ce", search_seed=7, **kw)
        assert [c for c, _ in a.rows] == [c for c, _ in b.rows]
        for (_, ra), (_, rb) in zip(a.rows, b.rows):
            assert ra.exec_time_s == rb.exec_time_s
        for ra, rb in zip(a.rounds, b.rounds):
            assert ra.population == rb.population
            assert ra.survivors == rb.survivors
        c = search.run("hemem", "ce", search_seed=8, **kw)
        assert [cf for cf, _ in a.rows] != [cf for cf, _ in c.rows]

    def test_one_dispatch_per_round_and_elite_shrinks(self):
        sr = search.run("hemem", "ce", trace=_trace(), k=K, budget=12,
                        ce_rounds=3, elite_frac=0.25, search_seed=0,
                        sim_seed=0)
        assert len(sr.rounds) == 3
        assert all(rec.dispatches == 1 for rec in sr.rounds)
        for rec in sr.rounds:
            assert len(rec.survivors[None]) <= len(rec.population[None])
            # CE scores every round at the full horizon
            assert rec.horizon == T
        # round 1 tries the published defaults first
        assert sr.rounds[0].population[None][0] == tuning.HEMEM_DEFAULTS

    def test_continuous_arms_alphas_leave_the_grid(self):
        """The CE continuous path samples ARMS alphas from a truncated
        normal — off-grid values — while staying on the precomputed-grid
        'pre' fast path (alphas are SWEEPABLE batch knobs)."""
        sr = search.run("arms", "ce", trace=_trace(), k=K, budget=10,
                        ce_rounds=2, search_seed=0, sim_seed=0)
        assert scan_engine.last_dispatch["sampling"] == "pre"
        drawn = [c for c, _ in sr.rows if c != tuning.ARMS_DEFAULTS]
        assert any(c["alpha_s"] not in tuning.ARMS_SPACE["alpha_s"]
                   for c in drawn)
        lo, hi = min(tuning.ARMS_SPACE["alpha_s"]), \
            max(tuning.ARMS_SPACE["alpha_s"])
        assert all(lo <= c["alpha_s"] <= hi for c in drawn)
        # discrete knobs stay on the grid
        assert all(c["noise_z"] in tuning.ARMS_SPACE["noise_z"]
                   for c in drawn)


# ------------------------------------------------------- ranking stability
class TestRanking:
    def test_equal_scores_keep_draw_order(self):
        rows = [({"a": 1}, _res(2.0)), ({"a": 2}, _res(1.0)),
                ({"a": 3}, _res(1.0)), ({"a": 4}, _res(1.0))]
        ranked = search.rank_rows(rows)
        assert [c["a"] for c, _ in ranked] == [2, 3, 4, 1]

    def test_duplicate_configs_share_a_lane_and_stay_adjacent(self):
        """Explicit duplicate configs are evaluated once (one lane) and —
        scoring identically under CRN — keep draw order in the ranking."""
        cfg_a = dict(tuning.HEMEM_DEFAULTS)
        cfg_b = dict(cfg_a, hot_threshold=1)
        with scan_engine.count_dispatches() as ctr:
            sr = search.run("hemem", "grid", trace=_trace(), k=K,
                            configs=[cfg_a, cfg_b, cfg_a], sim_seed=0)
        assert ctr.count == 1
        assert ctr.last["lanes"] == 2  # deduped population, not 3
        assert len(sr.rows) == 3
        dup = [i for i, (c, _) in enumerate(sr.rows) if c == cfg_a]
        assert dup == [dup[0], dup[0] + 1]  # adjacent, draw order
        r0, r1 = sr.rows[dup[0]][1], sr.rows[dup[1]][1]
        assert r0.exec_time_s == r1.exec_time_s


# ------------------------------------------------------- tuning thin views
class TestTuneViews:
    def test_strategy_views_keep_legacy_shape(self):
        trace = _trace()
        for strategy in ("grid", "asha", "ce"):
            best_cfg, best_res, rows = tuning.tune_hemem(
                trace, PMEM_LARGE, K, budget=6, strategy=strategy)
            assert set(best_cfg) == set(tuning.SPACE)
            assert best_res.exec_time_s == min(r.exec_time_s
                                               for _, r in rows)

    def test_unknown_strategy_rejected(self):
        with pytest.raises(ValueError):
            tuning.tune("hemem", _trace(), PMEM_LARGE, K, budget=2,
                        strategy="bayes")

    def test_machines_mode_returns_per_machine_tuples(self):
        out = tuning.tune("hemem", _trace(), None, K, budget=4,
                          machines=["pmem-large", "numa"])
        assert sorted(out) == ["NUMA", "pmem-large"]
        for best_cfg, best_res, rows in out.values():
            assert set(best_cfg) == set(tuning.SPACE)
            assert len(rows) <= 4

    def test_tune_arms_asha_keeps_pre_path(self):
        best_cfg, best_res, rows = tuning.tune_arms(
            _trace(), PMEM_LARGE, K, budget=6, strategy="asha")
        assert scan_engine.last_dispatch["sampling"] == "pre"
        assert set(best_cfg) == set(tuning.ARMS_SPACE)
        assert best_res.exec_time_s == min(r.exec_time_s for _, r in rows)

    def test_workload_lane_asha(self):
        out = tuning.tune("hemem", None, PMEM_LARGE, K, budget=6,
                          workloads=["gups", "silo-tpcc"], T=T, n=N,
                          strategy="asha")
        assert sorted(out) == ["gups", "silo-tpcc"]
        # the final round's dispatch covers W x survivors lanes
        d = scan_engine.last_dispatch
        assert d["synth"] is True and d["workloads"] == 2


# -------------------------------------------------------- transfer matrix
class TestTransferMatrix:
    def test_native_tuning_is_optimal_under_shared_crn(self):
        """With grid strategy the matrix is exact: phase 2 re-scores every
        tuned config under the SAME CRN field phase 1 ranked them with, so
        the native config is optimal among the tuned set — diagonal 1.0,
        off-diagonal slowdown >= 1.0."""
        tm = search.transfer_matrix(
            "hemem", _trace(), ["pmem-large", "numa", "cxl-1hop"], K,
            budget=5, strategy="grid")
        assert tm.slowdown.shape == (3, 3)
        assert np.allclose(np.diag(tm.slowdown), 1.0)
        assert (tm.slowdown >= 1.0 - 1e-12).all()
        rows = tm.rows()
        assert [r["tuned_on"] for r in rows] == tm.machines
        assert all(r["slowdown"][r["tuned_on"]] == 1.0 for r in rows)

    def test_needs_two_machines(self):
        with pytest.raises(ValueError):
            search.transfer_matrix("hemem", _trace(), ["numa"], K)
