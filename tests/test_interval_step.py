"""Fused interval fast path (kernels/interval_step): kernel-vs-ref
property tests across odd shapes, and fused-vs-unfused scan-engine
bitwise equivalence for every policy family on 2- and 3-tier machines.

Integer/bool outputs (masks, tiers, executed plans, migration counts)
must match BITWISE between the interpret-mode Pallas kernels and the jnp
references.  f32 outputs are held to a tight allclose only: XLA contracts
fma / reciprocal-division differently across separately compiled
programs, so last-ulp deviation between the interpret kernel and the
plain-jnp reference is expected (the CPU scan route uses the references
themselves, so engine-level equivalence stays bitwise).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.interval_step import kernel, ref
from repro.kernels.migrate.kernel import migrate_kernel
from repro.kernels.migrate.ref import migrate_ref
from repro.simulator import experiment, machines, scan_engine
from repro.simulator.sampling import uniform_field

F32 = dict(rtol=1e-6, atol=1e-6)


def _tiered(rng, B, n, k):
    """Lane-batched 2-tier machine + caps for accounting tests."""
    mach, caps = scan_engine._mach_lanes("pmem-large", B, n, k)
    return mach, caps


class TestTopkMask:
    # odd n (not multiples of 8/128), k at both extremes, heavy ties
    @pytest.mark.parametrize("B,n,k", [(1, 7, 1), (3, 37, 5), (2, 37, 37),
                                       (2, 200, 64), (1, 128, 128),
                                       (4, 513, 1)])
    def test_ref_matches_lax_topk(self, B, n, k):
        rng = np.random.default_rng(B * 1000 + n)
        # quantized values force threshold-equal groups the tie rule
        # must break identically to lax.top_k
        x = jnp.asarray(rng.integers(0, 5, (B, n)), jnp.float32) * 0.25
        want = jax.vmap(lambda r: scan_engine._topk_mask(r, k))(x)
        got = ref.topk_mask_ref(x, k)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("B,n,k", [(1, 7, 1), (3, 37, 5), (2, 37, 37),
                                       (2, 200, 64), (1, 128, 128)])
    def test_kernel_vs_ref_bitwise(self, B, n, k):
        rng = np.random.default_rng(n + k)
        x = jnp.asarray(rng.integers(0, 4, (B, n)), jnp.float32) * 0.5
        want = ref.topk_mask_ref(x, k)
        got = kernel.topk_mask_kernel(x, k, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_negative_and_signed_zero_ties(self):
        x = jnp.asarray([[-1.5, 0.0, -0.0, 2.0, -1.5, 0.0, -3.0]],
                        jnp.float32)
        for k in range(1, 8):
            want = jax.vmap(lambda r: scan_engine._topk_mask(r, k))(x)
            np.testing.assert_array_equal(
                np.asarray(ref.topk_mask_ref(x, k)), np.asarray(want))
            np.testing.assert_array_equal(
                np.asarray(kernel.topk_mask_kernel(x, k, interpret=True)),
                np.asarray(want))


def _plans(rng, B, n, P, D):
    """Sentinel-padded plans honouring the unique-valid-index contract."""
    promote = np.full((B, P), -1, np.int64)
    demote = np.full((B, D), -1, np.int64)
    for b in range(B):
        perm = rng.permutation(n)
        npro = rng.integers(0, min(P, n) + 1) if P else 0
        nde = rng.integers(0, min(D, n - npro) + 1) if D else 0
        promote[b, :npro] = perm[:npro]
        demote[b, :nde] = perm[npro:npro + nde]
    return jnp.asarray(promote, jnp.int32), jnp.asarray(demote, jnp.int32)


class TestTierMigrate:
    @pytest.mark.parametrize("B,n,R,P,D",
                             [(2, 13, 2, 3, 4), (3, 29, 3, 5, 5),
                              (1, 16, 4, 0, 0), (2, 10, 3, 1, 10),
                              (1, 7, 2, 7, 7)])
    def test_kernel_vs_ref_bitwise(self, B, n, R, P, D):
        rng = np.random.default_rng(B * 100 + n + R)
        tier = jnp.asarray(rng.integers(0, R, (B, n)), jnp.int32)
        caps = jnp.asarray(
            np.stack([np.append(rng.integers(1, n, R - 1), n)
                      for _ in range(B)]), jnp.int32)
        promote, demote = _plans(rng, B, n, P, D)
        want = ref.tier_migrate_ref(tier, promote, demote, caps)
        got = kernel.tier_migrate_kernel(tier, promote, demote, caps,
                                         interpret=True)
        for g, w, nm in zip(got, want,
                            ("tier", "pexec", "dexec", "up", "down")):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                          err_msg=nm)

    def test_empty_plans_are_noop(self):
        tier = jnp.asarray([[1, 0, 1, 1, 0]], jnp.int32)
        caps = jnp.asarray([[2, 5]], jnp.int32)
        empty = jnp.zeros((1, 0), jnp.int32)
        t, pex, dex, up, down = kernel.tier_migrate_kernel(
            tier, empty, empty, caps, interpret=True)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(tier))
        assert int(up.sum()) == 0 and int(down.sum()) == 0


class TestIntervalAccount:
    @pytest.mark.parametrize("B,n", [(1, 7), (3, 130), (2, 37)])
    def test_kernel_vs_ref(self, B, n):
        k = max(1, n // 4)
        rng = np.random.default_rng(n)
        mach, caps = _tiered(rng, B, n, k)
        R = caps.shape[-1]
        true = jnp.asarray(rng.gamma(1.5, 2.0, (B, n)), jnp.float32)
        tier = jnp.asarray(rng.integers(0, R, (B, n)), jnp.int32)
        up = jnp.asarray(rng.integers(0, 5, (B, R - 1)), jnp.float32)
        down = jnp.asarray(rng.integers(0, 5, (B, R - 1)), jnp.float32)
        oracle = ref.topk_mask_ref(true, k)
        want = ref.interval_account_ref(mach, true, tier, up, down,
                                        oracle, k)
        got = kernel.interval_account_kernel(
            mach.lat_ns, mach.bw_read, mach.bw_write, mach.mlp, true, tier,
            up, down, oracle, k, interpret=True)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), **F32)


class TestEwmaUpdate:
    @pytest.mark.parametrize("B,n", [(1, 17), (3, 1000), (2, 129)])
    @pytest.mark.parametrize("lane_params", [False, True])
    def test_kernel_vs_ref(self, B, n, lane_params):
        rng = np.random.default_rng(B + n)
        s = jnp.asarray(rng.random((B, n)), jnp.float32)
        l = jnp.asarray(rng.random((B, n)), jnp.float32)
        c = jnp.asarray(rng.poisson(5, (B, n)), jnp.float32)
        if lane_params:
            kw = dict(alpha_s=jnp.asarray(rng.random(B), jnp.float32),
                      alpha_l=jnp.asarray(rng.random(B), jnp.float32),
                      w_s=jnp.asarray(rng.random(B), jnp.float32),
                      w_l=jnp.asarray(rng.random(B), jnp.float32))
        else:
            kw = dict(alpha_s=0.7, alpha_l=0.1, w_s=0.2, w_l=0.8)
        want = ref.ewma_score_update_ref(s, l, c, **kw)
        got = kernel.ewma_update_kernel(s, l, c, interpret=True, **kw)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), **F32)


class TestMigrateKernelOddShapes:
    """Existing kernels/migrate data-plane kernel: odd page/feat tiles
    (not multiples of the f32 8x128 TPU tile) and empty batches."""

    @pytest.mark.parametrize("Ps,Pd,M,page,feat",
                             [(5, 3, 2, 3, 17), (7, 7, 7, 1, 1),
                              (6, 9, 5, 13, 31)])
    def test_vs_ref_odd(self, Ps, Pd, M, page, feat):
        rng = np.random.default_rng(Ps * 10 + feat)
        src = jnp.asarray(rng.standard_normal((Ps, page, feat)),
                          jnp.float32)
        dst = jnp.asarray(rng.standard_normal((Pd, page, feat)),
                          jnp.float32)
        src_idx = jnp.asarray(rng.choice(Ps, M, replace=False), jnp.int32)
        dst_idx = jnp.asarray(rng.choice(Pd, M, replace=False), jnp.int32)
        valid = jnp.asarray(rng.random(M) < 0.6)
        want = migrate_ref(src, dst, src_idx, dst_idx, valid)
        got = migrate_kernel(src, dst, src_idx, dst_idx, valid,
                             interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_empty_batch_is_noop(self):
        rng = np.random.default_rng(3)
        src = jnp.asarray(rng.standard_normal((3, 5, 17)), jnp.float32)
        dst = jnp.asarray(rng.standard_normal((4, 5, 17)), jnp.float32)
        e = jnp.zeros(0, jnp.int32)
        got = migrate_kernel(src, dst, e, e, jnp.zeros(0, bool),
                             interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(dst))


FAMILIES = ["arms", "hemem", "memtis", "tpp", "all-slow", "oracle"]
MACHS = ["pmem-large", "dram-cxl-pmem"]      # 2-tier and 3-tier
T_, N_, K_ = 64, 128, 16


def _exact(a, b):
    assert a.name == b.name
    assert a.exec_time_s == b.exec_time_s
    assert a.promotions == b.promotions
    assert a.demotions == b.demotions
    assert a.wasteful == b.wasteful
    assert a.hot_recall == b.hot_recall
    assert a.fast_hit_frac == b.fast_hit_frac
    np.testing.assert_array_equal(a.timeline_slow_bw, b.timeline_slow_bw)
    np.testing.assert_array_equal(a.timeline_fast_hits,
                                  b.timeline_fast_hits)
    np.testing.assert_array_equal(a.timeline_promotions,
                                  b.timeline_promotions)
    np.testing.assert_array_equal(a.timeline_mode, b.timeline_mode)


class TestFusedScanEquivalence:
    """The headline guarantee: ``use_interval_kernel`` never changes a
    bit of any simulation — every policy family, 2- AND 3-tier chains,
    trace-replay AND device-synthesis modes."""

    def test_all_families_all_machines_trace_mode(self):
        rng = np.random.default_rng(0)
        trace = rng.gamma(1.5, 2.0, size=(T_, N_)).astype(np.float32)
        u = uniform_field(T_, N_, seed=7)
        fused = experiment.sweep(FAMILIES, trace=trace, machines=MACHS,
                                 k=K_, sample_u=u, timelines=True)
        plain = experiment.sweep(FAMILIES, trace=trace, machines=MACHS,
                                 k=K_, sample_u=u, timelines=True,
                                 use_interval_kernel=False)
        assert experiment.scan_engine.last_dispatch["interval_kernel"] \
            is False
        for p in FAMILIES:
            for m in MACHS:
                _exact(fused.at(policy=p, machine=m),
                       plain.at(policy=p, machine=m))

    def test_synth_mode(self):
        fused = experiment.sweep(["arms", "hemem"], workloads=["gups"],
                                 machines=MACHS, k=K_, T=T_, n=N_,
                                 timelines=True)
        plain = experiment.sweep(["arms", "hemem"], workloads=["gups"],
                                 machines=MACHS, k=K_, T=T_, n=N_,
                                 timelines=True, use_interval_kernel=False)
        for p in ("arms", "hemem"):
            for m in MACHS:
                _exact(fused.at(policy=p, machine=m),
                       plain.at(policy=p, machine=m))


class TestStreamingReduce:
    def test_stream_matches_stack_scalars(self):
        rng = np.random.default_rng(1)
        trace = rng.gamma(1.5, 2.0, size=(T_, N_)).astype(np.float32)
        u = uniform_field(T_, N_, seed=2)
        stream = experiment.sweep(["arms", "tpp"], trace=trace, k=K_,
                                  sample_u=u)
        assert experiment.scan_engine.last_dispatch["reduce"] == "stream"
        stack = experiment.sweep(["arms", "tpp"], trace=trace, k=K_,
                                 sample_u=u, timelines=True)
        for p in ("arms", "tpp"):
            a, b = stream.at(policy=p), stack.at(policy=p)
            assert a.exec_time_s == b.exec_time_s
            assert a.promotions == b.promotions
            assert a.demotions == b.demotions
            assert a.wasteful == b.wasteful
            assert a.hot_recall == b.hot_recall
            assert a.timeline_slow_bw is None        # nothing [T]-shaped
            assert b.mean_slow_bw is None
            np.testing.assert_allclose(
                a.mean_slow_bw, float(np.mean(b.timeline_slow_bw)),
                rtol=1e-6)
            np.testing.assert_allclose(
                a.mean_fast_hits, float(np.mean(b.timeline_fast_hits)),
                rtol=1e-6)
            assert a.max_promotions_interval \
                == int(b.timeline_promotions.max())

    def test_stream_allocates_nothing_T_shaped(self):
        """Abstract-evaluate the synth-mode engine at bench scale
        (T=4096, n=65536): under reduce="stream" no output leaf may have
        a T-sized axis, proving O(1)-in-T output memory."""
        from repro.baselines.hemem import HeMemSpec
        from repro.simulator import workload_spec as wspec

        T, n, k = 4096, 65536, 4096
        wl = scan_engine._stack_workloads([wspec.named("gups", T=T)])
        mach, caps = scan_engine._mach_lanes("pmem-large", 1, n, k)
        spec = scan_engine._lane_specs(HeMemSpec.make(), 1)
        keys = jax.random.PRNGKey(0)[None]
        sample = jax.ShapeDtypeStruct((T, 1), jnp.float32)

        def run(reduce):
            return jax.eval_shape(
                lambda s: scan_engine._simulate(
                    spec, None, None, k, mach, caps, keys, s, "crn_prng",
                    False, wl=wl, wl_keys=keys,
                    noise_key=jax.random.PRNGKey(0), wl_rep=1, n=n,
                    reduce=reduce), sample)

        stream_leaves = jax.tree_util.tree_leaves(run("stream"))
        assert all(T not in leaf.shape for leaf in stream_leaves)
        stack_leaves = jax.tree_util.tree_leaves(run("stack"))
        assert any(T in leaf.shape for leaf in stack_leaves)  # sanity
