"""Deterministic synthetic token pipeline with host-side prefetch.

Zipf-distributed token ids (matching the skew the embedding tier exploits),
next-token labels, deterministic per (seed, step) — restart-safe: resuming
from step N reproduces exactly the batches a fault interrupted.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, zipf_s: float = 1.2):
        self.vocab, self.seq, self.batch = vocab, seq_len, global_batch
        self.seed = seed
        ranks = np.arange(1, vocab + 1)
        p = 1.0 / ranks ** zipf_s
        self.p = p / p.sum()

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        tokens = rng.choice(self.vocab, size=(self.batch, self.seq),
                            p=self.p).astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((self.batch, 1), -1, np.int32)], axis=1)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Double-buffered host prefetch thread."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            try:
                self.q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
