"""Straggler detection — the ARMS machinery reused at cluster scope.

The paper's hot/cold insight (dual-horizon EWMAs + change-point detection,
§4.1-4.2) applies verbatim to per-host step-time telemetry: the short EWMA
reacts to a host that suddenly slows (preemption signal, failing HBM,
thermal throttle); the long EWMA is the host's baseline; a Page-Hinkley
test on the fleet-normalized maximum flags sustained degradation.

``StragglerMonitor`` is host-side (numpy) — it runs in the launcher, not in
the jitted step."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerReport:
    flagged: np.ndarray        # bool [n_hosts]
    slowdown: np.ndarray       # f32 [n_hosts] short/long EWMA ratio
    fleet_alarm: bool          # PHT alarm on fleet max slowdown


class StragglerMonitor:
    def __init__(self, n_hosts: int, alpha_s: float = 0.7,
                 alpha_l: float = 0.05, threshold: float = 1.35,
                 pht_delta: float = 0.01, pht_lambda: float = 0.5):
        self.n = n_hosts
        self.alpha_s, self.alpha_l = alpha_s, alpha_l
        self.threshold = threshold
        self.pht_delta, self.pht_lambda = pht_delta, pht_lambda
        self.ewma_s = np.zeros(n_hosts)
        self.ewma_l = np.zeros(n_hosts)
        self.steps = 0
        # PHT state over fleet max slowdown
        self._pht_n = 0
        self._pht_mean = 0.0
        self._pht_m = 0.0
        self._pht_min = 0.0

    def observe(self, step_times: np.ndarray) -> StragglerReport:
        x = np.asarray(step_times, dtype=np.float64)
        assert x.shape == (self.n,)
        if self.steps == 0:
            self.ewma_s[:] = x
            self.ewma_l[:] = x
        else:
            self.ewma_s = self.alpha_s * x + (1 - self.alpha_s) * self.ewma_s
            self.ewma_l = self.alpha_l * x + (1 - self.alpha_l) * self.ewma_l
        self.steps += 1

        baseline = np.median(self.ewma_l)
        slowdown = self.ewma_s / max(baseline, 1e-9)
        flagged = (slowdown > self.threshold) & (self.steps >= 3)

        # Page-Hinkley on the fleet-max slowdown (sustained degradation)
        z = float(slowdown.max())
        self._pht_n += 1
        self._pht_mean += (z - self._pht_mean) / self._pht_n
        self._pht_m += z - self._pht_mean - self.pht_delta
        self._pht_min = min(self._pht_min, self._pht_m)
        alarm = (self._pht_m - self._pht_min) > self.pht_lambda
        if alarm:
            self._pht_n, self._pht_mean = 0, 0.0
            self._pht_m, self._pht_min = 0.0, 0.0
        return StragglerReport(flagged=flagged, slowdown=slowdown,
                               fleet_alarm=bool(alarm))
