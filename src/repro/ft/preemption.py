"""Preemption-safe execution: SIGTERM/SIGINT set a flag; the training loop
checkpoints and exits cleanly at the next step boundary."""
from __future__ import annotations

import signal
import threading


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._signals = signals
        self._old = {}

    def __enter__(self):
        for s in self._signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._old.items():
            signal.signal(s, h)
        return False

    def _handler(self, signum, frame):
        self._flag.set()

    def fire(self):          # for tests
        self._flag.set()

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()
