"""Gradient compression for cross-pod reduction with error feedback.

At 512+ chips the pod-level all-reduce crosses the (slow) inter-pod links;
compressing the pod-crossing traffic 2x (bf16) or 4x (int8 + per-tensor
scale) with error-feedback keeps convergence intact (the EF residual
carries the quantization error into the next step)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_bf16(grads):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)


def init_error_feedback(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)


def compress_int8(grads, ef):
    """-> (q_grads int8, scales f32, new_ef).  g' = g + ef; q = round(g'/s);
    ef' = g' - q*s."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        s = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / s), -127, 127).astype(jnp.int8)
        new_e = g - q.astype(jnp.float32) * s
        return q, s, new_e

    flat, treedef = jax.tree.flatten(grads)
    ef_flat = jax.tree.leaves(ef)
    qs, ss, es = zip(*[one(g, e) for g, e in zip(flat, ef_flat)])
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, ss),
            jax.tree.unflatten(treedef, es))


def decompress_int8(q_grads, scales):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, q_grads,
                        scales)
