"""Bandwidth-aware batched migration scheduling (paper §4.4).

Priority: candidates arrive hottest-first (from classifier top-k order), so
the hottest page is migrated first — no head-of-line blocking (contrast with
HeMem's serial FIFO queue, §3.2).

Batch size adapts to application bandwidth headroom (Nimble-style batching,
throttled so migrations never steal bandwidth from the application):

    BS = max(1, (BW_max - BW_app) / BW_max * BS_max)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.state import ARMSConfig, MigrationPlan, TieringState


def batch_size(bw_app, bw_max, bs_max: int):
    """The paper's BS formula; clamped to [1, bs_max]."""
    frac = jnp.clip((bw_max - bw_app) / bw_max, 0.0, 1.0)
    bs = jnp.floor(frac * bs_max).astype(jnp.int32)
    return jnp.clip(bs, 1, bs_max)


def build_plan(cand_idx, promote_ok, demote_idx, bw_app, bw_max,
               cfg: ARMSConfig) -> MigrationPlan:
    """Truncate the gated, priority-ordered candidate batch to BS entries."""
    bs = batch_size(jnp.asarray(bw_app, jnp.float32),
                    jnp.asarray(bw_max, jnp.float32),
                    min(cfg.bs_max, cand_idx.shape[0]))
    # Rank accepted candidates by arrival (= hotness) order.
    rank = jnp.cumsum(promote_ok.astype(jnp.int32)) - 1
    valid = promote_ok & (rank < bs)
    count = valid.sum().astype(jnp.int32)
    return MigrationPlan(
        promote=jnp.where(valid, cand_idx, -1),
        demote=jnp.where(valid, demote_idx, -1),
        valid=valid,
        count=count,
        batch_size=bs,
    )


def apply_plan(state: TieringState, plan: MigrationPlan) -> TieringState:
    """Update tier residency; the data plane executes the same plan."""
    n = state.in_fast.shape[0]
    promote = jnp.where(plan.valid, plan.promote, n)   # out-of-range = drop
    demote = jnp.where(plan.valid & (plan.demote >= 0), plan.demote, n)
    in_fast = state.in_fast.at[demote].set(False, mode="drop")
    in_fast = in_fast.at[promote].set(True, mode="drop")
    return state.replace(in_fast=in_fast)


def observe_migration_cost(state: TieringState, promo_us, demo_us,
                           cfg: ARMSConfig) -> TieringState:
    """Feed back measured per-page migration latencies (self-calibration)."""
    a = cfg.migrate_cost_alpha
    promo = a * jnp.asarray(promo_us, jnp.float32) + (1 - a) * state.promo_cost
    demo = a * jnp.asarray(demo_us, jnp.float32) + (1 - a) * state.demo_cost
    return state.replace(promo_cost=promo, demo_cost=demo)
