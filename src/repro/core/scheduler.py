"""Bandwidth-aware batched migration scheduling (paper §4.4).

Priority: candidates arrive hottest-first (from classifier top-k order), so
the hottest page is migrated first — no head-of-line blocking (contrast with
HeMem's serial FIFO queue, §3.2).

Batch size adapts to application bandwidth headroom (Nimble-style batching,
throttled so migrations never steal bandwidth from the application):

    BS = max(1, (BW_max - BW_app) / BW_max * BS_max)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.state import ARMSConfig, MigrationPlan, TieringState


def batch_size(bw_app, bw_max, bs_max: int):
    """The paper's BS formula; clamped to [1, bs_max].

    This is the CONSUMER-side clamp of the (raw, possibly > 1)
    utilization signal: the interval cost model reports oversaturation
    unclamped (simjax.tier_interval_outcome), and the clip here keeps the
    BS formula well-defined for any input.
    """
    frac = jnp.clip((bw_max - bw_app) / bw_max, 0.0, 1.0)
    bs = jnp.floor(frac * bs_max).astype(jnp.int32)
    return jnp.clip(bs, 1, bs_max)


def pair_budgets(tier_util, bs_max: int):
    """Per-adjacent-pair migration budgets over an N-tier chain.

    ``tier_util`` [..., R]: per-tier bandwidth utilization (raw ratios
    welcome — clipped here, the consumer).  A pair's budget runs the BS
    formula against its more-saturated endpoint, so migration traffic
    backs off from whichever tier of the hop is the bottleneck.
    Returns i32 [..., R-1] budgets in [1, bs_max].
    """
    u = jnp.maximum(tier_util[..., :-1], tier_util[..., 1:])
    frac = jnp.clip(1.0 - u, 0.0, 1.0)
    return jnp.clip(jnp.floor(frac * bs_max).astype(jnp.int32), 1, bs_max)


def build_plan(cand_idx, promote_ok, demote_idx, bw_app, bw_max,
               cfg: ARMSConfig, tier_util=None) -> MigrationPlan:
    """Truncate the gated, priority-ordered candidate batch to BS entries.

    ``tier_util`` (optional f32 [R]): per-tier utilization for N-tier
    machines.  Promotions all cross the top adjacent pair, so the plan is
    additionally throttled by that pair's budget; ``None`` keeps the
    classic two-tier BS formula exactly.
    """
    width = min(cfg.bs_max, cand_idx.shape[0])
    bs = batch_size(jnp.asarray(bw_app, jnp.float32),
                    jnp.asarray(bw_max, jnp.float32), width)
    if tier_util is not None:
        bs = jnp.minimum(
            bs, pair_budgets(jnp.asarray(tier_util, jnp.float32), width)[0])
    # Rank accepted candidates by arrival (= hotness) order.
    rank = jnp.cumsum(promote_ok.astype(jnp.int32)) - 1
    valid = promote_ok & (rank < bs)
    count = valid.sum().astype(jnp.int32)
    return MigrationPlan(
        promote=jnp.where(valid, cand_idx, -1),
        demote=jnp.where(valid, demote_idx, -1),
        valid=valid,
        count=count,
        batch_size=bs,
    )


def apply_plan(state: TieringState, plan: MigrationPlan) -> TieringState:
    """Update tier residency; the data plane executes the same plan."""
    n = state.in_fast.shape[0]
    promote = jnp.where(plan.valid, plan.promote, n)   # out-of-range = drop
    demote = jnp.where(plan.valid & (plan.demote >= 0), plan.demote, n)
    in_fast = state.in_fast.at[demote].set(False, mode="drop")
    in_fast = in_fast.at[promote].set(True, mode="drop")
    return state.replace(in_fast=in_fast)


def observe_migration_cost(state: TieringState, promo_us, demo_us,
                           cfg: ARMSConfig) -> TieringState:
    """Feed back measured per-page migration latencies (self-calibration)."""
    a = cfg.migrate_cost_alpha
    promo = a * jnp.asarray(promo_us, jnp.float32) + (1 - a) * state.promo_cost
    demo = a * jnp.asarray(demo_us, jnp.float32) + (1 - a) * state.demo_cost
    return state.replace(promo_cost=promo, demo_cost=demo)
