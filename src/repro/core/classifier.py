"""Threshold-free hot/cold page classification (paper §4.1, Algorithm 1).

Score update
------------
Two EWMAs per page.  NOTE on faithfulness: Algorithm 1 as printed updates
``EWMA = alpha*EWMA + (1-alpha)*accesses`` which, with alpha_s=0.7 and
alpha_l=0.1, would make the *long-term* average the more reactive one —
contradicting the paper's prose ("short-term, fast-moving EWMA_s (alpha_s =
0.7)", 1s vs 10s horizons).  We implement the prose semantics

    EWMA <- alpha * accesses + (1 - alpha) * EWMA

so alpha_s=0.7 reacts fast and alpha_l=0.1 tracks the long horizon.  See
DESIGN.md §1 "Formula note".

Classification
--------------
Pages are *ranked* by score and the top-k (k = fast-tier capacity in pages)
form the hot set — no hotness threshold, no cooling (EWMA decay subsumes it).
``hot_age`` counts consecutive intervals a page stayed in the top-k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.state import (MODE_RECENCY, ARMSConfig, TieringState)


def score_weights(cfg: ARMSConfig, mode):
    """(w_s, w_l) given mode; recency mode prioritizes the short-term EWMA."""
    recency = (mode == MODE_RECENCY)
    w_s = jnp.where(recency, cfg.w_s_recency, cfg.w_s_history)
    w_l = jnp.where(recency, cfg.w_l_recency, cfg.w_l_history)
    return w_s, w_l


def update_scores(state: TieringState, access_counts, cfg: ARMSConfig,
                  mode) -> TieringState:
    """Algorithm 1 lines 1-6: EWMA + hotness score update (vectorized).

    Routed through the fused interval-step EWMA op
    (kernels/interval_step.ops.ewma_score_update: Pallas kernel on TPU,
    fused jnp on other backends) unless ``cfg.use_score_kernel`` is False,
    which pins the jnp reference; every route computes the identical f32
    formula.  The op is lane-batched, so the [n] arrays ride a width-1
    batch axis (an outer ``vmap`` — the scan engine's lane batching —
    turns it into the real lane axis).
    """
    from repro.kernels.interval_step.ops import ewma_score_update

    x = jnp.asarray(access_counts, jnp.float32)
    w_s, w_l = score_weights(cfg, mode)
    ewma_s, ewma_l, score = ewma_score_update(
        state.ewma_s[None], state.ewma_l[None], x[None],
        alpha_s=cfg.alpha_s, alpha_l=cfg.alpha_l, w_s=w_s, w_l=w_l,
        use_kernel=bool(getattr(cfg, "use_score_kernel", True)))
    return state.replace(ewma_s=ewma_s[0], ewma_l=ewma_l[0],
                         prev_score=state.score, score=score[0])


def topk_hot_mask(score: jnp.ndarray, k: int):
    """Boolean mask of the top-k pages by score (Algorithm 1 lines 7-9).

    Ties are broken by page index (stable) via jax.lax.top_k semantics.
    """
    n = score.shape[0]
    k = min(int(k), n)
    _, idx = jax.lax.top_k(score, k)
    mask = jnp.zeros((n,), bool).at[idx].set(True)
    return mask, idx


def update_hot_age(state: TieringState, hot_mask) -> TieringState:
    """Algorithm 1 lines 10-12."""
    hot_age = jnp.where(hot_mask, state.hot_age + 1, 0)
    return state.replace(hot_age=hot_age)
