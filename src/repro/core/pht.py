"""Page-Hinkley change-point test (paper §4.2).

Sequential detection of an *increase* in the monitored signal (slow-tier
bandwidth utilization, normalized to [0,1] by BW_max).  Classic PHT for
increase detection:

    m_t   = m_{t-1} + (x_t - mean_t - delta)
    PH_t  = m_t - min_{i<=t} m_i
    alarm = PH_t > lambda

On alarm the test resets so a sustained shift produces one alarm, not a
continuous stream.  All ops are jax-traceable.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.state import ARMSConfig, PHTState, init_pht


def pht_update(state: PHTState, x, cfg: ARMSConfig):
    """One PHT step. Returns (new_state, alarm: bool scalar, stat: f32)."""
    x = jnp.asarray(x, jnp.float32)
    n = state.n + 1
    mean = state.mean + (x - state.mean) / n.astype(jnp.float32)
    m_t = state.m_t + (x - mean - cfg.pht_delta)
    m_min = jnp.minimum(state.m_min, m_t)
    stat = m_t - m_min
    alarm = stat > cfg.pht_lambda

    fresh = init_pht()
    new = PHTState(
        n=jnp.where(alarm, fresh.n, n),
        mean=jnp.where(alarm, fresh.mean, mean),
        m_t=jnp.where(alarm, fresh.m_t, m_t),
        m_min=jnp.where(alarm, fresh.m_min, m_min),
    )
    return new, alarm, stat
