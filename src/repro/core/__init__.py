"""ARMS core: the paper's primary contribution as a composable JAX module.

Threshold-free dual-EWMA hot/cold classification (Alg. 1), Page-Hinkley
change-point adaptation (§4.2), cost/benefit-gated promotions (Alg. 2) and the
bandwidth-aware batched migration scheduler (§4.4).
"""
from repro.core.controller import (MODE_SAMPLING_PERIODS, ARMSConfig,
                                   MigrationPlan, TieringState, arms_step,
                                   arms_step_impl, init_state, policy_every,
                                   sampling_period)
from repro.core.pht import pht_update
from repro.core.state import MODE_HISTORY, MODE_RECENCY

__all__ = [
    "ARMSConfig", "MigrationPlan", "TieringState", "arms_step",
    "arms_step_impl", "init_state", "pht_update", "MODE_HISTORY",
    "MODE_RECENCY", "MODE_SAMPLING_PERIODS", "sampling_period",
    "policy_every",
]
