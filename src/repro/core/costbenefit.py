"""Wasteful-migration elimination (paper §4.3, Algorithm 2).

Multi-round promotion filtering: a page entering the top-k is only a
*candidate* once its score is non-decreasing and its hot age >= 2 — one-hit
wonders never reach the migration queue.

Cost/benefit gate: the i-th hottest candidate p is paired with the i-th
coldest fast-tier victim q (or with a free fast-tier slot), and promoted only
if

    B = (p_score - q_score) * p_hotage * dLatency  >  C = L_promo + L_demo

where L_promo / L_demo are EWMAs of observed migration latencies (fed back by
the migration engine), making the gate self-calibrating — no threshold.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.state import ARMSConfig, TieringState

_NEG = jnp.float32(-3.4e38)
_POS = jnp.float32(3.4e38)


def promotion_candidates(state: TieringState, hot_mask, cfg: ARMSConfig,
                         bs_max: int):
    """Top `bs_max` promotion candidates, hottest first (Alg. 2 lines 1-4).

    Returns (idx[bs_max], valid[bs_max]).
    """
    is_cand = (hot_mask
               & (~state.in_fast)
               & (state.score >= state.prev_score)
               & (state.hot_age >= cfg.hot_age_min))
    keyed = jnp.where(is_cand, state.score, _NEG)
    val, idx = jax.lax.top_k(keyed, bs_max)
    return idx, val > _NEG


def demotion_victims(state: TieringState, hot_mask, bs_max: int):
    """Coldest fast-tier pages outside the top-k, coldest first."""
    is_victim = state.in_fast & (~hot_mask)
    keyed = jnp.where(is_victim, -state.score, _NEG)
    val, idx = jax.lax.top_k(keyed, bs_max)
    return idx, val > _NEG


def cost_benefit_gate(state: TieringState, cand_idx, cand_valid, victim_idx,
                      victim_valid, free_slots, cfg: ARMSConfig, mode=None):
    """Alg. 2 lines 5-10, vectorized over the candidate batch.

    The first ``free_slots`` candidates consume free fast-tier capacity
    (no demotion, q_score = 0, C = L_promo only); the rest pair with victims.

    Returns (promote_ok[bs], demote_idx[bs]) where demote_idx == -1 marks a
    free-slot promotion.
    """
    bs = cand_idx.shape[0]
    j = jnp.arange(bs)
    uses_free = j < free_slots
    vpos = jnp.clip(j - free_slots, 0, bs - 1)
    victim = victim_idx[vpos]
    victim_ok = victim_valid[vpos] & (~uses_free)

    q_score = jnp.where(uses_free, 0.0, state.score[victim])
    p_score = state.score[cand_idx]
    p_age = state.hot_age[cand_idx].astype(jnp.float32)

    # §4.3 "PEBS sampling inaccuracies ... cost-benefit provides immunity":
    # sampled counts are ~Poisson, so a score difference below (a fraction
    # of) the noise floor sqrt(p+q) carries no real benefit.  Self-scaling
    # with the count magnitude — noise_z is a fixed internal constant
    # (sensitivity is flat; see EXPERIMENTS.md), not a per-workload knob.
    del mode
    noise = cfg.noise_z * jnp.sqrt(jnp.maximum(p_score + q_score, 0.0))
    gain = jnp.maximum(p_score - q_score - noise, 0.0)
    benefit = gain * p_age * cfg.delta_latency * cfg.access_scale
    cost = jnp.where(uses_free, state.promo_cost,
                     state.promo_cost + state.demo_cost)
    ok = cand_valid & (uses_free | victim_ok) & (benefit > cost)
    demote = jnp.where(uses_free, -1, victim)
    return ok, demote
