"""ARMS controller: one policy interval end-to-end (paper Fig. 6).

``arms_step`` is the composable entry point used by the simulator, the paged
KV-cache tier, the MoE expert tier and the embedding tier.  It is pure and
jittable: (state, access_counts, slow_bw_frac, app_bw_frac) -> (state, plan).

Pipeline per interval:
  1. PHT on slow-tier bandwidth -> history/recency mode (§4.2); recency mode
     doubles the sampling rate (surfaced via ``sampling_period``) and runs the
     policy 5x more often (surfaced via ``policy_every``).
  2. dual-EWMA score update (Alg. 1), with mode-dependent weights.
  3. top-k ranking (k = fast tier capacity) + hot-age update.
  4. multi-round filter + cost/benefit gate (Alg. 2).
  5. bandwidth-aware batched, priority-ordered migration plan (§4.4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import classifier, costbenefit, scheduler
from repro.core.pht import pht_update
from repro.core.state import (MODE_HISTORY, MODE_RECENCY, ARMSConfig,
                              MigrationPlan, TieringState, init_state)

__all__ = [
    "ARMSConfig", "TieringState", "MigrationPlan", "init_state", "arms_step",
    "arms_step_impl", "sampling_period", "policy_every",
]

# §5: PEBS sampling period 10k default, 5k in recency mode.
SAMPLING_PERIOD_HISTORY = 10_000
SAMPLING_PERIOD_RECENCY = 5_000
# Mode-indexed sampling periods (index = MODE_HISTORY / MODE_RECENCY); the
# scan engine precomputes one CRN observation grid per entry.
MODE_SAMPLING_PERIODS = (SAMPLING_PERIOD_HISTORY, SAMPLING_PERIOD_RECENCY)
# §5: policy thread every 500ms steady, 100ms after a hot-set change.
POLICY_EVERY_HISTORY = 5
POLICY_EVERY_RECENCY = 1


def sampling_period(mode):
    return jnp.where(mode == MODE_RECENCY, SAMPLING_PERIOD_RECENCY,
                     SAMPLING_PERIOD_HISTORY)


def policy_every(mode):
    return jnp.where(mode == MODE_RECENCY, POLICY_EVERY_RECENCY,
                     POLICY_EVERY_HISTORY)


def arms_step_impl(state: TieringState, access_counts, slow_bw_frac,
                   app_bw_frac, *, cfg: ARMSConfig, k: int, tier_util=None):
    """One ARMS policy interval (untraced body — see ``arms_step``).

    This un-jitted entry point exists for callers that inline the controller
    into a larger compiled program (the lax.scan simulation engine, vmapped
    tuning sweeps).  There ``cfg``'s *float* fields may be traced arrays —
    e.g. a batch of (alpha_s, noise_z, ...) knob settings swept under vmap —
    while the shape-determining int fields (``bs_max``) stay static.

    Args:
      state: TieringState over n_pages.
      access_counts: [n_pages] accesses observed this interval.
      slow_bw_frac: scalar in [0,1] — slow-tier bandwidth / its max (PHT
        input; §4.2 "increase in slow tier bandwidth" signals hot-set change).
      app_bw_frac: scalar in [0,1] — application bandwidth / BW_max (BS
        throttle input; §4.4).
      cfg: ARMSConfig (static).
      k: fast-tier capacity in pages (static).
      tier_util: optional f32 [R] per-tier bandwidth utilization (N-tier
        machines); throttles the migration batch by the top adjacent
        pair's budget (scheduler.pair_budgets).  None = classic two-tier
        BS formula.

    Returns:
      (new_state, MigrationPlan)
    """
    # 1. change-point detection -> mode.  The TTL counts down only while the
    # slow-tier signal has stabilized (short EWMA not above long EWMA by more
    # than eps); while it keeps rising the system stays in recency mode
    # (§4.2: "until the bandwidth utilization has stabilized").
    x = jnp.asarray(slow_bw_frac, jnp.float32)
    sig_s = cfg.alpha_s * x + (1 - cfg.alpha_s) * state.sig_ewma_s
    sig_l = cfg.alpha_l * x + (1 - cfg.alpha_l) * state.sig_ewma_l
    stabilized = sig_s <= sig_l + cfg.stabilize_eps
    pht, alarm, _ = pht_update(state.pht, x, cfg)
    ttl = jnp.where(
        alarm, cfg.recency_ttl,
        jnp.where(stabilized, jnp.maximum(state.mode_ttl - 1, 0),
                  jnp.maximum(state.mode_ttl, 0)))
    mode = jnp.where(ttl > 0, MODE_RECENCY, MODE_HISTORY).astype(jnp.int32)
    state = state.replace(pht=pht, mode=mode, mode_ttl=ttl,
                          interval=state.interval + 1,
                          sig_ewma_s=sig_s, sig_ewma_l=sig_l)

    # 2. score update (Alg. 1).
    state = classifier.update_scores(state, access_counts, cfg, mode)

    # 3. top-k hot set + hot age.
    hot_mask, _ = classifier.topk_hot_mask(state.score, k)
    state = classifier.update_hot_age(state, hot_mask)

    # 4. candidates, victims, cost/benefit gate (Alg. 2).
    bs_max = min(cfg.bs_max, access_counts.shape[0])
    cand_idx, cand_valid = costbenefit.promotion_candidates(
        state, hot_mask, cfg, bs_max)
    victim_idx, victim_valid = costbenefit.demotion_victims(
        state, hot_mask, bs_max)
    free_slots = k - state.in_fast.sum().astype(jnp.int32)
    ok, demote_idx = costbenefit.cost_benefit_gate(
        state, cand_idx, cand_valid, victim_idx, victim_valid, free_slots,
        cfg, mode=mode)

    # 5. bandwidth-aware batch + priority order; apply residency update.
    plan = scheduler.build_plan(cand_idx, ok, demote_idx, app_bw_frac, 1.0,
                                cfg, tier_util=tier_util)
    state = scheduler.apply_plan(state, plan)
    return state, plan


@functools.partial(jax.jit, static_argnames=("cfg", "k"))
def arms_step(state: TieringState, access_counts, slow_bw_frac, app_bw_frac,
              *, cfg: ARMSConfig, k: int):
    """Jitted ``arms_step_impl`` (cfg/k static) — the standalone entry point
    used by the numpy simulator policy and the tiering integrations."""
    return arms_step_impl(state, access_counts, slow_bw_frac, app_bw_frac,
                          cfg=cfg, k=k)
