"""ARMS tiering state (paper §4, §5).

Per-page metadata mirrors the paper's ~20 bytes/page layout: raw access count
for the current interval arrives as an input; we persist two EWMAs, the current
and previous hotness scores, the hot age, and tier residency.  Controller-level
state holds the Page-Hinkley test (§4.2), the history/recency mode, and the
EWMA-estimated migration costs used by the cost/benefit gate (§4.3).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.utils.pytree import pytree_dataclass, static_dataclass

MODE_HISTORY = 0
MODE_RECENCY = 1


@static_dataclass
class ARMSConfig:
    """ARMS internal parameters (paper §6 "ARMS internal knobs").

    These are NOT per-workload tuning thresholds; the paper reports workloads
    are insensitive to them and we keep the published values.
    """

    alpha_s: float = 0.7        # short-term EWMA smoothing (fast; ~1s horizon)
    alpha_l: float = 0.1        # long-term EWMA smoothing (slow; ~10s horizon)
    w_s_history: float = 0.2    # score weights in history (steady) mode
    w_l_history: float = 0.8
    w_s_recency: float = 0.8    # score weights in recency mode (§4.2)
    w_l_recency: float = 0.2
    hot_age_min: int = 2        # multi-round promotion filter (§4.3)
    # Page-Hinkley test on normalized slow-tier bandwidth (§4.2).
    pht_delta: float = 0.005    # magnitude tolerance
    pht_lambda: float = 0.10    # alarm threshold
    recency_ttl: int = 20       # intervals to stay in recency mode after alarm
    # §4.2: "stays in this mode ... until the bandwidth utilization has
    # stabilized" — the TTL only counts down while the slow-tier signal is no
    # longer rising (its short EWMA within eps of its long EWMA).
    stabilize_eps: float = 0.02
    # Migration scheduler (§4.4).
    bs_max: int = 64            # max pages migrated per interval (BS_max)
    # Cost model (§4.3): latencies in microseconds (per page).
    latency_fast_us: float = 0.08   # 80 ns -> per-access; used as relative ΔL
    latency_slow_us: float = 0.25
    # Accesses represented by one observed count (PEBS 1-in-10,000 sampling,
    # §4.1).  Converts score (sampled accesses/interval) into real accesses so
    # benefit and cost share units (us).  Framework integrations with exact
    # counts use access_scale=1 and per-page costs in the same unit system.
    access_scale: float = 10_000.0
    # z-score of the Poisson noise floor subtracted from the promotion
    # benefit (§4.3 sampling-noise immunity).  Sensitivity is flat in
    # [0, 0.5] (see EXPERIMENTS.md §Claims); this is an internal constant
    # like alpha_s/alpha_l, not a per-workload knob.
    noise_z: float = 0.25
    migrate_cost_alpha: float = 0.3  # EWMA for observed migration latencies
    init_promo_cost_us: float = 50.0  # prior for a 2MB-page-equivalent move
    init_demo_cost_us: float = 50.0
    # Alg. 1 hot path: fused Pallas score-update kernel (interpret-mode on
    # non-TPU backends).  Set False to fall back to the pure-jnp reference.
    use_score_kernel: bool = True

    @property
    def delta_latency(self) -> float:
        return self.latency_slow_us - self.latency_fast_us


@pytree_dataclass
class PHTState:
    """Page-Hinkley test running state (increase detection)."""

    n: jnp.ndarray          # i32 sample count
    mean: jnp.ndarray       # f32 running mean of signal
    m_t: jnp.ndarray        # f32 cumulative deviation
    m_min: jnp.ndarray      # f32 running min of m_t


@pytree_dataclass
class TieringState:
    """Full ARMS state; all leaves are jax arrays (jit/scan friendly)."""

    # --- per-page arrays [n_pages] ---
    ewma_s: jnp.ndarray     # f32
    ewma_l: jnp.ndarray     # f32
    score: jnp.ndarray      # f32
    prev_score: jnp.ndarray  # f32
    hot_age: jnp.ndarray    # i32, consecutive intervals in top-k
    in_fast: jnp.ndarray    # bool, tier residency (True = fast tier)
    # --- controller scalars ---
    mode: jnp.ndarray       # i32, MODE_HISTORY / MODE_RECENCY
    mode_ttl: jnp.ndarray   # i32, remaining recency intervals
    interval: jnp.ndarray   # i32, policy interval counter
    sig_ewma_s: jnp.ndarray  # f32, short EWMA of the slow-tier signal
    sig_ewma_l: jnp.ndarray  # f32, long EWMA of the slow-tier signal
    promo_cost: jnp.ndarray  # f32 EWMA of observed per-page promotion cost (us)
    demo_cost: jnp.ndarray   # f32 EWMA of observed per-page demotion cost (us)
    pht: PHTState


def init_pht() -> PHTState:
    z = jnp.zeros((), jnp.float32)
    return PHTState(n=jnp.zeros((), jnp.int32), mean=z, m_t=z, m_min=z)


def init_state(n_pages: int, cfg: ARMSConfig, in_fast=None) -> TieringState:
    f = jnp.zeros((n_pages,), jnp.float32)
    if in_fast is None:
        in_fast = jnp.zeros((n_pages,), bool)
    return TieringState(
        ewma_s=f,
        ewma_l=f,
        score=f,
        prev_score=f,
        hot_age=jnp.zeros((n_pages,), jnp.int32),
        in_fast=in_fast,
        mode=jnp.asarray(MODE_HISTORY, jnp.int32),
        mode_ttl=jnp.zeros((), jnp.int32),
        interval=jnp.zeros((), jnp.int32),
        sig_ewma_s=jnp.zeros((), jnp.float32),
        sig_ewma_l=jnp.zeros((), jnp.float32),
        promo_cost=jnp.asarray(cfg.init_promo_cost_us, jnp.float32),
        demo_cost=jnp.asarray(cfg.init_demo_cost_us, jnp.float32),
        pht=init_pht(),
    )


@pytree_dataclass
class MigrationPlan:
    """Fixed-shape migration plan emitted once per policy interval (§4.4).

    ``promote[i]`` / ``demote[i]`` pair the i-th hottest accepted candidate
    with its victim; ``demote[i] == -1`` means a free fast-tier slot was used.
    Only entries with ``valid[i]`` are executed; ``count = sum(valid)``.
    Entries are sorted hottest-first (priority scheduling — no head-of-line
    blocking), and ``count`` never exceeds the bandwidth-aware batch size BS.
    """

    promote: jnp.ndarray   # i32 [bs_max]
    demote: jnp.ndarray    # i32 [bs_max]
    valid: jnp.ndarray     # bool [bs_max]
    count: jnp.ndarray     # i32 scalar
    batch_size: jnp.ndarray  # i32 scalar, the BS the scheduler allowed
