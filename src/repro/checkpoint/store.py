"""Sharded, checksummed, atomic checkpointing with async writes and
elastic (mesh-reshape) restore.

Layout:  <dir>/step_<N>/
           manifest.json   {step, leaves: [{key, file, shape, dtype, crc32}]}
           <leaf>.npy      one file per pytree leaf (per host in multi-host:
                           file names carry the process index so each host
                           writes only its addressable shards)

Writes go to ``step_<N>.tmp`` and are renamed only after the manifest is
fsync'd — a preempted/half-written checkpoint is never visible.  Restore
verifies CRC32 per leaf and can place leaves onto a DIFFERENT mesh than
they were saved from (elastic scaling): arrays are loaded on host and
``jax.device_put`` re-shards them to the target sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                       for p in path)
        out.append((key or "leaf", leaf))
    return out, treedef


def _leaf_file(key: str, process_index: int) -> str:
    safe = key.replace("/", "__")
    return f"{safe}.proc{process_index}.npy"


def save(tree, directory, step: int, *, keep: int = 3) -> Path:
    """Synchronous checkpoint save; returns the final step directory."""
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    proc = jax.process_index()

    leaves, _ = _flatten(tree)
    manifest = {"step": step, "format": 1, "leaves": []}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = _leaf_file(key, proc)
        raw = np.ascontiguousarray(arr)
        crc = zlib.crc32(raw.tobytes())
        # store raw bytes: survives dtypes numpy can't serialize (bf16, fp8)
        np.save(tmp / fname, raw.view(np.uint8).reshape(-1))
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype), "crc32": crc})
    mpath = tmp / f"manifest.proc{proc}.json"
    mpath.write_text(json.dumps(manifest, indent=1))
    with open(mpath) as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    _prune(directory, keep)
    return final


def _prune(directory: Path, keep: int):
    steps = sorted(p for p in directory.glob("step_*") if p.is_dir()
                   and not p.name.endswith(".tmp"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory) -> int | None:
    directory = Path(directory)
    steps = sorted(int(p.name.split("_")[1])
                   for p in directory.glob("step_*")
                   if p.is_dir() and not p.name.endswith(".tmp"))
    return steps[-1] if steps else None


def restore(tree_like, directory, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``tree_like`` (pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedSharding for elastic placement on a (possibly different) mesh."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = directory / f"step_{step:08d}"
    proc = jax.process_index()
    manifest = json.loads((d / f"manifest.proc{proc}.json").read_text())
    by_key = {m["key"]: m for m in manifest["leaves"]}

    leaves, treedef = _flatten(tree_like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    import ml_dtypes  # registers bf16/fp8 dtype names with numpy  # noqa
    for (key, like), shard in zip(leaves, shard_leaves):
        meta = by_key[key]
        raw = np.load(d / meta["file"])
        crc = zlib.crc32(raw.tobytes())
        if crc != meta["crc32"]:
            raise IOError(f"checkpoint corruption in {key}: crc mismatch")
        arr = raw.view(np.dtype(meta["dtype"])).reshape(meta["shape"])
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {like.shape}")
        arr = arr.astype(like.dtype)
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]


class AsyncCheckpointer:
    """Background-thread checkpoint writer (training never blocks on IO).

    ``save`` snapshots to host memory synchronously (cheap) and writes in a
    worker thread; ``wait`` joins outstanding writes (call before exit and
    before restoring)."""

    def __init__(self, directory, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, tree, step: int):
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self.wait()

        def work():
            try:
                save(host_tree, self.directory, step, keep=self.keep)
            except Exception as e:   # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
