"""Jittable engine-side bookkeeping shared by both simulation engines.

The numpy reference engine (simulator/engine.py) and the compiled
``lax.scan`` engine (simulator/scan_engine.py) must stay *semantically
aligned*: capacity/validity enforcement, wasteful-migration accounting and
the interval cost model are defined once here, as pure jax functions, and
used by both.  The numpy engine calls them per interval in CRN mode (where
bitwise agreement with the scan engine matters); the scan engine inlines
them into its scan body.

Since the N-tier machine protocol (simulator/machine_spec.py), placement
is an i32 per-page **tier index** (0 = fastest) and migrations are
adjacent-tier-pair hop chains; the two-tier boolean forms survive as thin
wrappers (``apply_padded_migrations``) whose decisions the tier forms
reproduce bitwise at N=2.

Numerical layout notes (N=2 bitwise equivalence with the pre-N-tier
engines): tier 0 charges app + migration bytes against one symmetric
bandwidth in a single division; every lower tier charges reads and writes
separately; the bottom tier's access count is computed by subtraction
(total - upper tiers), matching the legacy ``acc_slow`` expression; the
utilization ratios are returned RAW (a tier demanding more bandwidth-time
than the rest of the interval reports > 1) and are clamped only at the
signal consumer (the engines clamp the policy-facing ``app_bw`` signal;
core/scheduler.batch_size clips its input) — ``min(1, raw)`` equals the
old at-source clamp bitwise, while the raw value keeps the
oversaturation magnitude visible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.simulator.engine import WASTE_WINDOW
from repro.simulator.machine import CACHELINE, PAGE_BYTES

#: destination sentinel for tier-targeted moves: "the first tier below the
#: page's source with room" — the hop-chain demotion cascade.  The binary
#: shim (protocol.PolicySpec.tier_policy) emits its demotions with this
#: destination, which is what makes the shim bitwise-equal to
#: ``apply_tier_migrations``.
DST_BELOW = -2


def tier_access_split(true, tier, R: int):
    """Per-tier f32 access counts [R] + the f32 total.

    Tiers 0..R-2 are masked sums; the bottom tier is the sequential
    remainder ``total - sum(upper)`` — at R=2 exactly the legacy
    ``acc_slow = sum(true) - acc_fast``.
    """
    total = jnp.sum(true)
    accs = []
    rest = total
    for r in range(R - 1):
        a = jnp.sum(true * (tier == r))
        accs.append(a)
        rest = rest - a
    accs.append(rest)
    return accs, total


def _tier_times(mach, acc, mig_up, mig_down):
    """Per-tier latency + bandwidth times (the shared inner arithmetic of
    ``tier_interval_outcome`` and ``tier_utilization_impl`` — op-for-op the
    historical expressions, so factoring it out is bitwise-neutral).

    Returns (t_lat, [R] list of per-tier bandwidth times).
    """
    R = mach.lat_ns.shape[0]
    lat, br, bw = mach.lat_ns, mach.bw_read, mach.bw_write

    t_lat = acc[0] * lat[0]
    for r in range(1, R):
        t_lat = t_lat + acc[r] * lat[r]
    t_lat = t_lat * 1e-9 / mach.mlp

    # tier 0: one symmetric-bandwidth division (legacy fast-tier form).
    times = [(acc[0] * CACHELINE
              + (mig_up[0] + mig_down[0]) * PAGE_BYTES) / br[0]]
    for r in range(1, R):
        rd = mig_up[r - 1]
        if r < R - 1:
            rd = rd + mig_down[r]
        wr = mig_down[r - 1]
        if r < R - 1:
            wr = wr + mig_up[r]
        times.append((acc[r] * CACHELINE + rd * PAGE_BYTES) / br[r]
                     + wr * PAGE_BYTES / bw[r])
    return t_lat, times


def tier_interval_outcome(mach, acc, mig_up, mig_down):
    """N-tier interval cost (jnp mirror of
    machine_spec.interval_outcome_host, f32).

    ``mach``: TieredMachineSpec leaves [R]; ``acc``: list/array of R f32
    access counts; ``mig_up``/``mig_down``: f32 [R-1] pages crossing each
    adjacent pair.  Returns (wall_s, slow_share, app_bw_frac_raw,
    slow_bw_frac_raw); the *_raw ratios are unclamped (module docstring).
    """
    R = mach.lat_ns.shape[0]
    t_lat, times = _tier_times(mach, acc, mig_up, mig_down)

    rest_max = times[1]
    for r in range(2, R):
        rest_max = jnp.maximum(rest_max, times[r])
    wall = jnp.maximum(jnp.maximum(t_lat, times[0]),
                       jnp.maximum(rest_max, 1e-12))

    rest_acc = acc[1]
    for r in range(2, R):
        rest_acc = rest_acc + acc[r]
    slow_share = rest_acc / jnp.maximum(acc[0] + rest_acc, 1e-9)
    app_raw = times[0] / jnp.maximum(t_lat, jnp.maximum(rest_max, 1e-12))
    slow_raw = rest_max / jnp.maximum(t_lat, jnp.maximum(times[0], 1e-12))
    return wall, slow_share, app_raw, slow_raw


def interval_accounting_impl(mach, true_counts, tier, mig_up, mig_down):
    """Full per-interval cost/accounting step, shared with the numpy engine.

    Returns (acc_fast, acc_slow, wall_s, slow_share, app_bw_frac_raw) as
    f32 scalars; acc_fast/acc_slow aggregate tier 0 vs everything below.
    In CRN mode the numpy engine calls the jitted ``interval_accounting``
    so its arithmetic is bit-identical to the scan engine's.
    """
    R = mach.lat_ns.shape[0]
    true = jnp.asarray(true_counts, jnp.float32)
    accs, _ = tier_access_split(true, tier, R)
    wall, slow_share, app_raw, _ = tier_interval_outcome(
        mach, accs, jnp.asarray(mig_up, jnp.float32),
        jnp.asarray(mig_down, jnp.float32))
    acc_slow = accs[1]
    for r in range(2, R):
        acc_slow = acc_slow + accs[r]
    return accs[0], acc_slow, wall, slow_share, app_raw


interval_accounting = jax.jit(interval_accounting_impl)


def tier_utilization_impl(mach, true_counts, tier, mig_up, mig_down):
    """Per-tier bandwidth utilization f32 [R]: each tier's bandwidth time
    as a fraction of the interval wall time.

    The tier-native policy signal (protocol.PolicySpec.tier_policy):
    ``scheduler.pair_budgets`` runs the BS formula against a pair's
    more-saturated endpoint, so policies back migrations off whichever
    tier of a hop is the bottleneck.  Only tier-native programs compute
    it (statically gated in both engines), so existing compiled paths are
    untouched.  Neutral padded tiers (bw inf) report 0.
    """
    R = mach.lat_ns.shape[0]
    true = jnp.asarray(true_counts, jnp.float32)
    accs, _ = tier_access_split(true, tier, R)
    t_lat, times = _tier_times(mach, accs, jnp.asarray(mig_up, jnp.float32),
                               jnp.asarray(mig_down, jnp.float32))
    stack = jnp.stack(times)
    wall = jnp.maximum(jnp.maximum(t_lat, stack.max()), 1e-12)
    return stack / wall


tier_utilization = jax.jit(tier_utilization_impl)


# ------------------------------------------------------------- migrations
def apply_tier_migrations(tier, promote, demote, caps):
    """Adjacent-pair hop migrations over an i32 tier index, fixed shape.

    ``promote``/``demote`` follow the padded-index contract
    (baselines/protocol.py).  Demotions apply first, in priority order:
    each valid entry (page not already in the bottom tier) cascades down
    to the first tier below its source with free capacity — the bottom
    (``caps[-1] == n``) always has room, so demotions never fail and no
    tier exceeds its capacity.  Promotions then move pages to tier 0,
    capped by tier-0 room after demotions; excess requests are dropped.
    At N=2 the executed sets are bitwise those of the legacy boolean
    ``apply_padded_migrations``.

    Returns (tier, pexec, dexec, mig_up, mig_down): the new placement,
    boolean executed masks aligned with the padded arrays, and i32 [R-1]
    counts of pages crossing each adjacent pair (for per-tier bandwidth
    charging).
    """
    R = caps.shape[0]
    n = tier.shape[0]
    i32 = jnp.int32

    d_safe = jnp.where(demote >= 0, demote, 0)
    src = tier[d_safe]
    dexec = (demote >= 0) & (src < R - 1)
    dest = jnp.full(demote.shape, R - 1, i32)
    landed = jnp.zeros(demote.shape, bool)
    for r in range(1, R - 1):
        # occupancy after departures: every demoted page leaves its source
        # tier (it always lands somewhere below), freeing that slot.
        occ_r = (tier == r).sum() - (dexec & (src == r)).sum()
        cand = dexec & (~landed) & (src < r)
        rank = jnp.cumsum(cand.astype(i32)) - 1
        land = cand & (rank < caps[r] - occ_r)
        dest = jnp.where(land, r, dest)
        landed = landed | land
    tier = tier.at[jnp.where(dexec, demote, n)].set(dest, mode="drop")

    p_safe = jnp.where(promote >= 0, promote, 0)
    p_src = tier[p_safe]
    p_ok = (promote >= 0) & (p_src > 0)
    room = caps[0] - (tier == 0).sum().astype(i32)
    rank = jnp.cumsum(p_ok.astype(i32)) - 1
    pexec = p_ok & (rank < room)
    tier = tier.at[jnp.where(pexec, promote, n)].set(0, mode="drop")

    mig_up = jnp.stack([(pexec & (p_src > j)).sum().astype(i32)
                        for j in range(R - 1)])
    mig_down = jnp.stack([(dexec & (src <= j) & (dest > j)).sum().astype(i32)
                          for j in range(R - 1)])
    return tier, pexec, dexec, mig_up, mig_down


def apply_targeted_migrations(tier, pages, dst, caps):
    """Tier-TARGETED migrations: each valid entry of ``pages`` (sentinel
    -1 padded, priority order, unique per direction) requests a move to
    ``dst[i]``; ``DST_BELOW`` resolves to "first tier below the source
    with room" (the hop-chain demotion cascade).

    Execution order mirrors ``apply_tier_migrations`` exactly:

      * DOWN moves (resolved dst > src) run first, in priority order.
        A down-mover lands at the shallowest tier r >= its requested dst
        with free capacity (cascading deeper when full; the bottom always
        has room), so every down-mover leaves its source — which is what
        keeps the occupancy-after-departures precomputation valid.
      * UP moves (dst < src) then run per destination tier, shallowest
        first, each reading occupancy AFTER the downs and any earlier
        ups; requests that don't fit their exact destination are DROPPED
        (never cascaded), like hop-chain promotions.

    With the binary shim's inputs — demotions first with dst=DST_BELOW,
    then promotions with dst=0 — every expression reduces to the
    corresponding one in ``apply_tier_migrations``, and all arithmetic is
    integer/boolean, so the executed sets (and everything downstream) are
    bitwise identical.  Returns (tier, up_exec, down_exec, mig_up,
    mig_down) with the executed masks aligned to ``pages``.

    TRAILING-SENTINEL INVARIANT (load-bearing for the union fabric,
    simulator/fabric.py): appending sentinel (-1) entries AFTER a plan's
    real moves is a bitwise no-op — invalid entries join neither phase,
    and the cumsum admission ranks only count candidates, so earlier
    entries' prefix sums are untouched.  This is what lets ``UnionSpec``
    widen every member family's move list to one shared ``pad_mv``.
    """
    R = caps.shape[0]
    n = tier.shape[0]
    i32 = jnp.int32

    safe = jnp.where(pages >= 0, pages, 0)
    valid = pages >= 0
    src = tier[safe]
    dst = jnp.where(dst == DST_BELOW, src + 1, dst)
    dst = jnp.clip(dst, 0, R - 1)
    down = valid & (dst > src)           # src == R-1 can never move down

    dest = jnp.full(pages.shape, R - 1, i32)
    landed = jnp.zeros(pages.shape, bool)
    for r in range(1, R - 1):
        # occupancy after departures: every down-mover leaves its source
        # tier (it always lands somewhere below), freeing that slot.
        occ_r = (tier == r).sum() - (down & (src == r)).sum()
        cand = down & (~landed) & (dst <= r)
        rank = jnp.cumsum(cand.astype(i32)) - 1
        land = cand & (rank < caps[r] - occ_r)
        dest = jnp.where(land, r, dest)
        landed = landed | land
    tier = tier.at[jnp.where(down, pages, n)].set(dest, mode="drop")
    mig_down = jnp.stack([(down & (src <= j) & (dest > j)).sum().astype(i32)
                          for j in range(R - 1)])

    # up phase: destination tiers shallowest-first; sources re-read from
    # the updated placement (post-downs, post-earlier-ups), so room freed
    # by ups OUT of a tier is visible to ups INTO it.
    up_exec = jnp.zeros(pages.shape, bool)
    up_from = jnp.zeros(pages.shape, i32)
    for r in range(R - 1):
        u_src = tier[safe]
        cand = valid & (~down) & (dst == r) & (u_src > r)
        room = caps[r] - (tier == r).sum().astype(i32)
        rank = jnp.cumsum(cand.astype(i32)) - 1
        take = cand & (rank < room)
        up_from = jnp.where(take, u_src, up_from)
        tier = tier.at[jnp.where(take, pages, n)].set(r, mode="drop")
        up_exec = up_exec | take
    mig_up = jnp.stack([(up_exec & (up_from > j) & (dst <= j)).sum()
                        .astype(i32) for j in range(R - 1)])
    # every down executes: the cascade bottoms out at R-1, which has room.
    return tier, up_exec, down, mig_up, mig_down


def apply_padded_migrations(in_fast, promote, demote, k: int):
    """Two-tier boolean form, kept for the policy-protocol property tests
    and any binary-placement caller.

    ``promote``/``demote`` follow the padded-index contract
    (baselines/protocol.py): i32 arrays of independent widths whose ``-1``
    entries are padding; valid entries are page indices in priority order.
    Semantics identical to the numpy engine's variable-length version:
    demotions of pages actually in the fast tier are applied first; then
    promotions of pages not (any longer) in the fast tier, in plan order,
    capped by the free capacity after demotions.

    Returns (in_fast, pexec, dexec): the new residency plus boolean masks
    (aligned with the padded arrays) of the executed migrations.
    """
    n = in_fast.shape[0]
    d_safe = jnp.where(demote >= 0, demote, 0)
    dexec = (demote >= 0) & in_fast[d_safe]
    in_fast = in_fast.at[jnp.where(dexec, demote, n)].set(False, mode="drop")

    p_safe = jnp.where(promote >= 0, promote, 0)
    p_ok = (promote >= 0) & (~in_fast[p_safe])
    room = k - in_fast.sum().astype(jnp.int32)
    rank = jnp.cumsum(p_ok.astype(jnp.int32)) - 1
    pexec = p_ok & (rank < room)
    in_fast = in_fast.at[jnp.where(pexec, promote, n)].set(True, mode="drop")
    return in_fast, pexec, dexec


def apply_migrations(in_fast, promote, demote, valid, k: int):
    """Joint-``valid``-mask form (ARMS MigrationPlan layout) of
    ``apply_padded_migrations``: entries with ``valid[i]`` False are treated
    as padding in both arrays."""
    return apply_padded_migrations(
        in_fast, jnp.where(valid, promote, -1),
        jnp.where(valid & (demote >= 0), demote, -1), k)


def wasteful_update(t, promoted_at, demoted_at, promote, demote, pexec,
                    dexec):
    """WASTE_WINDOW accounting for one interval (t = 0-based engine index).

    Returns (wasteful_this_interval, promoted_at, demoted_at).
    """
    n = promoted_at.shape[0]
    p_safe = jnp.where(pexec, promote, 0)
    d_safe = jnp.where(dexec, demote, 0)
    waste = (pexec & (t - demoted_at[p_safe] <= WASTE_WINDOW)).sum() \
        + (dexec & (t - promoted_at[d_safe] <= WASTE_WINDOW)).sum()
    promoted_at = promoted_at.at[jnp.where(pexec, promote, n)].set(
        t, mode="drop")
    demoted_at = demoted_at.at[jnp.where(dexec, demote, n)].set(
        t, mode="drop")
    return waste.astype(jnp.int32), promoted_at, demoted_at
