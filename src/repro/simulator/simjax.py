"""Jittable engine-side bookkeeping shared by both simulation engines.

The numpy reference engine (simulator/engine.py) and the compiled
``lax.scan`` engine (simulator/scan_engine.py) must stay *semantically
aligned*: capacity/validity enforcement, wasteful-migration accounting and
the interval cost model are defined once here, as pure jax functions, and
used by both.  The numpy engine calls them per interval in CRN mode (where
bitwise agreement with the scan engine matters); the scan engine inlines
them into its scan body.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.simulator.engine import WASTE_WINDOW
from repro.simulator.machine import CACHELINE, PAGE_BYTES, MachineSpec
from repro.utils.pytree import pytree_dataclass


@pytree_dataclass
class MachineParams:
    """f32 leaves of a MachineSpec, so the cost model is scan/vmap friendly."""

    lat_fast_ns: jnp.ndarray
    lat_slow_ns: jnp.ndarray
    bw_fast: jnp.ndarray
    bw_slow_read: jnp.ndarray
    bw_slow_write: jnp.ndarray
    mlp: jnp.ndarray


def machine_params(m: MachineSpec) -> MachineParams:
    f = lambda v: jnp.asarray(v, jnp.float32)
    return MachineParams(
        lat_fast_ns=f(m.lat_fast_ns), lat_slow_ns=f(m.lat_slow_ns),
        bw_fast=f(m.bw_fast), bw_slow_read=f(m.bw_slow_read),
        bw_slow_write=f(m.bw_slow_write), mlp=f(m.mlp))


def interval_outcome(mp: MachineParams, acc_fast, acc_slow, promo_pages,
                     demo_pages):
    """jnp mirror of machine.interval_time + the engine's signal derivation.

    Returns (wall_s, slow_share, app_bw_frac):
      * ``slow_share`` is the slow-access share the engine feeds to the PHT
        (engine.py rationale: utilization pegs at 1 under saturation);
      * ``app_bw_frac`` is fast-tier bandwidth utilization for BS throttling.
    """
    app_fast_bytes = acc_fast * CACHELINE
    app_slow_bytes = acc_slow * CACHELINE
    mig_fast_bytes = (promo_pages + demo_pages) * PAGE_BYTES
    mig_slow_read = promo_pages * PAGE_BYTES
    mig_slow_write = demo_pages * PAGE_BYTES

    t_lat = (acc_fast * mp.lat_fast_ns
             + acc_slow * mp.lat_slow_ns) * 1e-9 / mp.mlp
    t_bw_fast = (app_fast_bytes + mig_fast_bytes) / mp.bw_fast
    t_bw_slow = ((app_slow_bytes + mig_slow_read) / mp.bw_slow_read
                 + mig_slow_write / mp.bw_slow_write)
    wall = jnp.maximum(jnp.maximum(t_lat, t_bw_fast),
                       jnp.maximum(t_bw_slow, 1e-12))
    slow_share = acc_slow / jnp.maximum(acc_fast + acc_slow, 1e-9)
    app_frac = jnp.minimum(1.0, t_bw_fast / wall)
    return wall, slow_share, app_frac


def apply_padded_migrations(in_fast, promote, demote, k: int):
    """Engine-side validation + capacity enforcement, fixed shape.

    ``promote``/``demote`` follow the padded-index contract
    (baselines/protocol.py): i32 arrays of independent widths whose ``-1``
    entries are padding; valid entries are page indices in priority order.
    Semantics identical to the numpy engine's variable-length version:
    demotions of pages actually in the fast tier are applied first; then
    promotions of pages not (any longer) in the fast tier, in plan order,
    capped by the free capacity after demotions.

    Returns (in_fast, pexec, dexec): the new residency plus boolean masks
    (aligned with the padded arrays) of the executed migrations.
    """
    n = in_fast.shape[0]
    d_safe = jnp.where(demote >= 0, demote, 0)
    dexec = (demote >= 0) & in_fast[d_safe]
    in_fast = in_fast.at[jnp.where(dexec, demote, n)].set(False, mode="drop")

    p_safe = jnp.where(promote >= 0, promote, 0)
    p_ok = (promote >= 0) & (~in_fast[p_safe])
    room = k - in_fast.sum().astype(jnp.int32)
    rank = jnp.cumsum(p_ok.astype(jnp.int32)) - 1
    pexec = p_ok & (rank < room)
    in_fast = in_fast.at[jnp.where(pexec, promote, n)].set(True, mode="drop")
    return in_fast, pexec, dexec


def apply_migrations(in_fast, promote, demote, valid, k: int):
    """Joint-``valid``-mask form (ARMS MigrationPlan layout) of
    ``apply_padded_migrations``: entries with ``valid[i]`` False are treated
    as padding in both arrays."""
    return apply_padded_migrations(
        in_fast, jnp.where(valid, promote, -1),
        jnp.where(valid & (demote >= 0), demote, -1), k)


def wasteful_update(t, promoted_at, demoted_at, promote, demote, pexec,
                    dexec):
    """WASTE_WINDOW accounting for one interval (t = 0-based engine index).

    Returns (wasteful_this_interval, promoted_at, demoted_at).
    """
    n = promoted_at.shape[0]
    p_safe = jnp.where(pexec, promote, 0)
    d_safe = jnp.where(dexec, demote, 0)
    waste = (pexec & (t - demoted_at[p_safe] <= WASTE_WINDOW)).sum() \
        + (dexec & (t - promoted_at[d_safe] <= WASTE_WINDOW)).sum()
    promoted_at = promoted_at.at[jnp.where(pexec, promote, n)].set(
        t, mode="drop")
    demoted_at = demoted_at.at[jnp.where(dexec, demote, n)].set(
        t, mode="drop")
    return waste.astype(jnp.int32), promoted_at, demoted_at


@jax.jit
def interval_accounting(mp: MachineParams, true_counts, in_fast, promo_pages,
                        demo_pages):
    """Full per-interval cost/accounting step, shared with the numpy engine.

    Returns (acc_fast, acc_slow, wall_s, slow_share, app_bw_frac) as f32
    scalars; in CRN mode the numpy engine calls this so its arithmetic is
    bit-identical to the scan engine's.
    """
    true = jnp.asarray(true_counts, jnp.float32)
    acc_fast = jnp.sum(true * in_fast)
    acc_slow = jnp.sum(true) - acc_fast
    wall, slow_share, app_frac = interval_outcome(
        mp, acc_fast, acc_slow, jnp.asarray(promo_pages, jnp.float32),
        jnp.asarray(demo_pages, jnp.float32))
    return acc_fast, acc_slow, wall, slow_share, app_frac
