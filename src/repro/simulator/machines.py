"""Machine registry: one ``machines.get()`` lookup for every call site.

Anywhere the simulator accepts a machine — ``engine.run``,
``scan_engine.simulate``, ``tuning.tune``, ``experiment.sweep``, the
benchmarks — it accepts a registry NAME (``"pmem-large"``, ``"numa"``,
``"cxl-1hop"``, ``"dram-cxl-pmem"``), a legacy two-tier ``MachineSpec``,
or a ``TieredMachineSpec``; resolution happens here instead of each call
site importing the preset dict.

Presets (Table-3-style; the two-tier ones are exact conversions of the
paper's Table 3 rows in machine.py):

  * ``pmem-large`` — DRAM + Optane PMem (paper's main machine);
  * ``numa``       — emulated-CXL remote NUMA node (paper §7.3);
  * ``cxl-1hop``   — DRAM + one-hop CXL-attached expander: DRAM-class
    media behind a CXL.mem link, so latency sits between local DRAM and
    PMem while read/write bandwidth stay symmetric-ish (HybridTier's
    CXL setting);
  * ``dram-cxl-pmem`` — three-tier chain: DRAM (capacity k), CXL
    expander (capacity 2k), PMem bottom (unbounded) — the multi-tier
    thrashing topology of Jenga's analysis;
  * ``hbm-pcie``  — accelerator HBM over host memory via PCIe: the
    serving-layer topology (paged-KV / expert slabs / embedding blocks,
    tiering/tiered_pool.py), tier-0 bandwidth pinned to the roofline's
    HBM constant so the serving cost model and roofline agree.
"""
from __future__ import annotations

from repro import roofline
from repro.simulator import machine as machine_mod
from repro.simulator import machine_spec
from repro.simulator.machine_spec import TieredMachineSpec

# Serving topology: accelerator HBM (tier 0, the roofline's memory-bound
# bandwidth — src/repro/roofline.py) over host memory reached through PCIe
# (~25 GB/s, the expert-slab latency budget in tiering/expert_tiering.py).
# This is the machine the TieredPool serving cost model charges against.
HBM_PCIE = machine_spec.make(
    "hbm-pcie",
    lat_ns=[120.0, 900.0],
    bw_read=[roofline.HBM_BW, 25e9],
    bw_write=[roofline.HBM_BW, 25e9])

CXL_1HOP = machine_spec.make(
    "cxl-1hop",
    lat_ns=[80.0, 250.0],
    bw_read=[138e9, 30e9],
    bw_write=[138e9, 25e9])

DRAM_CXL_PMEM = machine_spec.make(
    "dram-cxl-pmem",
    lat_ns=[80.0, 250.0, 400.0],
    bw_read=[138e9, 30e9, 7.45e9],
    bw_write=[138e9, 25e9, 2.25e9],
    capacity_pages=[-1.0, -2.0, 0.0])   # k / 2k / unbounded

REGISTRY: dict[str, TieredMachineSpec] = {
    **{nm: machine_spec.from_machine(m)
       for nm, m in machine_mod.MACHINES.items()},
    "cxl-1hop": CXL_1HOP,
    "dram-cxl-pmem": DRAM_CXL_PMEM,
    "hbm-pcie": HBM_PCIE,
}


def names() -> list[str]:
    return sorted(REGISTRY)


def get(m) -> TieredMachineSpec:
    """Resolve anything machine-shaped to a ``TieredMachineSpec``."""
    if isinstance(m, TieredMachineSpec):
        return m
    if isinstance(m, machine_mod.MachineSpec):
        return machine_spec.from_machine(m)
    if isinstance(m, str):
        key = m.lower()
        if key not in REGISTRY:
            raise ValueError(f"unknown machine {m!r}; known: {names()}")
        return REGISTRY[key]
    raise TypeError(f"machine must be a name, MachineSpec or "
                    f"TieredMachineSpec, got {type(m).__name__}")
