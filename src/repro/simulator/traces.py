"""Trace-derived workloads: capture real serving access streams, replay
them as simulator lanes, and FIT WorkloadSpec knobs to them (DESIGN.md §10).

Three pieces close the model-stack loop:

* ``TraceCapture`` / ``TraceWorkload`` — accumulate per-step access
  vectors from a real run (paged-KV attention mass per page, MoE router
  load per expert, embedding row touches per block), grouped into policy
  intervals, into a replayable [T, n] trace.  Counts are stored f64 and
  grouping is a plain ``np.add.reduceat``, so the round-trip conserves
  total access counts exactly (tests/test_traces.py).
* ``replay`` — run the captured trace as a lane in ``experiment.sweep``'s
  trace-replay mode: the serving stream becomes a first-class workload
  next to the synthetic specs, for any registered policy family.
* ``fit_workload_spec`` — a deterministic estimator mapping a captured
  stream onto WorkloadSpec knobs (hot fraction / hot weight from the mean
  access distribution, churn rate from hot-set overlap decay, duty cycle
  from busy/idle run lengths).  The fitted spec is FRACTIONAL in n, so a
  trace captured over 8 KV pages scales to a 4096-page sweep lane, a
  tuning study (``tuning.tune(workloads=[fit])``), or a robustness-
  leaderboard scenario.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.simulator.workload_spec import (KIND_HOTSET, NEVER, WorkloadSpec,
                                           _comp, _from_comps, with_label)

__all__ = ["TraceWorkload", "TraceCapture", "capture_from_steps",
           "fit_workload_spec", "replay"]


@dataclasses.dataclass
class TraceWorkload:
    """A captured access stream: ``counts[t, p]`` accesses to page p in
    policy interval t.  f64 on host (exact-conservation contract)."""

    counts: np.ndarray
    label: str = "trace"
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.counts = np.asarray(self.counts, np.float64)
        if self.counts.ndim != 2:
            raise ValueError(f"trace must be [T, n], got "
                             f"{self.counts.shape}")

    @property
    def T(self) -> int:
        return self.counts.shape[0]

    @property
    def n(self) -> int:
        return self.counts.shape[1]

    def total(self) -> float:
        """Total access count (f64; the conservation invariant)."""
        return float(self.counts.sum())

    def save(self, path: str) -> None:
        np.savez(path, counts=self.counts, label=self.label)

    @classmethod
    def load(cls, path: str) -> "TraceWorkload":
        with np.load(path, allow_pickle=False) as z:
            return cls(counts=z["counts"], label=str(z["label"]))


@dataclasses.dataclass
class TraceCapture:
    """Streaming capture: ``add`` one per-step access vector at a time;
    ``finish`` groups ``group`` consecutive steps into one policy interval
    (summed — conservation is exact, f64 reduceat)."""

    n: int
    group: int = 1
    _rows: list = dataclasses.field(default_factory=list)

    def add(self, access) -> None:
        row = np.asarray(access, np.float64).reshape(-1)
        if row.shape[0] != self.n:
            raise ValueError(f"expected [{self.n}] access vector, got "
                             f"{row.shape}")
        self._rows.append(row)

    @property
    def steps(self) -> int:
        return len(self._rows)

    def finish(self, label: str = "trace", meta: dict | None = None,
               drop_partial: bool = False) -> TraceWorkload:
        if not self._rows:
            raise ValueError("empty capture")
        rows = np.stack(self._rows)                      # [steps, n] f64
        g = max(1, int(self.group))
        steps = rows.shape[0]
        if drop_partial:
            steps = (steps // g) * g
            rows = rows[:steps]
        if steps == 0:
            raise ValueError("capture shorter than one policy interval")
        counts = np.add.reduceat(rows, np.arange(0, steps, g), axis=0)
        return TraceWorkload(counts=counts, label=label,
                             meta=dict(meta or {}, steps=steps, group=g))


def capture_from_steps(steps, group: int = 1,
                       label: str = "trace") -> TraceWorkload:
    """One-shot capture of a stacked [steps, n] access array."""
    steps = np.asarray(steps, np.float64)
    cap = TraceCapture(n=steps.shape[1], group=group)
    for row in steps:
        cap.add(row)
    return cap.finish(label=label)


def replay(tw: TraceWorkload, policies, machines="pmem-large", k: int = 0,
           **kw):
    """Run the captured trace as a sweep lane (trace-replay mode): the
    workload axis collapses to this single trace."""
    from repro.simulator import experiment
    k = k or max(1, tw.n // 4)
    return experiment.sweep(policies, trace=np.asarray(tw.counts,
                                                       np.float32),
                            machines=machines, k=k, **kw)


# ------------------------------------------------------------------ fitting
def _hot_stats(counts, hot_cover: float):
    """(hot_frac, hot_weight): smallest page fraction covering
    ``hot_cover`` of the mean access distribution."""
    n = counts.shape[1]
    p = counts.sum(0)
    tot = p.sum()
    if tot <= 0:
        return 1.0, 1.0
    p = np.sort(p / tot)[::-1]
    cum = np.cumsum(p)
    hot_k = int(np.argmax(cum >= hot_cover)) + 1
    return hot_k / n, float(cum[hot_k - 1])


def _churn(counts, hot_k: int):
    """Mean per-interval hot-set churn -> ``shift_every`` estimate.

    Windowed top-k sets; 1 - mean overlap between consecutive windows,
    normalized per interval.  A fully static hot set maps to NEVER."""
    T = counts.shape[0]
    W = max(1, T // 8)
    tops = []
    for s in range(0, T - W + 1, W):
        win = counts[s:s + W].sum(0)
        tops.append(set(np.argsort(-win, kind="stable")[:hot_k].tolist()))
    if len(tops) < 2:
        return NEVER
    overlaps = [len(a & b) / max(len(a), 1)
                for a, b in zip(tops[:-1], tops[1:])]
    churn_per_interval = (1.0 - float(np.mean(overlaps))) / W
    if churn_per_interval <= 1e-6:
        return NEVER
    return int(np.clip(round(1.0 / churn_per_interval), 1, NEVER))


def _duty(counts):
    """(period, duty, idle_scale) from the per-interval total series."""
    totals = counts.sum(1)
    peak = totals.max()
    if peak <= 0:
        return 1, 1.0, 1.0
    busy = totals > 0.05 * peak
    duty = float(busy.mean())
    if duty >= 1.0 - 1e-9:
        return 1, 1.0, 1.0
    # busy-run count -> period; idle_scale = idle-phase mean / busy mean
    starts = int(np.sum(busy[1:] & ~busy[:-1]) + int(busy[0]))
    period = max(2, int(round(len(totals) / max(starts, 1))))
    busy_mean = float(totals[busy].mean())
    idle_mean = float(totals[~busy].mean()) if (~busy).any() else 0.0
    return period, max(duty, 1.0 / period), \
        idle_mean / max(busy_mean, 1e-12)


def fit_workload_spec(tw: TraceWorkload, seed: int = 0,
                      hot_cover: float = 0.9) -> WorkloadSpec:
    """Fit a KIND_HOTSET WorkloadSpec to a captured trace.

    Pure function of (trace, seed) — bit-deterministic under a fixed seed
    (the CRN discipline; asserted in tests/test_traces.py), so fitted
    lanes pair exactly across sweep runs.
    """
    counts = np.asarray(tw.counts, np.float64)
    T, n = counts.shape
    hot_frac, hot_weight = _hot_stats(counts, hot_cover)
    hot_k = max(1, int(round(hot_frac * n)))
    shift_every = _churn(counts, hot_k)
    period, duty, idle_scale = _duty(counts)
    busy = counts.sum(1) > 0.05 * max(float(counts.sum(1).max()), 1e-12)
    work = float(counts.sum(1)[busy].mean()) if busy.any() \
        else float(counts.sum() / max(T, 1))
    spec = _from_comps([_comp(
        KIND_HOTSET, work=work, hot_frac=min(max(hot_frac, 1.0 / n), 1.0),
        hot_weight=min(max(hot_weight, 0.0), 1.0),
        shift_every=shift_every, period=period, duty=duty,
        idle_scale=min(max(idle_scale, 0.0), 1.0), seed=seed)])
    return with_label(spec, f"fit:{tw.label}")
