"""Compiled adaptive search engine: ASHA + cross-entropy tuning loops.

``tuning.tune`` historically scored one seeded random grid as a single
lane-batched sweep.  This module turns search itself into a compiled
engine: every *round* of an adaptive strategy — an elimination rung of
successive halving, a redraw generation of cross-entropy — is ONE
``experiment.sweep`` dispatch per policy family, with the round's config
population riding the policy axis and every lane sharing the CRN noise
field, so elimination decisions are paired comparisons (config
differences are never confounded with sampling noise).

Strategies (``run(family, strategy, ...)``):

  * ``"grid"`` — the historical exhaustive scoring of the sampled grid,
    one full-horizon dispatch; the compute reference the adaptive
    strategies are compared against.
  * ``"asha"`` — successive halving: round ``r`` of ``R`` simulates the
    surviving population at horizon ``T_r = T_full * eta**(r - R)``
    (clamped to ``t_min``), keeps the top ``1/eta`` under a stable
    exec-time ranking (a fully-tied rung eliminates nobody — zero
    information means an eta-cut would be draw-order luck), and the
    final round re-simulates survivors at the full horizon — total
    lane-intervals are a geometric fraction of the grid's
    ``budget * T_full`` whenever the rungs carry signal.
  * ``"ce"`` — cross-entropy: each round draws a population from a
    per-knob sampling distribution (categorical over the grid values;
    truncated normal for knobs named in ``CONTINUOUS_KNOBS`` — the ARMS
    alphas leave the grid entirely), scores it at full horizon, and
    refits the distribution from the elite set.  Deterministic under
    ``search_seed`` (one ``default_rng([search_seed, group])`` stream per
    group).

All strategies return a ``SearchResult`` carrying the per-round records
(population, survivors, dispatches, lane-intervals), so strategies are
comparable on *compute spent*, not just best-found:
``SearchResult.lane_intervals`` is the sum over rounds of
``dispatch lanes x horizon`` — the same unit for grid, ASHA and CE.

Lane modes: the search population can be scored per machine
(``machines=[...]``: per-machine elimination with the round dispatch
covering the union population x M machine lanes) or per workload
(``workloads=[...]``, ``T``/``n``) — both return ``{label: SearchResult}``
and both keep one dispatch per round.  ``transfer_matrix`` builds the
companion paper's headline robustness experiment on top of machine-lane
mode: tune per machine, then cross-evaluate every machine's tuned config
on every machine in ONE final sweep and report the A->B
slowdown-vs-native table.

ARMS keeps its precomputed-grid "pre" fast path: trace-mode
single-machine searches over SWEEPABLE knobs route through
``scan_engine.sweep_arms_configs`` (observation grids computed once and
shared by all config lanes) with streaming reduction; machine- or
workload-lane ARMS searches fall back to the generic CRN sweep.

``tuning.tune(strategy=...)`` / ``tune_hemem`` / ``tune_arms`` are thin
views over ``run`` keeping the historical ``(best_cfg, best_res, rows)``
return shape.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.baselines.arms_policy import SWEEPABLE, ARMSSpec
from repro.simulator import experiment, scan_engine
from repro.simulator import machines as machines_mod
from repro.simulator.engine import SimResult

__all__ = [
    "CONTINUOUS_KNOBS", "RoundRecord", "SearchResult", "TransferMatrix",
    "rank_rows", "run", "transfer_matrix",
]

#: family -> knobs the cross-entropy strategy samples continuously (from a
#: truncated normal over the grid's [min, max] range) instead of from the
#: grid's categorical values.  The ARMS alphas are genuinely continuous
#: controller gains; every other family's knobs are integer-ish grid values.
CONTINUOUS_KNOBS = {
    "arms": frozenset({"alpha_s", "alpha_l"}),
    "hybridtier": frozenset({"decay"}),
    "jenga": frozenset({"alpha"}),
    "tierbpf": frozenset({"alpha", "admit_thresh", "thrash_gain",
                          "regret_alpha"}),
}

STRATEGIES = ("grid", "asha", "ce")


def _cfg_key(cfg: dict) -> tuple:
    return tuple(sorted(cfg.items()))


def rank_rows(rows):
    """Stable exec-time ranking of ``(config, SimResult)`` rows.

    ``sorted`` is stable, so rows with bitwise-equal ``exec_time_s`` keep
    their draw order — rankings are deterministic even when CRN pairing
    makes duplicate configs score identically (asserted in
    tests/test_search.py).
    """
    return sorted(rows, key=lambda cr: cr[1].exec_time_s)


@dataclasses.dataclass
class RoundRecord:
    """One search round: ONE compiled dispatch per policy family."""

    index: int          #: 1-based round number
    horizon: int        #: intervals simulated this round (T_r)
    population: dict    #: group label -> configs entering the round
    survivors: dict     #: group label -> configs kept for the next round
    best_score: dict    #: group label -> best exec_time_s AT THIS HORIZON
    lanes: int          #: lanes of this round's dispatch
    dispatches: int     #: compiled dispatches this round (1 per family)
    lane_intervals: int  #: lanes * horizon — the round's compute spend


@dataclasses.dataclass
class SearchResult:
    """Outcome of one strategy run (per group in machine/workload modes).

    ``rows`` is the final FULL-horizon ranking (stable; see
    ``rank_rows``); ``rounds`` carries the shared per-round records —
    in machine/workload-lane modes every group's result holds the same
    round list, whose dispatch/lane-interval numbers cover the whole
    grouped search (the groups shared each round's dispatch).
    """

    family: str
    strategy: str
    best_config: dict
    best_result: SimResult
    rows: list
    rounds: list
    dispatches: int
    lane_intervals: int

    def curve(self):
        """[(cumulative lane-intervals, best exec_time_s at that round's
        horizon)] — the compute-vs-quality trajectory BENCH_search.json
        records.  Scores of non-final ASHA rounds are short-horizon."""
        pts, cum = [], 0
        for rec in self.rounds:
            cum += rec.lane_intervals
            best = min(rec.best_score.values())
            pts.append((cum, best))
        return pts


class _EvalCtx:
    """Shared evaluation state for one search.

    Resolves the trace / workload specs / machine lanes once, then scores
    a config population at any horizon as ONE compiled dispatch (per
    policy family): the population rides the policy axis of
    ``experiment.sweep`` (or the ARMS "pre" sweep), machine or workload
    lanes ride their own axes, and all lanes share the CRN noise source
    seeded by ``sim_seed``.  Short horizons slice the trace prefix
    (trace mode) or scan fewer synthesis intervals of the full-T-resolved
    workload specs (synth mode — the counter-based CRN rows make short
    runs exact prefixes of full runs).
    """

    def __init__(self, family, make, trace, machine, machines, workloads,
                 k, T, n, sim_seed, base_cfg, space, mesh=None):
        if machines is not None and workloads is not None:
            raise ValueError("machine-lane and workload-lane search modes "
                             "cannot be combined; pass one of them")
        self.family, self.make, self.k = family, make, k
        self.sim_seed, self.base_cfg = sim_seed, base_cfg
        self.mesh = mesh
        mach_in = list(machines) if machines is not None else [machine]
        self.machines = [machines_mod.get(m) for m in mach_in]
        self.wl_specs = None
        if workloads is not None:
            if trace is not None:
                raise ValueError("pass either trace or workloads, not both")
            if T is None or n is None:
                raise ValueError("workload-lane tuning needs T and n")
            self.trace = None
            self.wl_specs, names = experiment._resolve_workloads(
                list(workloads), T)
            self.T_full, self.n = int(T), int(n)
            self.group_axis = "workload"
            self.groups = experiment._dedup_labels(names)
        else:
            if trace is None:
                raise ValueError("need a trace or a workloads list")
            self.trace = np.asarray(trace)
            self.T_full, self.n = self.trace.shape
            if machines is not None:
                self.group_axis = "machine"
                self.groups = experiment._dedup_labels(
                    [m.name for m in self.machines])
            else:
                self.group_axis = None
                self.groups = [None]
        # ARMS precomputed-grid fast path: per-mode observation grids are
        # computed once from the CRN field and shared across config lanes.
        self.use_pre = (family == "arms" and self.trace is not None
                        and self.group_axis is None
                        and set(space) <= SWEEPABLE)

    def eval(self, configs, horizon: int):
        """Score ``configs`` at ``horizon`` -> (per-group result lists,
        lanes, dispatches, lane-intervals).  One compiled dispatch per
        policy family (asserted by the CI search gate via the dispatch
        delta)."""
        horizon = int(horizon)
        with scan_engine.count_dispatches() as ctr:
            if self.use_pre:
                # precomputed-grid path: single machine, single family, no
                # lane batch to shard — ``mesh`` intentionally ignored.
                overrides = {nm: [cfg[nm] for cfg in configs]
                             for nm in configs[0]}
                results = scan_engine.sweep_arms_configs(
                    self.trace[:horizon], self.machines[0], self.k,
                    overrides, base_cfg=self.base_cfg, seed=self.sim_seed,
                    reduce="stream")
                per_group = [results]
            else:
                specs = [self.make(**cfg) for cfg in configs]
                if self.group_axis == "workload":
                    res = experiment.sweep(
                        specs, workloads=self.wl_specs,
                        machines=[self.machines[0]], k=self.k, T=horizon,
                        n=self.n, sim_seed=self.sim_seed, mesh=self.mesh)
                    per_group = [[res.at(policy=b, workload=g)
                                  for b in range(len(configs))]
                                 for g in range(len(self.groups))]
                else:
                    res = experiment.sweep(
                        specs, trace=self.trace[:horizon],
                        machines=self.machines, k=self.k,
                        sim_seed=self.sim_seed, mesh=self.mesh)
                    per_group = [[res.at(policy=b, machine=g)
                                  for b in range(len(configs))]
                                 for g in range(len(self.groups))]
        # ``lanes`` from the dispatch record is LOGICAL (pre-padding), so
        # lane_intervals — and every ASHA/CE compute curve built from it —
        # is identical at any mesh size.
        lanes = ctr.last.get("lanes", len(configs))
        return per_group, lanes, ctr.count, lanes * horizon


def _union(pops):
    """Ordered-dedup union of all groups' populations -> (configs, key->idx).

    Grouped searches evaluate each distinct config once per round even
    when several groups keep it alive; duplicate configs *within* a
    population (allowed, e.g. explicit ``configs`` lists) share a lane.
    """
    union, keyidx = [], {}
    for pop in pops.values():
        for cfg in pop:
            key = _cfg_key(cfg)
            if key not in keyidx:
                keyidx[key] = len(union)
                union.append(cfg)
    return union, keyidx


def _round_rows(pops, per_group, keyidx, groups):
    """Per-group ``(config, SimResult)`` rows in draw order."""
    return {g: [(cfg, per_group[gi][keyidx[_cfg_key(cfg)]])
                for cfg in pops[g]]
            for gi, g in enumerate(groups)}


def _grid(ctx, family, configs):
    """Exhaustive full-horizon scoring — the historical ``tuning.tune``."""
    pops = {g: list(configs) for g in ctx.groups}
    union, keyidx = _union(pops)
    per_group, lanes, disp, li = ctx.eval(union, ctx.T_full)
    rows_g = _round_rows(pops, per_group, keyidx, ctx.groups)
    ranked = {g: rank_rows(rows_g[g]) for g in ctx.groups}
    rec = RoundRecord(1, ctx.T_full, pops,
                      {g: [c for c, _ in ranked[g]] for g in ctx.groups},
                      {g: ranked[g][0][1].exec_time_s for g in ctx.groups},
                      lanes, disp, li)
    return {g: SearchResult(family, "grid", ranked[g][0][0],
                            ranked[g][0][1], ranked[g], [rec], disp, li)
            for g in ctx.groups}


def _n_rounds(n0: int, eta: int, T_full: int, t_min: int,
              rounds) -> int:
    if rounds is not None:
        return max(1, int(rounds))
    if eta <= 1 or n0 <= eta or t_min >= T_full:
        return 1
    return max(1, math.ceil(math.log(n0) / math.log(eta)))


def _asha(ctx, family, configs, eta: int, rounds, t_min: int):
    """Successive halving: geometric horizon ladder, stable elimination.

    Non-final rounds keep the top ``ceil(pop/eta)`` — unless the round's
    ranking is FULLY tied (zero information), in which case nobody is
    eliminated and the ladder continues with the whole population."""
    eta = max(1, int(eta))
    T_full = ctx.T_full
    R = _n_rounds(len(configs), eta, T_full, t_min, rounds)
    pops = {g: list(configs) for g in ctx.groups}
    recs, total_disp, total_li = [], 0, 0
    final_rows = {}
    for r in range(1, R + 1):
        if r == R:
            T_r = T_full
        else:
            T_r = min(T_full, max(int(t_min),
                                  math.ceil(T_full * eta ** (r - R))))
        union, keyidx = _union(pops)
        per_group, lanes, disp, li = ctx.eval(union, T_r)
        total_disp += disp
        total_li += li
        rows_g = _round_rows(pops, per_group, keyidx, ctx.groups)
        surv, best = {}, {}
        for g in ctx.groups:
            ranked = rank_rows(rows_g[g])
            best[g] = ranked[0][1].exec_time_s
            if r < R:
                if ranked[0][1].exec_time_s == ranked[-1][1].exec_time_s:
                    # Zero-information rung: every lane scored
                    # bitwise-identically under the shared CRN (the knobs
                    # are inert at this horizon — e.g. Memtis cooling
                    # periods that first fire late in the trace).  An
                    # eta-cut here would eliminate by draw order alone,
                    # so refuse and carry the whole population; the
                    # search degrades toward exhaustive scoring instead
                    # of returning a draw-lucky config.
                    surv[g] = list(pops[g])
                    continue
                keep = max(1, math.ceil(len(ranked) / eta))
                top = {_cfg_key(c) for c, _ in ranked[:keep]}
                # survivors keep DRAW order (not rank order) so later
                # rounds' tie-breaking stays anchored to the draw.
                surv[g] = [c for c in pops[g] if _cfg_key(c) in top]
            else:
                surv[g] = [c for c, _ in ranked]
                final_rows[g] = ranked
        recs.append(RoundRecord(r, T_r,
                                {g: list(pops[g]) for g in ctx.groups},
                                {g: list(surv[g]) for g in ctx.groups},
                                best, lanes, disp, li))
        pops = surv
    return {g: SearchResult(family, "asha", final_rows[g][0][0],
                            final_rows[g][0][1], final_rows[g], recs,
                            total_disp, total_li)
            for g in ctx.groups}


def _init_dists(space, cont, rng_unused=None):
    dists = {}
    for nm in sorted(space):
        vals = [float(v) for v in space[nm]]
        if nm in cont:
            lo, hi = min(vals), max(vals)
            dists[nm] = dict(kind="cont", lo=lo, hi=hi,
                             mu=float(np.mean(vals)),
                             sigma=max((hi - lo) / 2.0, 1e-6))
        else:
            dists[nm] = dict(kind="disc", vals=list(space[nm]),
                             p=np.full(len(vals), 1.0 / len(vals)))
    return dists


def _ce_draw(rng, dists, space):
    cfg = {}
    for nm in sorted(space):
        d = dists[nm]
        if d["kind"] == "disc":
            cfg[nm] = d["vals"][int(rng.choice(len(d["vals"]), p=d["p"]))]
        else:
            cfg[nm] = float(np.clip(rng.normal(d["mu"], d["sigma"]),
                                    d["lo"], d["hi"]))
    # present knobs in the space's declaration order, like _sample_grid
    return {nm: cfg[nm] for nm in space}


def _ce_refit(dists, elite, smoothing: float):
    for nm, d in dists.items():
        ev = [cfg[nm] for cfg, _ in elite]
        if d["kind"] == "disc":
            freq = np.array([float(sum(1 for v in ev if v == val))
                             for val in d["vals"]]) / len(ev)
            p = (1.0 - smoothing) * d["p"] + smoothing * freq
            d["p"] = p / p.sum()
        else:
            d["mu"] = (1.0 - smoothing) * d["mu"] \
                + smoothing * float(np.mean(ev))
            # sigma floor keeps a sliver of exploration alive so a
            # degenerate elite set cannot freeze the distribution.
            d["sigma"] = max((1.0 - smoothing) * d["sigma"]
                             + smoothing * float(np.std(ev)),
                             1e-3 * (d["hi"] - d["lo"]))


def _ce(ctx, family, space, defaults, budget: int, rounds: int,
        elite_frac: float, smoothing: float, search_seed: int):
    """Cross-entropy over the knob space: redraw from an elite-fit
    distribution each round, all rounds scored at the full horizon."""
    R = max(1, int(rounds))
    pop_n = max(2, math.ceil(budget / R))
    cont = CONTINUOUS_KNOBS.get(family, frozenset())
    dists = {g: _init_dists(space, cont) for g in ctx.groups}
    rngs = {g: np.random.default_rng([int(search_seed), gi])
            for gi, g in enumerate(ctx.groups)}
    seen = {g: {} for g in ctx.groups}   # cfg key -> (cfg, res), draw order
    recs, total_disp, total_li = [], 0, 0
    for r in range(1, R + 1):
        pops = {}
        for g in ctx.groups:
            draws = [dict(defaults)] if (r == 1 and defaults) else []
            while len(draws) < pop_n:
                draws.append(_ce_draw(rngs[g], dists[g], space))
            pops[g] = draws
        union, keyidx = _union(pops)
        per_group, lanes, disp, li = ctx.eval(union, ctx.T_full)
        total_disp += disp
        total_li += li
        rows_g = _round_rows(pops, per_group, keyidx, ctx.groups)
        surv, best = {}, {}
        for g in ctx.groups:
            ranked = rank_rows(rows_g[g])
            best[g] = ranked[0][1].exec_time_s
            elite = ranked[:max(1, int(len(ranked) * elite_frac))]
            surv[g] = [c for c, _ in elite]
            _ce_refit(dists[g], elite, smoothing)
            for cfg, res in rows_g[g]:
                seen[g].setdefault(_cfg_key(cfg), (cfg, res))
        recs.append(RoundRecord(r, ctx.T_full, pops, surv, best, lanes,
                                disp, li))
    out = {}
    for g in ctx.groups:
        # every round ran at the full horizon under the same CRN noise, so
        # rows from different rounds are directly comparable (and repeat
        # draws scored identically — first draw kept).
        rows = rank_rows(list(seen[g].values()))
        out[g] = SearchResult(family, "ce", rows[0][0], rows[0][1], rows,
                              recs, total_disp, total_li)
    return out


def run(family: str, strategy: str = "asha", *, trace=None,
        machine="pmem-large", machines=None, workloads=None, k: int,
        budget: int = 24, eta: int = 3, rounds=None, t_min: int = 16,
        ce_rounds: int = 4, elite_frac: float = 0.25,
        ce_smoothing: float = 0.7, search_seed: int = 0, sim_seed: int = 0,
        space: dict | None = None, defaults: dict | None = None,
        base_cfg=None, configs=None, T: int | None = None,
        n: int | None = None, mesh=None):
    """Run one search strategy for one policy family.

    Modes mirror ``tuning.tune``: trace + single ``machine`` returns ONE
    ``SearchResult``; ``machines=[...]`` (machine-lane mode) or
    ``workloads=[...]`` + ``T``/``n`` (workload-lane mode) return
    ``{label: SearchResult}`` with per-group searches sharing each
    round's single dispatch.  ``configs`` overrides the seeded grid draw
    (grid/asha initial population; CE always redraws from its fitted
    distribution, seeded by ``search_seed``).

    ``budget`` is the population size for grid/asha and the total draw
    count across CE rounds (``ce_rounds`` populations of
    ``ceil(budget / ce_rounds)``); ``eta``/``rounds``/``t_min`` shape the
    ASHA ladder (``eta=1`` collapses to one full-horizon round — exactly
    grid search, bitwise).

    ``mesh`` shards each round's lane batch over devices via the sweep
    fabric (experiment.sweep) — results, rankings and lane-interval
    compute curves are bitwise-identical at any mesh size (the ARMS
    precomputed-grid fast path has no lane batch and ignores it).
    """
    from repro.simulator import tuning  # late import: tuning wraps run()
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         f"known: {list(STRATEGIES)}")
    if family not in tuning.FAMILIES:
        raise ValueError(f"unknown family {family!r}; "
                         f"known: {sorted(tuning.FAMILIES)}")
    make, fam_space, fam_defaults = tuning.FAMILIES[family]
    space = dict(space if space is not None else fam_space)
    defaults = dict(defaults if defaults is not None else fam_defaults)
    if base_cfg is not None:
        if family != "arms":
            raise ValueError("base_cfg is an ARMS-only knob")
        make = lambda **cfg: ARMSSpec.make(cfg, base_cfg=base_cfg)  # noqa: E731
    if configs is None:
        configs = tuning._sample_grid(space, defaults, budget, search_seed)
    else:
        configs = [dict(c) for c in configs]
    ctx = _EvalCtx(family, make, trace, machine, machines, workloads, k,
                   T, n, sim_seed, base_cfg, space, mesh=mesh)
    if strategy == "grid":
        out = _grid(ctx, family, configs)
    elif strategy == "asha":
        out = _asha(ctx, family, configs, eta, rounds, t_min)
    else:
        out = _ce(ctx, family, space, defaults, budget,
                  ce_rounds if rounds is None else rounds, elite_frac,
                  ce_smoothing, search_seed)
    if ctx.group_axis is None:
        return out[None]
    return out


# ------------------------------------------------- machine-transfer matrix
@dataclasses.dataclass
class TransferMatrix:
    """"Tuned on machine A, deployed on machine B" robustness table.

    ``exec_time[a, b]`` is the exec time of the config tuned natively on
    machine ``a`` when deployed on machine ``b``;
    ``slowdown[a, b] = exec_time[a, b] / exec_time[b, b]`` (1.0 on the
    diagonal; > 1 measures what deploying a foreign tuning costs vs
    re-tuning natively — the companion tuning paper's headline).
    """

    family: str
    machines: list
    tuned: dict                 #: machine label -> natively tuned config
    exec_time: np.ndarray       #: [A, B] deployed exec times (seconds)
    slowdown: np.ndarray        #: [A, B] vs the native-tuned diagonal
    search: dict                #: machine label -> SearchResult

    def rows(self):
        """JSON-friendly per-source rows for benches/tables."""
        out = []
        for a, src in enumerate(self.machines):
            out.append(dict(
                tuned_on=src, config=self.tuned[src],
                exec_time_s={b: round(float(self.exec_time[a, bi]), 6)
                             for bi, b in enumerate(self.machines)},
                slowdown={b: round(float(self.slowdown[a, bi]), 4)
                          for bi, b in enumerate(self.machines)}))
        return out


def transfer_matrix(family: str, trace, machines, k: int,
                    budget: int = 24, strategy: str = "asha",
                    search_seed: int = 0, sim_seed: int = 0,
                    **search_kw) -> TransferMatrix:
    """Tune per machine, then cross-evaluate tuned configs everywhere.

    Phase 1 is ONE machine-lane search (per-machine elimination, each
    round a single union-population x M-machine dispatch); phase 2
    re-scores the M tuned configs on all M machines in ONE final
    ``experiment.sweep`` dispatch (config axis x machine axis, shared
    CRN), so ``exec_time[b, b]`` reproduces the native search score
    bitwise and off-diagonal cells are paired with it.
    """
    machines = list(machines)
    if len(machines) < 2:
        raise ValueError("a transfer matrix needs >= 2 machines")
    per = run(family, strategy, trace=trace, machines=machines, k=k,
              budget=budget, search_seed=search_seed, sim_seed=sim_seed,
              **search_kw)
    labels = list(per)
    from repro.simulator import tuning  # late import: tuning wraps run()
    make = tuning.FAMILIES[family][0]
    specs = [make(**per[g].best_config) for g in labels]
    res = experiment.sweep(specs, trace=np.asarray(trace),
                           machines=machines, k=k, sim_seed=sim_seed)
    M = len(labels)
    exec_time = np.array([[res.at(policy=a, machine=b).exec_time_s
                           for b in range(M)] for a in range(M)])
    slowdown = exec_time / np.diag(exec_time)[None, :]
    return TransferMatrix(family, labels,
                          {g: per[g].best_config for g in labels},
                          exec_time, slowdown, per)
