"""Declarative N-tier machine protocol: batchable ``TieredMachineSpec``.

Machines were the last stateful-host API in the simulator: a frozen
two-tier ``MachineSpec`` dataclass (machine.py) baked into static jit
arguments, so hardware-sensitivity studies re-ran sequentially and
multi-tier topologies (DRAM/CXL/PMEM chains) could not be expressed at
all.  Here a machine is a pytree whose *leaves* are per-tier arrays —

    lat_ns[R], bw_read[R], bw_write[R], capacity_pages[R], mlp

over an arbitrary tier chain (tier 0 fastest, R-1 the unbounded bottom)
— batchable into sweep lanes exactly like policy and workload knobs, so
a P×W×M×S axis-product sweep is ONE compiled dispatch
(simulator/experiment.py).

Cost-model semantics (generalizing machine.interval_time):

  * page placement is an i32 per-page **tier index** (0 = fastest); the
    boolean ``in_fast`` of the two-tier model is ``tier == 0``;
  * migrations execute as chains of **adjacent-tier-pair hops**: a
    promotion moves a page from its tier to tier 0 crossing every pair
    on the way (read the pair's lower tier, write its upper tier); a
    demotion cascades down from its tier to the first tier with free
    capacity (the bottom always has room).  Each pair crossed charges
    its endpoints' bandwidth — per-tier bandwidth saturation;
  * tier 0 charges all its traffic (app reads + migration reads and
    writes) against one symmetric bandwidth, exactly the legacy
    fast-tier expression; every lower tier charges reads against
    ``bw_read[r]`` and writes against ``bw_write[r]`` separately,
    exactly the legacy slow-tier expression.  At N=2 the interval cost
    is therefore **bitwise identical** to the pre-refactor two-tier
    path in both engines — that equivalence is the refactor's safety
    net (tests/test_machine_spec.py).

Capacity encoding (``capacity_pages`` leaf, resolved per run by
``resolved_caps(spec, n, k)``):

    c == 0 : unbounded (resolved to n — a tier holding every page never
             blocks);  c > 0 : absolute pages;  c < 0 : ``round(-c*k)``
             pages, i.e. a multiple of the fast-tier capacity.
    Tier 0 is always resolved to the run's ``k`` and the bottom tier to
    ``n``, so the two-tier presets reproduce today's (k, unbounded)
    semantics exactly.

Per-pair migration costs (``promo_pair_us``/``demo_pair_us``, f32
[R-1]) are precomputed **in float64 on the host** at construction and
stored as f32 leaves: the values that cross a jit boundary are then
bit-identical to the legacy ``jnp.float32(machine.promo_page_us(m))``
path (an in-trace f32 division would drift in the last ulp and flip
ARMS cost/benefit decisions).  Consumers read the **path sums**
(``promo_path_us``) — the full bottom-to-top promotion cost — which are
invariant under neutral tier padding.

Neutral padding (``pad_tiers``): machines with different tier counts
share one stacked dispatch by inserting zero-capacity, infinite-
bandwidth, zero-latency tiers just above the bottom.  Such tiers take
no pages (cap 0), add no latency or bandwidth time (x/inf == 0), and
leave every real tier's traffic unchanged, so a padded two-tier machine
replays bitwise like the unpadded one.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.simulator.machine import CACHELINE, PAGE_BYTES, MachineSpec
from repro.utils.pytree import pytree_dataclass


@pytree_dataclass(meta=("name",))
class TieredMachineSpec:
    """N-tier machine; every field but ``name`` is a batchable leaf.

    Host-constructed specs carry f64 numpy leaves so the numpy engine's
    non-CRN cost path (``interval_outcome_host``) computes with the exact
    Table-3 constants, bit-identical to the pre-N-tier f64 engine; every
    device path casts to f32 at the lane-stack / jit boundary (the same
    f32 values the legacy ``machine_params`` cast produced)."""

    lat_ns: jnp.ndarray          # [R] per-access latency (ns)
    bw_read: jnp.ndarray         # [R] B/s (tier 0: symmetric bandwidth)
    bw_write: jnp.ndarray        # [R]
    capacity_pages: jnp.ndarray  # [R] encoded capacities (module doc)
    mlp: jnp.ndarray             # scalar memory-level parallelism
    promo_pair_us: jnp.ndarray   # [R-1] per-pair hop costs (f64-derived)
    demo_pair_us: jnp.ndarray    # [R-1]
    name: str = "machine"

    @property
    def n_tiers(self) -> int:
        return int(self.lat_ns.shape[-1])

    def promo_path_us(self):
        """Full bottom-to-top promotion cost; pair-0 cost at N=2."""
        return jnp.sum(self.promo_pair_us, axis=-1)

    def demo_path_us(self):
        return jnp.sum(self.demo_pair_us, axis=-1)


def make(name: str, lat_ns, bw_read, bw_write, capacity_pages=None,
         mlp: float = 64.0) -> TieredMachineSpec:
    """Host constructor: f64 leaves (class docstring; device paths cast)."""
    lat = np.asarray(lat_ns, np.float64)
    br = np.asarray(bw_read, np.float64)
    bw = np.asarray(bw_write, np.float64)
    R = lat.shape[0]
    if R < 2 or br.shape[0] != R or bw.shape[0] != R:
        raise ValueError(f"need >=2 tiers with matching leaves, got "
                         f"{lat.shape}/{br.shape}/{bw.shape}")
    caps = (np.zeros(R) if capacity_pages is None
            else np.asarray(capacity_pages, np.float64))
    if caps.shape[0] != R:
        raise ValueError("capacity_pages length must equal tier count")
    # hop j+1 -> j reads the lower tier and writes the upper one; the
    # term order matches machine.promo_page_us/demo_page_us exactly.
    promo = (PAGE_BYTES / br[1:] + PAGE_BYTES / bw[:-1]) * 1e6
    demo = (PAGE_BYTES / br[:-1] + PAGE_BYTES / bw[1:]) * 1e6
    return TieredMachineSpec(
        lat_ns=lat, bw_read=br, bw_write=bw,
        capacity_pages=caps, mlp=np.float64(mlp),
        promo_pair_us=promo, demo_pair_us=demo, name=name)


def from_machine(m: MachineSpec) -> TieredMachineSpec:
    """The legacy two-tier dataclass as a tier chain (cap encoding: tier 0
    takes the run's k, the slow tier is unbounded — today's semantics)."""
    return make(m.name, [m.lat_fast_ns, m.lat_slow_ns],
                [m.bw_fast, m.bw_slow_read], [m.bw_fast, m.bw_slow_write],
                mlp=m.mlp)


def resolved_caps(spec: TieredMachineSpec, n: int, k: int) -> np.ndarray:
    """Concrete per-tier capacities (i32 [R]) for a run of n pages, tier-0
    capacity k.  Host-side: runs before lane stacking."""
    caps = np.asarray(spec.capacity_pages, np.float64)
    R = caps.shape[0]
    out = np.empty(R, np.int64)
    out[0] = k
    out[R - 1] = n
    for r in range(1, R - 1):
        c = caps[r]
        if c == 0:
            out[r] = n
        elif c < 0:
            out[r] = int(round(-c * k))
        else:
            out[r] = int(round(c))
    return np.clip(out, 0, n).astype(np.int32)


def pad_tiers(spec: TieredMachineSpec, caps: np.ndarray, R_target: int):
    """Insert neutral tiers (cap 0, bw inf, lat 0) above the bottom tier so
    machines of different depth stack into one lane axis.  Semantically a
    no-op: padded == unpadded bitwise (module docstring).  Pair-cost leaves
    are zero-extended — consumers read path sums, which x+0 preserves."""
    R = spec.n_tiers
    if R == R_target:
        return spec, caps
    if R > R_target:
        raise ValueError(f"cannot shrink {R} tiers to {R_target}")
    pad = R_target - R
    f32 = np.float32
    ins = lambda arr, val: np.concatenate(
        [np.asarray(arr, f32)[:-1], np.full(pad, val, f32),
         np.asarray(arr, f32)[-1:]])
    spec = dataclasses.replace(
        spec,
        lat_ns=ins(spec.lat_ns, 0.0),
        bw_read=ins(spec.bw_read, np.inf),
        bw_write=ins(spec.bw_write, np.inf),
        capacity_pages=ins(spec.capacity_pages, 1e-9),
        promo_pair_us=np.concatenate(
            [np.asarray(spec.promo_pair_us, f32), np.zeros(pad, f32)]),
        demo_pair_us=np.concatenate(
            [np.asarray(spec.demo_pair_us, f32), np.zeros(pad, f32)]))
    caps = np.concatenate(
        [caps[:-1], np.zeros(pad, np.int32), caps[-1:]]).astype(np.int32)
    return spec, caps


def lane_stack(machs: list, n: int, k: int):
    """Stack resolved machines into one lane axis.

    -> (TieredMachineSpec with [M, ...] leaves, caps i32 [M, R]).  Tier
    counts are unified by neutral padding.  Names are overwritten to a
    common placeholder (meta must match to stack) — callers needing
    per-lane labels keep their own input list (experiment.sweep does).
    """
    import jax

    machs = list(machs)
    R = max(m.n_tiers for m in machs)
    specs, caps = [], []
    for m in machs:
        sp, cp = pad_tiers(m, resolved_caps(m, n, k), R)
        specs.append(dataclasses.replace(sp, name="lanes"))
        caps.append(cp)
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.asarray(x, jnp.float32) for x in xs]),
        *specs)
    return stacked, jnp.asarray(np.stack(caps), jnp.int32)


# ------------------------------------------------------- host cost model
def interval_outcome_host(spec: TieredMachineSpec, acc, mig_up, mig_down):
    """f64 reference interval cost for the numpy engine's non-CRN path.

    ``acc`` [R] per-tier access counts, ``mig_up``/``mig_down`` [R-1]
    pages crossing each adjacent pair upward/downward.  Returns
    (wall_s, slow_share, app_bw_frac_raw, slow_bw_frac_raw) — the
    *_raw ratios are unclamped (>1 == oversaturated; consumers clamp,
    see core/scheduler.batch_size).
    """
    lat = np.asarray(spec.lat_ns, np.float64)
    br = np.asarray(spec.bw_read, np.float64)
    bw = np.asarray(spec.bw_write, np.float64)
    R = lat.shape[0]
    acc = np.asarray(acc, np.float64)
    up = np.asarray(mig_up, np.float64)
    down = np.asarray(mig_down, np.float64)

    t_lat = acc[0] * lat[0]
    for r in range(1, R):
        t_lat = t_lat + acc[r] * lat[r]
    t_lat = t_lat * 1e-9 / float(spec.mlp)

    times = [(acc[0] * CACHELINE + (up[0] + down[0]) * PAGE_BYTES) / br[0]]
    for r in range(1, R):
        rd = up[r - 1]
        if r < R - 1:
            rd = rd + down[r]
        wr = down[r - 1]
        if r < R - 1:
            wr = wr + up[r]
        times.append((acc[r] * CACHELINE + rd * PAGE_BYTES) / br[r]
                     + wr * PAGE_BYTES / bw[r])

    wall = max(t_lat, *times, 1e-12)
    rest = acc[1]
    for r in range(2, R):
        rest = rest + acc[r]
    slow_share = rest / max(acc[0] + rest, 1e-9)
    app_raw = times[0] / max(t_lat, *times[1:], 1e-12)
    slow_raw = max(times[1:]) / max(t_lat, times[0], 1e-12)
    return wall, slow_share, app_raw, slow_raw


def tier_utilization_host(spec: TieredMachineSpec, acc, mig_up, mig_down):
    """f64 mirror of ``simjax.tier_utilization`` for the numpy engine's
    non-CRN path: each tier's bandwidth time over the interval wall —
    the tier-native policies' per-tier load signal.  Returns f64 [R]."""
    lat = np.asarray(spec.lat_ns, np.float64)
    br = np.asarray(spec.bw_read, np.float64)
    bw = np.asarray(spec.bw_write, np.float64)
    R = lat.shape[0]
    acc = np.asarray(acc, np.float64)
    up = np.asarray(mig_up, np.float64)
    down = np.asarray(mig_down, np.float64)

    t_lat = float((acc * lat).sum()) * 1e-9 / float(spec.mlp)
    times = [(acc[0] * CACHELINE + (up[0] + down[0]) * PAGE_BYTES) / br[0]]
    for r in range(1, R):
        rd = up[r - 1]
        if r < R - 1:
            rd = rd + down[r]
        wr = down[r - 1]
        if r < R - 1:
            wr = wr + up[r]
        times.append((acc[r] * CACHELINE + rd * PAGE_BYTES) / br[r]
                     + wr * PAGE_BYTES / bw[r])
    wall = max(t_lat, *times, 1e-12)
    return np.asarray(times, np.float64) / wall
