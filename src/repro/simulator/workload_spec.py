"""Declarative workload protocol: pure, batchable ``WorkloadSpec`` pytrees.

Workloads were the last pre-protocol API: stateful numpy ``for t in
range(T)`` loops host-materializing dense ``[T, n]`` float64 traces
(2 GiB per lane at n=65536, T=4096).  Here every workload is a
``WorkloadSpec`` — a pytree whose *leaves* are the scenario knobs (zipf
exponent, hot fraction, drift rate, phase windows; all f32/i32 and
batchable into sweep lanes) — with pure, jittable functions over a small
``WorkloadState`` pytree:

    state        = spec.init(n, key)
    state, probs = spec.step(state, t)       # [n] access distribution, sums to 1
    work         = spec.work_of(state, t)    # true accesses this interval

The compiled scan engine synthesizes ``true = work * probs`` on device per
interval (scan_engine.py), so per-lane trace storage drops from O(T*n) to
O(n); ``spec.materialize(T, n, seed)`` runs the same functions once and
returns the dense f32 array the numpy reference engine replays — the two
paths are bitwise-identical by construction (tests/test_workload_spec.py).

Like the policy protocol's observe/fires/policy split, the expensive
re-randomization events (hot-set relocation, zipf reshuffle, frontier
boosts) are factored out of the per-interval path:

    due   = spec.event_due(state, t)    # cheap scalar bool
    state = spec.event(state, t)        # O(n log n) redraw; masked per component
    probs = spec.probs_of(state, t)     # cheap O(n), every interval

``step`` composes them (cond(event_due) around event); the scan engine
hoists ``any(lane due)`` to a scalar ``lax.cond`` across workload lanes so
permutation redraws only run on event intervals.  Event draws are keyed by
``(seed, epoch)`` — pure functions of time, never a consumed key chain —
so gated and ungated replays cannot desync.

Internal representation
-----------------------
A spec is a stack of S *components*; every leaf is ``[S]`` (``S`` is
implied by leaf shapes, so specs compose structurally).  Each component
has a kind (zipf / hot-set / xsbench / tpcc-window / zipf+boost), its
knobs, an activity window ``[t_start, t_end)``, a duty cycle, and a
mixture weight.  The interval distribution is the rate-weighted mixture

    rate_c(t) = weight_c * active_c(t) * work_c * duty_c(t)
    probs(t)  = sum_c rate_c * p_c / sum_c rate_c,   work(t) = sum_c rate_c

which makes scenario algebra trivial: ``mix`` concatenates components and
scales weights, ``phases`` concatenates and sets activity windows,
``scale`` multiplies per-component work, ``drift`` adds a page-coordinate
shift rate.  Composed scenarios are declared, not hand-coded.

Hot sets are exact-k: rank permutations (one per component, redrawn on
events) define hot membership as ``rank < k_hot``, so ``k_hot`` stays a
*traced* knob while shapes stay static.
"""
from __future__ import annotations

import functools
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.pytree import pytree_dataclass

DEFAULT_PAGES = 4096      # 8 GiB RSS at 2 MB pages
DEFAULT_WORK = 2.0e7      # true accesses per interval
NEVER = 1 << 30           # i32-safe "no event" period

KIND_ZIPF, KIND_HOTSET, KIND_XSBENCH, KIND_TPCC, KIND_ZIPF_BOOST = range(5)

#: module counter: every host materialization bumps it.  The CI workload-
#: lane gate reads it to prove a synth sweep never built a [T, n] array.
MATERIALIZE_CALLS = 0


@pytree_dataclass
class WorkloadState:
    rank: jnp.ndarray      # i32 [S, n] permutation (zipf ranks / hot order)
    rank2: jnp.ndarray     # i32 [S, n] boost-set permutation (gapbs)
    base_key: jnp.ndarray  # u32 [S, 2] per-component event PRNG key


@pytree_dataclass
class WorkloadSpec:
    """Stack of S workload components; every field is a batchable leaf."""

    kind: jnp.ndarray          # i32 [S] component formula selector
    work: jnp.ndarray          # f32 [S] true accesses/interval at full duty
    weight: jnp.ndarray        # f32 [S] mixture weight
    t_start: jnp.ndarray       # i32 [S] activity window [t_start, t_end)
    t_end: jnp.ndarray         # i32 [S]
    s: jnp.ndarray             # f32 [S] zipf exponent
    hot_frac: jnp.ndarray      # f32 [S] hot-set fraction of n
    hot_weight: jnp.ndarray    # f32 [S] access mass on the hot set
    shift_every: jnp.ndarray   # i32 [S] rank-permutation redraw period
    window_frac: jnp.ndarray   # f32 [S] tpcc sliding-window fraction
    drift_pages: jnp.ndarray   # f32 [S] tpcc window drift (pages/interval)
    boost_every: jnp.ndarray   # i32 [S] gapbs boost-set redraw period
    boost_frac: jnp.ndarray    # f32 [S] gapbs boost-set fraction
    boost_gain: jnp.ndarray    # f32 [S] gapbs boost mass (pre-normalize)
    period: jnp.ndarray        # i32 [S] duty-cycle period (liblinear)
    duty: jnp.ndarray          # f32 [S] busy fraction of the period
    phase_off: jnp.ndarray     # i32 [S] duty-cycle phase offset (intervals)
    idle_scale: jnp.ndarray    # f32 [S] work multiplier when idle
    drift_rate: jnp.ndarray    # f32 [S] whole-distribution drift (combinator)
    seed: jnp.ndarray          # i32 [S] per-component randomness seed

    # ---------------------------------------------------------------- init
    def init(self, n: int, key):
        """Fresh per-component state; draws are keyed by (seed, epoch=0)."""
        bks = jax.vmap(lambda s: jax.random.fold_in(key, s))(self.seed)
        perm = lambda bk, tag: jax.random.permutation(
            jax.random.fold_in(jax.random.fold_in(bk, tag), 0), n)
        return WorkloadState(
            rank=jax.vmap(lambda bk: perm(bk, 1))(bks).astype(jnp.int32),
            rank2=jax.vmap(lambda bk: perm(bk, 2))(bks).astype(jnp.int32),
            base_key=bks)

    # -------------------------------------------------------------- events
    def event_due(self, state, t):
        """Scalar bool: does any ACTIVE component redraw a permutation at
        ``t``?  Gating on the activity window keeps inactive-phase
        components from firing wasted permutation sorts in the scan."""
        se = jnp.maximum(self.shift_every, 1)
        be = jnp.maximum(self.boost_every, 1)
        active = (t >= self.t_start) & (t < self.t_end)
        return jnp.any(active & (t > 0)
                       & (((t % se) == 0) | ((t % be) == 0)))

    def event(self, state, t, with_boost: bool = True):
        """Redraw rank permutations for due components (masked per
        component, keyed by epoch — safe to call on any interval).

        ``with_boost`` is a STATIC flag (see ``has_boost``): when no
        component can ever redraw a boost set, callers pass False and the
        second permutation sort is dropped from the program entirely —
        ``rank2`` is never read by non-boost kinds, so outputs are
        unchanged either way.
        """
        n = state.rank.shape[1]

        def upd(bk, se, be, ts, te, rank, rank2):
            se = jnp.maximum(se, 1)
            be = jnp.maximum(be, 1)
            fresh = lambda tag, epoch: jax.random.permutation(
                jax.random.fold_in(jax.random.fold_in(bk, tag), epoch),
                n).astype(jnp.int32)
            due = (t >= ts) & (t < te) & (t > 0)
            rank = jnp.where(due & ((t % se) == 0), fresh(1, t // se), rank)
            if with_boost:
                rank2 = jnp.where(due & ((t % be) == 0), fresh(2, t // be),
                                  rank2)
            return rank, rank2

        rank, rank2 = jax.vmap(upd)(state.base_key, self.shift_every,
                                    self.boost_every, self.t_start,
                                    self.t_end, state.rank, state.rank2)
        return state.replace(rank=rank, rank2=rank2)

    # ------------------------------------------------------------- mixture
    def _rates(self, t):
        """f32 [S] per-component access rate this interval."""
        f32 = jnp.float32
        active = ((t >= self.t_start) & (t < self.t_end)).astype(f32)
        per = jnp.maximum(self.period, 1)
        # phase_off staggers duty cycles across components (antiphase
        # tenants, adversarial phase flips — simulator/scenarios.py);
        # the default 0 is bitwise the historical formula.
        busy = ((t + self.phase_off) % per).astype(f32) \
            < self.duty * per.astype(f32)
        m = jnp.where(busy, f32(1.0), self.idle_scale)
        return self.weight * active * self.work * m

    def _comp_probs(self, state, t):
        """f32 [S, n] per-component normalized access distributions."""
        f32 = jnp.float32
        tf = jnp.asarray(t, f32)

        def one(kind, s, hot_frac, hot_weight, window_frac, drift_pages,
                boost_frac, boost_gain, drift_rate, rank, rank2):
            n = rank.shape[0]
            nf = f32(n)
            i = jnp.arange(n, dtype=jnp.int32)
            shift = jnp.floor(drift_rate * tf).astype(jnp.int32) % n
            idx = (i - shift) % n
            r = rank[idx].astype(f32)
            r2 = rank2[idx].astype(f32)

            def zipf(_):
                return (r + 1.0) ** (-s)

            def hotset(_):
                # guarded cold mass keeps hot_frac=1.0 valid (legacy gups
                # divided by n - k_hot and crashed); with every page hot
                # the branch normalization yields the uniform distribution
                kh = jnp.clip(jnp.round(nf * hot_frac), 1.0, nf)
                return jnp.where(r < kh, hot_weight / kh,
                                 (1.0 - hot_weight)
                                 / jnp.maximum(nf - kh, 1.0))

            def xsb(_):
                kh = jnp.clip(jnp.round(nf * hot_frac), 1.0, nf)
                return 0.5 / nf + jnp.where(r < kh, 0.5 / kh, 0.0)

            def tpcc(_):
                # clamp keeps window_frac=1.0 valid (legacy silo_tpcc took
                # a modulo by n - w and crashed there)
                w = jnp.clip(jnp.round(nf * window_frac), 1.0, nf - 1.0)
                span = jnp.maximum(nf - w, 1.0)
                head = jnp.mod(jnp.floor(drift_pages * tf), span)
                off = idx.astype(f32) - head
                inwin = (off >= 0.0) & (off < w)
                # geometric closed form of the legacy decay normalizer
                q = jnp.exp(-2.0 / w)
                denom = jnp.where(w > 1.0, (1.0 - q ** w) / (1.0 - q), 1.0)
                dec = jnp.exp(-(w - 1.0 - off) / (w * 0.5))
                return 0.05 / nf + jnp.where(inwin, 0.95 * dec / denom, 0.0)

            def boost(_):
                m = (r + 1.0) ** (-s)
                base = m / jnp.maximum(m.sum(), 1e-30)
                nb = jnp.clip(jnp.round(nf * boost_frac), 1.0, nf)
                return base + jnp.where(r2 < nb, boost_gain / nb, 0.0)

            p = jax.lax.switch(kind, [zipf, hotset, xsb, tpcc, boost], None)
            return p / jnp.maximum(p.sum(), 1e-30)

        return jax.vmap(one)(self.kind, self.s, self.hot_frac,
                             self.hot_weight, self.window_frac,
                             self.drift_pages, self.boost_frac,
                             self.boost_gain, self.drift_rate,
                             state.rank, state.rank2)

    def probs_of(self, state, t):
        """f32 [n] interval access distribution (sums to 1 to f32 tol)."""
        p = self._comp_probs(state, t)                       # [S, n]
        rate = self._rates(t)                                # [S]
        tot = rate.sum()
        mix = (rate[:, None] * p).sum(axis=0) / jnp.maximum(tot, 1e-30)
        n = state.rank.shape[1]
        return jnp.where(tot > 0.0, mix, jnp.float32(1.0 / n))

    def work_of(self, state, t):
        """f32 scalar: true accesses carried by this interval."""
        return self._rates(t).sum()

    def step(self, state, t):
        """Reference composition: cond(event_due) event, then probs."""
        state = jax.lax.cond(self.event_due(state, t),
                             lambda s: self.event(s, t), lambda s: s, state)
        return state, self.probs_of(state, t)

    # --------------------------------------------------- host conveniences
    @property
    def n_components(self) -> int:
        return int(np.asarray(self.kind).shape[0])

    def max_rate(self) -> float:
        """Host-side upper bound on any page's true per-interval count
        (probs <= 1; the duty multiplier can exceed 1 via idle_scale)."""
        rate = np.abs(np.asarray(self.work) * np.asarray(self.weight)) \
            * np.maximum(np.abs(np.asarray(self.idle_scale)), 1.0)
        return float(np.sum(rate))

    def has_boost(self) -> bool:
        """Host-side: can any component ever redraw its boost set?  Lets
        the engines statically skip the second permutation draw."""
        return bool(np.any(np.asarray(self.boost_every) < NEVER))

    def materialize(self, T: int, n: int, seed: int = 0) -> np.ndarray:
        """Dense f32 ``[T, n]`` trace for the numpy reference engine.

        Runs the very same jitted init/step functions the scan engine
        synthesizes from, so the rows are bitwise-identical to the
        device-synthesized counts under the same ``seed``.
        """
        global MATERIALIZE_CALLS
        MATERIALIZE_CALLS += 1
        tr = _materialize_jit(self, T, n, jax.random.PRNGKey(seed),
                              self.has_boost())
        return np.asarray(tr)


@functools.partial(jax.jit, static_argnames=("T", "n", "with_boost"))
def _materialize_jit(spec, T, n, key, with_boost):
    cls = type(spec)

    def row(st, t):
        # same cond + split functions the scan engine inlines (step's
        # reference composition, with the static boost-draw flag)
        st = jax.lax.cond(cls.event_due(spec, st, t),
                          lambda s: cls.event(spec, s, t, with_boost),
                          lambda s: s, st)
        probs = cls.probs_of(spec, st, t)
        return st, cls.work_of(spec, st, t) * probs

    _, tr = jax.lax.scan(row, spec.init(n, key), jnp.arange(T))
    return tr.astype(jnp.float32)


# --------------------------------------------------------------- builders
def _comp(kind, *, work=DEFAULT_WORK, weight=1.0, t_start=0, t_end=NEVER,
          s=0.0, hot_frac=0.0, hot_weight=0.0, shift_every=NEVER,
          window_frac=0.0, drift_pages=0.0, boost_every=NEVER,
          boost_frac=0.0, boost_gain=0.0, period=1, duty=1.0,
          phase_off=0, idle_scale=1.0, drift_rate=0.0, seed=0) -> dict:
    return dict(kind=kind, work=work, weight=weight, t_start=t_start,
                t_end=t_end, s=s, hot_frac=hot_frac, hot_weight=hot_weight,
                shift_every=max(1, int(shift_every)),
                window_frac=window_frac, drift_pages=drift_pages,
                boost_every=max(1, int(boost_every)), boost_frac=boost_frac,
                boost_gain=boost_gain, period=max(1, int(period)), duty=duty,
                phase_off=int(phase_off), idle_scale=idle_scale,
                drift_rate=drift_rate, seed=int(seed))


_F32 = ("work", "weight", "s", "hot_frac", "hot_weight", "window_frac",
        "drift_pages", "boost_frac", "boost_gain", "duty", "idle_scale",
        "drift_rate")
_I32 = ("kind", "t_start", "t_end", "shift_every", "boost_every", "period",
        "phase_off", "seed")


def _from_comps(comps: list[dict]) -> WorkloadSpec:
    cols = {}
    for f in _F32:
        cols[f] = jnp.asarray([c[f] for c in comps], jnp.float32)
    for f in _I32:
        cols[f] = jnp.asarray([c[f] for c in comps], jnp.int32)
    return WorkloadSpec(**cols)


def _to_comps(spec: WorkloadSpec) -> list[dict]:
    fields = _F32 + _I32
    cols = {f: np.asarray(getattr(spec, f)) for f in fields}
    S = cols["kind"].shape[0]
    return [{f: cols[f][c].item() for f in fields} for c in range(S)]


def with_label(spec: WorkloadSpec, label: str) -> WorkloadSpec:
    """Attach a display label (kept off the pytree; purely cosmetic)."""
    object.__setattr__(spec, "_label", label)
    return spec


def label_of(spec, default: str = "workload") -> str:
    return getattr(spec, "_label", default)


# ------------------------------------------------------- named workloads
def gups_spec(work=DEFAULT_WORK, seed=0, hot_frac=0.125, hot_weight=0.9,
              shift_every=150) -> WorkloadSpec:
    """Uniform accesses within a small hot set that relocates periodically."""
    return with_label(_from_comps([_comp(
        KIND_HOTSET, work=work, hot_frac=hot_frac, hot_weight=hot_weight,
        shift_every=shift_every, seed=seed)]), "gups")


def zipf_spec(s=0.99, work=DEFAULT_WORK, seed=1,
              shuffle_every=NEVER) -> WorkloadSpec:
    """Zipf distribution over a random permutation, optional reshuffles."""
    return with_label(_from_comps([_comp(
        KIND_ZIPF, work=work, s=s, shift_every=shuffle_every, seed=seed)]),
        "zipf")


def tpcc_spec(work=DEFAULT_WORK, seed=4, window_frac=0.15,
              drift_pages=2.0) -> WorkloadSpec:
    """"Latest" distribution: hot window slides as rows are inserted."""
    return with_label(_from_comps([_comp(
        KIND_TPCC, work=work, window_frac=window_frac,
        drift_pages=drift_pages, seed=seed)]), "silo-tpcc")


def xsbench_spec(work=DEFAULT_WORK, seed=5, hot_frac=0.02) -> WorkloadSpec:
    """Small very-hot lookup tables + uniform background over the RSS."""
    return with_label(_from_comps([_comp(
        KIND_XSBENCH, work=work, hot_frac=hot_frac, seed=seed)]), "xsbench")


def gapbs_spec(s=0.8, work=DEFAULT_WORK, seed=6, boost_every=40,
               boost_frac=0.05, boost_gain=0.3) -> WorkloadSpec:
    """Power-law degree distribution + periodic frontier boosts."""
    return with_label(_from_comps([_comp(
        KIND_ZIPF_BOOST, work=work, s=s, boost_every=boost_every,
        boost_frac=boost_frac, boost_gain=boost_gain, seed=seed)]), "gapbs")


def liblinear_spec(work=DEFAULT_WORK, seed=9, period=20, duty=0.5,
                   idle_scale=0.02) -> WorkloadSpec:
    """Periodic memory-intensive zipf sweeps alternating with near-idle
    compute phases (batched migration's best case, paper §7.2)."""
    return with_label(_from_comps([_comp(
        KIND_ZIPF, work=work, s=0.6, period=period, duty=duty,
        idle_scale=idle_scale, seed=seed)]), "liblinear")


def zipf_shuffled_spec(s=0.99, work=DEFAULT_WORK, seed=1,
                       shuffle_at=()) -> WorkloadSpec:
    """Zipf with ONE-SHOT reshuffles at the given times: each reshuffle
    switches to an independently-permuted zipf phase (``phases``
    combinator) — a reshuffle at ``v`` and nothing after, unlike the
    periodic ``shuffle_every`` knob."""
    times = sorted({int(v) for v in shuffle_at})
    children = [zipf_spec(s=s, work=work, seed=seed + 7919 * i)
                for i in range(len(times) + 1)]
    if not times:
        return children[0]
    return with_label(phases(children, times), "zipf")


def btree_spec(T: int = 400, work=DEFAULT_WORK, seed=2) -> WorkloadSpec:
    """Zipf index lookups with one hot-set reshuffle at T // 2 (Fig. 9)."""
    return with_label(zipf_shuffled_spec(
        s=0.9, work=work, seed=seed, shuffle_at=(max(1, T // 2),)), "btree")


#: name -> spec constructor taking (T, work, seed).  ``T`` only matters for
#: btree's mid-run reshuffle (legacy semantics: hot-set change at T // 2).
_NAMED = {
    "gups": lambda T, work, seed: gups_spec(work=work, seed=seed),
    "btree": lambda T, work, seed: btree_spec(T, work=work, seed=seed),
    "silo-ycsb": lambda T, work, seed: zipf_spec(
        s=0.99, work=work, seed=seed),
    "silo-tpcc": lambda T, work, seed: tpcc_spec(work=work, seed=seed),
    "xsbench": lambda T, work, seed: xsbench_spec(work=work, seed=seed),
    "gapbs-bc": lambda T, work, seed: gapbs_spec(
        s=0.8, work=work, seed=seed, boost_every=40, boost_frac=0.05,
        boost_gain=0.3),
    "gapbs-pr": lambda T, work, seed: zipf_spec(
        s=0.7, work=work, seed=seed),
    "gapbs-cc": lambda T, work, seed: gapbs_spec(
        s=0.75, work=work, seed=seed, boost_every=100, boost_frac=0.1,
        boost_gain=0.2),
    "liblinear": lambda T, work, seed: liblinear_spec(work=work, seed=seed),
}

NAMED_WORKLOADS = tuple(sorted(_NAMED))


def named(name: str, T: int = 400, work: float = DEFAULT_WORK,
          seed: int | None = None, seed_offset: int = 0) -> WorkloadSpec:
    """Spec for a paper workload by name (same seed derivation as the
    legacy ``workloads.make``: crc32 of the name, plus ``seed_offset``,
    unless an explicit ``seed`` is given)."""
    if name not in _NAMED:
        raise ValueError(f"unknown workload {name!r}; "
                         f"known: {sorted(_NAMED)}")
    if seed is None:
        seed = zlib.crc32(name.encode()) % 1000 + seed_offset
    return with_label(_NAMED[name](T, work, seed), name)


# ------------------------------------------------------------ combinators
def phases(specs: list[WorkloadSpec], boundaries: list[int],
           label: str | None = None) -> WorkloadSpec:
    """Piecewise scenario: ``specs[p]`` is active on ``[b_{p-1}, b_p)``.

    ``boundaries`` has ``len(specs) - 1`` ascending interval indices; each
    child's own activity window is intersected with its phase window, so
    nested ``phases`` compose.
    """
    if len(boundaries) != len(specs) - 1:
        raise ValueError(f"phases wants len(boundaries) == len(specs) - 1; "
                         f"got {len(boundaries)} vs {len(specs)}")
    if any(b2 <= b1 for b1, b2 in zip(boundaries, boundaries[1:])):
        raise ValueError(f"boundaries must ascend; got {boundaries}")
    if boundaries and int(boundaries[0]) < 1:
        # boundary 0 makes phase 0 a zero-length window: its spec would
        # silently never run.
        raise ValueError(f"first boundary must be >= 1; got {boundaries}")
    edges = [0] + [int(b) for b in boundaries] + [NEVER]
    comps = []
    for p, sp in enumerate(specs):
        for c in _to_comps(sp):
            c["t_start"] = max(c["t_start"], edges[p])
            c["t_end"] = min(c["t_end"], edges[p + 1])
            comps.append(c)
    return with_label(_from_comps(comps), label or "+".join(
        label_of(sp, f"p{i}") for i, sp in enumerate(specs)))


def mix(specs: list[WorkloadSpec], weights: list[float] | None = None,
        label: str | None = None) -> WorkloadSpec:
    """Blend scenarios: rate-weighted mixture of the children.  Weights
    normalize to 1 (``mix(xs, [2, 2]) == mix(xs, [1, 1])``)."""
    if weights is None:
        weights = [1.0] * len(specs)
    if len(weights) != len(specs):
        raise ValueError("mix wants one weight per spec")
    tot = float(sum(weights))
    if tot <= 0.0:
        raise ValueError("mix weights must sum > 0")
    comps = []
    for w, sp in zip(weights, specs):
        for c in _to_comps(sp):
            c["weight"] = c["weight"] * float(w) / tot
            comps.append(c)
    return with_label(_from_comps(comps), label or "mix(" + ",".join(
        label_of(sp, f"m{i}") for i, sp in enumerate(specs)) + ")")


def scale(spec: WorkloadSpec, work_mult: float) -> WorkloadSpec:
    """Scale a scenario's access intensity by ``work_mult``."""
    comps = _to_comps(spec)
    for c in comps:
        c["work"] *= float(work_mult)
    return with_label(_from_comps(comps),
                      f"{label_of(spec)}*{work_mult:g}")


def drift(spec: WorkloadSpec, pages_per_interval: float) -> WorkloadSpec:
    """March the whole access distribution forward by
    ``pages_per_interval`` pages per interval (mod n)."""
    comps = _to_comps(spec)
    for c in comps:
        c["drift_rate"] += float(pages_per_interval)
    return with_label(_from_comps(comps),
                      f"drift({label_of(spec)},{pages_per_interval:g})")


def pad_components(spec: WorkloadSpec, S: int) -> WorkloadSpec:
    """Extend to exactly ``S`` components with inert (never-active,
    zero-weight) filler so structurally different scenarios stack into one
    lane-batched sweep."""
    have = spec.n_components
    if have > S:
        raise ValueError(f"spec has {have} components > requested {S}")
    comps = _to_comps(spec)
    comps += [_comp(KIND_ZIPF, work=0.0, weight=0.0, t_end=0)
              for _ in range(S - have)]
    return with_label(_from_comps(comps), label_of(spec))
