"""Adversarial thrashing scenarios: WorkloadSpec combinators sized
RELATIVE to the machine.

Every constructor takes the run geometry ``(n, k)`` — total pages and
fast-tier capacity (``machine_spec.resolved_caps`` pins tier-0 capacity
to ``k`` on every preset, so one scenario spec instantiates unchanged
across machines) — and returns a plain ``WorkloadSpec``.  The suite is
built to stress the failure modes the robustness leaderboard
(benchmarks/bench_robustness.py) scores:

  * ``capacity_straddle`` — working sets at 0.9x / 1.0x / 1.1x the fast
    tier: just-fits rewards placement, just-misses punishes policies that
    keep migrating the overflow (the classic thrash inducer);
  * ``phase_flip`` — two antiphase hot sets alternating on a fast duty
    cycle: a responsive policy without thrash avoidance chases every
    flip (Jenga's motivating pathology);
  * ``drifting_hot`` — the hot set marches through the address space, so
    yesterday's placement decays at a constant rate;
  * ``duty_cycled_tenants`` — staggered tenants whose hot sets sum past
    fast-tier capacity: pressure arrives as a rotating schedule, not a
    steady state.

Degenerate knobs are clamped here (mirroring the PR-3 ``hot_frac=1.0``
clamps): drift rates wrap mod n, flip periods floor at 2 intervals, and
hot fractions never round below one page (tests/test_scenarios.py).
"""
from __future__ import annotations

from repro.simulator.workload_spec import (DEFAULT_WORK, KIND_HOTSET,
                                           WorkloadSpec, _comp, _from_comps,
                                           _to_comps, drift, with_label)

__all__ = ["capacity_straddle", "phase_flip", "drifting_hot",
           "duty_cycled_tenants", "serving_mix", "suite",
           "STRADDLE_RATIOS"]

STRADDLE_RATIOS = (0.9, 1.0, 1.1)


def _hot_frac(pages: float, n: int) -> float:
    """Hot-set fraction for ``pages`` hot pages, never rounding below one
    page (small-n regression: tests/test_scenarios.py)."""
    return min(max(float(pages), 1.0), float(n)) / float(n)


def capacity_straddle(n: int, k: int, ratio: float,
                      work: float = DEFAULT_WORK, seed: int = 11,
                      shift_every: int = 200) -> WorkloadSpec:
    """Hot working set sized at ``ratio`` x fast-tier capacity."""
    spec = _from_comps([_comp(
        KIND_HOTSET, work=work, hot_frac=_hot_frac(ratio * k, n),
        hot_weight=0.95, shift_every=shift_every, seed=seed)])
    return with_label(spec, f"straddle-{ratio:g}x")


def phase_flip(n: int, k: int, period: int = 10,
               work: float = DEFAULT_WORK, seed: int = 23) -> WorkloadSpec:
    """Two antiphase hot sets flipping every ``period // 2`` intervals.

    Each set alone fits the fast tier, so an oracle simply holds the
    union's hottest half; a reactive policy re-migrates ~k pages every
    flip.  ``period`` floors at 2 (a zero-length flip window would
    silently degenerate to one always-on hot set).
    """
    period = max(int(period), 2)
    half = period // 2
    mk = lambda off, sd: _comp(
        KIND_HOTSET, work=work, hot_frac=_hot_frac(0.8 * k, n),
        hot_weight=0.95, period=period, duty=half / period, phase_off=off,
        idle_scale=0.02, seed=sd)
    spec = _from_comps([mk(0, seed), mk(period - half, seed + 1)])
    return with_label(spec, f"phase-flip-{period}")


def drifting_hot(n: int, k: int, rate: float = 2.0,
                 work: float = DEFAULT_WORK, seed: int = 31) -> WorkloadSpec:
    """Hot set marching ``rate`` pages/interval through the address space.

    ``rate`` wraps mod n (a drift of n pages/interval is a no-op; rates
    beyond n alias to their residue — the degenerate-knob clamp).
    """
    rate = float(rate) % float(n)
    base = _from_comps([_comp(
        KIND_HOTSET, work=work, hot_frac=_hot_frac(0.8 * k, n),
        hot_weight=0.95, seed=seed)])
    return with_label(drift(base, rate), f"drift-{rate:g}")


def duty_cycled_tenants(n: int, k: int, tenants: int = 3, period: int = 60,
                        work: float = DEFAULT_WORK,
                        seed: int = 41) -> WorkloadSpec:
    """Staggered tenants whose hot sets overflow the fast tier in
    aggregate: tenant ``i`` is busy for ``period // tenants`` intervals,
    offset so exactly one tenant is hot at a time — placement must follow
    the schedule, not a stationary distribution."""
    tenants = max(int(tenants), 2)
    period = max(int(period), tenants)
    slot = period // tenants
    comps = [_comp(
        KIND_HOTSET, work=work / tenants, hot_frac=_hot_frac(0.75 * k, n),
        hot_weight=0.9, period=period, duty=slot / period,
        phase_off=period - i * slot, idle_scale=0.05, seed=seed + i)
        for i in range(tenants)]
    return with_label(_from_comps(comps), f"tenants-{tenants}")


def serving_mix(n: int, k: int, tenants: int = 4, period: int = 48,
                specs: list[WorkloadSpec] | None = None,
                work: float = DEFAULT_WORK, seed: int = 53) -> WorkloadSpec:
    """Multi-tenant serving traffic: ``tenants`` request streams x
    staggered request phases.

    Each tenant's access shape comes from ``specs`` — typically
    ``traces.fit_workload_spec`` outputs captured from real serving runs
    (benchmarks/bench_serving.py wires the live capture->fit->scenario
    path); with ``specs=None`` the defaults stand in for the fitted
    archetypes (chat-style concentrated KV reuse, wider churning RAG
    context, bursty MoE routing).  Tenants are duty-cycled onto staggered
    request phases (one tenant's burst at a time, ``duty_cycled_tenants``
    style) with per-tenant work scaled so aggregate load matches ``work``
    — pressure on the fast tier is a rotating schedule of heterogeneous
    hot sets, the serving-loop pathology the leaderboard scores.
    """
    tenants = max(int(tenants), 2)
    period = max(int(period), tenants)
    slot = period // tenants
    if specs is None:
        specs = [_from_comps([_comp(
            KIND_HOTSET, work=work,
            hot_frac=_hot_frac((0.5 + 0.25 * (i % 3)) * k, n),
            hot_weight=0.92, shift_every=80 + 40 * i, seed=seed + 7 * i)])
            for i in range(tenants)]
    comps = []
    for i in range(tenants):
        for c in _to_comps(specs[i % len(specs)]):
            c = dict(c, work=c["work"] / tenants, period=period,
                     duty=slot / period, phase_off=period - i * slot,
                     idle_scale=min(c.get("idle_scale", 1.0), 0.05),
                     seed=c["seed"] + 131 * i)
            comps.append(c)
    return with_label(_from_comps(comps), f"serving-mix-{tenants}")


def suite(n: int, k: int, work: float = DEFAULT_WORK) -> list[WorkloadSpec]:
    """The adversarial scenario suite for a run geometry — the workload
    axis of the robustness leaderboard."""
    return ([capacity_straddle(n, k, r, work=work)
             for r in STRADDLE_RATIOS]
            + [phase_flip(n, k, work=work),
               drifting_hot(n, k, work=work),
               duty_cycled_tenants(n, k, work=work),
               serving_mix(n, k, work=work)])
