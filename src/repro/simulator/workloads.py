"""Workload access-trace generators (paper Table 4 analogues).

Each generator returns a float64 array ``[T, n_pages]`` of TRUE per-interval
access counts; every interval carries the same amount of application work
(``work`` accesses), so simulated execution time is directly comparable across
policies.  PEBS-style sampling noise is applied separately (sampling.py) —
policies never see these true counts.

The set mirrors the paper's workloads: GUPS (dynamic hot set), Silo-YCSB /
Btree (Zipfian), Silo-TPCC ("latest" distribution), XSBench (small hot set +
uniform background), GapBS BC/PR/CC (power-law with phase changes), and a
Liblinear-style periodic streaming workload (§7.2 "dynamic batched
migrations").
"""
from __future__ import annotations

import numpy as np

DEFAULT_PAGES = 4096      # 8 GiB RSS at 2 MB pages
DEFAULT_WORK = 2.0e7      # true accesses per interval


def _zipf_probs(n: int, s: float, rng: np.random.Generator) -> np.ndarray:
    p = 1.0 / np.arange(1, n + 1) ** s
    p /= p.sum()
    return rng.permutation(p)


def gups(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
         seed: int = 0, hot_frac: float = 0.125, hot_weight: float = 0.9,
         shift_every: int = 150) -> np.ndarray:
    """Uniform accesses within a small hot set that RELOCATES periodically."""
    rng = np.random.default_rng(seed)
    k_hot = max(1, int(n * hot_frac))
    trace = np.empty((T, n))
    hot = rng.choice(n, k_hot, replace=False)
    for t in range(T):
        if t > 0 and t % shift_every == 0:
            hot = rng.choice(n, k_hot, replace=False)
        p = np.full(n, (1 - hot_weight) / (n - k_hot))
        p[hot] = hot_weight / k_hot
        trace[t] = work * p
    return trace


def zipfian(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
            seed: int = 1, s: float = 0.99, shuffle_at=()) -> np.ndarray:
    """Static Zipf distribution (Silo YCSB-C), optional mid-run reshuffles."""
    rng = np.random.default_rng(seed)
    p = _zipf_probs(n, s, rng)
    trace = np.empty((T, n))
    for t in range(T):
        if t in shuffle_at:
            p = _zipf_probs(n, s, rng)
        trace[t] = work * p
    return trace


def btree(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
          seed: int = 2) -> np.ndarray:
    """Zipfian index lookups with a hot-set change mid-run (paper Fig. 9)."""
    return zipfian(T, n, work, seed=seed, s=0.9, shuffle_at=(T // 2,))


def silo_ycsb(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
              seed: int = 3) -> np.ndarray:
    return zipfian(T, n, work, seed=seed, s=0.99)


def silo_tpcc(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
              seed: int = 4, window_frac: float = 0.15,
              drift_pages: float = 2.0) -> np.ndarray:
    """"Latest" distribution: a hot window slides forward as rows are
    inserted (paper §7.1: Memtis's infrequent cooling hurts here).

    Drift is calibrated to TPC-C-like insert rates: tens of thousands of
    txn/s filling a 2 MB page every ~50 ms -> ~2 pages per 100 ms interval.
    """
    w = max(1, int(n * window_frac))
    trace = np.empty((T, n))
    decay = np.exp(-np.arange(w) / (w / 2))   # newest rows hottest
    decay /= decay.sum()
    for t in range(T):
        head = int(t * drift_pages) % (n - w)
        p = np.full(n, 0.05 / n)
        p[head:head + w] += 0.95 * decay[::-1]
        p /= p.sum()
        trace[t] = work * p
    return trace


def xsbench(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
            seed: int = 5, hot_frac: float = 0.02) -> np.ndarray:
    """Small very-hot lookup tables + uniform random background over the
    whole RSS — the background makes threshold policies thrash (§3.2)."""
    rng = np.random.default_rng(seed)
    k_hot = max(1, int(n * hot_frac))
    hot = rng.choice(n, k_hot, replace=False)
    p = np.full(n, 0.5 / n)
    p[hot] += 0.5 / k_hot
    return np.tile(work * p, (T, 1))


def _gapbs(T, n, work, seed, s, boost_every, boost_frac, boost_gain):
    """Power-law degree distribution + periodic frontier boosts."""
    rng = np.random.default_rng(seed)
    base = _zipf_probs(n, s, rng)
    trace = np.empty((T, n))
    boost = np.zeros(n)
    nb = max(1, int(n * boost_frac))
    for t in range(T):
        if t % boost_every == 0:
            boost[:] = 0.0
            boost[rng.choice(n, nb, replace=False)] = boost_gain / nb
        p = base + boost
        p /= p.sum()
        trace[t] = work * p
    return trace


def gapbs_bc(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
             seed: int = 6) -> np.ndarray:
    return _gapbs(T, n, work, seed, s=0.8, boost_every=40, boost_frac=0.05,
                  boost_gain=0.3)


def gapbs_pr(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
             seed: int = 7) -> np.ndarray:
    return _gapbs(T, n, work, seed, s=0.7, boost_every=10**9, boost_frac=0.0,
                  boost_gain=0.0)


def gapbs_cc(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
             seed: int = 8) -> np.ndarray:
    return _gapbs(T, n, work, seed, s=0.75, boost_every=100, boost_frac=0.1,
                  boost_gain=0.2)


def liblinear(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
              seed: int = 9, period: int = 20, duty: float = 0.5) -> np.ndarray:
    """Periodic phases: memory-intensive Zipf sweeps alternating with
    near-idle compute phases — batched migration's best case (§7.2)."""
    rng = np.random.default_rng(seed)
    p = _zipf_probs(n, 0.6, rng)
    trace = np.empty((T, n))
    for t in range(T):
        busy = (t % period) < duty * period
        trace[t] = (work if busy else 0.02 * work) * p
    return trace


WORKLOADS = {
    "gups": gups,
    "btree": btree,
    "silo-ycsb": silo_ycsb,
    "silo-tpcc": silo_tpcc,
    "xsbench": xsbench,
    "gapbs-bc": gapbs_bc,
    "gapbs-pr": gapbs_pr,
    "gapbs-cc": gapbs_cc,
    "liblinear": liblinear,
}


def make(name: str, T: int = 400, n: int = DEFAULT_PAGES,
         work: float = DEFAULT_WORK, seed_offset: int = 0) -> np.ndarray:
    import zlib
    gen = WORKLOADS[name]
    base_seed = zlib.crc32(name.encode()) % 1000  # deterministic across runs
    return gen(T, n, work, seed=base_seed + seed_offset)
