"""Workload access-trace generators (paper Table 4 analogues) — legacy API.

Every generator is now a thin constructor over the declarative
``WorkloadSpec`` protocol (simulator/workload_spec.py): it builds the
spec and host-materializes the dense ``[T, n_pages]`` float32 array of
TRUE per-interval access counts the numpy reference engine replays.
Every interval carries the same amount of application work (``work``
accesses), so simulated execution time is directly comparable across
policies.  PEBS-style sampling noise is applied separately (sampling.py)
— policies never see these true counts.

The compiled scan engine does not need these arrays at all: it
synthesizes the same counts on device, interval by interval, directly
from the spec (O(n) per lane instead of O(T*n) — see
``scan_engine.simulate_workload`` / ``sweep_workloads``), bitwise
identical to the materialized rows.

The set mirrors the paper's workloads: GUPS (dynamic hot set), Silo-YCSB /
Btree (Zipfian), Silo-TPCC ("latest" distribution), XSBench (small hot set +
uniform background), GapBS BC/PR/CC (power-law with phase changes), and a
Liblinear-style periodic streaming workload (§7.2 "dynamic batched
migrations").
"""
from __future__ import annotations

import numpy as np

from repro.simulator import workload_spec
from repro.simulator.workload_spec import DEFAULT_PAGES, DEFAULT_WORK


def gups(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
         seed: int = 0, hot_frac: float = 0.125, hot_weight: float = 0.9,
         shift_every: int = 150) -> np.ndarray:
    """Uniform accesses within a small hot set that RELOCATES periodically."""
    return workload_spec.gups_spec(
        work=work, seed=seed, hot_frac=hot_frac, hot_weight=hot_weight,
        shift_every=shift_every).materialize(T, n)


def zipfian(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
            seed: int = 1, s: float = 0.99, shuffle_at=()) -> np.ndarray:
    """Static Zipf distribution (Silo YCSB-C), optional one-shot mid-run
    reshuffles (independently-permuted phases)."""
    return workload_spec.zipf_shuffled_spec(
        s=s, work=work, seed=seed, shuffle_at=shuffle_at).materialize(T, n)


def btree(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
          seed: int = 2) -> np.ndarray:
    """Zipfian index lookups with a hot-set change mid-run (paper Fig. 9)."""
    return workload_spec.btree_spec(T, work=work, seed=seed).materialize(T, n)


def silo_ycsb(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
              seed: int = 3) -> np.ndarray:
    return zipfian(T, n, work, seed=seed, s=0.99)


def silo_tpcc(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
              seed: int = 4, window_frac: float = 0.15,
              drift_pages: float = 2.0) -> np.ndarray:
    """"Latest" distribution: a hot window slides forward as rows are
    inserted (paper §7.1: Memtis's infrequent cooling hurts here).

    Drift is calibrated to TPC-C-like insert rates: tens of thousands of
    txn/s filling a 2 MB page every ~50 ms -> ~2 pages per 100 ms interval.
    """
    return workload_spec.tpcc_spec(
        work=work, seed=seed, window_frac=window_frac,
        drift_pages=drift_pages).materialize(T, n)


def xsbench(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
            seed: int = 5, hot_frac: float = 0.02) -> np.ndarray:
    """Small very-hot lookup tables + uniform random background over the
    whole RSS — the background makes threshold policies thrash (§3.2)."""
    return workload_spec.xsbench_spec(
        work=work, seed=seed, hot_frac=hot_frac).materialize(T, n)


def gapbs_bc(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
             seed: int = 6) -> np.ndarray:
    return workload_spec.gapbs_spec(
        s=0.8, work=work, seed=seed, boost_every=40, boost_frac=0.05,
        boost_gain=0.3).materialize(T, n)


def gapbs_pr(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
             seed: int = 7) -> np.ndarray:
    return workload_spec.zipf_spec(
        s=0.7, work=work, seed=seed).materialize(T, n)


def gapbs_cc(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
             seed: int = 8) -> np.ndarray:
    return workload_spec.gapbs_spec(
        s=0.75, work=work, seed=seed, boost_every=100, boost_frac=0.1,
        boost_gain=0.2).materialize(T, n)


def liblinear(T: int, n: int = DEFAULT_PAGES, work: float = DEFAULT_WORK,
              seed: int = 9, period: int = 20, duty: float = 0.5) -> np.ndarray:
    """Periodic phases: memory-intensive Zipf sweeps alternating with
    near-idle compute phases — batched migration's best case (§7.2)."""
    return workload_spec.liblinear_spec(
        work=work, seed=seed, period=period, duty=duty).materialize(T, n)


WORKLOADS = {
    "gups": gups,
    "btree": btree,
    "silo-ycsb": silo_ycsb,
    "silo-tpcc": silo_tpcc,
    "xsbench": xsbench,
    "gapbs-bc": gapbs_bc,
    "gapbs-pr": gapbs_pr,
    "gapbs-cc": gapbs_cc,
    "liblinear": liblinear,
}


def spec(name: str, T: int = 400, work: float = DEFAULT_WORK,
         seed_offset: int = 0) -> workload_spec.WorkloadSpec:
    """The ``WorkloadSpec`` behind ``make`` (seed derivation lives in
    ``workload_spec.named``)."""
    return workload_spec.named(name, T=T, work=work,
                               seed_offset=seed_offset)


def make(name: str, T: int = 400, n: int = DEFAULT_PAGES,
         work: float = DEFAULT_WORK, seed_offset: int = 0) -> np.ndarray:
    return spec(name, T=T, work=work, seed_offset=seed_offset).materialize(
        T, n)
