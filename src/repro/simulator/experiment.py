"""Axis-product experiment API: policies × workloads × machines × seeds.

The spec trilogy — policies (baselines/protocol.py), workloads
(simulator/workload_spec.py) and machines (simulator/machine_spec.py) —
makes every experiment axis a batchable pytree, so the full paper
question ("which policy is robust across workloads AND machines without
tuning?") flattens into lanes of ONE compiled scan-engine dispatch:

    res = experiment.sweep(
        policies=["arms", HeMemSpec.make(hot_threshold=4)],
        workloads=["gups", "silo-tpcc"],       # synth mode (needs T, n)
        machines=["pmem-large", "dram-cxl-pmem"],
        seeds=[0], k=256, T=300, n=2048)
    res.at(policy="arms", workload="gups", machine="dram-cxl-pmem")

Lane layout per dispatch: ``((w*P + p)*M + m)*S + s`` — workloads
outermost (each workload's device-synthesized state feeds its P*M*S
policy/machine/seed lanes), machines of different tier depth unified by
neutral padding (machine_spec.pad_tiers), seeds innermost.  Policies of
*different families* (different state pytrees) cannot share a lane axis;
they are grouped by family, one dispatch per family, each still covering
the full W×M×S product — a single-family sweep (e.g. a tuning grid
across machines) is exactly one dispatch, which the CI machine-sweep
gate asserts.

Noise pairing: with a single seed, lanes share common random numbers
(trace mode: one uniform field from ``sim_seed``; synth mode: the
counter-based ``crn_prng`` rows) so policy/workload/machine comparisons
are paired.  With multiple seeds the sampling switches to per-lane
``prng`` keys — each seed lane draws its own noise.

``tuning.tune``, ``benchmarks/paper_tables.py`` and
``examples/simulate_tiering.py`` route their sweeps through here.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.arms_policy import ARMSSpec
from repro.baselines.hemem import HeMemSpec
from repro.baselines.hybridtier import HybridTierSpec
from repro.baselines.jenga import JengaSpec
from repro.baselines.memtis import MemtisSpec
from repro.baselines.static import AllSlowSpec, OracleSpec
from repro.baselines.tierbpf import TierBPFSpec
from repro.baselines.tpp import TPPSpec
from repro.simulator import fabric, machine_spec, scan_engine, workload_spec
from repro.simulator import machines as machines_mod
from repro.simulator.engine import SimResult, oracle_topk_masks
from repro.simulator.sampling import uniform_field

__all__ = ["sweep", "SweepResult", "policy_spec", "POLICY_REGISTRY"]

POLICY_REGISTRY = {
    "arms": lambda: ARMSSpec.make(),
    "hemem": lambda: HeMemSpec.make(),
    "memtis": lambda: MemtisSpec.make(),
    "tpp": lambda: TPPSpec.make(),
    "all-slow": AllSlowSpec,
    "oracle": OracleSpec,
    # tier-native families (see baselines/protocol.py, tier-native contract)
    "hybridtier": lambda: HybridTierSpec.make(),
    "jenga": lambda: JengaSpec.make(),
    "tierbpf": lambda: TierBPFSpec.make(),
}

AXES = ("policy", "workload", "machine", "seed")


def policy_spec(p):
    """Resolve a policy name to its default-knob spec; specs pass through."""
    if isinstance(p, str):
        if p not in POLICY_REGISTRY:
            raise ValueError(f"unknown policy {p!r}; "
                             f"known: {sorted(POLICY_REGISTRY)}")
        return POLICY_REGISTRY[p]()
    return p


@dataclasses.dataclass
class SweepResult:
    """Structured P×W×M×S result grid.

    ``axes`` maps axis name -> labels (in order policy, workload, machine,
    seed); ``grid`` is the flat SimResult list in C order over those axes.
    """

    axes: dict
    grid: list

    @property
    def shape(self) -> tuple:
        return tuple(len(self.axes[a]) for a in AXES)

    def _index(self, axis: str, key) -> int:
        if isinstance(key, str):
            labels = [lb.lower() for lb in self.axes[axis]]
            try:
                return labels.index(key.lower())
            except ValueError:
                raise KeyError(
                    f"{key!r} not on {axis} axis {self.axes[axis]}")
        key = int(key)
        # flat C-order indexing would silently alias a negative or
        # out-of-range index into a neighbouring axis block.
        if not 0 <= key < len(self.axes[axis]):
            raise IndexError(f"{axis} index {key} out of range "
                             f"[0, {len(self.axes[axis])})")
        return key

    def at(self, policy=0, workload=0, machine=0, seed=0) -> SimResult:
        """One cell, addressed by axis label or integer index."""
        p, w, m, s = (self._index(a, v) for a, v in
                      zip(AXES, (policy, workload, machine, seed)))
        P, W, M, S = self.shape
        return self.grid[((p * W + w) * M + m) * S + s]

    def items(self):
        """Yield (coords dict, SimResult) over the full grid."""
        P, W, M, S = self.shape
        for i, res in enumerate(self.grid):
            s = i % S
            m = (i // S) % M
            w = (i // (S * M)) % W
            p = i // (S * M * W)
            yield {a: self.axes[a][j]
                   for a, j in zip(AXES, (p, w, m, s))}, res


def _dedup_labels(labels):
    """Disambiguate duplicate axis labels (``name#i``) — shared with the
    search engine, whose grouped modes key results by these labels."""
    import collections
    counts = collections.Counter(labels)
    return [f"{nm}#{i}" if counts[nm] > 1 else nm
            for i, nm in enumerate(labels)]


#: lane_stack / TieredMachineSpec placeholder names that carry no identity;
#: hand-built specs keep their given ``name``, these fall back to ``m{i}``.
_ANON_MACHINE_NAMES = ("", "machine", "lanes")


def _machine_labels(machines_in, mach_specs):
    """Axis labels for the machine axis: the preset STRING the caller
    passed, else the spec's own name, else a positional ``m{i}``."""
    labels = []
    for i, (m_in, sp) in enumerate(zip(machines_in, mach_specs)):
        if isinstance(m_in, str):
            labels.append(m_in)
            continue
        nm = getattr(sp, "name", "") or ""
        labels.append(f"m{i}" if nm in _ANON_MACHINE_NAMES else nm)
    return labels


def _resolve_workloads(workloads, T):
    specs, names = [], []
    for i, w in enumerate(workloads):
        if isinstance(w, str):
            specs.append(workload_spec.named(w, T=T))
            names.append(w)
        else:
            specs.append(w)
            names.append(workload_spec.label_of(w, f"wl{i}"))
    return specs, names


def sweep(policies, *, workloads=None, trace=None, machines="pmem-large",
          seeds=(0,), k: int, T: int | None = None, n: int | None = None,
          sim_seed: int = 0, wl_seed: int = 0, sample_u=None,
          timelines: bool = False, use_interval_kernel: bool = True,
          dispatch: str = "auto", mesh=None,
          _pad_multiple=None) -> SweepResult:
    """Axis-product sweep; ONE lane-batched dispatch for the whole panel.

    ``policies``: policy names and/or PolicySpec instances (a tuning grid
    is a list of same-family specs).  ``workloads``: workload names /
    WorkloadSpecs (device-synthesis mode; requires ``T``/``n``) — or pass
    a materialized ``trace`` instead (trace-replay mode, workload axis
    collapses to the single trace).  ``machines``: registry names /
    MachineSpecs / TieredMachineSpecs; tier depths may differ (neutral
    padding unifies them in one dispatch).  ``seeds``: one entry keeps
    all lanes CRN-paired (noise from ``sim_seed``); several entries give
    each seed lane its own PRNG noise stream.

    Per-interval outputs STREAM by default: timelines fold into running
    sums/extrema inside the scan carry (``SimResult.mean_*`` /
    ``max_promotions_interval``), so a wide sweep's output memory is
    O(lanes), independent of T.  Pass ``timelines=True`` to opt back into
    stacked [T] ``timeline_*`` series.  Scalar results are identical
    either way.  ``use_interval_kernel=False`` pins the historical
    unfused interval path (equivalence tests / kernel benchmark only).

    ``dispatch`` selects how mixed-family panels compile: ``"auto"``
    (default) fuses >1 distinct family into ONE program via the union
    fabric (simulator/fabric.py) and leaves single-family panels on the
    plain stacked path; ``"union"`` / ``"grouped"`` force either side
    (grouped = historical one-dispatch-per-family, the union path's
    bitwise reference).  ``mesh`` shards the lane axis over devices:
    ``None`` (no sharding), ``"auto"`` (all local devices), or an int
    device count — results are bitwise-identical at any mesh size;
    padded lanes are dropped before labeling.  ``_pad_multiple`` is
    test-only: it forces lane padding even on a 1-device mesh so the
    padding/labeling honesty is regression-testable anywhere.
    """
    reduce = "stack" if timelines else "stream"
    policies = [policies] if not isinstance(policies, (list, tuple)) \
        else list(policies)
    pol_specs = [policy_spec(p) for p in policies]
    machines_in = [machines] if not isinstance(machines, (list, tuple)) \
        else list(machines)
    mach_specs = [machines_mod.get(m) for m in machines_in]
    mach_labels = _machine_labels(machines_in, mach_specs)
    seeds = list(seeds)
    P, M, S = len(pol_specs), len(mach_specs), len(seeds)
    if not (P and M and S):
        raise ValueError("every axis needs at least one entry")

    synth = workloads is not None
    if synth:
        if trace is not None:
            raise ValueError("pass either trace or workloads, not both")
        if T is None or n is None:
            raise ValueError("workload-synthesis mode needs T and n")
        if not list(workloads):
            raise ValueError("every axis needs at least one entry")
        wl_specs, wl_names = _resolve_workloads(list(workloads), T)
        W = len(wl_specs)
        wl = scan_engine._stack_workloads(wl_specs)
        wl_boost = any(w.has_boost() for w in wl_specs)
    else:
        if trace is None:
            raise ValueError("need a trace or a workloads list")
        trace = np.asarray(trace)
        T, n = trace.shape
        W, wl_names = 1, ["trace"]
        oracle = oracle_topk_masks(trace, k)
    assert 0 < k <= n

    if sample_u is not None:
        if S > 1:
            # "crn" never consumes the per-lane keys: the seed lanes would
            # be silent bitwise copies of each other.
            raise ValueError("sample_u fixes the noise for every lane; "
                             "it cannot be combined with a seeds axis")
        sampling = "crn"
        sample = jnp.asarray(sample_u, jnp.float32)
        assert sample.shape == (T, n)
    elif S == 1:
        # paired comparisons: every lane shares one CRN noise source.
        sampling = "crn" if not synth else "crn_prng"
        sample = (jnp.asarray(uniform_field(T, n, seed=sim_seed))
                  if not synth else jnp.zeros((T, 1), jnp.float32))
    else:
        sampling = "prng"
        sample = jnp.zeros((T, 1), jnp.float32)

    # group same-family policies: different state pytrees cannot stack —
    # unless the union fabric fuses the mixed panel into ONE group (and
    # therefore ONE compiled program).
    if dispatch not in ("auto", "union", "grouped"):
        raise ValueError(f"dispatch={dispatch!r}; "
                         "expected auto | union | grouped")
    mach_all, caps_all = machine_spec.lane_stack(mach_specs, n, k)
    n_families = len({jax.tree_util.tree_structure(sp)
                      for sp in pol_specs})
    use_union = dispatch == "union" or (dispatch == "auto"
                                        and n_families > 1)
    if use_union:
        lane_specs = fabric.build_union(pol_specs, n, k, mach_all)
        groups = {fabric.UnionSpec: list(range(P))}
    else:
        lane_specs = pol_specs
        # key on the TREEDEF (class + meta), not the class: same-family
        # specs with different meta (e.g. migration_limit) have different
        # pad widths and cannot stack leaf-wise.
        groups = {}
        for i, sp in enumerate(pol_specs):
            groups.setdefault(jax.tree_util.tree_structure(sp),
                              []).append(i)

    grid = [None] * (P * W * M * S)
    for cls, idxs in groups.items():
        Pg = len(idxs)
        L = W * Pg * M * S
        lane = np.arange(L)
        p_local = (lane // (M * S)) % Pg
        m_of = (lane // S) % M
        s_of = lane % S
        spec_l = scan_engine._take_lanes(
            scan_engine._stack_specs([lane_specs[i] for i in idxs]),
            jnp.asarray(p_local, jnp.int32))
        mach_l = scan_engine._take_lanes(mach_all,
                                         jnp.asarray(m_of, jnp.int32))
        caps_l = jnp.take(caps_all, jnp.asarray(m_of, jnp.int32), axis=0)
        keys = jnp.stack([jax.random.PRNGKey(int(seeds[s])) for s in s_of])
        min_period = min(lane_specs[i].min_sampling_period() for i in idxs)
        if synth:
            out, finfo = fabric.sim_synth(
                spec_l, wl, k, mach_l, caps_l, keys, sample,
                jax.random.PRNGKey(sim_seed),
                jnp.stack([jax.random.PRNGKey(wl_seed)] * W),
                sampling,
                scan_engine._synth_need_normal(wl_specs, min_period),
                Pg * M * S, n, wl_boost=wl_boost,
                interval_kernel=use_interval_kernel, reduce=reduce,
                mesh=mesh, pad_multiple=_pad_multiple)
        else:
            out, finfo = fabric.sim_trace(
                spec_l, jnp.asarray(trace, jnp.float32),
                jnp.asarray(oracle), k, mach_l, caps_l, keys, sample,
                sampling, scan_engine._need_normal(trace, min_period),
                interval_kernel=use_interval_kernel, reduce=reduce,
                mesh=mesh, pad_multiple=_pad_multiple)
        out = scan_engine._timelines_lane_major(out)
        scan_engine._record_dispatch(
            lanes=L, sampling=sampling, policy=lane_specs[idxs[0]].name,
            synth=synth, workloads=W, configs=Pg, machines=M, seeds=S, T=T,
            axis_product=True, interval_kernel=use_interval_kernel,
            reduce=reduce, dispatch="union" if use_union else "grouped",
            families=n_families if use_union else 1, **finfo)
        for l in range(L):
            w = l // (Pg * M * S)
            p = idxs[p_local[l]]
            m, s = m_of[l], s_of[l]
            name = f"{pol_specs[p].name}@{wl_names[w]}[{mach_labels[m]}]"
            if S > 1:
                name += f"[seed={seeds[s]}]"
            grid[((p * W + w) * M + m) * S + s] = scan_engine._to_result(
                out, l, name)

    axes = dict(policy=_dedup_labels([sp.name for sp in pol_specs]),
                workload=_dedup_labels(wl_names),
                machine=_dedup_labels(mach_labels),
                seed=[str(s) for s in seeds])
    return SweepResult(axes=axes, grid=grid)
