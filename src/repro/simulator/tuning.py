"""Tuning study over HeMem's knobs (paper §3).

The paper uses SMAC/Bayesian optimization; the search space here is small
enough (4 knobs) that seeded random search with a modest budget finds the
same best-region configurations.  ``tune_hemem`` returns the best-performing
config per workload — the paper's "Tuned-HeMem" comparator.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.baselines.hemem import HeMemPolicy
from repro.simulator.engine import run

SPACE = dict(
    hot_threshold=[1, 2, 4, 8, 16, 32],
    cooling_threshold=[4, 9, 18, 36, 72],
    migration_period=[1, 2, 5, 10],
    sample_period=[2_500, 5_000, 10_000, 20_000],
)


def sample_configs(budget: int, seed: int = 0):
    """Seeded random draw from the knob grid (default config always tried)."""
    rng = np.random.default_rng(seed)
    keys = list(SPACE)
    grid = list(itertools.product(*(SPACE[k] for k in keys)))
    picks = rng.choice(len(grid), size=min(budget, len(grid)), replace=False)
    configs = [dict(zip(keys, grid[i])) for i in picks]
    default = dict(hot_threshold=8, cooling_threshold=18, migration_period=5,
                   sample_period=10_000)
    if default not in configs:
        configs.insert(0, default)
    return configs


def tune_hemem(trace, machine, k, budget: int = 24, seed: int = 0):
    """-> (best_config, best_result, all_rows sorted by exec time)."""
    rows = []
    for cfg in sample_configs(budget, seed):
        res = run(HeMemPolicy(**cfg), trace, machine, k, seed=seed)
        rows.append((cfg, res))
    rows.sort(key=lambda cr: cr[1].exec_time_s)
    best_cfg, best_res = rows[0]
    return best_cfg, best_res, rows
