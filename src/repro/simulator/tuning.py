"""Tuning studies: HeMem's knobs (paper §3) + ARMS internal-knob sweeps.

The paper uses SMAC/Bayesian optimization; the search space here is small
enough (4 knobs) that seeded random search with a modest budget finds the
same best-region configurations.  ``tune_hemem`` returns the best-performing
config per workload — the paper's "Tuned-HeMem" comparator.  HeMem is a
numpy policy, so its sweep replays simulations sequentially through the
reference engine.

``tune_arms`` is the JAX-native equivalent (the "From Good to Great"-style
parameter study over ARMS's internal knobs, paper §6 sensitivity): the whole
budget runs as ONE compiled ``lax.scan`` simulation batched over configs
(``scan_engine.sweep_arms_configs``) with a shared common-random-number
noise field, instead of ``budget`` sequential replays.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.baselines.hemem import HeMemPolicy
from repro.simulator.engine import run

SPACE = dict(
    hot_threshold=[1, 2, 4, 8, 16, 32],
    cooling_threshold=[4, 9, 18, 36, 72],
    migration_period=[1, 2, 5, 10],
    sample_period=[2_500, 5_000, 10_000, 20_000],
)

# ARMS internal knobs (paper §6 reports workloads are INSENSITIVE to these;
# the sweep reproduces that claim rather than hunting per-workload optima).
ARMS_SPACE = dict(
    alpha_s=[0.5, 0.6, 0.7, 0.8, 0.9],
    alpha_l=[0.05, 0.1, 0.2],
    noise_z=[0.0, 0.25, 0.5],
    pht_lambda=[0.05, 0.1, 0.2],
)
ARMS_DEFAULTS = dict(alpha_s=0.7, alpha_l=0.1, noise_z=0.25, pht_lambda=0.10)


def _sample_grid(space: dict, defaults: dict, budget: int, seed: int):
    """Seeded random draw from a knob grid (default config always tried)."""
    rng = np.random.default_rng(seed)
    keys = list(space)
    grid = list(itertools.product(*(space[k] for k in keys)))
    picks = rng.choice(len(grid), size=min(budget, len(grid)), replace=False)
    configs = [dict(zip(keys, grid[i])) for i in picks]
    if defaults not in configs:
        configs.insert(0, dict(defaults))
    return configs


def sample_configs(budget: int, seed: int = 0):
    """HeMem knob draw (default config always tried)."""
    return _sample_grid(
        SPACE,
        dict(hot_threshold=8, cooling_threshold=18, migration_period=5,
             sample_period=10_000),
        budget, seed)


def sample_arms_configs(budget: int, seed: int = 0):
    """ARMS internal-knob draw (published defaults always tried)."""
    return _sample_grid(ARMS_SPACE, ARMS_DEFAULTS, budget, seed)


def tune_hemem(trace, machine, k, budget: int = 24, seed: int = 0):
    """-> (best_config, best_result, all_rows sorted by exec time)."""
    rows = []
    for cfg in sample_configs(budget, seed):
        res = run(HeMemPolicy(**cfg), trace, machine, k, seed=seed)
        rows.append((cfg, res))
    rows.sort(key=lambda cr: cr[1].exec_time_s)
    best_cfg, best_res = rows[0]
    return best_cfg, best_res, rows


def tune_arms(trace, machine, k, budget: int = 24, seed: int = 0,
              base_cfg=None):
    """Batched ARMS internal-knob sweep: one compiled scan over all configs.

    -> (best_config, best_result, all_rows sorted by exec time).  All
    configs see identical sampling noise (shared CRN field), so row
    ordering reflects the knobs alone.
    """
    from repro.simulator.scan_engine import sweep_arms_configs

    cfgs = sample_arms_configs(budget, seed)
    overrides = {key: [c[key] for c in cfgs] for key in ARMS_SPACE}
    results = sweep_arms_configs(trace, machine, k, overrides,
                                 base_cfg=base_cfg, seed=seed)
    rows = sorted(zip(cfgs, results), key=lambda cr: cr[1].exec_time_s)
    best_cfg, best_res = rows[0]
    return best_cfg, best_res, rows
