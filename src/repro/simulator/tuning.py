"""Tuning studies: every baseline's knobs (paper §3) + ARMS internal knobs.

The paper uses SMAC/Bayesian optimization; the search spaces here are small
enough that seeded search with a modest budget finds the same best-region
configurations.  ``tune_hemem``/``tune_memtis``/``tune_tpp`` return the
best-performing config per workload — the paper's "Tuned-X" comparators —
and ``tune_arms`` is the internal-knob sensitivity study ("From Good to
Great"-style, paper §6).

All four are thin views over the compiled search engine
(``simulator/search.py``): pick ``strategy="grid"`` (the historical
exhaustive scoring, default), ``"asha"`` (successive halving over a
geometric horizon ladder) or ``"ce"`` (cross-entropy redraw) — every
search *round* runs as ONE compiled ``lax.scan`` simulation batched over
config lanes (the population rides the policy axis of
``experiment.sweep``), with every lane sharing a common-random-number
noise field — paired comparisons, so row ordering and elimination reflect
the knobs alone, and grid mode stays identical to replaying each config
through the numpy reference engine with the same field (asserted in
tests).  Machines are accepted by registry name.

Seeding is split on purpose: ``search_seed`` drives the config-grid draw
(and CE's redraw stream), ``sim_seed`` the CRN workload noise.  (Earlier
revisions used one ``seed`` for both, so changing the search seed
silently changed the noise the configs were scored under.)
"""
from __future__ import annotations

import math

import numpy as np

from repro.baselines.arms_policy import ARMSSpec
from repro.baselines.hemem import HeMemSpec
from repro.baselines.hybridtier import HybridTierSpec
from repro.baselines.jenga import JengaSpec
from repro.baselines.memtis import MemtisSpec
from repro.baselines.tierbpf import TierBPFSpec
from repro.baselines.tpp import TPPSpec
from repro.simulator import search

SPACE = dict(
    hot_threshold=[1, 2, 4, 8, 16, 32],
    cooling_threshold=[4, 9, 18, 36, 72],
    migration_period=[1, 2, 5, 10],
    sample_period=[2_500, 5_000, 10_000, 20_000],
)
HEMEM_DEFAULTS = dict(hot_threshold=8, cooling_threshold=18,
                      migration_period=5, sample_period=10_000)

MEMTIS_SPACE = dict(
    cooling_period_samples=[2.5e5, 5e5, 1e6, 2e6, 4e6],
    adaptation_period=[2, 5, 10, 20],
)
MEMTIS_DEFAULTS = dict(cooling_period_samples=2e6, adaptation_period=10)

TPP_SPACE = dict(
    promote_hits=[1, 2, 4, 8],
    watermark=[0.90, 0.95, 0.98, 0.995],
)
TPP_DEFAULTS = dict(promote_hits=2, watermark=0.98)

# ARMS internal knobs (paper §6 reports workloads are INSENSITIVE to these;
# the sweep reproduces that claim rather than hunting per-workload optima).
ARMS_SPACE = dict(
    alpha_s=[0.5, 0.6, 0.7, 0.8, 0.9],
    alpha_l=[0.05, 0.1, 0.2],
    noise_z=[0.0, 0.25, 0.5],
    pht_lambda=[0.05, 0.1, 0.2],
)
ARMS_DEFAULTS = dict(alpha_s=0.7, alpha_l=0.1, noise_z=0.25, pht_lambda=0.10)

# Tier-native families (PR 8).  Their knobs route through the same grid /
# asha / ce strategies — the search engine groups lanes by spec type, so a
# tier-native population still runs as one compiled dispatch per round.
HYBRIDTIER_SPACE = dict(
    hot_thresh=[2.0, 4.0, 6.0, 9.0, 12.0],
    warm_thresh=[0.5, 1.0, 2.0],
    decay=[0.5, 0.7, 0.9],
    migration_period=[2, 4, 8],
)
HYBRIDTIER_DEFAULTS = dict(hot_thresh=6.0, warm_thresh=1.0, decay=0.7,
                           migration_period=4)

JENGA_SPACE = dict(
    alpha=[0.3, 0.5, 0.7, 0.9],
    confirm=[1, 2, 3, 4],
    cooldown=[0, 1, 3, 6],
    migration_period=[1, 2],
)
JENGA_DEFAULTS = dict(alpha=0.5, confirm=2, cooldown=3, migration_period=1)

TIERBPF_SPACE = dict(
    alpha=[0.3, 0.5, 0.7],
    admit_thresh=[1.0, 2.0, 4.0, 8.0],
    thrash_gain=[0.5, 1.0, 2.0, 4.0],
    regret_alpha=[0.1, 0.3, 0.5],
)
TIERBPF_DEFAULTS = dict(alpha=0.5, admit_thresh=2.0, thrash_gain=2.0,
                        regret_alpha=0.3)

#: name -> (spec factory taking the space's keys as kwargs, space, defaults)
FAMILIES = {
    "hemem": (HeMemSpec.make, SPACE, HEMEM_DEFAULTS),
    "memtis": (MemtisSpec.make, MEMTIS_SPACE, MEMTIS_DEFAULTS),
    "tpp": (TPPSpec.make, TPP_SPACE, TPP_DEFAULTS),
    "arms": (lambda **cfg: ARMSSpec.make(cfg), ARMS_SPACE, ARMS_DEFAULTS),
    "hybridtier": (HybridTierSpec.make, HYBRIDTIER_SPACE,
                   HYBRIDTIER_DEFAULTS),
    "jenga": (JengaSpec.make, JENGA_SPACE, JENGA_DEFAULTS),
    "tierbpf": (TierBPFSpec.make, TIERBPF_SPACE, TIERBPF_DEFAULTS),
}


def _decode_grid_index(space: dict, keys: list, sizes: list, i: int) -> dict:
    """Mixed-radix decode of flat grid index ``i`` (last knob fastest —
    the ``itertools.product`` C order earlier revisions materialized)."""
    vals, rem = {}, int(i)
    for nm, size in zip(reversed(keys), reversed(sizes)):
        vals[nm] = space[nm][rem % size]
        rem //= size
    return {nm: vals[nm] for nm in keys}


def _sample_grid(space: dict, defaults: dict, budget: int, seed: int):
    """Seeded random draw from a knob grid (default config always tried).

    Grid indices are sampled and mixed-radix-decoded directly — the
    Cartesian product is never materialized, so the draw is O(budget)
    even for the larger spaces the search engine defines.  Returns at
    most ``budget`` configs: when the default config isn't among the
    draws, it REPLACES the last draw instead of growing the list (earlier
    revisions returned ``budget + 1`` configs).
    """
    rng = np.random.default_rng(seed)
    keys = list(space)
    sizes = [len(space[nm]) for nm in keys]
    total = math.prod(sizes)
    m = max(1, min(budget, total))
    if total > max(4096, 4 * m):
        # huge grid: rejection-sample unique indices, O(m) memory.
        picks, seen = [], set()
        while len(picks) < m:
            i = int(rng.integers(total))
            if i not in seen:
                seen.add(i)
                picks.append(i)
    else:
        # small grid: same draw stream as the historical rng.choice over
        # the materialized product, so seeded grids stay bit-identical.
        picks = [int(i) for i in rng.choice(total, size=m, replace=False)]
    configs = [_decode_grid_index(space, keys, sizes, i) for i in picks]
    defaults = dict(defaults)
    if defaults not in configs:
        if len(configs) >= budget:
            configs = configs[:max(0, budget - 1)]
        configs.insert(0, defaults)
    return configs


def sample_configs(budget: int, seed: int = 0):
    """HeMem knob draw (default config always tried)."""
    return _sample_grid(SPACE, HEMEM_DEFAULTS, budget, seed)


def sample_arms_configs(budget: int, seed: int = 0):
    """ARMS internal-knob draw (published defaults always tried)."""
    return _sample_grid(ARMS_SPACE, ARMS_DEFAULTS, budget, seed)


def _legacy(sr: search.SearchResult):
    return sr.best_config, sr.best_result, sr.rows


def tune(family: str, trace, machine, k, budget: int = 24,
         search_seed: int = 0, sim_seed: int = 0, space: dict | None = None,
         defaults: dict | None = None, workloads=None, T: int | None = None,
         n: int | None = None, *, strategy: str = "grid", machines=None,
         eta: int = 3, rounds: int | None = None, t_min: int = 16,
         ce_rounds: int = 4, elite_frac: float = 0.25,
         ce_smoothing: float = 0.7, base_cfg=None, mesh=None):
    """Lane-batched tuning for any policy family, under any strategy.

    -> (best_config, best_result, all (config, result) rows sorted by exec
    time).  ``search_seed`` draws the config grid (and CE's redraws);
    ``sim_seed`` seeds the shared CRN noise all lanes are scored under.
    ``machine`` may be a registry name, a MachineSpec, or a
    TieredMachineSpec (machines.get).

    ``strategy`` selects the search loop (see ``simulator/search.py``):
    ``"grid"`` scores the whole budget in one full-horizon dispatch (the
    historical behaviour); ``"asha"`` (knobs ``eta``/``rounds``/``t_min``)
    eliminates over a geometric horizon ladder; ``"ce"`` (knobs
    ``ce_rounds``/``elite_frac``/``ce_smoothing``) refits a sampling
    distribution per round.  Every round of any strategy is ONE compiled
    dispatch per family.  For round records / dispatch counts /
    lane-interval accounting, call ``search.run`` directly — this view
    keeps the historical return shape.

    Workload-lane mode: pass ``workloads`` (a list of workload names or
    ``WorkloadSpec``s, plus ``T``/``n``; ``trace`` must then be None) to
    search across W workloads with each round ONE compiled dispatch of
    W x population lanes — traces are synthesized on device, nothing
    [T, n] is materialized, and the return value becomes a dict
    ``{workload_name: (best_config, best_result, rows)}``.

    Machine-lane mode: pass ``machines=[...]`` (registry names / specs;
    ``machine`` is then ignored) to tune per machine — per-machine
    elimination with each round's union population x M machines in one
    dispatch — returning ``{machine_name: (best_config, best_result,
    rows)}``.  ``search.transfer_matrix`` builds the cross-deployment
    robustness table on top of this mode.

    All modes inherit the sweep's streaming reduction — rows carry scalar
    summaries, not ``timeline_*`` arrays — so tuning memory is O(lanes)
    regardless of T.  ``mesh`` shards each round's lanes over devices
    (simulator/fabric.py) with bitwise-identical rankings.
    """
    out = search.run(family, strategy, trace=trace, machine=machine,
                     machines=machines, workloads=workloads, k=k,
                     budget=budget, eta=eta, rounds=rounds, t_min=t_min,
                     ce_rounds=ce_rounds, elite_frac=elite_frac,
                     ce_smoothing=ce_smoothing, search_seed=search_seed,
                     sim_seed=sim_seed, space=space, defaults=defaults,
                     base_cfg=base_cfg, T=T, n=n, mesh=mesh)
    if isinstance(out, dict):
        return {nm: _legacy(sr) for nm, sr in out.items()}
    return _legacy(out)


def tune_hemem(trace, machine, k, budget: int = 24, search_seed: int = 0,
               sim_seed: int = 0, strategy: str = "grid", **kw):
    """The paper's "Tuned-HeMem" comparator, as one compiled batched sweep."""
    return tune("hemem", trace, machine, k, budget, search_seed, sim_seed,
                strategy=strategy, **kw)


def tune_memtis(trace, machine, k, budget: int = 24, search_seed: int = 0,
                sim_seed: int = 0, strategy: str = "grid", **kw):
    return tune("memtis", trace, machine, k, budget, search_seed, sim_seed,
                strategy=strategy, **kw)


def tune_tpp(trace, machine, k, budget: int = 24, search_seed: int = 0,
             sim_seed: int = 0, strategy: str = "grid", **kw):
    return tune("tpp", trace, machine, k, budget, search_seed, sim_seed,
                strategy=strategy, **kw)


def tune_arms(trace, machine, k, budget: int = 24, search_seed: int = 0,
              sim_seed: int = 0, base_cfg=None, strategy: str = "grid",
              **kw):
    """Batched ARMS internal-knob search: one compiled scan per round.

    Routed through the unified ``tune(family="arms", ...)`` path, so
    ASHA/CE work for ARMS knobs too; trace-mode single-machine searches
    keep the ARMS-specialized sweep (precomputed per-mode observation
    grids) rather than the generic per-interval CRN transform.
    """
    return tune("arms", trace, machine, k, budget, search_seed, sim_seed,
                base_cfg=base_cfg, strategy=strategy, **kw)
