"""Tuning studies: every baseline's knobs (paper §3) + ARMS internal knobs.

The paper uses SMAC/Bayesian optimization; the search spaces here are small
enough that seeded random search with a modest budget finds the same
best-region configurations.  ``tune_hemem``/``tune_memtis``/``tune_tpp``
return the best-performing config per workload — the paper's "Tuned-X"
comparators — and ``tune_arms`` is the internal-knob sensitivity study
("From Good to Great"-style, paper §6).

All four are thin wrappers over one ``tune`` entry point: the whole search
budget runs as ONE compiled ``lax.scan`` simulation batched over config
lanes (the config grid rides the policy axis of ``experiment.sweep``),
with every lane sharing a common-random-number noise field — paired
comparisons, so row ordering reflects the knobs alone, and identical to
replaying each config through the numpy reference engine with the same
field (asserted in tests).  Machines are accepted by registry name.

Seeding is split on purpose: ``search_seed`` drives the config-grid draw,
``sim_seed`` the CRN workload noise.  (Earlier revisions used one ``seed``
for both, so changing the search seed silently changed the noise the
configs were scored under.)
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.baselines.arms_policy import ARMSSpec
from repro.baselines.hemem import HeMemSpec
from repro.baselines.memtis import MemtisSpec
from repro.baselines.tpp import TPPSpec
from repro.simulator import experiment, scan_engine

SPACE = dict(
    hot_threshold=[1, 2, 4, 8, 16, 32],
    cooling_threshold=[4, 9, 18, 36, 72],
    migration_period=[1, 2, 5, 10],
    sample_period=[2_500, 5_000, 10_000, 20_000],
)
HEMEM_DEFAULTS = dict(hot_threshold=8, cooling_threshold=18,
                      migration_period=5, sample_period=10_000)

MEMTIS_SPACE = dict(
    cooling_period_samples=[2.5e5, 5e5, 1e6, 2e6, 4e6],
    adaptation_period=[2, 5, 10, 20],
)
MEMTIS_DEFAULTS = dict(cooling_period_samples=2e6, adaptation_period=10)

TPP_SPACE = dict(
    promote_hits=[1, 2, 4, 8],
    watermark=[0.90, 0.95, 0.98, 0.995],
)
TPP_DEFAULTS = dict(promote_hits=2, watermark=0.98)

# ARMS internal knobs (paper §6 reports workloads are INSENSITIVE to these;
# the sweep reproduces that claim rather than hunting per-workload optima).
ARMS_SPACE = dict(
    alpha_s=[0.5, 0.6, 0.7, 0.8, 0.9],
    alpha_l=[0.05, 0.1, 0.2],
    noise_z=[0.0, 0.25, 0.5],
    pht_lambda=[0.05, 0.1, 0.2],
)
ARMS_DEFAULTS = dict(alpha_s=0.7, alpha_l=0.1, noise_z=0.25, pht_lambda=0.10)

#: name -> (spec factory taking the space's keys as kwargs, space, defaults)
FAMILIES = {
    "hemem": (HeMemSpec.make, SPACE, HEMEM_DEFAULTS),
    "memtis": (MemtisSpec.make, MEMTIS_SPACE, MEMTIS_DEFAULTS),
    "tpp": (TPPSpec.make, TPP_SPACE, TPP_DEFAULTS),
    "arms": (lambda **cfg: ARMSSpec.make(cfg), ARMS_SPACE, ARMS_DEFAULTS),
}


def _sample_grid(space: dict, defaults: dict, budget: int, seed: int):
    """Seeded random draw from a knob grid (default config always tried)."""
    rng = np.random.default_rng(seed)
    keys = list(space)
    grid = list(itertools.product(*(space[k] for k in keys)))
    picks = rng.choice(len(grid), size=min(budget, len(grid)), replace=False)
    configs = [dict(zip(keys, grid[i])) for i in picks]
    if defaults not in configs:
        configs.insert(0, dict(defaults))
    return configs


def sample_configs(budget: int, seed: int = 0):
    """HeMem knob draw (default config always tried)."""
    return _sample_grid(SPACE, HEMEM_DEFAULTS, budget, seed)


def sample_arms_configs(budget: int, seed: int = 0):
    """ARMS internal-knob draw (published defaults always tried)."""
    return _sample_grid(ARMS_SPACE, ARMS_DEFAULTS, budget, seed)


def tune(family: str, trace, machine, k, budget: int = 24,
         search_seed: int = 0, sim_seed: int = 0, space: dict | None = None,
         defaults: dict | None = None, workloads=None, T: int | None = None,
         n: int | None = None):
    """Lane-batched random-search tuning for any policy family.

    -> (best_config, best_result, all (config, result) rows sorted by exec
    time).  ``search_seed`` draws the config grid; ``sim_seed`` seeds the
    shared CRN noise all lanes are scored under.  ``machine`` may be a
    registry name, a MachineSpec, or a TieredMachineSpec (machines.get).

    Workload-lane mode: pass ``workloads`` (a list of workload names or
    ``WorkloadSpec``s, plus ``T``/``n``; ``trace`` must then be None) to
    score ONE config grid across W workloads in ONE compiled dispatch of
    W x budget lanes — traces are synthesized on device, nothing [T, n]
    is materialized, and the return value becomes a dict
    ``{workload_name: (best_config, best_result, rows)}``.

    Both modes are thin views over ``experiment.sweep``: the config grid
    rides the policy axis of the axis-product API.  They inherit the
    sweep's streaming reduction — rows carry scalar summaries, not
    ``timeline_*`` arrays — so tuning memory is O(lanes) regardless of T.
    """
    if family not in FAMILIES:
        raise ValueError(f"unknown family {family!r}; "
                         f"known: {sorted(FAMILIES)}")
    make, fam_space, fam_defaults = FAMILIES[family]
    configs = _sample_grid(space if space is not None else fam_space,
                           defaults if defaults is not None else fam_defaults,
                           budget, search_seed)
    pol_specs = [make(**cfg) for cfg in configs]
    if workloads is not None:
        if trace is not None:
            raise ValueError("pass either trace or workloads, not both")
        if T is None or n is None:
            raise ValueError("workload-lane tuning needs T and n")
        res = experiment.sweep(pol_specs, workloads=list(workloads),
                               machines=[machine], k=k, T=T, n=n,
                               sim_seed=sim_seed)
        # result-dict keys come straight from the sweep's workload axis
        # (names resolved + duplicate labels disambiguated there), so the
        # two label schemes cannot drift.
        out = {}
        for w, nm in enumerate(res.axes["workload"]):
            results = [res.at(policy=b, workload=w)
                       for b in range(len(configs))]
            rows = sorted(zip(configs, results),
                          key=lambda cr: cr[1].exec_time_s)
            out[nm] = (rows[0][0], rows[0][1], rows)
        return out
    res = experiment.sweep(pol_specs, trace=trace, machines=[machine], k=k,
                           sim_seed=sim_seed)
    results = [res.at(policy=b) for b in range(len(configs))]
    rows = sorted(zip(configs, results), key=lambda cr: cr[1].exec_time_s)
    best_cfg, best_res = rows[0]
    return best_cfg, best_res, rows


def tune_hemem(trace, machine, k, budget: int = 24, search_seed: int = 0,
               sim_seed: int = 0):
    """The paper's "Tuned-HeMem" comparator, as one compiled batched sweep."""
    return tune("hemem", trace, machine, k, budget, search_seed, sim_seed)


def tune_memtis(trace, machine, k, budget: int = 24, search_seed: int = 0,
                sim_seed: int = 0):
    return tune("memtis", trace, machine, k, budget, search_seed, sim_seed)


def tune_tpp(trace, machine, k, budget: int = 24, search_seed: int = 0,
             sim_seed: int = 0):
    return tune("tpp", trace, machine, k, budget, search_seed, sim_seed)


def tune_arms(trace, machine, k, budget: int = 24, search_seed: int = 0,
              sim_seed: int = 0, base_cfg=None):
    """Batched ARMS internal-knob sweep: one compiled scan over all configs.

    Uses the ARMS-specialized sweep (precomputed per-mode observation
    grids) rather than the generic per-interval CRN transform.
    """
    cfgs = sample_arms_configs(budget, search_seed)
    overrides = {key: [c[key] for c in cfgs] for key in ARMS_SPACE}
    results = scan_engine.sweep_arms_configs(trace, machine, k, overrides,
                                             base_cfg=base_cfg,
                                             seed=sim_seed)
    rows = sorted(zip(cfgs, results), key=lambda cr: cr[1].exec_time_s)
    best_cfg, best_res = rows[0]
    return best_cfg, best_res, rows
