"""PEBS-style access sampling emulation (paper §2, §4.1).

Hardware event sampling observes roughly 1 in ``period`` accesses; over an
interval the per-page sample count is well modeled as Poisson(true/period).
This reproduces the sampling inaccuracies the paper identifies (§3.2): two
pages with identical true rates receive different counts over short windows,
and sparse-but-hot pages may briefly receive zero samples.
"""
from __future__ import annotations

import numpy as np


def pebs_sample(true_counts: np.ndarray, period: float,
                rng: np.random.Generator) -> np.ndarray:
    """Observed per-page sample counts for one interval."""
    lam = np.maximum(true_counts, 0.0) / float(period)
    return rng.poisson(lam).astype(np.float64)
