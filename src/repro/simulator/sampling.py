"""PEBS-style access sampling emulation (paper §2, §4.1).

Hardware event sampling observes roughly 1 in ``period`` accesses; over an
interval the per-page sample count is well modeled as Poisson(true/period).
This reproduces the sampling inaccuracies the paper identifies (§3.2): two
pages with identical true rates receive different counts over short windows,
and sparse-but-hot pages may briefly receive zero samples.
"""
from __future__ import annotations

import numpy as np


def pebs_sample(true_counts: np.ndarray, period: float,
                rng: np.random.Generator) -> np.ndarray:
    """Observed per-page sample counts for one interval."""
    lam = np.maximum(true_counts, 0.0) / float(period)
    return rng.poisson(lam).astype(np.float64)


# --------------------------------------------------------------------------
# Common-random-number (CRN) sampling path, shared by the numpy reference
# engine and the compiled lax.scan engine (simulator/scan_engine.py).
#
# The two engines cannot share numpy's bit-level Poisson sampler, so for
# engine-equivalence (and for paired comparisons across configs in tuning
# sweeps) the noise is expressed as a precomputed uniform field u[t, page]
# and both engines apply the SAME jitted inverse-CDF transform
# ``pebs_sample_from_uniform`` to it.  Identical u + identical transform =>
# bitwise-identical observed counts on both paths.
# --------------------------------------------------------------------------

_POISSON_TERMS = 24      # exact inverse-CDF terms; P(N >= 24 | lam < 12) ~ 1e-3
_NORMAL_SWITCH = 12.0    # above this rate use the normal approximation


def pebs_sample_from_uniform(u, true_counts, period, *,
                             need_normal: bool = True):
    """Jittable Poisson-from-uniform PEBS sample (CRN path).

    ``u`` in [0,1) per page; small rates use the exact inverse CDF (pmf by
    the recurrence p_j = p_{j-1} * lam / j — one ``exp`` per element, the
    rest cheap multiply/adds), large rates the rounded normal approximation.
    The noise *model* only needs to be Poisson-like; what matters is that
    both engines apply this exact transform.

    ``need_normal=False`` statically drops the ndtri branch; callers may set
    it when ``max(lam) < _NORMAL_SWITCH`` (the selected values are identical
    either way — the normal branch would be dead).
    """
    import jax
    import jax.numpy as jnp

    u = jnp.asarray(u, jnp.float32)
    lam = jnp.maximum(jnp.asarray(true_counts, jnp.float32), 0.0) \
        / jnp.asarray(period, jnp.float32)
    # Unrolled recurrence (NOT cumsum/cumprod: XLA lowers those to a
    # quadratic reduce-window on CPU, ~30x slower than this elementwise
    # chain at simulator scale).
    pmf = jnp.exp(-lam)
    cdf = pmf
    out = (cdf < u).astype(jnp.float32)
    for j in range(1, _POISSON_TERMS):
        pmf = pmf * lam / j
        cdf = cdf + pmf
        out = out + (cdf < u)
    if need_normal:
        z = jax.scipy.special.ndtri(jnp.clip(u, 1e-7, 1.0 - 1e-7))
        large = jnp.maximum(jnp.floor(lam + z * jnp.sqrt(lam) + 0.5), 0.0)
        out = jnp.where(lam < _NORMAL_SWITCH, out, large)
    return jnp.where(lam <= 0.0, 0.0, out)


def uniform_field(T: int, n: int, seed: int = 0) -> np.ndarray:
    """Host-side CRN uniform noise field for a whole trace replay."""
    return np.random.default_rng(seed).random((T, n)).astype(np.float32)


# --------------------------------------------------------------------------
# Device-resident CRN rows for the trace-synthesis path.
#
# Workload-lane sweeps (scan_engine.sweep_workloads / sweep_workload_configs)
# never build a [T, n] array anywhere: each interval draws ONE uniform row
# from a counter-based key (fold_in by t — no consumed key chain), shared by
# every sweep lane, so config comparisons stay paired while per-lane storage
# stays O(n).  ``synth_noise_field`` reconstructs the same rows host-side so
# the numpy reference engine can replay a synth run bitwise (tests only —
# it IS the O(T*n) array the synth path avoids).
# --------------------------------------------------------------------------

def synth_uniform_row(key, t, n: int):
    """Jittable [n] uniform row for interval ``t`` (shared across lanes)."""
    import jax
    import jax.numpy as jnp

    return jax.random.uniform(jax.random.fold_in(key, t), (n,),
                              dtype=jnp.float32)


def synth_noise_field(T: int, n: int, seed: int = 0) -> np.ndarray:
    """Host [T, n] replica of the rows a synth run draws in-scan."""
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(seed)
    rows = jax.vmap(lambda t: synth_uniform_row(key, t, n))(jnp.arange(T))
    return np.asarray(rows)
