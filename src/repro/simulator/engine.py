"""Tiered-memory simulation engine.

Replays a workload trace (true per-interval access counts) against a policy
that only sees PEBS-sampled counts + bandwidth signals, enforces migration
capacity/validity, charges migration traffic to tier bandwidth, and scores
execution time, migration counts, wasteful migrations, and hot-set recall.

Execution-time semantics: every interval carries identical application work,
so ``exec_time = sum(interval wall times)`` — matching the paper's
"execution time for fixed work" methodology (Fig. 2).

This is the *reference* engine: policies arrive as stateful ``Policy``
objects (today: ``protocol.LegacyPolicyAdapter`` around a functional
``PolicySpec``), and migrations are variable-length index lists.  The
compiled scan engine (scan_engine.py) replays the same specs with
fixed-shape sentinel-padded migrations; under a shared CRN field
(``sample_u``) the two agree exactly, for every policy.

Placement is an i32 per-page TIER INDEX over an N-tier chain
(simulator/machine_spec.py): ``machine`` may be a registry name, a legacy
two-tier ``MachineSpec``, or a ``TieredMachineSpec``; promotions move
pages to tier 0 (capped by its capacity), demotions cascade down to the
first tier with room, and each adjacent pair crossed charges its
endpoints' bandwidth.  At N=2 this replays bitwise like the historical
boolean ``in_fast`` engine.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.baselines.base import Policy
from repro.simulator.sampling import pebs_sample

WASTE_WINDOW = 20  # intervals; promote->demote (or inverse) within = wasteful


@dataclasses.dataclass
class SimResult:
    name: str
    exec_time_s: float
    promotions: int
    demotions: int
    wasteful: int
    hot_recall: float            # mean fraction of oracle top-k held fast
    fast_hit_frac: float         # fraction of accesses served by fast tier
    # [T] per-interval series; None under the scan engine's streaming
    # reduction (reduce="stream"), which folds them into the summaries
    # below instead of materializing anything [T]-shaped.
    timeline_slow_bw: np.ndarray | None = None
    timeline_fast_hits: np.ndarray | None = None
    timeline_mode: np.ndarray | None = None  # ARMS mode (0 elsewhere)
    timeline_promotions: np.ndarray | None = None
    # streaming summaries (None under reduce="stack"; derive them from the
    # timelines there instead).
    mean_slow_bw: float | None = None
    mean_fast_hits: float | None = None
    mean_mode: float | None = None
    max_promotions_interval: int | None = None

    def row(self) -> dict:
        return dict(name=self.name, exec_time_s=round(self.exec_time_s, 4),
                    promotions=self.promotions, demotions=self.demotions,
                    wasteful=self.wasteful,
                    hot_recall=round(self.hot_recall, 4),
                    fast_hit_frac=round(self.fast_hit_frac, 4))


_CRN_SAMPLE = None


def _crn_sampler():
    """Module-cached jitted CRN sampler so compilation amortizes across
    run() calls (a fresh jax.jit wrapper per call would retrace every
    time)."""
    global _CRN_SAMPLE
    if _CRN_SAMPLE is None:
        import jax

        from repro.simulator.sampling import pebs_sample_from_uniform
        _CRN_SAMPLE = jax.jit(pebs_sample_from_uniform)
    return _CRN_SAMPLE


def oracle_topk_masks(trace: np.ndarray, k: int) -> np.ndarray:
    """[T, n] bool mask of each interval's true top-k pages, vectorized.

    Hoisted out of the interval loop (one partition over the whole trace
    instead of T per-interval ones) and shared with the scan engine so both
    score recall against the identical oracle.  The tie rule matches
    ``jax.lax.top_k`` exactly — strictly-greater values first, then
    threshold-equal values by ascending page index — so the
    device-computed oracle of the trace-synthesis path
    (``scan_engine.simulate_workload``) agrees bitwise with this host mask
    on the same f32 trace.
    """
    trace = np.asarray(trace)
    n = trace.shape[1]
    assert 0 < k <= n
    kth = np.partition(trace, n - k, axis=1)[:, n - k, None]
    greater = trace > kth
    need = k - greater.sum(axis=1, keepdims=True, dtype=np.int32)
    eq = trace == kth
    # i32 cumsum: counts are bounded by n, and the default i64 temporary
    # would be 2x the trace's own footprint at bench scale
    return greater | (eq & (np.cumsum(eq, axis=1, dtype=np.int32) <= need))


def apply_tier_migrations_np(tier, promote, demote, caps):
    """Numpy mirror of ``simjax.apply_tier_migrations`` (variable-length
    index lists instead of padded arrays; mutates ``tier`` in place).

    Returns (promote_exec, demote_exec, mig_up, mig_down): the executed
    page-index arrays (priority order preserved) and the i64 [R-1]
    adjacent-pair crossing counts.
    """
    R = len(caps)
    demote = np.asarray(demote, np.int64)
    promote = np.asarray(promote, np.int64)

    src = tier[demote]
    keep = src < R - 1
    demote, src = demote[keep], src[keep]
    dest = np.full(len(demote), R - 1, np.int64)
    occ = np.bincount(tier, minlength=R).astype(np.int64)
    occ -= np.bincount(src, minlength=R)          # departures free slots
    landed = np.zeros(len(demote), bool)
    for r in range(1, R - 1):
        cand = np.flatnonzero(~landed & (src < r))
        take = cand[:max(int(caps[r] - occ[r]), 0)]
        dest[take] = r
        landed[take] = True
        occ[r] += len(take)
    tier[demote] = dest
    mig_down = np.array([((src <= j) & (dest > j)).sum()
                         for j in range(R - 1)], np.int64)

    p_src = tier[promote]
    keep = p_src > 0
    promote, p_src = promote[keep], p_src[keep]
    room = max(int(caps[0]) - int((tier == 0).sum()), 0)
    promote, p_src = promote[:room], p_src[:room]
    tier[promote] = 0
    mig_up = np.array([(p_src > j).sum() for j in range(R - 1)], np.int64)
    return promote, demote, mig_up, mig_down


def apply_targeted_migrations_np(tier, pages, dst, caps):
    """Numpy mirror of ``simjax.apply_targeted_migrations`` (variable-length
    aligned ``pages``/``dst`` lists; mutates ``tier`` in place).

    Returns (up_exec, down_exec, mig_up, mig_down): executed up-/down-move
    page arrays (priority order preserved) and i64 [R-1] pair crossings.
    """
    from repro.simulator.simjax import DST_BELOW

    R = len(caps)
    pages = np.asarray(pages, np.int64)
    dst = np.asarray(dst, np.int64)
    src = tier[pages]
    dst = np.where(dst == DST_BELOW, src + 1, dst)
    dst = np.clip(dst, 0, R - 1)

    down_m = dst > src
    d_pages, d_src, d_dst = pages[down_m], src[down_m], dst[down_m]
    dest = np.full(len(d_pages), R - 1, np.int64)
    landed = np.zeros(len(d_pages), bool)
    for r in range(1, R - 1):
        occ_r = int((tier == r).sum()) - int((d_src == r).sum())
        cand = np.flatnonzero(~landed & (d_dst <= r))
        take = cand[:max(int(caps[r]) - occ_r, 0)]
        dest[take] = r
        landed[take] = True
    tier[d_pages] = dest
    mig_down = np.array([((d_src <= j) & (dest > j)).sum()
                         for j in range(R - 1)], np.int64)

    u_pages, u_dst = pages[~down_m], dst[~down_m]
    taken = np.zeros(len(u_pages), bool)
    u_from = np.zeros(len(u_pages), np.int64)
    for r in range(R - 1):
        u_src = tier[u_pages] if len(u_pages) else u_pages
        cand = np.flatnonzero((u_dst == r) & (u_src > r))
        room = max(int(caps[r]) - int((tier == r).sum()), 0)
        take = cand[:room]
        u_from[take] = u_src[take]
        tier[u_pages[take]] = r
        taken[take] = True
    mig_up = np.array([(taken & (u_from > j) & (u_dst <= j)).sum()
                       for j in range(R - 1)], np.int64)
    return u_pages[taken], d_pages, mig_up, mig_down


def run(policy: Policy, trace: np.ndarray, machine, k: int,
        seed: int = 0, sample_u: np.ndarray | None = None) -> SimResult:
    """Replay ``trace`` under ``policy`` (numpy reference engine).

    ``machine``: registry name, two-tier ``MachineSpec``, or
    ``TieredMachineSpec`` (resolved via ``machines.get``).

    ``sample_u``: optional [T, n] uniform field switching PEBS sampling (and
    the cost model) to the common-random-number path shared with the
    compiled scan engine — both engines then see bitwise-identical noise and
    interval arithmetic, which is what makes exact cross-engine equivalence
    testable.  Default (None) keeps the original numpy Poisson sampling.
    """
    from repro.simulator import machine_spec, machines

    machine = machines.get(machine)
    R = machine.n_tiers
    T, n = trace.shape
    assert 0 < k <= n
    caps = machine_spec.resolved_caps(machine, n, k)
    rng = np.random.default_rng(seed)
    policy.reset(n, k, machine)
    oracle_mask = oracle_topk_masks(trace, k)
    if sample_u is not None:
        import jax
        import jax.numpy as jnp

        from repro.simulator import simjax
        assert sample_u.shape == (T, n)
        crn_sample = _crn_sampler()
        # one explicit f32/device conversion of the machine leaves before
        # the loop (not T implicit downcasts inside it) — also what keeps
        # the cost arithmetic f32, and therefore bitwise-equal to the scan
        # engine's, even under jax_enable_x64.
        mach_dev = jax.tree_util.tree_map(
            lambda v: jnp.asarray(v, jnp.float32), machine)

    tier = np.full(n, R - 1, np.int32)    # everything starts at the bottom
    promoted_at = np.full(n, -(10 ** 9))
    demoted_at = np.full(n, -(10 ** 9))
    tier_native = bool(getattr(policy, "tier_native", False))
    tier_util = np.zeros(R)               # last interval's per-tier load

    slow_bw_frac = 1.0   # everything starts slow
    app_bw_frac = 0.0
    exec_time = 0.0
    promotions = demotions = wasteful = 0
    acc_fast_total = acc_total = 0.0
    recall_sum = 0.0
    tl_slow = np.zeros(T)
    tl_hits = np.zeros(T)
    tl_mode = np.zeros(T, np.int32)
    tl_promos = np.zeros(T, np.int32)

    for t in range(T):
        true = trace[t]
        if policy.wants_true_counts():
            observed = true
        elif sample_u is not None:
            observed = np.asarray(crn_sample(
                sample_u[t], true.astype(np.float32),
                np.float32(policy.sampling_period())), np.float64)
        else:
            observed = pebs_sample(true, policy.sampling_period(), rng)

        if tier_native:
            pages, dstv = policy.step_tiers(
                observed, slow_bw_frac, app_bw_frac, tier_util, caps)
            # tier-targeted execution: ups/downs share the binary path's
            # wasteful/counter accounting (an up-move IS a promotion).
            promote, demote, mig_up, mig_down = apply_targeted_migrations_np(
                tier, pages, dstv, caps)
        else:
            promote, demote = policy.step(observed, slow_bw_frac,
                                          app_bw_frac)
            # --- engine-side validation, capacity + hop-chain execution ---
            promote, demote, mig_up, mig_down = apply_tier_migrations_np(
                tier, promote, demote, caps)

        # --- wasteful-migration accounting ---
        wasteful += int((t - demoted_at[promote] <= WASTE_WINDOW).sum())
        wasteful += int((t - promoted_at[demote] <= WASTE_WINDOW).sum())
        promoted_at[promote] = t
        demoted_at[demote] = t
        promotions += len(promote)
        demotions += len(demote)
        tl_promos[t] = len(promote)

        # --- cost model ---
        if sample_u is not None:
            # CRN mode: identical f32 arithmetic to the scan engine.
            acc_fast, acc_slow, wall, slow_share, app_raw = (
                float(v) for v in simjax.interval_accounting(
                    mach_dev, true.astype(np.float32), jnp.asarray(tier),
                    mig_up.astype(np.float32), mig_down.astype(np.float32)))
        else:
            in_fast = tier == 0
            acc_fast = float(true[in_fast].sum())
            accs = [acc_fast]
            rest = float(true.sum()) - acc_fast
            for r in range(1, R - 1):
                a = float(true[tier == r].sum())
                accs.append(a)
                rest -= a
            accs.append(rest)
            acc_slow = sum(accs[1:])
            wall, slow_share, app_raw, _ = machine_spec.interval_outcome_host(
                machine, accs, mig_up, mig_down)
        # policy-mechanism overhead charged to the application (e.g. TPP's
        # NUMA hint faults are taken on slow-tier accesses).
        extra_ns = getattr(policy, "slow_access_extra_ns", 0.0)
        if extra_ns:
            wall += acc_slow * extra_ns * 1e-9 / float(machine.mlp)
        exec_time += wall
        # The paper's PHT input is slow-tier bandwidth; when the slow tier
        # saturates, utilization pegs at 1 and carries no signal, so we feed
        # the underlying quantity PHT is meant to detect (§4.2: "more memory
        # references go to the slow tier"): the slow-access share.
        slow_bw_frac = slow_share
        # consumer-side clamp of the RAW utilization ratio: the policy
        # signal stays in [0,1] (bitwise the old at-source clamp).
        app_bw_frac = min(1.0, app_raw)
        if tier_native:
            if sample_u is not None:
                tier_util = np.asarray(simjax.tier_utilization(
                    mach_dev, true.astype(np.float32), jnp.asarray(tier),
                    mig_up.astype(np.float32),
                    mig_down.astype(np.float32)), np.float64)
            else:
                tier_util = machine_spec.tier_utilization_host(
                    machine, accs, mig_up, mig_down)

        acc_fast_total += acc_fast
        acc_total += acc_fast + acc_slow
        recall_sum += float((tier == 0)[oracle_mask[t]].sum()) / k
        tl_slow[t] = slow_bw_frac
        tl_hits[t] = acc_fast / max(acc_fast + acc_slow, 1e-9)
        tl_mode[t] = getattr(policy, "mode", 0)

    return SimResult(
        name=policy.name, exec_time_s=exec_time, promotions=promotions,
        demotions=demotions, wasteful=wasteful,
        hot_recall=recall_sum / T,
        fast_hit_frac=acc_fast_total / max(acc_total, 1e-9),
        timeline_slow_bw=tl_slow, timeline_fast_hits=tl_hits,
        timeline_mode=tl_mode, timeline_promotions=tl_promos)
