"""Tiered-memory simulation engine.

Replays a workload trace (true per-interval access counts) against a policy
that only sees PEBS-sampled counts + bandwidth signals, enforces migration
capacity/validity, charges migration traffic to tier bandwidth, and scores
execution time, migration counts, wasteful migrations, and hot-set recall.

Execution-time semantics: every interval carries identical application work,
so ``exec_time = sum(interval wall times)`` — matching the paper's
"execution time for fixed work" methodology (Fig. 2).

This is the *reference* engine: policies arrive as stateful ``Policy``
objects (today: ``protocol.LegacyPolicyAdapter`` around a functional
``PolicySpec``), and migrations are variable-length index lists.  The
compiled scan engine (scan_engine.py) replays the same specs with
fixed-shape sentinel-padded migrations; under a shared CRN field
(``sample_u``) the two agree exactly, for every policy.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.baselines.base import Policy
from repro.simulator.machine import MachineSpec, interval_time
from repro.simulator.sampling import pebs_sample

WASTE_WINDOW = 20  # intervals; promote->demote (or inverse) within = wasteful


@dataclasses.dataclass
class SimResult:
    name: str
    exec_time_s: float
    promotions: int
    demotions: int
    wasteful: int
    hot_recall: float            # mean fraction of oracle top-k held fast
    fast_hit_frac: float         # fraction of accesses served by fast tier
    timeline_slow_bw: np.ndarray
    timeline_fast_hits: np.ndarray
    timeline_mode: np.ndarray    # ARMS mode per interval (0 elsewhere)
    timeline_promotions: np.ndarray

    def row(self) -> dict:
        return dict(name=self.name, exec_time_s=round(self.exec_time_s, 4),
                    promotions=self.promotions, demotions=self.demotions,
                    wasteful=self.wasteful,
                    hot_recall=round(self.hot_recall, 4),
                    fast_hit_frac=round(self.fast_hit_frac, 4))


_CRN_SAMPLE = None


def _crn_sampler():
    """Module-cached jitted CRN sampler so compilation amortizes across
    run() calls (a fresh jax.jit wrapper per call would retrace every
    time)."""
    global _CRN_SAMPLE
    if _CRN_SAMPLE is None:
        import jax

        from repro.simulator.sampling import pebs_sample_from_uniform
        _CRN_SAMPLE = jax.jit(pebs_sample_from_uniform)
    return _CRN_SAMPLE


def oracle_topk_masks(trace: np.ndarray, k: int) -> np.ndarray:
    """[T, n] bool mask of each interval's true top-k pages, vectorized.

    Hoisted out of the interval loop (one partition over the whole trace
    instead of T per-interval ones) and shared with the scan engine so both
    score recall against the identical oracle.  The tie rule matches
    ``jax.lax.top_k`` exactly — strictly-greater values first, then
    threshold-equal values by ascending page index — so the
    device-computed oracle of the trace-synthesis path
    (``scan_engine.simulate_workload``) agrees bitwise with this host mask
    on the same f32 trace.
    """
    trace = np.asarray(trace)
    n = trace.shape[1]
    assert 0 < k <= n
    kth = np.partition(trace, n - k, axis=1)[:, n - k, None]
    greater = trace > kth
    need = k - greater.sum(axis=1, keepdims=True, dtype=np.int32)
    eq = trace == kth
    # i32 cumsum: counts are bounded by n, and the default i64 temporary
    # would be 2x the trace's own footprint at bench scale
    return greater | (eq & (np.cumsum(eq, axis=1, dtype=np.int32) <= need))


def run(policy: Policy, trace: np.ndarray, machine: MachineSpec, k: int,
        seed: int = 0, sample_u: np.ndarray | None = None) -> SimResult:
    """Replay ``trace`` under ``policy`` (numpy reference engine).

    ``sample_u``: optional [T, n] uniform field switching PEBS sampling (and
    the cost model) to the common-random-number path shared with the
    compiled scan engine — both engines then see bitwise-identical noise and
    interval arithmetic, which is what makes exact cross-engine equivalence
    testable.  Default (None) keeps the original numpy Poisson sampling.
    """
    T, n = trace.shape
    assert 0 < k <= n
    rng = np.random.default_rng(seed)
    policy.reset(n, k, machine)
    oracle_mask = oracle_topk_masks(trace, k)
    if sample_u is not None:
        from repro.simulator import simjax
        assert sample_u.shape == (T, n)
        mp = simjax.machine_params(machine)
        crn_sample = _crn_sampler()

    in_fast = np.zeros(n, bool)
    promoted_at = np.full(n, -(10 ** 9))
    demoted_at = np.full(n, -(10 ** 9))

    slow_bw_frac = 1.0   # everything starts slow
    app_bw_frac = 0.0
    exec_time = 0.0
    promotions = demotions = wasteful = 0
    acc_fast_total = acc_total = 0.0
    recall_sum = 0.0
    tl_slow = np.zeros(T)
    tl_hits = np.zeros(T)
    tl_mode = np.zeros(T, np.int32)
    tl_promos = np.zeros(T, np.int32)

    for t in range(T):
        true = trace[t]
        if policy.wants_true_counts():
            observed = true
        elif sample_u is not None:
            observed = np.asarray(crn_sample(
                sample_u[t], true.astype(np.float32),
                np.float32(policy.sampling_period())), np.float64)
        else:
            observed = pebs_sample(true, policy.sampling_period(), rng)

        promote, demote = policy.step(observed, slow_bw_frac, app_bw_frac)

        # --- engine-side validation & capacity enforcement ---
        demote = np.asarray(demote, np.int64)
        promote = np.asarray(promote, np.int64)
        demote = demote[in_fast[demote]]
        in_fast[demote] = False
        promote = promote[~in_fast[promote]]
        room = k - int(in_fast.sum())
        promote = promote[:room]
        in_fast[promote] = True

        # --- wasteful-migration accounting ---
        wasteful += int((t - demoted_at[promote] <= WASTE_WINDOW).sum())
        wasteful += int((t - promoted_at[demote] <= WASTE_WINDOW).sum())
        promoted_at[promote] = t
        demoted_at[demote] = t
        promotions += len(promote)
        demotions += len(demote)
        tl_promos[t] = len(promote)

        # --- cost model ---
        if sample_u is not None:
            # CRN mode: identical f32 arithmetic to the scan engine.
            acc_fast, acc_slow, wall, slow_share, app_frac = (
                float(v) for v in simjax.interval_accounting(
                    mp, true.astype(np.float32), in_fast,
                    float(len(promote)), float(len(demote))))
        else:
            acc_fast = float(true[in_fast].sum())
            acc_slow = float(true.sum()) - acc_fast
            out = interval_time(machine, acc_fast, acc_slow,
                                len(promote), len(demote))
            wall = out.wall_s
            slow_share = acc_slow / max(acc_fast + acc_slow, 1e-9)
            app_frac = out.app_bw_frac
        # policy-mechanism overhead charged to the application (e.g. TPP's
        # NUMA hint faults are taken on slow-tier accesses).
        extra_ns = getattr(policy, "slow_access_extra_ns", 0.0)
        if extra_ns:
            wall += acc_slow * extra_ns * 1e-9 / machine.mlp
        exec_time += wall
        # The paper's PHT input is slow-tier bandwidth; when the slow tier
        # saturates, utilization pegs at 1 and carries no signal, so we feed
        # the underlying quantity PHT is meant to detect (§4.2: "more memory
        # references go to the slow tier"): the slow-access share.
        slow_bw_frac = slow_share
        app_bw_frac = app_frac

        acc_fast_total += acc_fast
        acc_total += acc_fast + acc_slow
        recall_sum += float(in_fast[oracle_mask[t]].sum()) / k
        tl_slow[t] = slow_bw_frac
        tl_hits[t] = acc_fast / max(acc_fast + acc_slow, 1e-9)
        tl_mode[t] = getattr(policy, "mode", 0)

    return SimResult(
        name=policy.name, exec_time_s=exec_time, promotions=promotions,
        demotions=demotions, wasteful=wasteful,
        hot_recall=recall_sum / T,
        fast_hit_frac=acc_fast_total / max(acc_total, 1e-9),
        timeline_slow_bw=tl_slow, timeline_fast_hits=tl_hits,
        timeline_mode=tl_mode, timeline_promotions=tl_promos)
