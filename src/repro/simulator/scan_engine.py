"""Compiled ``lax.scan`` simulation engine + lane-batched sweeps, for EVERY
policy speaking the functional protocol (baselines/protocol.py).

The numpy engine (engine.py) replays a trace with a Python loop and one
policy call per interval — fine as a *reference*, but host<->device
round-trips and per-call dispatch dominate, and tuning studies replay
dozens of full simulations sequentially.  Here the entire replay — PEBS
sampling, the policy (via its pure ``observe``/``fires``/``policy``
functions), engine-side capacity/validity enforcement, the interval cost
model, and wasteful/recall accounting — is one ``jax.lax.scan`` over
intervals, compiled once and executed with zero per-interval host syncs.
On top of it:

  * ``simulate``             — single run of ANY spec, SimResult output;
  * ``sweep_seeds``          — batched over PRNG keys (sampling-noise
    study: per-lane noise drawn from keys threaded through the carry);
  * ``sweep_policy_configs`` — batched over a policy family's knobs: one
    spec per lane, all lanes sharing one CRN noise field (paired
    comparisons — config differences are never confounded with noise);
  * ``arms_sim`` / ``sweep_arms_configs`` — the ARMS-specialized wrappers
    (the latter precomputes both mode-dependent observation grids once and
    broadcasts them, so ARMS config lanes pay zero sampling cost);
  * ``simulate_workload`` / ``sweep_workloads`` / ``sweep_workload_configs``
    — the trace-SYNTHESIS path: the scan carries ``WorkloadSpec`` state
    (simulator/workload_spec.py) and synthesizes ``true = work * probs``
    plus the oracle top-k mask on device each interval; per-lane storage
    is O(n), nothing ``[T, n]`` exists on host or device.

MACHINES are sweep lanes too: every entry point accepts a registry name
(``machines.get``), a legacy two-tier ``MachineSpec``, or an N-tier
``TieredMachineSpec`` (simulator/machine_spec.py), and the machine's
f32 per-tier leaves ride the same lane axis as policy and workload
knobs — ``experiment.sweep`` flattens a P×W×M×S axis product into ONE
dispatch of this engine.  The scan carry holds an i32 per-page tier
index; migrations are adjacent-pair hop chains
(``simjax.apply_tier_migrations``) and the interval cost charges each
tier's bandwidth separately.  N=2 replays are bitwise-identical to the
historical boolean two-tier engine (tests/test_machine_spec.py).

Batching layout: sweep lanes live in an explicit leading axis of the scan
carry rather than under an outer ``vmap`` of the whole simulation.  This
matters: the policy-pass gate is a ``lax.cond`` on the *scalar*
``any(lane fires)``, so on intervals where no lane's policy is due the
expensive pass (top-k / sort ranking dominates the profile) is genuinely
skipped — an outer vmap would turn that cond into a select and pay the
policy every interval.  Inside the fire branch the policy IS ``jax.vmap``-ed
over lanes, with per-lane knobs read from the spec's batched leaves.

Engine-side bookkeeping is shared with the numpy engine via
``simulator/simjax.py``; with a common-random-number uniform field
(``sample_u``) the two engines agree bitwise on sampling and interval
arithmetic, so promotions/demotions/wasteful counts match exactly for every
policy (see tests/test_scan_engine.py).

NOTE on the module boundary: ``simulator/experiment.py`` (the axis-product
orchestrator) assembles lanes directly on this module's underscore helpers
(``_sim_jit``/``_sim_synth_jit``, ``_stack_specs``/``_stack_workloads``/
``_take_lanes``, ``_need_normal``/``_synth_need_normal``, ``_to_result``/
``_timelines_lane_major``/``_record_dispatch``).  They are a load-bearing
internal contract shared by exactly those two modules — change their
signatures in lockstep.
"""
from __future__ import annotations

import contextlib
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.arms_policy import SWEEPABLE, ARMSSpec
from repro.core.state import ARMSConfig
from repro.kernels.interval_step import ops as interval_ops
from repro.simulator import machine_spec, machines, simjax, workload_spec
from repro.simulator.engine import SimResult, oracle_topk_masks
from repro.simulator.sampling import (_NORMAL_SWITCH, pebs_sample_from_uniform,
                                      synth_uniform_row, uniform_field)

__all__ = [
    "SWEEPABLE", "simulate", "sweep_seeds", "sweep_policy_configs",
    "arms_sim", "sweep_arms_configs", "simulate_workload",
    "sweep_workloads", "sweep_workload_configs", "last_dispatch",
    "dispatch_count", "count_dispatches", "DispatchCounter",
]

#: Info about the most recent compiled dispatch (lanes, sampling mode).
#: The CI quick gates read this to assert tuning and machine sweeps stay
#: lane-batched instead of silently regressing to a sequential loop.
last_dispatch: dict = {}
#: monotone count of compiled simulation dispatches this process has issued
#: (every ``_record_dispatch`` call).  Kept for observability; callers that
#: ASSERT on dispatch deltas use ``count_dispatches`` below — differencing
#: the global races when two measured regions interleave.
dispatch_count: int = 0


class DispatchCounter:
    """Live tally handed out by ``count_dispatches``: ``count`` dispatches
    so far, ``records`` their ``_record_dispatch`` info dicts in order."""

    def __init__(self):
        self.count = 0
        self.records: list = []

    @property
    def last(self) -> dict:
        return self.records[-1] if self.records else {}


#: counters currently open via ``count_dispatches`` (nesting is fine: every
#: open counter sees every dispatch issued inside its region).
_active_counters: list = []


@contextlib.contextmanager
def count_dispatches():
    """Context-managed dispatch counter for gates and the search engine.

        with scan_engine.count_dispatches() as ctr:
            experiment.sweep(...)
        assert ctr.count == 1 and ctr.last["lanes"] == L

    Unlike read-and-reset differencing of the module-global
    ``dispatch_count``, concurrent/nested measured regions cannot race:
    each region owns its counter and only dispatches issued within the
    region are tallied.
    """
    ctr = DispatchCounter()
    _active_counters.append(ctr)
    try:
        yield ctr
    finally:
        _active_counters.remove(ctr)


def _need_normal(trace, min_period: float) -> bool:
    """Static: can any page's sampling rate reach the normal-approx regime?

    When False the ndtri branch of the sampler is dead code and statically
    dropped; selected values are identical either way, so this never
    affects cross-engine equivalence.
    """
    return bool(np.max(trace) / float(min_period) >= _NORMAL_SWITCH)


def _bwhere(pred, a, b):
    """Per-lane select: pred [B], leaves [B] or [B, ...]."""
    return jax.tree_util.tree_map(
        lambda x, y: jnp.where(pred.reshape((-1,) + (1,) * (x.ndim - 1)),
                               x, y), a, b)


def _lane_specs(spec, B: int):
    """Broadcast one spec's leaves to B identical sweep lanes."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(jnp.asarray(x),
                                   (B,) + jnp.shape(jnp.asarray(x))), spec)


def _stack_specs(specs):
    """Stack same-family specs leaf-wise into one lane-batched spec."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *specs)


def _take_lanes(pytree, idx):
    """Gather lanes of a lane-batched pytree along axis 0."""
    return jax.tree_util.tree_map(lambda x: jnp.take(x, idx, axis=0), pytree)


def _stack_workloads(wl_specs):
    """Stack WorkloadSpecs into one [W]-lane spec (component-count padded)."""
    S = max(sp.n_components for sp in wl_specs)
    return _stack_specs([workload_spec.pad_components(sp, S)
                         for sp in wl_specs])


def _mach_lanes(machine, B: int, n: int, k: int):
    """One machine broadcast to B lanes -> (mach [B,...], caps i32 [B, R])."""
    mach, caps = machine_spec.lane_stack([machines.get(machine)], n, k)
    idx = jnp.zeros((B,), jnp.int32)
    return _take_lanes(mach, idx), jnp.take(caps, idx, axis=0)


def _topk_mask(x, k: int):
    """Device oracle mask: exact top-k of ``x``, tie rule identical to the
    host ``oracle_topk_masks`` (strictly-greater first, then ascending
    index among threshold-equal values — ``lax.top_k``'s rule)."""
    _, idx = jax.lax.top_k(x, k)
    return jnp.zeros(x.shape, bool).at[idx].set(True)


def _init_carry(spec, B: int, n: int, k: int, mach, keys):
    f32 = jnp.float32
    cls = type(spec)
    R = mach.lat_ns.shape[-1]
    state = jax.vmap(lambda sp, mc: cls.init(sp, n, k, mc),
                     axis_size=B)(spec, mach)
    return dict(
        state=state,
        tier=jnp.full((B, n), R - 1, jnp.int32),   # start at the bottom
        promoted_at=jnp.full((B, n), -(10 ** 9), jnp.int32),
        demoted_at=jnp.full((B, n), -(10 ** 9), jnp.int32),
        t=jnp.zeros((), jnp.int32),
        key=keys,
        slow_bw=jnp.ones((B,), f32),      # everything starts slow
        app_bw=jnp.zeros((B,), f32),
        exec_time=jnp.zeros((B,), f32),
        promotions=jnp.zeros((B,), jnp.int32),
        demotions=jnp.zeros((B,), jnp.int32),
        wasteful=jnp.zeros((B,), jnp.int32),
        acc_fast_total=jnp.zeros((B,), f32),
        acc_total=jnp.zeros((B,), f32),
        recall_sum=jnp.zeros((B,), f32),
    )


def _simulate(spec, trace, oracle_mask, k: int, mach, caps, keys, sample,
              sampling: str, need_normal: bool, wl=None, wl_keys=None,
              noise_key=None, wl_rep: int = 1, n: int | None = None,
              wl_boost: bool = True, interval_kernel: bool = True,
              reduce: str = "stack", tier_shim: bool = False, widx=None):
    """Traceable batched replay; returns a dict of [B] scalars + timelines.

    Lanes (= sweep entries) form the leading axis of every carried array,
    of every leaf of ``spec``, and of every leaf of ``mach`` (a
    ``TieredMachineSpec`` with [B, R]-shaped tier leaves; ``caps`` is the
    resolved i32 [B, R] per-tier capacity).  True counts come from one of
    two sources:
      * trace mode (``wl is None``): ``trace`` is a host-materialized
        [T, n] array scanned as xs, with the host-computed ``oracle_mask``;
      * synth mode: ``wl`` is a [W]-lane-batched ``WorkloadSpec`` whose
        state lives in the scan carry — each interval synthesizes
        ``true = work * probs`` on device (each workload lane feeding
        ``wl_rep`` consecutive policy lanes) and the oracle top-k mask is
        computed on device from the synthesized counts.  No [T, n] array
        exists anywhere; per-lane storage is O(n).  Workload
        re-randomization events are gated behind a scalar any-lane
        ``lax.cond`` exactly like the policy pass.

    ``sampling`` (static) selects the PEBS noise source:
      * "prng": per-lane keys threaded through the carry; per-interval
        uniforms transformed by the shared Poisson inverse-CDF;
      * "crn":  ``sample`` is a [T, n] uniform field, transformed per
        interval with each lane's sampling period — the path the numpy
        engine mirrors bitwise;
      * "crn_prng": one uniform row per interval drawn on device from
        ``noise_key`` (counter-based fold_in by t), shared across lanes —
        CRN pairing without any [T, n] field (synth-mode default);
      * "pre":  ``sample`` is a [T, P, n] stack of precomputed observation
        grids (one per period in the family's ``PRE_PERIODS``); lanes only
        select by ``spec.obs_index(state)``.

    ``interval_kernel`` (static) routes the interval hot path through the
    fused ``kernels/interval_step`` ops — threshold-select oracle masks
    instead of full ``lax.top_k`` + scatter, migrations + wasteful
    accounting hoisted inside the any-lane fire cond (bitwise a no-op on
    non-fire intervals, so the hop-chain gather/scatter work is genuinely
    skipped), and single-call fused accounting + recall.  Every route is
    bitwise-equal to the unfused path under CRN (tests/test_interval_step).

    ``reduce`` (static) selects the per-interval output layout:
      * "stack":  timelines stacked into [T, B] ys (historical layout);
      * "stream": timelines folded into running sums/extrema inside the
        scan carry — the scan emits NO ys, so per-lane output memory is
        O(n), not O(T).  The result dict then carries ``mean_*`` /
        ``max_promotions_interval`` summaries and no ``timeline_*`` keys.

    Specs with ``tier_native`` take the TIER-TARGETED route: the carry
    additionally holds the last interval's per-tier utilization (f32
    [B, R], ``simjax.tier_utilization``), the policy emits aligned
    ``(pages, dst)`` moves via ``tier_policy``, and the engine executes
    them with ``simjax.apply_targeted_migrations`` — up-moves count as
    promotions, down-moves as demotions, sharing the binary path's
    wasteful accounting.  ``tier_shim`` (static) forces BINARY specs
    through that same route via the base-class shim; it is bitwise-equal
    to the default hop-chain path (tests/test_tier_native.py), and exists
    so tests can assert exactly that.
    """
    assert reduce in ("stack", "stream")
    if wl is None:
        T, n = trace.shape
    else:
        T = sample.shape[0]
        wl_cls = type(wl)
    B = keys.shape[0]
    cls = type(spec)
    pad_p, pad_d = spec.pad_promote(n, k), spec.pad_demote(n, k)
    f32 = jnp.float32

    tn = cls.tier_native or tier_shim
    vobserve = jax.vmap(cls.observe)
    vfires = jax.vmap(cls.fires)
    vpolicy = jax.vmap(cls.policy, in_axes=(0, 0, 0, 0, None))
    vtier_policy = jax.vmap(cls.tier_policy,
                            in_axes=(0, 0, 0, 0, 0, None, 0))
    vperiod = jax.vmap(cls.sampling_period)
    vmode = jax.vmap(cls.mode_of)

    def observed_for(xs_sample, true_b, state, subs, t0):
        if cls.wants_true_counts:
            return true_b
        if sampling == "pre":
            idx = jax.vmap(cls.obs_index)(spec, state)          # [B]
            return xs_sample[idx]                               # [B, n]
        period = vperiod(spec, state)[:, None]                  # [B, 1]
        if sampling == "prng":
            u = jax.vmap(lambda s: jax.random.uniform(s, (n,), dtype=f32)
                         )(subs)
            sampled = pebs_sample_from_uniform(u, true_b, period,
                                               need_normal=need_normal)
        elif sampling == "crn_prng":
            u = synth_uniform_row(noise_key, t0, n)
            sampled = pebs_sample_from_uniform(u[None], true_b, period,
                                               need_normal=need_normal)
        else:
            sampled = pebs_sample_from_uniform(xs_sample[None], true_b,
                                               period,
                                               need_normal=need_normal)
        if cls.mixed_observation:
            # union lanes mixing observation kinds (fabric.py): oracle
            # lanes read true counts, the rest keep the sampled row the
            # whole batch shares — bitwise what each family's own
            # dispatch would observe.
            wt = jax.vmap(cls.wants_true_lane)(spec)            # [B]
            sampled = jnp.where(wt[:, None], true_b, sampled)
        return sampled

    def step(c, xs):
        if wl is None:
            true, orc, xs_sample = xs
            true_b = jnp.broadcast_to(true[None], (B, n))        # [B, n]
            orc_b = jnp.broadcast_to(orc[None], (B, n))
            wst = None
        else:
            xs_sample = xs
            wst, tw = c["wl_state"], c["t"]
            due = jax.vmap(wl_cls.event_due, in_axes=(0, 0, None))(
                wl, wst, tw)
            # scalar any-lane gate: permutation redraws (sorts) only run
            # on intervals where some workload lane has an event due.
            wst = jax.lax.cond(
                jnp.any(due),
                lambda s: jax.vmap(
                    lambda w, st_: wl_cls.event(w, st_, tw, wl_boost))(
                    wl, s),
                lambda s: s, wst)
            probs = jax.vmap(wl_cls.probs_of, in_axes=(0, 0, None))(
                wl, wst, tw)                                     # [W, n]
            workt = jax.vmap(wl_cls.work_of, in_axes=(0, 0, None))(
                wl, wst, tw)                                     # [W]
            true_w = workt[:, None] * probs
            orc_w = (interval_ops.topk_mask(true_w, k) if interval_kernel
                     else jax.vmap(lambda x: _topk_mask(x, k))(true_w))
            if widx is None:
                true_b = jnp.repeat(true_w, wl_rep, axis=0)      # [B, n]
                orc_b = jnp.repeat(orc_w, wl_rep, axis=0)
            else:
                # sharded lanes (fabric.py): every shard synthesizes the
                # full replicated [W] workload stack and gathers its own
                # lanes' rows by GLOBAL workload index — a row gather is
                # value-wise exactly the ``repeat`` above, so shard
                # results are bitwise the unsharded path's.
                true_b = jnp.take(true_w, widx, axis=0)          # [B, n]
                orc_b = jnp.take(orc_w, widx, axis=0)
        state = c["state"]
        split = jax.vmap(jax.random.split, out_axes=1)(c["key"])
        key, subs = split[0], split[1]
        observed = observed_for(xs_sample, true_b, state, subs, c["t"])
        t = c["t"] + 1
        state = vobserve(spec, state, observed)
        do = vfires(spec, state)                                # [B]

        R = caps.shape[-1]

        def plan(st):
            new_state, promote, demote = vpolicy(
                spec, st, c["slow_bw"], c["app_bw"], k)
            # lanes whose policy is not due keep their state; their padded
            # outputs are blanked so no migrations execute.
            st = _bwhere(do, new_state, st)
            promote = jnp.where(do[:, None], promote, -1)
            demote = jnp.where(do[:, None], demote, -1)
            return st, promote, demote

        if tn:
            # Tier-targeted route: the policy sees the per-tier utilization
            # and emits (pages, dst) moves; migrations + wasteful
            # accounting ride inside the any-lane fire cond (bitwise a
            # no-op on skip intervals — all-(-1) pages execute nothing).
            def fire(op):
                st, tier0, p_at0, d_at0 = op
                st2, pages, dst = vtier_policy(
                    spec, st, c["tier_util"], c["slow_bw"], c["app_bw"], k,
                    caps)
                st = _bwhere(do, st2, st)
                pages = jnp.where(do[:, None], pages, -1)
                tier, up_exec, down_exec, mig_up, mig_down = jax.vmap(
                    simjax.apply_targeted_migrations)(tier0, pages, dst,
                                                      caps)
                waste, p_at, d_at = jax.vmap(
                    simjax.wasteful_update,
                    in_axes=(None, 0, 0, 0, 0, 0, 0))(
                    t - 1, p_at0, d_at0, pages, pages, up_exec, down_exec)
                return (st, tier, p_at, d_at,
                        up_exec.sum(axis=1).astype(jnp.int32),
                        down_exec.sum(axis=1).astype(jnp.int32), waste,
                        mig_up, mig_down)

            def skip(op):
                st, tier0, p_at0, d_at0 = op
                z = jnp.zeros((B,), jnp.int32)
                zp = jnp.zeros((B, R - 1), jnp.int32)
                return st, tier0, p_at0, d_at0, z, z, z, zp, zp

            (state, tier, promoted_at, demoted_at, n_promo, n_demo, waste,
             mig_up, mig_down) = jax.lax.cond(
                jnp.any(do), fire, skip,
                (state, c["tier"], c["promoted_at"], c["demoted_at"]))
            if interval_kernel:
                acc_fast, acc_slow, wall, slow_share, app_raw, recall = \
                    interval_ops.interval_account(
                        mach, true_b, tier, mig_up.astype(f32),
                        mig_down.astype(f32), orc_b, k)
            else:
                acc_fast, acc_slow, wall, slow_share, app_raw = jax.vmap(
                    simjax.interval_accounting_impl)(
                    mach, true_b, tier, mig_up.astype(f32),
                    mig_down.astype(f32))
                recall = ((tier == 0) & orc_b).sum(axis=1).astype(f32) / k
        elif interval_kernel:
            # Fused route: migrations + wasteful accounting ride INSIDE the
            # any-lane fire cond.  On non-fire intervals the unfused path
            # executes them against all-(-1) plans — a bitwise no-op — so
            # skipping them entirely preserves CRN equivalence while
            # dropping the hop-chain gather/scatter from most intervals.
            def fire(op):
                st, tier0, p_at0, d_at0 = op
                st, promote, demote = plan(st)
                tier, pexec, dexec, mig_up, mig_down = \
                    interval_ops.tier_migrate(tier0, promote, demote, caps)
                waste, p_at, d_at = jax.vmap(
                    simjax.wasteful_update,
                    in_axes=(None, 0, 0, 0, 0, 0, 0))(
                    t - 1, p_at0, d_at0, promote, demote, pexec, dexec)
                return (st, tier, p_at, d_at,
                        pexec.sum(axis=1).astype(jnp.int32),
                        dexec.sum(axis=1).astype(jnp.int32), waste,
                        mig_up, mig_down)

            def skip(op):
                st, tier0, p_at0, d_at0 = op
                z = jnp.zeros((B,), jnp.int32)
                zp = jnp.zeros((B, R - 1), jnp.int32)
                return st, tier0, p_at0, d_at0, z, z, z, zp, zp

            (state, tier, promoted_at, demoted_at, n_promo, n_demo, waste,
             mig_up, mig_down) = jax.lax.cond(
                jnp.any(do), fire, skip,
                (state, c["tier"], c["promoted_at"], c["demoted_at"]))
            acc_fast, acc_slow, wall, slow_share, app_raw, recall = \
                interval_ops.interval_account(
                    mach, true_b, tier, mig_up.astype(f32),
                    mig_down.astype(f32), orc_b, k)
        else:
            def fire(st):
                return plan(st)

            def skip(st):
                return (st, jnp.full((B, pad_p), -1, jnp.int32),
                        jnp.full((B, pad_d), -1, jnp.int32))

            # Scalar predicate: the policy pass (top-k / sort ranking
            # dominates its cost) only runs on intervals where at least one
            # lane's cadence is due — unlike an outer vmap-of-cond, which
            # would select-execute it every interval.
            state, promote, demote = jax.lax.cond(jnp.any(do), fire, skip,
                                                  state)

            tier, pexec, dexec, mig_up, mig_down = jax.vmap(
                simjax.apply_tier_migrations, in_axes=(0, 0, 0, 0))(
                c["tier"], promote, demote, caps)
            n_promo = pexec.sum(axis=1).astype(jnp.int32)       # [B]
            n_demo = dexec.sum(axis=1).astype(jnp.int32)
            waste, promoted_at, demoted_at = jax.vmap(
                simjax.wasteful_update, in_axes=(None, 0, 0, 0, 0, 0, 0))(
                t - 1, c["promoted_at"], c["demoted_at"], promote, demote,
                pexec, dexec)
            acc_fast, acc_slow, wall, slow_share, app_raw = jax.vmap(
                simjax.interval_accounting_impl)(
                mach, true_b, tier, mig_up.astype(f32),
                mig_down.astype(f32))
            recall = ((tier == 0) & orc_b).sum(axis=1).astype(f32) / k
        if cls.mixed_observation:
            # per-lane mechanism overhead (union lanes): non-TPP lanes
            # carry 0.0, and ``wall + acc_slow * 0.0 * 1e-9 / mlp`` adds
            # +0.0 to a nonnegative finite wall — a bitwise no-op.
            extra = jax.vmap(cls.slow_extra_lane)(spec)          # [B]
            wall = wall + acc_slow * extra * f32(1e-9) / mach.mlp
        elif cls.slow_access_extra_ns:
            # policy-mechanism overhead charged to the application (TPP's
            # NUMA hint faults are taken on slow-tier accesses).
            wall = wall + acc_slow * f32(cls.slow_access_extra_ns) \
                * f32(1e-9) / mach.mlp

        new_c = dict(
            state=state, tier=tier,
            promoted_at=promoted_at, demoted_at=demoted_at, t=t, key=key,
            slow_bw=slow_share,
            # consumer-side clamp of the RAW tier-0 utilization: the
            # policy-facing signal stays in [0,1] (bitwise the historical
            # at-source clamp; the raw ratio keeps oversaturation visible
            # to accounting consumers).
            app_bw=jnp.minimum(1.0, app_raw),
            exec_time=c["exec_time"] + wall,
            promotions=c["promotions"] + n_promo,
            demotions=c["demotions"] + n_demo,
            wasteful=c["wasteful"] + waste,
            acc_fast_total=c["acc_fast_total"] + acc_fast,
            acc_total=c["acc_total"] + acc_fast + acc_slow,
            recall_sum=c["recall_sum"] + recall)
        if tn:
            new_c["tier_util"] = jax.vmap(simjax.tier_utilization_impl)(
                mach, true_b, tier, mig_up.astype(f32),
                mig_down.astype(f32))
        if wl is not None:
            new_c["wl_state"] = wst
        hits_val = acc_fast / jnp.maximum(acc_fast + acc_slow, 1e-9)
        if reduce == "stream":
            # per-interval outputs folded into the carry: the scan emits no
            # ys, so nothing [T, ...]-shaped is ever allocated.
            new_c["slow_sum"] = c["slow_sum"] + slow_share
            new_c["hits_sum"] = c["hits_sum"] + hits_val
            new_c["mode_sum"] = c["mode_sum"] + vmode(spec, state)
            new_c["promos_max"] = jnp.maximum(c["promos_max"], n_promo)
            ys = {}
        else:
            ys = dict(slow=slow_share, hits=hits_val,
                      mode=vmode(spec, state), promos=n_promo)
        return new_c, ys

    carry = _init_carry(spec, B, n, k, mach, keys)
    if tn:
        carry["tier_util"] = jnp.zeros((B, caps.shape[-1]), f32)
    if reduce == "stream":
        carry["slow_sum"] = jnp.zeros((B,), f32)
        carry["hits_sum"] = jnp.zeros((B,), f32)
        carry["mode_sum"] = jnp.zeros((B,), jnp.int32)
        carry["promos_max"] = jnp.zeros((B,), jnp.int32)
    if wl is None:
        trace = jnp.asarray(trace, f32)
        xs = (trace, jnp.asarray(oracle_mask, bool), sample)
    else:
        carry["wl_state"] = jax.vmap(wl_cls.init, in_axes=(0, None, 0))(
            wl, n, wl_keys)
        xs = sample
    carry, ys = jax.lax.scan(step, carry, xs)
    out = dict(
        exec_time=carry["exec_time"], promotions=carry["promotions"],
        demotions=carry["demotions"], wasteful=carry["wasteful"],
        hot_recall=carry["recall_sum"] / T,
        fast_hit_frac=carry["acc_fast_total"]
        / jnp.maximum(carry["acc_total"], 1e-9))
    if reduce == "stream":
        out.update(
            mean_slow_bw=carry["slow_sum"] / T,
            mean_fast_hits=carry["hits_sum"] / T,
            mean_mode=carry["mode_sum"].astype(f32) / T,
            max_promotions_interval=carry["promos_max"])
    else:
        out.update(
            timeline_slow_bw=ys["slow"], timeline_fast_hits=ys["hits"],
            timeline_mode=ys["mode"], timeline_promotions=ys["promos"])
    return out


#: Donation lists: every donated position is (re)built fresh at each call
#: site — spec / mach / caps lane stacks and PRNG key stacks — so XLA can
#: reuse their buffers for outputs.  trace / oracle / sample are NEVER
#: donated: callers hold and reuse them across dispatches (CRN pairing).
#: Donation is best-effort by shape: [B]-shaped spec leaves alias the [B]
#: result scalars; the machine's small [B, R] rows have no same-shaped
#: output, which XLA reports per dispatch — silence just that notice.
warnings.filterwarnings(
    "ignore", message="Some donated buffers were not usable")
@functools.partial(
    jax.jit, static_argnames=("k", "sampling", "need_normal",
                              "interval_kernel", "reduce", "tier_shim"),
    donate_argnums=(0, 4, 5, 6))
def _sim_jit(spec, trace, oracle_mask, k, mach, caps, keys, sample,
             sampling, need_normal, interval_kernel=True, reduce="stack",
             tier_shim=False):
    return _simulate(spec, trace, oracle_mask, k, mach, caps, keys, sample,
                     sampling, need_normal, interval_kernel=interval_kernel,
                     reduce=reduce, tier_shim=tier_shim)


def _precompute_observations(trace, u, periods: tuple, need_normal: bool):
    """[T, P, n] observation grids for a shared CRN field, one per period.

    Row-by-row scan keeps the transform's intermediates small while
    producing the full grids every sweep lane shares.
    """
    def row(_, xs):
        u_t, tr_t = xs
        return None, jnp.stack([
            pebs_sample_from_uniform(u_t, tr_t, jnp.float32(p),
                                     need_normal=need_normal)
            for p in periods])
    return jax.lax.scan(row, None, (u, trace))[1]


@functools.partial(
    jax.jit, static_argnames=("k", "periods", "need_normal",
                              "interval_kernel", "reduce"),
    donate_argnums=(0, 4, 5, 6))
def _sim_pre_jit(spec, trace, oracle_mask, k, mach, caps, keys, u, periods,
                 need_normal, interval_kernel=True, reduce="stack"):
    obs = _precompute_observations(trace, u, periods, need_normal)
    return _simulate(spec, trace, oracle_mask, k, mach, caps, keys, obs,
                     "pre", need_normal, interval_kernel=interval_kernel,
                     reduce=reduce)


@functools.partial(
    jax.jit, static_argnames=("k", "sampling", "need_normal",
                              "wl_rep", "n", "wl_boost",
                              "interval_kernel", "reduce", "tier_shim"),
    donate_argnums=(0, 3, 4, 5, 7, 8))
def _sim_synth_jit(spec, wl, k, mach, caps, keys, sample, noise_key,
                   wl_keys, sampling, need_normal, wl_rep, n,
                   wl_boost=True, interval_kernel=True, reduce="stack",
                   tier_shim=False):
    # NB: ``wl`` (position 1) and ``sample`` (6) are NOT donated —
    # experiment.sweep shares one workload stack / CRN field across every
    # per-family dispatch of a single axis-product call.
    return _simulate(spec, None, None, k, mach, caps, keys, sample,
                     sampling, need_normal, wl=wl, wl_keys=wl_keys,
                     noise_key=noise_key, wl_rep=wl_rep, n=n,
                     wl_boost=wl_boost, interval_kernel=interval_kernel,
                     reduce=reduce, tier_shim=tier_shim)


def _synth_need_normal(wl_specs, min_period: float) -> bool:
    """Static host bound for synth mode: can any page's sampling rate reach
    the normal-approx regime?  Uses the specs' work bound (probs <= 1), so
    it may be conservatively True — the sampler's selected values are
    identical either way (see pebs_sample_from_uniform)."""
    return max(sp.max_rate() for sp in wl_specs) / float(min_period) \
        >= _NORMAL_SWITCH


def _to_result(out, lane: int, name: str) -> SimResult:
    lane_out = jax.tree_util.tree_map(lambda x: x[lane], out)
    res = SimResult(
        name=name,
        exec_time_s=float(lane_out["exec_time"]),
        promotions=int(lane_out["promotions"]),
        demotions=int(lane_out["demotions"]),
        wasteful=int(lane_out["wasteful"]),
        hot_recall=float(lane_out["hot_recall"]),
        fast_hit_frac=float(lane_out["fast_hit_frac"]))
    if "timeline_slow_bw" in lane_out:       # reduce="stack"
        ts = {k: np.asarray(v) for k, v in lane_out.items()
              if k.startswith("timeline_")}
        res.timeline_slow_bw = ts["timeline_slow_bw"].astype(np.float64)
        res.timeline_fast_hits = ts["timeline_fast_hits"].astype(np.float64)
        res.timeline_mode = ts["timeline_mode"].astype(np.int32)
        res.timeline_promotions = ts["timeline_promotions"].astype(np.int32)
    else:                                    # reduce="stream" summaries
        res.mean_slow_bw = float(lane_out["mean_slow_bw"])
        res.mean_fast_hits = float(lane_out["mean_fast_hits"])
        res.mean_mode = float(lane_out["mean_mode"])
        res.max_promotions_interval = int(
            lane_out["max_promotions_interval"])
    return res


def _timelines_lane_major(out):
    """scan stacks timelines as [T, B]; give callers [B, T]."""
    for key in list(out):
        if key.startswith("timeline_"):
            out[key] = jnp.swapaxes(out[key], 0, 1)
    return out


def _record_dispatch(**info):
    global dispatch_count
    dispatch_count += 1
    if "T" in info and "lanes" in info:
        # lanes x intervals: the dispatch's compute spend in the unit the
        # search engine compares strategies on (SearchResult.lane_intervals).
        # ``lanes`` is always the LOGICAL lane count — mesh padding reports
        # its widened count separately (``padded_lanes``, fabric.py) so
        # search compute curves stay comparable across mesh sizes.
        info["lane_intervals"] = int(info["lanes"]) * int(info["T"])
    last_dispatch.clear()
    last_dispatch.update(info)
    for ctr in _active_counters:
        ctr.count += 1
        ctr.records.append(dict(info))


# ------------------------------------------------------------- public API
def simulate(spec, trace, machine, k: int, seed: int = 0, sample_u=None,
             name: str | None = None,
             use_interval_kernel: bool = True,
             tier_shim: bool = False) -> SimResult:
    """Device-resident replay of ``trace`` under any policy spec.

    ``machine``: registry name / MachineSpec / TieredMachineSpec.
    ``sample_u``: optional [T, n] uniform field selecting the CRN sampling
    path (pass the same field to ``engine.run(..., sample_u=...)`` for an
    exactly-comparable reference run).  Default: PEBS noise drawn with
    ``jax.random`` from a key threaded through the scan carry.
    ``use_interval_kernel=False`` pins the historical unfused interval
    path — the fused route is bitwise-equal, so this only matters for
    equivalence tests and the kernel benchmark.  ``tier_shim=True`` forces
    a binary spec through the tier-targeted executor via the protocol's
    shim — also bitwise-equal (tests/test_tier_native.py).
    """
    trace = np.asarray(trace)
    assert 0 < k <= trace.shape[1]
    oracle = oracle_topk_masks(trace, k)
    crn = sample_u is not None
    sample = (jnp.asarray(sample_u, jnp.float32) if crn
              else jnp.zeros((trace.shape[0], 1), jnp.float32))
    keys = jax.random.PRNGKey(seed)[None]
    mach, caps = _mach_lanes(machine, 1, trace.shape[1], k)
    out = _sim_jit(_lane_specs(spec, 1), jnp.asarray(trace, jnp.float32),
                   jnp.asarray(oracle), k, mach, caps, keys, sample,
                   "crn" if crn else "prng",
                   _need_normal(trace, spec.min_sampling_period()),
                   interval_kernel=use_interval_kernel,
                   tier_shim=tier_shim)
    _record_dispatch(lanes=1, sampling="crn" if crn else "prng",
                     policy=spec.name, machines=1, T=trace.shape[0],
                     interval_kernel=use_interval_kernel, reduce="stack")
    return _to_result(_timelines_lane_major(out), 0, name or spec.name)


def sweep_seeds(trace, machine, k: int, seeds, cfg: ARMSConfig | None = None,
                spec=None) -> list[SimResult]:
    """Batched runs over PRNG seeds: one compile, one device dispatch.

    Every seed's full replay runs in lockstep in the lane axis — the
    sampling-noise study (and any seed-averaged comparison) no longer pays
    one sequential simulation per seed.  Defaults to ARMS (``cfg``); pass
    any ``spec`` for a baseline.
    """
    if spec is None:
        spec = ARMSSpec.make(base_cfg=cfg)
    elif cfg is not None:
        raise ValueError("pass either cfg (ARMS) or spec, not both")
    seeds = list(seeds)
    if not seeds:
        raise ValueError("sweep_seeds needs at least one seed")
    trace = np.asarray(trace)
    oracle = oracle_topk_masks(trace, k)
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    mach, caps = _mach_lanes(machine, len(seeds), trace.shape[1], k)
    out = _sim_jit(_lane_specs(spec, len(seeds)),
                   jnp.asarray(trace, jnp.float32), jnp.asarray(oracle), k,
                   mach, caps, keys,
                   jnp.zeros((trace.shape[0], 1), jnp.float32), "prng",
                   _need_normal(trace, spec.min_sampling_period()))
    _record_dispatch(lanes=len(seeds), sampling="prng", policy=spec.name,
                     machines=1, T=trace.shape[0], interval_kernel=True,
                     reduce="stack")
    out = _timelines_lane_major(out)
    return [_to_result(out, i, f"{spec.name}[seed={s}]")
            for i, s in enumerate(seeds)]


def sweep_policy_configs(spec_family, trace, machine, k: int, configs,
                         sim_seed: int = 0, sample_u=None
                         ) -> list[SimResult]:
    """Lane-batched sweep over one policy family's knob grid.

    ``spec_family`` is a callable mapping a config dict to a spec (e.g.
    ``HeMemSpec.make``); ``configs`` a list of config dicts, one lane each.
    All lanes share ONE common-random-number uniform noise field
    (``sample_u`` or ``sampling.uniform_field(T, n, seed=sim_seed)``), so
    config comparisons are paired — never confounded with sampling noise —
    and the whole sweep is one compiled ``scan``+``vmap`` program.  The
    numpy engine replaying any one config with the same field produces
    identical migrations (the tuning-equivalence tests assert this).
    """
    configs = list(configs)
    if not configs:
        raise ValueError("sweep_policy_configs needs at least one config")
    specs = [spec_family(**cfg) for cfg in configs]
    spec = _stack_specs(specs)
    trace = np.asarray(trace)
    T, n = trace.shape
    oracle = oracle_topk_masks(trace, k)
    if sample_u is None:
        sample_u = uniform_field(T, n, seed=sim_seed)
    assert sample_u.shape == (T, n)
    min_period = min(s.min_sampling_period() for s in specs)
    keys = jnp.stack([jax.random.PRNGKey(0)] * len(configs))
    mach, caps = _mach_lanes(machine, len(configs), n, k)
    out = _sim_jit(spec, jnp.asarray(trace, jnp.float32),
                   jnp.asarray(oracle), k, mach, caps, keys,
                   jnp.asarray(sample_u, jnp.float32), "crn",
                   _need_normal(trace, min_period))
    _record_dispatch(lanes=len(configs), sampling="crn",
                     policy=specs[0].name, machines=1, T=T,
                     interval_kernel=True, reduce="stack")
    out = _timelines_lane_major(out)
    labels = [",".join(f"{nm}={v:.6g}" for nm, v in sorted(cfg.items()))
              for cfg in configs]
    return [_to_result(out, i, f"{specs[0].name}[{lbl}]")
            for i, lbl in enumerate(labels)]


def arms_sim(trace, machine, k: int, cfg: ARMSConfig | None = None,
             seed: int = 0, sample_u=None, name: str = "arms") -> SimResult:
    """ARMS replay of ``trace`` — scan-engine counterpart of
    ``engine.run(ARMSPolicy(cfg), ...)``."""
    return simulate(ARMSSpec.make(base_cfg=cfg), trace, machine, k,
                    seed=seed, sample_u=sample_u, name=name)


def sweep_arms_configs(trace, machine, k: int, overrides: dict,
                       base_cfg: ARMSConfig | None = None, seed: int = 0,
                       sample_u=None, reduce: str = "stack"
                       ) -> list[SimResult]:
    """Batched ARMS runs over a grid of float knob settings.

    ``overrides`` maps ARMSConfig float field names to equal-length value
    lists; row b of every list forms config b.  All configs share one CRN
    uniform noise field, which lets the per-mode observation grids
    (``ARMSSpec.PRE_PERIODS``) be computed once and broadcast across
    lanes: config lanes pay zero sampling cost, and the whole sweep is one
    compiled ``scan``+``vmap`` program.  ``reduce="stream"`` drops the
    ``timeline_*`` stacks for O(lanes) output (scalars are identical) —
    the search engine's eliminate-and-redraw loops use it.
    """
    names = tuple(sorted(overrides))
    if not names:
        raise ValueError("overrides must name at least one ARMSConfig knob")
    B = len(overrides[names[0]])
    if B == 0 or any(len(overrides[nm]) != B for nm in names):
        raise ValueError(
            "override value lists must be non-empty and of equal length; "
            f"got {({nm: len(overrides[nm]) for nm in names})}")
    specs = [ARMSSpec.make({nm: overrides[nm][b] for nm in names},
                           base_cfg=base_cfg) for b in range(B)]
    spec = _stack_specs(specs)
    trace = np.asarray(trace)
    T, n = trace.shape
    oracle = oracle_topk_masks(trace, k)
    if sample_u is None:
        sample_u = uniform_field(T, n, seed=seed)
    need_normal = _need_normal(trace, specs[0].min_sampling_period())
    keys = jnp.stack([jax.random.PRNGKey(0)] * B)
    mach, caps = _mach_lanes(machine, B, n, k)
    out = _sim_pre_jit(spec, jnp.asarray(trace, jnp.float32),
                       jnp.asarray(oracle), k, mach, caps, keys,
                       jnp.asarray(sample_u, jnp.float32),
                       ARMSSpec.PRE_PERIODS, need_normal, reduce=reduce)
    _record_dispatch(lanes=B, sampling="pre", policy="arms", machines=1,
                     T=T, interval_kernel=True, reduce=reduce)
    out = _timelines_lane_major(out)
    labels = [",".join(f"{nm}={float(overrides[nm][b]):.4g}" for nm in names)
              for b in range(B)]
    return [_to_result(out, i, f"arms[{lbl}]")
            for i, lbl in enumerate(labels)]


# --------------------------------------------- trace synthesis (workloads)
def simulate_workload(spec, workload, machine, k: int, T: int, n: int,
                      sim_seed: int = 0, wl_seed: int = 0, sample_u=None,
                      name: str | None = None,
                      use_interval_kernel: bool = True) -> SimResult:
    """Device-synthesized replay of a ``WorkloadSpec`` under any policy.

    The scan engine synthesizes ``true = work * probs`` per interval from
    the spec's pure ``step`` and computes the oracle mask on device — no
    [T, n] trace is materialized anywhere (per-lane storage O(n)).  Under
    the same seeds the run is bitwise-identical to replaying
    ``workload.materialize(T, n, wl_seed)`` with the
    ``sampling.synth_noise_field(T, n, sim_seed)`` CRN field (or with
    ``sample_u`` if given).
    """
    assert 0 < k <= n
    crn = sample_u is not None
    if crn:
        sample = jnp.asarray(sample_u, jnp.float32)
        assert sample.shape == (T, n)
    else:
        sample = jnp.zeros((T, 1), jnp.float32)
    wl = _stack_workloads([workload])
    mach, caps = _mach_lanes(machine, 1, n, k)
    out = _sim_synth_jit(
        _lane_specs(spec, 1), wl, k, mach, caps,
        jax.random.PRNGKey(0)[None], sample, jax.random.PRNGKey(sim_seed),
        jax.random.PRNGKey(wl_seed)[None], "crn" if crn else "crn_prng",
        _synth_need_normal([workload], spec.min_sampling_period()), 1, n,
        wl_boost=workload.has_boost(),
        interval_kernel=use_interval_kernel)
    _record_dispatch(lanes=1, sampling="crn" if crn else "crn_prng",
                     policy=spec.name, synth=True, workloads=1, configs=1,
                     machines=1, T=T, interval_kernel=use_interval_kernel,
                     reduce="stack")
    label = name or f"{spec.name}@{workload_spec.label_of(workload)}"
    return _to_result(_timelines_lane_major(out), 0, label)


def sweep_workloads(workloads, machine, k: int, T: int, n: int,
                    cfg: ARMSConfig | None = None, spec=None,
                    sim_seed: int = 0, wl_seed: int = 0,
                    names=None) -> list[SimResult]:
    """One policy across W workload lanes: ONE compiled dispatch.

    ``workloads`` is a list of ``WorkloadSpec``s (combinator outputs
    welcome; component counts are padded to stack).  Every lane
    synthesizes its own trace on device and all lanes share the
    counter-based CRN noise rows, so workload comparisons are paired.
    Defaults to ARMS (``cfg``); pass any policy ``spec`` for a baseline.
    """
    if spec is None:
        spec = ARMSSpec.make(base_cfg=cfg)
    elif cfg is not None:
        raise ValueError("pass either cfg (ARMS) or spec, not both")
    workloads = list(workloads)
    if not workloads:
        raise ValueError("sweep_workloads needs at least one workload")
    W = len(workloads)
    names = list(names) if names is not None else [
        workload_spec.label_of(w, f"wl{i}") for i, w in enumerate(workloads)]
    mach, caps = _mach_lanes(machine, W, n, k)
    out = _sim_synth_jit(
        _lane_specs(spec, W), _stack_workloads(workloads), k, mach, caps,
        jnp.stack([jax.random.PRNGKey(0)] * W),
        jnp.zeros((T, 1), jnp.float32), jax.random.PRNGKey(sim_seed),
        jnp.stack([jax.random.PRNGKey(wl_seed)] * W), "crn_prng",
        _synth_need_normal(workloads, spec.min_sampling_period()), 1, n,
        wl_boost=any(w.has_boost() for w in workloads))
    _record_dispatch(lanes=W, sampling="crn_prng", policy=spec.name,
                     synth=True, workloads=W, configs=1, machines=1, T=T,
                     interval_kernel=True, reduce="stack")
    out = _timelines_lane_major(out)
    return [_to_result(out, i, f"{spec.name}@{nm}")
            for i, nm in enumerate(names)]


def sweep_workload_configs(spec_family, configs, workloads, machine, k: int,
                           T: int, n: int, sim_seed: int = 0,
                           wl_seed: int = 0, sample_u=None, names=None
                           ) -> list[list[SimResult]]:
    """W workloads x B configs as ONE compiled dispatch of W*B lanes.

    Lane ``w * B + b`` scores config ``b`` on workload ``w``; each
    workload's state is synthesized once per interval and feeds its B
    config lanes.  All lanes share the CRN noise rows (device
    counter-based by default; pass ``sample_u`` for an explicit field),
    so config comparisons stay paired within and across workloads.
    Returns results grouped per workload: ``out[w][b]``.
    """
    configs = list(configs)
    workloads = list(workloads)
    if not configs or not workloads:
        raise ValueError("sweep_workload_configs needs >=1 config and "
                         ">=1 workload")
    W, B = len(workloads), len(configs)
    names = list(names) if names is not None else [
        workload_spec.label_of(w, f"wl{i}") for i, w in enumerate(workloads)]
    pol_specs = [spec_family(**cfg) for cfg in configs]
    lane_spec = _stack_specs([pol_specs[b]
                              for _ in range(W) for b in range(B)])
    crn = sample_u is not None
    if crn:
        sample = jnp.asarray(sample_u, jnp.float32)
        assert sample.shape == (T, n)
    else:
        sample = jnp.zeros((T, 1), jnp.float32)
    min_period = min(s.min_sampling_period() for s in pol_specs)
    mach, caps = _mach_lanes(machine, W * B, n, k)
    out = _sim_synth_jit(
        lane_spec, _stack_workloads(workloads), k, mach, caps,
        jnp.stack([jax.random.PRNGKey(0)] * (W * B)), sample,
        jax.random.PRNGKey(sim_seed),
        jnp.stack([jax.random.PRNGKey(wl_seed)] * W),
        "crn" if crn else "crn_prng",
        _synth_need_normal(workloads, min_period), B, n,
        wl_boost=any(w.has_boost() for w in workloads))
    _record_dispatch(lanes=W * B, sampling="crn" if crn else "crn_prng",
                     policy=pol_specs[0].name, synth=True, workloads=W,
                     configs=B, machines=1, T=T, interval_kernel=True,
                     reduce="stack")
    out = _timelines_lane_major(out)
    labels = [",".join(f"{nm}={v:.6g}" for nm, v in sorted(cfg.items()))
              for cfg in configs]
    return [[_to_result(out, w * B + b,
                        f"{pol_specs[b].name}@{names[w]}[{labels[b]}]")
             for b in range(B)] for w in range(W)]
