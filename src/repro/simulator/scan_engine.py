"""Compiled ``lax.scan`` simulation engine + vmapped tuning sweeps.

The numpy engine (engine.py) replays a trace with a Python loop and one
policy call per interval — fine as a *reference*, but host<->device
round-trips and per-call dispatch dominate for the JAX-native ARMS policy,
and tuning studies replay dozens of full simulations sequentially.  Here
the entire replay — PEBS sampling, the ARMS controller, engine-side
capacity/validity enforcement, the interval cost model, and
wasteful/recall accounting — is one ``jax.lax.scan`` over intervals,
compiled once and executed with zero per-interval host syncs.  On top of
it:

  * ``arms_sim``            — single run, SimResult-compatible output;
  * ``sweep_seeds``         — batched over PRNG keys (sampling-noise
    study: per-lane noise drawn from keys threaded through the carry);
  * ``sweep_arms_configs``  — batched over ARMS float knobs (the
    "From Good to Great"-style parameter sweep).  All configs share one
    CRN noise field, so the two observation grids (history / recency
    sampling period) are precomputed ONCE and broadcast — config lanes
    pay zero sampling cost.

Batching layout: sweep lanes live in an explicit leading axis of the scan
carry rather than under an outer ``vmap`` of the whole simulation.  This
matters: policy-cadence gating is a ``lax.cond`` on the *scalar*
``any(lane fires)``, so on intervals where no lane's policy is due the
controller (top-k ranking dominates the profile) is genuinely skipped —
an outer vmap would turn that cond into a select and pay the controller
every interval.  The controller itself is ``jax.vmap``-ed over lanes
inside the fire branch, with per-lane config knobs rebuilt from the swept
value vectors.

Engine-side bookkeeping is shared with the numpy engine via
``simulator/simjax.py``; with a common-random-number uniform field
(``sample_u``) the two engines agree bitwise on sampling and interval
arithmetic, so promotions/demotions/wasteful counts match exactly (see
tests/test_scan_engine.py).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import (SAMPLING_PERIOD_HISTORY,
                                   SAMPLING_PERIOD_RECENCY, arms_step_impl,
                                   policy_every, sampling_period)
from repro.core.scheduler import observe_migration_cost
from repro.core.state import MODE_RECENCY, ARMSConfig, MigrationPlan, \
    init_state
from repro.simulator import machine as machine_mod
from repro.simulator import simjax
from repro.simulator.engine import SimResult, oracle_topk_masks
from repro.simulator.sampling import (_NORMAL_SWITCH,
                                      pebs_sample_from_uniform)

# ARMSConfig float knobs that may be batched (traced) in a config sweep.
# Shape-determining ints (bs_max) and the kernel flag must stay static.
SWEEPABLE = frozenset({
    "alpha_s", "alpha_l", "w_s_history", "w_l_history", "w_s_recency",
    "w_l_recency", "pht_delta", "pht_lambda", "stabilize_eps", "noise_z",
    "latency_fast_us", "latency_slow_us", "access_scale",
    "migrate_cost_alpha", "init_promo_cost_us", "init_demo_cost_us",
})


def _empty_plan(B: int, bs_max: int) -> MigrationPlan:
    i32 = jnp.int32
    return MigrationPlan(
        promote=jnp.full((B, bs_max), -1, i32),
        demote=jnp.full((B, bs_max), -1, i32),
        valid=jnp.zeros((B, bs_max), bool),
        count=jnp.zeros((B,), i32),
        batch_size=jnp.zeros((B,), i32))


def _init_carry(B: int, n: int, keys):
    f32 = jnp.float32
    return dict(
        in_fast=jnp.zeros((B, n), bool),
        buf=jnp.zeros((B, n), f32),
        promoted_at=jnp.full((B, n), -(10 ** 9), jnp.int32),
        demoted_at=jnp.full((B, n), -(10 ** 9), jnp.int32),
        t=jnp.zeros((), jnp.int32),
        key=keys,
        slow_bw=jnp.ones((B,), f32),      # everything starts slow
        app_bw=jnp.zeros((B,), f32),
        exec_time=jnp.zeros((B,), f32),
        promotions=jnp.zeros((B,), jnp.int32),
        demotions=jnp.zeros((B,), jnp.int32),
        wasteful=jnp.zeros((B,), jnp.int32),
        acc_fast_total=jnp.zeros((B,), f32),
        acc_total=jnp.zeros((B,), f32),
        recall_sum=jnp.zeros((B,), f32),
    )


def _need_normal(trace) -> bool:
    """Static: can any page's sampling rate reach the normal-approx regime?

    When False the ndtri branch of the sampler is dead code and statically
    dropped; selected values are identical either way, so this never
    affects cross-engine equivalence.
    """
    return bool(np.max(trace) / SAMPLING_PERIOD_RECENCY >= _NORMAL_SWITCH)


def _bwhere(pred, a, b):
    """Per-lane select: pred [B], leaves [B] or [B, ...]."""
    return jax.tree_util.tree_map(
        lambda x, y: jnp.where(pred.reshape((-1,) + (1,) * (x.ndim - 1)),
                               x, y), a, b)


def _simulate(trace, oracle_mask, base_cfg: ARMSConfig, k: int,
              cfg_names: tuple, cfg_vals, mp, promo_us, demo_us, keys,
              sample, sampling: str, need_normal: bool):
    """Traceable batched replay; returns a dict of [B] scalars + timelines.

    Lanes (= sweep entries) form the leading axis of every carried array.
    ``cfg_names``/``cfg_vals`` (static names, [B, F] values) rebuild a
    per-lane ARMSConfig inside the vmapped controller; empty names = all
    lanes share ``base_cfg``.  ``sampling`` (static) selects the PEBS noise
    source:
      * "prng": per-lane keys threaded through the carry; per-interval
        uniforms transformed by the shared Poisson inverse-CDF;
      * "crn":  ``sample`` is a [T, n] uniform field, transformed per
        interval — the path the numpy engine mirrors bitwise;
      * "pre":  ``sample`` is a precomputed (obs_history, obs_recency)
        pair of [T, n] observation grids; lanes only select by mode.
    """
    T, n = trace.shape
    B = keys.shape[0]
    bs_max = min(base_cfg.bs_max, n)
    f32 = jnp.float32

    def lane_cfg(vec):
        if not cfg_names:
            return base_cfg
        return dataclasses.replace(
            base_cfg, **{nm: vec[i] for i, nm in enumerate(cfg_names)})

    def controller(state, counts, slow_bw, app_bw, vec):
        cfg = lane_cfg(vec)
        state, plan = arms_step_impl(state, counts, slow_bw, app_bw,
                                     cfg=cfg, k=k)
        state = jax.lax.cond(
            plan.count > 0,
            lambda s: observe_migration_cost(s, promo_us, demo_us, cfg),
            lambda s: s, state)
        return state, plan

    def observed_for(xs_sample, true, mode, subs):
        period = sampling_period(mode).astype(f32)[:, None]     # [B, 1]
        if sampling == "prng":
            u = jax.vmap(lambda s: jax.random.uniform(s, (n,), dtype=f32)
                         )(subs)
            return pebs_sample_from_uniform(u, true[None], period,
                                            need_normal=need_normal)
        if sampling == "crn":
            return pebs_sample_from_uniform(xs_sample[None], true[None],
                                            period, need_normal=need_normal)
        obs_h, obs_r = xs_sample
        return jnp.where(mode[:, None] == MODE_RECENCY, obs_r[None],
                         obs_h[None])

    def step(c, xs):
        true, orc, xs_sample = xs
        state = c["state"]
        mode = state.mode                                       # [B]
        split = jax.vmap(jax.random.split, out_axes=1)(c["key"])
        key, subs = split[0], split[1]
        observed = observed_for(xs_sample, true, mode, subs)    # [B, n]
        t = c["t"] + 1                       # 1-based policy tick (shared)
        every = policy_every(mode)                              # [B]
        buf = c["buf"] + observed
        do = (t % every) == 0                                   # [B]

        def fire(args):
            state, buf = args
            counts = buf / every.astype(f32)[:, None]
            new_state, plan = jax.vmap(controller)(
                state, counts, c["slow_bw"], c["app_bw"], cfg_vals)
            # lanes whose policy is not due keep their state/buffer; their
            # plan entries are invalidated so no migrations execute.
            state = _bwhere(do, new_state, state)
            buf = jnp.where(do[:, None], 0.0, buf)
            plan = MigrationPlan(
                promote=jnp.where(do[:, None], plan.promote, -1),
                demote=jnp.where(do[:, None], plan.demote, -1),
                valid=plan.valid & do[:, None],
                count=jnp.where(do, plan.count, 0),
                batch_size=jnp.where(do, plan.batch_size, 0))
            return state, buf, plan

        def skip(args):
            state, buf = args
            return state, buf, _empty_plan(B, bs_max)

        # Scalar predicate: the controller (top-k ranking dominates its
        # cost) only runs on intervals where at least one lane's policy
        # cadence is due — unlike an outer vmap-of-cond, which would
        # select-execute it every interval.
        state, buf, plan = jax.lax.cond(jnp.any(do), fire, skip,
                                        (state, buf))

        in_fast, pexec, dexec = jax.vmap(
            simjax.apply_migrations, in_axes=(0, 0, 0, 0, None))(
            c["in_fast"], plan.promote, plan.demote, plan.valid, k)
        n_promo = pexec.sum(axis=1).astype(jnp.int32)           # [B]
        n_demo = dexec.sum(axis=1).astype(jnp.int32)
        waste, promoted_at, demoted_at = jax.vmap(
            simjax.wasteful_update, in_axes=(None, 0, 0, 0, 0, 0, 0))(
            t - 1, c["promoted_at"], c["demoted_at"], plan.promote,
            plan.demote, pexec, dexec)
        acc_fast, acc_slow, wall, slow_share, app_frac = jax.vmap(
            simjax.interval_accounting, in_axes=(None, None, 0, 0, 0))(
            mp, true, in_fast, n_promo.astype(f32), n_demo.astype(f32))
        recall = (in_fast & orc[None]).sum(axis=1).astype(f32) / k

        new_c = dict(
            state=state, in_fast=in_fast, buf=buf,
            promoted_at=promoted_at, demoted_at=demoted_at, t=t, key=key,
            slow_bw=slow_share, app_bw=app_frac,
            exec_time=c["exec_time"] + wall,
            promotions=c["promotions"] + n_promo,
            demotions=c["demotions"] + n_demo,
            wasteful=c["wasteful"] + waste,
            acc_fast_total=c["acc_fast_total"] + acc_fast,
            acc_total=c["acc_total"] + acc_fast + acc_slow,
            recall_sum=c["recall_sum"] + recall)
        ys = dict(slow=slow_share,
                  hits=acc_fast / jnp.maximum(acc_fast + acc_slow, 1e-9),
                  mode=state.mode, promos=n_promo)
        return new_c, ys

    trace = jnp.asarray(trace, f32)
    if sampling == "prng":
        xs_sample = jnp.zeros((T, 1), f32)   # placeholder xs leaf
    elif sampling == "crn":
        xs_sample = jnp.asarray(sample, f32)
    else:
        xs_sample = sample                   # (obs_h, obs_r) [T, n] pair
    carry = _init_carry(B, n, keys)
    carry["state"] = jax.vmap(lambda v: init_state(n, lane_cfg(v)))(cfg_vals)
    xs = (trace, jnp.asarray(oracle_mask, bool), xs_sample)
    carry, ys = jax.lax.scan(step, carry, xs)
    return dict(
        exec_time=carry["exec_time"], promotions=carry["promotions"],
        demotions=carry["demotions"], wasteful=carry["wasteful"],
        hot_recall=carry["recall_sum"] / T,
        fast_hit_frac=carry["acc_fast_total"]
        / jnp.maximum(carry["acc_total"], 1e-9),
        timeline_slow_bw=ys["slow"], timeline_fast_hits=ys["hits"],
        timeline_mode=ys["mode"], timeline_promotions=ys["promos"])


@functools.partial(
    jax.jit,
    static_argnames=("base_cfg", "k", "cfg_names", "sampling", "need_normal"))
def _sim_jit(trace, oracle_mask, base_cfg, k, cfg_names, cfg_vals, mp,
             promo_us, demo_us, keys, sample, sampling, need_normal):
    return _simulate(trace, oracle_mask, base_cfg, k, cfg_names, cfg_vals,
                     mp, promo_us, demo_us, keys, sample, sampling,
                     need_normal)


def _machine_args(machine):
    return (simjax.machine_params(machine),
            jnp.float32(machine_mod.promo_page_us(machine)),
            jnp.float32(machine_mod.demo_page_us(machine)))


def _to_result(out, lane: int, name: str) -> SimResult:
    lane_out = jax.tree_util.tree_map(lambda x: x[lane], out)
    ts = {k: np.asarray(v) for k, v in lane_out.items()
          if k.startswith("timeline_")}
    return SimResult(
        name=name,
        exec_time_s=float(lane_out["exec_time"]),
        promotions=int(lane_out["promotions"]),
        demotions=int(lane_out["demotions"]),
        wasteful=int(lane_out["wasteful"]),
        hot_recall=float(lane_out["hot_recall"]),
        fast_hit_frac=float(lane_out["fast_hit_frac"]),
        timeline_slow_bw=ts["timeline_slow_bw"].astype(np.float64),
        timeline_fast_hits=ts["timeline_fast_hits"].astype(np.float64),
        timeline_mode=ts["timeline_mode"].astype(np.int32),
        timeline_promotions=ts["timeline_promotions"].astype(np.int32))


def _timelines_lane_major(out):
    """scan stacks timelines as [T, B]; give callers [B, T]."""
    for key in list(out):
        if key.startswith("timeline_"):
            out[key] = jnp.swapaxes(out[key], 0, 1)
    return out


def arms_sim(trace, machine, k: int, cfg: ARMSConfig | None = None,
             seed: int = 0, sample_u=None, name: str = "arms") -> SimResult:
    """Device-resident ARMS replay of ``trace`` — scan-engine ``run()``.

    ``sample_u``: optional [T, n] uniform field selecting the CRN sampling
    path (pass the same field to ``engine.run(..., sample_u=...)`` for an
    exactly-comparable reference run).  Default: PEBS noise drawn with
    ``jax.random`` from a key threaded through the scan carry.
    """
    cfg = cfg or ARMSConfig()
    trace = np.asarray(trace)
    assert 0 < k <= trace.shape[1]
    oracle = oracle_topk_masks(trace, k)
    mp, promo_us, demo_us = _machine_args(machine)
    crn = sample_u is not None
    sample = (jnp.asarray(sample_u, jnp.float32) if crn
              else jnp.zeros((trace.shape[0], 1), jnp.float32))
    keys = jax.random.PRNGKey(seed)[None]
    out = _sim_jit(jnp.asarray(trace, jnp.float32), jnp.asarray(oracle),
                   cfg, k, (), jnp.zeros((1, 0), jnp.float32), mp, promo_us,
                   demo_us, keys, sample, "crn" if crn else "prng",
                   _need_normal(trace))
    return _to_result(_timelines_lane_major(out), 0, name)


def sweep_seeds(trace, machine, k: int, seeds, cfg: ARMSConfig | None = None
                ) -> list[SimResult]:
    """Batched ARMS runs over PRNG seeds: one compile, one device dispatch.

    Every seed's full replay runs in lockstep in the lane axis — the
    sampling-noise study (and any seed-averaged comparison) no longer pays
    one sequential simulation per seed.
    """
    cfg = cfg or ARMSConfig()
    seeds = list(seeds)
    if not seeds:
        raise ValueError("sweep_seeds needs at least one seed")
    trace = np.asarray(trace)
    oracle = oracle_topk_masks(trace, k)
    mp, promo_us, demo_us = _machine_args(machine)
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    B = len(seeds)
    out = _sim_jit(jnp.asarray(trace, jnp.float32), jnp.asarray(oracle),
                   cfg, k, (), jnp.zeros((B, 0), jnp.float32), mp, promo_us,
                   demo_us, keys, jnp.zeros((trace.shape[0], 1), jnp.float32),
                   "prng", _need_normal(trace))
    out = _timelines_lane_major(out)
    return [_to_result(out, i, f"arms[seed={s}]")
            for i, s in enumerate(seeds)]


def _precompute_observations(trace, u, need_normal: bool):
    """Both mode-dependent observation grids for a shared CRN field.

    Row-by-row scan keeps the transform's intermediates small while
    producing the full [T, n] grids every config lane shares.
    """
    def row(_, xs):
        u_t, tr_t = xs
        obs_h = pebs_sample_from_uniform(
            u_t, tr_t, jnp.float32(SAMPLING_PERIOD_HISTORY),
            need_normal=need_normal)
        obs_r = pebs_sample_from_uniform(
            u_t, tr_t, jnp.float32(SAMPLING_PERIOD_RECENCY),
            need_normal=need_normal)
        return None, (obs_h, obs_r)
    return jax.lax.scan(row, None, (u, trace))[1]


@functools.partial(
    jax.jit,
    static_argnames=("base_cfg", "k", "cfg_names", "need_normal"))
def _sweep_cfg_jit(trace, oracle_mask, base_cfg, k, cfg_names, cfg_vals, mp,
                   promo_us, demo_us, keys, u, need_normal):
    obs = _precompute_observations(trace, u, need_normal)
    return _simulate(trace, oracle_mask, base_cfg, k, cfg_names, cfg_vals,
                     mp, promo_us, demo_us, keys, obs, "pre", need_normal)


def sweep_arms_configs(trace, machine, k: int, overrides: dict,
                       base_cfg: ARMSConfig | None = None, seed: int = 0
                       ) -> list[SimResult]:
    """Batched ARMS runs over a grid of float knob settings.

    ``overrides`` maps ARMSConfig float field names to equal-length value
    lists; row b of every list forms config b.  All configs share one CRN
    uniform noise field (paired comparisons — config differences are never
    confounded with sampling noise), which lets the per-mode observation
    grids be computed once and broadcast across lanes: config lanes pay
    zero sampling cost, and the whole sweep is one compiled
    ``scan``+``vmap`` program.
    """
    base_cfg = base_cfg or ARMSConfig()
    bad = set(overrides) - SWEEPABLE
    if bad:
        raise ValueError(
            f"non-sweepable ARMSConfig fields {sorted(bad)}; sweepable: "
            f"{sorted(SWEEPABLE)}")
    names = tuple(sorted(overrides))
    if not names:
        raise ValueError("overrides must name at least one ARMSConfig knob")
    B = len(overrides[names[0]])
    if B == 0 or any(len(overrides[nm]) != B for nm in names):
        raise ValueError(
            "override value lists must be non-empty and of equal length; "
            f"got {({nm: len(overrides[nm]) for nm in names})}")
    vals = np.asarray([[float(overrides[nm][b]) for nm in names]
                       for b in range(B)], np.float32)
    trace = np.asarray(trace)
    T, n = trace.shape
    oracle = oracle_topk_masks(trace, k)
    mp, promo_us, demo_us = _machine_args(machine)
    u = jax.random.uniform(jax.random.PRNGKey(seed), (T, n),
                           dtype=jnp.float32)
    keys = jnp.stack([jax.random.PRNGKey(0)] * B)
    out = _sweep_cfg_jit(jnp.asarray(trace, jnp.float32),
                         jnp.asarray(oracle), base_cfg, k, names,
                         jnp.asarray(vals), mp, promo_us, demo_us, keys, u,
                         _need_normal(trace))
    out = _timelines_lane_major(out)
    labels = [",".join(f"{nm}={v:.4g}" for nm, v in zip(names, row))
              for row in vals]
    return [_to_result(out, i, f"arms[{lbl}]")
            for i, lbl in enumerate(labels)]
