"""Mesh sweep fabric: shard the lane axis over devices and fuse a
mixed-family policy panel into ONE compiled program.

The scan engine (scan_engine.py) batches sweep lanes in the leading axis
of every carried array, and ``experiment.sweep`` flattens the P×W×M×S
axis product into those lanes — but with two ceilings this module
removes:

* **Lane sharding** (``sim_trace`` / ``sim_synth``): the per-lane
  ``[B, n]`` state bounds sweep width by one device's memory.  The
  fabric pads the flat lane axis to a multiple of the mesh size
  (replicating lane 0 — padded lanes are DROPPED from results before
  labeling), then runs the unchanged ``scan_engine._simulate`` under
  ``shard_map`` over a 1-D ``jax.sharding.Mesh``: spec / machine / caps
  / PRNG-key lanes are sharded with ``PartitionSpec("lanes")``, the
  trace / CRN field / workload stack are replicated, and carries are
  donated.  Results are bitwise-identical to the unsharded path at any
  mesh size (including a forced mesh of 1) because nothing a lane
  computes ever depends on which shard it landed on:

    - per-lane PRNG keys are data, derived HOST-side from the global
      lane id (seed), and the in-scan ``split`` is a per-lane vmap;
    - the any-lane fire / workload-event ``lax.cond`` gates become
      per-SHARD conds, but both branches are bitwise no-ops for lanes
      that don't fire (the engine's load-bearing skip invariant), so a
      shard skipping an interval another shard fires on changes nothing;
    - synth lanes gather their workload row by GLOBAL workload index
      (``widx``) from the replicated [W] synthesis — value-wise exactly
      the unsharded ``repeat``.

  Streaming aggregation (``reduce="stream"``) already makes outputs
  O(lanes); the fabric's only cross-device traffic is the final
  per-lane result gather.

* **Union dispatch** (``build_union`` / ``UnionSpec``): policies of
  different families have different state pytrees, so the sweep
  historically issued one compiled dispatch per family.  ``UnionSpec``
  is a single PolicySpec whose state is a tuple of neutral-padded SLOT
  arrays — the leaf union over the member families, bucketed by
  (shape, dtype) with per-bucket multiplicity the max over members (so
  union state memory is the max family's, not the sum) — and whose
  per-lane ``fam`` index selects the active member via ``lax.switch``.
  Every lane runs the tier-targeted route; binary members go through
  the protocol's base shim, which PR 8 proved bitwise-equal to the
  hop-chain path under CRN.  Mixed observation kinds (oracle lanes see
  true counts; TPP lanes carry a per-slow-access overhead) ride
  per-lane leaves consulted by the engine's ``mixed_observation``
  hooks.  A full mixed-family robustness board therefore compiles to
  literally ONE program, bitwise-equal to the per-family grouped path.

``experiment.sweep(dispatch=..., mesh=...)`` is the public face; the
entry points here share the scan engine's underscore-helper contract
(change signatures in lockstep).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.baselines.protocol import SENTINEL, PolicySpec
from repro.simulator import scan_engine
from repro.utils.pytree import pytree_dataclass, static_dataclass

__all__ = ["UnionSpec", "UnionMember", "build_union", "resolve_mesh",
           "sim_trace", "sim_synth"]

#: the 1-D mesh axis every fabric dispatch shards lanes over
LANE_AXIS = "lanes"


# ------------------------------------------------------------ union spec
@static_dataclass
class UnionMember:
    """Static identity of one member family inside a ``UnionSpec``.

    Keyed by the member's spec TREEDEF (class + meta), not just its
    class: two HeMemSpecs with different ``migration_limit`` meta have
    different pad widths / behaviour and get separate branches.
    """

    name: str
    spec_treedef: object      #: treedef of the member spec pytree
    state_treedef: object     #: treedef of the member state pytree
    slot_ids: tuple           #: state leaf i lives in union slot slot_ids[i]
    pad_mv: int               #: the member's own pad_moves(n, k)


@pytree_dataclass(meta=("members", "slot_defs", "pad_mv", "min_period"))
class UnionSpec(PolicySpec):
    """One spec whose lanes may each be a DIFFERENT policy family.

    Data leaves (lane-batched under the engine's vmap):
      * ``fam``        — i32 member index selecting the active branch;
      * ``knobs[f]``   — member f's spec LEAVES (inactive lanes carry the
        member's panel-representative values; their branch output is
        discarded by the switch);
      * ``wants_true`` — bool, this lane observes true counts (oracle);
      * ``slow_extra`` — f32 ns per slow access (TPP; 0.0 elsewhere is a
        bitwise no-op in the engine's wall term).

    State is a tuple of slot arrays (``slot_defs``); member states pack
    into / unpack out of their ``slot_ids``, untouched slots pass
    through.  All behaviour methods are a ``lax.switch`` over members —
    under the engine's lane vmap that is ONE program executing every
    branch and selecting per lane.
    """

    fam: jnp.ndarray
    knobs: tuple
    wants_true: jnp.ndarray
    slow_extra: jnp.ndarray
    members: tuple = ()
    slot_defs: tuple = ()     #: ((shape, dtype-name), ...) per union slot
    pad_mv: int = 1
    min_period: float = PolicySpec.DEFAULT_SAMPLE_PERIOD

    name = "union"
    tier_native = True        # every lane takes the tier-targeted route
    mixed_observation = True  # per-lane wants_true / slow_extra hooks

    # --- member plumbing -------------------------------------------------
    def _member_spec(self, f: int):
        m = self.members[f]
        return jax.tree_util.tree_unflatten(m.spec_treedef,
                                            list(self.knobs[f]))

    def _unpack(self, f: int, slots):
        m = self.members[f]
        return jax.tree_util.tree_unflatten(
            m.state_treedef, [slots[i] for i in m.slot_ids])

    def _pack(self, f: int, slots, state):
        out = list(slots)
        for i, leaf in zip(self.members[f].slot_ids,
                           jax.tree_util.tree_leaves(state)):
            # same-dtype cast: a no-op on values that normalizes weak
            # types so every switch branch returns identical avals.
            out[i] = jnp.asarray(leaf).astype(self.slot_defs[i][1])
        return tuple(out)

    def _switch(self, make_branch, *operands):
        branches = [make_branch(f) for f in range(len(self.members))]
        return jax.lax.switch(self.fam, branches, *operands)

    # --- shape contract --------------------------------------------------
    def pad_promote(self, n: int, k: int) -> int:
        return self.pad_mv

    pad_demote = pad_promote

    def pad_moves(self, n: int, k: int) -> int:
        return self.pad_mv

    def min_sampling_period(self) -> float:
        return float(self.min_period)

    # --- per-lane hooks (scan_engine ``mixed_observation`` route) --------
    def wants_true_lane(self):
        return self.wants_true

    def slow_extra_lane(self):
        return self.slow_extra

    # --- behaviour: lax.switch over members ------------------------------
    def init(self, n_pages, k, machine):
        zeros = tuple(jnp.zeros(shape, dtype)
                      for shape, dtype in self.slot_defs)

        def branch(f):
            return lambda mach: self._pack(
                f, zeros, self._member_spec(f).init(n_pages, k, mach))

        return self._switch(branch, machine)

    def observe(self, state, observed):
        def branch(f):
            return lambda st, obs: self._pack(
                f, st, self._member_spec(f).observe(self._unpack(f, st),
                                                    obs))

        return self._switch(branch, state, observed)

    def fires(self, state):
        def branch(f):
            return lambda st: jnp.asarray(
                self._member_spec(f).fires(self._unpack(f, st)))

        return self._switch(branch, state)

    def sampling_period(self, state):
        def branch(f):
            return lambda st: jnp.asarray(
                self._member_spec(f).sampling_period(self._unpack(f, st)),
                jnp.float32)

        return self._switch(branch, state)

    def mode_of(self, state):
        def branch(f):
            return lambda st: jnp.asarray(
                self._member_spec(f).mode_of(self._unpack(f, st)),
                jnp.int32)

        return self._switch(branch, state)

    def tier_policy(self, state, tier_util, slow_bw, app_bw, k: int, caps):
        def branch(f):
            def run(st, tu, sb, ab, cp):
                sp = self._member_spec(f)
                st2, pages, dst = sp.tier_policy(
                    self._unpack(f, st), tu, sb, ab, k, cp)
                # widen to the union's pad_mv by APPENDING sentinels —
                # trailing skipped entries after the member's own moves,
                # a bitwise no-op in apply_targeted_migrations.
                pad = self.pad_mv - pages.shape[0]
                pages = jnp.concatenate(
                    [pages.astype(jnp.int32),
                     jnp.full((pad,), SENTINEL, jnp.int32)])
                dst = jnp.concatenate(
                    [dst.astype(jnp.int32), jnp.zeros((pad,), jnp.int32)])
                return self._pack(f, st, st2), pages, dst

            return run

        return self._switch(branch, state, tier_util, slow_bw, app_bw,
                            caps)


def build_union(pol_specs, n: int, k: int, mach_all):
    """Union-ize a mixed-family policy panel.

    ``pol_specs`` are the panel's (unstacked) PolicySpecs; ``mach_all``
    a lane-stacked machine pytree ([M, ...] leaves) whose single-lane
    shape templates the state layouts (all lanes share one padded tier
    depth, machine_spec.lane_stack).  Returns one ``UnionSpec`` per
    policy (stackable: identical meta), ready for
    ``scan_engine._stack_specs`` + ``_take_lanes``.

    Slot layout: member state leaves are bucketed by (shape, dtype);
    the union carries max-over-members slots per bucket, so the union
    state is as big as the LARGEST member's, not the sum.  Layouts are
    computed by ``jax.eval_shape`` of each member's ``init`` — no
    device computation happens here.
    """
    mach1 = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), mach_all)
    # member identity = spec treedef (class + meta): specs that cannot
    # stack leaf-wise get their own branch.
    fam_of, reps, keys = [], [], {}
    for sp in pol_specs:
        key = jax.tree_util.tree_structure(sp)
        if key not in keys:
            keys[key] = len(reps)
            reps.append(sp)
        fam_of.append(keys[key])

    slot_req: dict = {}
    fam_layouts = []
    for rep in reps:
        st = jax.eval_shape(lambda m, sp=rep: sp.init(n, k, m), mach1)
        leaves, state_treedef = jax.tree_util.tree_flatten(st)
        buckets: dict = {}
        fam_slots = []
        for leaf in leaves:
            bk = (tuple(leaf.shape), jnp.dtype(leaf.dtype).name)
            i = buckets.get(bk, 0)
            buckets[bk] = i + 1
            fam_slots.append((bk, i))
        for bk, cnt in buckets.items():
            slot_req[bk] = max(slot_req.get(bk, 0), cnt)
        fam_layouts.append((state_treedef, fam_slots))

    # deterministic global slot order: sort buckets by (dtype, shape)
    slot_defs, base = [], {}
    for bk in sorted(slot_req, key=lambda b: (b[1], b[0])):
        base[bk] = len(slot_defs)
        slot_defs.extend([bk] * slot_req[bk])
    slot_defs = tuple(slot_defs)

    members = tuple(
        UnionMember(
            name=rep.name,
            spec_treedef=jax.tree_util.tree_structure(rep),
            state_treedef=treedef,
            slot_ids=tuple(base[bk] + i for bk, i in fam_slots),
            pad_mv=int(rep.pad_moves(n, k)))
        for rep, (treedef, fam_slots) in zip(reps, fam_layouts))
    pad_mv = max(m.pad_mv for m in members)
    min_period = min(sp.min_sampling_period() for sp in pol_specs)
    rep_knobs = tuple(
        tuple(jnp.asarray(lf) for lf in jax.tree_util.tree_leaves(rep))
        for rep in reps)

    out = []
    for sp, f in zip(pol_specs, fam_of):
        knobs = tuple(
            tuple(jnp.asarray(lf)
                  for lf in jax.tree_util.tree_leaves(sp))
            if g == f else rep_knobs[g]
            for g in range(len(reps)))
        out.append(UnionSpec(
            fam=jnp.asarray(f, jnp.int32), knobs=knobs,
            wants_true=jnp.asarray(type(sp).wants_true_counts),
            slow_extra=jnp.float32(type(sp).slow_access_extra_ns),
            members=members, slot_defs=slot_defs, pad_mv=int(pad_mv),
            min_period=float(min_period)))
    return out


# --------------------------------------------------------- lane sharding
def resolve_mesh(mesh) -> int | None:
    """``mesh`` param -> shard count D, or None for the plain path.

    ``None`` never shards; ``"auto"`` shards over every local device
    (plain path on a single-device host); an int forces that many
    devices (1 is allowed — the forced-shard_map equivalence tests).
    """
    if mesh is None:
        return None
    if mesh == "auto":
        d = jax.device_count()
        return d if d > 1 else None
    d = int(mesh)
    if not 1 <= d <= jax.device_count():
        raise ValueError(f"mesh={d} but only {jax.device_count()} "
                         "device(s) are available")
    return d


def _lane_mesh(D: int) -> Mesh:
    return Mesh(np.array(jax.devices()[:D]), (LANE_AXIS,))


def _pad_lanes(tree, B: int, Lp: int):
    """Widen lane-batched leaves [B, ...] -> [Lp, ...] replicating lane 0
    (cheap, and keeps every padded lane a valid simulation)."""
    idx = jnp.concatenate([jnp.arange(B, dtype=jnp.int32),
                           jnp.zeros((Lp - B,), jnp.int32)])
    return scan_engine._take_lanes(tree, idx)


def _unpad_out(out: dict, B: int) -> dict:
    """Drop padded lanes from a raw engine output dict ([B]-leading
    scalars; ``timeline_*`` are [T, B] until _timelines_lane_major)."""
    return {key: (v[:, :B] if key.startswith("timeline_") else v[:B])
            for key, v in out.items()}


def _out_specs(reduce: str) -> dict:
    names = ["exec_time", "promotions", "demotions", "wasteful",
             "hot_recall", "fast_hit_frac"]
    if reduce == "stream":
        return {nm: P(LANE_AXIS) for nm in names + [
            "mean_slow_bw", "mean_fast_hits", "mean_mode",
            "max_promotions_interval"]}
    specs = {nm: P(LANE_AXIS) for nm in names}
    specs.update({nm: P(None, LANE_AXIS) for nm in (
        "timeline_slow_bw", "timeline_fast_hits", "timeline_mode",
        "timeline_promotions")})
    return specs


@functools.partial(
    jax.jit, static_argnames=("k", "sampling", "need_normal",
                              "interval_kernel", "reduce", "tier_shim",
                              "mesh"),
    donate_argnums=(0, 4, 5, 6))
def _fab_trace_jit(spec, trace, oracle_mask, k, mach, caps, keys, sample,
                   sampling, need_normal, interval_kernel, reduce,
                   tier_shim, mesh):
    lane, rep = P(LANE_AXIS), P()
    f = shard_map(
        lambda sp, tr, om, mc, cp, ky, sm: scan_engine._simulate(
            sp, tr, om, k, mc, cp, ky, sm, sampling, need_normal,
            interval_kernel=interval_kernel, reduce=reduce,
            tier_shim=tier_shim),
        mesh=mesh,
        in_specs=(lane, rep, rep, lane, lane, lane, rep),
        out_specs=_out_specs(reduce), check_rep=False)
    return f(spec, trace, oracle_mask, mach, caps, keys, sample)


@functools.partial(
    jax.jit, static_argnames=("k", "sampling", "need_normal", "n",
                              "wl_boost", "interval_kernel", "reduce",
                              "tier_shim", "mesh"),
    donate_argnums=(0, 3, 4, 5, 9))
def _fab_synth_jit(spec, wl, k, mach, caps, keys, sample, noise_key,
                   wl_keys, widx, sampling, need_normal, n, wl_boost,
                   interval_kernel, reduce, tier_shim, mesh):
    # NB mirrors _sim_synth_jit's donation: wl / sample are shared across
    # dispatches (CRN pairing) and never donated; widx (9) is rebuilt per
    # call and is.
    lane, rep = P(LANE_AXIS), P()
    f = shard_map(
        lambda sp, w, mc, cp, ky, sm, nk, wk, wi: scan_engine._simulate(
            sp, None, None, k, mc, cp, ky, sm, sampling, need_normal,
            wl=w, wl_keys=wk, noise_key=nk, n=n, wl_boost=wl_boost,
            interval_kernel=interval_kernel, reduce=reduce,
            tier_shim=tier_shim, widx=wi),
        mesh=mesh,
        in_specs=(lane, rep, lane, lane, lane, rep, rep, rep, lane),
        out_specs=_out_specs(reduce), check_rep=False)
    return f(spec, wl, mach, caps, keys, sample, noise_key, wl_keys, widx)


def _plan_padding(B: int, D: int, pad_multiple) -> int:
    mult = D * int(pad_multiple or 1)
    return ((B + mult - 1) // mult) * mult


def sim_trace(spec, trace, oracle_mask, k, mach, caps, keys, sample,
              sampling, need_normal, interval_kernel=True, reduce="stack",
              tier_shim=False, mesh=None, pad_multiple=None):
    """Trace-mode dispatch, optionally sharded.  Returns ``(out, info)``:
    the raw engine output dict with padded lanes already dropped, and
    the fabric's dispatch info ({} on the plain path)."""
    D = resolve_mesh(mesh)
    if D is None and not pad_multiple:
        out = scan_engine._sim_jit(
            spec, trace, oracle_mask, k, mach, caps, keys, sample,
            sampling, need_normal, interval_kernel=interval_kernel,
            reduce=reduce, tier_shim=tier_shim)
        return out, {}
    D = D or 1
    B = keys.shape[0]
    Lp = _plan_padding(B, D, pad_multiple)
    spec, mach, caps, keys = (
        _pad_lanes(x, B, Lp) for x in (spec, mach, caps, keys))
    out = _fab_trace_jit(spec, trace, oracle_mask, k, mach, caps, keys,
                         sample, sampling, need_normal, interval_kernel,
                         reduce, tier_shim, _lane_mesh(D))
    return _unpad_out(out, B), dict(mesh=D, padded_lanes=Lp)


def sim_synth(spec, wl, k, mach, caps, keys, sample, noise_key, wl_keys,
              sampling, need_normal, wl_rep, n, wl_boost=True,
              interval_kernel=True, reduce="stack", tier_shim=False,
              mesh=None, pad_multiple=None):
    """Synth-mode dispatch, optionally sharded (see ``sim_trace``).

    ``wl_rep`` maps lane -> workload exactly as in ``_sim_synth_jit``
    (each workload feeds ``wl_rep`` consecutive lanes); the sharded path
    turns it into an explicit global ``widx`` gather so shard-local
    lanes read the right replicated synthesis row.
    """
    D = resolve_mesh(mesh)
    if D is None and not pad_multiple:
        out = scan_engine._sim_synth_jit(
            spec, wl, k, mach, caps, keys, sample, noise_key, wl_keys,
            sampling, need_normal, wl_rep, n, wl_boost=wl_boost,
            interval_kernel=interval_kernel, reduce=reduce,
            tier_shim=tier_shim)
        return out, {}
    D = D or 1
    B = keys.shape[0]
    Lp = _plan_padding(B, D, pad_multiple)
    widx = jnp.concatenate([
        jnp.arange(B, dtype=jnp.int32) // jnp.int32(wl_rep),
        jnp.zeros((Lp - B,), jnp.int32)])
    spec, mach, caps, keys = (
        _pad_lanes(x, B, Lp) for x in (spec, mach, caps, keys))
    out = _fab_synth_jit(spec, wl, k, mach, caps, keys, sample, noise_key,
                         wl_keys, widx, sampling, need_normal, n, wl_boost,
                         interval_kernel, reduce, tier_shim, _lane_mesh(D))
    return _unpad_out(out, B), dict(mesh=D, padded_lanes=Lp)
