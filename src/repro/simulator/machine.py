"""Two-tier machine models (paper Table 3) and the interval cost model.

The simulator charges each interval of application work against the tier the
pages live in:

    t_lat     = (acc_fast*L_fast + acc_slow*L_slow) / MLP
    t_bw_fast = (acc_fast*CL + mig_bytes) / BW_fast
    t_bw_slow = (acc_slow*CL + mig_bytes_slow) / BW_slow
    t         = max(t_lat, t_bw_fast, t_bw_slow)

i.e. the workload is limited by whichever resource saturates first; migration
traffic shares tier bandwidth with the application (this is exactly the
interference ARMS's BS formula manages).  MLP models the memory-level
parallelism of the threaded workload.

Both engines now run on the N-tier generalization of this model
(simulator/machine_spec.py, an i32 per-page tier index + adjacent-pair
hop migrations); the two-tier dataclass here remains the host-facing
Table-3 description and converts via ``machines.get`` /
``machine_spec.from_machine`` (N=2 replays are bitwise-identical).
"""
from __future__ import annotations

import dataclasses

CACHELINE = 64
PAGE_BYTES = 2 * 1024 * 1024  # 2 MB huge pages (paper §5)


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    name: str
    lat_fast_ns: float
    lat_slow_ns: float
    bw_fast: float          # B/s
    bw_slow_read: float     # B/s
    bw_slow_write: float    # B/s
    mlp: float = 64.0       # outstanding misses across threads

    @property
    def bw_slow(self) -> float:
        return self.bw_slow_read


# Table 3.
PMEM_LARGE = MachineSpec(
    name="pmem-large",
    lat_fast_ns=80.0, lat_slow_ns=200.0,
    bw_fast=138e9, bw_slow_read=7.45e9, bw_slow_write=2.25e9)

NUMA = MachineSpec(
    name="NUMA",
    lat_fast_ns=95.0, lat_slow_ns=145.0,
    bw_fast=56e9, bw_slow_read=36e9, bw_slow_write=36e9)

MACHINES = {"pmem-large": PMEM_LARGE, "numa": NUMA}


@dataclasses.dataclass(frozen=True)
class IntervalOutcome:
    """Raw (UNCLAMPED) utilization ratios: a tier demanding more
    bandwidth-time than the rest of the interval provides reports > 1 —
    the oversaturation magnitude the controller's cost/benefit signal
    needs.  Clamping happens only at the signal consumer (the engines
    clamp the policy-facing signal; core/scheduler.batch_size clips its
    input); ``min(1, raw)`` reproduces the old at-source clamp bitwise."""

    wall_s: float
    slow_bw_frac: float   # slow-tier bandwidth-time / rest of interval
    app_bw_frac: float    # fast-tier bandwidth-time / rest of interval


def interval_time(m: MachineSpec, acc_fast: float, acc_slow: float,
                  promo_pages: float, demo_pages: float) -> IntervalOutcome:
    """Wall time for one interval of work under a given placement."""
    app_fast_bytes = acc_fast * CACHELINE
    app_slow_bytes = acc_slow * CACHELINE
    # promotion: read slow + write fast; demotion: read fast + write slow.
    mig_fast_bytes = (promo_pages + demo_pages) * PAGE_BYTES
    mig_slow_read = promo_pages * PAGE_BYTES
    mig_slow_write = demo_pages * PAGE_BYTES

    t_lat = (acc_fast * m.lat_fast_ns + acc_slow * m.lat_slow_ns) * 1e-9 / m.mlp
    t_bw_fast = (app_fast_bytes + mig_fast_bytes) / m.bw_fast
    t_bw_slow = ((app_slow_bytes + mig_slow_read) / m.bw_slow_read
                 + mig_slow_write / m.bw_slow_write)
    wall = max(t_lat, t_bw_fast, t_bw_slow, 1e-12)

    slow_frac = t_bw_slow / max(t_lat, t_bw_fast, 1e-12)
    app_frac = t_bw_fast / max(t_lat, t_bw_slow, 1e-12)
    return IntervalOutcome(wall_s=wall, slow_bw_frac=slow_frac,
                           app_bw_frac=app_frac)


def promo_page_us(m: MachineSpec) -> float:
    """Per-page promotion latency (read slow + write fast), microseconds."""
    return (PAGE_BYTES / m.bw_slow_read + PAGE_BYTES / m.bw_fast) * 1e6


def demo_page_us(m: MachineSpec) -> float:
    """Per-page demotion latency (read fast + write slow), microseconds."""
    return (PAGE_BYTES / m.bw_fast + PAGE_BYTES / m.bw_slow_write) * 1e6
