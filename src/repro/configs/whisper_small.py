"""whisper-small [audio] — enc-dec 12L+12L d_model=768 12H hd=64 d_ff=3072
vocab=51865 (padded 51968); conv frontend STUBBED: input_specs() provides
precomputed frame embeddings [B, 1500, 768] (arXiv:2212.04356)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size_raw=51865,
    n_enc_layers=12, enc_seq=1500,
)
