"""Model / shape configuration system.

``ModelConfig`` is a frozen, hashable dataclass covering every assigned
architecture family (dense GQA, MLA, MoE, SSM, hybrid, enc-dec, VLM).  One
``src/repro/configs/<arch>.py`` per assigned architecture instantiates it
with the published dimensions; ``registry.py`` resolves ``--arch``/``--shape``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size_raw: int
    head_dim: int = 128
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_pct: float = 1.0       # stablelm-2 uses partial rotary (25%)
    tie_embeddings: bool = False
    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 128
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1          # MoE layer every N layers (llama4: 2)
    first_dense: int = 0        # deepseek-v2: first layer is dense
    dense_d_ff: int = 0         # d_ff of dense layers inside MoE models
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_kernel: int = 4
    # --- hybrid (zamba2): shared attention block every N mamba layers ---
    attn_every: int = 0
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0            # precomputed frame embeddings (conv stub)
    # --- vlm (llava): patch embeddings prepended (projector stub) ---
    n_patches: int = 0
    # --- attention window (llama4 long-context chunked attention) ---
    sliding_window: int = 0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # sharding divisibility (model axis); vocab is padded to this multiple
    shard_multiple: int = 16

    @property
    def vocab_size(self) -> int:
        """Vocabulary padded for even sharding over the model axis."""
        return _round_up(self.vocab_size_raw, 128)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_head_dim(self) -> int:
        return self.d_inner // max(self.ssm_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM/hybrid/windowed attention)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def n_params(self) -> int:
        """Approximate parameter count (for 6ND model-FLOPs accounting)."""
        from repro.models import model as model_lib
        return model_lib.count_params(self)

    def validate(self) -> None:
        assert self.d_model % self.shard_multiple == 0, self.name
        assert self.vocab_size % 128 == 0, self.name
        if self.n_heads:
            assert (self.n_heads * self.head_dim) % self.shard_multiple == 0
        if self.n_experts:
            assert self.n_experts % self.shard_multiple == 0 or \
                self.shard_multiple % self.n_experts == 0, \
                f"{self.name}: experts must tile the model axis"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assigned-cell applicability rules (see DESIGN.md §5)."""
    if shape.name == "long_500k" and not model.subquadratic:
        return False, "pure full-attention arch: 500k decode skipped (DESIGN.md §5)"
    return True, ""
