"""mamba2-370m [ssm] — SSD (state-space duality, arXiv:2405.21060).
48L d_model=1024 attn-free, ssm_state=128, headdim 64 -> 32 heads,
vocab=50280 (padded 50304 for sharding)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size_raw=50280,
    ssm_state=128, ssm_heads=32, ssm_expand=2, ssm_chunk=64,
)
