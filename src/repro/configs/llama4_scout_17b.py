"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) hd=128,
MoE 16 experts top-1 + shared expert on alternating layers, d_ff=8192,
vocab=202048; iRoPE-style chunked local attention (window 8192) keeps the
decode working set bounded -> long_500k eligible
(hf:meta-llama/Llama-4-Scout-17B-16E)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, dense_d_ff=8192, vocab_size_raw=202048, rope_theta=5e5,
    n_experts=16, experts_per_token=1, n_shared_experts=1, moe_d_ff=8192,
    moe_every=2, sliding_window=8192,
)
