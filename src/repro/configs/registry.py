"""Architecture / shape registry: resolves ``--arch`` and ``--shape``.

Also provides ``reduced(cfg)`` — a structure-preserving shrink of any config
(small width, few layers/experts, tiny vocab) used by the per-arch CPU smoke
tests; the FULL configs are only ever lowered via ShapeDtypeStructs in the
dry-run.
"""
from __future__ import annotations

import dataclasses

from repro.configs import (deepseek_v2_236b, granite_8b,
                           llama4_scout_17b, llava_next_mistral_7b,
                           mamba2_370m, mistral_nemo_12b, qwen3_14b,
                           stablelm_1p6b, whisper_small, zamba2_1p2b)
from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig,
                                shape_applicable)

ARCHS = {m.CONFIG.name: m.CONFIG for m in (
    zamba2_1p2b, mistral_nemo_12b, stablelm_1p6b, qwen3_14b, granite_8b,
    llama4_scout_17b, deepseek_v2_236b, mamba2_370m, whisper_small,
    llava_next_mistral_7b)}

# short aliases for --arch
ALIASES = {
    "zamba2": "zamba2-1.2b",
    "mistral-nemo": "mistral-nemo-12b",
    "stablelm": "stablelm-1.6b",
    "qwen3": "qwen3-14b",
    "granite": "granite-8b",
    "llama4-scout": "llama4-scout-17b-16e",
    "deepseek-v2": "deepseek-v2-236b",
    "mamba2": "mamba2-370m",
    "whisper": "whisper-small",
    "llava-next": "llava-next-mistral-7b",
}


def get_arch(name: str) -> ModelConfig:
    cfg = ARCHS.get(ALIASES.get(name, name))
    if cfg is None:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    cfg.validate()
    return cfg


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells():
    """All 40 assigned (arch x shape) cells with applicability verdicts."""
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, why = shape_applicable(arch, shape)
            yield arch, shape, ok, why


def reduced(cfg: ModelConfig, seq_hint: int = 32) -> ModelConfig:
    """Structure-preserving tiny variant for CPU smoke tests."""
    over = dict(
        name=cfg.name + "-smoke",
        d_model=64,
        d_ff=min(cfg.d_ff, 128) if cfg.d_ff else 0,
        vocab_size_raw=256,
        dtype="float32",
        shard_multiple=1,
    )
    if cfg.n_heads:
        over.update(n_heads=4, n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads
                    else 4, head_dim=16)
    if cfg.family == "hybrid":
        over.update(n_layers=5, attn_every=2, ssm_state=16, ssm_heads=4,
                    d_ff=128)
    elif cfg.family == "ssm":
        over.update(n_layers=4, ssm_state=16, ssm_heads=4, ssm_chunk=8)
    elif cfg.family == "moe":
        if cfg.use_mla:
            over.update(n_layers=3, n_experts=8, experts_per_token=2,
                        n_shared_experts=1, moe_d_ff=32, dense_d_ff=128,
                        kv_lora_rank=32, q_lora_rank=48, rope_head_dim=16,
                        v_head_dim=16)
        else:
            over.update(n_layers=4, n_experts=8, experts_per_token=1,
                        n_shared_experts=1, moe_d_ff=64, dense_d_ff=128,
                        sliding_window=16 if cfg.sliding_window else 0)
    elif cfg.family == "encdec":
        over.update(n_layers=2, n_enc_layers=2, enc_seq=16)
    elif cfg.family == "vlm":
        over.update(n_layers=2, n_patches=8)
    else:
        over.update(n_layers=2)
    return dataclasses.replace(cfg, **over)
