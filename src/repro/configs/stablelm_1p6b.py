"""stablelm-1.6b [dense] — 24L d_model=2048 32H (kv=32) hd=64 d_ff=5632
vocab=100352, partial rotary 25% (hf:stabilityai/stablelm-2-1_6b)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=5632, vocab_size_raw=100352, rope_pct=0.25,
)
