"""llava-next-mistral-7b [vlm] — Mistral-7B backbone: 32L d_model=4096 32H
(GQA kv=8) hd=128 d_ff=14336 vocab=32000; anyres vision tower + projector
STUBBED: input_specs() provides patch embeddings [B, 576, 4096]
(hf:llava-hf/llava-v1.6-mistral-7b-hf)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size_raw=32000, rope_theta=1e6,
    n_patches=576,
)
