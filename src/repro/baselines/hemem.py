"""HeMem baseline (Raybuck et al., SOSP'21) — static-threshold tiering.

Faithful simplifications of the behaviors the paper analyzes (§2-3):
  * per-page sample counts accumulate until a COOLING event (any page count
    reaching ``cooling_threshold`` halves all counts);
  * a page is hot iff its count >= ``hot_threshold`` (static);
  * a migration pass runs every ``migration_period`` intervals;
  * migration is SERIAL and FIFO in hot-page *discovery* order -> newly very
    hot pages suffer head-of-line blocking (paper §3.2 "Serial migration");
  * cold pages are demoted only to make room (no free-page pool).

The tunable knobs exposed here are the ones the paper's tuning study sweeps.
"""
from __future__ import annotations

import numpy as np

from repro.baselines.base import Policy

# Default knob values from the HeMem implementation (paper §2/§3.1).
DEFAULTS = dict(hot_threshold=8.0, cooling_threshold=18.0,
                migration_period=5, sample_period=10_000.0)


class HeMemPolicy(Policy):
    name = "hemem"
    migration_limit = 12   # serial migration: ~120 pages/s at 100ms intervals

    def __init__(self, hot_threshold=None, cooling_threshold=None,
                 migration_period=None, sample_period=None):
        self.hot_threshold = DEFAULTS["hot_threshold"] \
            if hot_threshold is None else float(hot_threshold)
        self.cooling_threshold = DEFAULTS["cooling_threshold"] \
            if cooling_threshold is None else float(cooling_threshold)
        self.migration_period = DEFAULTS["migration_period"] \
            if migration_period is None else int(migration_period)
        self._sample_period = DEFAULTS["sample_period"] \
            if sample_period is None else float(sample_period)

    def reset(self, n_pages, k, machine):
        self.n, self.k = n_pages, k
        self.counts = np.zeros(n_pages)
        self.in_fast = np.zeros(n_pages, bool)
        self.first_hot = np.full(n_pages, np.inf)  # FIFO discovery order
        self.t = 0
        self.cooling_events = 0

    def sampling_period(self):
        return self._sample_period

    def step(self, observed, slow_bw_frac, app_bw_frac):
        self.t += 1
        self.counts += observed
        # cooling: triggered when any page reaches the cooling threshold.
        if self.counts.max() >= self.cooling_threshold:
            self.counts *= 0.5
            self.cooling_events += 1

        hot = self.counts >= self.hot_threshold
        newly_hot = hot & np.isinf(self.first_hot)
        self.first_hot[newly_hot] = self.t
        self.first_hot[~hot] = np.inf

        if self.t % self.migration_period:
            return np.empty(0, np.int64), np.empty(0, np.int64)

        want = np.flatnonzero(hot & ~self.in_fast)
        want = want[np.argsort(self.first_hot[want], kind="stable")]  # FIFO
        want = want[: self.migration_limit]

        free = self.k - int(self.in_fast.sum())
        need_victims = max(0, len(want) - free)
        cold_in_fast = np.flatnonzero(self.in_fast & ~hot)
        victims = cold_in_fast[np.argsort(self.counts[cold_in_fast],
                                          kind="stable")][:need_victims]
        # without enough cold victims, promotions stall (paper §3.2
        # "Inaccurate cooling threshold" -> zero cold pages in DRAM).
        want = want[: free + len(victims)]
        self.in_fast[victims] = False
        self.in_fast[want] = True
        return want, victims
