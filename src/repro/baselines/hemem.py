"""HeMem baseline (Raybuck et al., SOSP'21) — static-threshold tiering.

Faithful simplifications of the behaviors the paper analyzes (§2-3):
  * per-page sample counts accumulate until a COOLING event (any page count
    reaching ``cooling_threshold`` halves all counts);
  * a page is hot iff its count >= ``hot_threshold`` (static);
  * a migration pass runs every ``migration_period`` intervals;
  * migration is SERIAL and FIFO in hot-page *discovery* order -> newly very
    hot pages suffer head-of-line blocking (paper §3.2 "Serial migration");
  * cold pages are demoted only to make room (no free-page pool).

The tunable knobs exposed here are the ones the paper's tuning study sweeps;
they are leaves of ``HeMemSpec``, so a whole tuning budget runs lane-batched
in the compiled scan engine (see simulator/tuning.py).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.baselines.protocol import (LegacyPolicyAdapter, PolicySpec,
                                      capacity_victims, ranked_take,
                                      scatter_set, truncate_ranked)
from repro.utils.pytree import pytree_dataclass

# Default knob values from the HeMem implementation (paper §2/§3.1).
DEFAULTS = dict(hot_threshold=8.0, cooling_threshold=18.0,
                migration_period=5, sample_period=10_000.0)


@pytree_dataclass
class HeMemState:
    counts: jnp.ndarray        # f32 [n] cooled sample counts
    in_fast: jnp.ndarray      # bool [n] policy's residency belief
    first_hot: jnp.ndarray    # f32 [n] FIFO discovery order (inf = not hot)
    t: jnp.ndarray            # i32 interval counter
    cooling_events: jnp.ndarray  # i32


@pytree_dataclass(meta=("migration_limit",))
class HeMemSpec(PolicySpec):
    hot_threshold: jnp.ndarray
    cooling_threshold: jnp.ndarray
    migration_period: jnp.ndarray     # i32
    sample_period: jnp.ndarray
    migration_limit: int = 12  # serial: ~120 pages/s at 100ms intervals

    name = "hemem"

    @classmethod
    def make(cls, hot_threshold=None, cooling_threshold=None,
             migration_period=None, sample_period=None,
             migration_limit: int = 12) -> "HeMemSpec":
        pick = lambda v, key: DEFAULTS[key] if v is None else v
        return cls(
            hot_threshold=jnp.float32(pick(hot_threshold, "hot_threshold")),
            cooling_threshold=jnp.float32(
                pick(cooling_threshold, "cooling_threshold")),
            migration_period=jnp.int32(
                pick(migration_period, "migration_period")),
            sample_period=jnp.float32(pick(sample_period, "sample_period")),
            migration_limit=migration_limit)

    def init(self, n_pages, k, machine):
        return HeMemState(
            counts=jnp.zeros((n_pages,), jnp.float32),
            in_fast=jnp.zeros((n_pages,), bool),
            first_hot=jnp.full((n_pages,), jnp.inf, jnp.float32),
            t=jnp.zeros((), jnp.int32),
            cooling_events=jnp.zeros((), jnp.int32))

    def sampling_period(self, state):
        return jnp.asarray(self.sample_period, jnp.float32)

    def min_sampling_period(self):
        import numpy as np
        return float(np.min(np.asarray(self.sample_period)))

    def observe(self, state, observed):
        t = state.t + 1
        counts = state.counts + observed
        # cooling: triggered when any page reaches the cooling threshold.
        cool = counts.max() >= self.cooling_threshold
        counts = jnp.where(cool, counts * 0.5, counts)
        hot = counts >= self.hot_threshold
        newly_hot = hot & jnp.isinf(state.first_hot)
        first_hot = jnp.where(newly_hot, t.astype(jnp.float32),
                              state.first_hot)
        first_hot = jnp.where(hot, first_hot, jnp.inf)
        return state.replace(
            counts=counts, first_hot=first_hot, t=t,
            cooling_events=state.cooling_events + cool.astype(jnp.int32))

    def fires(self, state):
        period = jnp.maximum(self.migration_period.astype(jnp.int32), 1)
        return (state.t % period) == 0

    def policy(self, state, slow_bw, app_bw, k):
        n = state.counts.shape[0]
        hot = state.counts >= self.hot_threshold
        want, n_want = ranked_take(                        # FIFO order
            state.first_hot, hot & ~state.in_fast,
            self.pad_promote(n, k), self.migration_limit)
        # without enough cold victims, promotions stall (paper §3.2
        # "Inaccurate cooling threshold" -> zero cold pages in DRAM).
        victims, _, n_take = capacity_victims(
            state.in_fast, state.counts, state.in_fast & ~hot, n_want, k,
            self.pad_demote(n, k))
        promote = truncate_ranked(want, n_take)
        in_fast = scatter_set(state.in_fast, victims, False)
        in_fast = scatter_set(in_fast, promote, True)
        return state.replace(in_fast=in_fast), promote, victims


class HeMemPolicy(LegacyPolicyAdapter):
    """HeMem for the numpy reference engine (functional spec under the hood).

    Subclasses may override the ``migration_limit`` class attribute (the
    greedy-capacity test does); it is forwarded into the spec.
    """

    migration_limit = 12

    def __init__(self, hot_threshold=None, cooling_threshold=None,
                 migration_period=None, sample_period=None):
        super().__init__(HeMemSpec.make(
            hot_threshold, cooling_threshold, migration_period,
            sample_period, migration_limit=type(self).migration_limit))
