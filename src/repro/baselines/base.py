"""Stateful policy interface of the numpy reference engine.

A policy sees only PEBS-sampled counts and bandwidth signals (never true
access counts) and returns per-interval promotion/demotion page lists.  The
simulator engine applies them, charges migration traffic, and scores the run.

This imperative interface is now the *legacy* face of the functional policy
protocol (baselines/protocol.py): every concrete policy is a pure
``PolicySpec`` (jittable init/step over pytree state) and reaches the numpy
engine through ``protocol.LegacyPolicyAdapter``, so both engines replay
bitwise-identical decisions.  Only the engine-facing contract lives here.
"""
from __future__ import annotations

import numpy as np


class Policy:
    name: str = "base"
    #: pages the engine will migrate for this policy in one interval; models
    #: serial (kernel-thread) vs batched (Nimble/ARMS) migration mechanisms.
    migration_limit: int = 10**9

    def reset(self, n_pages: int, k: int, machine) -> None:
        raise NotImplementedError

    def sampling_period(self) -> float:
        return 10_000.0

    def step(self, observed: np.ndarray, slow_bw_frac: float,
             app_bw_frac: float):
        """-> (promote_idx: np.ndarray, demote_idx: np.ndarray)

        ``promote`` are slow-tier pages to move fast (priority order);
        ``demote`` are fast-tier pages to move slow.  The engine executes
        demotions first, then promotions, capped by capacity and
        ``migration_limit``.
        """
        raise NotImplementedError

    def wants_true_counts(self) -> bool:
        return False
