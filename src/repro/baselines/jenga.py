"""Jenga-style baseline — thrash-free responsive tiering (tier-native).

Jenga (PAPERS.md) shows that making a tiering policy *responsive* (short
EWMA horizon, migration pass every interval or two) collapses under phase
flips unless it is paired with explicit thrash avoidance.  This spec
implements both halves on the tier-native contract:

  * responsiveness: per-page EWMA hotness with a fast ``alpha`` and a
    short ``migration_period``;
  * confirmation: a page only moves after its rank-partition target has
    been stable for ``confirm`` consecutive passes (one noisy interval
    cannot trigger a migration);
  * cooldown: a page that just moved is pinned for ``cooldown`` passes —
    the ping-pong breaker.

Per-pair budgets come from ``scheduler.pair_budgets`` on the engine's
per-tier utilization, like every tier-native policy.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.baselines.protocol import (LegacyPolicyAdapter, PolicySpec,
                                      rank_desc, rank_partition, tier_plan)
from repro.core.scheduler import pair_budgets
from repro.utils.pytree import pytree_dataclass

DEFAULTS = dict(alpha=0.5, confirm=2, cooldown=3, migration_period=1,
                sample_period=10_000.0)


@pytree_dataclass
class JengaState:
    ewma: jnp.ndarray      # f32 [n] per-page hotness estimate
    tier: jnp.ndarray      # i32 [n] residency belief
    streak: jnp.ndarray    # i32 [n] consecutive passes with same target
    last_tgt: jnp.ndarray  # i32 [n] previous pass's raw target
    moved_at: jnp.ndarray  # i32 [n] pass index of the page's last move
    passes: jnp.ndarray    # i32 policy-pass counter
    t: jnp.ndarray         # i32 interval counter


@pytree_dataclass(meta=("bs_max",))
class JengaSpec(PolicySpec):
    alpha: jnp.ndarray             # EWMA weight of the newest interval
    confirm: jnp.ndarray           # i32 confirmation streak before a move
    cooldown: jnp.ndarray          # i32 passes a moved page stays pinned
    migration_period: jnp.ndarray  # i32
    sample_period: jnp.ndarray
    bs_max: int = 128

    name = "jenga"
    tier_native = True

    @classmethod
    def make(cls, alpha=None, confirm=None, cooldown=None,
             migration_period=None, sample_period=None,
             bs_max: int = 128) -> "JengaSpec":
        pick = lambda v, key: DEFAULTS[key] if v is None else v
        return cls(
            alpha=jnp.float32(pick(alpha, "alpha")),
            confirm=jnp.int32(pick(confirm, "confirm")),
            cooldown=jnp.int32(pick(cooldown, "cooldown")),
            migration_period=jnp.int32(
                pick(migration_period, "migration_period")),
            sample_period=jnp.float32(pick(sample_period, "sample_period")),
            bs_max=bs_max)

    def pad_promote(self, n: int, k: int) -> int:
        return max(1, min(n, 2 * self.bs_max))

    def pad_demote(self, n: int, k: int) -> int:
        return max(1, min(n, 2 * self.bs_max))

    def init(self, n_pages, k, machine):
        R = machine.lat_ns.shape[-1]
        return JengaState(
            ewma=jnp.zeros((n_pages,), jnp.float32),
            tier=jnp.full((n_pages,), R - 1, jnp.int32),
            streak=jnp.zeros((n_pages,), jnp.int32),
            last_tgt=jnp.full((n_pages,), R - 1, jnp.int32),
            moved_at=jnp.full((n_pages,), -(10 ** 6), jnp.int32),
            passes=jnp.zeros((), jnp.int32),
            t=jnp.zeros((), jnp.int32))

    def sampling_period(self, state):
        return jnp.asarray(self.sample_period, jnp.float32)

    def min_sampling_period(self):
        return float(np.min(np.asarray(self.sample_period)))

    def observe(self, state, observed):
        a = jnp.clip(self.alpha, 0.0, 1.0)
        return state.replace(ewma=(1 - a) * state.ewma + a * observed,
                             t=state.t + 1)

    def fires(self, state):
        period = jnp.maximum(self.migration_period.astype(jnp.int32), 1)
        return (state.t % period) == 0

    def tier_policy(self, state, tier_util, slow_bw, app_bw, k, caps):
        n = state.ewma.shape[0]
        p = state.passes + 1
        raw = rank_partition(rank_desc(state.ewma), caps)
        streak = jnp.where(raw == state.last_tgt, state.streak + 1,
                           jnp.ones((), jnp.int32))
        conf = jnp.maximum(self.confirm.astype(jnp.int32), 1)
        cool = jnp.maximum(self.cooldown.astype(jnp.int32), 0)
        eligible = (streak >= conf) & (p - state.moved_at > cool)
        tgt = jnp.where(eligible, raw, state.tier)
        budgets = pair_budgets(tier_util, self.bs_max)
        pages, dst, tier = tier_plan(
            state.ewma, state.tier, tgt, caps, budgets,
            self.pad_demote(n, k), self.pad_promote(n, k))
        moved_at = jnp.where(tier != state.tier, p, state.moved_at)
        return (state.replace(tier=tier, streak=streak, last_tgt=raw,
                              moved_at=moved_at, passes=p), pages, dst)


class JengaPolicy(LegacyPolicyAdapter):
    """Jenga for the numpy reference engine (functional spec inside)."""

    def __init__(self, alpha=None, confirm=None, cooldown=None,
                 migration_period=None, sample_period=None):
        super().__init__(JengaSpec.make(
            alpha, confirm, cooldown, migration_period, sample_period))
