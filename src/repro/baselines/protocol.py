"""Functional policy protocol: pure ``init``/``step`` over pytree state.

Every tiering policy — ARMS and all baselines — is expressed as a
``PolicySpec``: a pytree dataclass whose *leaves* are the policy's tunable
knobs (f32/i32 scalars, batchable into sweep lanes) and whose *meta* fields
are static shape/identity data (name, pad widths, flags).  The behaviour is
a set of pure, jittable functions over an immutable ``PolicyState`` pytree:

    state = spec.init(n_pages, k, machine)
    state = spec.observe(state, observed)        # cheap, every interval
    fire  = spec.fires(state)                    # is the policy pass due?
    state, promote, demote = spec.policy(state, slow_bw, app_bw, k)
    state, promote, demote = spec.step(state, observed, slow_bw, app_bw, k)

``step`` is the composed reference semantics (observe + cond(fires) around
policy).  The split exists so the compiled scan engine can hoist the
cadence gate to a *scalar* ``lax.cond`` across sweep lanes (see
scan_engine.py) while the numpy reference engine uses ``step`` as-is.

Padded-index contract
---------------------
``promote``/``demote`` are fixed-shape i32 arrays of widths
``spec.pad_promote(n, k)`` / ``spec.pad_demote(n, k)``.  Entries equal to
the sentinel ``-1`` are padding and are skipped; the remaining entries are
page indices in priority order (hottest/most-urgent first).  The engines
execute demotions first, then promotions capped by free capacity — see
``simjax.apply_padded_migrations`` (scan engine) and the variable-length
equivalent in ``engine.run`` (numpy engine); both agree exactly (property-
tested in tests/test_policy_protocol.py).

``LegacyPolicyAdapter`` wraps a spec back into the stateful ``Policy``
interface so the numpy reference engine keeps replaying every policy with
bitwise-identical decisions — that cross-engine agreement is the
correctness oracle for the compiled scan engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.base import Policy

SENTINEL = -1


# --------------------------------------------------------------- helpers
def ranked_take(key, mask, pad: int, limit=None):
    """First ``limit`` indices of ``mask`` ordered by ``key`` ascending.

    Ties break by ascending page index (jnp.argsort is stable), matching a
    stable numpy argsort applied over ``np.flatnonzero(mask)``.  Returns a
    ``pad``-wide sentinel-padded i32 index array (valid entries form a
    prefix) plus the valid count.  ``limit`` may be a traced scalar or
    static int; ``None`` keeps every masked index (up to ``pad``).
    """
    n = key.shape[0]
    pad = max(1, min(pad, n))
    # top_k, not argsort: XLA's generic sort is ~50x slower on CPU at
    # simulator scale, and top_k's tie rule (lower index first) matches a
    # stable ascending argsort exactly.
    _, order = jax.lax.top_k(jnp.where(mask, -key.astype(jnp.float32),
                                       -jnp.inf), pad)
    order = order.astype(jnp.int32)
    count = mask.sum().astype(jnp.int32)
    if limit is not None:
        count = jnp.minimum(count, jnp.asarray(limit, jnp.int32))
    count = jnp.minimum(count, pad)
    keep = jnp.arange(pad, dtype=jnp.int32) < count
    return jnp.where(keep, order, SENTINEL), count


def truncate_ranked(idx, count):
    """Keep the first ``count`` valid (prefix) entries of a ranked list."""
    keep = jnp.arange(idx.shape[0], dtype=jnp.int32) < count
    return jnp.where(keep, idx, SENTINEL)


def scatter_set(dst, idx, value: bool):
    """Set ``dst[idx] = value`` for non-sentinel entries of ``idx``."""
    n = dst.shape[0]
    safe = jnp.where(idx >= 0, idx, n)
    return dst.at[safe].set(value, mode="drop")


# ---------------------------------------------------------------- protocol
class PolicySpec:
    """Base of the functional policy protocol (subclass + pytree_dataclass).

    Class attributes are static protocol metadata; dataclass fields are the
    knob leaves.  All methods must be pure and traceable; ``self``'s leaves
    may be traced arrays (batched sweep lanes under vmap).
    """

    name: str = "base"
    #: pages migrated per policy pass; models serial (kernel-thread) vs
    #: batched (Nimble/ARMS) migration mechanisms.  Specs that sweep shape-
    #: relevant knobs keep this a static meta field instead.
    migration_limit: int = 10 ** 9
    #: observed counts are TRUE counts (oracle upper bound), not PEBS samples
    wants_true_counts: bool = False
    #: per-slow-access application overhead of the policy mechanism (TPP
    #: NUMA hint faults); charged by both engines.
    slow_access_extra_ns: float = 0.0
    #: whether sampling_period/mode depend on runtime state (ARMS) or are
    #: constant per spec (every baseline).
    dynamic_sampling_period: bool = False
    has_mode: bool = False

    DEFAULT_SAMPLE_PERIOD = 10_000.0

    # --- static shape contract -------------------------------------------
    def pad_promote(self, n: int, k: int) -> int:
        return max(1, min(n, self.migration_limit))

    def pad_demote(self, n: int, k: int) -> int:
        return max(1, min(n, self.migration_limit))

    # --- pure functions over pytree state --------------------------------
    def init(self, n_pages: int, k: int, machine):
        raise NotImplementedError

    def observe(self, state, observed):
        """Cheap per-interval accumulation (counts, faults, buffers)."""
        return state

    def fires(self, state):
        """Scalar bool: does the (expensive) policy pass run this interval?"""
        return jnp.asarray(True)

    def sampling_period(self, state):
        return jnp.float32(self.DEFAULT_SAMPLE_PERIOD)

    def min_sampling_period(self) -> float:
        """Host-side lower bound on the sampling period (static shapes)."""
        return float(self.DEFAULT_SAMPLE_PERIOD)

    def mode_of(self, state):
        """Controller mode for the SimResult timeline (ARMS; 0 elsewhere)."""
        return jnp.zeros((), jnp.int32)

    def policy(self, state, slow_bw, app_bw, k: int):
        """-> (state, promote, demote): the full policy pass.

        ``promote``/``demote`` follow the padded-index contract (module
        docstring).  Only called on intervals where ``fires(state)``.
        """
        raise NotImplementedError

    def step(self, state, observed, slow_bw, app_bw, k: int):
        """Reference composition: observe, then cond(fires) around policy."""
        n = observed.shape[0]
        state = self.observe(state, observed)
        pad_p, pad_d = self.pad_promote(n, k), self.pad_demote(n, k)

        def fire(s):
            return self.policy(s, slow_bw, app_bw, k)

        def skip(s):
            return (s, jnp.full((pad_p,), SENTINEL, jnp.int32),
                    jnp.full((pad_d,), SENTINEL, jnp.int32))

        return jax.lax.cond(self.fires(state), fire, skip, state)


def capacity_victims(in_fast, cold_key, cold_mask, n_want, k: int, pad_d: int,
                     extra_need=0):
    """Shared victim selection: free slots, then coldest-first demotions.

    Returns (victims, n_victims, n_take) where ``n_take`` caps the
    promotion list at ``free + n_victims`` (the engines never exceed
    capacity, so a policy that respects this bound sees every request
    executed and its internal residency belief stays exact).
    """
    free = (k - in_fast.sum()).astype(jnp.int32)
    need = jnp.maximum(jnp.maximum(n_want - free, extra_need), 0)
    victims, n_vict = ranked_take(cold_key, cold_mask, pad_d, need)
    n_take = jnp.minimum(n_want, free + n_vict)
    return victims, n_vict, n_take


# ----------------------------------------------------------- legacy bridge
@functools.partial(jax.jit, static_argnames=("k",))
def _protocol_step(spec, state, observed, slow_bw, app_bw, k: int):
    return spec.step(state, observed, slow_bw, app_bw, k)


class LegacyPolicyAdapter(Policy):
    """A functional ``PolicySpec`` exposed as a stateful numpy-engine Policy.

    The adapter holds the pytree state between intervals and calls the
    spec's jitted ``step`` once per interval; padded outputs are converted
    to the engine's variable-length index lists by dropping sentinels (order
    preserved).  Decisions are therefore bitwise-identical to the compiled
    scan engine's — the basis of the cross-engine equivalence tests.
    """

    def __init__(self, spec: PolicySpec):
        self.spec = spec
        self.name = spec.name
        self.slow_access_extra_ns = spec.slow_access_extra_ns

    def reset(self, n_pages, k, machine):
        self.n, self.k = n_pages, k
        self.state = self.spec.init(n_pages, k, machine)
        self._period = float(self.spec.sampling_period(self.state))

    def sampling_period(self):
        return self._period

    def wants_true_counts(self):
        return self.spec.wants_true_counts

    @property
    def mode(self) -> int:
        if not type(self.spec).has_mode:
            return 0
        return int(self.spec.mode_of(self.state))

    def step(self, observed, slow_bw_frac, app_bw_frac):
        self.state, promote, demote = _protocol_step(
            self.spec, self.state, jnp.asarray(observed, jnp.float32),
            jnp.float32(slow_bw_frac), jnp.float32(app_bw_frac), self.k)
        if type(self.spec).dynamic_sampling_period:
            self._period = float(self.spec.sampling_period(self.state))
        promote = np.asarray(promote, np.int64)
        demote = np.asarray(demote, np.int64)
        return promote[promote >= 0], demote[demote >= 0]
