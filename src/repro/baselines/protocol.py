"""Functional policy protocol: pure ``init``/``step`` over pytree state.

Every tiering policy — ARMS and all baselines — is expressed as a
``PolicySpec``: a pytree dataclass whose *leaves* are the policy's tunable
knobs (f32/i32 scalars, batchable into sweep lanes) and whose *meta* fields
are static shape/identity data (name, pad widths, flags).  The behaviour is
a set of pure, jittable functions over an immutable ``PolicyState`` pytree:

    state = spec.init(n_pages, k, machine)
    state = spec.observe(state, observed)        # cheap, every interval
    fire  = spec.fires(state)                    # is the policy pass due?
    state, promote, demote = spec.policy(state, slow_bw, app_bw, k)
    state, promote, demote = spec.step(state, observed, slow_bw, app_bw, k)

``step`` is the composed reference semantics (observe + cond(fires) around
policy).  The split exists so the compiled scan engine can hoist the
cadence gate to a *scalar* ``lax.cond`` across sweep lanes (see
scan_engine.py) while the numpy reference engine uses ``step`` as-is.

Padded-index contract
---------------------
``promote``/``demote`` are fixed-shape i32 arrays of widths
``spec.pad_promote(n, k)`` / ``spec.pad_demote(n, k)``.  Entries equal to
the sentinel ``-1`` are padding and are skipped; the remaining entries are
page indices in priority order (hottest/most-urgent first).  The engines
execute demotions first, then promotions capped by free capacity — see
``simjax.apply_padded_migrations`` (scan engine) and the variable-length
equivalent in ``engine.run`` (numpy engine); both agree exactly (property-
tested in tests/test_policy_protocol.py).

Tier-native contract
--------------------
Binary promote/demote only speaks about tier 0; middle tiers of an N-tier
chain are reachable solely through the engine's hop-chain cascade.  Specs
that set ``tier_native = True`` implement ``tier_policy`` instead and see
the whole chain:

    state, pages, dst = spec.tier_policy(
        state, tier_util, slow_bw, app_bw, k, caps)

``tier_util`` is the f32 [R] per-tier bandwidth utilization of the last
interval (simjax.tier_utilization); ``caps`` the i32 [R] resolved per-tier
capacities.  ``pages``/``dst`` are ``pad_moves(n, k)``-wide tier-TARGETED
moves: sentinel-padded page indices in priority order (down-moves first,
then up-moves) with explicit destination tiers (``simjax.DST_BELOW``
requests the hop-chain demotion cascade).  The engines execute them with
``simjax.apply_targeted_migrations``.  Per-pair migration budgets come
from ``scheduler.pair_budgets(tier_util, bs_max)`` and are enforced
policy-side by ``tier_plan``/``pair_limit`` below, so both engines see
identical plans and a policy's residency belief stays exact.

Binary specs need no changes: the base ``tier_policy`` is a shim that
concatenates ``policy``'s demotions (dst=DST_BELOW) and promotions
(dst=0), which ``apply_targeted_migrations`` executes bitwise-identically
to the hop-chain path — asserted for all six families in
tests/test_tier_native.py.

``LegacyPolicyAdapter`` wraps a spec back into the stateful ``Policy``
interface so the numpy reference engine keeps replaying every policy with
bitwise-identical decisions — that cross-engine agreement is the
correctness oracle for the compiled scan engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.base import Policy
from repro.simulator.simjax import DST_BELOW

SENTINEL = -1


# --------------------------------------------------------------- helpers
def ranked_take(key, mask, pad: int, limit=None):
    """First ``limit`` indices of ``mask`` ordered by ``key`` ascending.

    Ties break by ascending page index (jnp.argsort is stable), matching a
    stable numpy argsort applied over ``np.flatnonzero(mask)``.  Returns a
    ``pad``-wide sentinel-padded i32 index array (valid entries form a
    prefix) plus the valid count.  ``limit`` may be a traced scalar or
    static int; ``None`` keeps every masked index (up to ``pad``).
    """
    n = key.shape[0]
    pad = max(1, min(pad, n))
    # top_k, not argsort: XLA's generic sort is ~50x slower on CPU at
    # simulator scale, and top_k's tie rule (lower index first) matches a
    # stable ascending argsort exactly.
    _, order = jax.lax.top_k(jnp.where(mask, -key.astype(jnp.float32),
                                       -jnp.inf), pad)
    order = order.astype(jnp.int32)
    count = mask.sum().astype(jnp.int32)
    if limit is not None:
        count = jnp.minimum(count, jnp.asarray(limit, jnp.int32))
    count = jnp.minimum(count, pad)
    keep = jnp.arange(pad, dtype=jnp.int32) < count
    return jnp.where(keep, order, SENTINEL), count


def truncate_ranked(idx, count):
    """Keep the first ``count`` valid (prefix) entries of a ranked list."""
    keep = jnp.arange(idx.shape[0], dtype=jnp.int32) < count
    return jnp.where(keep, idx, SENTINEL)


def scatter_set(dst, idx, value: bool):
    """Set ``dst[idx] = value`` for non-sentinel entries of ``idx``."""
    n = dst.shape[0]
    safe = jnp.where(idx >= 0, idx, n)
    return dst.at[safe].set(value, mode="drop")


# ---------------------------------------------------------------- protocol
class PolicySpec:
    """Base of the functional policy protocol (subclass + pytree_dataclass).

    Class attributes are static protocol metadata; dataclass fields are the
    knob leaves.  All methods must be pure and traceable; ``self``'s leaves
    may be traced arrays (batched sweep lanes under vmap).
    """

    name: str = "base"
    #: pages migrated per policy pass; models serial (kernel-thread) vs
    #: batched (Nimble/ARMS) migration mechanisms.  Specs that sweep shape-
    #: relevant knobs keep this a static meta field instead.
    migration_limit: int = 10 ** 9
    #: observed counts are TRUE counts (oracle upper bound), not PEBS samples
    wants_true_counts: bool = False
    #: per-slow-access application overhead of the policy mechanism (TPP
    #: NUMA hint faults); charged by both engines.
    slow_access_extra_ns: float = 0.0
    #: whether sampling_period/mode depend on runtime state (ARMS) or are
    #: constant per spec (every baseline).
    dynamic_sampling_period: bool = False
    has_mode: bool = False
    #: specs that see and target the tier vector directly implement
    #: ``tier_policy`` and set this; binary specs reach the targeted
    #: executor through the base shim (module docstring).
    tier_native: bool = False
    #: specs whose LANES mix observation kinds (simulator/fabric.py union
    #: specs: some lanes want true counts, some sampled; some carry a
    #: per-lane mechanism overhead).  The scan engine then consults the
    #: per-lane hooks below instead of the class-level flags.
    mixed_observation: bool = False

    DEFAULT_SAMPLE_PERIOD = 10_000.0

    # --- static shape contract -------------------------------------------
    def pad_promote(self, n: int, k: int) -> int:
        return max(1, min(n, self.migration_limit))

    def pad_demote(self, n: int, k: int) -> int:
        return max(1, min(n, self.migration_limit))

    def pad_moves(self, n: int, k: int) -> int:
        """Width of the tier-native ``pages``/``dst`` arrays (down-moves
        first, then up-moves — the shim's concatenation layout)."""
        return self.pad_demote(n, k) + self.pad_promote(n, k)

    # --- pure functions over pytree state --------------------------------
    def init(self, n_pages: int, k: int, machine):
        raise NotImplementedError

    def observe(self, state, observed):
        """Cheap per-interval accumulation (counts, faults, buffers)."""
        return state

    def fires(self, state):
        """Scalar bool: does the (expensive) policy pass run this interval?"""
        return jnp.asarray(True)

    def sampling_period(self, state):
        return jnp.float32(self.DEFAULT_SAMPLE_PERIOD)

    def min_sampling_period(self) -> float:
        """Host-side lower bound on the sampling period (static shapes)."""
        return float(self.DEFAULT_SAMPLE_PERIOD)

    def mode_of(self, state):
        """Controller mode for the SimResult timeline (ARMS; 0 elsewhere)."""
        return jnp.zeros((), jnp.int32)

    # --- per-lane hooks (``mixed_observation`` specs only) ----------------
    def wants_true_lane(self):
        """Scalar bool: does THIS lane observe true counts (oracle lanes
        of a union spec)?  Only consulted when ``mixed_observation``."""
        return jnp.asarray(type(self).wants_true_counts)

    def slow_extra_lane(self):
        """Scalar f32: this lane's per-slow-access overhead in ns (TPP
        lanes of a union spec).  Only consulted when ``mixed_observation``;
        0.0 lanes add a bitwise no-op (+0.0) to the wall term."""
        return jnp.float32(type(self).slow_access_extra_ns)

    def policy(self, state, slow_bw, app_bw, k: int):
        """-> (state, promote, demote): the full policy pass.

        ``promote``/``demote`` follow the padded-index contract (module
        docstring).  Only called on intervals where ``fires(state)``.
        """
        raise NotImplementedError

    def step(self, state, observed, slow_bw, app_bw, k: int):
        """Reference composition: observe, then cond(fires) around policy."""
        n = observed.shape[0]
        state = self.observe(state, observed)
        pad_p, pad_d = self.pad_promote(n, k), self.pad_demote(n, k)

        def fire(s):
            return self.policy(s, slow_bw, app_bw, k)

        def skip(s):
            return (s, jnp.full((pad_p,), SENTINEL, jnp.int32),
                    jnp.full((pad_d,), SENTINEL, jnp.int32))

        return jax.lax.cond(self.fires(state), fire, skip, state)

    # --- tier-native contract --------------------------------------------
    def tier_policy(self, state, tier_util, slow_bw, app_bw, k: int, caps):
        """-> (state, pages, dst): tier-targeted moves (module docstring).

        Base implementation is the BINARY SHIM: run the classic
        promote/demote pass and emit demotions (dst=DST_BELOW, the
        hop-chain cascade) followed by promotions (dst=0).  Executed
        through ``simjax.apply_targeted_migrations`` this is bitwise the
        hop-chain path, for every binary policy.
        """
        state, promote, demote = self.policy(state, slow_bw, app_bw, k)
        pages = jnp.concatenate([demote, promote])
        dst = jnp.concatenate(
            [jnp.full(demote.shape, DST_BELOW, jnp.int32),
             jnp.zeros(promote.shape, jnp.int32)])
        return state, pages, dst

    def step_tiers(self, state, observed, tier_util, slow_bw, app_bw,
                   k: int, caps):
        """Reference composition of the tier-native contract: observe,
        then cond(fires) around ``tier_policy`` (numpy-engine path)."""
        n = observed.shape[0]
        state = self.observe(state, observed)
        pm = self.pad_moves(n, k)

        def fire(s):
            return self.tier_policy(s, tier_util, slow_bw, app_bw, k, caps)

        def skip(s):
            return (s, jnp.full((pm,), SENTINEL, jnp.int32),
                    jnp.zeros((pm,), jnp.int32))

        return jax.lax.cond(self.fires(state), fire, skip, state)


def capacity_victims(in_fast, cold_key, cold_mask, n_want, k: int, pad_d: int,
                     extra_need=0):
    """Shared victim selection: free slots, then coldest-first demotions.

    Returns (victims, n_victims, n_take) where ``n_take`` caps the
    promotion list at ``free + n_victims`` (the engines never exceed
    capacity, so a policy that respects this bound sees every request
    executed and its internal residency belief stays exact).
    """
    free = (k - in_fast.sum()).astype(jnp.int32)
    need = jnp.maximum(jnp.maximum(n_want - free, extra_need), 0)
    victims, n_vict = ranked_take(cold_key, cold_mask, pad_d, need)
    n_take = jnp.minimum(n_want, free + n_vict)
    return victims, n_vict, n_take


# ------------------------------------------------ tier-native plan helpers
def rank_desc(score):
    """Dense 0-based rank of each page under DESCENDING score (rank 0 =
    hottest; ties break by ascending page index — argsort is stable)."""
    n = score.shape[0]
    order = jnp.argsort(-score.astype(jnp.float32))
    return jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))


def rank_partition(rank, caps):
    """Per-tier scores -> target placement: fill tiers shallowest-first by
    rank against the capacity ladder (page with rank < caps[0] targets
    tier 0, the next caps[1] ranks tier 1, ...).  Zero-capacity padded
    tiers are skipped automatically.  Returns i32 [n] target tiers."""
    cum = jnp.cumsum(caps)
    return jnp.sum(rank[:, None] >= cum[None, :-1], axis=1).astype(jnp.int32)


def pair_limit(lo, hi, valid, budgets):
    """Per-pair budget filter over a priority-ordered move list.

    Entry i crosses adjacent pairs ``lo[i] <= j < hi[i]``; it survives iff
    for EVERY crossed pair fewer than ``budgets[j]`` earlier valid entries
    cross that pair.  Counting earlier candidates (not earlier survivors)
    keeps the filter one vectorized pass per pair; it is conservative —
    never over budget, occasionally under when an earlier move was itself
    dropped by a different pair.  Returns the surviving-entry mask.
    """
    ok = valid
    for j in range(budgets.shape[0]):
        crosses = valid & (lo <= j) & (j < hi)
        rank = jnp.cumsum(crosses.astype(jnp.int32)) - 1
        ok = ok & (~crosses | (rank < budgets[j]))
    return ok


def tier_plan(score, cur, target, caps, budgets, pad_down: int, pad_up: int):
    """Feasible tier-targeted moves from a desired placement.

    ``score`` f32 [n] per-page hotness, ``cur`` i32 [n] the policy's
    residency belief, ``target`` i32 [n] the desired placement (e.g. from
    ``rank_partition``), ``caps`` i32 [R], ``budgets`` i32 [R-1] per-pair
    migration budgets (scheduler.pair_budgets).  Returns (pages, dst,
    new_cur): a ``pad_down + pad_up``-wide sentinel-padded move list —
    down-moves first (coldest-first), then up-moves (hottest-first) —
    that ``simjax.apply_targeted_migrations`` is GUARANTEED to execute
    verbatim (down-moves land exactly at their target, up-moves are all
    admitted), because admission here mirrors the executor's order:
    budgets first, then capacity bottom-up for downs / shallowest-first
    for ups with departures freeing slots.  ``new_cur`` therefore stays
    an exact belief of the engine-side placement.
    """
    i32 = jnp.int32
    R = caps.shape[0]
    n = score.shape[0]
    target = jnp.clip(target, 0, R - 1)
    occ = jnp.stack([(cur == r).sum() for r in range(R)]).astype(i32)

    # down-moves: coldest-first, budget-filtered, then capacity-admitted
    # bottom-up (deeper targets admit first; their departures free slots
    # for shallower targets — the executor sees the same order).
    d_pages, _ = ranked_take(score, target > cur, pad_down)
    d_safe = jnp.where(d_pages >= 0, d_pages, 0)
    d_valid = d_pages >= 0
    d_cur = jnp.where(d_valid, cur[d_safe], 0)
    d_tgt = jnp.where(d_valid, target[d_safe], R - 1)
    d_ok = pair_limit(d_cur, d_tgt, d_valid, budgets)
    adm_d = jnp.zeros(d_pages.shape, bool)
    for r in range(R - 1, 0, -1):
        dep = (adm_d & (d_cur == r)).sum().astype(i32)
        room = caps[r] - occ[r] + dep
        cand = d_ok & (d_tgt == r) & (~adm_d)
        rank = jnp.cumsum(cand.astype(i32)) - 1
        adm_d = adm_d | (cand & (rank < room))
    d_pages = jnp.where(adm_d, d_pages, SENTINEL)
    rem = jnp.stack([
        budgets[j] - (adm_d & (d_cur <= j) & (j < d_tgt)).sum().astype(i32)
        for j in range(R - 1)])
    rem = jnp.maximum(rem, 0)
    occ2 = occ + jnp.stack([
        (adm_d & (d_tgt == r)).sum() - (adm_d & (d_cur == r)).sum()
        for r in range(R)]).astype(i32)

    # up-moves: hottest-first, remaining budgets, capacity-admitted
    # shallowest-destination-first against the post-down occupancy.
    u_pages, _ = ranked_take(-score, target < cur, pad_up)
    u_safe = jnp.where(u_pages >= 0, u_pages, 0)
    u_valid = u_pages >= 0
    u_cur = jnp.where(u_valid, cur[u_safe], 0)
    u_tgt = jnp.where(u_valid, target[u_safe], 0)
    u_ok = pair_limit(u_tgt, u_cur, u_valid, rem)
    adm_u = jnp.zeros(u_pages.shape, bool)
    for r in range(R - 1):
        dep = (adm_u & (u_cur == r)).sum().astype(i32)
        room = caps[r] - occ2[r] + dep
        cand = u_ok & (u_tgt == r) & (~adm_u)
        rank = jnp.cumsum(cand.astype(i32)) - 1
        adm_u = adm_u | (cand & (rank < room))
    u_pages = jnp.where(adm_u, u_pages, SENTINEL)

    new_cur = cur.at[jnp.where(adm_d, d_pages, n)].set(
        d_tgt, mode="drop")
    new_cur = new_cur.at[jnp.where(adm_u, u_pages, n)].set(
        u_tgt, mode="drop")
    pages = jnp.concatenate([d_pages, u_pages])
    dst = jnp.concatenate([d_tgt, u_tgt])
    return pages, dst, new_cur


# ----------------------------------------------------------- legacy bridge
@functools.partial(jax.jit, static_argnames=("k",))
def _protocol_step(spec, state, observed, slow_bw, app_bw, k: int):
    return spec.step(state, observed, slow_bw, app_bw, k)


@functools.partial(jax.jit, static_argnames=("k",))
def _protocol_step_tiers(spec, state, observed, tier_util, slow_bw, app_bw,
                         k: int, caps):
    return spec.step_tiers(state, observed, tier_util, slow_bw, app_bw,
                           k, caps)


class LegacyPolicyAdapter(Policy):
    """A functional ``PolicySpec`` exposed as a stateful numpy-engine Policy.

    The adapter holds the pytree state between intervals and calls the
    spec's jitted ``step`` once per interval; padded outputs are converted
    to the engine's variable-length index lists by dropping sentinels (order
    preserved).  Decisions are therefore bitwise-identical to the compiled
    scan engine's — the basis of the cross-engine equivalence tests.
    """

    def __init__(self, spec: PolicySpec):
        self.spec = spec
        self.name = spec.name
        self.slow_access_extra_ns = spec.slow_access_extra_ns

    def reset(self, n_pages, k, machine):
        self.n, self.k = n_pages, k
        self.state = self.spec.init(n_pages, k, machine)
        self._period = float(self.spec.sampling_period(self.state))

    def sampling_period(self):
        return self._period

    def wants_true_counts(self):
        return self.spec.wants_true_counts

    @property
    def mode(self) -> int:
        if not type(self.spec).has_mode:
            return 0
        return int(self.spec.mode_of(self.state))

    @property
    def tier_native(self) -> bool:
        return type(self.spec).tier_native

    def step(self, observed, slow_bw_frac, app_bw_frac):
        self.state, promote, demote = _protocol_step(
            self.spec, self.state, jnp.asarray(observed, jnp.float32),
            jnp.float32(slow_bw_frac), jnp.float32(app_bw_frac), self.k)
        if type(self.spec).dynamic_sampling_period:
            self._period = float(self.spec.sampling_period(self.state))
        promote = np.asarray(promote, np.int64)
        demote = np.asarray(demote, np.int64)
        return promote[promote >= 0], demote[demote >= 0]

    def step_tiers(self, observed, slow_bw_frac, app_bw_frac, tier_util,
                   caps):
        """Tier-native interval: -> (pages, dst) aligned i64 arrays with
        sentinels dropped (priority order preserved)."""
        self.state, pages, dst = _protocol_step_tiers(
            self.spec, self.state, jnp.asarray(observed, jnp.float32),
            jnp.asarray(tier_util, jnp.float32),
            jnp.float32(slow_bw_frac), jnp.float32(app_bw_frac), self.k,
            jnp.asarray(caps, jnp.int32))
        if type(self.spec).dynamic_sampling_period:
            self._period = float(self.spec.sampling_period(self.state))
        pages = np.asarray(pages, np.int64)
        dst = np.asarray(dst, np.int64)
        keep = pages >= 0
        return pages[keep], dst[keep]
