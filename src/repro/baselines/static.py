"""Static placements: the all-slow baseline (paper Fig. 1 normalization) and
an oracle upper bound (true-count top-k, instant migration)."""
from __future__ import annotations

import numpy as np

from repro.baselines.base import Policy


class AllSlowPolicy(Policy):
    name = "all-slow"

    def reset(self, n_pages, k, machine):
        pass

    def step(self, observed, slow_bw_frac, app_bw_frac):
        return np.empty(0, np.int64), np.empty(0, np.int64)


class OraclePolicy(Policy):
    """Sees TRUE access counts and rebalances instantly — an upper bound on
    any sampling-based policy (migration traffic still charged)."""

    name = "oracle"
    migration_limit = 10**9

    def reset(self, n_pages, k, machine):
        self.n, self.k = n_pages, k
        self.in_fast = np.zeros(n_pages, bool)

    def wants_true_counts(self):
        return True

    def step(self, observed, slow_bw_frac, app_bw_frac):
        order = np.argsort(observed)[::-1]
        target = np.zeros(self.n, bool)
        target[order[: self.k]] = True
        promote = np.flatnonzero(target & ~self.in_fast)
        demote = np.flatnonzero(~target & self.in_fast)[: len(promote)]
        self.in_fast = target
        return promote, demote
