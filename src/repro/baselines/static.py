"""Static placements: the all-slow baseline (paper Fig. 1 normalization) and
an oracle upper bound (true-count top-k, instant migration)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.baselines.protocol import (LegacyPolicyAdapter, PolicySpec,
                                      ranked_take)
from repro.utils.pytree import pytree_dataclass


@pytree_dataclass
class StaticState:
    t: jnp.ndarray            # i32


@pytree_dataclass
class AllSlowSpec(PolicySpec):
    name = "all-slow"

    def init(self, n_pages, k, machine):
        return StaticState(t=jnp.zeros((), jnp.int32))

    def observe(self, state, observed):
        return state.replace(t=state.t + 1)

    def fires(self, state):
        return jnp.asarray(False)

    def pad_promote(self, n, k):
        return 1

    def pad_demote(self, n, k):
        return 1

    def policy(self, state, slow_bw, app_bw, k):
        empty = jnp.full((1,), -1, jnp.int32)
        return state, empty, empty


@pytree_dataclass
class OracleState:
    in_fast: jnp.ndarray      # bool [n]
    last_obs: jnp.ndarray     # f32 [n] this interval's TRUE counts
    t: jnp.ndarray            # i32


@pytree_dataclass
class OracleSpec(PolicySpec):
    """Sees TRUE access counts and rebalances instantly — an upper bound on
    any sampling-based policy (migration traffic still charged)."""

    name = "oracle"
    wants_true_counts = True

    def pad_promote(self, n, k):
        return max(1, min(n, k))

    def pad_demote(self, n, k):
        return max(1, min(n, k))

    def init(self, n_pages, k, machine):
        return OracleState(
            in_fast=jnp.zeros((n_pages,), bool),
            last_obs=jnp.zeros((n_pages,), jnp.float32),
            t=jnp.zeros((), jnp.int32))

    def observe(self, state, observed):
        return state.replace(last_obs=observed, t=state.t + 1)

    def policy(self, state, slow_bw, app_bw, k):
        n = state.last_obs.shape[0]
        _, top = jax.lax.top_k(state.last_obs, k)     # desc, ties by index
        target = jnp.zeros((n,), bool).at[top].set(True)
        idx = jnp.arange(n, dtype=jnp.int32)
        promote, n_p = ranked_take(idx, target & ~state.in_fast,
                                   self.pad_promote(n, k))
        demote, _ = ranked_take(idx, ~target & state.in_fast,
                                self.pad_demote(n, k), n_p)
        return state.replace(in_fast=target), promote, demote


class AllSlowPolicy(LegacyPolicyAdapter):
    def __init__(self):
        super().__init__(AllSlowSpec())


class OraclePolicy(LegacyPolicyAdapter):
    def __init__(self):
        super().__init__(OracleSpec())
