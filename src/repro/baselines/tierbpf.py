"""TierBPF-style baseline — migration admission control (tier-native).

TierBPF (PAPERS.md) argues the migration *mechanism* should be guarded by
an admission controller: promotions are only admitted above a hotness
bar, and the migration budget backs off when recent promotions turn out
to be regretted (the promoted pages are headed back down next pass — the
thrashing signature).  This spec implements that controller on the
tier-native contract:

  * per-page EWMA hotness ranks pages against the capacity ladder;
  * ``admit_thresh`` gates promotions — a page below the bar stays put
    no matter its rank;
  * a regret estimate (EWMA of the fraction of last pass's up-moves whose
    target flipped back down) scales every pair budget by
    ``1 - thrash_gain * regret`` — sustained thrash throttles migration
    traffic toward zero instead of burning hop bandwidth.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.baselines.protocol import (LegacyPolicyAdapter, PolicySpec,
                                      rank_desc, rank_partition, tier_plan)
from repro.core.scheduler import pair_budgets
from repro.utils.pytree import pytree_dataclass

DEFAULTS = dict(alpha=0.5, admit_thresh=2.0, thrash_gain=2.0,
                regret_alpha=0.3, migration_period=2,
                sample_period=10_000.0)


@pytree_dataclass
class TierBPFState:
    ewma: jnp.ndarray        # f32 [n]
    tier: jnp.ndarray        # i32 [n] residency belief
    up_at: jnp.ndarray       # i32 [n] pass index of the page's last up-move
    regret: jnp.ndarray      # f32 scalar: recent-promotion regret estimate
    passes: jnp.ndarray      # i32
    t: jnp.ndarray           # i32


@pytree_dataclass(meta=("bs_max",))
class TierBPFSpec(PolicySpec):
    alpha: jnp.ndarray             # hotness EWMA weight
    admit_thresh: jnp.ndarray      # min EWMA hotness to admit a promotion
    thrash_gain: jnp.ndarray       # budget backoff per unit regret
    regret_alpha: jnp.ndarray      # regret-estimate EWMA weight
    migration_period: jnp.ndarray  # i32
    sample_period: jnp.ndarray
    bs_max: int = 128

    name = "tierbpf"
    tier_native = True

    @classmethod
    def make(cls, alpha=None, admit_thresh=None, thrash_gain=None,
             regret_alpha=None, migration_period=None, sample_period=None,
             bs_max: int = 128) -> "TierBPFSpec":
        pick = lambda v, key: DEFAULTS[key] if v is None else v
        return cls(
            alpha=jnp.float32(pick(alpha, "alpha")),
            admit_thresh=jnp.float32(pick(admit_thresh, "admit_thresh")),
            thrash_gain=jnp.float32(pick(thrash_gain, "thrash_gain")),
            regret_alpha=jnp.float32(pick(regret_alpha, "regret_alpha")),
            migration_period=jnp.int32(
                pick(migration_period, "migration_period")),
            sample_period=jnp.float32(pick(sample_period, "sample_period")),
            bs_max=bs_max)

    def pad_promote(self, n: int, k: int) -> int:
        return max(1, min(n, 2 * self.bs_max))

    def pad_demote(self, n: int, k: int) -> int:
        return max(1, min(n, 2 * self.bs_max))

    def init(self, n_pages, k, machine):
        R = machine.lat_ns.shape[-1]
        return TierBPFState(
            ewma=jnp.zeros((n_pages,), jnp.float32),
            tier=jnp.full((n_pages,), R - 1, jnp.int32),
            up_at=jnp.full((n_pages,), -(10 ** 6), jnp.int32),
            regret=jnp.zeros((), jnp.float32),
            passes=jnp.zeros((), jnp.int32),
            t=jnp.zeros((), jnp.int32))

    def sampling_period(self, state):
        return jnp.asarray(self.sample_period, jnp.float32)

    def min_sampling_period(self):
        return float(np.min(np.asarray(self.sample_period)))

    def observe(self, state, observed):
        a = jnp.clip(self.alpha, 0.0, 1.0)
        return state.replace(ewma=(1 - a) * state.ewma + a * observed,
                             t=state.t + 1)

    def fires(self, state):
        period = jnp.maximum(self.migration_period.astype(jnp.int32), 1)
        return (state.t % period) == 0

    def tier_policy(self, state, tier_util, slow_bw, app_bw, k, caps):
        f32 = jnp.float32
        n = state.ewma.shape[0]
        p = state.passes + 1
        raw = rank_partition(rank_desc(state.ewma), caps)
        # regret: of the pages promoted LAST pass, how many does the
        # ranking already want back down?  EWMA-smoothed, it throttles the
        # budgets — the admission-control half of the policy.
        recent = state.up_at == (p - 1)
        flip = (recent & (raw > state.tier)).sum().astype(f32)
        regret_now = flip / jnp.maximum(recent.sum().astype(f32), 1.0)
        ra = jnp.clip(self.regret_alpha, 0.0, 1.0)
        regret = (1 - ra) * state.regret + ra * regret_now
        scale = jnp.clip(1.0 - self.thrash_gain * regret, 0.0, 1.0)
        budgets = pair_budgets(tier_util, self.bs_max)
        budgets = jnp.maximum(
            jnp.floor(budgets.astype(f32) * scale).astype(jnp.int32), 1)
        # admission gate: un-hot pages are never promoted, whatever their
        # rank says this pass.
        tgt = jnp.where((raw < state.tier)
                        & (state.ewma < self.admit_thresh),
                        state.tier, raw)
        pages, dst, tier = tier_plan(
            state.ewma, state.tier, tgt, caps, budgets,
            self.pad_demote(n, k), self.pad_promote(n, k))
        up_at = jnp.where(tier < state.tier, p, state.up_at)
        return (state.replace(tier=tier, up_at=up_at, regret=regret,
                              passes=p), pages, dst)


class TierBPFPolicy(LegacyPolicyAdapter):
    """TierBPF for the numpy reference engine (functional spec inside)."""

    def __init__(self, alpha=None, admit_thresh=None, thrash_gain=None,
                 regret_alpha=None, migration_period=None,
                 sample_period=None):
        super().__init__(TierBPFSpec.make(
            alpha, admit_thresh, thrash_gain, regret_alpha,
            migration_period, sample_period))
