"""HybridTier-style baseline — lightweight frequency-based CXL tiering,
the first policy speaking the TIER-NATIVE contract (protocol docstring).

HybridTier (PAPERS.md) places pages by decayed access-frequency counters
across the whole DRAM/CXL/far-tier chain instead of a binary hot/cold
split: the counter ranking is partitioned against the per-tier capacity
ladder, frequency thresholds gate entry to the fast tier (no promotion on
a single hot sample) and sink cold pages to the bottom, and per-pair
migration budgets back off from whichever tier of a hop is the bandwidth
bottleneck (``scheduler.pair_budgets`` on the engine's per-tier
utilization signal).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.baselines.protocol import (LegacyPolicyAdapter, PolicySpec,
                                      rank_desc, rank_partition, tier_plan)
from repro.core.scheduler import pair_budgets
from repro.utils.pytree import pytree_dataclass

DEFAULTS = dict(hot_thresh=6.0, warm_thresh=1.0, decay=0.7,
                migration_period=4, sample_period=10_000.0)


@pytree_dataclass
class HybridTierState:
    counts: jnp.ndarray    # f32 [n] decayed access-frequency counters
    tier: jnp.ndarray      # i32 [n] residency belief over the whole chain
    t: jnp.ndarray         # i32


@pytree_dataclass(meta=("bs_max",))
class HybridTierSpec(PolicySpec):
    hot_thresh: jnp.ndarray        # min frequency to enter the fast tier
    warm_thresh: jnp.ndarray       # below this, sink to the bottom tier
    decay: jnp.ndarray             # per-interval counter decay in (0, 1]
    migration_period: jnp.ndarray  # i32 intervals between passes
    sample_period: jnp.ndarray
    bs_max: int = 128

    name = "hybridtier"
    tier_native = True

    @classmethod
    def make(cls, hot_thresh=None, warm_thresh=None, decay=None,
             migration_period=None, sample_period=None,
             bs_max: int = 128) -> "HybridTierSpec":
        pick = lambda v, key: DEFAULTS[key] if v is None else v
        return cls(
            hot_thresh=jnp.float32(pick(hot_thresh, "hot_thresh")),
            warm_thresh=jnp.float32(pick(warm_thresh, "warm_thresh")),
            decay=jnp.float32(pick(decay, "decay")),
            migration_period=jnp.int32(
                pick(migration_period, "migration_period")),
            sample_period=jnp.float32(pick(sample_period, "sample_period")),
            bs_max=bs_max)

    # pad width per direction; budgets (<= bs_max per pair) cap the number
    # of moves the plan can admit anyway.
    def pad_promote(self, n: int, k: int) -> int:
        return max(1, min(n, 2 * self.bs_max))

    def pad_demote(self, n: int, k: int) -> int:
        return max(1, min(n, 2 * self.bs_max))

    def init(self, n_pages, k, machine):
        R = machine.lat_ns.shape[-1]
        return HybridTierState(
            counts=jnp.zeros((n_pages,), jnp.float32),
            tier=jnp.full((n_pages,), R - 1, jnp.int32),
            t=jnp.zeros((), jnp.int32))

    def sampling_period(self, state):
        return jnp.asarray(self.sample_period, jnp.float32)

    def min_sampling_period(self):
        return float(np.min(np.asarray(self.sample_period)))

    def observe(self, state, observed):
        return state.replace(counts=state.counts * self.decay + observed,
                             t=state.t + 1)

    def fires(self, state):
        period = jnp.maximum(self.migration_period.astype(jnp.int32), 1)
        return (state.t % period) == 0

    def tier_policy(self, state, tier_util, slow_bw, app_bw, k, caps):
        n = state.counts.shape[0]
        R = caps.shape[0]
        tgt = rank_partition(rank_desc(state.counts), caps)
        # promotion gate: only frequency-hot pages may enter the fast tier
        # (a single hot sample is not enough — the HybridTier argument).
        tgt = jnp.where((tgt == 0) & (state.tier > 0)
                        & (state.counts < self.hot_thresh),
                        state.tier, tgt)
        # cold pages sink to the bottom regardless of rank.
        tgt = jnp.where(state.counts < self.warm_thresh, R - 1, tgt)
        budgets = pair_budgets(tier_util, self.bs_max)
        pages, dst, tier = tier_plan(
            state.counts, state.tier, tgt, caps, budgets,
            self.pad_demote(n, k), self.pad_promote(n, k))
        return state.replace(tier=tier), pages, dst


class HybridTierPolicy(LegacyPolicyAdapter):
    """HybridTier for the numpy reference engine (functional spec inside)."""

    def __init__(self, hot_thresh=None, warm_thresh=None, decay=None,
                 migration_period=None, sample_period=None):
        super().__init__(HybridTierSpec.make(
            hot_thresh, warm_thresh, decay, migration_period, sample_period))
