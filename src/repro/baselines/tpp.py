"""TPP baseline (Maruf et al., ASPLOS'23) — recency/fault-based promotion.

TPP instruments slow-tier pages with NUMA hint faults: a page is promoted
once it faults twice.  Faults are CUMULATIVE (the kernel keeps no frequency
history), so merely-warm pages eventually cross the 2-fault bar — hot and
warm pages are indistinguishable (paper §7.1), which at skewed fast:slow
ratios (1:8) yields continuous promotion pressure and an "extremely high
number of migrations".  Demotion takes from the tail of an approximated
inactive LRU list; at 2 MB granularity and sampled visibility this list is
noisy, so genuinely hot pages get evicted.  Hint faults themselves cost the
application latency on slow-tier accesses (``slow_access_extra_ns``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.baselines.protocol import (LegacyPolicyAdapter, PolicySpec,
                                      capacity_victims, ranked_take,
                                      scatter_set, truncate_ranked)
from repro.utils.pytree import pytree_dataclass

DEFAULTS = dict(promote_hits=2.0, watermark=0.98)


@pytree_dataclass
class TPPState:
    in_fast: jnp.ndarray      # bool [n]
    faults: jnp.ndarray       # f32 [n] cumulative hint faults
    last_access: jnp.ndarray  # i32 [n] last *sampled* access interval
    t: jnp.ndarray            # i32


@pytree_dataclass(meta=("migration_limit",))
class TPPSpec(PolicySpec):
    promote_hits: jnp.ndarray
    watermark: jnp.ndarray
    migration_limit: int = 12

    name = "tpp"
    slow_access_extra_ns = 60.0   # NUMA hint-fault + TLB-shootdown amortized

    @classmethod
    def make(cls, promote_hits=None, watermark=None,
             migration_limit: int = 12) -> "TPPSpec":
        pick = lambda v, key: DEFAULTS[key] if v is None else v
        return cls(promote_hits=jnp.float32(pick(promote_hits,
                                                 "promote_hits")),
                   watermark=jnp.float32(pick(watermark, "watermark")),
                   migration_limit=migration_limit)

    def pad_demote(self, n, k):
        # watermark free-target demotions can exceed migration_limit; the
        # victim count is still bounded by the fast-tier population.
        return max(1, min(n, k))

    def init(self, n_pages, k, machine):
        return TPPState(
            in_fast=jnp.zeros((n_pages,), bool),
            faults=jnp.zeros((n_pages,), jnp.float32),
            last_access=jnp.zeros((n_pages,), jnp.int32),
            t=jnp.zeros((), jnp.int32))

    def observe(self, state, observed):
        t = state.t + 1
        # hint faults only occur on slow-tier pages (fast pages are mapped).
        faults = state.faults + jnp.where(state.in_fast, 0.0,
                                          jnp.minimum(observed, 4.0))
        last_access = jnp.where(observed > 0, t, state.last_access)
        return state.replace(faults=faults, last_access=last_access, t=t)

    def policy(self, state, slow_bw, app_bw, k):
        n = state.faults.shape[0]
        eligible = (state.faults >= self.promote_hits) & ~state.in_fast
        # fault-arrival order approximation: the kernel processes faults in
        # arrival order, which under sampling is effectively arbitrary ->
        # index rotation (clock) starting at a per-interval offset.
        start = (state.t * 97) % n
        clock = (jnp.arange(n, dtype=jnp.int32) - start) % n
        want, n_want = ranked_take(clock, eligible,
                                   self.pad_promote(n, k),
                                   self.migration_limit)
        # inactive-list approximation: pages without a *sampled* access
        # recently go first; ties in stale clock (index) order.  The
        # watermark keeps a free-slot target even without promotions.
        free = (k - state.in_fast.sum()).astype(jnp.int32)
        target_free = jnp.floor((1.0 - self.watermark) * k).astype(jnp.int32)
        victims, _, n_take = capacity_victims(
            state.in_fast, state.last_access, state.in_fast, n_want, k,
            self.pad_demote(n, k), extra_need=target_free - free)
        promote = truncate_ranked(want, n_take)
        in_fast = scatter_set(state.in_fast, victims, False)
        in_fast = scatter_set(in_fast, promote, True)
        faults = state.faults.at[jnp.where(promote >= 0, promote, n)].set(
            0.0, mode="drop")
        faults = faults.at[jnp.where(victims >= 0, victims, n)].set(
            0.0, mode="drop")
        return state.replace(in_fast=in_fast, faults=faults), promote, victims


class TPPPolicy(LegacyPolicyAdapter):
    """TPP for the numpy reference engine (functional spec underneath)."""

    def __init__(self, promote_hits: float = 2.0, watermark: float = 0.98):
        super().__init__(TPPSpec.make(promote_hits, watermark))
