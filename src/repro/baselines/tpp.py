"""TPP baseline (Maruf et al., ASPLOS'23) — recency/fault-based promotion.

TPP instruments slow-tier pages with NUMA hint faults: a page is promoted
once it faults twice.  Faults are CUMULATIVE (the kernel keeps no frequency
history), so merely-warm pages eventually cross the 2-fault bar — hot and
warm pages are indistinguishable (paper §7.1), which at skewed fast:slow
ratios (1:8) yields continuous promotion pressure and an "extremely high
number of migrations".  Demotion takes from the tail of an approximated
inactive LRU list; at 2 MB granularity and sampled visibility this list is
noisy, so genuinely hot pages get evicted.  Hint faults themselves cost the
application latency on slow-tier accesses (``slow_access_extra_ns``).
"""
from __future__ import annotations

import numpy as np

from repro.baselines.base import Policy


class TPPPolicy(Policy):
    name = "tpp"
    migration_limit = 12
    slow_access_extra_ns = 60.0   # NUMA hint-fault + TLB-shootdown amortized

    def __init__(self, promote_hits: float = 2.0, watermark: float = 0.98):
        self.promote_hits = float(promote_hits)
        self.watermark = float(watermark)

    def reset(self, n_pages, k, machine):
        self.n, self.k = n_pages, k
        self.in_fast = np.zeros(n_pages, bool)
        self.faults = np.zeros(n_pages)     # cumulative hint faults
        self.last_access = np.zeros(n_pages)
        self.t = 0

    def step(self, observed, slow_bw_frac, app_bw_frac):
        self.t += 1
        # hint faults only occur on slow-tier pages (fast pages are mapped).
        self.faults += np.where(self.in_fast, 0.0, np.minimum(observed, 4.0))
        self.last_access[observed > 0] = self.t

        want = np.flatnonzero((self.faults >= self.promote_hits)
                              & ~self.in_fast)
        # fault-arrival order approximation: least-recently-promoted first is
        # unknowable; the kernel processes them in fault order, which under
        # sampling is effectively arbitrary -> index rotation (clock).
        if len(want):
            start = np.searchsorted(want, (self.t * 97) % self.n)
            want = np.roll(want, -start)[: self.migration_limit]

        victims = np.empty(0, np.int64)
        free = self.k - int(self.in_fast.sum())
        over = len(want) - free
        target_free = int((1 - self.watermark) * self.k)
        need = max(over, target_free - free, 0)
        if need > 0:
            fast_idx = np.flatnonzero(self.in_fast)
            # inactive-list approximation: pages without a *sampled* access
            # in the last interval go first; ties in stale clock order.
            idle = self.last_access[fast_idx] < self.t
            order = np.lexsort((self.last_access[fast_idx], ~idle))
            victims = fast_idx[order][:need]
        want = want[: free + len(victims)]
        self.in_fast[victims] = False
        self.in_fast[want] = True
        self.faults[want] = 0.0
        self.faults[victims] = 0.0
        return want, victims
