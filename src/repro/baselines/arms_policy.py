"""ARMS wrapped as a simulator policy (the paper's system, §4-5).

Bridges the pure-JAX controller into the numpy simulation loop: accumulates
sampled counts between policy invocations (500 ms / 100 ms cadence expressed
in 100 ms simulator intervals), feeds slow-tier bandwidth to the PHT, and
executes the bandwidth-aware batched migration plan.
"""
from __future__ import annotations

import numpy as np

from repro.baselines.base import Policy
from repro.core import (ARMSConfig, arms_step, init_state, policy_every,
                        sampling_period)
from repro.core.scheduler import observe_migration_cost
from repro.simulator import machine as machine_mod


class ARMSPolicy(Policy):
    name = "arms"

    def __init__(self, cfg: ARMSConfig | None = None):
        self.base_cfg = cfg or ARMSConfig()

    @property
    def migration_limit(self):  # batched migrations: up to BS_max per pass
        return self.base_cfg.bs_max

    def reset(self, n_pages, k, machine):
        self.n, self.k = n_pages, k
        self.cfg = self.base_cfg
        self.state = init_state(n_pages, self.cfg)
        self.buf = np.zeros(n_pages)
        self.t = 0
        self._machine = machine
        self._promo_us = machine_mod.promo_page_us(machine)
        self._demo_us = machine_mod.demo_page_us(machine)

    def sampling_period(self):
        return float(sampling_period(self.state.mode))

    def step(self, observed, slow_bw_frac, app_bw_frac):
        self.t += 1
        self.buf += observed
        every = int(policy_every(self.state.mode))
        if self.t % every:
            return np.empty(0, np.int64), np.empty(0, np.int64)

        # normalize accumulated counts to per-interval rate so the EWMA scale
        # is mode-independent (500ms vs 100ms policy cadence, §5).
        self.state, plan = arms_step(
            self.state, self.buf / every, float(slow_bw_frac),
            float(app_bw_frac), cfg=self.cfg, k=self.k)
        self.buf[:] = 0.0

        valid = np.asarray(plan.valid)
        promote = np.asarray(plan.promote)[valid]
        demote = np.asarray(plan.demote)[valid]
        demote = demote[demote >= 0]
        if len(promote):   # §4.3: self-calibrating migration-cost feedback
            self.state = observe_migration_cost(
                self.state, self._promo_us, self._demo_us, self.cfg)
        return promote.astype(np.int64), demote.astype(np.int64)

    @property
    def mode(self) -> int:
        return int(self.state.mode)
