"""ARMS as a simulator policy (the paper's system, §4-5), in both forms:

* ``ARMSSpec`` — the functional-protocol spec (baselines/protocol.py): pure
  init/observe/fires/policy over pytree state, with the ARMSConfig float
  knobs under sweep (``cfg_names``/``cfg_vals``) living as traceable leaves
  so a whole tuning grid runs lane-batched in the compiled scan engine.
* ``ARMSPolicy`` — the hand-tuned stateful wrapper for the numpy reference
  engine.  It predates ``LegacyPolicyAdapter`` and stays separate because
  ARMS's sampling period / cadence are mode-dependent: the generic adapter
  would poll them from device state every interval, while this wrapper
  caches them on the HOST and refreshes once per policy invocation (mode
  only changes inside ``arms_step``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.base import Policy
from repro.baselines.protocol import PolicySpec
from repro.core import ARMSConfig, arms_step, init_state
from repro.core.controller import (MODE_SAMPLING_PERIODS,
                                   POLICY_EVERY_HISTORY, POLICY_EVERY_RECENCY,
                                   SAMPLING_PERIOD_HISTORY,
                                   SAMPLING_PERIOD_RECENCY, arms_step_impl,
                                   policy_every, sampling_period)
from repro.core.scheduler import observe_migration_cost
from repro.core.state import MODE_HISTORY, MODE_RECENCY, TieringState
from repro.utils.pytree import pytree_dataclass

# ARMSConfig float knobs that may be batched (traced) in a config sweep.
# Shape-determining ints (bs_max) and the kernel flag must stay static.
SWEEPABLE = frozenset({
    "alpha_s", "alpha_l", "w_s_history", "w_l_history", "w_s_recency",
    "w_l_recency", "pht_delta", "pht_lambda", "stabilize_eps", "noise_z",
    "latency_fast_us", "latency_slow_us", "access_scale",
    "migrate_cost_alpha", "init_promo_cost_us", "init_demo_cost_us",
})


@pytree_dataclass
class ARMSRunState:
    inner: TieringState
    buf: jnp.ndarray       # f32 [n] counts accumulated since last policy run
    t: jnp.ndarray         # i32 simulator-interval counter
    promo_us: jnp.ndarray  # f32 measured per-page migration latencies for
    demo_us: jnp.ndarray   # the §4.3 self-calibration feedback


@pytree_dataclass(meta=("cfg_names", "base_cfg"))
class ARMSSpec(PolicySpec):
    """Functional-protocol ARMS.  ``cfg_vals[i]`` overrides ARMSConfig field
    ``cfg_names[i]`` — the overridden floats are pytree leaves, so sweep
    lanes batch over them while ``base_cfg`` (and every shape-determining
    int) stays static."""

    cfg_vals: jnp.ndarray = dataclasses.field(
        default_factory=lambda: jnp.zeros((0,), jnp.float32))
    cfg_names: tuple = ()
    base_cfg: ARMSConfig = ARMSConfig()

    name = "arms"
    dynamic_sampling_period = True
    has_mode = True
    #: mode-indexed sampling periods for precomputed CRN observation grids
    PRE_PERIODS = MODE_SAMPLING_PERIODS

    @classmethod
    def make(cls, overrides: dict | None = None,
             base_cfg: ARMSConfig | None = None) -> "ARMSSpec":
        overrides = overrides or {}
        bad = set(overrides) - SWEEPABLE
        if bad:
            raise ValueError(
                f"non-sweepable ARMSConfig fields {sorted(bad)}; sweepable: "
                f"{sorted(SWEEPABLE)}")
        names = tuple(sorted(overrides))
        vals = jnp.asarray([float(overrides[nm]) for nm in names],
                           jnp.float32)
        return cls(cfg_vals=vals, cfg_names=names,
                   base_cfg=base_cfg or ARMSConfig())

    def cfg(self) -> ARMSConfig:
        if not self.cfg_names:
            return self.base_cfg
        return dataclasses.replace(
            self.base_cfg,
            **{nm: self.cfg_vals[i] for i, nm in enumerate(self.cfg_names)})

    def pad_promote(self, n, k):
        return max(1, min(n, self.base_cfg.bs_max))

    pad_demote = pad_promote

    def init(self, n_pages, k, machine):
        # machine is a TieredMachineSpec (a host name/MachineSpec resolves
        # here for direct callers); the path sums (full bottom-to-top hop
        # chain) are the N-tier generalization of the legacy per-page
        # promo/demo latencies and equal them bitwise at N=2 (the pair
        # costs are host-precomputed f64 -> f32 leaves, machine_spec.py).
        from repro.simulator import machines
        machine = machines.get(machine)
        return ARMSRunState(
            inner=init_state(n_pages, self.cfg()),
            buf=jnp.zeros((n_pages,), jnp.float32),
            t=jnp.zeros((), jnp.int32),
            promo_us=jnp.asarray(machine.promo_path_us(), jnp.float32),
            demo_us=jnp.asarray(machine.demo_path_us(), jnp.float32))

    def observe(self, state, observed):
        return state.replace(buf=state.buf + observed, t=state.t + 1)

    def fires(self, state):
        return (state.t % policy_every(state.inner.mode)) == 0

    def sampling_period(self, state):
        return sampling_period(state.inner.mode).astype(jnp.float32)

    def min_sampling_period(self):
        return float(SAMPLING_PERIOD_RECENCY)

    def mode_of(self, state):
        return state.inner.mode

    def obs_index(self, state):
        """Index into the PRE_PERIODS observation grids ("pre" sampling)."""
        return (state.inner.mode == MODE_RECENCY).astype(jnp.int32)

    def policy(self, state, slow_bw, app_bw, k):
        cfg = self.cfg()
        # normalize accumulated counts to per-interval rate so the EWMA
        # scale is mode-independent (500ms vs 100ms policy cadence, §5).
        every = policy_every(state.inner.mode).astype(jnp.float32)
        counts = state.buf / every
        inner, plan = arms_step_impl(state.inner, counts, slow_bw, app_bw,
                                     cfg=cfg, k=k)
        # §4.3: self-calibrating migration-cost feedback
        inner = jax.lax.cond(
            plan.count > 0,
            lambda s: observe_migration_cost(s, state.promo_us,
                                             state.demo_us, cfg),
            lambda s: s, inner)
        promote = jnp.where(plan.valid, plan.promote, -1).astype(jnp.int32)
        demote = jnp.where(plan.valid & (plan.demote >= 0), plan.demote,
                           -1).astype(jnp.int32)
        state = state.replace(inner=inner, buf=jnp.zeros_like(state.buf))
        return state, promote, demote


@pytree_dataclass(meta=("cfg_names", "base_cfg", "pool_every"))
class ARMSServeSpec(ARMSSpec):
    """ARMS exactly as the pre-refactor serving layer ran it.

    The serving pools (tiering/tiered_pool.py) historically called
    ``core.arms_step`` directly: RAW accumulated counts (no per-interval
    normalization), a FIXED ``policy_every`` cadence (not the
    mode-dependent 5/1 simulator cadence), and no §4.3 migration-cost
    feedback.  This spec reproduces that path bit-for-bit through the
    PolicySpec protocol — the legacy-equivalence regression in
    tests/test_serving_protocol.py asserts plan-sequence equality against
    a frozen copy of the old ``arms_step`` serving loop.  Use plain
    ``ARMSSpec`` for simulator sweeps; use this inside serving pools.
    """

    pool_every: int = 8

    name = "arms"
    dynamic_sampling_period = False

    @classmethod
    def make_serving(cls, base_cfg: ARMSConfig, pool_every: int,
                     overrides: dict | None = None) -> "ARMSServeSpec":
        spec = cls.make(overrides, base_cfg=base_cfg)
        return dataclasses.replace(spec, pool_every=int(pool_every))

    def fires(self, state):
        # observe() increments t first, so the first fire lands on interval
        # pool_every — the legacy ``kv.step % cfg.policy_every == 0`` gate.
        return (state.t % self.pool_every) == 0

    def sampling_period(self, state):
        return jnp.float32(self.DEFAULT_SAMPLE_PERIOD)

    def policy(self, state, slow_bw, app_bw, k):
        # raw counts, no normalization, no migration-cost feedback: the
        # legacy serving semantics (class docstring).
        inner, plan = arms_step_impl(state.inner, state.buf, slow_bw,
                                     app_bw, cfg=self.cfg(), k=k)
        promote = jnp.where(plan.valid, plan.promote, -1).astype(jnp.int32)
        demote = jnp.where(plan.valid & (plan.demote >= 0), plan.demote,
                           -1).astype(jnp.int32)
        state = state.replace(inner=inner, buf=jnp.zeros_like(state.buf))
        return state, promote, demote


class ARMSPolicy(Policy):
    name = "arms"

    def __init__(self, cfg: ARMSConfig | None = None):
        self.base_cfg = cfg or ARMSConfig()

    @property
    def migration_limit(self):  # batched migrations: up to BS_max per pass
        return self.base_cfg.bs_max

    def reset(self, n_pages, k, machine):
        from repro.simulator import machines
        machine = machines.get(machine)
        self.n, self.k = n_pages, k
        self.cfg = self.base_cfg
        self.state = init_state(n_pages, self.cfg)
        self.buf = np.zeros(n_pages)
        self.t = 0
        self._machine = machine
        # f32 path sums, matching ARMSSpec.init (and the legacy f64->f32
        # per-page costs bitwise at N=2).
        self._promo_us = float(
            np.sum(np.asarray(machine.promo_pair_us, np.float32)))
        self._demo_us = float(
            np.sum(np.asarray(machine.demo_pair_us, np.float32)))
        self._set_mode(MODE_HISTORY)

    def _set_mode(self, mode: int):
        """Host-side cadence cache, refreshed once per policy invocation."""
        self._mode = int(mode)
        recency = self._mode == MODE_RECENCY
        self._every = POLICY_EVERY_RECENCY if recency else POLICY_EVERY_HISTORY
        self._period = float(SAMPLING_PERIOD_RECENCY if recency
                             else SAMPLING_PERIOD_HISTORY)

    def sampling_period(self):
        return self._period

    def step(self, observed, slow_bw_frac, app_bw_frac):
        self.t += 1
        self.buf += observed
        every = self._every
        if self.t % every:
            return np.empty(0, np.int64), np.empty(0, np.int64)

        # normalize accumulated counts to per-interval rate so the EWMA scale
        # is mode-independent (500ms vs 100ms policy cadence, §5).  f32 in,
        # f32 divide: the controller computes in f32 either way, and dividing
        # after the cast keeps this bitwise-aligned with the scan engine.
        counts = self.buf.astype(np.float32) / np.float32(every)
        self.state, plan = arms_step(
            self.state, counts, float(slow_bw_frac),
            float(app_bw_frac), cfg=self.cfg, k=self.k)
        self.buf[:] = 0.0

        valid = np.asarray(plan.valid)
        promote = np.asarray(plan.promote)[valid]
        demote = np.asarray(plan.demote)[valid]
        demote = demote[demote >= 0]
        if len(promote):   # §4.3: self-calibrating migration-cost feedback
            self.state = observe_migration_cost(
                self.state, self._promo_us, self._demo_us, self.cfg)
        self._set_mode(int(self.state.mode))
        return promote.astype(np.int64), demote.astype(np.int64)

    @property
    def mode(self) -> int:
        return self._mode
