"""ARMS wrapped as a simulator policy (the paper's system, §4-5).

Bridges the pure-JAX controller into the numpy simulation loop: accumulates
sampled counts between policy invocations (500 ms / 100 ms cadence expressed
in 100 ms simulator intervals), feeds slow-tier bandwidth to the PHT, and
executes the bandwidth-aware batched migration plan.

The policy cadence and sampling period are tracked on the HOST, refreshed
from the returned state once per policy invocation: ``mode`` only changes
inside ``arms_step``, so polling ``policy_every(state.mode)`` every simulator
interval (as earlier versions did) forced a device->host sync per interval
for a value that could not have changed.
"""
from __future__ import annotations

import numpy as np

from repro.baselines.base import Policy
from repro.core import ARMSConfig, arms_step, init_state
from repro.core.controller import (POLICY_EVERY_HISTORY, POLICY_EVERY_RECENCY,
                                   SAMPLING_PERIOD_HISTORY,
                                   SAMPLING_PERIOD_RECENCY)
from repro.core.scheduler import observe_migration_cost
from repro.core.state import MODE_HISTORY, MODE_RECENCY
from repro.simulator import machine as machine_mod


class ARMSPolicy(Policy):
    name = "arms"

    def __init__(self, cfg: ARMSConfig | None = None):
        self.base_cfg = cfg or ARMSConfig()

    @property
    def migration_limit(self):  # batched migrations: up to BS_max per pass
        return self.base_cfg.bs_max

    def reset(self, n_pages, k, machine):
        self.n, self.k = n_pages, k
        self.cfg = self.base_cfg
        self.state = init_state(n_pages, self.cfg)
        self.buf = np.zeros(n_pages)
        self.t = 0
        self._machine = machine
        self._promo_us = machine_mod.promo_page_us(machine)
        self._demo_us = machine_mod.demo_page_us(machine)
        self._set_mode(MODE_HISTORY)

    def _set_mode(self, mode: int):
        """Host-side cadence cache, refreshed once per policy invocation."""
        self._mode = int(mode)
        recency = self._mode == MODE_RECENCY
        self._every = POLICY_EVERY_RECENCY if recency else POLICY_EVERY_HISTORY
        self._period = float(SAMPLING_PERIOD_RECENCY if recency
                             else SAMPLING_PERIOD_HISTORY)

    def sampling_period(self):
        return self._period

    def step(self, observed, slow_bw_frac, app_bw_frac):
        self.t += 1
        self.buf += observed
        every = self._every
        if self.t % every:
            return np.empty(0, np.int64), np.empty(0, np.int64)

        # normalize accumulated counts to per-interval rate so the EWMA scale
        # is mode-independent (500ms vs 100ms policy cadence, §5).  f32 in,
        # f32 divide: the controller computes in f32 either way, and dividing
        # after the cast keeps this bitwise-aligned with the scan engine.
        counts = self.buf.astype(np.float32) / np.float32(every)
        self.state, plan = arms_step(
            self.state, counts, float(slow_bw_frac),
            float(app_bw_frac), cfg=self.cfg, k=self.k)
        self.buf[:] = 0.0

        valid = np.asarray(plan.valid)
        promote = np.asarray(plan.promote)[valid]
        demote = np.asarray(plan.demote)[valid]
        demote = demote[demote >= 0]
        if len(promote):   # §4.3: self-calibrating migration-cost feedback
            self.state = observe_migration_cost(
                self.state, self._promo_us, self._demo_us, self.cfg)
        self._set_mode(int(self.state.mode))
        return promote.astype(np.int64), demote.astype(np.int64)

    @property
    def mode(self) -> int:
        return self._mode
