"""Memtis baseline (Lee et al., SOSP'23) — dynamic hot threshold, static
cooling period.

Memtis removes HeMem's hot_threshold by picking, each adaptation interval,
the smallest count threshold whose hot set fits the fast tier (histogram
based).  It keeps STATIC knobs for everything else; the one the paper blames
(§7.1 "infrequent cooling") is the cooling period of 2M PEBS samples, which
at a 1/10k sampling rate spans tens to hundreds of seconds — far longer than
hot-set churn in TPC-C-like ("latest") workloads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.baselines.protocol import (LegacyPolicyAdapter, PolicySpec,
                                      capacity_victims, ranked_take,
                                      scatter_set, truncate_ranked)
from repro.utils.pytree import pytree_dataclass

DEFAULTS = dict(cooling_period_samples=2e6, adaptation_period=10)


@pytree_dataclass
class MemtisState:
    counts: jnp.ndarray        # f32 [n]
    in_fast: jnp.ndarray      # bool [n]
    samples_seen: jnp.ndarray  # f32, since last cooling
    hot_threshold: jnp.ndarray  # f32, histogram-adapted
    t: jnp.ndarray            # i32
    cooling_events: jnp.ndarray  # i32


@pytree_dataclass(meta=("migration_limit",))
class MemtisSpec(PolicySpec):
    cooling_period_samples: jnp.ndarray
    adaptation_period: jnp.ndarray    # i32
    migration_limit: int = 12  # kernel kmigrated-style serial migration

    name = "memtis"

    @classmethod
    def make(cls, cooling_period_samples=None, adaptation_period=None,
             migration_limit: int = 12) -> "MemtisSpec":
        pick = lambda v, key: DEFAULTS[key] if v is None else v
        return cls(
            cooling_period_samples=jnp.float32(
                pick(cooling_period_samples, "cooling_period_samples")),
            adaptation_period=jnp.int32(
                pick(adaptation_period, "adaptation_period")),
            migration_limit=migration_limit)

    def init(self, n_pages, k, machine):
        return MemtisState(
            counts=jnp.zeros((n_pages,), jnp.float32),
            in_fast=jnp.zeros((n_pages,), bool),
            samples_seen=jnp.zeros((), jnp.float32),
            hot_threshold=jnp.ones((), jnp.float32),
            t=jnp.zeros((), jnp.int32),
            cooling_events=jnp.zeros((), jnp.int32))

    def observe(self, state, observed):
        counts = state.counts + observed
        samples = state.samples_seen + observed.sum()
        # static-period cooling (the pathology the paper highlights).
        cool = samples >= self.cooling_period_samples
        counts = jnp.where(cool, counts * 0.5, counts)
        samples = jnp.where(cool, 0.0, samples)
        return state.replace(
            counts=counts, samples_seen=samples, t=state.t + 1,
            cooling_events=state.cooling_events + cool.astype(jnp.int32))

    def policy(self, state, slow_bw, app_bw, k):
        n = state.counts.shape[0]
        # histogram-based threshold: smallest thr with |hot| <= k (k-th
        # largest count via top_k — full sorts are pathological on CPU XLA).
        adapt_every = jnp.maximum(self.adaptation_period.astype(jnp.int32), 1)
        thr = jnp.maximum(jax.lax.top_k(state.counts, k)[0][k - 1], 1.0)
        hot_threshold = jnp.where((state.t % adapt_every) == 0, thr,
                                  state.hot_threshold)
        hot = state.counts >= hot_threshold
        want, n_want = ranked_take(                    # hottest-first
            -state.counts, hot & ~state.in_fast,
            self.pad_promote(n, k), self.migration_limit)
        victims, _, n_take = capacity_victims(
            state.in_fast, state.counts, state.in_fast & ~hot, n_want, k,
            self.pad_demote(n, k))
        promote = truncate_ranked(want, n_take)
        in_fast = scatter_set(state.in_fast, victims, False)
        in_fast = scatter_set(in_fast, promote, True)
        return (state.replace(in_fast=in_fast, hot_threshold=hot_threshold),
                promote, victims)


class MemtisPolicy(LegacyPolicyAdapter):
    """Memtis for the numpy reference engine (functional spec underneath)."""

    def __init__(self, cooling_period_samples: float = 2e6,
                 adaptation_period: int = 10):
        super().__init__(MemtisSpec.make(cooling_period_samples,
                                         adaptation_period))
