"""Memtis baseline (Lee et al., SOSP'23) — dynamic hot threshold, static
cooling period.

Memtis removes HeMem's hot_threshold by picking, each adaptation interval,
the smallest count threshold whose hot set fits the fast tier (histogram
based).  It keeps STATIC knobs for everything else; the one the paper blames
(§7.1 "infrequent cooling") is the cooling period of 2M PEBS samples, which
at a 1/10k sampling rate spans tens to hundreds of seconds — far longer than
hot-set churn in TPC-C-like ("latest") workloads.
"""
from __future__ import annotations

import numpy as np

from repro.baselines.base import Policy


class MemtisPolicy(Policy):
    name = "memtis"
    migration_limit = 12   # kernel kmigrated-style serial migration

    def __init__(self, cooling_period_samples: float = 2e6,
                 adaptation_period: int = 10):
        self.cooling_period_samples = float(cooling_period_samples)
        self.adaptation_period = int(adaptation_period)

    def reset(self, n_pages, k, machine):
        self.n, self.k = n_pages, k
        self.counts = np.zeros(n_pages)
        self.in_fast = np.zeros(n_pages, bool)
        self.samples_seen = 0.0
        self.t = 0
        self.hot_threshold = 1.0
        self.cooling_events = 0

    def step(self, observed, slow_bw_frac, app_bw_frac):
        self.t += 1
        self.counts += observed
        self.samples_seen += float(observed.sum())
        # static-period cooling (the pathology the paper highlights).
        if self.samples_seen >= self.cooling_period_samples:
            self.counts *= 0.5
            self.samples_seen = 0.0
            self.cooling_events += 1

        if self.t % self.adaptation_period == 0:
            # histogram-based threshold: smallest thr with |hot| <= k.
            order = np.sort(self.counts)[::-1]
            thr = order[self.k - 1] if self.k <= len(order) else 0.0
            self.hot_threshold = max(thr, 1.0)

        hot = self.counts >= self.hot_threshold
        want = np.flatnonzero(hot & ~self.in_fast)
        want = want[np.argsort(self.counts[want])[::-1]]
        want = want[: self.migration_limit]

        free = self.k - int(self.in_fast.sum())
        need_victims = max(0, len(want) - free)
        cold_in_fast = np.flatnonzero(self.in_fast & ~hot)
        victims = cold_in_fast[np.argsort(self.counts[cold_in_fast],
                                          kind="stable")][:need_victims]
        want = want[: free + len(victims)]
        self.in_fast[victims] = False
        self.in_fast[want] = True
        return want, victims
