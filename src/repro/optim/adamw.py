"""AdamW with decoupled weight decay, cosine LR schedule and global-norm
clipping — written against plain pytrees (no optax dependency).

Optimizer state (m, v in f32, plus an f32 master copy when params are bf16)
inherits the parameter sharding, which combined with the FSDP param specs
gives ZeRO-style sharded optimizer memory for free under GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils.pytree import pytree_dataclass, static_dataclass


@static_dataclass
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    master_fp32: bool = True


@pytree_dataclass
class AdamWState:
    step: jnp.ndarray
    m: Any
    v: Any
    master: Any   # f32 master params (or None-like empty dict)


def init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if cfg.master_fp32 else {})
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros), master=master)


def schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), \
        norm


def update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """-> (new_params, new_state, metrics)."""
    grads_f32, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m,
                     grads_f32)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state.v,
                     grads_f32)

    base = state.master if cfg.master_fp32 else params

    def upd(p, mm, vv):
        u = (mm / bc1) / (jnp.sqrt(vv / bc2) + cfg.eps)
        return p - lr * (u + cfg.weight_decay * p.astype(jnp.float32))

    new_base = jax.tree.map(upd, base, m, v)
    if cfg.master_fp32:
        new_params = jax.tree.map(
            lambda nb, p: nb.astype(p.dtype), new_base, params)
        new_master = new_base
    else:
        new_params = jax.tree.map(
            lambda nb, p: nb.astype(p.dtype), new_base, params)
        new_master = {}
    new_state = AdamWState(step=step, m=m, v=v, master=new_master)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
