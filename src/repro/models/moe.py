"""Mixture-of-Experts with GShard-style capacity-based dispatch.

Top-k routing with per-slot priority, static per-expert capacity (drops on
overflow), optional shared experts (DeepSeek-V2), and a load-balancing
auxiliary loss.  Expert weights are stacked [E, ...] so expert parallelism
is a plain PartitionSpec over the 'model' mesh axis; dispatch/combine are
einsums that SPMD turns into all-to-alls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def moe_init(rng, cfg, dtype) -> dict:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    r = jax.random.split(rng, 4)
    std = 1.0 / (D ** 0.5)
    p = {
        "router": {"w": (jax.random.normal(r[0], (D, E), jnp.float32)
                         * std).astype(jnp.float32)},   # router in f32
        "wi": (jax.random.normal(r[1], (E, D, 2 * F), jnp.float32)
               * std).astype(dtype),
        "wo": (jax.random.normal(r[2], (E, F, D), jnp.float32)
               * (1.0 / F ** 0.5)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.swiglu_init(r[3], D,
                                    cfg.n_shared_experts * F, dtype)
    return p


# GShard grouping was tried and REFUTED for this dispatch formulation
# (§Perf iteration A6: per-group capacity + sharded expert buffers raised
# the collective term 54->477 s and memory 58->152 s; even expert-only
# constraints measured 68/54 vs 58/54 without).  The scatter-based
# dispatch with global capacity (A3) remains the best measured layout.
N_GROUPS = 1


def _capacity(tokens: int, cfg) -> int:
    cap = int(tokens * cfg.experts_per_token * cfg.capacity_factor
              / cfg.n_experts)
    return max(4, -(-cap // 4) * 4)   # round up to a multiple of 4


def moe_apply(p, x, cfg):
    """x: [B, S, D] -> (y [B,S,D], aux_loss scalar f32).

    Router statistics (tokens per expert) are also returned for the ARMS
    expert-tiering integration — they are exactly the paper's "page access
    counts" at expert granularity.
    """
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.experts_per_token
    G = N_GROUPS if T % N_GROUPS == 0 and T >= N_GROUPS else 1
    Cg = _capacity(T // G, cfg)
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ p["router"]["w"])        # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # [T,k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                 # renormalize

    # --- slot-priority position assignment, PER GROUP (GShard) ---
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)     # [T,k,E]
    grouped = onehot.reshape(G, T // G, k, E)
    slot_major = grouped.transpose(0, 2, 1, 3).reshape(G, k * (T // G), E)
    pos_flat = jnp.cumsum(slot_major, axis=1) - slot_major
    pos = pos_flat.reshape(G, k, T // G, E).transpose(0, 2, 1, 3)
    pos_tk = (pos * grouped).sum(-1).reshape(T, k)              # [T,k]
    keep = pos_tk < Cg

    gates = jnp.where(keep, gate_vals, 0.0)
    # --- scatter/gather dispatch (§Perf iteration A3) ---
    # Scatter-add moves exactly the T*k token copies routing requires; the
    # einsum form materialized [T,E,C] one-hots and forced SPMD to
    # replicate the token dim per device.
    group_of = jnp.arange(T) // (T // G)                        # [T]
    e_idx = jnp.where(keep, expert_idx, E)       # overflow -> dropped row
    slot_idx = (group_of[:, None] * Cg
                + jnp.clip(pos_tk, 0, Cg - 1))                  # [T,k]
    xin = jnp.zeros((E, G * Cg, D), x.dtype).at[e_idx, slot_idx].add(
        xf[:, None, :] * keep[..., None].astype(x.dtype), mode="drop")
    gate_up = jnp.einsum("ecd,edf->ecf", xin, p["wi"])
    g, u = jnp.split(gate_up, 2, axis=-1)
    h = jax.nn.silu(g) * u
    yout = jnp.einsum("ecf,efd->ecd", h, p["wo"])               # [E,GCg,D]
    y = (yout[e_idx.clip(0, E - 1), slot_idx]                   # [T,k,D]
         * gates[..., None].astype(x.dtype)).sum(axis=1)        # [T,D]

    if cfg.n_shared_experts:
        y = y + L.swiglu(p["shared"], xf)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0)
    mean_prob = probs.mean(axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(frac_tokens * mean_prob)

    expert_load = jnp.zeros((E,), jnp.float32).at[e_idx].add(
        keep.astype(jnp.float32), mode="drop")                  # [E] tokens
    return y.reshape(B, S, D), aux, expert_load
