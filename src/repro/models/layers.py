"""Core neural layers (pure functions over explicit param pytrees).

Everything is jit/scan/vmap-friendly: params are nested dicts of arrays,
forward functions are pure.  Matmuls run in the config dtype (bf16 on TPU);
normalization statistics and softmax run in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def dtype_of(cfg):
    return DTYPES[cfg.dtype]


# ------------------------------------------------------------------ RMSNorm
def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------- Linear
def linear_init(rng, d_in: int, d_out: int, dtype, scale: float = 1.0) -> dict:
    std = scale / (d_in ** 0.5)
    return {"w": (jax.random.normal(rng, (d_in, d_out), jnp.float32)
                  * std).astype(dtype)}


def linear(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"]


# -------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float, pct: float = 1.0):
    """Frequencies for (partially) rotary embeddings."""
    rot = int(head_dim * pct) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               pct: float = 1.0) -> jnp.ndarray:
    """x: [B, S, H, hd]; positions: [B, S] (int)."""
    inv, rot = rope_freqs(x.shape[-1], theta, pct)
    ang = positions[..., None].astype(jnp.float32) * inv  # [B,S,rot/2]
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    out = out.reshape(x.shape[:-1] + (rot,))
    return jnp.concatenate([out.astype(x.dtype), x[..., rot:]], axis=-1)


# ------------------------------------------------------------------- SwiGLU
def swiglu_init(rng, d: int, f: int, dtype) -> dict:
    r1, r2 = jax.random.split(rng)
    return {"wi": linear_init(r1, d, 2 * f, dtype),
            "wo": linear_init(r2, f, d, dtype)}


def swiglu(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    gate_up = linear(p["wi"], x)
    gate, up = jnp.split(gate_up, 2, axis=-1)
    return linear(p["wo"], jax.nn.silu(gate) * up)


# ------------------------------------------------------------- GELU MLP
def gelu_mlp_init(rng, d: int, f: int, dtype) -> dict:
    r1, r2 = jax.random.split(rng)
    return {"wi": linear_init(r1, d, f, dtype),
            "wo": linear_init(r2, f, d, dtype)}


def gelu_mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return linear(p["wo"], jax.nn.gelu(linear(p["wi"], x)))


# -------------------------------------------------------------- Embeddings
def embedding_init(rng, vocab: int, d: int, dtype) -> dict:
    return {"table": (jax.random.normal(rng, (vocab, d), jnp.float32)
                      * 0.02).astype(dtype)}


def embed(p: dict, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], ids, axis=0)


def unembed(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["table"].T


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000 ** (dim / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang))  # d is even for all our configs
    return pe


def sinusoidal_at(pos, d: int) -> jnp.ndarray:
    """Sinusoidal embedding for one (traced) position -> [d]."""
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / (10_000 ** (dim / d))
    pe = jnp.zeros((d,), jnp.float32)
    return pe.at[0::2].set(jnp.sin(ang)).at[1::2].set(jnp.cos(ang))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  vocab: int) -> jnp.ndarray:
    """Mean token cross-entropy in f32; labels < 0 are masked out."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
