"""Transformer / SSM block definitions (pre-norm residual).

Each block family exposes ``<fam>_init(rng, cfg, dtype)`` and apply
functions for full-sequence and decode modes.  Blocks are scanned over
stacked parameters (leading layer axis) by models/model.py, so every apply
is shape-stable and side-effect-free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MoE


# ------------------------------------------------------------- dense (GQA)
def dense_block_init(rng, cfg, dtype, d_ff=None):
    r = jax.random.split(rng, 2)
    return {"ln1": L.rmsnorm_init(cfg.d_model, dtype),
            "attn": A.gqa_init(r[0], cfg, dtype),
            "ln2": L.rmsnorm_init(cfg.d_model, dtype),
            "mlp": L.swiglu_init(r[1], cfg.d_model, d_ff or cfg.d_ff, dtype)}


def dense_block_full(p, x, cfg, *, causal=True, window=0):
    h, kv = A.gqa_full(p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
                       causal=causal, window=window)
    x = x + h
    x = x + L.swiglu(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, kv


def dense_block_decode_flat(p, x, k_st, v_st, idx, pos, cfg, *, window=0):
    """Decode against the stacked [L,B,KV,S,dh] cache (in-place writes)."""
    h, k_st, v_st = A.gqa_decode_flat(
        p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), k_st, v_st, idx,
        pos, cfg, window=window)
    x = x + h
    x = x + L.swiglu(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, k_st, v_st


def moe_block_decode_flat(p, x, caches, idx, pos, cfg, *, window=0):
    xn = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        c_st, r_st = caches
        h, c_st, r_st = A.mla_decode_flat(p["attn"], xn, c_st, r_st, idx,
                                          pos, cfg)
        caches = (c_st, r_st)
    else:
        k_st, v_st = caches
        h, k_st, v_st = A.gqa_decode_flat(p["attn"], xn, k_st, v_st, idx,
                                          pos, cfg, window=window)
        caches = (k_st, v_st)
    x = x + h
    y, _, load = MoE.moe_apply(p["moe"],
                               L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    return x + y, caches, load


def dense_block_decode(p, x, cache, pos, cfg, *, window=0):
    h, cache = A.gqa_decode(p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                            cache, pos, cfg, window=window)
    x = x + h
    x = x + L.swiglu(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, cache


# ---------------------------------------------------------------- MoE block
def moe_block_init(rng, cfg, dtype):
    r = jax.random.split(rng, 2)
    attn = (A.mla_init(r[0], cfg, dtype) if cfg.use_mla
            else A.gqa_init(r[0], cfg, dtype))
    return {"ln1": L.rmsnorm_init(cfg.d_model, dtype),
            "attn": attn,
            "ln2": L.rmsnorm_init(cfg.d_model, dtype),
            "moe": MoE.moe_init(r[1], cfg, dtype)}


def moe_block_full(p, x, cfg, *, window=0):
    xn = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        h, kv = A.mla_full(p["attn"], xn, cfg)
    else:
        h, kv = A.gqa_full(p["attn"], xn, cfg, window=window)
    x = x + h
    y, aux, load = MoE.moe_apply(p["moe"],
                                 L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    return x + y, kv, aux, load


def moe_block_decode(p, x, cache, pos, cfg, *, window=0):
    xn = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        h, cache = A.mla_decode(p["attn"], xn, cache, pos, cfg)
    else:
        h, cache = A.gqa_decode(p["attn"], xn, cache, pos, cfg,
                                window=window)
    x = x + h
    y, _, load = MoE.moe_apply(p["moe"],
                               L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    return x + y, cache, load


# ------------------------------------------------------ MLA + dense (deepseek layer 0)
def mla_dense_block_init(rng, cfg, dtype):
    r = jax.random.split(rng, 2)
    return {"ln1": L.rmsnorm_init(cfg.d_model, dtype),
            "attn": A.mla_init(r[0], cfg, dtype),
            "ln2": L.rmsnorm_init(cfg.d_model, dtype),
            "mlp": L.swiglu_init(r[1], cfg.d_model, cfg.dense_d_ff, dtype)}


def mla_dense_block_full(p, x, cfg):
    h, kv = A.mla_full(p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg)
    x = x + h
    x = x + L.swiglu(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, kv


def mla_dense_block_decode(p, x, cache, pos, cfg):
    h, cache = A.mla_decode(p["attn"],
                            L.rmsnorm(p["ln1"], x, cfg.norm_eps), cache,
                            pos, cfg)
    x = x + h
    x = x + L.swiglu(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, cache


# -------------------------------------------------------------- mamba block
def mamba_block_init(rng, cfg, dtype):
    return {"ln": L.rmsnorm_init(cfg.d_model, dtype),
            "mamba": M.mamba2_init(rng, cfg, dtype)}


def mamba_block_full(p, x, cfg):
    h, cache = M.mamba2_full(p["mamba"], L.rmsnorm(p["ln"], x, cfg.norm_eps),
                             cfg)
    return x + h, cache


def mamba_block_decode(p, x, cache, cfg):
    h, cache = M.mamba2_decode(p["mamba"],
                               L.rmsnorm(p["ln"], x, cfg.norm_eps), cache,
                               cfg)
    return x + h, cache


# ------------------------------------------------- enc-dec blocks (whisper)
def encoder_block_init(rng, cfg, dtype):
    r = jax.random.split(rng, 2)
    return {"ln1": L.rmsnorm_init(cfg.d_model, dtype),
            "attn": A.gqa_init(r[0], cfg, dtype),
            "ln2": L.rmsnorm_init(cfg.d_model, dtype),
            "mlp": L.gelu_mlp_init(r[1], cfg.d_model, cfg.d_ff, dtype)}


def encoder_block_full(p, x, cfg):
    h, _ = A.gqa_full(p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
                      causal=False, rope=False)
    x = x + h
    return x + L.gelu_mlp(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))


def decoder_block_init(rng, cfg, dtype):
    r = jax.random.split(rng, 3)
    return {"ln1": L.rmsnorm_init(cfg.d_model, dtype),
            "self_attn": A.gqa_init(r[0], cfg, dtype),
            "ln_x": L.rmsnorm_init(cfg.d_model, dtype),
            "cross_attn": A.gqa_init(r[1], cfg, dtype),
            "ln2": L.rmsnorm_init(cfg.d_model, dtype),
            "mlp": L.gelu_mlp_init(r[2], cfg.d_model, cfg.d_ff, dtype)}


def cross_kv(p, enc_out, cfg):
    """Precompute per-layer cross K/V from encoder output."""
    B, S, _ = enc_out.shape
    KV, dh = cfg.n_kv_heads, cfg.head_dim
    k = L.linear(p["cross_attn"]["wk"], enc_out).reshape(B, S, KV, dh)
    v = L.linear(p["cross_attn"]["wv"], enc_out).reshape(B, S, KV, dh)
    return A.KVCache(k=k, v=v)


def decoder_block_full(p, x, enc_kv: A.KVCache, cfg):
    h, self_kv = A.gqa_full(p["self_attn"],
                            L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
                            causal=True, rope=False)
    x = x + h
    x = x + A.gqa_cross(p["cross_attn"],
                        L.rmsnorm(p["ln_x"], x, cfg.norm_eps), enc_kv, cfg)
    return x + L.gelu_mlp(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps)), \
        self_kv


def decoder_block_decode(p, x, self_cache, enc_kv, pos, cfg):
    h, self_cache = A.gqa_decode(
        p["self_attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), self_cache,
        pos, cfg, rope=False)
    x = x + h
    x = x + A.gqa_cross(p["cross_attn"],
                        L.rmsnorm(p["ln_x"], x, cfg.norm_eps), enc_kv, cfg)
    return x + L.gelu_mlp(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps)), \
        self_cache
