"""Attention variants: GQA (with qk-norm, partial RoPE, sliding window) and
MLA (DeepSeek-V2 multi-head latent attention with weight absorption for the
decode path).

All functions support three call modes:
  * full-sequence (train / prefill): returns per-layer KV to cache;
  * decode: single new token against a fixed-size KV cache + position;
  * cross (whisper decoder): keys/values from precomputed encoder states.

Softmax is computed in f32.  Masks are built from positions so decode
lowers with static shapes (required by the dry-run).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L

NEG_INF = -1e30


# ------------------------------------------------------------------ helpers
def _sdpa(q, k, v, mask, scale):
    """q:[B,Sq,H,dh] k/v:[B,Sk,KV,dh] mask:[B,1,Sq,Sk] bool (True=keep)."""
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, Sq, KV, rep, dh)
    scores = jnp.einsum("bqkrd,bskd->bkrqs", qg, k).astype(jnp.float32)
    scores = scores * scale
    scores = jnp.where(mask[:, :, None], scores, NEG_INF)  # [B,KV,rep,Sq,Sk]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrqs,bskd->bqkrd", probs, v)
    return out.reshape(B, Sq, H, dh)


def causal_mask(sq: int, sk: int, q_offset, window: int = 0):
    """[1,1,sq,sk] boolean; q position i attends to j <= i (+window)."""
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(sk)[None, :]
    m = kj <= qi
    if window:
        m &= kj > qi - window
    return m[None, None]


def length_mask(sk: int, valid_len):
    kj = jnp.arange(sk)[None, :]
    return (kj < valid_len)[:, None, None, :] if jnp.ndim(valid_len) \
        else (kj < valid_len)[None, None, None, :]


# ---------------------------------------------------------------------- GQA
def gqa_init(rng, cfg, dtype) -> dict:
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    r = jax.random.split(rng, 4)
    p = {"wq": L.linear_init(r[0], D, H * dh, dtype),
         "wk": L.linear_init(r[1], D, KV * dh, dtype),
         "wv": L.linear_init(r[2], D, KV * dh, dtype),
         "wo": L.linear_init(r[3], H * dh, D, dtype, scale=0.5)}
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(dh, dtype)
        p["k_norm"] = L.rmsnorm_init(dh, dtype)
    return p


@dataclasses.dataclass(frozen=True)
class KVCache:
    """Contiguous KV cache [B, S_max, KV, dh] (paged variant in tiering/)."""
    k: jnp.ndarray
    v: jnp.ndarray


jax.tree_util.register_dataclass(KVCache, data_fields=["k", "v"],
                                 meta_fields=[])


def gqa_qkv(p, x, positions, cfg, *, rope: bool = True):
    B, S, D = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = L.linear(p["wq"], x).reshape(B, S, H, dh)
    k = L.linear(p["wk"], x).reshape(B, S, KV, dh)
    v = L.linear(p["wv"], x).reshape(B, S, KV, dh)
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = L.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if rope:
        q = L.apply_rope(q, positions, cfg.rope_theta, cfg.rope_pct)
        k = L.apply_rope(k, positions, cfg.rope_theta, cfg.rope_pct)
    return q, k, v


def gqa_full(p, x, cfg, *, causal: bool = True, rope: bool = True,
             window: int = 0):
    """Train/prefill: full-sequence attention.  Returns (out, KVCache).

    Long sequences take the online-softmax KV-block path (xla_flash) — the
    S x S score matrix is never materialized (§Perf iteration A4)."""
    from repro.models import xla_flash
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q, k, v = gqa_qkv(p, x, positions, cfg, rope=rope)
    if xla_flash.use_flash(S):
        H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        rep = H // KV
        qh = q.reshape(B, S, KV, rep, dh).transpose(0, 2, 3, 1, 4) \
            .reshape(B, KV * rep, S, dh)
        kh = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
        vh = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
        out = xla_flash.flash_sdpa(qh, kh, vh, dh ** -0.5, causal=causal,
                                   window=window)
        out = out.reshape(B, KV, rep, S, dh).transpose(0, 3, 1, 2, 4) \
            .reshape(B, S, -1)
    else:
        if causal:
            mask = jnp.broadcast_to(causal_mask(S, S, 0, window),
                                    (B, 1, S, S))
        else:
            mask = jnp.ones((B, 1, S, S), bool)
        out = _sdpa(q, k, v, mask, cfg.head_dim ** -0.5)
        out = out.reshape(B, S, -1)
    out = L.linear(p["wo"], out)
    return out, KVCache(k=k, v=v)


def gqa_decode(p, x, cache: KVCache, pos, cfg, *, rope: bool = True,
               window: int = 0):
    """One-token decode against a cache of static size S_max.

    x: [B, 1, D]; pos: scalar int32 (tokens already generated).
    If ``window`` is set and the cache is window-sized, the cache is a RING
    BUFFER over the last ``window`` positions (RoPE is baked into K at write
    time, so slot order is irrelevant to the attention scores).
    Returns (out [B,1,D], updated cache).
    """
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q, k_new, v_new = gqa_qkv(p, x, positions, cfg, rope=rope)
    S_max = cache.k.shape[1]
    ring = bool(window) and S_max <= window
    slot = pos % S_max if ring else pos
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))
    kj = jnp.arange(S_max)[None, None, None, :]
    if ring:
        mask = kj <= pos        # all slots once the ring has wrapped
    else:
        mask = kj <= pos
        if window:
            mask &= kj > pos - window
    mask = jnp.broadcast_to(mask, (B, 1, 1, S_max))
    out = _sdpa(q, k, v, mask, cfg.head_dim ** -0.5)
    out = L.linear(p["wo"], out.reshape(B, 1, -1))
    return out, KVCache(k=k, v=v)


def gqa_cross(p, x, enc_kv: KVCache, cfg):
    """Cross-attention (whisper decoder): q from x, kv precomputed."""
    B, S, _ = x.shape
    H, dh = cfg.n_heads, cfg.head_dim
    q = L.linear(p["wq"], x).reshape(B, S, H, dh)
    Sk = enc_kv.k.shape[1]
    mask = jnp.ones((B, 1, S, Sk), bool)
    out = _sdpa(q, enc_kv.k, enc_kv.v, mask, dh ** -0.5)
    return L.linear(p["wo"], out.reshape(B, S, -1))


def gqa_decode_flat(p, x, k_st, v_st, idx, pos, cfg, *, window: int = 0):
    """One-token decode writing directly into the STACKED cache.

    k_st/v_st: [L, B, KV, S, dh] — KV-major, sequence-inner layout (no
    transpose before the attention dots) with token writes as [1,B,KV,1,dh]
    dynamic-update-slices (in-place on TPU).  See EXPERIMENTS.md §Perf
    iteration C2.  Returns (out, k_st, v_st).
    """
    B = x.shape[0]
    KV, dh = cfg.n_kv_heads, cfg.head_dim
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q, k_new, v_new = gqa_qkv(p, x, positions, cfg)     # [B,1,H/KV,dh]
    S_max = k_st.shape[3]
    ring = bool(window) and S_max <= window
    slot = pos % S_max if ring else pos

    upd_k = k_new.transpose(0, 2, 1, 3)[None]           # [1,B,KV,1,dh]
    upd_v = v_new.transpose(0, 2, 1, 3)[None]
    k_st = jax.lax.dynamic_update_slice(k_st, upd_k, (idx, 0, 0, slot, 0))
    v_st = jax.lax.dynamic_update_slice(v_st, upd_v, (idx, 0, 0, slot, 0))

    k_l = jax.lax.dynamic_index_in_dim(k_st, idx, 0, keepdims=False)
    v_l = jax.lax.dynamic_index_in_dim(v_st, idx, 0, keepdims=False)
    # barrier: keep downstream dtype converts (CPU f32 dot policy) on the
    # per-layer slice — without it XLA hoists the convert onto the whole
    # stacked cache (§Perf iteration C2 vs C3).
    k_l, v_l = jax.lax.optimization_barrier((k_l, v_l))

    H = cfg.n_heads
    rep = H // KV
    qg = q.reshape(B, KV, rep, dh)
    s = jnp.einsum("bkrd,bksd->bkrs", qg, k_l).astype(jnp.float32)
    s = s * (dh ** -0.5)
    kj = jnp.arange(S_max)[None, None, None, :]
    mask = kj <= pos
    if window and not ring:
        mask &= kj > pos - window
    s = jnp.where(mask, s, NEG_INF)
    pgates = jax.nn.softmax(s, axis=-1).astype(v_l.dtype)
    out = jnp.einsum("bkrs,bksd->bkrd", pgates, v_l)
    out = L.linear(p["wo"], out.reshape(B, 1, H * dh))
    return out, k_st, v_st


def mla_decode_flat(p, x, c_st, r_st, idx, pos, cfg):
    """MLA decode with weight absorption against stacked latent caches.

    c_st: [L, B, S, R]; r_st: [L, B, S, rope_d].  Token writes are
    [1,B,1,*] in-place updates.  Returns (out, c_st, r_st)."""
    B = x.shape[0]
    H, nope, rope_d, vd = (cfg.n_heads, cfg.head_dim, cfg.rope_head_dim,
                           cfg.v_head_dim)
    R = cfg.kv_lora_rank
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q_nope, q_rope = _mla_q(p, x, positions, cfg)
    c_new, kr_new = _mla_kv_a(p, x, positions, cfg)
    c_st = jax.lax.dynamic_update_slice(c_st, c_new[None],
                                        (idx, 0, pos, 0))
    r_st = jax.lax.dynamic_update_slice(r_st, kr_new[None],
                                        (idx, 0, pos, 0))
    c_kv = jax.lax.dynamic_index_in_dim(c_st, idx, 0, keepdims=False)
    k_rope = jax.lax.dynamic_index_in_dim(r_st, idx, 0, keepdims=False)

    wkv_b = p["wkv_b"]["w"].reshape(R, H, nope + vd)
    w_k, w_v = wkv_b[..., :nope], wkv_b[..., nope:]
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_k)
    scale = (nope + rope_d) ** -0.5
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat, c_kv)
              + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope))
    scores = scores.astype(jnp.float32) * scale
    S_max = c_kv.shape[1]
    mask = (jnp.arange(S_max)[None, None, None, :] <= pos)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out_lat = jnp.einsum("bhqs,bsr->bqhr", probs, c_kv)
    out = jnp.einsum("bqhr,rhd->bqhd", out_lat, w_v)
    out = L.linear(p["wo"], out.reshape(B, 1, -1))
    return out, c_st, r_st


# ---------------------------------------------------------------------- MLA
def mla_init(rng, cfg, dtype) -> dict:
    """DeepSeek-V2 multi-head latent attention (kv_lora compression)."""
    D, H = cfg.d_model, cfg.n_heads
    nope, rope_d, vd = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    r = jax.random.split(rng, 6)
    p = {
        "wkv_a": L.linear_init(r[0], D, cfg.kv_lora_rank + rope_d, dtype),
        "kv_norm": L.rmsnorm_init(cfg.kv_lora_rank, dtype),
        "wkv_b": L.linear_init(r[1], cfg.kv_lora_rank, H * (nope + vd),
                               dtype),
        "wo": L.linear_init(r[2], H * vd, D, dtype, scale=0.5),
    }
    if cfg.q_lora_rank:
        p["wq_a"] = L.linear_init(r[3], D, cfg.q_lora_rank, dtype)
        p["q_norm"] = L.rmsnorm_init(cfg.q_lora_rank, dtype)
        p["wq_b"] = L.linear_init(r[4], cfg.q_lora_rank, H * (nope + rope_d),
                                  dtype)
    else:
        p["wq"] = L.linear_init(r[5], D, H * (nope + rope_d), dtype)
    return p


@dataclasses.dataclass(frozen=True)
class MLACache:
    """Latent cache: compressed c_kv [B,S,kv_lora] + shared k_rope
    [B,S,rope_d] — the memory win that makes MLA pages cheap to tier."""
    c_kv: jnp.ndarray
    k_rope: jnp.ndarray


jax.tree_util.register_dataclass(MLACache, data_fields=["c_kv", "k_rope"],
                                 meta_fields=[])


def _mla_q(p, x, positions, cfg):
    B, S, _ = x.shape
    H, nope, rope_d = cfg.n_heads, cfg.head_dim, cfg.rope_head_dim
    if cfg.q_lora_rank:
        q = L.linear(p["wq_b"], L.rmsnorm(p["q_norm"],
                                          L.linear(p["wq_a"], x),
                                          cfg.norm_eps))
    else:
        q = L.linear(p["wq"], x)
    q = q.reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_a(p, x, positions, cfg):
    B, S, _ = x.shape
    kv = L.linear(p["wkv_a"], x)
    c_kv = L.rmsnorm(p["kv_norm"], kv[..., : cfg.kv_lora_rank], cfg.norm_eps)
    k_rope = kv[..., cfg.kv_lora_rank:][:, :, None, :]   # one shared head
    k_rope = L.apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_full(p, x, cfg, *, causal: bool = True):
    """Train/prefill path: materialize per-head K/V from the latent.

    The nope and rope score contributions are fused into ONE [B,H,S,S]
    matmul by concatenating the head dims — two separate score tensors
    doubled the softmax chain's HBM reads (§Perf iteration A2)."""
    B, S, _ = x.shape
    H, nope, rope_d, vd = (cfg.n_heads, cfg.head_dim, cfg.rope_head_dim,
                           cfg.v_head_dim)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q_nope, q_rope = _mla_q(p, x, positions, cfg)
    c_kv, k_rope = _mla_kv_a(p, x, positions, cfg)
    kvb = L.linear(p["wkv_b"], c_kv).reshape(B, S, H, nope + vd)
    k_nope, v = kvb[..., :nope], kvb[..., nope:]

    scale = (nope + rope_d) ** -0.5
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)   # [B,S,H,nope+rd]
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, rope_d))], axis=-1)
    from repro.models import xla_flash
    if xla_flash.use_flash(S):
        out = xla_flash.flash_sdpa(
            q_cat.transpose(0, 2, 1, 3), k_cat.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), scale, causal=causal)
        out = out.transpose(0, 2, 1, 3)                  # [B,S,H,vd]
    else:
        scores = jnp.einsum("bqhd,bshd->bhqs", q_cat, k_cat)
        scores = scores.astype(jnp.float32) * scale
        if causal:
            mask = causal_mask(S, S, 0)[0]
            scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    out = L.linear(p["wo"], out.reshape(B, S, -1))
    return out, MLACache(c_kv=c_kv, k_rope=k_rope)


def mla_decode(p, x, cache: MLACache, pos, cfg):
    """Decode with WEIGHT ABSORPTION: queries/attention run in the latent
    space so the 32k/500k cache is only kv_lora(+rope) wide per token."""
    B = x.shape[0]
    H, nope, rope_d, vd = (cfg.n_heads, cfg.head_dim, cfg.rope_head_dim,
                           cfg.v_head_dim)
    R = cfg.kv_lora_rank
    positions = jnp.broadcast_to(pos[None, None], (B, 1))
    q_nope, q_rope = _mla_q(p, x, positions, cfg)           # [B,1,H,*]
    c_new, kr_new = _mla_kv_a(p, x, positions, cfg)
    c_kv = jax.lax.dynamic_update_slice(cache.c_kv, c_new, (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache.k_rope, kr_new, (0, pos, 0))

    wkv_b = p["wkv_b"]["w"].reshape(R, H, nope + vd)
    w_k = wkv_b[..., :nope]                                  # [R,H,nope]
    w_v = wkv_b[..., nope:]                                  # [R,H,vd]
    # absorb: q' = q_nope @ w_k^T  -> latent-space query [B,1,H,R]
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_k)
    scale = (nope + rope_d) ** -0.5
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat, c_kv)
              + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope))
    scores = scores.astype(jnp.float32) * scale
    S_max = c_kv.shape[1]
    mask = (jnp.arange(S_max)[None, None, None, :] <= pos)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out_lat = jnp.einsum("bhqs,bsr->bqhr", probs, c_kv)      # [B,1,H,R]
    out = jnp.einsum("bqhr,rhd->bqhd", out_lat, w_v)         # [B,1,H,vd]
    out = L.linear(p["wo"], out.reshape(B, 1, -1))
    return out, MLACache(c_kv=c_kv, k_rope=k_rope)
