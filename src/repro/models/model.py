"""Model assembly: scanned layer stacks + unified train/prefill/decode API.

Compile-time discipline: homogeneous layer stacks are ``jax.lax.scan``-ned
over stacked parameters (leading [L] axis), so HLO size is O(1) in depth —
required to dry-run 60-layer/236B configs on a CPU-host compile.

API (uniform across families):
  init_params(rng, cfg)                 -> params pytree
  forward(params, batch, cfg)           -> (logits, aux_loss)   # full seq
  loss_fn(params, batch, cfg)           -> scalar loss
  init_cache(cfg, batch_size, s_max)    -> cache pytree (zeros)
  decode_step(params, token, cache, pos, cfg) -> (logits, cache)
``batch``: {"tokens": [B,S], "labels": [B,S]} plus per-family stub inputs
("audio_embeds" for whisper, "patch_embeds" for llava).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import mamba2 as M


# ----------------------------------------------------------------- helpers
def _stack_init(block_init, rng, n, cfg, dtype, **kw):
    rngs = jax.random.split(rng, n)
    return jax.vmap(lambda r: block_init(r, cfg, dtype, **kw))(rngs)


def scan_decode(layer_params, cache, x, apply_fn, n_layers: int):
    """Decode-path layer scan with the stacked cache as loop CARRY.

    Carrying the cache (instead of slicing it as scan xs and restacking as
    ys) lets XLA update the [L, ...] cache buffers in place per layer —
    the xs/ys form double-buffers and copies the FULL stacked cache every
    iteration, which dominated decode HBM traffic (EXPERIMENTS.md §Perf,
    iteration C1: 68% of all bytes)."""

    def body(carry, xs):
        h, cache_st = carry
        p_l, idx = xs
        cache_l = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0,
                                                   keepdims=False),
            cache_st)
        h, new_l = apply_fn(p_l, h, cache_l)
        cache_st = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(c, n, idx, 0),
            cache_st, new_l)
        return (h, cache_st), None

    (x, cache), _ = jax.lax.scan(
        body, (x, cache), (layer_params, jnp.arange(n_layers)))
    return x, cache


def _maybe_remat(fn, remat: bool):
    return jax.checkpoint(fn) if remat else fn


def _zero_kv(bsz, s, kv_heads, dh, layers, dtype):
    shape = (layers, bsz, s, kv_heads, dh) if layers else (bsz, s, kv_heads,
                                                           dh)
    return A.KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


# =================================================================== dense
def _dense_init(rng, cfg, dtype):
    r = jax.random.split(rng, 3)
    p = {"embed": L.embedding_init(r[0], cfg.vocab_size, cfg.d_model, dtype),
         "layers": _stack_init(B.dense_block_init, r[1], cfg.n_layers, cfg,
                               dtype),
         "final_norm": L.rmsnorm_init(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = L.embedding_init(r[2], cfg.vocab_size, cfg.d_model,
                                        dtype)
    return p


def _logits(p, x, cfg):
    x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    table = p.get("unembed", p["embed"])
    return L.unembed(table, x)


def _dense_forward(p, batch, cfg, remat=False, constrain=None):
    c = constrain or (lambda t: t)
    x = L.embed(p["embed"], batch["tokens"])
    if cfg.family == "vlm":
        x = jnp.concatenate(
            [batch["patch_embeds"].astype(x.dtype), x], axis=1)
    x = c(x)

    def body(h, p_l):
        h, _ = B.dense_block_full(p_l, h, cfg, window=cfg.sliding_window)
        return c(h), None

    x, _ = jax.lax.scan(_maybe_remat(body, remat), x, p["layers"])
    return _logits(p, x, cfg), jnp.float32(0.0)


def _flat_kv_zeros(cfg, bsz, s_max, layers, dtype):
    """Stacked decode cache, KV-major [L, B, KV, S, dh] (see §Perf C2)."""
    w = min(cfg.sliding_window, s_max) if cfg.sliding_window else s_max
    shape = (layers, bsz, cfg.n_kv_heads, w, cfg.head_dim)
    return A.KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


import os

#: §Perf baseline reference: the pre-hillclimb decode structure (scan
#: xs/ys cache restacking + [L,B,S,KV,dh] layout).  Selected with
#: REPRO_LEGACY_DECODE=1 so iteration deltas stay reproducible.
_LEGACY_DECODE = os.environ.get("REPRO_LEGACY_DECODE") == "1"


def _dense_cache(cfg, bsz, s_max, dtype):
    if _LEGACY_DECODE:
        w = min(cfg.sliding_window, s_max) if cfg.sliding_window else s_max
        return _zero_kv(bsz, w, cfg.n_kv_heads, cfg.head_dim, cfg.n_layers,
                        dtype)
    return _flat_kv_zeros(cfg, bsz, s_max, cfg.n_layers, dtype)


def _dense_decode_legacy(p, token, cache, pos, cfg):
    x = L.embed(p["embed"], token)

    def body(h, xs):
        p_l, c_l = xs
        h, c_l = B.dense_block_decode(p_l, h, c_l, pos, cfg,
                                      window=cfg.sliding_window)
        return h, c_l

    x, cache = jax.lax.scan(body, x, (p["layers"], cache))
    return _logits(p, x, cfg), cache


def _dense_decode(p, token, cache, pos, cfg):
    if _LEGACY_DECODE:
        return _dense_decode_legacy(p, token, cache, pos, cfg)
    x = L.embed(p["embed"], token)

    def body(carry, xs):
        h, k_st, v_st = carry
        p_l, idx = xs
        h, k_st, v_st = B.dense_block_decode_flat(
            p_l, h, k_st, v_st, idx, pos, cfg, window=cfg.sliding_window)
        return (h, k_st, v_st), None

    (x, k_st, v_st), _ = jax.lax.scan(
        body, (x, cache.k, cache.v),
        (p["layers"], jnp.arange(cfg.n_layers)))
    return _logits(p, x, cfg), A.KVCache(k=k_st, v=v_st)


# ==================================================================== MoE
# llama4-style: alternating dense / MoE super-layers.
def _moe_alt_init(rng, cfg, dtype):
    r = jax.random.split(rng, 4)
    n_super = cfg.n_layers // 2
    return {
        "embed": L.embedding_init(r[0], cfg.vocab_size, cfg.d_model, dtype),
        "dense_layers": _stack_init(B.dense_block_init, r[1], n_super, cfg,
                                    dtype, d_ff=cfg.dense_d_ff or cfg.d_ff),
        "moe_layers": _stack_init(B.moe_block_init, r[2], n_super, cfg,
                                  dtype),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "unembed": L.embedding_init(r[3], cfg.vocab_size, cfg.d_model,
                                    dtype),
    }


def _moe_alt_forward(p, batch, cfg, remat=False, constrain=None):
    c = constrain or (lambda t: t)
    x = c(L.embed(p["embed"], batch["tokens"]))

    def body(carry, xs):
        h, aux = carry
        pd, pm = xs
        h, _ = B.dense_block_full(pd, h, cfg, window=cfg.sliding_window)
        h, _, aux_l, load = B.moe_block_full(pm, h, cfg,
                                             window=cfg.sliding_window)
        return (c(h), aux + aux_l), load

    (x, aux), loads = jax.lax.scan(
        _maybe_remat(body, remat), (x, jnp.float32(0.0)),
        (p["dense_layers"], p["moe_layers"]))
    return _logits(p, x, cfg), aux


def _moe_alt_cache(cfg, bsz, s_max, dtype):
    n_super = cfg.n_layers // 2
    return {"dense": _flat_kv_zeros(cfg, bsz, s_max, n_super, dtype),
            "moe": _flat_kv_zeros(cfg, bsz, s_max, n_super, dtype)}


def _moe_alt_decode(p, token, cache, pos, cfg):
    x = L.embed(p["embed"], token)
    w = cfg.sliding_window

    def body(carry, xs):
        h, dk, dv, mk, mv = carry
        pd, pm, idx = xs
        h, dk, dv = B.dense_block_decode_flat(pd, h, dk, dv, idx, pos, cfg,
                                              window=w)
        h, (mk, mv), _ = B.moe_block_decode_flat(pm, h, (mk, mv), idx, pos,
                                                 cfg, window=w)
        return (h, dk, dv, mk, mv), None

    n_super = cfg.n_layers // 2
    (x, dk, dv, mk, mv), _ = jax.lax.scan(
        body,
        (x, cache["dense"].k, cache["dense"].v, cache["moe"].k,
         cache["moe"].v),
        (p["dense_layers"], p["moe_layers"], jnp.arange(n_super)))
    return _logits(p, x, cfg), {"dense": A.KVCache(k=dk, v=dv),
                                "moe": A.KVCache(k=mk, v=mv)}


# deepseek-style: first layer dense(MLA), remaining layers MoE(MLA).
def _moe_mla_init(rng, cfg, dtype):
    r = jax.random.split(rng, 5)
    return {
        "embed": L.embedding_init(r[0], cfg.vocab_size, cfg.d_model, dtype),
        "layer0": B.mla_dense_block_init(r[1], cfg, dtype),
        "moe_layers": _stack_init(B.moe_block_init, r[2],
                                  cfg.n_layers - cfg.first_dense, cfg,
                                  dtype),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "unembed": L.embedding_init(r[3], cfg.vocab_size, cfg.d_model,
                                    dtype),
    }


def _moe_mla_forward(p, batch, cfg, remat=False, constrain=None):
    c = constrain or (lambda t: t)
    x = c(L.embed(p["embed"], batch["tokens"]))
    x, _ = B.mla_dense_block_full(p["layer0"], x, cfg)
    x = c(x)

    def body(carry, p_l):
        h, aux = carry
        h, _, aux_l, load = B.moe_block_full(p_l, h, cfg)
        return (c(h), aux + aux_l), load

    (x, aux), _ = jax.lax.scan(_maybe_remat(body, remat),
                               (x, jnp.float32(0.0)), p["moe_layers"])
    return _logits(p, x, cfg), aux


def _mla_zero(bsz, s, cfg, layers, dtype):
    shape_c = (layers, bsz, s, cfg.kv_lora_rank) if layers else \
        (bsz, s, cfg.kv_lora_rank)
    shape_r = (layers, bsz, s, cfg.rope_head_dim) if layers else \
        (bsz, s, cfg.rope_head_dim)
    return A.MLACache(c_kv=jnp.zeros(shape_c, dtype),
                      k_rope=jnp.zeros(shape_r, dtype))


def _moe_mla_cache(cfg, bsz, s_max, dtype):
    return {"layer0": _mla_zero(bsz, s_max, cfg, 0, dtype),
            "moe": _mla_zero(bsz, s_max, cfg,
                             cfg.n_layers - cfg.first_dense, dtype)}


def _moe_mla_decode(p, token, cache, pos, cfg):
    x = L.embed(p["embed"], token)
    x, c0 = B.mla_dense_block_decode(p["layer0"], x, cache["layer0"], pos,
                                     cfg)

    def body(carry, xs):
        h, c_st, r_st = carry
        p_l, idx = xs
        h, (c_st, r_st), _ = B.moe_block_decode_flat(
            p_l, h, (c_st, r_st), idx, pos, cfg)
        return (h, c_st, r_st), None

    (x, c_st, r_st), _ = jax.lax.scan(
        body, (x, cache["moe"].c_kv, cache["moe"].k_rope),
        (p["moe_layers"], jnp.arange(cfg.n_layers - cfg.first_dense)))
    return _logits(p, x, cfg), {
        "layer0": c0, "moe": A.MLACache(c_kv=c_st, k_rope=r_st)}


# ==================================================================== SSM
def _ssm_init(rng, cfg, dtype):
    r = jax.random.split(rng, 3)
    return {"embed": L.embedding_init(r[0], cfg.vocab_size, cfg.d_model,
                                      dtype),
            "layers": _stack_init(B.mamba_block_init, r[1], cfg.n_layers,
                                  cfg, dtype),
            "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
            "unembed": L.embedding_init(r[2], cfg.vocab_size, cfg.d_model,
                                        dtype)}


def _ssm_forward(p, batch, cfg, remat=False, constrain=None):
    c = constrain or (lambda t: t)
    x = c(L.embed(p["embed"], batch["tokens"]))

    def body(h, p_l):
        h, _ = B.mamba_block_full(p_l, h, cfg)
        return c(h), None

    x, _ = jax.lax.scan(_maybe_remat(body, remat), x, p["layers"])
    return _logits(p, x, cfg), jnp.float32(0.0)


def _mamba_zero(cfg, bsz, layers, dtype):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    shape_c = ((layers, bsz, cfg.conv_kernel - 1, conv_dim) if layers else
               (bsz, cfg.conv_kernel - 1, conv_dim))
    shape_s = ((layers, bsz, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)
               if layers else
               (bsz, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state))
    return M.MambaCache(conv=jnp.zeros(shape_c, dtype),
                        ssm=jnp.zeros(shape_s, dtype))


def _ssm_cache(cfg, bsz, s_max, dtype):
    del s_max  # recurrent state: O(1) in sequence length
    return _mamba_zero(cfg, bsz, cfg.n_layers, dtype)


def _ssm_decode(p, token, cache, pos, cfg):
    del pos
    x = L.embed(p["embed"], token)
    x, cache = scan_decode(
        p["layers"], cache, x,
        lambda p_l, h, c_l: B.mamba_block_decode(p_l, h, c_l, cfg),
        cfg.n_layers)
    return _logits(p, x, cfg), cache


# ================================================================= hybrid
# zamba2-style: groups of mamba layers with ONE shared attention block
# (weights reused at every application) between groups.
def _hybrid_dims(cfg):
    group = cfg.attn_every
    n_groups = cfg.n_layers // group
    tail = cfg.n_layers - n_groups * group
    return group, n_groups, tail


def _hybrid_init(rng, cfg, dtype):
    r = jax.random.split(rng, 5)
    group, n_groups, tail = _hybrid_dims(cfg)
    p = {"embed": L.embedding_init(r[0], cfg.vocab_size, cfg.d_model, dtype),
         "mamba_groups": jax.vmap(
             lambda rr: _stack_init(B.mamba_block_init, rr, group, cfg,
                                    dtype))(jax.random.split(r[1], n_groups)),
         "shared_attn": B.dense_block_init(r[2], cfg, dtype),
         "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
         "unembed": L.embedding_init(r[4], cfg.vocab_size, cfg.d_model,
                                     dtype)}
    if tail:
        p["mamba_tail"] = _stack_init(B.mamba_block_init, r[3], tail, cfg,
                                      dtype)
    return p


def _hybrid_forward(p, batch, cfg, remat=False, constrain=None):
    c = constrain or (lambda t: t)
    x = c(L.embed(p["embed"], batch["tokens"]))
    group, n_groups, tail = _hybrid_dims(cfg)

    def inner(h, p_l):
        h, _ = B.mamba_block_full(p_l, h, cfg)
        return h, None

    def outer(h, p_g):
        h, _ = jax.lax.scan(inner, h, p_g)
        h, _ = B.dense_block_full(p["shared_attn"], h, cfg)  # shared weights
        return c(h), None

    x, _ = jax.lax.scan(_maybe_remat(outer, remat), x, p["mamba_groups"])
    if tail:
        x, _ = jax.lax.scan(inner, x, p["mamba_tail"])
    return _logits(p, x, cfg), jnp.float32(0.0)


def _hybrid_cache(cfg, bsz, s_max, dtype):
    group, n_groups, tail = _hybrid_dims(cfg)
    c = {"mamba_groups": jax.tree.map(
            lambda z: jnp.broadcast_to(z[None], (n_groups,) + z.shape),
            _mamba_zero(cfg, bsz, group, dtype)),
         "attn": _zero_kv(bsz, s_max, cfg.n_kv_heads, cfg.head_dim,
                          n_groups, dtype)}
    if tail:
        c["mamba_tail"] = _mamba_zero(cfg, bsz, tail, dtype)
    return c


def _hybrid_decode(p, token, cache, pos, cfg):
    x = L.embed(p["embed"], token)
    group, n_groups, tail = _hybrid_dims(cfg)

    def inner_apply(p_l, h, c_l):
        return B.mamba_block_decode(p_l, h, c_l, cfg)

    def outer_apply(p_g, h, c_g):
        h, mamba_c = scan_decode(p_g, c_g["mamba"], h, inner_apply, group)
        h, kv_g = B.dense_block_decode(p["shared_attn"], h, c_g["attn"],
                                       pos, cfg)
        return h, {"mamba": mamba_c, "attn": kv_g}

    x, outer_c = scan_decode(
        p["mamba_groups"],
        {"mamba": cache["mamba_groups"], "attn": cache["attn"]},
        x, outer_apply, n_groups)
    new = {"mamba_groups": outer_c["mamba"], "attn": outer_c["attn"]}
    if tail:
        x, c_tail = scan_decode(p["mamba_tail"], cache["mamba_tail"], x,
                                inner_apply, tail)
        new["mamba_tail"] = c_tail
    return _logits(p, x, cfg), new


# ================================================================= enc-dec
def _encdec_init(rng, cfg, dtype):
    r = jax.random.split(rng, 4)
    return {"embed": L.embedding_init(r[0], cfg.vocab_size, cfg.d_model,
                                      dtype),
            "enc_layers": _stack_init(B.encoder_block_init, r[1],
                                      cfg.n_enc_layers, cfg, dtype),
            "enc_norm": L.rmsnorm_init(cfg.d_model, dtype),
            "dec_layers": _stack_init(B.decoder_block_init, r[2],
                                      cfg.n_layers, cfg, dtype),
            "final_norm": L.rmsnorm_init(cfg.d_model, dtype)}


def _encode(p, audio_embeds, cfg):
    x = audio_embeds + L.sinusoidal_positions(
        audio_embeds.shape[1], cfg.d_model).astype(audio_embeds.dtype)[None]

    def body(h, p_l):
        return B.encoder_block_full(p_l, h, cfg), None

    x, _ = jax.lax.scan(body, x, p["enc_layers"])
    return L.rmsnorm(p["enc_norm"], x, cfg.norm_eps)


def _encdec_forward(p, batch, cfg, remat=False, constrain=None):
    c = constrain or (lambda t: t)
    enc_out = c(_encode(p, batch["audio_embeds"], cfg))
    S = batch["tokens"].shape[1]
    x = L.embed(p["embed"], batch["tokens"])
    x = c(x + L.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None])

    def body(h, p_l):
        enc_kv = B.cross_kv(p_l, enc_out, cfg)
        h, _ = B.decoder_block_full(p_l, h, enc_kv, cfg)
        return c(h), None

    x, _ = jax.lax.scan(_maybe_remat(body, remat), x, p["dec_layers"])
    x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    return L.unembed(p["embed"], x), jnp.float32(0.0)


def _encdec_cache(cfg, bsz, s_max, dtype):
    return {"self": _zero_kv(bsz, s_max, cfg.n_kv_heads, cfg.head_dim,
                             cfg.n_layers, dtype),
            "cross": _zero_kv(bsz, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim,
                              cfg.n_layers, dtype)}


def _encdec_decode(p, token, cache, pos, cfg):
    x = L.embed(p["embed"], token)
    x = x + L.sinusoidal_at(jnp.asarray(pos), cfg.d_model)[None, None] \
        .astype(x.dtype)

    # cross-KV is read-only: slice it as scan xs; carry only the self cache
    def body(carry, xs):
        h, self_st = carry
        p_l, cross_l, idx = xs
        self_l = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, idx, 0,
                                                   keepdims=False), self_st)
        h, self_c = B.decoder_block_decode(p_l, h, self_l, cross_l, pos,
                                           cfg)
        self_st = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(c, n, idx, 0),
            self_st, self_c)
        return (h, self_st), None

    (x, self_c), _ = jax.lax.scan(
        body, (x, cache["self"]),
        (p["dec_layers"], cache["cross"], jnp.arange(cfg.n_layers)))
    x = L.rmsnorm(p["final_norm"], x, cfg.norm_eps)
    return L.unembed(p["embed"], x), {"self": self_c,
                                      "cross": cache["cross"]}


# ================================================================ dispatch
_FAMILY = {
    "dense": (_dense_init, _dense_forward, _dense_cache, _dense_decode),
    "vlm": (_dense_init, _dense_forward, _dense_cache, _dense_decode),
    "ssm": (_ssm_init, _ssm_forward, _ssm_cache, _ssm_decode),
    "hybrid": (_hybrid_init, _hybrid_forward, _hybrid_cache, _hybrid_decode),
    "encdec": (_encdec_init, _encdec_forward, _encdec_cache, _encdec_decode),
}


def _family_fns(cfg):
    if cfg.family == "moe":
        if cfg.use_mla:
            return (_moe_mla_init, _moe_mla_forward, _moe_mla_cache,
                    _moe_mla_decode)
        return (_moe_alt_init, _moe_alt_forward, _moe_alt_cache,
                _moe_alt_decode)
    return _FAMILY[cfg.family]


def init_params(rng, cfg):
    dtype = L.dtype_of(cfg)
    return _family_fns(cfg)[0](rng, cfg, dtype)


def forward(params, batch, cfg, remat: bool = False, constrain=None):
    return _family_fns(cfg)[1](params, batch, cfg, remat, constrain)


def loss_fn(params, batch, cfg, remat: bool = False, constrain=None):
    logits, aux = forward(params, batch, cfg, remat, constrain)
    labels = batch["labels"]
    if cfg.family == "vlm":   # patch positions carry no labels
        pad = jnp.full(batch["patch_embeds"].shape[:2], -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    return L.cross_entropy(logits, labels, cfg.vocab_size) + aux


def init_cache(cfg, bsz: int, s_max: int):
    return _family_fns(cfg)[2](cfg, bsz, s_max, L.dtype_of(cfg))


def decode_step(params, token, cache, pos, cfg):
    """token: [B,1] int32; pos: scalar int32. -> (logits [B,1,V], cache)."""
    return _family_fns(cfg)[3](params, token, cache, pos, cfg)


def prefill(params, batch, cfg):
    """Full-sequence forward returning logits (cache wiring for serving is
    provided by the paged-KV tiering layer, repro.tiering)."""
    return forward(params, batch, cfg)[0]


@functools.lru_cache(maxsize=64)
def count_params(cfg) -> int:
    """Exact parameter count via eval_shape (no allocation)."""
    shapes = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    return int(sum(np.prod(s.shape) for s in jax.tree.leaves(shapes)))


def active_params(cfg) -> int:
    """Active parameters per token (MoE: routed experts count k-of-E)."""
    total = count_params(cfg)
    if cfg.family != "moe":
        return total
    # subtract inactive routed-expert weights
    F, D, E, k = cfg.moe_d_ff, cfg.d_model, cfg.n_experts, \
        cfg.experts_per_token
    n_moe_layers = (cfg.n_layers - cfg.first_dense if cfg.use_mla
                    else cfg.n_layers // 2)
    per_expert = 3 * D * F
    return total - n_moe_layers * per_expert * (E - k)
