"""Mamba2 (state-space duality / SSD) block — arXiv:2405.21060.

Implements the chunked SSD algorithm: intra-chunk (quadratic, attention-like)
term + inter-chunk recurrence carried by a sequential scan over chunks, which
is the TPU-friendly formulation (dense matmuls inside chunks feed the MXU,
the scan carries an [H, P, N] state).  The pure-jnp version here is the
oracle for the Pallas kernel in kernels/mamba_scan.

Decode is the exact SSM recurrence on a persistent [B, H, P, N] state plus a
rolling conv window — no KV cache at all (the reason long_500k is
SSM-eligible, DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L


def mamba2_init(rng, cfg, dtype) -> dict:
    D, di = cfg.d_model, cfg.d_inner
    N, H, K = cfg.ssm_state, cfg.ssm_heads, cfg.conv_kernel
    G = 1
    conv_dim = di + 2 * G * N
    r = jax.random.split(rng, 4)
    return {
        "in_proj": L.linear_init(
            r[0], D, 2 * di + 2 * G * N + H, dtype),
        "conv_w": (jax.random.normal(r[1], (K, conv_dim), jnp.float32)
                   * (1.0 / K ** 0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": L.rmsnorm_init(di, dtype),
        "out_proj": L.linear_init(r[2], di, D, dtype, scale=0.5),
    }


@dataclasses.dataclass(frozen=True)
class MambaCache:
    conv: jnp.ndarray    # [B, K-1, conv_dim] rolling conv window
    ssm: jnp.ndarray     # [B, H, P, N] recurrent state


jax.tree_util.register_dataclass(MambaCache, data_fields=["conv", "ssm"],
                                 meta_fields=[])


def _split_proj(zxbcdt, cfg):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    G = 1
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di: di + di + 2 * G * N]
    dt = zxbcdt[..., di + di + 2 * G * N:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv1d, kernel K. xBC: [B,S,Cd], w: [K,Cd]."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xBC.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _segsum(dA):
    """dA: [..., Q] -> [..., Q, Q]: sum_{j<m<=i} dA_m for i>=j else -inf."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # [..,i,j] = cs_i-cs_j
    ii, jj = jnp.arange(Q)[:, None], jnp.arange(Q)[None, :]
    return jnp.where(ii >= jj, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD scan.

    x: [B,S,H,P] (pre-multiplied inputs), dt: [B,S,H] (post-softplus),
    A: [H] (negative), Bm/Cm: [B,S,N] (single group).
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    dA = dtc * A                                         # [b,c,q,h] (<=0)
    dA_h = dA.transpose(0, 1, 3, 2)                      # [b,c,h,q]
    dA_cs = jnp.cumsum(dA_h, axis=-1)                    # [b,c,h,q]

    # 1. intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(dA_h))                        # [b,c,h,q,q]
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)           # [b,c,q,k]
    xdt = xc * dtc[..., None]                            # [b,c,q,h,p]
    y_diag = jnp.einsum("bchqk,bcqk,bckhp->bcqhp",
                        Lmat, CB.astype(Lmat.dtype), xdt)

    # 2. per-chunk input states (decay to end of chunk)
    decay_end = jnp.exp(dA_cs[..., -1:] - dA_cs)         # [b,c,h,q]
    states = jnp.einsum("bcqn,bchq,bcqhp->bchpn",
                        Bc, decay_end * dtc.transpose(0, 1, 3, 2), xc)

    # 3. inter-chunk recurrence (sequential scan over chunks)
    chunk_decay = jnp.exp(dA_cs[..., -1])                # [b,c,h]
    h0 = jnp.zeros((Bsz, H, P, N), x.dtype) if init_state is None \
        else init_state

    def scan_fn(h, inp):
        s_c, g_c = inp                                   # [b,h,p,n], [b,h]
        carried = h                                      # state BEFORE chunk
        h = h * g_c[..., None, None] + s_c
        return h, carried

    states_cm = states.transpose(1, 0, 2, 3, 4)          # [c,b,h,p,n]
    decay_cm = chunk_decay.transpose(1, 0, 2)            # [c,b,h]
    h_final, carried = jax.lax.scan(scan_fn, h0, (states_cm, decay_cm))
    carried = carried.transpose(1, 0, 2, 3, 4)           # [b,c,h,p,n]

    # 4. off-diagonal contribution from carried states
    decay_out = jnp.exp(dA_cs)                           # [b,c,h,q]
    y_off = jnp.einsum("bcqn,bchpn,bchq->bcqhp", Cc, carried, decay_out)

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, h_final


def mamba2_full(p, x, cfg):
    """Train/prefill. x: [B,S,D] -> (y [B,S,D], MambaCache)."""
    Bsz, S, D = x.shape
    di, N, H, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.conv_kernel
    P = cfg.ssm_head_dim

    zxbcdt = L.linear(p["in_proj"], x)
    z, xBC_raw, dt = _split_proj(zxbcdt, cfg)
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xs = xBC[..., :di].reshape(Bsz, S, H, P)
    Bm = xBC[..., di: di + N]
    Cm = xBC[..., di + N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    # pad S to a chunk multiple; padded steps have dt=0 (identity decay, no
    # input) so y[:S] and the final state are exact.
    Q = cfg.ssm_chunk
    S_pad = -(-S // Q) * Q
    if S_pad != S:
        pad = ((0, 0), (0, S_pad - S))
        xs_p = jnp.pad(xs, pad + ((0, 0), (0, 0)))
        dt_p = jnp.pad(dt, pad + ((0, 0),))
        Bm_p = jnp.pad(Bm, pad + ((0, 0),))
        Cm_p = jnp.pad(Cm, pad + ((0, 0),))
    else:
        xs_p, dt_p, Bm_p, Cm_p = xs, dt, Bm, Cm
    y, h_final = ssd_chunked(xs_p.astype(jnp.float32), dt_p, A,
                             Bm_p.astype(jnp.float32),
                             Cm_p.astype(jnp.float32), Q)
    y = y[:, :S]
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, S, di).astype(x.dtype)

    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = L.linear(p["out_proj"], y)
    # cache the raw (pre-conv) inputs so decode continues the conv window
    conv_cache = jnp.pad(
        xBC_raw, ((0, 0), (K - 1, 0), (0, 0)))[:, -(K - 1):]
    return out, MambaCache(conv=conv_cache, ssm=h_final.astype(x.dtype))


def mamba2_decode(p, x, cache: MambaCache, cfg):
    """One-token recurrent step. x: [B,1,D] -> (y [B,1,D], cache)."""
    Bsz = x.shape[0]
    di, N, H, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.conv_kernel
    P = cfg.ssm_head_dim

    zxbcdt = L.linear(p["in_proj"], x)[:, 0]             # [B, *]
    z, xBC_new, dt = _split_proj(zxbcdt, cfg)
    window = jnp.concatenate([cache.conv, xBC_new[:, None, :]], axis=1)
    conv_out = jax.nn.silu(
        (window * p["conv_w"][None]).sum(axis=1) + p["conv_b"])
    xs = conv_out[..., :di].reshape(Bsz, H, P)
    Bm = conv_out[..., di: di + N]
    Cm = conv_out[..., di + N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                 # [B,H]
    h = cache.ssm.astype(jnp.float32)
    h = (h * dA[..., None, None]
         + jnp.einsum("bh,bhp,bn->bhpn", dt, xs.astype(jnp.float32),
                      Bm.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(Bsz, di).astype(x.dtype)

    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = L.linear(p["out_proj"], y)[:, None, :]
    return out, MambaCache(conv=window[:, 1:], ssm=h.astype(x.dtype))
