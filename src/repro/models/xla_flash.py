"""Flash-pattern attention in pure XLA (§Perf iteration A4).

An online-softmax scan over KV blocks: the [Sq, Sk] score matrix is never
materialized — only one [Sq, block] tile per step plus the carried
(max, denom, accumulator) state.  In the HLO this collapses the naive
path's ~8 full-score-tensor HBM round-trips (dot out, mask-select,
subtract-exp, reduce, divide, transpose-copy, PV read, backward) into ~2
per tile — the same traffic shape as the Pallas flash kernel, expressible
without custom kernels, so the dry-run artifact reflects it.

Enabled for sequences >= FLASH_MIN_SEQ (prefill/train lowerings); short
sequences (smoke tests) keep the naive path.  Equivalence pinned by
tests/test_flash_equivalence.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

FLASH_MIN_SEQ = 4096
BLOCK = 2048
NEG_INF = -1e30


def flash_sdpa(q, k, v, scale: float, *, causal: bool = True,
               window: int = 0, block: int = BLOCK):
    """q: [B,H,Sq,dh], k: [B,H,Sk,dh], v: [B,H,Sk,vd] -> [B,H,Sq,vd].

    Computed in f32 accumulators with running max/denominator.
    """
    B, H, Sq, dh = q.shape
    Sk = k.shape[2]
    vd = v.shape[3]
    block = min(block, Sk)
    assert Sk % block == 0, (Sk, block)
    nb = Sk // block

    qf = q.astype(jnp.float32) * scale
    kb = k.astype(jnp.float32).reshape(B, H, nb, block, dh) \
        .transpose(2, 0, 1, 3, 4)                       # [nb,B,H,blk,dh]
    vb = v.astype(jnp.float32).reshape(B, H, nb, block, vd) \
        .transpose(2, 0, 1, 3, 4)

    q_pos = jnp.arange(Sq)[:, None]                     # [Sq,1]

    def body(carry, xs):
        m, l, acc = carry
        k_blk, v_blk, ib = xs
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, k_blk)    # [B,H,Sq,blk]
        k_pos = ib * block + jnp.arange(block)[None, :]
        mask = jnp.ones((Sq, block), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l = l * alpha + p.sum(-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, v_blk)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, vd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-30)
    return out.astype(q.dtype)


def use_flash(seq_len: int) -> bool:
    return seq_len >= FLASH_MIN_SEQ
