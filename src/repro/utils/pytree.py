"""Pytree dataclass helpers.

``pytree_dataclass`` registers a frozen dataclass whose fields are jax data
(arrays / scalars) so instances flow through jit/scan/vmap; fields named in
``meta`` are hashable aux data instead (static under jit, part of the
treedef).  ``static_dataclass`` is a frozen, hashable dataclass used for
configuration objects that are closed over (static) in jitted functions.
"""
from __future__ import annotations

import dataclasses

import jax


def _replace(self, **kw):
    return dataclasses.replace(self, **kw)


def pytree_dataclass(cls=None, *, meta: tuple = ()):
    def wrap(c):
        c = dataclasses.dataclass(frozen=True)(c)
        fields = [f.name for f in dataclasses.fields(c)
                  if f.name not in meta]
        jax.tree_util.register_dataclass(c, data_fields=fields,
                                         meta_fields=list(meta))
        c.replace = _replace
        return c

    return wrap(cls) if cls is not None else wrap


def static_dataclass(cls=None):
    def wrap(c):
        return dataclasses.dataclass(frozen=True)(c)

    return wrap(cls) if cls is not None else wrap
