"""Tracing-time mesh context for internal activation-sharding constraints.

Layer code (e.g. the MoE dispatch) sometimes needs constraints on tensors
whose layout the generic batch-dim hook cannot describe (expert buffers).
The step factories enter ``use_mesh(mesh)`` while tracing; ``constrain``
is a no-op outside the context or when an axis is absent from the mesh.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding, PartitionSpec

_MESH = contextvars.ContextVar("repro_act_mesh", default=None)


@contextlib.contextmanager
def use_mesh(mesh):
    tok = _MESH.set(mesh)
    try:
        yield
    finally:
        _MESH.reset(tok)


def current_mesh():
    return _MESH.get()


def constrain(x, spec_entries):
    """spec_entries: tuple of axis names / tuples / None per dim; entries
    naming axes absent from the mesh (or dims not divisible) collapse to
    None."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def ok(entry, dim):
        if entry is None:
            return None
        axes = entry if isinstance(entry, tuple) else (entry,)
        if not all(a in names for a in axes):
            return None
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        return entry if dim % size == 0 and dim >= size else None

    spec = PartitionSpec(*(ok(e, d) for e, d in zip(spec_entries, x.shape)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
