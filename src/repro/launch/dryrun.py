import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every assigned (architecture x input shape) cell, lower + compile the
appropriate step function on the production mesh — 16x16 (single-pod) and
2x16x16 (multi-pod) — and record memory_analysis / cost_analysis /
collective bytes as JSON artifacts consumed by the roofline report.

The two XLA_FLAGS lines above MUST run before any other import: jax locks
the device count at first initialization.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
      --shape train_4k --mesh pod1
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from pathlib import Path  # noqa: E402

import jax          # noqa: E402

from repro import roofline  # noqa: E402
from repro.configs import registry  # noqa: E402
from repro.configs.base import shape_applicable  # noqa: E402
from repro.launch import sharding, specs, steps  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.optim import adamw  # noqa: E402

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# grad-accumulation per train cell: keeps per-microbatch tokens/device ~4k.
GRAD_ACCUM = 8


def _sanitize(d):
    # cost_analysis() returns a single dict on newer jax, [dict] on older.
    if isinstance(d, (list, tuple)):
        d = d[0] if d else {}
    out = {}
    for k, v in (d or {}).items():
        try:
            out[k] = float(v)
        except (TypeError, ValueError):
            out[k] = str(v)
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               opt_overrides=None, sharding_overrides=None,
               dtype: str = "float32", force: bool = False):
    """Lower + compile one cell; returns the result record (dict).

    Roofline artifacts are lowered with a UNIFORM f32 model dtype: the CPU
    backend lowers bf16 dots via f32 with whole-buffer convert churn that a
    TPU lowering does not have, polluting byte accounting.  An f32-uniform
    module is structurally identical to the TPU bf16 module; the reported
    bf16-target memory term is bytes * 0.5 (documented in EXPERIMENTS.md).
    """
    import dataclasses
    cfg = registry.get_arch(arch)
    if dtype and cfg.dtype != dtype:
        cfg = dataclasses.replace(cfg, dtype=dtype)
    shape = registry.get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    if not ok and not force:
        return {"arch": cfg.name, "shape": shape.name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        params_sds = specs.param_specs(cfg)
        p_shard = sharding.param_shardings(params_sds, mesh)

        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig()
            opt_sds = specs.opt_specs(cfg, opt_cfg, params_sds)
            o_shard = sharding.param_shardings(
                jax.tree.map(lambda x: x, opt_sds), mesh)
            batch_sds = specs.batch_specs(cfg, shape)
            b_shard = sharding.batch_sharding(mesh, batch_sds)
            step = steps.make_train_step(cfg, opt_cfg,
                                         grad_accum=GRAD_ACCUM, remat=True,
                                         mesh=mesh)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None))
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            batch_sds = specs.batch_specs(cfg, shape, with_labels=False)
            b_shard = sharding.batch_sharding(mesh, batch_sds)
            step = steps.make_prefill_step(cfg, mesh=mesh)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            token, cache, pos = specs.decode_specs(cfg, shape)
            t_shard = sharding.batch_sharding(mesh, token)
            c_shard = sharding.cache_sharding(mesh, cache)
            p_shard = sharding.param_shardings(params_sds, mesh,
                                               serve=True)
            step = steps.make_serve_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, t_shard, c_shard,
                              sharding.replicated(mesh)),
                out_shardings=(t_shard, c_shard),
                donate_argnums=(2,))   # serving consumes the old cache
            lowered = jitted.lower(params_sds, token, cache, pos)

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = _sanitize(compiled.cost_analysis())
    hlo = compiled.as_text()
    chips = 512 if multi_pod else 256
    # scan-aware per-device cost model (XLA cost_analysis counts while
    # bodies once; see roofline.analyze_hlo) -> globals = per-device * chips
    analysis = roofline.analyze_hlo(hlo)
    coll = {k: int(v) for k, v in analysis["collectives"].items()}
    terms = roofline.roofline(
        {"flops": analysis["flops"] * chips,
         "bytes accessed": analysis["bytes"] * chips},
        coll["_total"] * chips, chips)
    mflops = roofline.model_flops(cfg, shape)

    rec = {
        "arch": cfg.name, "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "chips": chips,
        "memory_analysis": {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)},
        "cost_analysis": {k: cost[k] for k in ("flops", "bytes accessed")
                          if k in cost},
        "collectives": coll,
        "roofline": terms.row(),
        "model_flops": mflops,
        "useful_flops_ratio": (mflops / terms.flops) if terms.flops else None,
        "params": int(jax.tree.reduce(
            lambda a, b: a + b,
            jax.tree.map(lambda x: 1.0 * x.size, params_sds))),
    }
    return rec


def run_cells(cells, meshes, out_dir: Path, skip_existing: bool = False,
              args_ns=None):
    out_dir.mkdir(parents=True, exist_ok=True)
    results = []
    for arch, shape_name in cells:
        for mesh_name in meshes:
            multi = mesh_name == "pod2"
            tag = f"{arch}__{shape_name}__{mesh_name}"
            path = out_dir / f"{tag}.json"
            if skip_existing and path.exists():
                rec = json.loads(path.read_text())
                if rec.get("status") in ("ok", "skipped"):
                    results.append(rec)
                    print(f"[dryrun] {tag}: cached {rec['status']}",
                          flush=True)
                    continue
            try:
                rec = lower_cell(arch, shape_name, multi,
                                 force=getattr(args_ns, "force", False))
            except Exception as e:   # a failure here is a sharding bug
                rec = {"arch": arch, "shape": shape_name,
                       "mesh": mesh_name, "status": "FAILED",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
            path.write_text(json.dumps(rec, indent=2))
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f" compile={rec['compile_s']}s"
                         f" dom={r['dominant']}"
                         f" comp={r['compute_s']:.3e}s"
                         f" mem={r['memory_s']:.3e}s"
                         f" coll={r['collective_s']:.3e}s")
            print(f"[dryrun] {tag}: {status}{extra}", flush=True)
            results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2",
                                                       "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="lower a cell the assignment rules would skip "
                         "(extra, non-assigned artifacts)")
    ap.add_argument("--out", default=str(ARTIFACTS))
    args = ap.parse_args()

    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a.name, s.name) for a, s, _ok, _why in registry.all_cells()]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = run_cells(cells, meshes, Path(args.out),
                        skip_existing=args.skip_existing, args_ns=args)
    failed = [r for r in results if r["status"] == "FAILED"]
    print(f"[dryrun] done: {len(results)} cells, {len(failed)} failed")
    raise SystemExit(1 if failed else 0)


if __name__ == "__main__":
    main()
