"""End-to-end training launcher (deliverable b: the train driver).

Wires every substrate together: config registry, synthetic data pipeline
with prefetch, sharded train step (grad accumulation + AdamW), async
checkpointing with restart, preemption handling (SIGTERM -> checkpoint ->
clean exit), and straggler monitoring (ARMS EWMA/PHT on per-host step
times).

CPU-scale by default (reduced configs); pass --full to run the real config
(requires TPU-scale memory).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import store
from repro.configs import registry
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.ft.preemption import PreemptionGuard
from repro.ft.stragglers import StragglerMonitor
from repro.launch import steps as steps_lib
from repro.optim import adamw
from repro.models import model as M


def train(arch: str, n_steps: int, batch: int, seq: int, ckpt_dir=None,
          restore: bool = False, full: bool = False, grad_accum: int = 1,
          ckpt_every: int = 20, log_every: int = 5, seed: int = 0):
    cfg = registry.get_arch(arch)
    if not full:
        cfg = registry.reduced(cfg)
    opt_cfg = adamw.AdamWConfig(total_steps=max(n_steps, 2),
                                warmup_steps=max(n_steps // 10, 1))

    rng = jax.random.PRNGKey(seed)
    params = M.init_params(rng, cfg)
    opt_state = adamw.init(params, opt_cfg)
    start_step = 0
    ckpt = store.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    if restore and ckpt_dir and store.latest_step(ckpt_dir) is not None:
        (params, opt_state), start_step = store.restore(
            (params, opt_state), ckpt_dir)
        print(f"[train] restored step {start_step}")

    data = SyntheticLM(cfg.vocab_size_raw, seq, batch, seed=seed)
    prefetch = Prefetcher(data, start_step=start_step)
    step_fn = jax.jit(steps_lib.make_train_step(
        cfg, opt_cfg, grad_accum=grad_accum, remat=False))
    monitor = StragglerMonitor(n_hosts=jax.process_count())

    losses = []
    with PreemptionGuard() as guard:
        for i in range(start_step, n_steps):
            step_t0 = time.time()
            step_idx, batch_np = prefetch.next()
            assert step_idx == i, (step_idx, i)
            jbatch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()}
            if cfg.family == "encdec":
                jbatch["audio_embeds"] = jax.numpy.zeros(
                    (batch, cfg.enc_seq, cfg.d_model), jax.numpy.float32)
            if cfg.family == "vlm":
                jbatch["patch_embeds"] = jax.numpy.zeros(
                    (batch, cfg.n_patches, cfg.d_model), jax.numpy.float32)
            params, opt_state, metrics = step_fn(params, opt_state, jbatch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - step_t0
            rep = monitor.observe(np.full(jax.process_count(), dt))
            if rep.flagged.any():
                print(f"[train] straggler hosts: "
                      f"{np.flatnonzero(rep.flagged).tolist()}")
            if i % log_every == 0:
                tok_s = batch * seq / max(dt, 1e-9)
                print(f"[train] step {i} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"{tok_s:,.0f} tok/s", flush=True)
            if ckpt and (i + 1) % ckpt_every == 0:
                ckpt.save((params, opt_state), i + 1)
            if guard.preempted:
                print("[train] preemption signal: checkpoint + exit")
                if ckpt:
                    ckpt.save((params, opt_state), i + 1)
                break
    if ckpt:
        ckpt.wait()
    prefetch.close()
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    losses = train(args.arch, args.steps, args.batch, args.seq,
                   ckpt_dir=args.ckpt_dir, restore=args.restore,
                   full=args.full, grad_accum=args.grad_accum)
    print(f"[train] final loss {losses[-1]:.4f} "
          f"(from {losses[0]:.4f} over {len(losses)} steps)")


if __name__ == "__main__":
    main()
