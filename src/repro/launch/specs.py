"""ShapeDtypeStruct stand-ins for every model input (deliverable e, step 2).

Weak-type-correct, shardable, no device allocation: the dry-run lowers
against these.  Stub-frontend archs get precomputed frame/patch embeddings
per the assignment ("the modality frontend is a STUB").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import model as M

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg, shape, with_labels: bool = True):
    """Training / prefill batch ShapeDtypeStructs."""
    B, S = shape.global_batch, shape.seq_len
    dtype = L.dtype_of(cfg)
    spec = {}
    if cfg.family == "vlm":
        spec["tokens"] = SDS((B, S - cfg.n_patches), jnp.int32)
        spec["patch_embeds"] = SDS((B, cfg.n_patches, cfg.d_model), dtype)
        if with_labels:
            spec["labels"] = SDS((B, S - cfg.n_patches), jnp.int32)
    elif cfg.family == "encdec":
        spec["tokens"] = SDS((B, S), jnp.int32)
        spec["audio_embeds"] = SDS((B, cfg.enc_seq, cfg.d_model), dtype)
        if with_labels:
            spec["labels"] = SDS((B, S), jnp.int32)
    else:
        spec["tokens"] = SDS((B, S), jnp.int32)
        if with_labels:
            spec["labels"] = SDS((B, S), jnp.int32)
    return spec


def decode_specs(cfg, shape):
    """(token, cache, pos) ShapeDtypeStructs for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: M.init_cache(cfg, B, S))
    token = SDS((B, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    return token, cache, pos


def param_specs(cfg, rng_seed: int = 0):
    return jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(rng_seed), cfg))


def opt_specs(cfg, opt_cfg, params_sds):
    from repro.optim import adamw
    return jax.eval_shape(lambda p: adamw.init(p, opt_cfg), params_sds)


def input_specs(cfg, shape):
    """All inputs for the step function of this (arch x shape) cell."""
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, shape, with_labels=False)}
    token, cache, pos = decode_specs(cfg, shape)
    return {"token": token, "cache": cache, "pos": pos}
