"""Step factories: train (grad-accumulation + AdamW), prefill, serve.

These are the functions the dry-run lowers and the launchers execute.
Gradient accumulation is a lax.scan over microbatches — bounding live
activation memory and letting XLA overlap the per-microbatch reduce
collectives with the next microbatch's compute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim import adamw


def make_activation_constraint(mesh):
    """Per-layer activation sharding pin: batch over the DP axes.

    Without this, SPMD propagation loses the batch sharding inside deep
    scans (observed: every device processing the FULL batch through
    attention — §Perf iteration A5) and silently replicates activations.
    """
    if mesh is None:
        return None
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import dp_axes
    dp = dp_axes(mesh)

    def constrain(x):
        spec = P(dp, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return constrain


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, grad_accum: int = 1,
                    remat: bool = True, mesh=None):
    """-> train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  batch leading dim must be divisible by grad_accum."""
    from repro.utils import act_sharding
    constrain = make_activation_constraint(mesh)

    def micro_loss(params, micro):
        with act_sharding.use_mesh(mesh):
            return M.loss_fn(params, micro, cfg, remat=remat,
                             constrain=constrain)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(micro_loss)(params, batch)
        else:
            micros = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, micro):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(micro_loss)(params, micro)
                grad_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero),
                                            micros)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)

        params, opt_state, metrics = adamw.update(grads, opt_state, params,
                                                  opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, mesh=None):
    from repro.utils import act_sharding
    constrain = make_activation_constraint(mesh)

    def prefill_step(params, batch):
        with act_sharding.use_mesh(mesh):
            logits, _ = M.forward(params, batch, cfg, constrain=constrain)
        return logits

    return prefill_step


def make_serve_step(cfg, greedy: bool = True):
    """One decode step: embeds, L-layer stack against the KV/state cache,
    unembed, greedy next-token."""

    def serve_step(params, token, cache, pos):
        logits, cache = M.decode_step(params, token, cache, pos, cfg)
        if greedy:  # [B,1] so the output feeds the next step's input
            nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            return nxt, cache
        return logits, cache

    return serve_step
