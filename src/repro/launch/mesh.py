"""Production mesh construction (deliverable e, step 1).

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS for 512 host devices
before any jax import; tests and benches see the single real device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic variant: any shape whose product divides the device count."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def dp_axes(mesh) -> tuple:
    """Data-parallel axes = every axis that isn't 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
