"""Serving launcher with ARMS-tiered paged KV cache (deliverable b).

Runs batched greedy decoding for a (reduced by default) architecture with
the attention KV cache paged across fast/slow tiers under the ARMS
controller, and reports throughput + tiering telemetry (promotions, fast-
tier hit mass — the paper's Fig. 8/10 signals at the serving layer).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b \
      --tokens 96 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import model as M
from repro.tiering import paged_kv as PK


def serve(arch: str, n_tokens: int, batch: int, full: bool = False,
          page_size: int = 16, fast_frac: float = 0.25, seed: int = 0):
    cfg = registry.get_arch(arch)
    if not full:
        cfg = registry.reduced(cfg)
    if cfg.family in ("ssm",):
        raise SystemExit(f"{arch}: attention-free arch — KV tiering "
                         "inapplicable (DESIGN.md §5); use plain decode.")
    rng = jax.random.PRNGKey(seed)
    params = M.init_params(rng, cfg)

    n_pages = max(4, -(-n_tokens // page_size))
    pk_cfg = PK.PagedKVConfig(
        page_size=page_size, n_pages=n_pages,
        fast_pages=max(1, int(n_pages * fast_frac)), policy_every=4)

    # one tiered paged-KV per attention layer is the production layout;
    # for the driver we tier layer 0 and use the model decode for the rest
    # of the stack (keeps the example readable).
    kv = PK.init_paged_kv(pk_cfg, batch, cfg.n_kv_heads, cfg.head_dim,
                          dtype=jnp.float32)
    cache = M.init_cache(cfg, batch, n_pages * page_size)

    token = jnp.zeros((batch, 1), jnp.int32)
    t0 = time.time()
    promotions = 0
    fast_mass = []
    for t in range(n_tokens):
        logits, cache = M.decode_step(params, token, cache, jnp.int32(t),
                                      cfg)
        token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        # drive the tiered layer with this step's q/k/v telemetry
        q = jax.random.normal(jax.random.fold_in(rng, t),
                              (batch, cfg.n_heads, cfg.head_dim))
        k_new = jax.random.normal(jax.random.fold_in(rng, 2 * t),
                                  (batch, cfg.n_kv_heads, cfg.head_dim))
        _, kv, plan = PK.serve_decode_step(kv, q, k_new, k_new,
                                           jnp.int32(t), pk_cfg)
        promotions += int(plan.count)
        hot_mass = float(jnp.where(kv.in_fast, kv.arms.ewma_l, 0.0).sum())
        tot_mass = float(kv.arms.ewma_l.sum())
        fast_mass.append(hot_mass / max(tot_mass, 1e-9))
    dt = time.time() - t0
    tok_s = n_tokens * batch / dt
    print(f"[serve] {arch}: {n_tokens} steps x {batch} seqs = "
          f"{tok_s:,.0f} tok/s")
    print(f"[serve] tiering: {promotions} page promotions, "
          f"fast-tier attention-mass share (end) = {fast_mass[-1]:.2%}")
    return tok_s, promotions, fast_mass


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    serve(args.arch, args.tokens, args.batch, full=args.full)


if __name__ == "__main__":
    main()
