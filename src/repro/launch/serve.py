"""Serving launcher with a policy-tiered paged KV cache (deliverable b).

Runs batched greedy decoding for a (reduced by default) architecture with
the attention KV cache paged across fast/slow tiers under ANY registered
placement policy (``--policy``, every family in
``experiment.POLICY_REGISTRY``), and reports throughput plus the SAME
slowdown/thrash telemetry as the robustness leaderboard
(benchmarks/bench_robustness.py): modeled tiered-vs-all-fast wall ratio,
wasteful-migration fraction, promotions/demotions.

Telemetry accumulates in a device-side carry (the TieredPool) and syncs
ONCE after the decode loop; ``--sync-telemetry`` restores the legacy
per-token host-sync path (kept for the before/after tok/s comparison in
benchmarks/bench_serving.py).  ``--capture`` saves the per-interval
paged-KV attention-mass stream as a replayable ``TraceWorkload``
(simulator/traces.py) — the capture->fit pipeline that turns serving
traffic into sweep/tuning/leaderboard lanes.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b \
      --tokens 96 --batch 4 --policy memtis
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import model as M
from repro.tiering import paged_kv as PK
from repro.tiering import tiered_pool as TP


@dataclasses.dataclass
class ServeReport:
    """One serving run's throughput + leaderboard-style telemetry."""
    arch: str
    policy: str
    tok_s: float
    promotions: int
    demotions: int
    wasteful: int
    thrash: float            # wasteful / migrations (leaderboard metric)
    slowdown: float          # modeled tiered wall / all-fast wall
    fast_mass: np.ndarray    # [T] fast-tier attention-mass share per step
    telemetry: dict          # full tiered_pool.telemetry record
    trace: object = None     # TraceWorkload when capture=True
    kv: object = None        # final PagedKV (tests inspect the pools)


def serve(arch: str, n_tokens: int, batch: int, full: bool = False,
          page_size: int = 16, fast_frac: float = 0.25, seed: int = 0,
          policy: str = "arms", machine: str = TP.DEFAULT_MACHINE,
          sync_telemetry: bool = False, capture: bool = False,
          quiet: bool = False) -> ServeReport:
    cfg = registry.get_arch(arch)
    if not full:
        cfg = registry.reduced(cfg)
    if cfg.family in ("ssm",):
        raise SystemExit(f"{arch}: attention-free arch — KV tiering "
                         "inapplicable (DESIGN.md §5); use plain decode.")
    rng = jax.random.PRNGKey(seed)
    params = M.init_params(rng, cfg)

    n_pages = max(4, -(-n_tokens // page_size))
    pk_cfg = PK.PagedKVConfig(
        page_size=page_size, n_pages=n_pages,
        fast_pages=max(1, int(n_pages * fast_frac)), policy_every=4,
        machine=machine)

    # one tiered paged-KV per attention layer is the production layout;
    # for the driver we tier layer 0 and use the model decode for the rest
    # of the stack (keeps the example readable).
    kv = PK.init_paged_kv(pk_cfg, batch, cfg.n_kv_heads, cfg.head_dim,
                          dtype=jnp.float32, policy=policy)
    cache = M.init_cache(cfg, batch, n_pages * page_size)

    token = jnp.zeros((batch, 1), jnp.int32)
    t0 = time.time()
    promotions_sync = 0
    shares = []    # device scalars; one transfer after the loop
    masses = []    # device [n_pages] access rows (trace capture)
    # long-EWMA attention mass (the legacy fast-mass telemetry): the
    # share of DECAYED mass resident fast, not just this step's slice.
    mass_ewma = jnp.zeros((n_pages,), jnp.float32)
    for t in range(n_tokens):
        logits, cache = M.decode_step(params, token, cache, jnp.int32(t),
                                      cfg)
        token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        # drive the tiered layer with this step's q/k/v telemetry; K and V
        # are DISTINCT streams (the pools must be allowed to diverge).
        q = jax.random.normal(jax.random.fold_in(rng, 3 * t),
                              (batch, cfg.n_heads, cfg.head_dim))
        k_new = jax.random.normal(jax.random.fold_in(rng, 3 * t + 1),
                                  (batch, cfg.n_kv_heads, cfg.head_dim))
        v_new = jax.random.normal(jax.random.fold_in(rng, 3 * t + 2),
                                  (batch, cfg.n_kv_heads, cfg.head_dim))
        _, kv, plan = PK.serve_decode_step(kv, q, k_new, v_new,
                                           jnp.int32(t), pk_cfg)
        mass_ewma = 0.98 * mass_ewma + plan.access
        shares.append((mass_ewma * kv.pool.in_fast).sum()
                      / jnp.maximum(mass_ewma.sum(), 1e-9))
        if capture:
            masses.append(plan.access)
        if sync_telemetry:
            # legacy per-token host-sync path (perf comparison only)
            promotions_sync += int(plan.count)
            float(plan.fast_share)
    jax.block_until_ready(kv.pool)
    dt = time.time() - t0
    tok_s = n_tokens * batch / dt

    tele = TP.telemetry(kv.pool)                   # the one host sync
    fast_mass = np.asarray(jnp.stack(shares))
    trace = None
    if capture:
        from repro.simulator import traces
        trace = traces.capture_from_steps(
            np.asarray(jnp.stack(masses)), group=pk_cfg.policy_every,
            label=f"{arch}-kv")
    if sync_telemetry:
        assert promotions_sync == tele["promotions"]
    rep = ServeReport(
        arch=arch, policy=str(policy), tok_s=tok_s,
        promotions=tele["promotions"], demotions=tele["demotions"],
        wasteful=tele["wasteful"], thrash=tele["thrash"],
        slowdown=tele["slowdown"], fast_mass=fast_mass,
        telemetry=tele, trace=trace, kv=kv)
    if not quiet:
        print(f"[serve] {arch}/{rep.policy}: {n_tokens} steps x {batch} "
              f"seqs = {tok_s:,.0f} tok/s"
              + (" (sync telemetry)" if sync_telemetry else ""))
        print(f"[serve] tiering: {rep.promotions} promotions / "
              f"{rep.demotions} demotions, thrash={rep.thrash:.3f}, "
              f"modeled slowdown vs all-fast = {rep.slowdown:.2f}x, "
              f"fast-tier attention-mass share (end) = "
              f"{fast_mass[-1]:.2%}")
    return rep


def main():
    from repro.simulator.experiment import POLICY_REGISTRY
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--policy", default="arms",
                    choices=sorted(POLICY_REGISTRY))
    ap.add_argument("--machine", default=TP.DEFAULT_MACHINE)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sync-telemetry", action="store_true",
                    help="legacy per-token host-sync telemetry (slow)")
    ap.add_argument("--capture", default=None, metavar="PATH",
                    help="save the paged-KV access trace as an .npz "
                         "TraceWorkload")
    args = ap.parse_args()
    rep = serve(args.arch, args.tokens, args.batch, full=args.full,
                policy=args.policy, machine=args.machine, seed=args.seed,
                sync_telemetry=args.sync_telemetry,
                capture=args.capture is not None)
    if args.capture:
        rep.trace.save(args.capture)
        print(f"[serve] trace [{rep.trace.T}x{rep.trace.n}] -> "
              f"{args.capture}")


if __name__ == "__main__":
    main()
