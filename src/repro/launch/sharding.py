"""Sharding rules: params (TP over 'model' + FSDP over 'data'), inputs
(DP over 'pod'x'data'), KV caches (batch over DP axes, sequence over
'model' when head counts don't tile it).

Rules are name-based (Megatron layout where the name identifies the role)
with a divisibility-checked generic fallback, so every architecture lowers
with zero per-arch special cases; the hillclimb (§Perf) then tightens the
three chosen cells.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import axis_size, dp_axes

# param names whose FIRST matmul dim is the contracting/model dim
_ROW_PARALLEL = {"wo", "out_proj"}


def _divisible(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _leaf_spec(path, shape, mesh) -> P:
    names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    msize = axis_size(mesh, "model")
    dsize = axis_size(mesh, "data")
    nd = len(shape)

    # embeddings: [V, D] vocab over model, d_model over data
    if "table" in names:
        lead = nd - 2
        v_ok = _divisible(shape[lead], msize)
        d_ok = _divisible(shape[lead + 1], dsize)
        return P(*([None] * lead), "model" if v_ok else None,
                 "data" if d_ok else None)

    if nd == 0 or nd == 1:
        return P()

    # stacked-layer leading axes (scan dims) stay unsharded
    lead = nd - 2
    a, b = shape[-2], shape[-1]

    # MoE expert stacks [*, E, D, F] / [*, E, F, D]: experts over model (EP)
    if nd >= 3 and names and names[-1] in ("wi", "wo") and "moe" in names:
        lead = nd - 3
        e = shape[lead]
        e_spec = "model" if _divisible(e, msize) else None
        a_spec = "data" if _divisible(a, dsize) else None
        return P(*([None] * lead), e_spec, a_spec, None)

    row = any(n in _ROW_PARALLEL for n in names[-2:])
    if row:  # [contracting(model), out(data)]
        return P(*([None] * lead),
                 "model" if _divisible(a, msize) else None,
                 "data" if _divisible(b, dsize) else None)
    return P(*([None] * lead),
             "data" if _divisible(a, dsize) else None,
             "model" if _divisible(b, msize) else None)


def param_shardings(params_shapes, mesh, serve: bool = False):
    """Pytree of NamedSharding matching a params (or grads/opt-state) tree
    of ShapeDtypeStructs.

    ``serve=True`` drops the FSDP ('data') factor: at decode batch sizes,
    re-gathering weight shards every step costs more than the memory the
    sharding saves — weights stay TP('model')-sharded and replicated
    across data-parallel serving replicas (§Perf iteration B2)."""
    def spec(path, leaf):
        p = _leaf_spec(path, leaf.shape, mesh)
        if serve:
            p = PartitionSpec(*(None if e == "data" else e for e in p))
        return NamedSharding(mesh, p)

    return jax.tree_util.tree_map_with_path(spec, params_shapes)


def batch_sharding(mesh, batch_shapes):
    """Token batches: leading (global batch) dim over all DP axes."""
    dp = dp_axes(mesh)

    def spec(leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        total = 1
        for a in dp:
            total *= axis_size(mesh, a)
        first = dp if leaf.ndim and _divisible(b, total) else None
        rest = [None] * (leaf.ndim - 1)
        return NamedSharding(mesh, P(first, *rest))

    return jax.tree.map(spec, batch_shapes)


def cache_sharding(mesh, cache_shapes, seq_axis_hint: int = -3):
    """KV/state caches: batch dim over DP axes when divisible; the sequence
    dim over 'model' when divisible (flash-decoding style split); head dims
    over 'model' only when batch could not be sharded AND heads divide.

    Cache layouts handled: [L?, B, S, KV, dh] (KV), [L?, B, S, R] (MLA
    latent), [L?, B, K-1, C] / [L?, B, H, P, N] (mamba)."""
    dp = dp_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= axis_size(mesh, a)
    msize = axis_size(mesh, "model")

    def spec(leaf):
        shape = leaf.shape
        nd = len(shape)
        entries = [None] * nd
        # batch dim: stacked cache layouts ([L, B, ...], ndim >= 4) carry
        # the batch at dim 1; unstacked ([B, ...]) at dim 0.  Never shard
        # the layer-stack dim — a layer-scan over a layer-sharded cache
        # degenerates into per-layer collective-permutes (§Perf B1).
        cand = 1 if nd >= 4 else 0
        b_at = cand if (_divisible(shape[cand], dp_total)
                        and shape[cand] >= dp_total) else None
        if b_at is not None:
            entries[b_at] = dp
        # sequence dim: the largest remaining dim divisible by model size
        s_at, s_val = None, 0
        for i in range(nd):
            if i == b_at:
                continue
            if _divisible(shape[i], msize) and shape[i] > s_val \
                    and shape[i] >= msize:
                s_at, s_val = i, shape[i]
        if s_at is not None:
            if b_at is None and _divisible(shape[s_at], dp_total * msize):
                # batch unshardable (e.g. long_500k B=1): context-parallel
                # split of the sequence over EVERY axis.
                entries[s_at] = dp + ("model",)
            else:
                entries[s_at] = "model"
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(spec, cache_shapes)


def replicated(mesh):
    return NamedSharding(mesh, P())
