"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh):

    compute    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory     = HLO_bytes / (chips x HBM_bw)
    collective = collective_bytes / (chips x link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``.  collective_bytes is
parsed from the (optimized) HLO text: operand bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, multiplied by
the trip counts of enclosing while loops (lax.scan bodies), which we recover
from the loop-condition constants.
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e-class hardware constants (per the assignment).
PEAK_FLOPS_BF16 = 197e12     # FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALL_RE = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w\.\-]+)")


def _shape_bytes(sig: str) -> int:
    """Sum byte sizes of all shapes in an HLO result signature."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_computations(hlo: str):
    """-> {name: list of instruction lines}.

    A computation header is a line ending in '{' that contains '->'
    (possibly with tuple-typed parameters); its name is the token before
    the first '(' minus any ENTRY prefix and '%' sigil."""
    comps = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped:
            head = stripped.split("(", 1)[0]
            head = head.replace("ENTRY", "").strip().lstrip("%")
            cur = head
            comps[cur] = []
        elif stripped == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(stripped)
    return comps


def _trip_count(cond_lines) -> int:
    """Best-effort scan trip count from a while-condition computation:
    the comparison constant (lax.scan emits `compare(i, K)` with K const)."""
    consts = [int(m.group(1))
              for line in cond_lines
              for m in [re.search(r"constant\((\d+)\)", line)] if m]
    return max(consts) if consts else 1


_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?[^=]*?)\s*([a-z][\w\-]*)\(")
_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "iota", "after-all", "partition-id", "replica-id"}
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _computation_multipliers(comps):
    """Scan-aware execution-count multiplier per computation (while trip
    counts propagated through call edges)."""
    trip = {}
    for name, lines in comps.items():
        for ln in lines:
            if " while(" in ln and "body=" in ln:
                body = re.search(r"body=%?([\w\.\-]+)", ln)
                cond = re.search(r"condition=%?([\w\.\-]+)", ln)
                if not body:
                    continue
                # prefer XLA's own annotation when present
                ktc = re.search(r'known_trip_count[^0-9]*(\d+)', ln)
                if ktc:
                    k = int(ktc.group(1))
                elif cond and cond.group(1) in comps:
                    k = _trip_count(comps[cond.group(1)])
                else:
                    k = 1
                trip[(name, body.group(1))] = k

    # call edges: computation -> callees mentioned via to_apply/calls/body
    edges = {name: set() for name in comps}
    for name, lines in comps.items():
        for ln in lines:
            for callee in _CALL_RE.findall(ln):
                if callee in comps and callee != name:
                    edges[name].add(callee)

    mult = {name: 1 for name in comps}
    roots = [n for n in comps
             if not any(n in e for e in edges.values())]
    seen = set()

    def visit(name, m):
        if (name, m) in seen or len(seen) > 10_000:
            return
        seen.add((name, m))
        mult[name] = max(mult[name], m)
        for callee in edges[name]:
            k = trip.get((name, callee), 1)
            visit(callee, m * k)

    for r in roots:
        visit(r, 1)
    return mult


_PARAM_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([^=]*?)\s*"
                       r"parameter\((\d+)\)")
_SLICE_OPS = ("dynamic-slice", "slice", "gather")


def _fusion_reads(comps):
    """Per fused computation: ({param_index: effective bytes or None},
    root_dus_update_bytes or None).

    A parameter consumed ONLY by slicing ops reads just the slices (the
    stacked-layer-weights-inside-scan pattern); None means a full read.
    A fusion whose ROOT is dynamic-update-slice writes only the update
    region in place (XLA aliases the output with the big operand), so we
    also report the update's byte size; the aliased buffer param is not a
    full read either.
    """
    out = {}
    for cname, lines in comps.items():
        if "fused_computation" not in cname:
            continue
        params = {}     # value name -> (index, full bytes)
        sizes = {}
        root_dus = None
        dus_buffers = set()
        for ln in lines:
            pm = _PARAM_RE.match(ln)
            if pm:
                params[pm.group(1)] = (int(pm.group(3)),
                                       _shape_bytes(pm.group(2)))
                sizes[pm.group(1)] = _shape_bytes(pm.group(2))
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            nm, rtype, op = im.groups()
            sizes[nm] = _shape_bytes(rtype)
            if op == "dynamic-update-slice" and ln.startswith("ROOT"):
                ops_ = _operand_names(ln)
                if len(ops_) >= 2:
                    root_dus = sizes.get(ops_[1], None)
                    dus_buffers.add(ops_[0])
        eff = {}
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            _, rtype, op = im.groups()
            ops_ = _operand_names(ln)
            for pos_i, o in enumerate(ops_):
                if o not in params:
                    continue
                idx, _full = params[o]
                if op == "dynamic-update-slice" and pos_i == 0 and \
                        o in dus_buffers:
                    continue   # aliased in-place buffer: not a read
                if op in _SLICE_OPS:
                    prev = eff.get(idx, 0)
                    if prev is not None:
                        eff[idx] = prev + _shape_bytes(rtype)
                else:
                    eff[idx] = None          # non-slice consumer: full read
        out[cname] = ({idx: eff.get(idx, 0)
                       for idx, _ in params.values()}, root_dus)
    return out


def analyze_hlo(hlo: str) -> dict:
    """Scan-aware per-device cost model over post-SPMD optimized HLO.

    Returns dict(flops, bytes, collectives={kind: bytes, _total}).
    - flops: 2*prod(result_dims)*prod(contracting_dims) per dot, times the
      enclosing scan trip counts (XLA cost_analysis counts while bodies
      once, which undercounts layer-scanned models by ~n_layers).
    - bytes: operand + result bytes of every top-level (post-fusion)
      instruction — an HBM-traffic model (fusion internals stay in
      registers/VMEM).
    - collectives: operand bytes per collective kind.
    """
    comps = _parse_computations(hlo)
    mult = _computation_multipliers(comps)
    freads = _fusion_reads(comps)

    # name -> result bytes, per computation (HLO is SSA per computation)
    flops = 0.0
    byts = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}

    for cname, lines in comps.items():
        m = mult.get(cname, 1)
        sizes = {}
        shapes = {}
        for ln in lines:
            im = _INSTR_RE.match(ln)
            if not im:
                continue
            name, rtype, op = im.groups()
            rbytes = _shape_bytes(rtype)
            sizes[name] = rbytes
            sm = _SHAPE_RE.search(rtype)
            if sm:
                dims = [int(d) for d in sm.group(2).split(",") if d]
                shapes[name] = dims
            if op == "dot":
                ops = _operand_names(ln)
                cd = _CDIMS_RE.search(ln)
                k = 1
                if cd and ops:
                    lhs = shapes.get(ops[0])
                    if lhs:
                        for d in cd.group(1).split(","):
                            if d and int(d) < len(lhs):
                                k *= lhs[int(d)]
                rdims = shapes.get(name, [1])
                n = 1
                for d in rdims:
                    n *= d
                flops += 2.0 * n * k * m
                continue
            if "fused_computation" in cname:
                continue  # fusion internals don't touch HBM
            if op in _SKIP_OPS or op in ("while", "conditional", "call"):
                continue
            ops_ = _operand_names(ln)
            # slicing/indexing ops only touch the slice, not the operand:
            if op in ("dynamic-slice", "slice", "gather"):
                byts += 2.0 * rbytes * m          # read slice + write result
                continue
            if op == "dynamic-update-slice":
                u = sizes.get(ops_[1], rbytes) if len(ops_) > 1 else rbytes
                byts += 2.0 * u * m               # read + write the update
                continue
            if op == "scatter":
                u = sizes.get(ops_[-1], 0) if ops_ else 0
                byts += 2.0 * u * m
                continue
            is_coll = next((c for c in _COLLECTIVES if op.startswith(c)),
                           None)
            if op == "fusion":
                callee = _CALL_RE.search(ln)
                eff, root_dus = freads.get(callee.group(1), ({}, None)) \
                    if callee else ({}, None)
                obytes = 0
                for j, o in enumerate(ops_):
                    e = eff.get(j, None)
                    obytes += sizes.get(o, 0) if e is None else e
                if root_dus is not None:
                    # in-place DUS fusion: writes only the update region
                    byts += (root_dus + obytes) * m
                    continue
            else:
                obytes = sum(sizes.get(o, 0) for o in ops_)
            byts += (rbytes + obytes) * m
            if is_coll:
                coll[is_coll] += rbytes * m
    coll["_total"] = sum(coll.values())
    return {"flops": flops, "bytes": byts, "collectives": coll}


def _operand_names(ln: str):
    """Value operands of an instruction line (inside the first paren
    group, before any attribute list)."""
    start = ln.find("(")
    if start < 0:
        return []
    depth = 0
    end = start
    for i, ch in enumerate(ln[start:], start):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    inner = ln[start + 1: end]
    return re.findall(r"%([\w\.\-]+)", inner)


def collective_bytes(hlo: str) -> dict:
    """Back-compat wrapper -> {kind: bytes, '_total': bytes}."""
    coll = analyze_hlo(hlo)["collectives"]
    return {k: int(v) for k, v in coll.items()}


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_hbm: float
    bytes_collective: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def row(self) -> dict:
        return dict(compute_s=self.compute_s, memory_s=self.memory_s,
                    collective_s=self.collective_s, dominant=self.dominant,
                    flops=self.flops, bytes_hbm=self.bytes_hbm,
                    bytes_collective=self.bytes_collective)


def roofline(cost_analysis: dict, coll_bytes: float,
             chips: int) -> RooflineTerms:
    flops = float(cost_analysis.get("flops", 0.0))
    byts = float(cost_analysis.get("bytes accessed", 0.0))
    return RooflineTerms(
        compute_s=flops / (chips * PEAK_FLOPS_BF16),
        memory_s=byts / (chips * HBM_BW),
        collective_s=coll_bytes / (chips * ICI_BW),
        flops=flops, bytes_hbm=byts, bytes_collective=coll_bytes,
        chips=chips)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); decode D = B."""
    from repro.models.model import active_params
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch   # decode: one token per sequence
