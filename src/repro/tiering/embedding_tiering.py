"""ARMS-tiered embedding rows (DESIGN.md §2, integration 3).

Pages = blocks of vocabulary rows (row_block rows).  Access counts = token
frequency histograms from the data pipeline / request stream — Zipfian in
practice, so a small HBM-resident hot set serves almost all lookups (the
202k-row llama4 table at bf16 x 5120 is ~2 GB per replica; the hot 10%
covers >95% of tokens)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import ARMSConfig, TieringState, arms_step
from repro.core import init_state as arms_init


@dataclasses.dataclass(frozen=True)
class EmbedTierConfig:
    vocab: int
    row_block: int = 256
    fast_blocks: int = 32
    policy_every: int = 16
    # dLatency: a 256-row block over PCIe (~2.6 MB at d=5120) ~100 us vs
    # ~3 us from HBM; one access = one token lookup in the block.
    arms: ARMSConfig = ARMSConfig(access_scale=1.0, latency_fast_us=3.0,
                                  latency_slow_us=100.0,
                                  init_promo_cost_us=20.0,
                                  init_demo_cost_us=20.0)

    @property
    def n_blocks(self) -> int:
        return -(-self.vocab // self.row_block)


@dataclasses.dataclass(frozen=True)
class EmbedTier:
    table: jnp.ndarray       # [V, D] home copy (slow tier)
    in_fast: jnp.ndarray     # [n_blocks] bool
    counts: jnp.ndarray      # [n_blocks] f32
    arms: TieringState
    step: jnp.ndarray


jax.tree_util.register_dataclass(
    EmbedTier, data_fields=["table", "in_fast", "counts", "arms", "step"],
    meta_fields=[])


def init_embed_tier(cfg: EmbedTierConfig, table) -> EmbedTier:
    return EmbedTier(table=table,
                     in_fast=jnp.zeros((cfg.n_blocks,), bool),
                     counts=jnp.zeros((cfg.n_blocks,), jnp.float32),
                     arms=arms_init(cfg.n_blocks, cfg.arms),
                     step=jnp.zeros((), jnp.int32))


def lookup(t: EmbedTier, ids, cfg: EmbedTierConfig):
    """Embedding lookup + per-block access accounting.

    Returns (embeddings, fast_hit_fraction, new_tier)."""
    emb = jnp.take(t.table, ids, axis=0)
    blocks = ids // cfg.row_block
    hist = jnp.zeros((cfg.n_blocks,), jnp.float32).at[
        blocks.reshape(-1)].add(1.0)
    hits = t.in_fast[blocks].mean()
    t = dataclasses.replace(t, counts=t.counts + hist, step=t.step + 1)
    return emb, hits, t


def policy(t: EmbedTier, cfg: EmbedTierConfig):
    slow_frac = jnp.where(t.in_fast, 0.0, t.counts).sum() / \
        jnp.maximum(t.counts.sum(), 1e-9)
    arms, plan = arms_step(t.arms, t.counts, slow_frac, 0.5, cfg=cfg.arms,
                           k=cfg.fast_blocks)
    # placement is metadata-only here: the home table is authoritative and
    # the fast tier is a cache of blocks (no copies needed for correctness)
    return dataclasses.replace(t, arms=arms, in_fast=arms.in_fast,
                               counts=jnp.zeros_like(t.counts)), plan
