"""Policy-tiered embedding rows (DESIGN.md §2 integration 3, §10).

Pages = blocks of vocabulary rows (row_block rows).  Access counts = token
frequency histograms from the data pipeline / request stream — Zipfian in
practice, so a small HBM-resident hot set serves almost all lookups (the
202k-row llama4 table at bf16 x 5120 is ~2 GB per replica; the hot 10%
covers >95% of tokens).

Placement runs through the shared ``tiered_pool`` executor (any
``experiment.POLICY_REGISTRY`` family; default ARMS with the legacy
serving semantics).  It is metadata-only here: the home table is
authoritative and the fast tier is a cache of blocks, so the pool moves no
buffers (``bufs=()``) — residency just prices lookups via the measured
per-tier read volumes (rows touched x row bytes, split by block tier).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import ARMSConfig
from repro.tiering import tiered_pool as TP


@dataclasses.dataclass(frozen=True)
class EmbedTierConfig:
    vocab: int
    row_block: int = 256
    fast_blocks: int = 32
    policy_every: int = 16
    # dLatency: a 256-row block over PCIe (~2.6 MB at d=5120) ~100 us vs
    # ~3 us from HBM; one access = one token lookup in the block.
    arms: ARMSConfig = ARMSConfig(access_scale=1.0, latency_fast_us=3.0,
                                  latency_slow_us=100.0,
                                  init_promo_cost_us=20.0,
                                  init_demo_cost_us=20.0)
    machine: str = TP.DEFAULT_MACHINE

    @property
    def n_blocks(self) -> int:
        return -(-self.vocab // self.row_block)


@dataclasses.dataclass(frozen=True)
class EmbedTier:
    table: jnp.ndarray       # [V, D] home copy (slow tier)
    pool: TP.TieredPool

    @property
    def in_fast(self):
        return self.pool.in_fast

    @property
    def counts(self):
        return self.pool.counts

    @property
    def step(self):
        return self.pool.t

    @property
    def arms(self):
        return self.pool.state.inner


jax.tree_util.register_dataclass(
    EmbedTier, data_fields=["table", "pool"], meta_fields=[])


def block_bytes(t: EmbedTier, cfg: EmbedTierConfig) -> float:
    """Bytes of one row block — the migration-traffic unit."""
    return float(cfg.row_block * t.table.shape[1] * t.table.dtype.itemsize)


def init_embed_tier(cfg: EmbedTierConfig, table,
                    policy="arms") -> EmbedTier:
    pool = TP.init_pool(policy, cfg.n_blocks, cfg.fast_blocks,
                        machine=cfg.machine, arms_cfg=cfg.arms,
                        pool_every=cfg.policy_every)
    return EmbedTier(table=table, pool=pool)


def lookup(t: EmbedTier, ids, cfg: EmbedTierConfig):
    """Embedding lookup + per-block access accounting.

    Returns (embeddings, fast_hit_fraction, new_tier)."""
    emb = jnp.take(t.table, ids, axis=0)
    blocks = ids // cfg.row_block
    hist = jnp.zeros((cfg.n_blocks,), jnp.float32).at[
        blocks.reshape(-1)].add(1.0)
    hits = t.in_fast[blocks].mean()
    row_b = float(t.table.shape[1] * t.table.dtype.itemsize)
    rf = (hist * t.in_fast).sum() * row_b
    rs = (hist * ~t.in_fast).sum() * row_b
    pool = TP.pool_observe(t.pool, hist, rf, rs)
    return emb, hits, dataclasses.replace(t, pool=pool)


def policy(t: EmbedTier, cfg: EmbedTierConfig):
    """Run the placement policy if due (``policy_every`` lookups since the
    last pass).  Metadata-only — no block copies (module docstring)."""
    pool, _, plan = TP.pool_fire(
        t.pool, k=cfg.fast_blocks, bufs=(), copy_back=False,
        page_bytes=block_bytes(t, cfg))
    return dataclasses.replace(t, pool=pool), plan
