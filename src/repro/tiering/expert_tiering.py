"""Policy-tiered MoE expert weights (DESIGN.md §2 integration 2, §10).

Pages = expert weight slabs.  Access counts = router load (tokens dispatched
per expert per step) — exact, not sampled.  The placement policy (default
ARMS, any ``experiment.POLICY_REGISTRY`` family via the shared
``tiered_pool`` executor) keeps the hot experts' slabs HBM-resident (fast
pool of k slots) and the long tail in host memory; hot-age filtering
suppresses thrash from bursty routing (the paper's one-hit wonders, §4.3).

The slow pool always holds the home copy of every expert, so demotion is
metadata-only (``copy_back=False``); promotion copies the slab up.  The
measured per-tier read volume — the bytes ``effective_weights`` pulls from
each pool for the experts actually dispatched — feeds the pool's
application-bandwidth signal (the satellite-3 fix for the old hardcoded
``app_bw_frac=0.5``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import ARMSConfig
from repro.tiering import tiered_pool as TP


@dataclasses.dataclass(frozen=True)
class ExpertTierConfig:
    n_experts: int
    fast_experts: int
    policy_every: int = 4
    # dLatency: fetching an expert slab over PCIe (~25 GB/s) vs HBM — e.g.
    # a 47 MB deepseek expert: ~1.9 ms vs ~60 us; one "access" = one step's
    # dispatch to that expert.
    arms: ARMSConfig = ARMSConfig(access_scale=1.0, latency_fast_us=60.0,
                                  latency_slow_us=1900.0,
                                  init_promo_cost_us=200.0,
                                  init_demo_cost_us=200.0, bs_max=8)
    machine: str = TP.DEFAULT_MACHINE


@dataclasses.dataclass(frozen=True)
class ExpertTier:
    wi_fast: jnp.ndarray     # [Kf, D, 2F]
    wo_fast: jnp.ndarray     # [Kf, F, D]
    wi_slow: jnp.ndarray     # [E, D, 2F]  (home copy of every expert)
    wo_slow: jnp.ndarray     # [E, F, D]
    pool: TP.TieredPool

    @property
    def in_fast(self):
        return self.pool.in_fast

    @property
    def slot(self):
        return self.pool.slot

    @property
    def counts(self):
        return self.pool.counts

    @property
    def step(self):
        return self.pool.t

    @property
    def arms(self):
        return self.pool.state.inner


jax.tree_util.register_dataclass(
    ExpertTier,
    data_fields=["wi_fast", "wo_fast", "wi_slow", "wo_slow", "pool"],
    meta_fields=[])


def expert_slab_bytes(t: ExpertTier) -> float:
    """Bytes of one expert's (wi, wo) slab — the per-tier read-volume and
    migration-traffic unit."""
    wi = 1
    for d in t.wi_slow.shape[1:]:
        wi *= d
    wo = 1
    for d in t.wo_slow.shape[1:]:
        wo *= d
    return float(wi * t.wi_slow.dtype.itemsize
                 + wo * t.wo_slow.dtype.itemsize)


def init_expert_tier(cfg: ExpertTierConfig, wi, wo,
                     policy="arms") -> ExpertTier:
    E = cfg.n_experts
    Kf = cfg.fast_experts
    pool = TP.init_pool(policy, E, Kf, machine=cfg.machine,
                        arms_cfg=cfg.arms, pool_every=cfg.policy_every)
    return ExpertTier(
        wi_fast=jnp.zeros((Kf,) + wi.shape[1:], wi.dtype),
        wo_fast=jnp.zeros((Kf,) + wo.shape[1:], wo.dtype),
        wi_slow=wi, wo_slow=wo,
        pool=pool)


def effective_weights(t: ExpertTier):
    """[E, ...] views: resident experts read the fast pool (HBM), the rest
    the slow pool — the per-tier read split is the serving cost signal."""
    Kf = t.wi_fast.shape[0]
    slot = jnp.clip(t.slot, 0, Kf - 1)
    wi = jnp.where(t.in_fast[:, None, None], t.wi_fast[slot], t.wi_slow)
    wo = jnp.where(t.in_fast[:, None, None], t.wo_fast[slot], t.wo_slow)
    return wi, wo


def read_volumes(t: ExpertTier, expert_load):
    """(fast_bytes, slow_bytes) for one step: each DISPATCHED expert
    (load > 0) reads its slab once from its tier."""
    hit = expert_load > 0
    sb = expert_slab_bytes(t)
    fast = (hit & t.in_fast).sum().astype(jnp.float32) * sb
    slow = (hit & ~t.in_fast).sum().astype(jnp.float32) * sb
    return fast, slow


def observe_and_policy(t: ExpertTier, expert_load, cfg: ExpertTierConfig):
    """Accumulate router load; periodically run the policy and execute the
    plan via the shared pool executor.  Returns (tier, PoolPlan)."""
    rf, rs = read_volumes(t, expert_load)
    pool, bufs, plan = TP.pool_step(
        t.pool, expert_load, rf, rs, k=cfg.fast_experts,
        bufs=((t.wi_fast, t.wi_slow), (t.wo_fast, t.wo_slow)),
        copy_back=False, page_bytes=expert_slab_bytes(t))
    (wi_f, _), (wo_f, _) = bufs
    t = dataclasses.replace(t, wi_fast=wi_f, wo_fast=wo_f, pool=pool)
    return t, plan
