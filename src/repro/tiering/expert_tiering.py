"""ARMS-tiered MoE expert weights (DESIGN.md §2, integration 2).

Pages = expert weight slabs.  Access counts = router load (tokens dispatched
per expert per step) — exact, not sampled.  The ARMS controller keeps the
hot experts' slabs HBM-resident (fast pool of k slots) and the long tail in
host memory; hot-age filtering suppresses thrash from bursty routing (the
paper's one-hit wonders, §4.3).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import ARMSConfig, TieringState, arms_step
from repro.core import init_state as arms_init


@dataclasses.dataclass(frozen=True)
class ExpertTierConfig:
    n_experts: int
    fast_experts: int
    policy_every: int = 4
    # dLatency: fetching an expert slab over PCIe (~25 GB/s) vs HBM — e.g.
    # a 47 MB deepseek expert: ~1.9 ms vs ~60 us; one "access" = one step's
    # dispatch to that expert.
    arms: ARMSConfig = ARMSConfig(access_scale=1.0, latency_fast_us=60.0,
                                  latency_slow_us=1900.0,
                                  init_promo_cost_us=200.0,
                                  init_demo_cost_us=200.0, bs_max=8)


@dataclasses.dataclass(frozen=True)
class ExpertTier:
    wi_fast: jnp.ndarray     # [Kf, D, 2F]
    wo_fast: jnp.ndarray     # [Kf, F, D]
    wi_slow: jnp.ndarray     # [E, D, 2F]  (home copy of every expert)
    wo_slow: jnp.ndarray     # [E, F, D]
    in_fast: jnp.ndarray     # [E] bool
    slot: jnp.ndarray        # [E] i32 fast-pool slot (valid when in_fast)
    counts: jnp.ndarray      # [E] f32 accumulated router load
    arms: TieringState
    step: jnp.ndarray


jax.tree_util.register_dataclass(
    ExpertTier,
    data_fields=["wi_fast", "wo_fast", "wi_slow", "wo_slow", "in_fast",
                 "slot", "counts", "arms", "step"],
    meta_fields=[])


def init_expert_tier(cfg: ExpertTierConfig, wi, wo) -> ExpertTier:
    E = cfg.n_experts
    Kf = cfg.fast_experts
    return ExpertTier(
        wi_fast=jnp.zeros((Kf,) + wi.shape[1:], wi.dtype),
        wo_fast=jnp.zeros((Kf,) + wo.shape[1:], wo.dtype),
        wi_slow=wi, wo_slow=wo,
        in_fast=jnp.zeros((E,), bool),
        slot=jnp.zeros((E,), jnp.int32),
        counts=jnp.zeros((E,), jnp.float32),
        arms=arms_init(E, cfg.arms),
        step=jnp.zeros((), jnp.int32))


def effective_weights(t: ExpertTier):
    """[E, ...] views: resident experts read the fast pool (HBM), the rest
    the slow pool — the per-tier read split is the serving cost signal."""
    Kf = t.wi_fast.shape[0]
    slot = jnp.clip(t.slot, 0, Kf - 1)
    wi = jnp.where(t.in_fast[:, None, None], t.wi_fast[slot], t.wi_slow)
    wo = jnp.where(t.in_fast[:, None, None], t.wo_fast[slot], t.wo_slow)
    return wi, wo


def observe_and_policy(t: ExpertTier, expert_load, cfg: ExpertTierConfig):
    """Accumulate router load; periodically run ARMS and execute the plan."""
    t = dataclasses.replace(t, counts=t.counts + expert_load,
                            step=t.step + 1)
    slow_frac = jnp.where(t.in_fast, 0.0, t.counts).sum() / \
        jnp.maximum(t.counts.sum(), 1e-9)

    def policy(t):
        arms, plan = arms_step(t.arms, t.counts, slow_frac, 0.5,
                               cfg=cfg.arms, k=cfg.fast_experts)
        t = _apply(t, plan)
        return dataclasses.replace(t, arms=arms,
                                   counts=jnp.zeros_like(t.counts)), plan

    def skip(t):
        bs = min(cfg.arms.bs_max, cfg.n_experts)
        from repro.core import MigrationPlan
        return t, MigrationPlan(promote=jnp.full((bs,), -1, jnp.int32),
                                demote=jnp.full((bs,), -1, jnp.int32),
                                valid=jnp.zeros((bs,), bool),
                                count=jnp.zeros((), jnp.int32),
                                batch_size=jnp.zeros((), jnp.int32))

    return jax.lax.cond(t.step % cfg.policy_every == 0, policy, skip, t)


def _apply(t: ExpertTier, plan):
    Kf = t.wi_fast.shape[0]
    E = t.in_fast.shape[0]

    def body(state, entry):
        wi_f, wo_f, in_fast, slot = state
        p, d, valid = entry
        p_c = jnp.clip(p, 0, E - 1)
        d_c = jnp.clip(d, 0, E - 1)
        has_victim = d >= 0
        used = jnp.minimum(in_fast.sum(), Kf - 1).astype(jnp.int32)
        f_slot = jnp.clip(jnp.where(has_victim, slot[d_c], used), 0, Kf - 1)

        def run(args):
            wi_f, wo_f, in_fast, slot = args
            # demotion is free: the slow pool always holds the home copy
            wi_f = jax.lax.dynamic_update_slice_in_dim(
                wi_f, jax.lax.dynamic_slice_in_dim(t.wi_slow, p_c, 1, 0),
                f_slot, 0)
            wo_f = jax.lax.dynamic_update_slice_in_dim(
                wo_f, jax.lax.dynamic_slice_in_dim(t.wo_slow, p_c, 1, 0),
                f_slot, 0)
            in_fast = in_fast.at[d_c].set(
                jnp.where(has_victim, False, in_fast[d_c]))
            in_fast = in_fast.at[p_c].set(True)
            slot = slot.at[p_c].set(f_slot)
            return wi_f, wo_f, in_fast, slot

        return jax.lax.cond(valid, run, lambda a: a,
                            (wi_f, wo_f, in_fast, slot)), None

    (wi_f, wo_f, in_fast, slot), _ = jax.lax.scan(
        body, (t.wi_fast, t.wo_fast, t.in_fast, t.slot),
        (plan.promote, plan.demote, plan.valid))
    return dataclasses.replace(t, wi_fast=wi_f, wo_fast=wo_f,
                               in_fast=in_fast, slot=slot)
