"""Beyond-paper serving mode: ARMS-guided sparse paged attention.

The paper places hot pages in the fast tier so that full attention is
cheap; the step BEYOND the paper is to let the ARMS hot-set *define the
attention working set*: attend only to (a) fast-resident pages (ARMS's
top-k by attention mass — the pages that matter, by construction), (b) a
recency window of the newest pages, and (c) the attention-sink page 0
(StreamingLLM observation).  The cold slow-tier pages are SKIPPED, so both
the slow-tier bandwidth AND the attention compute shrink by the cold-set
fraction — tiering becomes a throughput optimization, not just capacity.

Quality: on workloads where attention mass concentrates (the same skew
ARMS exploits), the output approximates full attention; the approximation
error is bounded by the skipped attention mass, which ARMS's own EWMA
estimates — so the system can monitor its sparsification error online.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.tiering.paged_kv import PagedKV, PagedKVConfig, _gather_kv


def sparse_attention_step(kv: PagedKV, q, pos, cfg: PagedKVConfig,
                          recent_pages: int = 2):
    """Decode attention over ONLY the hot working set.

    q: [B, H, dh] -> (out [B,H,dh], page mass estimate [n_pages],
    attended_fraction scalar).
    """
    B, H, dh = q.shape
    page, n = cfg.page_size, cfg.n_pages
    k, v = _gather_kv(kv)                           # [n, page, B, KV, dh]
    KV = k.shape[3]
    rep = H // KV

    cur_page = pos // page
    page_ids = jnp.arange(n)
    attend = (kv.in_fast                                    # ARMS hot set
              | (page_ids >= cur_page - recent_pages + 1)
              & (page_ids <= cur_page)                      # recency window
              | (page_ids == 0))                            # attention sink

    kf = k.transpose(2, 0, 1, 3, 4).reshape(B, n * page, KV, dh)
    vf = v.transpose(2, 0, 1, 3, 4).reshape(B, n * page, KV, dh)
    qg = q.reshape(B, KV, rep, dh)
    s = jnp.einsum("bkrd,bskd->bkrs", qg, kf).astype(jnp.float32)
    s *= dh ** -0.5
    tok_ok = (jnp.repeat(attend, page)[None]
              & (jnp.arange(n * page) <= pos)[None])
    s = jnp.where(tok_ok[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrs,bskd->bkrd", p.astype(vf.dtype), vf)
    mass = p.reshape(B, KV, rep, n, page).sum(axis=(0, 1, 2, 4))
    frac = attend.sum() / jnp.maximum((jnp.arange(n) * page <= pos).sum(),
                                      1)
    return out.reshape(B, H, dh), mass, frac
