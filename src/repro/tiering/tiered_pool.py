"""Policy-generic tiered pool executor (DESIGN.md §10).

The serving integrations (paged-KV pages, MoE expert slabs, embedding row
blocks) historically hard-wired ``core.arms_step``.  This module replaces
that with the functional PolicySpec protocol (baselines/protocol.py): a
``TieredPool`` carries ANY registered policy family's spec + state next to
the residency metadata, and one ``pool_step`` runs

    observe -> cond(fires) [ policy -> apply_padded_migrations -> data move ]

so the KV page pool and the expert slab pool are driven by exactly the
contract the simulator engines execute — ``simjax.apply_padded_migrations``
is the shared residency executor, and the ARMS-family serving behaviour is
regression-pinned to the legacy ``arms_step`` path
(tests/test_serving_protocol.py).

Cost signals (the satellite-3 fix): instead of the old hardcoded
``app_bw_frac=0.5``, the pool accumulates MEASURED per-tier read volumes —
the bytes ``paged_kv._gather_kv`` / ``expert_tiering.effective_weights``
define (resident entries read tier 0, the rest tier 1) — and derives the
application-bandwidth signal from the per-tier service times on the pool's
machine (default ``hbm-pcie``, whose tier-0 bandwidth is pinned to
``roofline.HBM_BW``).  ``serving_interval_outcome`` mirrors
``simjax.tier_interval_outcome``'s two-tier bandwidth terms over raw byte
volumes; the cross-check against the simulator cost model is asserted in
tests/test_serving_protocol.py.

Telemetry is accumulated DEVICE-SIDE (promotions, demotions, wasteful
migrations in the simulator's WASTE_WINDOW sense, modeled tiered vs
all-fast wall time) so a serving loop never host-syncs per token; one
``telemetry(pool)`` call at the end reports the same slowdown/thrash
numbers as the robustness leaderboard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.baselines.arms_policy import ARMSServeSpec
from repro.baselines.protocol import SENTINEL, PolicySpec, ranked_take
from repro.core import ARMSConfig
from repro.simulator import machines, simjax
from repro.utils.pytree import pytree_dataclass

DEFAULT_MACHINE = "hbm-pcie"
_EPS = 1e-12


def serving_policy(policy, arms_cfg: ARMSConfig | None = None,
                   pool_every: int = 8) -> PolicySpec:
    """Resolve a policy family name (or a spec instance) for serving.

    ``"arms"`` maps to ``ARMSServeSpec`` — the legacy serving semantics
    (raw counts, fixed cadence; see baselines/arms_policy.py) — bound to
    the pool's ARMSConfig and cadence.  Every other name resolves through
    ``experiment.POLICY_REGISTRY``, so the serving layer accepts exactly
    the simulator's policy families.
    """
    if isinstance(policy, PolicySpec):
        return policy
    name = str(policy).lower()
    if name == "arms":
        return ARMSServeSpec.make_serving(arms_cfg or ARMSConfig(),
                                          pool_every)
    from repro.simulator.experiment import POLICY_REGISTRY
    if name not in POLICY_REGISTRY:
        raise ValueError(f"unknown policy {policy!r}; known: "
                         f"{sorted(POLICY_REGISTRY)}")
    return POLICY_REGISTRY[name]()


@pytree_dataclass
class PoolPlan:
    """One pool interval's migration outcome (padded-index contract) plus
    the step's access echo for host-free trace capture."""
    promote: jnp.ndarray   # i32 [pad_p] sentinel-padded page ids
    demote: jnp.ndarray    # i32 [pad_d]
    pexec: jnp.ndarray     # bool masks of the EXECUTED entries
    dexec: jnp.ndarray
    count: jnp.ndarray     # i32 executed promotions (legacy plan.count)
    access: jnp.ndarray    # f32 [n] this step's access signal (capture)
    fast_share: jnp.ndarray  # f32 access share served fast, post-policy


@pytree_dataclass
class TieredPool:
    """Residency + policy + device-side telemetry for one tiered pool.

    ``spec`` is a data field: its knob leaves trace under jit while its
    class is part of the treedef — one compiled serving program per policy
    family, exactly the sweep-engine dispatch discipline.
    """
    spec: PolicySpec
    state: object            # spec's PolicyState pytree
    in_fast: jnp.ndarray     # [n] bool residency
    slot: jnp.ndarray        # [n] i32 slot within the page's tier pool
    counts: jnp.ndarray      # [n] f32 access signal since last policy fire
    read_fast: jnp.ndarray   # f32 bytes read per tier since last fire —
    read_slow: jnp.ndarray   # the measured app_bw signal window
    promoted_at: jnp.ndarray  # [n] i32 WASTE_WINDOW bookkeeping
    demoted_at: jnp.ndarray
    t: jnp.ndarray           # i32 observed intervals
    promos: jnp.ndarray      # i32 executed migrations (cumulative)
    demos: jnp.ndarray
    waste: jnp.ndarray       # i32 wasteful migrations (simjax.WASTE_WINDOW)
    wall_s: jnp.ndarray      # f32 modeled tiered serving time
    wall_flat_s: jnp.ndarray  # f32 all-fast counterfactual
    mach: object             # 2-tier TieredMachineSpec, f32 leaves


def init_pool(policy, n: int, k: int, machine=DEFAULT_MACHINE,
              arms_cfg: ARMSConfig | None = None,
              pool_every: int = 8) -> TieredPool:
    spec = serving_policy(policy, arms_cfg=arms_cfg, pool_every=pool_every)
    mach = machines.get(machine)
    mach32 = jax.tree_util.tree_map(
        lambda a: jnp.asarray(a, jnp.float32), mach)
    i32 = jnp.int32
    f32 = jnp.float32
    return TieredPool(
        spec=spec, state=spec.init(n, k, mach),
        in_fast=jnp.zeros((n,), bool),
        slot=jnp.arange(n, dtype=i32),
        counts=jnp.zeros((n,), f32),
        read_fast=jnp.zeros((), f32), read_slow=jnp.zeros((), f32),
        promoted_at=jnp.full((n,), -(10 ** 9), i32),
        demoted_at=jnp.full((n,), -(10 ** 9), i32),
        t=jnp.zeros((), i32),
        promos=jnp.zeros((), i32), demos=jnp.zeros((), i32),
        waste=jnp.zeros((), i32),
        wall_s=jnp.zeros((), f32), wall_flat_s=jnp.zeros((), f32),
        mach=mach32)


def serving_interval_outcome(mach, read_fast, read_slow, up_bytes=0.0,
                             down_bytes=0.0):
    """Two-tier bandwidth cost over raw BYTE volumes.

    The byte-volume mirror of ``simjax.tier_interval_outcome``'s bandwidth
    terms (accesses*CACHELINE / migrations*PAGE_BYTES become measured
    bytes; the latency term does not apply — serving reads are whole
    pages, not sampled cachelines).  Returns (wall_s, app_bw_frac_raw);
    the ratio is unclamped, consumers clamp (simjax module docstring).
    """
    br, bw = mach.bw_read, mach.bw_write
    t0 = (read_fast + up_bytes + down_bytes) / br[0]
    t1 = (read_slow + up_bytes) / br[1] + down_bytes / bw[1]
    wall = jnp.maximum(jnp.maximum(t0, t1), _EPS)
    app_raw = t0 / jnp.maximum(t1, _EPS)
    return wall, app_raw


def pool_signals(pool: TieredPool):
    """(slow_bw_frac, app_bw_frac) over the since-last-fire window.

    ``slow_bw``: share of the access signal served by slow pages (the
    legacy serving formula, unchanged).  ``app_bw``: measured per-tier
    read-time ratio, clamped to the [0, 1] the policies expect.
    """
    slow_bw = jnp.where(pool.in_fast, 0.0, pool.counts).sum() \
        / jnp.maximum(pool.counts.sum(), 1e-9)
    _, app_raw = serving_interval_outcome(pool.mach, pool.read_fast,
                                          pool.read_slow)
    return slow_bw, jnp.clip(app_raw, 0.0, 1.0)


def pool_tier_util(pool: TieredPool):
    """f32 [2] per-tier read-time share of the window wall — the serving
    mirror of ``simjax.tier_utilization`` for tier-native specs."""
    br = pool.mach.bw_read
    t0 = pool.read_fast / br[0]
    t1 = pool.read_slow / br[1]
    wall = jnp.maximum(jnp.maximum(t0, t1), _EPS)
    return jnp.stack([t0, t1]) / wall


def pool_observe(pool: TieredPool, access, read_fast=0.0,
                 read_slow=0.0) -> TieredPool:
    """Accumulate one serving interval's access signal + read volumes."""
    f32 = jnp.float32
    read_fast = jnp.asarray(read_fast, f32)
    read_slow = jnp.asarray(read_slow, f32)
    br = pool.mach.bw_read
    step_wall = jnp.maximum(
        jnp.maximum(read_fast / br[0], read_slow / br[1]), _EPS)
    return pool.replace(
        state=pool.spec.observe(pool.state, access),
        counts=pool.counts + access,
        read_fast=pool.read_fast + read_fast,
        read_slow=pool.read_slow + read_slow,
        t=pool.t + 1,
        wall_s=pool.wall_s + step_wall,
        wall_flat_s=pool.wall_flat_s + (read_fast + read_slow) / br[0]
        + _EPS)


def pool_fire(pool: TieredPool, *, k: int, bufs=(), copy_back: bool = True,
              page_bytes: float = 0.0):
    """cond(fires): policy pass + residency executor + data movement.

    ``bufs`` is a tuple of ``(fast [k, ...], slow [n, ...])`` array pairs
    moved along with residency (slow pools are indexed by page id — the
    home-slot invariant).  ``copy_back=False`` models pools whose slow
    tier always holds the home copy (expert slabs, embedding blocks), so
    demotion moves no data.  Returns (pool, bufs, PoolPlan).
    """
    spec = pool.spec
    n = pool.in_fast.shape[0]
    pad_p, pad_d = spec.pad_promote(n, k), spec.pad_demote(n, k)
    i32 = jnp.int32
    f32 = jnp.float32

    def fire(args):
        pool, bufs = args
        slow_bw, app_bw = pool_signals(pool)
        if type(spec).tier_native:
            # tier-native families (hybridtier/jenga/tierbpf) see the
            # 2-tier chain directly; their targeted moves collapse to
            # promote (dst 0) / demote (any deeper dst) lists here.
            caps = jnp.asarray([k, n], i32)
            state, pages, dst = spec.tier_policy(
                pool.state, pool_tier_util(pool), slow_bw, app_bw, k, caps)
            pm = pages.shape[0]
            pos = jnp.arange(pm, dtype=f32)
            valid = pages >= 0
            ip, _ = ranked_take(pos, valid & (dst == 0), pad_p)
            promote = jnp.where(ip >= 0, pages[jnp.clip(ip, 0, pm - 1)],
                                SENTINEL)
            idn, _ = ranked_take(pos, valid & (dst != 0), pad_d)
            demote = jnp.where(idn >= 0, pages[jnp.clip(idn, 0, pm - 1)],
                               SENTINEL)
        else:
            state, promote, demote = spec.policy(pool.state, slow_bw,
                                                 app_bw, k)
        in_fast, pexec, dexec = simjax.apply_padded_migrations(
            pool.in_fast, promote, demote, k)

        # --- slot bookkeeping (demotions land on their home slot; executed
        # promotions fill free fast slots in ascending order) -------------
        d_safe = jnp.where(dexec, demote, 0)
        d_src = pool.slot[d_safe]                       # vacated fast slots
        slot = pool.slot.at[jnp.where(dexec, demote, n)].set(
            jnp.where(dexec, demote, 0), mode="drop")
        in_fast_mid = pool.in_fast.at[
            jnp.where(dexec, demote, n)].set(False, mode="drop")
        occupied = jnp.zeros((k,), bool).at[
            jnp.where(in_fast_mid, pool.slot, k)].set(True, mode="drop")
        free_order = jnp.argsort(occupied).astype(i32)  # free slots first,
        p_rank = jnp.cumsum(pexec.astype(i32)) - 1      # ascending (stable)
        p_dst = free_order[jnp.clip(p_rank, 0, k - 1)]
        slot = slot.at[jnp.where(pexec, promote, n)].set(
            jnp.where(pexec, p_dst, 0), mode="drop")

        # --- data movement ------------------------------------------------
        def move(fast, slow):
            if copy_back:
                d_rows = fast[jnp.clip(d_src, 0, k - 1)]
                slow = slow.at[jnp.where(dexec, demote, slow.shape[0])].set(
                    d_rows, mode="drop")
            p_rows = slow[jnp.clip(promote, 0, slow.shape[0] - 1)]
            fast = fast.at[jnp.where(pexec, p_dst, k)].set(
                p_rows, mode="drop")
            return fast, slow

        bufs = tuple(move(f, s) for f, s in bufs)

        # --- telemetry (device-side; simulator semantics) -----------------
        n_up = pexec.sum().astype(i32)
        n_down = dexec.sum().astype(i32)
        waste_inc, promoted_at, demoted_at = simjax.wasteful_update(
            pool.t, pool.promoted_at, pool.demoted_at, promote, demote,
            pexec, dexec)
        up_b = n_up.astype(f32) * page_bytes
        down_b = jnp.where(copy_back, n_down.astype(f32) * page_bytes, 0.0)
        mig_wall, _ = serving_interval_outcome(
            pool.mach, jnp.zeros((), f32), jnp.zeros((), f32), up_b, down_b)
        pool = pool.replace(
            state=state, in_fast=in_fast, slot=slot,
            counts=jnp.zeros_like(pool.counts),
            read_fast=jnp.zeros((), f32), read_slow=jnp.zeros((), f32),
            promoted_at=promoted_at, demoted_at=demoted_at,
            promos=pool.promos + n_up, demos=pool.demos + n_down,
            waste=pool.waste + waste_inc,
            wall_s=pool.wall_s + jnp.where(n_up + n_down > 0, mig_wall,
                                           0.0))
        plan = PoolPlan(promote=promote, demote=demote, pexec=pexec,
                        dexec=dexec, count=n_up,
                        access=jnp.zeros((n,), f32),
                        fast_share=jnp.zeros((), f32))
        return pool, bufs, plan

    def skip(args):
        pool, bufs = args
        plan = PoolPlan(
            promote=jnp.full((pad_p,), SENTINEL, i32),
            demote=jnp.full((pad_d,), SENTINEL, i32),
            pexec=jnp.zeros((pad_p,), bool),
            dexec=jnp.zeros((pad_d,), bool),
            count=jnp.zeros((), i32),
            access=jnp.zeros((n,), f32),
            fast_share=jnp.zeros((), f32))
        return pool, bufs, plan

    return jax.lax.cond(spec.fires(pool.state), fire, skip, (pool, bufs))


def pool_step(pool: TieredPool, access, read_fast=0.0, read_slow=0.0, *,
              k: int, bufs=(), copy_back: bool = True,
              page_bytes: float = 0.0):
    """observe + cond(fires) around the policy/executor — the serving
    mirror of ``PolicySpec.step``.  Returns (pool, bufs, PoolPlan); the
    plan echoes the step's access signal + post-policy fast-tier access
    share so serving loops capture traces without host syncs."""
    access = jnp.asarray(access, jnp.float32)
    pool = pool_observe(pool, access, read_fast, read_slow)
    pool, bufs, plan = pool_fire(pool, k=k, bufs=bufs, copy_back=copy_back,
                                 page_bytes=page_bytes)
    share = (access * pool.in_fast).sum() \
        / jnp.maximum(access.sum(), 1e-9)
    plan = plan.replace(access=access, fast_share=share)
    return pool, bufs, plan


def telemetry(pool: TieredPool) -> dict:
    """Host-side summary — the leaderboard's slowdown/thrash metrics.

    The ONE host sync of a serving run; everything here was accumulated
    on device by ``pool_step``.
    """
    moves = int(pool.promos) + int(pool.demos)
    wall = float(pool.wall_s)
    flat = float(pool.wall_flat_s)
    return dict(
        promotions=int(pool.promos), demotions=int(pool.demos),
        wasteful=int(pool.waste),
        thrash=float(pool.waste) / max(moves, 1),
        modeled_wall_s=wall, modeled_flat_s=flat,
        slowdown=wall / max(flat, _EPS),
        fast_resident=int(pool.in_fast.sum()))
