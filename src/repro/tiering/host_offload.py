"""Slow-tier realization modes (DESIGN.md §2).

``memkind`` places slow-pool buffers in JAX's ``pinned_host`` memory space
(real host offload on TPU); ``buffer`` keeps them as ordinary device arrays
(identical data plane; always compiles — the dry-run default)."""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def supports_memkind() -> bool:
    try:
        dev = jax.devices()[0]
        kinds = getattr(dev, "addressable_memories", lambda: [])()
        return any(getattr(m, "kind", "") == "pinned_host" for m in kinds)
    except Exception:
        return False


def to_slow_tier(x, mode: str = "buffer", mesh=None):
    """Place an array in the slow tier."""
    if mode == "memkind" and supports_memkind():
        sharding = NamedSharding(mesh, P(), memory_kind="pinned_host") \
            if mesh is not None else \
            jax.devices()[0].memory("pinned_host")
        return jax.device_put(x, sharding)
    return x


def to_fast_tier(x, mode: str = "buffer", mesh=None):
    if mode == "memkind" and supports_memkind():
        sharding = NamedSharding(mesh, P(), memory_kind="device") \
            if mesh is not None else jax.devices()[0].memory("device")
        return jax.device_put(x, sharding)
    return x
