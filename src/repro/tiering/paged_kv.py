"""Policy-tiered paged KV cache (DESIGN.md §2 integration 1, §10).

The KV cache is split into fixed-size token pages living in one of two
pools: the FAST pool (HBM) and the SLOW pool (host memory over PCIe; on
this CPU container a second buffer — see host_offload.py).  A page table
maps each logical page to (tier, slot).  Per decode step:

  1. attention runs over all logical pages (gathered per-tier);
  2. the per-page ACCESS SIGNAL is the attention mass the page received
     (the KV analogue of the paper's PEBS counts — pages whose keys win
     softmax weight are the hot set);
  3. the per-tier READ VOLUMES (the bytes ``_gather_kv`` pulls from each
     pool) feed the measured bandwidth signals;
  4. every ``policy_every`` steps the placement policy — ANY family in
     ``experiment.POLICY_REGISTRY``, default ARMS — scores pages and the
     shared ``tiered_pool`` executor migrates both pools.

Invariant: every logical page lives in exactly one pool slot; fast-pool
capacity is k pages — exactly the paper's top-k classification target.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import ARMSConfig
from repro.tiering import tiered_pool as TP


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    page_size: int = 64
    n_pages: int = 64            # logical pages per sequence-group
    fast_pages: int = 16         # fast-pool capacity (k)
    policy_every: int = 8        # decode steps between policy invocations
    # dLatency: a KV page streamed over PCIe vs HBM; one access = one unit
    # of attention mass landing on the page in a decode step.
    arms: ARMSConfig = ARMSConfig(access_scale=1.0, latency_fast_us=1.0,
                                  latency_slow_us=30.0,
                                  init_promo_cost_us=5.0,
                                  init_demo_cost_us=5.0)
    machine: str = TP.DEFAULT_MACHINE


@dataclasses.dataclass(frozen=True)
class PagedKV:
    """State for one layer's paged KV over a batch-shared page space."""
    k_fast: jnp.ndarray      # [Pf, page, B, KV, dh]
    v_fast: jnp.ndarray
    k_slow: jnp.ndarray      # [Ps, page, B, KV, dh]
    v_slow: jnp.ndarray
    pool: TP.TieredPool      # residency + policy state + telemetry

    # residency metadata delegates to the pool (sparse_attention.py and
    # the tests read these directly; the pool is the single source).
    @property
    def in_fast(self):
        return self.pool.in_fast

    @property
    def slot(self):
        return self.pool.slot

    @property
    def counts(self):
        return self.pool.counts

    @property
    def step(self):
        return self.pool.t

    @property
    def arms(self):
        """Inner ARMS TieringState when an ARMS-family policy drives the
        pool (legacy telemetry accessor)."""
        return self.pool.state.inner


jax.tree_util.register_dataclass(
    PagedKV,
    data_fields=["k_fast", "v_fast", "k_slow", "v_slow", "pool"],
    meta_fields=[])


def init_paged_kv(cfg: PagedKVConfig, bsz: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16, policy="arms") -> PagedKV:
    """``policy``: a family name from ``experiment.POLICY_REGISTRY`` or a
    PolicySpec instance; ``"arms"`` keeps the legacy serving semantics."""
    page, n, pf = cfg.page_size, cfg.n_pages, cfg.fast_pages
    ps = n  # slow pool can hold every page (home slot = logical id)
    shape_f = (pf, page, bsz, kv_heads, head_dim)
    shape_s = (ps, page, bsz, kv_heads, head_dim)
    pool = TP.init_pool(policy, n, pf, machine=cfg.machine,
                        arms_cfg=cfg.arms, pool_every=cfg.policy_every)
    return PagedKV(
        k_fast=jnp.zeros(shape_f, dtype), v_fast=jnp.zeros(shape_f, dtype),
        k_slow=jnp.zeros(shape_s, dtype), v_slow=jnp.zeros(shape_s, dtype),
        pool=pool)


def with_residency(kv: PagedKV, in_fast) -> PagedKV:
    """Override the residency mask (tests / sparse-attention what-ifs);
    slots and pool state are left as-is."""
    return dataclasses.replace(
        kv, pool=kv.pool.replace(in_fast=jnp.asarray(in_fast, bool)))


def page_kv_bytes(kv: PagedKV) -> float:
    """Bytes one K+V page occupies — the unit of the measured per-tier
    read volumes and of migration traffic."""
    page_elems = 1
    for d in kv.k_slow.shape[1:]:
        page_elems *= d
    return float(2 * page_elems * kv.k_slow.dtype.itemsize)


def _gather_kv(kv: PagedKV):
    """Materialize logical [n_pages, page, B, KV, dh] views of K and V.

    Resident pages read the fast pool; the rest read the slow pool — the
    per-tier read volumes are what the serving cost model charges."""
    k = jnp.where(kv.in_fast[:, None, None, None, None],
                  kv.k_fast[jnp.clip(kv.slot, 0, kv.k_fast.shape[0] - 1)],
                  kv.k_slow[kv.slot])
    v = jnp.where(kv.in_fast[:, None, None, None, None],
                  kv.v_fast[jnp.clip(kv.slot, 0, kv.v_fast.shape[0] - 1)],
                  kv.v_slow[kv.slot])
    return k, v


def read_volumes(kv: PagedKV, pos, cfg: PagedKVConfig):
    """(fast_bytes, slow_bytes) one decode step's ``_gather_kv`` pulls:
    every valid page (holding tokens <= pos) is read once from its tier."""
    n_valid = jnp.minimum(pos // cfg.page_size + 1, cfg.n_pages)
    valid = jnp.arange(cfg.n_pages) < n_valid
    pb = page_kv_bytes(kv)
    fast = (valid & kv.in_fast).sum().astype(jnp.float32) * pb
    slow = (valid & ~kv.in_fast).sum().astype(jnp.float32) * pb
    return fast, slow


def write_token(kv: PagedKV, k_new, v_new, pos, cfg: PagedKVConfig):
    """Append this step's K/V ([B, KV, dh]) at logical position ``pos``."""
    page_id = pos // cfg.page_size
    offset = pos % cfg.page_size
    slot = kv.slot[page_id]
    in_fast = kv.in_fast[page_id]
    Pf = kv.k_fast.shape[0]

    def upd(pool, new, slot_idx):
        return jax.lax.dynamic_update_slice(
            pool, new[None, None], (slot_idx, offset, 0, 0, 0))

    kf = jax.lax.cond(in_fast,
                      lambda: upd(kv.k_fast, k_new,
                                  jnp.clip(slot, 0, Pf - 1)),
                      lambda: kv.k_fast)
    vf = jax.lax.cond(in_fast,
                      lambda: upd(kv.v_fast, v_new,
                                  jnp.clip(slot, 0, Pf - 1)),
                      lambda: kv.v_fast)
    ks = jax.lax.cond(in_fast, lambda: kv.k_slow,
                      lambda: upd(kv.k_slow, k_new, slot))
    vs = jax.lax.cond(in_fast, lambda: kv.v_slow,
                      lambda: upd(kv.v_slow, v_new, slot))
    return dataclasses.replace(kv, k_fast=kf, v_fast=vf, k_slow=ks,
                               v_slow=vs)


def paged_attention_step(kv: PagedKV, q, pos, cfg: PagedKVConfig,
                         scale=None):
    """Decode attention over the paged cache.

    q: [B, H, dh] -> (out [B, H, dh], page attention-mass counts [n_pages]).
    """
    B, H, dh = q.shape
    page, n = cfg.page_size, cfg.n_pages
    k, v = _gather_kv(kv)                       # [n, page, B, KV, dh]
    KV = k.shape[3]
    rep = H // KV
    scale = scale or dh ** -0.5

    kf = k.transpose(2, 0, 1, 3, 4).reshape(B, n * page, KV, dh)
    vf = v.transpose(2, 0, 1, 3, 4).reshape(B, n * page, KV, dh)
    qg = q.reshape(B, KV, rep, dh)
    s = jnp.einsum("bkrd,bskd->bkrs", qg, kf).astype(jnp.float32) * scale
    valid = jnp.arange(n * page)[None] <= pos
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrs,bskd->bkrd", p.astype(vf.dtype), vf)
    # per-page attention mass, summed over batch/heads — the access signal
    mass = p.reshape(B, KV, rep, n, page).sum(axis=(0, 1, 2, 4))
    return out.reshape(B, H, dh), mass


@functools.partial(jax.jit, static_argnames=("cfg",))
def serve_decode_step(kv: PagedKV, q, k_new, v_new, pos,
                      cfg: PagedKVConfig):
    """Full tiered decode step for one attention layer:
    write -> attend -> pool_step (observe + periodic policy + migration).

    Returns (out, new_kv, PoolPlan-with-count-0-when-skipped)."""
    kv = write_token(kv, k_new, v_new, pos, cfg)
    out, mass = paged_attention_step(kv, q, pos, cfg)
    rf, rs = read_volumes(kv, pos, cfg)
    pool, bufs, plan = TP.pool_step(
        kv.pool, mass, rf, rs, k=cfg.fast_pages,
        bufs=((kv.k_fast, kv.k_slow), (kv.v_fast, kv.v_slow)),
        copy_back=True, page_bytes=page_kv_bytes(kv))
    (kf, ks), (vf, vs) = bufs
    kv = dataclasses.replace(kv, k_fast=kf, k_slow=ks, v_fast=vf,
                             v_slow=vs, pool=pool)
    return out, kv, plan
