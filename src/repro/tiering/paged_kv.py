"""ARMS-tiered paged KV cache (DESIGN.md §2, integration 1).

The KV cache is split into fixed-size token pages living in one of two
pools: the FAST pool (HBM) and the SLOW pool (host memory over PCIe; on
this CPU container a second buffer — see host_offload.py).  A page table
maps each logical page to (tier, slot).  Per decode step:

  1. attention runs over all logical pages (gathered per-tier);
  2. the per-page ACCESS SIGNAL is the attention mass the page received
     (the KV analogue of the paper's PEBS counts — pages whose keys win
     softmax weight are the hot set);
  3. every ``policy_every`` steps the ARMS controller (core/) scores pages
     and emits a bandwidth-aware batched migration plan;
  4. the plan executes via the batched-migration Pallas kernel
     (kernels/migrate) on both pools.

Invariant: every logical page lives in exactly one pool slot; fast-pool
capacity is k pages — exactly the paper's top-k classification target.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import ARMSConfig, MigrationPlan, TieringState, arms_step
from repro.core import init_state as arms_init
from repro.kernels.migrate.ref import migrate_ref


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    page_size: int = 64
    n_pages: int = 64            # logical pages per sequence-group
    fast_pages: int = 16         # fast-pool capacity (k)
    policy_every: int = 8        # decode steps between ARMS invocations
    # dLatency: a KV page streamed over PCIe vs HBM; one access = one unit
    # of attention mass landing on the page in a decode step.
    arms: ARMSConfig = ARMSConfig(access_scale=1.0, latency_fast_us=1.0,
                                  latency_slow_us=30.0,
                                  init_promo_cost_us=5.0,
                                  init_demo_cost_us=5.0)


@dataclasses.dataclass(frozen=True)
class PagedKV:
    """State for one layer's paged KV over a batch-shared page space."""
    k_fast: jnp.ndarray      # [Pf, page, B, KV, dh]
    v_fast: jnp.ndarray
    k_slow: jnp.ndarray      # [Ps, page, B, KV, dh]
    v_slow: jnp.ndarray
    in_fast: jnp.ndarray     # [n_pages] bool — tier of each logical page
    slot: jnp.ndarray        # [n_pages] i32 — slot within its tier pool
    counts: jnp.ndarray      # [n_pages] f32 — accumulated attention mass
    arms: TieringState
    step: jnp.ndarray        # i32


jax.tree_util.register_dataclass(
    PagedKV,
    data_fields=["k_fast", "v_fast", "k_slow", "v_slow", "in_fast", "slot",
                 "counts", "arms", "step"],
    meta_fields=[])


def init_paged_kv(cfg: PagedKVConfig, bsz: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> PagedKV:
    page, n, pf = cfg.page_size, cfg.n_pages, cfg.fast_pages
    ps = n  # slow pool can hold every page
    shape_f = (pf, page, bsz, kv_heads, head_dim)
    shape_s = (ps, page, bsz, kv_heads, head_dim)
    # initial placement: all pages in the slow pool, slot = logical id
    return PagedKV(
        k_fast=jnp.zeros(shape_f, dtype), v_fast=jnp.zeros(shape_f, dtype),
        k_slow=jnp.zeros(shape_s, dtype), v_slow=jnp.zeros(shape_s, dtype),
        in_fast=jnp.zeros((n,), bool),
        slot=jnp.arange(n, dtype=jnp.int32),
        counts=jnp.zeros((n,), jnp.float32),
        arms=arms_init(n, cfg.arms),
        step=jnp.zeros((), jnp.int32))


def _gather_kv(kv: PagedKV):
    """Materialize logical [n_pages, page, B, KV, dh] views of K and V.

    Resident pages read the fast pool; the rest read the slow pool — the
    per-tier read volumes are what the serving cost model charges."""
    k = jnp.where(kv.in_fast[:, None, None, None, None],
                  kv.k_fast[jnp.clip(kv.slot, 0, kv.k_fast.shape[0] - 1)],
                  kv.k_slow[kv.slot])
    v = jnp.where(kv.in_fast[:, None, None, None, None],
                  kv.v_fast[jnp.clip(kv.slot, 0, kv.v_fast.shape[0] - 1)],
                  kv.v_slow[kv.slot])
    return k, v


def write_token(kv: PagedKV, k_new, v_new, pos, cfg: PagedKVConfig):
    """Append this step's K/V ([B, KV, dh]) at logical position ``pos``."""
    page_id = pos // cfg.page_size
    offset = pos % cfg.page_size
    slot = kv.slot[page_id]
    in_fast = kv.in_fast[page_id]
    Pf = kv.k_fast.shape[0]

    def upd(pool, new, slot_idx):
        return jax.lax.dynamic_update_slice(
            pool, new[None, None], (slot_idx, offset, 0, 0, 0))

    kf = jax.lax.cond(in_fast,
                      lambda: upd(kv.k_fast, k_new,
                                  jnp.clip(slot, 0, Pf - 1)),
                      lambda: kv.k_fast)
    vf = jax.lax.cond(in_fast,
                      lambda: upd(kv.v_fast, v_new,
                                  jnp.clip(slot, 0, Pf - 1)),
                      lambda: kv.v_fast)
    ks = jax.lax.cond(in_fast, lambda: kv.k_slow,
                      lambda: upd(kv.k_slow, k_new, slot))
    vs = jax.lax.cond(in_fast, lambda: kv.v_slow,
                      lambda: upd(kv.v_slow, v_new, slot))
    return dataclasses.replace(kv, k_fast=kf, v_fast=vf, k_slow=ks,
                               v_slow=vs)


def paged_attention_step(kv: PagedKV, q, pos, cfg: PagedKVConfig,
                         scale=None):
    """Decode attention over the paged cache.

    q: [B, H, dh] -> (out [B, H, dh], page attention-mass counts [n_pages]).
    """
    B, H, dh = q.shape
    page, n = cfg.page_size, cfg.n_pages
    k, v = _gather_kv(kv)                       # [n, page, B, KV, dh]
    KV = k.shape[3]
    rep = H // KV
    scale = scale or dh ** -0.5

    kf = k.transpose(2, 0, 1, 3, 4).reshape(B, n * page, KV, dh)
    vf = v.transpose(2, 0, 1, 3, 4).reshape(B, n * page, KV, dh)
    qg = q.reshape(B, KV, rep, dh)
    s = jnp.einsum("bkrd,bskd->bkrs", qg, kf).astype(jnp.float32) * scale
    valid = jnp.arange(n * page)[None] <= pos
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrs,bskd->bkrd", p.astype(vf.dtype), vf)
    # per-page attention mass, summed over batch/heads — the access signal
    mass = p.reshape(B, KV, rep, n, page).sum(axis=(0, 1, 2, 4))
    return out.reshape(B, H, dh), mass


def arms_policy_step(kv: PagedKV, cfg: PagedKVConfig, slow_bw_frac,
                     app_bw_frac):
    """Run the ARMS controller over accumulated page counts and execute the
    migration plan on the pools.  Returns (new_kv, plan)."""
    arms, plan = arms_step(kv.arms, kv.counts, slow_bw_frac, app_bw_frac,
                           cfg=cfg.arms, k=cfg.fast_pages)
    kv = _execute_plan(kv, plan, arms)
    return dataclasses.replace(kv, arms=arms,
                               counts=jnp.zeros_like(kv.counts)), plan


def _execute_plan(kv: PagedKV, plan: MigrationPlan, arms: TieringState):
    """Move promoted pages slow->fast (into the demoted pages' slots or
    free slots) and demoted pages fast->slow (back to their home slot —
    slow slot = logical id, so demotion targets are always free)."""
    Pf = kv.k_fast.shape[0]
    n = kv.in_fast.shape[0]

    def body(state, entry):
        (kf, vf, ks, vs, in_fast, slot) = state
        p_id, d_id, valid = entry
        p_id_c = jnp.clip(p_id, 0, n - 1)
        d_id_c = jnp.clip(d_id, 0, n - 1)
        has_victim = d_id >= 0
        # fast slot target: victim's slot, else count of used fast slots
        used = jnp.minimum(in_fast.sum(), Pf - 1).astype(jnp.int32)
        f_slot = jnp.where(has_victim, slot[d_id_c], used)
        f_slot = jnp.clip(f_slot, 0, Pf - 1)

        def run(args):
            kf, vf, ks, vs, in_fast, slot = args
            # demote victim: fast[f_slot] -> slow[d_id] (home slot)
            kv_page_k = jax.lax.dynamic_slice_in_dim(kf, f_slot, 1, 0)
            kv_page_v = jax.lax.dynamic_slice_in_dim(vf, f_slot, 1, 0)
            ks = jax.lax.cond(
                has_victim,
                lambda: jax.lax.dynamic_update_slice_in_dim(
                    ks, kv_page_k, d_id_c, 0),
                lambda: ks)
            vs = jax.lax.cond(
                has_victim,
                lambda: jax.lax.dynamic_update_slice_in_dim(
                    vs, kv_page_v, d_id_c, 0),
                lambda: vs)
            # promote: slow[slot[p_id]] -> fast[f_slot]
            src_k = jax.lax.dynamic_slice_in_dim(ks, slot[p_id_c], 1, 0)
            src_v = jax.lax.dynamic_slice_in_dim(vs, slot[p_id_c], 1, 0)
            kf = jax.lax.dynamic_update_slice_in_dim(kf, src_k, f_slot, 0)
            vf = jax.lax.dynamic_update_slice_in_dim(vf, src_v, f_slot, 0)
            in_fast = in_fast.at[d_id_c].set(
                jnp.where(has_victim, False, in_fast[d_id_c]))
            slot = slot.at[d_id_c].set(
                jnp.where(has_victim, d_id_c, slot[d_id_c]))
            in_fast = in_fast.at[p_id_c].set(True)
            slot = slot.at[p_id_c].set(f_slot)
            return kf, vf, ks, vs, in_fast, slot

        state2 = jax.lax.cond(valid, run, lambda a: a,
                              (kf, vf, ks, vs, in_fast, slot))
        return state2, None

    init = (kv.k_fast, kv.v_fast, kv.k_slow, kv.v_slow, kv.in_fast, kv.slot)
    (kf, vf, ks, vs, in_fast, slot), _ = jax.lax.scan(
        body, init, (plan.promote, plan.demote, plan.valid))
    return dataclasses.replace(kv, k_fast=kf, v_fast=vf, k_slow=ks,
                               v_slow=vs, in_fast=in_fast, slot=slot)


@functools.partial(jax.jit, static_argnames=("cfg",))
def serve_decode_step(kv: PagedKV, q, k_new, v_new, pos,
                      cfg: PagedKVConfig):
    """Full tiered decode step for one attention layer:
    write -> attend -> accumulate counts -> (periodically) ARMS policy.

    Returns (out, new_kv, MigrationPlan-with-count-0-when-skipped)."""
    kv = write_token(kv, k_new, v_new, pos, cfg)
    out, mass = paged_attention_step(kv, q, pos, cfg)
    kv = dataclasses.replace(kv, counts=kv.counts + mass,
                             step=kv.step + 1)

    # slow-tier bandwidth signal: attention mass served from slow pages
    slow_mass = jnp.where(kv.in_fast, 0.0, kv.counts).sum() / \
        jnp.maximum(kv.counts.sum(), 1e-9)

    def policy(kv):
        return arms_policy_step(kv, cfg, slow_mass, 0.5)

    def skip(kv):
        empty = MigrationPlan(
            promote=jnp.full((min(cfg.arms.bs_max, cfg.n_pages),), -1,
                             jnp.int32),
            demote=jnp.full((min(cfg.arms.bs_max, cfg.n_pages),), -1,
                            jnp.int32),
            valid=jnp.zeros((min(cfg.arms.bs_max, cfg.n_pages),), bool),
            count=jnp.zeros((), jnp.int32),
            batch_size=jnp.zeros((), jnp.int32))
        return kv, empty

    kv, plan = jax.lax.cond(kv.step % cfg.policy_every == 0, policy, skip,
                            kv)
    return out, kv, plan
