"""Pure-jnp oracle for the fused SSD (Mamba2) chunked scan.

Delegates to the model's chunked implementation (itself validated against
the naive recurrence in tests/test_models_smoke.py / test_kernels.py)."""
from __future__ import annotations

from repro.models.mamba2 import ssd_chunked


def mamba_scan_ref(x, dt, A, Bm, Cm, chunk: int):
    """x: [B,S,H,P], dt: [B,S,H] (post-softplus), A: [H] (negative),
    Bm/Cm: [B,S,N] -> (y [B,S,H,P], final_state [B,H,P,N])."""
    return ssd_chunked(x, dt, A, Bm, Cm, chunk)
