"""jit'd public wrapper for the fused SSD scan kernel."""
from __future__ import annotations

from repro.kernels._backend import interpret_mode
from repro.kernels.mamba_scan.kernel import mamba_scan_kernel
from repro.kernels.mamba_scan.ref import mamba_scan_ref


def mamba_scan(x, dt, A, Bm, Cm, *, chunk: int = 64,
               use_kernel: bool = True):
    if not use_kernel:
        return mamba_scan_ref(x, dt, A, Bm, Cm, chunk)
    return mamba_scan_kernel(x, dt, A, Bm, Cm, chunk=chunk,
                             interpret=interpret_mode())
