"""Pallas TPU kernel: fused Mamba2 SSD chunked scan.

Grid: (B, H, n_chunks) with the chunk axis innermost and SEQUENTIAL — the
[P, N] recurrent state lives in VMEM scratch and is carried across chunk
steps, so the full layer scan is ONE kernel launch: intra-chunk quadratic
block (decay-masked C·Bᵀ, MXU matmuls), chunk-state build, and the
inter-chunk recurrence all stay in VMEM.  This is the SSM analogue of the
flash-attention carry pattern.

VMEM working set per step: x[Q,P] + B/C[Q,N] + decay[Q,Q] + state[P,N]
(f32) — e.g. Q=64, P=64, N=128: ~120 KB, comfortably within v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref,
            *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)           # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # [Q]
    a = a_ref[0]                                     # scalar (negative)
    bm = b_ref[0, :].astype(jnp.float32)             # [Q, N]
    cm = c_ref[0, :].astype(jnp.float32)             # [Q, N]

    da = dt * a                                      # [Q] (<= 0)
    da_cs = jnp.cumsum(da)                           # [Q]

    # intra-chunk: L[i,j] = exp(cs_i - cs_j) for i >= j
    diff = da_cs[:, None] - da_cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(ii >= jj, jnp.exp(diff), 0.0)   # [Q,Q]
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,Q]
    xdt = x * dt[:, None]                            # [Q,P]
    y = jax.lax.dot_general(cb * lmat, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # off-diagonal: carried state with decay from chunk start
    h = h_ref[...]                                   # [P,N]
    decay_in = jnp.exp(da_cs)[:, None]               # [Q,1]
    y += decay_in * jax.lax.dot_general(
        cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # [Q,P]

    # state update: h' = exp(sum da) * h + sum_i decay_to_end_i dt_i x_i B_i
    decay_end = jnp.exp(da_cs[-1] - da_cs)           # [Q]
    weighted = xdt * decay_end[:, None]              # [Q,P]
    h_new = (jnp.exp(da_cs[-1]) * h
             + jax.lax.dot_general(weighted, bm, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    h_ref[...] = h_new

    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _emit_state():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_scan_kernel(x, dt, A, Bm, Cm, *, chunk: int = 64,
                      interpret: bool = True):
    """See ref.mamba_scan_ref. x: [B,S,H,P]."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    grid = (Bsz, H, nc)

    y, h_final = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, h_final
