"""jit'd public wrapper for flash attention (prefill / training forward)."""
from __future__ import annotations

from repro.kernels._backend import interpret_mode
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_kernel: bool = True):
    if not use_kernel:
        return flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_kernel(q, k, v, causal=causal, window=window,
                                  interpret=interpret_mode())
