"""Pure-jnp oracle for causal (optionally windowed) GQA flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B,S,H,dh], k/v: [B,S,KV,dh] -> [B,S,H,dh]."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    rep = H // KV
    qg = q.reshape(B, S, KV, rep, dh)
    s = jnp.einsum("bqkrd,bskd->bkrqs", qg, k).astype(jnp.float32)
    s *= dh ** -0.5
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kj <= qi
    if window:
        mask &= kj > qi - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrqs,bskd->bqkrd", p, v)
    return out.reshape(B, S, H, dh)
