"""Pallas TPU kernel: tiled causal flash attention (prefill/training fwd).

Grid: (B, KV, Sq/bq, Sk/bk) with the key axis innermost so the online
softmax carry (m, l, acc in VMEM scratch) is reused across key tiles.
Causal tiles entirely above the diagonal are skipped via pl.when, giving
the ~2x triangular saving.  Block sizes default to (128, 128) -> MXU-aligned
(dh is 64 or 128 in all assigned configs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, bq: int, bk: int, scale: float, causal: bool, window: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk
    run = True
    if causal:
        run = k_start <= q_start + bq - 1   # tile intersects causal region

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0].astype(jnp.float32)        # [bq*rep? no: bq, dh]
        k = k_ref[0, :, 0].astype(jnp.float32)        # [bk, dh]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask &= kj <= qi
        if window:
            mask &= kj > qi - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, :, 0] = (acc_ref[...]
                          / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = True):
    """Single-query-head-per-KV variant: q [B,S,H,dh] with H == KV * rep is
    folded so each grid cell handles one (batch, q-head) row block."""
    B, S, H, dh = q.shape
    KV = k.shape[2]
    rep = H // KV
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0

    # fold rep into batch of query heads: grid over (B*rep, KV, ...)
    qh = q.reshape(B, S, KV, rep, dh).transpose(0, 3, 1, 2, 4) \
        .reshape(B * rep, S, KV, dh)

    grid = (B * rep, KV, S // bq, S // bk)

    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, scale=dh ** -0.5,
                          causal=causal, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, dh), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, dh),
                         lambda b, h, iq, ik: (b // rep, ik, h, 0)),
            pl.BlockSpec((1, bk, 1, dh),
                         lambda b, h, iq, ik: (b // rep, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dh),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B * rep, S, KV, dh), q.dtype),
        interpret=interpret,
    )(qh, k, v)
    out = out.reshape(B, rep, S, KV, dh).transpose(0, 2, 3, 1, 4)
    return out.reshape(B, S, H, dh)
