"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens):
    """Decode attention over a paged KV cache.

    q:            [B, H, dh]           one query token per sequence
    k_pages:      [P, page, KV, dh]    global page pool
    v_pages:      [P, page, KV, dh]
    block_tables: [B, pages_per_seq]   page ids per sequence (i32)
    seq_lens:     [B]                  valid tokens per sequence (i32)
    -> [B, H, dh]
    """
    B, H, dh = q.shape
    page = k_pages.shape[1]
    KV = k_pages.shape[2]
    rep = H // KV
    n_pp = block_tables.shape[1]

    k = k_pages[block_tables]                # [B, n_pp, page, KV, dh]
    v = v_pages[block_tables]
    k = k.reshape(B, n_pp * page, KV, dh)
    v = v.reshape(B, n_pp * page, KV, dh)

    qg = q.reshape(B, KV, rep, dh)
    scores = jnp.einsum("bkrd,bskd->bkrs", qg, k).astype(jnp.float32)
    scores *= dh ** -0.5
    valid = jnp.arange(n_pp * page)[None] < seq_lens[:, None]   # [B, S]
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrs,bskd->bkrd", probs, v)
    return out.reshape(B, H, dh)
