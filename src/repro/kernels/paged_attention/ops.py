"""jit'd public wrapper for paged decode attention.

On TPU the Pallas kernel runs compiled; elsewhere (this CPU container) it
runs in interpret mode, which executes the same kernel body in Python for
bit-level validation against ref.py.
"""
from __future__ import annotations

from repro.kernels._backend import interpret_mode
from repro.kernels.paged_attention.kernel import paged_attention_kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens,
                    *, use_kernel: bool = True):
    if not use_kernel:
        return paged_attention_ref(q, k_pages, v_pages, block_tables,
                                   seq_lens)
    return paged_attention_kernel(q, k_pages, v_pages, block_tables,
                                  seq_lens, interpret=interpret_mode())
