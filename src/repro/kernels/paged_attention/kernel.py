"""Pallas TPU kernel: paged decode attention (flash-decoding style).

Grid: (B, KV, pages_per_seq) — innermost axis walks a sequence's pages in
order; the page id for each step comes from the scalar-prefetched block
table, so the BlockSpec index_map DMAs exactly the page the sequence needs
(HBM -> VMEM), which is what makes an ARMS-tiered page pool work: pages are
physical tiles, attention never touches pages outside the table.

Online softmax state (running max / denom / accumulator) lives in VMEM
scratch and is carried across the page-walk; the output block is written on
the last page step.

VMEM budget per step: one KV page (page x dh), the [rep, dh] query block and
the f32 accumulator — tile sizes are chosen so page*dh and rep*dh are
multiples of the (8,128) TPU tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page: int, scale: float):
    b = pl.program_id(0)
    i = pl.program_id(2)
    n_pp = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # [rep, dh]
    k = k_ref[0, :, 0].astype(jnp.float32)         # [page, dh]
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * scale                                   # [rep, page]
    token_pos = i * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(token_pos < lens_ref[b], s, NEG_INF)

    m_prev = m_ref[...]                             # [rep, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                          # [rep, page]
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(i == n_pp - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_kernel(q, k_pages, v_pages, block_tables, seq_lens,
                           *, interpret: bool = True):
    """See ref.paged_attention_ref for semantics. q: [B, H, dh]."""
    B, H, dh = q.shape
    P, page, KV, _ = k_pages.shape
    rep = H // KV
    n_pp = block_tables.shape[1]
    qg = q.reshape(B, KV, rep, dh)

    grid = (B, KV, n_pp)

    def q_map(b, h, i, tables, lens):
        return (b, h, 0, 0)

    def kv_map(b, h, i, tables, lens):
        return (tables[b, i], 0, h, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, page=page, scale=dh ** -0.5),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, rep, dh), q_map),
                pl.BlockSpec((1, page, 1, dh), kv_map),
                pl.BlockSpec((1, page, 1, dh), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, rep, dh), q_map),
            scratch_shapes=[
                pltpu.VMEM((rep, 1), jnp.float32),   # running max
                pltpu.VMEM((rep, 1), jnp.float32),   # running denom
                pltpu.VMEM((rep, dh), jnp.float32),  # accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, rep, dh), q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, qg, k_pages, v_pages)
    return out.reshape(B, H, dh)
