"""Pallas TPU kernel: batched page migration (gather/scatter by table).

This is the data plane of the paper's §4.4 batched migration mechanism: one
grid step per migration entry; the scalar-prefetched (src, dst, valid)
tables drive the BlockSpec index maps, so each step DMAs one page from the
source pool tile into the destination pool tile.  Invalid entries are
routed to a scratch page (index 0 read, self-write) and masked by writing
the existing destination content back.

``input_output_aliases`` makes the destination update in place — a batch of
BS migrations is one kernel launch, the TPU analogue of Nimble's
multi-threaded batched copies.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(src_idx_ref, dst_idx_ref, valid_ref, src_ref, dst_in_ref,
            dst_out_ref):
    i = pl.program_id(0)

    @pl.when(valid_ref[i])
    def _copy():
        dst_out_ref[...] = src_ref[...]

    @pl.when(jnp.logical_not(valid_ref[i]))
    def _keep():
        dst_out_ref[...] = dst_in_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def migrate_kernel(src_pool, dst_pool, src_idx, dst_idx, valid,
                   *, interpret: bool = True):
    M = src_idx.shape[0]
    if M == 0:            # empty batch: zero-size grids don't lower
        return dst_pool
    _, page, feat = src_pool.shape

    def src_map(i, src, dst, val):
        return (src[i], 0, 0)

    def dst_map(i, src, dst, val):
        # invalid entries read+write destination slot dst[i] anyway (no-op
        # copy of existing content); index stays in range via the engine.
        return (dst[i], 0, 0)

    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(M,),
            in_specs=[
                pl.BlockSpec((1, page, feat), src_map),
                pl.BlockSpec((1, page, feat), dst_map),
            ],
            out_specs=pl.BlockSpec((1, page, feat), dst_map),
        ),
        out_shape=jax.ShapeDtypeStruct(dst_pool.shape, dst_pool.dtype),
        input_output_aliases={4: 0},   # dst_pool (4th operand) -> output
        interpret=interpret,
    )(src_idx, dst_idx, valid, src_pool, dst_pool)
    return out
