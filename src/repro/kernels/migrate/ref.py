"""Pure-jnp oracle for the batched page-migration engine."""
from __future__ import annotations

import jax.numpy as jnp


def migrate_ref(src_pool, dst_pool, src_idx, dst_idx, valid):
    """Copy src_pool[src_idx[i]] -> dst_pool[dst_idx[i]] where valid[i].

    src_pool: [Ps, page, feat]; dst_pool: [Pd, page, feat];
    src_idx/dst_idx: [M] i32; valid: [M] bool.  Invalid entries are no-ops.
    Returns the updated dst_pool.
    """
    Pd = dst_pool.shape[0]
    pages = src_pool[src_idx]                       # [M, page, feat]
    # route invalid writes to a scratch row index Pd (dropped)
    tgt = jnp.where(valid, dst_idx, Pd)
    return dst_pool.at[tgt].set(pages, mode="drop")
