"""jit'd public wrapper for the batched page-migration engine."""
from __future__ import annotations

from repro.kernels._backend import interpret_mode
from repro.kernels.migrate.kernel import migrate_kernel
from repro.kernels.migrate.ref import migrate_ref


def migrate_pages(src_pool, dst_pool, src_idx, dst_idx, valid,
                  *, use_kernel: bool = True):
    if not use_kernel:
        return migrate_ref(src_pool, dst_pool, src_idx, dst_idx, valid)
    return migrate_kernel(src_pool, dst_pool, src_idx, dst_idx, valid,
                          interpret=interpret_mode())
