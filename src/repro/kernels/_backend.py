"""Module-cached backend probe shared by every ``kernels/*/ops.py``.

Every op wrapper used to call ``jax.default_backend() != "tpu"`` on each
invocation to decide whether the Pallas kernel should run compiled or in
interpret mode.  Inside the scan engine that probe sat on the per-interval
hot path (one backend-registry lookup per op per interval per lane), so it
is resolved ONCE at import of the first op module and cached here.

``REPRO_FORCE_INTERPRET=1`` (any non-empty value other than ``0``) forces
interpret mode regardless of backend — the switch the kernel-vs-ref CI
checks use to exercise the Pallas path on CPU containers.
"""
from __future__ import annotations

import os

_INTERPRET: bool | None = None


def force_interpret() -> bool:
    """Did the environment pin interpret mode (``REPRO_FORCE_INTERPRET``)?"""
    return os.environ.get("REPRO_FORCE_INTERPRET", "0") not in ("", "0")


def interpret_mode() -> bool:
    """True when Pallas kernels must run interpreted (non-TPU backend or
    ``REPRO_FORCE_INTERPRET``).  The backend probe runs once per process;
    jax backends cannot change after initialization, so caching is safe.
    """
    global _INTERPRET
    if _INTERPRET is None:
        import jax

        _INTERPRET = force_interpret() or jax.default_backend() != "tpu"
    return _INTERPRET
