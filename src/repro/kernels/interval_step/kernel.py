"""Pallas TPU kernels: fused per-interval fast path of the scan engine.

Four kernels, one grid step per sweep lane (grid = (B,)), each fusing a
stage of scan_engine's interval body that the unfused path spreads over
many small XLA ops:

  * ``topk_mask_kernel``    — exact top-k mask by threshold bisection over
    the uint32 order key (32 count-passes) plus an index bisection for the
    tie-break (no ``lax.top_k`` partial sort, no scatter, no cumsum — the
    tie rule still matches ``lax.top_k`` exactly: strictly-greater first,
    ascending index among threshold-equal values);
  * ``tier_migrate_kernel`` — the adjacent-pair hop-chain migration engine
    as a per-lane sequential sweep over the padded plans with per-tier
    occupancy counters (equivalent to the vectorized simjax form for
    plans whose valid page indices are unique — the padded-index
    contract);
  * ``interval_account_kernel`` — per-tier access split, interval cost and
    oracle recall in ONE pass over the [n] row;
  * ``ewma_update_kernel``  — the lane-batched dual-EWMA + score update
    (kernels/score_update generalized to [B, n] with per-lane weights).

All four run compiled on TPU and in interpret mode elsewhere; their
bitwise contracts are the references in ref.py (tests/test_interval_step).
f32 row reductions accumulate in row-major element order, matching the
XLA CPU reduce the references lower to; on compiled TPU the tiled reduce
may associate differently — the ops layer only selects these kernels on
TPU, where every path goes through them consistently.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.simulator.machine import CACHELINE, PAGE_BYTES

LANE = 128          # f32 minor-dim tile


def _padded(n: int) -> int:
    return max(LANE, -(-n // LANE) * LANE)


def _pad_cols(x, fill):
    n = x.shape[-1]
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, _padded(n) - n)],
                   constant_values=fill)


# ------------------------------------------------------------ top-k mask
def _topk_body(n: int, k: int, x_ref, out_ref):
    x = x_ref[...]                                        # (1, n_pad) f32
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = iota < n
    u = jax.lax.bitcast_convert_type(x, jnp.uint32)
    # sign-magnitude bit order (ref._order_key): sign BIT, not x < 0, so
    # +0.0 ranks strictly above -0.0 exactly like lax.top_k.
    sign = jnp.uint32(0x80000000)
    key = jnp.where((u & sign) != 0, ~u, u | sign)
    key = jnp.where(valid, key, 0)                        # pads never win

    def val_bit(i, t):
        cand = t | (jnp.uint32(1) << (31 - i).astype(jnp.uint32))
        cnt = jnp.sum((key >= cand).astype(jnp.int32))
        return jnp.where(cnt >= k, cand, t)

    t = jax.lax.fori_loop(0, 32, val_bit, jnp.uint32(0))
    greater = key > t
    eq = (key == t) & valid
    need = k - jnp.sum(greater.astype(jnp.int32))         # >= 1 always

    # largest m with count(eq & iota < m) < need; ties are then iota <= m.
    # Bits 30..0 cover any n (i32 iota); bit 31 would wrap negative.
    def idx_bit(i, m):
        cand = m + (jnp.int32(1) << (31 - i))
        cnt = jnp.sum((eq & (iota < cand)).astype(jnp.int32))
        return jnp.where(cnt < need, cand, m)

    m = jax.lax.fori_loop(1, 32, idx_bit, jnp.int32(0))
    out_ref[...] = (greater | (eq & (iota <= m))).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_mask_kernel(x, k: int, *, interpret: bool = True):
    B, n = x.shape
    xp = _pad_cols(jnp.asarray(x, jnp.float32), 0.0)
    spec = pl.BlockSpec((1, xp.shape[1]), lambda b: (b, 0))
    out = pl.pallas_call(
        functools.partial(_topk_body, n, k),
        grid=(B,),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.int32),
        interpret=interpret,
    )(xp)
    return out[:, :n] != 0


# ------------------------------------------------------- tier migrations
def _migrate_body(R: int, n: int, tier_ref, promote_ref, demote_ref,
                  caps_ref, tier_out, pexec_ref, dexec_ref, mig_up_ref,
                  mig_down_ref, dest_ref):
    i32 = jnp.int32
    tier = tier_ref[...]                                  # (1, n_pad) i32
    iota_pg = jax.lax.broadcasted_iota(i32, tier.shape, 1)
    valid_pg = iota_pg < n
    iota_r = jax.lax.iota(i32, R)
    D = demote_ref.shape[1]
    P = promote_ref.shape[1]

    def occupancy(t_row, r):
        return jnp.sum(((t_row == r) & valid_pg).astype(i32))

    # pass 1: validity + per-tier departure counts (sources read from the
    # ORIGINAL placement, as the vectorized form gathers them up front).
    def dep_step(i, dep):
        d = demote_ref[0, i]
        src = tier_ref[0, jnp.maximum(d, 0)]
        dx = (d >= 0) & (src < R - 1)
        return dep + dx.astype(i32) * (iota_r == src)

    dep = jax.lax.fori_loop(0, D, dep_step, jnp.zeros((R,), i32))

    # per-middle-tier slack once departures free their slots (the same
    # "occupancy after ALL departures" the vectorized form ranks against).
    slack = [i32(0)]
    for r in range(1, R - 1):
        slack.append(caps_ref[0, r] - (occupancy(tier, r) - dep[r]))
    slack = slack + [i32(n)]                              # bottom: room

    # pass 2: land each demotion at the first middle tier below its source
    # with room left; entry order within a tier matches the cumsum rank.
    def land_step(i, land_cnt):
        d = demote_ref[0, i]
        src = tier_ref[0, jnp.maximum(d, 0)]
        dx = (d >= 0) & (src < R - 1)
        dest = i32(R - 1)
        for r in range(R - 2, 0, -1):      # try lowest r > src first
            room = (slack[r] - land_cnt[r]) > 0
            dest = jnp.where((src < r) & room, i32(r), dest)
        dest = jnp.where(dx, dest, i32(R - 1))
        dexec_ref[0, i] = dx
        dest_ref[i] = dest
        return land_cnt + dx.astype(i32) * (iota_r == dest)

    jax.lax.fori_loop(0, D, land_step, jnp.zeros((R,), i32))

    # pass 3: apply demotions + accumulate adjacent-pair down-crossings.
    tier_out[...] = tier
    iota_pair = jax.lax.iota(i32, R - 1)

    def apply_down(i, mig_down):
        d = demote_ref[0, i]
        src = tier_ref[0, jnp.maximum(d, 0)]
        dx = dexec_ref[0, i]
        dest = dest_ref[i]
        idx = jnp.where(dx, d, 0)
        tier_out[0, idx] = jnp.where(dx, dest, tier_out[0, idx])
        cross = dx & (src <= iota_pair) & (dest > iota_pair)
        return mig_down + cross.astype(i32)

    mig_down = jax.lax.fori_loop(0, D, apply_down, jnp.zeros((R - 1,), i32))

    # pass 4: promotions to tier 0, capped by room after demotions; the
    # rank counts every valid request (not only executed ones), matching
    # the vectorized cumsum rule.  Sources read post-demotion, pre-write.
    room0 = caps_ref[0, 0] - occupancy(tier_out[...], 0)

    def promo_step(i, carry):
        cnt, mig_up = carry
        p = promote_ref[0, i]
        src = tier_out[0, jnp.maximum(p, 0)]
        ok = (p >= 0) & (src > 0)
        ex = ok & (cnt < room0)
        pexec_ref[0, i] = ex
        cross = ex & (src > iota_pair)
        return cnt + ok.astype(i32), mig_up + cross.astype(i32)

    _, mig_up = jax.lax.fori_loop(
        0, P, promo_step, (i32(0), jnp.zeros((R - 1,), i32)))

    def apply_up(i, _):
        p = promote_ref[0, i]
        ex = pexec_ref[0, i]
        idx = jnp.where(ex, p, 0)
        tier_out[0, idx] = jnp.where(ex, i32(0), tier_out[0, idx])
        return 0

    jax.lax.fori_loop(0, P, apply_up, 0)
    mig_up_ref[...] = mig_up[None]
    mig_down_ref[...] = mig_down[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def tier_migrate_kernel(tier, promote, demote, caps, *,
                        interpret: bool = True):
    B, n = tier.shape
    R = caps.shape[1]
    P, D = promote.shape[1], demote.shape[1]
    tp = _pad_cols(tier, R)                  # pad tier R: matches no r
    row = pl.BlockSpec((1, tp.shape[1]), lambda b: (b, 0))

    def entries(x, w):
        # zero-width plans get one always-invalid pad entry so the kernel
        # keeps a non-empty block; outputs are sliced back to width 0.
        if w == 0:
            x = jnp.full((B, 1), -1, jnp.int32)
        return x, pl.BlockSpec((1, max(w, 1)), lambda b: (b, 0))

    promote_in, pspec = entries(promote, P)
    demote_in, dspec = entries(demote, D)
    outs = pl.pallas_call(
        functools.partial(_migrate_body, R, n),
        grid=(B,),
        in_specs=[row, pspec, dspec,
                  pl.BlockSpec((1, R), lambda b: (b, 0))],
        out_specs=[row, pspec, dspec,
                   pl.BlockSpec((1, R - 1), lambda b: (b, 0)),
                   pl.BlockSpec((1, R - 1), lambda b: (b, 0))],
        out_shape=[jax.ShapeDtypeStruct(tp.shape, jnp.int32),
                   jax.ShapeDtypeStruct((B, max(P, 1)), jnp.bool_),
                   jax.ShapeDtypeStruct((B, max(D, 1)), jnp.bool_),
                   jax.ShapeDtypeStruct((B, R - 1), jnp.int32),
                   jax.ShapeDtypeStruct((B, R - 1), jnp.int32)],
        scratch_shapes=[pltpu.SMEM((max(D, 1),), jnp.int32)],
        interpret=interpret,
    )(tp, promote_in, demote_in, caps)
    new_tier, pexec, dexec, mig_up, mig_down = outs
    return (new_tier[:, :n], pexec[:, :P], dexec[:, :D], mig_up, mig_down)


# --------------------------------------------------- interval accounting
def _account_body(R: int, n: int, k: int, lat_ref, br_ref, bw_ref, mlp_ref,
                  true_ref, tier_ref, up_ref, down_ref, orc_ref, out_ref):
    true = true_ref[...]                                  # (1, n_pad) f32
    tier = tier_ref[...]
    orc = orc_ref[...]
    mlp = mlp_ref[0, 0]

    total = jnp.sum(true)
    accs, rest = [], total
    for r in range(R - 1):
        a = jnp.sum(true * (tier == r))
        accs.append(a)
        rest = rest - a
    accs.append(rest)

    t_lat = accs[0] * lat_ref[0, 0]
    for r in range(1, R):
        t_lat = t_lat + accs[r] * lat_ref[0, r]
    t_lat = t_lat * 1e-9 / mlp

    times = [(accs[0] * CACHELINE
              + (up_ref[0, 0] + down_ref[0, 0]) * PAGE_BYTES)
             / br_ref[0, 0]]
    for r in range(1, R):
        rd = up_ref[0, r - 1]
        if r < R - 1:
            rd = rd + down_ref[0, r]
        wr = down_ref[0, r - 1]
        if r < R - 1:
            wr = wr + up_ref[0, r]
        times.append((accs[r] * CACHELINE + rd * PAGE_BYTES) / br_ref[0, r]
                     + wr * PAGE_BYTES / bw_ref[0, r])

    rest_max = times[1]
    for r in range(2, R):
        rest_max = jnp.maximum(rest_max, times[r])
    wall = jnp.maximum(jnp.maximum(t_lat, times[0]),
                       jnp.maximum(rest_max, 1e-12))

    rest_acc = accs[1]
    for r in range(2, R):
        rest_acc = rest_acc + accs[r]
    slow_share = rest_acc / jnp.maximum(accs[0] + rest_acc, 1e-9)
    app_raw = times[0] / jnp.maximum(t_lat, jnp.maximum(rest_max, 1e-12))
    recall = jnp.sum(((tier == 0) & (orc != 0)).astype(jnp.int32)) \
        .astype(jnp.float32) / k

    out_ref[0, 0] = accs[0]
    out_ref[0, 1] = rest_acc
    out_ref[0, 2] = wall
    out_ref[0, 3] = slow_share
    out_ref[0, 4] = app_raw
    out_ref[0, 5] = recall


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def interval_account_kernel(lat, br, bw, mlp, true, tier, mig_up, mig_down,
                            oracle, k: int, *, interpret: bool = True):
    """Fused per-lane accounting: lat/br/bw [B, R] f32, mlp [B] f32,
    true [B, n] f32, tier [B, n] i32, mig_up/mig_down [B, R-1] f32,
    oracle [B, n] bool.  Returns the six [B] f32 outputs of
    ``ref.interval_account_ref``."""
    B, n = true.shape
    R = lat.shape[1]
    row = pl.BlockSpec((1, _padded(n)), lambda b: (b, 0))
    tiers = pl.BlockSpec((1, R), lambda b: (b, 0))
    pairs = pl.BlockSpec((1, R - 1), lambda b: (b, 0))
    out = pl.pallas_call(
        functools.partial(_account_body, R, n, k),
        grid=(B,),
        in_specs=[tiers, tiers, tiers,
                  pl.BlockSpec((1, 1), lambda b: (b, 0)),
                  row, row, pairs, pairs, row],
        out_specs=pl.BlockSpec((1, 6), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 6), jnp.float32),
        interpret=interpret,
    )(lat, br, bw, mlp[:, None], _pad_cols(true, 0.0),
      _pad_cols(tier, R), mig_up, mig_down,
      _pad_cols(oracle.astype(jnp.int32), 0))
    return tuple(out[:, i] for i in range(6))


# -------------------------------------------------------- EWMA + score
def _ewma_body(p_ref, s_ref, l_ref, c_ref, s_out, l_out, score_out):
    b = pl.program_id(0)
    a_s, a_l = p_ref[b, 0], p_ref[b, 1]
    w_s, w_l = p_ref[b, 2], p_ref[b, 3]
    c = c_ref[...]
    s = a_s * c + (1 - a_s) * s_ref[...]
    ll = a_l * c + (1 - a_l) * l_ref[...]
    s_out[...] = s
    l_out[...] = ll
    score_out[...] = w_s * s + w_l * ll


@functools.partial(jax.jit, static_argnames=("interpret",))
def ewma_update_kernel(ewma_s, ewma_l, counts, *, alpha_s, alpha_l, w_s,
                       w_l, interpret: bool = True):
    """Lane-batched dual-EWMA + score: arrays [B, n] f32; each smoothing /
    weight param a scalar or [B] (per-lane traced values — mode-dependent
    score weights ride the lane axis)."""
    B, n = ewma_s.shape
    params = jnp.stack([jnp.broadcast_to(jnp.asarray(v, jnp.float32), (B,))
                        for v in (alpha_s, alpha_l, w_s, w_l)], axis=1)
    row = pl.BlockSpec((1, _padded(n)), lambda b: (b, 0))
    outs = pl.pallas_call(
        _ewma_body,
        grid=(B,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), row, row, row],
        out_specs=[row, row, row],
        out_shape=[jax.ShapeDtypeStruct((B, _padded(n)), jnp.float32)
                   for _ in range(3)],
        interpret=interpret,
    )(params, _pad_cols(ewma_s, 0.0), _pad_cols(ewma_l, 0.0),
      _pad_cols(counts, 0.0))
    return tuple(o[:, :n] for o in outs)
