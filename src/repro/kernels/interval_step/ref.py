"""Pure-jnp oracles for the fused per-interval fast path.

Each reference is the BITWISE contract for its Pallas kernel *and* for the
unfused scan-engine path it replaces (scan_engine._simulate with
``use_interval_kernel=False``):

  * ``topk_mask_ref`` computes the exact top-k mask by threshold bisection
    over the order-preserving uint32 transform of f32 — no ``lax.top_k``
    partial sort, no scatter — with ``lax.top_k``'s tie rule (strictly
    greater first, then ascending index among threshold-equal values), so
    the mask is identical to ``zeros.at[top_k(x, k)[1]].set(True)``.
  * ``tier_migrate_ref`` / ``interval_account_ref`` are the vmapped forms
    of the simjax per-lane functions — literally the same jnp ops the
    unfused path traces, so CPU lanes routed here stay bit-identical.
  * ``ewma_score_update_ref`` is the lane-batched form of
    kernels/score_update's elementwise formula.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.simulator import simjax

_SIGN = jnp.uint32(0x80000000)


def _order_key(x):
    """Order-preserving uint32 key of f32: key(a) > key(b) iff a sorts
    above b under ``lax.top_k``'s TOTAL order on non-NaN inputs.  That
    order is sign-magnitude on bits, so +0.0 ranks strictly above -0.0 —
    branch on the sign BIT (``u & 0x80000000``), not on ``x < 0`` (which
    is False for -0.0 and would tie the two zeros)."""
    u = jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.uint32)
    return jnp.where((u & _SIGN) != 0, ~u, u | _SIGN)


def topk_mask_ref(x, k: int):
    """Exact top-k bool mask along the last axis, any leading batch dims.

    Threshold bisection: 32 count-passes find the k-th largest key t; the
    mask is ``key > t`` plus the first ``k - count(key > t)`` ties by
    ascending index — exactly the ``lax.top_k`` + scatter mask.
    """
    n = x.shape[-1]
    assert 0 < k <= n
    key = _order_key(x)
    t = jnp.zeros(x.shape[:-1], jnp.uint32)
    for b in range(31, -1, -1):
        cand = t | jnp.uint32(1 << b)
        cnt = jnp.sum((key >= cand[..., None]).astype(jnp.int32), axis=-1)
        t = jnp.where(cnt >= k, cand, t)
    greater = key > t[..., None]
    eq = key == t[..., None]
    need = k - jnp.sum(greater.astype(jnp.int32), axis=-1)
    tie = eq & (jnp.cumsum(eq.astype(jnp.int32), axis=-1) <= need[..., None])
    return greater | tie


def tier_migrate_ref(tier, promote, demote, caps):
    """Lane-batched ``simjax.apply_tier_migrations``: tier [B, n] i32,
    promote [B, P] / demote [B, D] padded-index plans, caps [B, R] i32.
    Returns (tier, pexec, dexec, mig_up, mig_down) with a leading B axis.
    """
    return jax.vmap(simjax.apply_tier_migrations, in_axes=(0, 0, 0, 0))(
        tier, promote, demote, caps)


def interval_account_ref(mach, true, tier, mig_up, mig_down, oracle, k: int):
    """Lane-batched interval accounting + oracle recall in one call.

    ``mach`` is a lane-batched TieredMachineSpec ([B, R] tier leaves);
    ``true`` f32 [B, n]; ``tier`` i32 [B, n]; ``mig_up``/``mig_down`` f32
    [B, R-1]; ``oracle`` bool [B, n].  Returns (acc_fast, acc_slow, wall,
    slow_share, app_raw, recall), each [B] f32 — the first five bitwise
    those of ``vmap(simjax.interval_accounting_impl)``, recall the scan
    engine's ``((tier == 0) & oracle).sum / k``.
    """
    acc_fast, acc_slow, wall, slow_share, app_raw = jax.vmap(
        simjax.interval_accounting_impl)(mach, true, tier, mig_up, mig_down)
    recall = ((tier == 0) & oracle).sum(axis=1).astype(jnp.float32) / k
    return acc_fast, acc_slow, wall, slow_share, app_raw, recall


def ewma_score_update_ref(ewma_s, ewma_l, counts, *, alpha_s, alpha_l,
                          w_s, w_l):
    """Lane-batched dual-EWMA + score: arrays [B, n] f32, smoothing/weight
    params scalars or [B] (broadcast over pages)."""
    def col(v):
        v = jnp.asarray(v, jnp.float32)
        return v[:, None] if v.ndim == 1 else v

    a_s, a_l, ws, wl = col(alpha_s), col(alpha_l), col(w_s), col(w_l)
    s = a_s * counts + (1 - a_s) * ewma_s
    l = a_l * counts + (1 - a_l) * ewma_l
    return s, l, ws * s + wl * l
