"""Dispatch layer for the fused interval fast path.

Routing (resolved once per process via ``kernels/_backend``):

  * TPU backend           -> compiled Pallas kernels (kernel.py);
  * ``REPRO_FORCE_INTERPRET`` -> interpret-mode Pallas kernels — the
    validation route the kernel-vs-ref CI tests pin on CPU containers;
  * any other backend     -> the fused jnp references (ref.py).

The references are the kernels' bitwise contract, so the scan engine's
CRN equivalence guarantees hold on every route.  Unlike the other
``kernels/*/ops.py`` wrappers there is no per-call ``use_kernel`` flag:
the scan engine toggles the whole fused path at a higher level
(``use_interval_kernel``), and these ops always take the best route for
the backend.
"""
from __future__ import annotations

from repro.kernels._backend import force_interpret, interpret_mode
from repro.kernels.interval_step import kernel, ref


def _pallas() -> bool:
    """Route to the Pallas kernel (compiled on TPU, interpret if forced)?"""
    return force_interpret() or not interpret_mode()


def topk_mask(x, k: int):
    """Exact top-k bool mask of [B, n] rows (``lax.top_k`` tie rule)."""
    if _pallas():
        return kernel.topk_mask_kernel(x, k, interpret=interpret_mode())
    return ref.topk_mask_ref(x, k)


def tier_migrate(tier, promote, demote, caps):
    """Lane-batched hop-chain migrations; see simjax.apply_tier_migrations.

    Contract: valid (non ``-1``) entries within each lane's plan are
    unique page indices (the padded-index contract) — the sequential
    kernel and the vectorized reference only coincide under it.
    """
    if _pallas():
        return kernel.tier_migrate_kernel(tier, promote, demote, caps,
                                          interpret=interpret_mode())
    return ref.tier_migrate_ref(tier, promote, demote, caps)


def interval_account(mach, true, tier, mig_up, mig_down, oracle, k: int):
    """Fused interval accounting + oracle recall over lane-batched rows;
    ``mach`` is a lane-batched TieredMachineSpec."""
    if _pallas():
        return kernel.interval_account_kernel(
            mach.lat_ns, mach.bw_read, mach.bw_write, mach.mlp, true, tier,
            mig_up, mig_down, oracle, k, interpret=interpret_mode())
    return ref.interval_account_ref(mach, true, tier, mig_up, mig_down,
                                    oracle, k)


def ewma_score_update(ewma_s, ewma_l, counts, *, alpha_s, alpha_l, w_s,
                      w_l, use_kernel: bool = True):
    """Lane-batched dual-EWMA + hotness score ([B, n] arrays; params
    scalar or [B]).  ``use_kernel=False`` pins the jnp reference — the
    escape hatch ``ARMSConfig.use_score_kernel`` flips at config level."""
    if use_kernel and _pallas():
        return kernel.ewma_update_kernel(
            ewma_s, ewma_l, counts, alpha_s=alpha_s, alpha_l=alpha_l,
            w_s=w_s, w_l=w_l, interpret=interpret_mode())
    return ref.ewma_score_update_ref(
        ewma_s, ewma_l, counts, alpha_s=alpha_s, alpha_l=alpha_l,
        w_s=w_s, w_l=w_l)
