"""Pure-jnp oracle for the fused ARMS score update (Alg. 1 lines 1-6)."""
from __future__ import annotations

import jax.numpy as jnp


def score_update_ref(ewma_s, ewma_l, counts, *, alpha_s, alpha_l, w_s, w_l):
    s = alpha_s * counts + (1 - alpha_s) * ewma_s
    l = alpha_l * counts + (1 - alpha_l) * ewma_l
    return s, l, w_s * s + w_l * l
