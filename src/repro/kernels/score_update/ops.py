"""Public wrapper for the fused ARMS score update.

This is the controller's real hot path: ``core.classifier.update_scores``
routes through here (kernel by default, interpret-mode on non-TPU backends;
``use_kernel=False`` selects the pure-jnp reference — the escape hatch
``ARMSConfig.use_score_kernel=False`` flips at the config level).
"""
from __future__ import annotations

from repro.kernels._backend import interpret_mode
from repro.kernels.score_update.kernel import score_update_kernel
from repro.kernels.score_update.ref import score_update_ref


def score_update(ewma_s, ewma_l, counts, *, alpha_s, alpha_l, w_s, w_l,
                 use_kernel: bool = True):
    if not use_kernel:
        return score_update_ref(ewma_s, ewma_l, counts, alpha_s=alpha_s,
                                alpha_l=alpha_l, w_s=w_s, w_l=w_l)
    return score_update_kernel(ewma_s, ewma_l, counts, alpha_s=alpha_s,
                               alpha_l=alpha_l, w_s=w_s, w_l=w_l,
                               interpret=interpret_mode())
