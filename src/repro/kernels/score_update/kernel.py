"""Pallas TPU kernel: fused dual-EWMA + hotness-score update.

At framework scale the ARMS controller tracks millions of pages (KV pages
across layers x sequences); this fuses the three elementwise passes of
Alg. 1 into one VMEM-resident sweep (one read of each EWMA + the counts,
one write of each output) — memory-bound, so fusion is the whole win.
Tiles are (8, 512) f32 over a 2-D folded view of the page array.

The smoothing/weight scalars arrive as a (4,) f32 SMEM operand rather than
compile-time constants: on the controller's real path the score weights are
mode-dependent traced values (recency vs history, §4.2), and tuning sweeps
vmap over them — so they must be data, not static kwargs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROWS, COLS = 8, 512


def _kernel(p_ref, s_ref, l_ref, c_ref, s_out, l_out, score_out):
    alpha_s, alpha_l, w_s, w_l = p_ref[0], p_ref[1], p_ref[2], p_ref[3]
    c = c_ref[...]
    s = alpha_s * c + (1 - alpha_s) * s_ref[...]
    ll = alpha_l * c + (1 - alpha_l) * l_ref[...]
    s_out[...] = s
    l_out[...] = ll
    score_out[...] = w_s * s + w_l * ll


@functools.partial(jax.jit, static_argnames=("interpret",))
def score_update_kernel(ewma_s, ewma_l, counts, *, alpha_s, alpha_l, w_s,
                        w_l, interpret: bool = True):
    n = ewma_s.shape[0]
    tile = ROWS * COLS
    n_pad = -(-n // tile) * tile
    pad = n_pad - n

    def fold(x):
        return jnp.pad(x, (0, pad)).reshape(n_pad // COLS, COLS)

    params = jnp.stack([jnp.asarray(v, jnp.float32)
                        for v in (alpha_s, alpha_l, w_s, w_l)])
    grid = (n_pad // tile,)
    spec = pl.BlockSpec((ROWS, COLS), lambda i: (i, 0))
    outs = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n_pad // COLS, COLS), jnp.float32)
                   for _ in range(3)],
        interpret=interpret,
    )(params, fold(ewma_s), fold(ewma_l), fold(counts))
    return tuple(o.reshape(n_pad)[:n] for o in outs)
